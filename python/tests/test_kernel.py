"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

This is the CORE correctness signal of the compile path: the kernels lower
(interpret=True) into the AOT HLO that the rust runtime executes, so kernel
== oracle here implies the served numerics match the paper's definitions.

Hypothesis sweeps shapes / bitwidths / scales; fixed seeds keep CI stable.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (dorefa_act, dorefa_weight, max_abs_tanh,
                             quant_matmul, waveq_reg, wrpn_weight)
from compile.kernels import ref
from compile.kernels.waveq_reg import make_waveq_reg

RTOL, ATOL = 1e-4, 1e-5


def rnd(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype("float32") * scale)


# ---------------------------------------------------------------------------
# waveq_reg
# ---------------------------------------------------------------------------

class TestWaveqReg:
    @pytest.mark.parametrize("shape", [(7,), (64,), (33, 5), (8, 8, 3, 4), (1025,)])
    @pytest.mark.parametrize("beta", [1.5, 3.0, 4.7, 8.0])
    def test_value_matches_oracle(self, shape, beta):
        w = rnd(shape, seed=hash((shape, beta)) % 2**31)
        got = waveq_reg(w, beta)
        want = ref.waveq_reg(w, beta)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("norm", [0, 1, 2])
    def test_all_normalization_variants(self, norm):
        w = rnd((129, 3), seed=norm)
        beta = jnp.float32(3.3)
        got = waveq_reg(w, beta, norm=norm)
        want = ref.waveq_reg(w, beta, norm=norm)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_zero_on_grid(self):
        # Weights exactly on the sin^2 minima: R must vanish.
        beta = 3.0
        k = 2.0**beta - 1.0
        w = jnp.asarray([i / k for i in range(-int(k), int(k) + 1)], jnp.float32)
        assert float(waveq_reg(w, beta)) < 1e-10

    def test_maximal_off_grid(self):
        beta = 3.0
        k = 2.0**beta - 1.0
        w = jnp.asarray([0.5 / k], jnp.float32)  # midpoint between levels
        r = float(waveq_reg(w, beta)) * 2.0**beta
        np.testing.assert_allclose(r, 1.0, rtol=1e-5)

    @pytest.mark.parametrize("beta", [2.0, 3.5, 6.0])
    def test_grad_w_matches_analytic_and_autodiff(self, beta):
        w = rnd((300,), seed=3)
        b = jnp.float32(beta)
        gw = jax.grad(lambda w: waveq_reg(w, b))(w)
        np.testing.assert_allclose(gw, ref.waveq_reg_grad_w(w, b), rtol=1e-3, atol=1e-5)
        gw_auto = jax.grad(lambda w: ref.waveq_reg(w, b))(w)
        np.testing.assert_allclose(gw, gw_auto, rtol=1e-3, atol=1e-5)

    @pytest.mark.parametrize("norm", [0, 1, 2])
    def test_grad_beta_matches_autodiff(self, norm):
        w = rnd((257,), seed=norm + 10, scale=0.5)
        f_kernel = make_waveq_reg(norm)
        for beta in [1.7, 3.0, 4.2]:
            b = jnp.float32(beta)
            gb = jax.grad(lambda b: f_kernel(w, b))(b)
            gb_auto = jax.grad(lambda b: ref.waveq_reg(w, b, norm=norm))(b)
            np.testing.assert_allclose(gb, gb_auto, rtol=1e-3, atol=1e-5)

    @given(
        n=st.integers(1, 3000),
        beta=st.floats(1.1, 8.0),
        scale=st.floats(0.01, 3.0),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=30, deadline=None)
    def test_hypothesis_sweep(self, n, beta, scale, seed):
        w = rnd((n,), seed=seed, scale=scale)
        got = waveq_reg(w, beta)
        want = ref.waveq_reg(w, jnp.float32(beta))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)

    def test_under_jit(self):
        w = rnd((100,))
        f = jax.jit(lambda w, b: waveq_reg(w, b))
        np.testing.assert_allclose(f(w, 3.0), ref.waveq_reg(w, 3.0), rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# dorefa
# ---------------------------------------------------------------------------

class TestDorefa:
    @pytest.mark.parametrize("bits", [2, 3, 4, 5, 8])
    @pytest.mark.parametrize("shape", [(11,), (64, 64), (3, 3, 8, 16)])
    def test_weight_matches_oracle(self, bits, shape):
        w = rnd(shape, seed=bits)
        k = float(2**bits - 1)
        np.testing.assert_allclose(
            dorefa_weight(w, k), ref.dorefa_weight(w, k), rtol=RTOL, atol=ATOL
        )

    def test_weight_range_and_levels(self, bits=3):
        w = rnd((1000,), seed=1, scale=2.0)
        k = float(2**bits - 1)
        m = float(max_abs_tanh(w))
        q = np.asarray(dorefa_weight(w, k))
        assert q.min() >= -m - 1e-6 and q.max() <= m + 1e-6
        # Every output must be on the grid m * (2i - k)/k.
        lev = np.round((q / m + 1.0) * k / 2.0)
        np.testing.assert_allclose(q, m * (lev * 2.0 / k - 1.0), atol=1e-5)

    def test_weight_ste_gradient(self):
        w = rnd((200,), seed=2)
        k = 7.0
        g = jax.grad(lambda w: jnp.sum(dorefa_weight(w, k) * 3.0))(w)
        want = 3.0 * (1.0 - jnp.tanh(w) ** 2)
        np.testing.assert_allclose(g, want, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_act_matches_oracle(self, bits):
        x = rnd((77, 13), seed=bits, scale=1.5)
        k = float(2**bits - 1)
        np.testing.assert_allclose(dorefa_act(x, k), ref.dorefa_act(x, k), rtol=RTOL, atol=ATOL)

    def test_act_ste_masks_out_of_range(self):
        x = jnp.asarray([-0.5, 0.25, 0.75, 1.5], jnp.float32)
        g = jax.grad(lambda x: jnp.sum(dorefa_act(x, 15.0)))(x)
        np.testing.assert_allclose(g, [0.0, 1.0, 1.0, 0.0], atol=1e-6)

    @given(
        n=st.integers(1, 2000),
        bits=st.integers(2, 8),
        scale=st.floats(0.05, 4.0),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_sweep(self, n, bits, scale, seed):
        w = rnd((n,), seed=seed, scale=scale)
        k = float(2**bits - 1)
        np.testing.assert_allclose(
            dorefa_weight(w, k), ref.dorefa_weight(w, k), rtol=1e-3, atol=1e-5
        )


# ---------------------------------------------------------------------------
# wrpn
# ---------------------------------------------------------------------------

class TestWrpn:
    @pytest.mark.parametrize("bits", [2, 3, 5])
    def test_matches_oracle(self, bits):
        w = rnd((501,), seed=bits, scale=1.5)
        k = float(2**bits - 1)
        np.testing.assert_allclose(wrpn_weight(w, k), ref.wrpn_weight(w, k), rtol=RTOL, atol=ATOL)

    def test_extremes_map_to_scale(self):
        w = jnp.asarray([-5.0, 5.0, 0.1], jnp.float32)
        q = np.asarray(wrpn_weight(w, 7.0))
        np.testing.assert_allclose(q[:2], [-5.0, 5.0], atol=1e-5)  # c = max|W| = 5

    def test_outputs_on_scaled_grid(self):
        w = rnd((400,), seed=9)
        k = 7.0
        m = float(np.max(np.abs(np.asarray(w))))
        q = np.asarray(wrpn_weight(w, k))
        j = np.round((q / m + 1.0) * k / 2.0)
        np.testing.assert_allclose(q, m * (2.0 * j / k - 1.0), atol=1e-5)

    def test_ste_gradient_is_identity_within_scale(self):
        w = jnp.asarray([-0.9, -0.5, 0.5, 1.0], jnp.float32)
        g = jax.grad(lambda w: jnp.sum(wrpn_weight(w, 7.0)))(w)
        np.testing.assert_allclose(g, [1.0, 1.0, 1.0, 1.0], atol=1e-6)


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------

class TestQuantMatmul:
    @pytest.mark.parametrize(
        "m,k,n", [(4, 8, 4), (32, 64, 16), (128, 128, 128), (65, 200, 33), (1, 7, 1)]
    )
    def test_matches_oracle(self, m, k, n):
        x = rnd((m, k), seed=m * n)
        w = rnd((k, n), seed=m + n)
        kq = 15.0
        mm = max_abs_tanh(w)
        got = quant_matmul(x, w, kq)
        want = ref.quant_matmul(x, w, kq, mm)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_gradients_match_manual_ste(self):
        x = rnd((16, 32), seed=5)
        w = rnd((32, 8), seed=6)
        kq = 7.0

        def loss(x, w):
            return jnp.sum(quant_matmul(x, w, kq) ** 2)

        gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
        mm = max_abs_tanh(w)
        wq = ref.dorefa_weight(w, kq, mm)
        g = 2.0 * (x @ wq)
        np.testing.assert_allclose(gx, g @ wq.T, rtol=1e-3, atol=1e-3)
        want_gw = (x.T @ g) * (1.0 - jnp.tanh(w) ** 2)
        np.testing.assert_allclose(gw, want_gw, rtol=1e-3, atol=1e-3)

    @given(
        m=st.integers(1, 80),
        k=st.integers(1, 150),
        n=st.integers(1, 80),
        bits=st.integers(2, 8),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_shapes(self, m, k, n, bits, seed):
        x = rnd((m, k), seed=seed)
        w = rnd((k, n), seed=seed + 1)
        kq = float(2**bits - 1)
        got = quant_matmul(x, w, kq)
        want = ref.quant_matmul(x, w, kq, max_abs_tanh(w))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
