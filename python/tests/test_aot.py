"""AOT pipeline: HLO-text emission + manifest integrity.

Guards the python->rust contract: HLO must be text (the 0.5.1-compatible
interchange), entry signatures in the manifest must match the lowered
programs, and MAC/param metadata must be complete for the energy model.
"""

import json
import os

import jax
import pytest

from compile import models as zoo
from compile import train_step as ts
from compile.aot import model_json, to_hlo_text


class TestHloText:
    def test_emits_parseable_hlo_text(self):
        m = zoo.get_model("mlp")
        prog = ts.make_eval(m, 8, None)
        lowered = jax.jit(prog.fn).lower(*prog.arg_specs)
        text = to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "f32" in text
        # return_tuple=True => root is a tuple
        assert "tuple(" in text or ") tuple" in text or "(f32" in text

    def test_waveq_program_contains_sine(self):
        m = zoo.get_model("mlp")
        prog = ts.make_train_waveq(m, 8)
        text = to_hlo_text(jax.jit(prog.fn).lower(*prog.arg_specs))
        assert "sine" in text  # the sinusoidal regularizer survived lowering

    def test_quant_program_contains_round(self):
        m = zoo.get_model("mlp")
        prog = ts.make_train_quant(m, 8, "dorefa")
        text = to_hlo_text(jax.jit(prog.fn).lower(*prog.arg_specs))
        assert "round-nearest" in text or "round" in text


class TestManifestContract:
    def test_program_signature_lengths(self):
        m = zoo.get_model("simplenet5")
        prog = ts.make_train_waveq(m, 16)
        assert len(prog.in_names) == len(prog.arg_specs)
        # inputs: 2P + beta,vbeta,x,y + 7 scalars
        assert len(prog.in_names) == 2 * m.num_params + 4 + 7
        # outputs: 2P + beta,vbeta + loss,acc,ce,reg_w
        assert len(prog.out_names) == 2 * m.num_params + 2 + 4

    def test_model_json_complete(self):
        m = zoo.get_model("resnet20l")
        j = model_json(m, 64, 1)
        assert j["num_qlayers"] == m.num_qlayers
        assert len(j["params"]) == m.num_params
        qidxs = sorted(p["qidx"] for p in j["params"] if p["qidx"] is not None)
        assert qidxs == list(range(m.num_qlayers))
        for p in j["params"]:
            if p["kind"] in ("conv", "dwconv", "fc"):
                assert p["macs"] > 0
            assert p["count"] == int(
                __import__("numpy").prod(p["shape"]) if p["shape"] else 1
            )

    def test_state_name_prefixes(self):
        m = zoo.get_model("mlp")
        prog = ts.make_train_fp32(m, 8)
        ws = [n for n in prog.in_names if n.startswith("w:")]
        vs = [n for n in prog.in_names if n.startswith("v:")]
        assert len(ws) == len(vs) == m.num_params
        # outputs echo the same state names so rust can re-feed positionally
        assert prog.out_names[: m.num_params] == ws


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
class TestBuiltArtifacts:
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        with open(path) as f:
            return json.load(f), os.path.dirname(path)

    def test_every_program_file_exists_and_is_text(self):
        man, root = self.manifest()
        for name, p in man["programs"].items():
            fp = os.path.join(root, p["file"])
            assert os.path.exists(fp), f"missing artifact for {name}"
            head = open(fp, "rb").read(200)
            assert b"HloModule" in head, f"{name} is not HLO text"

    def test_every_program_model_exists(self):
        man, _ = self.manifest()
        for name, p in man["programs"].items():
            if p["model"] is not None:
                assert p["model"] in man["models"], name

    def test_kw_inputs_match_model_qlayers(self):
        man, _ = self.manifest()
        for name, p in man["programs"].items():
            if p["model"] is None:
                continue
            model = man["models"][p["model"]]
            for a in p["inputs"]:
                if a["name"] in ("kw", "beta", "vbeta"):
                    assert a["shape"] == [model["num_qlayers"]], (name, a)
