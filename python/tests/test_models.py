"""L2 model zoo: shape correctness, quantization-slot policy, MAC accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models as zoo
from compile.layers import QuantCtx

ALL = list(zoo.ZOO.keys())


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes(name):
    m = zoo.get_model(name)
    params = m.init(0)
    h, w, c = m.input_shape
    x = jnp.zeros((4, h, w, c), jnp.float32)
    logits = m.apply(params, x, QuantCtx())
    assert logits.shape == (4, m.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL)
def test_quantized_forward_shapes(name):
    m = zoo.get_model(name)
    params = m.init(1)
    h, w, c = m.input_shape
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, h, w, c)).astype("float32"))
    kw = jnp.full((m.num_qlayers,), 7.0, jnp.float32)
    ka = jnp.float32(15.0)
    logits = m.apply(params, x, QuantCtx(kw=kw, ka=ka, quantizer="dorefa"))
    assert logits.shape == (2, m.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL)
def test_first_and_last_layers_not_quantized(name):
    m = zoo.get_model(name)
    compute = [s for s in m.specs if s.kind in ("conv", "dwconv", "fc")]
    assert compute[0].qidx is None, "first layer must stay fp32 (paper §4.1)"
    assert compute[-1].qidx is None, "last layer must stay fp32 (paper §4.1)"
    # interior layers all quantized, slots contiguous from 0
    interior = [s.qidx for s in compute[1:-1]]
    assert all(q is not None for q in interior)
    assert sorted(interior) == list(range(m.num_qlayers))


@pytest.mark.parametrize("name", ALL)
def test_mac_counts_positive_for_compute_layers(name):
    m = zoo.get_model(name)
    for s in m.specs:
        if s.kind in ("conv", "dwconv", "fc"):
            assert s.macs > 0, f"{s.name} has zero MACs"
        else:
            assert s.macs == 0


def test_width_multiplier_scales_params():
    base = zoo.get_model("simplenet5")
    wide = zoo.get_model("simplenet5", width_mult=2)
    n_base = sum(int(np.prod(s.shape)) for s in base.specs)
    n_wide = sum(int(np.prod(s.shape)) for s in wide.specs)
    assert n_wide > 2.5 * n_base  # conv params scale ~quadratically in width


def test_resnet_residual_paths_change_output():
    m = zoo.get_model("resnet20l")
    params = m.init(3)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 16, 16, 3)).astype("float32"))
    y1 = m.apply(params, x, QuantCtx())
    # Zero a mid-block conv: residual shortcut keeps signal flowing (finite, different)
    idx = m.qlayer_param_indices[2]
    params2 = list(params)
    params2[idx] = jnp.zeros_like(params2[idx])
    y2 = m.apply(params2, x, QuantCtx())
    assert bool(jnp.all(jnp.isfinite(y2)))
    assert not np.allclose(np.asarray(y1), np.asarray(y2))


def test_grads_flow_to_all_params():
    m = zoo.get_model("simplenet5")
    params = m.init(0)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(8, 16, 16, 3)).astype("float32"))
    y = jax.nn.one_hot(jnp.arange(8) % 10, 10)
    kw = jnp.full((m.num_qlayers,), 7.0)

    def loss(ps):
        logits = m.apply(ps, x, QuantCtx(kw=kw, ka=jnp.float32(15.0)))
        return -jnp.mean(jnp.sum(y * jax.nn.log_softmax(logits), axis=-1))

    grads = jax.grad(loss)(params)
    for g, s in zip(grads, m.specs):
        assert bool(jnp.all(jnp.isfinite(g))), f"non-finite grad for {s.name}"
        if s.kind in ("conv", "fc"):
            assert float(jnp.max(jnp.abs(g))) > 0, f"zero grad for {s.name}"
