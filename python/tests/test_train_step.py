"""L2 train/eval step semantics: optimizer updates, STE wiring, the WaveQ
joint objective (beta learning + freeze flag), and loss-decrease smoke."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models as zoo
from compile import train_step as ts
from compile.losses import waveq_penalty
from compile.optim import clip_beta, sgd_momentum


def batch_for(model, batch, seed=0):
    rng = np.random.default_rng(seed)
    h, w, c = model.input_shape
    x = jnp.asarray(rng.normal(size=(batch, h, w, c)).astype("float32"))
    labels = rng.integers(0, model.num_classes, batch)
    y = jax.nn.one_hot(jnp.asarray(labels), model.num_classes, dtype=jnp.float32)
    return x, y


class TestOptim:
    def test_sgd_momentum_formula(self):
        p = [jnp.asarray([1.0, 2.0])]
        v = [jnp.asarray([0.5, -0.5])]
        g = [jnp.asarray([0.1, 0.2])]
        np_, nv = sgd_momentum(p, v, g, 0.1, 0.9)
        np.testing.assert_allclose(nv[0], [0.55, -0.25], rtol=1e-6)
        np.testing.assert_allclose(np_[0], [1.0 - 0.055, 2.0 + 0.025], rtol=1e-6)

    def test_clip_beta_bounds(self):
        b = jnp.asarray([0.0, 4.0, 99.0])
        out = np.asarray(clip_beta(b))
        assert out[0] > 1.0 and out[2] == 8.0 and out[1] == 4.0


class TestWaveqStep:
    @pytest.fixture(scope="class")
    def setup(self):
        m = zoo.get_model("mlp")
        prog = ts.make_train_waveq(m, 32)
        return m, prog, jax.jit(prog.fn)

    def make_args(self, m, prog, lam_w=0.5, lam_b=0.01, flag=1.0, beta0=4.0, seed=0):
        params = m.init(seed)
        vels = [jnp.zeros_like(p) for p in params]
        x, y = batch_for(m, 32, seed)
        beta = jnp.full((m.num_qlayers,), beta0, jnp.float32)
        vbeta = jnp.zeros((m.num_qlayers,), jnp.float32)
        scal = lambda v: jnp.float32(v)
        return (params + vels
                + [beta, vbeta, x, y, scal(0.05), scal(0.9), scal(0.02), scal(15.0),
                   scal(lam_w), scal(lam_b), scal(flag)])

    def test_output_arity_matches_manifest(self, setup):
        m, prog, fn = setup
        out = fn(*self.make_args(m, prog))
        assert len(out) == len(prog.out_names)

    def test_loss_decreases_over_steps(self, setup):
        m, prog, fn = setup
        args = self.make_args(m, prog, lam_w=0.0, lam_b=0.0, flag=0.0)
        P = m.num_params
        first = None
        for _ in range(12):
            out = fn(*args)
            loss = float(out[prog.out_names.index("loss")])
            if first is None:
                first = loss
            # thread state: params, vels, beta, vbeta
            args[: 2 * P + 2] = list(out[: 2 * P + 2])
        assert loss < first, f"{first} -> {loss}"

    def test_beta_frozen_when_flag_zero(self, setup):
        m, prog, fn = setup
        out = fn(*self.make_args(m, prog, flag=0.0, lam_b=0.05))
        beta_new = np.asarray(out[prog.out_names.index("beta")])
        np.testing.assert_allclose(beta_new, 4.0, atol=1e-6)

    def test_beta_decreases_under_bit_penalty(self, setup):
        m, prog, fn = setup
        # With flag=1 and lambda_beta > 0, dE/dbeta includes +lambda_beta,
        # so beta must strictly decrease.
        out = fn(*self.make_args(m, prog, lam_w=0.0, lam_b=0.1, flag=1.0))
        beta_new = np.asarray(out[prog.out_names.index("beta")])
        assert (beta_new < 4.0).all()

    def test_reg_loss_reported_and_nonnegative(self, setup):
        m, prog, fn = setup
        out = fn(*self.make_args(m, prog, lam_w=1.0))
        regw = float(out[prog.out_names.index("reg_w")])
        assert regw >= 0.0
        ce = float(out[prog.out_names.index("ce")])
        loss = float(out[prog.out_names.index("loss")])
        assert loss == pytest.approx(ce + 1.0 * regw + 0.01 * 2 * 4.0, rel=1e-3)

    def test_waveq_penalty_zero_when_weights_on_grid(self):
        m = zoo.get_model("mlp")
        params = m.init(0)
        beta = jnp.full((m.num_qlayers,), 3.0, jnp.float32)
        qws = [params[i] for i in m.qlayer_param_indices]
        base = float(waveq_penalty(qws, beta))
        assert base > 0  # random weights are off-grid
        # Snap normalized weights to the grid -> penalty ~0:
        k = 7.0
        snapped = []
        for w in qws:
            t = jnp.tanh(w)
            mm = jnp.max(jnp.abs(t))
            v = t / (2 * mm) + 0.5
            vq = jnp.round(v * k) / k
            snapped.append(jnp.arctanh((vq - 0.5) * 2 * mm * 0.999999))
        assert float(waveq_penalty(snapped, beta)) < 1e-4


class TestQuantStep:
    def test_dorefa_step_runs_and_improves(self):
        m = zoo.get_model("mlp")
        prog = ts.make_train_quant(m, 32, "dorefa")
        fn = jax.jit(prog.fn)
        params = m.init(1)
        vels = [jnp.zeros_like(p) for p in params]
        x, y = batch_for(m, 32, 1)
        kw = jnp.full((m.num_qlayers,), 15.0, jnp.float32)
        args = (params + vels
                + [x, y, jnp.float32(0.05), jnp.float32(0.9), kw, jnp.float32(15.0)])
        P = m.num_params
        losses = []
        for _ in range(10):
            out = fn(*args)
            losses.append(float(out[prog.out_names.index("loss")]))
            args[: 2 * P] = list(out[: 2 * P])
        assert losses[-1] < losses[0]

    def test_eval_consistent_with_train_metrics(self):
        m = zoo.get_model("mlp")
        eval_prog = ts.make_eval(m, 32, "dorefa")
        fn = jax.jit(eval_prog.fn)
        params = m.init(2)
        x, y = batch_for(m, 32, 2)
        kw = jnp.full((m.num_qlayers,), 7.0, jnp.float32)
        loss, acc = fn(*params, x, y, kw, jnp.float32(15.0))
        assert np.isfinite(float(loss))
        assert 0.0 <= float(acc) <= 1.0

    def test_quant_eval_weight_distortion_shrinks_with_bits(self):
        # (fp32 eval is NOT the high-bit limit of quant eval: DoReFa's
        # activation quantizer clips to [0,1] regardless of k. Instead check
        # that the *weight* quantization distortion vanishes as bits grow:
        # logits-loss at W8 must be closer to W20 ~= no weight rounding than
        # W2 is.)
        m = zoo.get_model("mlp")
        params = m.init(3)
        x, y = batch_for(m, 32, 3)
        lq = jax.jit(ts.make_eval(m, 32, "dorefa").fn)
        ka = jnp.float32(2.0**20 - 1)
        def loss_at(bits):
            kw = jnp.full((m.num_qlayers,), 2.0**bits - 1, jnp.float32)
            loss, _ = lq(*params, x, y, kw, ka)
            return float(loss)
        ref20 = loss_at(20)
        d2 = abs(loss_at(2) - ref20)
        d8 = abs(loss_at(8) - ref20)
        assert d8 < d2, f"W8 distortion {d8} should be < W2 distortion {d2}"
        assert d8 < 0.05


class TestRegProfile:
    def test_shapes_and_variant_ordering(self):
        prog = ts.make_reg_profile(n_w=32, n_b=16)
        fn = jax.jit(prog.fn)
        w = jnp.linspace(-1, 1, 32)
        b = jnp.linspace(1, 8, 16)
        outs = fn(w, b)
        assert len(outs) == 9
        for o in outs:
            assert o.shape == (32, 16)
        # r_n0 >= r_n1 >= r_n2 pointwise (denominators 1, 2^b, 4^b).
        r0, r1, r2 = np.asarray(outs[0]), np.asarray(outs[3]), np.asarray(outs[6])
        assert (r0 + 1e-9 >= r1).all() and (r1 + 1e-9 >= r2).all()
