"""Fused train/eval step builders — the programs that get AOT-lowered.

Every program takes/returns a *flat positional* signature (manifest-described)
so the rust coordinator can keep the whole train state device-resident and
re-feed outputs as next-step inputs without host round-trips:

  train_fp32  : [w*P, v*P, x, y, lr, mom]                        -> [w'*P, v'*P, loss, acc]
  train_dorefa: [w*P, v*P, x, y, lr, mom, kw(Q,), ka]            -> [w'*P, v'*P, loss, acc]
  train_wrpn  : same as dorefa (on the width-multiplied model)
  train_waveq : [w*P, v*P, beta(Q,), vbeta(Q,), x, y, lr, mom,
                 lr_beta, ka, lambda_w, lambda_beta, beta_train] -> [w'*P, v'*P, beta', vbeta',
                                                                     loss, acc, ce, reg_w]
  eval_fp32   : [w*P, x, y]                                      -> [loss, acc]
  eval_quant  : [w*P, x, y, kw(Q,), ka]                          -> [loss, acc]   (dorefa)
  eval_wrpn   : [w*P, x, y, kw(Q,), ka]                          -> [loss, acc]   (wrpn)

Notes tying back to the paper:
  * 'waveq' quantizes the forward pass with the *continuous* levels
    kw_i = 2**beta_i - 1 derived from the live beta vector, so the same HLO
    serves preset mode (beta frozen, beta_train=0) and learned mode
    (beta_train=1) — Eq. 2.2's joint optimization.
  * beta's gradient comes only from the sinusoidal regularizer + lambda_beta
    (the STE quantizer contributes none) — exactly the mechanism of §2.2.
  * beta is clipped to (1, 8] after the update (b = ceil(beta) in [2, 8]).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import QuantCtx
from .losses import accuracy, cross_entropy, waveq_penalty
from .models import Model
from .optim import clip_beta, sgd_momentum

SCALAR = jax.ShapeDtypeStruct((), jnp.float32)


def _vec(n):
    return jax.ShapeDtypeStruct((n,), jnp.float32)


def _param_specs(model: Model):
    return [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.specs]


def _batch_specs(model: Model, batch: int):
    h, w, c = model.input_shape
    return (
        jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32),
        jax.ShapeDtypeStruct((batch, model.num_classes), jnp.float32),
    )


def _qweights(model: Model, params):
    return [params[i] for i in model.qlayer_param_indices]


class Program:
    """A lowered-to-be program: fn + arg specs + I/O names for the manifest."""

    def __init__(self, name, fn, arg_specs, in_names, out_names):
        self.name = name
        self.fn = fn
        self.arg_specs = arg_specs
        self.in_names = in_names
        self.out_names = out_names


def _state_names(model: Model, prefix: str) -> list[str]:
    return [f"{prefix}:{s.name}" for s in model.specs]


def make_train_fp32(model: Model, batch: int) -> Program:
    P = model.num_params

    def step(*args):
        params, vels = list(args[:P]), list(args[P : 2 * P])
        x, y, lr, mom = args[2 * P : 2 * P + 4]

        def loss_fn(ps):
            logits = model.apply(ps, x, QuantCtx())
            return cross_entropy(logits, y), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        acc = accuracy(logits, y)
        new_p, new_v = sgd_momentum(params, vels, grads, lr, mom)
        return tuple(new_p) + tuple(new_v) + (loss, acc)

    x, y = _batch_specs(model, batch)
    specs = _param_specs(model) * 2 + [x, y, SCALAR, SCALAR]
    in_names = _state_names(model, "w") + _state_names(model, "v") + ["x", "y", "lr", "mom"]
    out_names = _state_names(model, "w") + _state_names(model, "v") + ["loss", "acc"]
    return Program(f"train_fp32_{model.name}", step, specs, in_names, out_names)


def make_train_quant(model: Model, batch: int, quantizer: str) -> Program:
    """Plain quantized training (DoReFa or WRPN): preset kw vector input."""
    P, Q = model.num_params, model.num_qlayers

    def step(*args):
        params, vels = list(args[:P]), list(args[P : 2 * P])
        x, y, lr, mom, kw, ka = args[2 * P : 2 * P + 6]

        def loss_fn(ps):
            logits = model.apply(ps, x, QuantCtx(kw=kw, ka=ka, quantizer=quantizer))
            return cross_entropy(logits, y), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        acc = accuracy(logits, y)
        new_p, new_v = sgd_momentum(params, vels, grads, lr, mom)
        return tuple(new_p) + tuple(new_v) + (loss, acc)

    x, y = _batch_specs(model, batch)
    specs = _param_specs(model) * 2 + [x, y, SCALAR, SCALAR, _vec(Q), SCALAR]
    in_names = (_state_names(model, "w") + _state_names(model, "v")
                + ["x", "y", "lr", "mom", "kw", "ka"])
    out_names = _state_names(model, "w") + _state_names(model, "v") + ["loss", "acc"]
    return Program(f"train_{quantizer}_{model.name}", step, specs, in_names, out_names)


def make_train_waveq(model: Model, batch: int, norm: int = 1) -> Program:
    """DoReFa backbone + WaveQ sinusoidal regularizer, learned-or-preset beta."""
    P, Q = model.num_params, model.num_qlayers

    def step(*args):
        params, vels = list(args[:P]), list(args[P : 2 * P])
        (beta, vbeta, x, y, lr, mom, lr_beta, ka,
         lam_w, lam_beta, beta_train) = args[2 * P : 2 * P + 11]

        def loss_fn(ps, b):
            kw = 2.0**b - 1.0
            logits = model.apply(ps, x, QuantCtx(kw=kw, ka=ka, quantizer="dorefa"))
            ce = cross_entropy(logits, y)
            reg_w = waveq_penalty(_qweights(model, ps), b, norm=norm)
            loss = ce + lam_w * reg_w + lam_beta * jnp.sum(b)
            return loss, (ce, reg_w, logits)

        (loss, (ce, reg_w, logits)), (gp, gb) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(params, beta)
        acc = accuracy(logits, y)
        new_p, new_v = sgd_momentum(params, vels, gp, lr, mom)
        # The coordinator's freeze flag gates the bitwidth update (phase 3).
        gb = gb * beta_train
        new_vbeta = mom * vbeta + gb
        new_beta = clip_beta(beta - lr_beta * new_vbeta)
        return (tuple(new_p) + tuple(new_v)
                + (new_beta, new_vbeta, loss, acc, ce, reg_w))

    x, y = _batch_specs(model, batch)
    specs = (_param_specs(model) * 2
             + [_vec(Q), _vec(Q), x, y] + [SCALAR] * 7)
    in_names = (_state_names(model, "w") + _state_names(model, "v")
                + ["beta", "vbeta", "x", "y", "lr", "mom", "lr_beta", "ka",
                   "lambda_w", "lambda_beta", "beta_train"])
    out_names = (_state_names(model, "w") + _state_names(model, "v")
                 + ["beta", "vbeta", "loss", "acc", "ce", "reg_w"])
    suffix = "" if norm == 1 else f"_n{norm}"
    return Program(f"train_waveq_{model.name}{suffix}", step, specs, in_names, out_names)


def make_eval(model: Model, batch: int, quantizer: str | None) -> Program:
    """Quantized or fp32 evaluation: [loss, acc] over one batch."""
    P, Q = model.num_params, model.num_qlayers

    if quantizer is None:
        def step(*args):
            params = list(args[:P])
            x, y = args[P], args[P + 1]
            logits = model.apply(params, x, QuantCtx())
            return cross_entropy(logits, y), accuracy(logits, y)

        x, y = _batch_specs(model, batch)
        specs = _param_specs(model) + [x, y]
        in_names = _state_names(model, "w") + ["x", "y"]
        return Program(f"eval_fp32_{model.name}", step, specs, in_names, ["loss", "acc"])

    def step(*args):
        params = list(args[:P])
        x, y, kw, ka = args[P : P + 4]
        logits = model.apply(params, x, QuantCtx(kw=kw, ka=ka, quantizer=quantizer))
        return cross_entropy(logits, y), accuracy(logits, y)

    x, y = _batch_specs(model, batch)
    specs = _param_specs(model) + [x, y, _vec(Q), SCALAR]
    in_names = _state_names(model, "w") + ["x", "y", "kw", "ka"]
    tag = "quant" if quantizer == "dorefa" else quantizer
    return Program(f"eval_{tag}_{model.name}", step, specs, in_names, ["loss", "acc"])


def make_reg_profile(n_w: int = 512, n_b: int = 256) -> Program:
    """R_k(w, beta) + d/dbeta + d^2/dbeta^2 grids for Figures 2 & 3.

    Inputs: w grid (n_w,), beta grid (n_b,). Outputs, for k in {0,1,2}:
    Rk, dRk/dbeta, d2Rk/dbeta2, each (n_w, n_b). Pointwise (no layer mean) —
    exactly the curves plotted in the paper's Figure 3.
    """

    def pointwise(w, b, norm):
        k = 2.0**b - 1.0
        s = jnp.sin(jnp.pi * w * k)
        return s * s / 2.0 ** (norm * b)

    def step(wgrid, bgrid):
        outs = []
        for norm in (0, 1, 2):
            f = lambda w, b: pointwise(w, b, norm)
            d1 = jax.grad(f, argnums=1)
            d2 = jax.grad(lambda w, b: d1(w, b), argnums=1)
            mk = lambda g: jax.vmap(lambda w: jax.vmap(lambda b: g(w, b))(bgrid))(wgrid)
            outs.extend([mk(f), mk(d1), mk(d2)])
        return tuple(outs)

    specs = [_vec(n_w), _vec(n_b)]
    out_names = [f"{q}_n{k}" for k in (0, 1, 2) for q in ("r", "d1", "d2")]
    return Program("reg_profile", step, specs, ["wgrid", "bgrid"], out_names)
