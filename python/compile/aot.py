"""AOT pipeline: lower every program to HLO *text* + write the manifest.

HLO text — NOT ``lowered.compiler_ir("hlo").as_hlo_text()`` on new jax, and
NOT serialized protos — is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids which xla_extension 0.5.1 (the version behind
the published ``xla`` rust crate) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Run from python/:  python -m compile.aot --out ../artifacts [--models mlp,...]

Emits:
  artifacts/<program>.hlo.txt     one per program
  artifacts/manifest.json         signatures, param layouts, MACs, batch sizes
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import models as zoo
from . import train_step as ts

# Per-model batch sizes (train == eval so one batch shape serves both).
BATCH = {
    "mlp": 128,
    "simplenet5": 64, "resnet20l": 64, "vgg11l": 64, "svhn8": 64,
    "alexnetl": 32, "resnet18l": 32, "mobilenetl": 32,
}

# Dataset each model trains on (`data::synth` spec names on the Rust side).
# Carried in the manifest because input shape alone is ambiguous: cifar-lite
# and svhn-lite are both 16x16x3/10-way.
DATASET = {
    "mlp": "mlp-lite",
    "simplenet5": "cifar-lite", "resnet20l": "cifar-lite", "vgg11l": "cifar-lite",
    "svhn8": "svhn-lite",
    "alexnetl": "imagenet-lite", "resnet18l": "imagenet-lite", "mobilenetl": "imagenet-lite",
}

# WRPN width multiplier (the paper's WRPN-2x configuration).
WRPN_WIDTH = 2

# Models that get WRPN programs (Table 1 + Table 2 comparisons).
WRPN_MODELS = zoo.TABLE2_MODELS + zoo.TABLE1_MODELS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def model_json(model: zoo.Model, batch: int, width_mult: int) -> dict:
    base = model.name.removesuffix(f"_w{width_mult}")
    return {
        "name": model.name,
        "dataset": DATASET[base],
        "input_shape": list(model.input_shape),
        "num_classes": model.num_classes,
        "batch": batch,
        "width_mult": width_mult,
        "num_qlayers": model.num_qlayers,
        "params": [
            {
                "name": s.name,
                "shape": list(s.shape),
                "kind": s.kind,
                "init": s.init,
                "qidx": s.qidx,
                "macs": s.macs,
                "count": model.weight_count(s),
            }
            for s in model.specs
        ],
    }


def lower_program(prog: ts.Program, out_dir: str, manifest: dict, model_key: str | None):
    t0 = time.time()
    lowered = jax.jit(prog.fn).lower(*prog.arg_specs)
    text = to_hlo_text(lowered)
    fname = f"{prog.name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    manifest["programs"][prog.name] = {
        "file": fname,
        "model": model_key,
        "inputs": [
            {"name": n, **spec_json(s)} for n, s in zip(prog.in_names, prog.arg_specs)
        ],
        "outputs": prog.out_names,
    }
    print(f"  {prog.name}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s")


def programs_for_model(name: str) -> list[tuple[ts.Program, str]]:
    """All programs for one base model; returns (program, model_key) pairs."""
    batch = BATCH[name]
    base = zoo.get_model(name)
    out: list[tuple[ts.Program, str]] = [
        (ts.make_train_fp32(base, batch), name),
        (ts.make_train_quant(base, batch, "dorefa"), name),
        (ts.make_train_waveq(base, batch), name),
        (ts.make_eval(base, batch, None), name),
        (ts.make_eval(base, batch, "dorefa"), name),
    ]
    if name in WRPN_MODELS or name == "mlp":
        wide = zoo.get_model(name, width_mult=WRPN_WIDTH)
        wide.name = f"{name}_w{WRPN_WIDTH}"
        out.append((ts.make_train_quant(wide, batch, "wrpn"), wide.name))
        out.append((ts.make_eval(wide, batch, "wrpn"), wide.name))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(zoo.ZOO.keys()),
                    help="comma-separated subset of the zoo")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: dict = {"programs": {}, "models": {}}
    names = [n for n in args.models.split(",") if n]
    t0 = time.time()
    for name in names:
        print(f"[aot] {name}")
        batch = BATCH[name]
        base = zoo.get_model(name)
        manifest["models"][name] = model_json(base, batch, 1)
        if name in WRPN_MODELS or name == "mlp":
            wide = zoo.get_model(name, width_mult=WRPN_WIDTH)
            wide.name = f"{name}_w{WRPN_WIDTH}"
            manifest["models"][wide.name] = model_json(wide, batch, WRPN_WIDTH)
        for prog, model_key in programs_for_model(name):
            lower_program(prog, args.out, manifest, model_key)

    print("[aot] reg_profile")
    lower_program(ts.make_reg_profile(), args.out, manifest, None)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done: {len(manifest['programs'])} programs in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
