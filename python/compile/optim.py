"""SGD with momentum — the paper's training backbone, in flat-list form.

The AOT interchange keeps state as a flat list of arrays (weights then
velocities) so the rust coordinator can hold it device-resident and feed it
positionally; see DESIGN.md §5.

Gradients are clipped by global L2 norm before the momentum update: the
residual/affine-only-normalization models (resnet*l) can produce exploding
early gradients that a single step turns into NaNs — clipping makes every
(model, lr) cell of Tables 1/2 train out of the box, the same role BN +
warmup play in the paper's Distiller setup.
"""

from __future__ import annotations

import jax.numpy as jnp

GRAD_CLIP_NORM = 5.0


def clip_by_global_norm(grads, max_norm=GRAD_CLIP_NORM):
    """Scale the gradient list so its global L2 norm is <= max_norm."""
    total = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
    scale = jnp.minimum(1.0, max_norm / total)
    return [g * scale for g in grads]


def sgd_momentum(params, vels, grads, lr, momentum, clip=True):
    """v' = mu*v + clip(g) ; w' = w - lr*v'  (returns (params', vels'))."""
    if clip:
        grads = clip_by_global_norm(grads)
    new_vels = [momentum * v + g for v, g in zip(vels, grads)]
    new_params = [w - lr * v for w, v in zip(params, new_vels)]
    return new_params, new_vels


def clip_beta(beta: jnp.ndarray, lo: float = 1.0, hi: float = 8.0) -> jnp.ndarray:
    """Keep the continuous bitwidth parameter in its meaningful range.

    b = ceil(beta) must land in [2, 8] (paper Fig. 5 reports 2..8-bit
    assignments), so beta lives in (1, 8].
    """
    return jnp.clip(beta, lo + 1e-3, hi)
