"""Facade kept for discoverability: the L2 model definitions live in
``models.py`` (zoo), ``layers.py`` (DSL), ``train_step.py`` (programs).
"""

from .models import ZOO, Model, get_model  # noqa: F401
from .train_step import (make_eval, make_train_fp32, make_train_quant,  # noqa: F401
                         make_train_waveq)
