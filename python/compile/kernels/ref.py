"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth for correctness: pytest (and hypothesis sweeps)
compare each Pallas kernel against the oracle with ``assert_allclose``.
They are also the "roofline reference" used in the §Perf analysis: the
kernels must not lose accuracy relative to these definitions.

Conventions (paper, §2.2 "Quantizer", Eq. 2.3):
  * ``k = 2**b - 1`` quantization levels over [0, 1] (``quantize_k``);
  * DoReFa weights are tanh-normalized into [0, 1], quantized, then mapped
    back to [-1, 1];
  * WaveQ regularizer (Eq. 2.2 / Eq. 2.5):
        R_norm(w; beta) = mean_j sin^2(pi * w_j * (2**beta - 1)) / 2**(norm*beta)
    with ``norm`` in {0, 1, 2} selecting the Figure-3 variant (the paper's
    production choice is norm=1).  We use *mean* over j rather than the
    paper's sum so that lambda_w is independent of layer size (a per-layer
    constant absorbed into lambda_w; see DESIGN.md §6).
"""

from __future__ import annotations

import jax.numpy as jnp

LN2 = 0.6931471805599453


def quantize_k(x: jnp.ndarray, k) -> jnp.ndarray:
    """Linear quantizer over [0, 1] with k steps: round(x*k)/k  (Eq. 2.3)."""
    return jnp.round(x * k) / k


def waveq_reg(w: jnp.ndarray, beta, norm: int = 1) -> jnp.ndarray:
    """WaveQ sinusoidal regularizer for one layer (scalar).

    R = mean_j sin^2(pi * w_j * (2**beta - 1)) / 2**(norm * beta)
    """
    k = 2.0**beta - 1.0
    s = jnp.sin(jnp.pi * w * k)
    return jnp.mean(s * s) / 2.0 ** (norm * beta)


def waveq_reg_grad_w(w, beta, norm: int = 1):
    """Analytic dR/dw for one layer: sin(2 pi w k) * pi k / (N * 2**(norm b))."""
    k = 2.0**beta - 1.0
    n = w.size
    return jnp.sin(2.0 * jnp.pi * w * k) * (jnp.pi * k) / (n * 2.0 ** (norm * beta))


def waveq_reg_grad_beta(w, beta, norm: int = 1):
    """Analytic dR/dbeta for one layer (scalar).

    d/dbeta [ sin^2(pi w k) 2^{-n beta} ]
      = sin(2 pi w k) * pi w * ln2 * 2^beta * 2^{-n beta}
        - n ln2 sin^2(pi w k) 2^{-n beta},   with k = 2^beta - 1
    """
    k = 2.0**beta - 1.0
    two_nb = 2.0 ** (norm * beta)
    s = jnp.sin(jnp.pi * w * k)
    term1 = jnp.sin(2.0 * jnp.pi * w * k) * jnp.pi * w * LN2 * 2.0**beta
    term2 = norm * LN2 * s * s
    return jnp.mean(term1 - term2) / two_nb


def dorefa_weight(w: jnp.ndarray, k, max_abs_tanh=None) -> jnp.ndarray:
    """DoReFa-Net weight quantizer (Eq. 2.3) with per-layer scale c = m
    (§2.2 "Quantizer": w_q = c * w_qo maps quantized weights to [-c, +c]).

    w_q = m * (2 * quantize_k( tanh(w) / (2 m) + 1/2 ) - 1),  m = max|tanh(W)|
    """
    t = jnp.tanh(w)
    m = jnp.max(jnp.abs(t)) if max_abs_tanh is None else max_abs_tanh
    return m * (2.0 * quantize_k(t / (2.0 * m) + 0.5, k) - 1.0)


def dorefa_act(x: jnp.ndarray, k) -> jnp.ndarray:
    """DoReFa activation quantizer: quantize_k(clip(x, 0, 1))."""
    return quantize_k(jnp.clip(x, 0.0, 1.0), k)


def wrpn_weight(w: jnp.ndarray, k, max_abs=None) -> jnp.ndarray:
    """WRPN weight quantizer with per-layer scale c = max|W| (see wrpn.py):
    w_q = m * (2 * quantize_k(clip(w, -m, m)/(2m) + 1/2) - 1)."""
    m = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) if max_abs is None else max_abs
    return m * (quantize_k(jnp.clip(w, -m, m) / (2.0 * m) + 0.5, k) * 2.0 - 1.0)


def quant_matmul(x: jnp.ndarray, w: jnp.ndarray, k, max_abs_tanh) -> jnp.ndarray:
    """x @ dorefa_weight(w) — the fused fake-quant matmul reference."""
    return x @ dorefa_weight(w, k, max_abs_tanh)
