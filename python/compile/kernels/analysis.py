"""Structural performance analysis of the Pallas kernels (DESIGN.md §8/§9).

interpret=True means CPU wallclock is NOT a TPU proxy, so the L1 perf
deliverable is analytic: per-kernel VMEM footprint, arithmetic intensity and
MXU/VPU utilization estimates derived from the BlockSpecs, reported against
TPU roofline numbers. Run:

    python -m compile.kernels.analysis

The output is recorded in EXPERIMENTS.md §Perf (L1).
"""

from __future__ import annotations

import dataclasses

from .common import TILE
from .quant_matmul import DEF_BK, DEF_BM, DEF_BN

# TPU v4-ish reference constants (per core).
VMEM_BYTES = 16 * 2**20          # 16 MiB VMEM
MXU_FLOPS_PER_CYCLE = 2 * 128 * 128  # one 128x128 MAC array, 2 flops/MAC
VPU_LANES = 8 * 128              # vector unit lanes
HBM_BW_BYTES_PER_CYCLE = 1229    # ~1.2 TB/s at ~1 GHz

F32 = 4


@dataclasses.dataclass
class KernelReport:
    name: str
    vmem_bytes: int
    flops_per_block: float
    bytes_per_block: float

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_block / self.bytes_per_block

    def utilization(self, peak_flops_per_cycle: float) -> float:
        """Fraction of peak sustained if HBM feeds the block stream."""
        cycles_mem = self.bytes_per_block / HBM_BW_BYTES_PER_CYCLE
        cycles_compute = self.flops_per_block / peak_flops_per_cycle
        return cycles_compute / max(cycles_compute, cycles_mem)


def waveq_reg_report() -> KernelReport:
    """Elementwise sin^2 + reduction over (1, TILE) blocks.

    VMEM: one input tile + one partial-sum cell (+ beta scalar).
    FLOPs: sin (~8 flop equiv on the VPU transcendental unit), mul, add
    per element in fwd; we count 12/elem.
    """
    vmem = TILE * F32 + F32 + F32
    flops = 12.0 * TILE
    # Streamed: read TILE f32, write 1 partial.
    bytes_moved = TILE * F32 + F32
    return KernelReport("waveq_reg fwd", vmem, flops, bytes_moved)


def waveq_reg_bwd_report() -> KernelReport:
    vmem = 2 * TILE * F32 + 3 * F32
    flops = 20.0 * TILE  # sin + cos path, two outputs
    bytes_moved = 2 * TILE * F32 + F32
    return KernelReport("waveq_reg bwd", vmem, flops, bytes_moved)


def dorefa_report() -> KernelReport:
    vmem = 2 * TILE * F32 + 2 * F32
    flops = 10.0 * TILE  # tanh + scale + round + scale
    bytes_moved = 2 * TILE * F32
    return KernelReport("dorefa_weight", vmem, flops, bytes_moved)


def quant_matmul_report(bm: int = DEF_BM, bk: int = DEF_BK, bn: int = DEF_BN) -> KernelReport:
    """Fused dequant + MXU block product.

    VMEM: x(bm,bk) + w(bk,bn) + acc(bm,bn).
    FLOPs: 2*bm*bk*bn MACs + 10*bk*bn dequant epilogue.
    Bytes per block-step: stream x-tile + w-tile (acc stays resident).
    In a real int4/int8 deployment the w-tile bytes shrink by 4-8x — that
    is the TPU translation of the paper's bit-serial saving (DESIGN.md §8).
    """
    vmem = (bm * bk + bk * bn + bm * bn) * F32
    flops = 2.0 * bm * bk * bn + 10.0 * bk * bn
    bytes_moved = (bm * bk + bk * bn) * F32
    return KernelReport(f"quant_matmul {bm}x{bk}x{bn}", vmem, flops, bytes_moved)


def main() -> None:
    print(f"{'kernel':<28} {'VMEM':>10} {'%VMEM':>7} {'AI':>8} {'util_est':>9}")
    for rep, peak in [
        (waveq_reg_report(), VPU_LANES * 2),
        (waveq_reg_bwd_report(), VPU_LANES * 2),
        (dorefa_report(), VPU_LANES * 2),
        (quant_matmul_report(), MXU_FLOPS_PER_CYCLE),
        (quant_matmul_report(256, 128, 128), MXU_FLOPS_PER_CYCLE),
        (quant_matmul_report(128, 256, 128), MXU_FLOPS_PER_CYCLE),
        (quant_matmul_report(32, 32, 32), MXU_FLOPS_PER_CYCLE),
    ]:
        print(
            f"{rep.name:<28} {rep.vmem_bytes:>10} {100*rep.vmem_frac:>6.2f}% "
            f"{rep.arithmetic_intensity:>8.2f} {100*rep.utilization(peak):>8.1f}%"
        )
    print(
        "\nnotes: AI = flops/byte per block; util_est = compute-bound fraction"
        "\nassuming HBM-streamed tiles; int-packed weights would cut the"
        "\nquant_matmul weight-tile bytes 4-8x (the paper's bit-serial saving"
        "\nmapped to HBM->VMEM traffic)."
    )


if __name__ == "__main__":
    main()
