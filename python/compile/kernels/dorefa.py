"""DoReFa-Net quantizers (Zhou et al., 2016) as Pallas kernels with STE.

Weight path (paper Eq. 2.3 plus the per-layer scale c of §2.2 "Quantizer"):
    t    = tanh(w),  m = max|tanh(W)|
    w_qo = 2 * quantize_k( t / (2 m) + 1/2 ) - 1                  in [-1, 1]
    w_q  = c * w_qo,   c = m
    quantize_k(x) = round(x * k) / k,   k = 2**b - 1

The paper's Quantizer paragraph is explicit that "a scaling factor c is
determined per layer to map the final quantized weight w_q into the range
[-c, +c]"; we take c = m so quantization is a pure snap-to-grid at the
latent weight scale. (Without c — mapping onto the full [-1, 1] — every
layer's forward gain is multiplied by 1/m, which compounds across depth and
collapses training when the normalization layers are affine-only; Distiller
relies on full BatchNorm to absorb that gain.)

The full-tensor ``m`` is a cheap XLA reduction computed outside the kernel
and passed in as a scalar, so the kernel itself is a single elementwise pass
(one VMEM round-trip on TPU).

Backward (straight-through estimator, the standard DoReFa rule): the round()
is treated as identity and ``m`` as a constant, giving

    dw_q/dw = (1 - tanh(w)^2)       [the m's cancel: m * (1/m) * tanh']
    dw_q/dk = 0   (STE erases the quantizer-step dependence; in WaveQ the
                   bitwidth gradient flows through the sinusoidal regularizer
                   instead — that is the point of the paper)

Activation path: a_q = quantize_k(clip(x, 0, 1)), STE masked to [0, 1].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE, pad_to_tiles, rows_per_block, unpad_from_tiles


def _wq_kernel(k_ref, m_ref, w_ref, out_ref):
    k = k_ref[0]
    m = m_ref[0]
    x = jnp.tanh(w_ref[...]) * (0.5 / m) + 0.5
    out_ref[...] = m * (2.0 * (jnp.round(x * k) / k) - 1.0)


def _wq_bwd_kernel(m_ref, g_ref, w_ref, dw_ref):
    t = jnp.tanh(w_ref[...])
    # c = m cancels the 1/m of the normalization under STE.
    dw_ref[...] = g_ref[...] * (1.0 - t * t)


def _aq_kernel(k_ref, x_ref, out_ref):
    k = k_ref[0]
    x = jnp.clip(x_ref[...], 0.0, 1.0)
    out_ref[...] = jnp.round(x * k) / k


def _aq_bwd_kernel(g_ref, x_ref, dx_ref):
    x = x_ref[...]
    mask = jnp.logical_and(x >= 0.0, x <= 1.0).astype(jnp.float32)
    dx_ref[...] = g_ref[...] * mask


def _scalar_spec():
    return pl.BlockSpec((1,), lambda i: (0,))


def _tile_spec(rows: int):
    return pl.BlockSpec((rows_per_block(rows), TILE), lambda i: (i, 0))


def _elementwise_call(kernel, scalars, x2d):
    """Run an elementwise kernel over (n_rows, TILE) with scalar operands."""
    rows = x2d.shape[0]
    grid = rows // rows_per_block(rows)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[_scalar_spec() for _ in scalars] + [_tile_spec(rows)],
        out_specs=_tile_spec(rows),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.float32),
        interpret=True,
    )(*[s.reshape(1) for s in scalars], x2d)


def max_abs_tanh(w: jnp.ndarray) -> jnp.ndarray:
    """max|tanh(W)| with a floor to avoid division blow-up at init."""
    return jnp.maximum(jnp.max(jnp.abs(jnp.tanh(w))), 1e-8)


@jax.custom_vjp
def _dorefa_weight(w, k, m):
    w2d, n = pad_to_tiles(w)
    q2d = _elementwise_call(_wq_kernel, [k, m], w2d)
    return unpad_from_tiles(q2d, n, w.shape)


def _dorefa_weight_fwd(w, k, m):
    return _dorefa_weight(w, k, m), (w, m)


def _dorefa_weight_bwd(res, g):
    w, m = res
    w2d, n = pad_to_tiles(w)
    g2d, _ = pad_to_tiles(g)
    rows = w2d.shape[0]
    dw2d = pl.pallas_call(
        _wq_bwd_kernel,
        grid=(rows // rows_per_block(rows),),
        in_specs=[_scalar_spec(), _tile_spec(rows), _tile_spec(rows)],
        out_specs=_tile_spec(rows),
        out_shape=jax.ShapeDtypeStruct(w2d.shape, jnp.float32),
        interpret=True,
    )(m.reshape(1), g2d, w2d)
    return unpad_from_tiles(dw2d, n, w.shape), None, None


_dorefa_weight.defvjp(_dorefa_weight_fwd, _dorefa_weight_bwd)


def dorefa_weight(w: jnp.ndarray, k) -> jnp.ndarray:
    """Fake-quantize a weight tensor with k = 2**b - 1 levels (STE backward)."""
    w = w.astype(jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    m = jax.lax.stop_gradient(max_abs_tanh(w))
    return _dorefa_weight(w, k, m)


@jax.custom_vjp
def _dorefa_act(x, k):
    x2d, n = pad_to_tiles(x)
    q2d = _elementwise_call(_aq_kernel, [k], x2d)
    return unpad_from_tiles(q2d, n, x.shape)


def _dorefa_act_fwd(x, k):
    return _dorefa_act(x, k), (x,)


def _dorefa_act_bwd(res, g):
    (x,) = res
    x2d, n = pad_to_tiles(x)
    g2d, _ = pad_to_tiles(g)
    rows = x2d.shape[0]
    dx2d = pl.pallas_call(
        _aq_bwd_kernel,
        grid=(rows // rows_per_block(rows),),
        in_specs=[_tile_spec(rows), _tile_spec(rows)],
        out_specs=_tile_spec(rows),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.float32),
        interpret=True,
    )(g2d, x2d)
    return unpad_from_tiles(dx2d, n, x.shape), None


_dorefa_act.defvjp(_dorefa_act_fwd, _dorefa_act_bwd)


def dorefa_act(x: jnp.ndarray, k) -> jnp.ndarray:
    """Fake-quantize activations to k = 2**a - 1 levels over [0, 1] (STE)."""
    return _dorefa_act(x.astype(jnp.float32), jnp.asarray(k, jnp.float32))
