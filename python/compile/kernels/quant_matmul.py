"""Fused fake-quant matmul: x @ dorefa_weight(w) as one MXU-tiled kernel.

This is the TPU adaptation of the paper's bit-serial compute story
(DESIGN.md §8): on Stripes the win from small bitwidths is serial cycles;
on TPU it is HBM->VMEM weight traffic. So the kernel streams *latent*
weights tile-by-tile into VMEM, fake-quantizes the tile in-register
(dequant fused into the matmul prologue — in a real int-packed deployment
only the compressed tile would cross HBM), and feeds the MXU-aligned
(bm, bk) x (bk, bn) product into an output accumulator.

Grid = (M/bm, N/bn, K/bk) with K innermost so each (i, j) output block stays
resident while K is reduced. Blocks default to 128x128 (MXU native);
smaller operands clamp the block to the operand size.

Backward (custom_vjp, STE through the quantizer — see dorefa.py):
    dx = g @ w_q^T          (w_q recomputed via the dorefa kernel)
    dw = (x^T @ g) * (1 - tanh(w)^2) / m
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .common import pad2d
from .dorefa import dorefa_weight, max_abs_tanh

# Block shape: multiples of the 128x128 MXU tile. 256-wide K blocks halve
# the grid length (and with it the interpret-mode while-loop overhead) while
# staying ~1 MiB/operand — within VMEM with double buffering (§Perf L1).
DEF_BM, DEF_BK, DEF_BN = 128, 256, 128


def _mm_kernel(k_ref, m_ref, x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    k = k_ref[0]
    m = m_ref[0]
    # Fake-quantize the weight tile in VMEM (Eq. 2.3 + scale c = m),
    # then hit the MXU.
    t = jnp.tanh(w_ref[...]) * (0.5 / m) + 0.5
    wq = m * (2.0 * (jnp.round(t * k) / k) - 1.0)
    o_ref[...] += jnp.dot(x_ref[...], wq, preferred_element_type=jnp.float32)


def _blocks(m: int, kdim: int, n: int) -> tuple[int, int, int]:
    return min(DEF_BM, m), min(DEF_BK, kdim), min(DEF_BN, n)


def _qmm(x: jnp.ndarray, w: jnp.ndarray, k: jnp.ndarray, m: jnp.ndarray):
    mm, kk = x.shape
    _, nn = w.shape
    bm, bk, bn = _blocks(mm, kk, nn)
    xp, wp = pad2d(x, bm, bk), pad2d(w, bk, bn)
    grid = (xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, kq: (0,)),
            pl.BlockSpec((1,), lambda i, j, kq: (0,)),
            pl.BlockSpec((bm, bk), lambda i, j, kq: (i, kq)),
            pl.BlockSpec((bk, bn), lambda i, j, kq: (kq, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kq: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=True,
    )(k.reshape(1), m.reshape(1), xp, wp)
    return out[:mm, :nn]


@jax.custom_vjp
def _quant_matmul(x, w, k, m):
    return _qmm(x, w, k, m)


def _quant_matmul_fwd(x, w, k, m):
    return _qmm(x, w, k, m), (x, w, k, m)


def _quant_matmul_bwd(res, g):
    x, w, k, m = res
    wq = ref.dorefa_weight(w, k, m)  # cheap recompute; fused by XLA
    dx = g @ wq.T
    t = jnp.tanh(w)
    # STE with c = m: the scale cancels the 1/m normalization.
    dw = (x.T @ g) * (1.0 - t * t)
    return dx, dw, None, None


_quant_matmul.defvjp(_quant_matmul_fwd, _quant_matmul_bwd)


def quant_matmul(x: jnp.ndarray, w: jnp.ndarray, k) -> jnp.ndarray:
    """x @ fake_quant(w) with k = 2**b - 1 levels; STE backward.

    x: (M, K) activations, w: (K, N) latent fp32 weights, k: scalar.
    """
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    m = jax.lax.stop_gradient(max_abs_tanh(w))
    return _quant_matmul(x, w, k, m)


def fp_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Full-precision counterpart (baseline path), plain XLA dot."""
    return x @ w
