"""WRPN quantizer (Mishra et al., 2018) as a Pallas kernel with STE.

WRPN compensates for reduced precision by widening layers (the width
multiplier lives in the model zoo, ``models.py``) and uses a clip +
linear-quantize rule for weights. As with DoReFa (see dorefa.py), the
original formulation maps onto the fixed range [-1, 1] and relies on full
BatchNorm to absorb the resulting per-layer gain; our affine-only
normalization cannot, so we apply the same per-layer scale c = max|W|
(paper §2.2 "Quantizer": w_q = c * w_qo in [-c, +c]):

    m   = max|W|
    w_q = m * ( 2 * quantize_k( clip(w, -m, m) / (2 m) + 1/2 ) - 1 )

Backward: straight-through (the clip never bites with m = max|W|, so the
gradient is the identity — WRPN's defining simplicity vs DoReFa's tanh).

Activations reuse the DoReFa activation quantizer (identical definition).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import pad_to_tiles, rows_per_block, unpad_from_tiles
from .dorefa import _elementwise_call, _scalar_spec, _tile_spec  # shared plumbing


def _wrpn_kernel(k_ref, m_ref, w_ref, out_ref):
    k = k_ref[0]
    m = m_ref[0]
    x = jnp.clip(w_ref[...], -m, m) * (0.5 / m) + 0.5
    out_ref[...] = m * ((jnp.round(x * k) / k) * 2.0 - 1.0)


def _wrpn_bwd_kernel(m_ref, g_ref, w_ref, dw_ref):
    w = w_ref[...]
    mask = (jnp.abs(w) <= m_ref[0]).astype(jnp.float32)
    dw_ref[...] = g_ref[...] * mask


def max_abs(w: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)


@jax.custom_vjp
def _wrpn_weight(w, k, m):
    w2d, n = pad_to_tiles(w)
    q2d = _elementwise_call(_wrpn_kernel, [k, m], w2d)
    return unpad_from_tiles(q2d, n, w.shape)


def _wrpn_weight_fwd(w, k, m):
    return _wrpn_weight(w, k, m), (w, m)


def _wrpn_weight_bwd(res, g):
    w, m = res
    w2d, n = pad_to_tiles(w)
    g2d, _ = pad_to_tiles(g)
    rows = w2d.shape[0]
    dw2d = pl.pallas_call(
        _wrpn_bwd_kernel,
        grid=(rows // rows_per_block(rows),),
        in_specs=[_scalar_spec(), _tile_spec(rows), _tile_spec(rows)],
        out_specs=_tile_spec(rows),
        out_shape=jax.ShapeDtypeStruct(w2d.shape, jnp.float32),
        interpret=True,
    )(m.reshape(1), g2d, w2d)
    return unpad_from_tiles(dw2d, n, w.shape), None, None


_wrpn_weight.defvjp(_wrpn_weight_fwd, _wrpn_weight_bwd)


def wrpn_weight(w: jnp.ndarray, k) -> jnp.ndarray:
    """Fake-quantize weights WRPN-style with k = 2**b - 1 levels (STE)."""
    w = w.astype(jnp.float32)
    m = jax.lax.stop_gradient(max_abs(w))
    return _wrpn_weight(w, jnp.asarray(k, jnp.float32), m)
