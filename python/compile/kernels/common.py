"""Shared tiling helpers for the Pallas kernels.

All elementwise/reduction kernels operate on a flattened view of the weight
tensor, padded to a multiple of ``TILE`` and reshaped to ``(n_rows, TILE)``.
``TILE = 8 * 128`` matches one VPU register tile (8 sublanes x 128 lanes) on
TPU; on the interpret path it is just a cache-friendly chunk.

Each grid step processes a block of ``BLOCK_ROWS`` rows (1 MiB of f32 —
comfortably within the ~16 MiB VMEM budget alongside double-buffering on a
real TPU). Fewer, larger grid steps matter doubly here: interpret-mode
Pallas lowers the grid to an XLA while-loop, so grid length is pure
per-iteration overhead on the CPU path (§Perf L1: moving from 1-row to
256-row blocks cut the waveq train step by >2x).

Padding is with zeros, which is *safe by construction* for every kernel in
this package: ``sin(0) = 0`` so padded elements contribute nothing to the
WaveQ regularizer or its gradients, and quantizer outputs on the padded tail
are sliced off before reshaping back.
"""

from __future__ import annotations

import jax.numpy as jnp

# One VPU tile: 8 sublanes x 128 lanes.
TILE = 8 * 128
# Rows per grid step: 256 * 1024 * 4 B = 1 MiB per operand block.
BLOCK_ROWS = 256


def pad_to_tiles(w: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Flatten ``w``, zero-pad so rows are a multiple of BLOCK_ROWS
    -> ((n_rows, TILE), n_true_elements)."""
    flat = w.reshape(-1)
    n = flat.size
    rows = -(-n // TILE)
    rows_padded = -(-rows // min(rows, BLOCK_ROWS)) * min(rows, BLOCK_ROWS)
    n_pad = rows_padded * TILE - n
    if n_pad:
        flat = jnp.concatenate([flat, jnp.zeros((n_pad,), flat.dtype)])
    return flat.reshape(-1, TILE), n


def rows_per_block(n_rows: int) -> int:
    """Block height for an (n_rows, TILE) operand (n_rows % result == 0)."""
    return min(n_rows, BLOCK_ROWS)


def unpad_from_tiles(tiles: jnp.ndarray, n: int, shape) -> jnp.ndarray:
    """Inverse of :func:`pad_to_tiles`."""
    return tiles.reshape(-1)[:n].reshape(shape)


def pad2d(x: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    """Zero-pad a 2-D array so both dims are multiples of (bm, bn)."""
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x
