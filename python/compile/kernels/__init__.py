"""Layer-1 Pallas kernels for WaveQ.

Public surface:
  * :func:`waveq_reg.waveq_reg`      — sinusoidal regularizer, d/dw and d/dbeta
  * :func:`dorefa.dorefa_weight`     — DoReFa weight fake-quantizer (STE)
  * :func:`dorefa.dorefa_act`        — DoReFa activation fake-quantizer (STE)
  * :func:`wrpn.wrpn_weight`         — WRPN weight fake-quantizer (STE)
  * :func:`quant_matmul.quant_matmul`— fused fake-quant matmul (MXU-tiled)
  * :mod:`ref`                        — pure-jnp oracles for all of the above

Everything here is build-time only: kernels lower (interpret=True) into the
HLO emitted by ``compile/aot.py`` and never run as Python at serving time.
"""

from .dorefa import dorefa_act, dorefa_weight, max_abs_tanh  # noqa: F401
from .quant_matmul import fp_matmul, quant_matmul  # noqa: F401
from .waveq_reg import waveq_reg  # noqa: F401
from .wrpn import wrpn_weight  # noqa: F401
