"""WaveQ sinusoidal regularizer as a Pallas kernel (the paper's Eq. 2.2/2.5).

For one layer with flattened weights ``w`` and continuous bitwidth parameter
``beta`` (the sinusoidal period parameter), the regularizer is

    R_norm(w; beta) = mean_j sin^2(pi * w_j * k) / 2**(norm * beta),
    k = 2**beta - 1

``norm`` in {0, 1, 2} selects the Figure-3 normalization variant; the paper's
production choice (free of vanishing/exploding gradients in beta) is norm=1.

The kernel is wrapped in a ``jax.custom_vjp`` with *analytic* gradients —
this is the heart of the paper: dR/dbeta is what makes the bitwidth a
learnable, continuous parameter of SGD, and dR/dw is what propels weights
toward the quantization grid (the sin^2 minima).

TPU shape (see DESIGN.md §8): the hot loop is elementwise + reduction, tiled
to (1, 8*128) VPU blocks over a (n_tiles, 1024) view; partial sums land in a
per-tile output accumulated by a final cheap ``jnp.sum``. ``sin`` maps to the
VPU transcendental unit. ``interpret=True`` everywhere: CPU PJRT cannot run
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE, pad_to_tiles, rows_per_block, unpad_from_tiles

LN2 = 0.6931471805599453
PI = 3.141592653589793


def _fwd_kernel(beta_ref, w_ref, out_ref):
    """Partial sum of sin^2(pi * w * k) for one (1, TILE) block."""
    k = 2.0 ** beta_ref[0] - 1.0
    s = jnp.sin(PI * w_ref[...] * k)
    out_ref[0] = jnp.sum(s * s)


def _bwd_kernel(norm: int, beta_ref, g_ref, w_ref, dw_ref, dbeta_ref):
    """Per-block dR/dw and the dR/dbeta partial sum.

    dR/dw_j    = g * sin(2 pi w_j k) * pi k / (N * 2**(norm beta))
    dR/dbeta  += g * [ sin(2 pi w k) pi w ln2 2**beta
                       - norm ln2 sin^2(pi w k) ] / (N * 2**(norm beta))
    (the 1/(N * 2**(norm beta)) factor is folded into ``g`` by the wrapper.)
    """
    beta = beta_ref[0]
    g = g_ref[0]
    k = 2.0**beta - 1.0
    w = w_ref[...]
    s2 = jnp.sin(2.0 * PI * w * k)
    dw_ref[...] = g * s2 * (PI * k)
    s = jnp.sin(PI * w * k)
    t1 = s2 * (PI * LN2) * w * 2.0**beta
    t2 = (norm * LN2) * s * s
    dbeta_ref[0] = g * jnp.sum(t1 - t2)


def _scalar_spec():
    # Whole (1,)-array visible to every grid step.
    return pl.BlockSpec((1,), lambda i: (0,))


def _reg_sum(w2d: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """sum_j sin^2(pi w_j (2^beta - 1)) over all blocks (un-normalized)."""
    rows = w2d.shape[0]
    rb = rows_per_block(rows)
    grid = rows // rb
    partials = pl.pallas_call(
        _fwd_kernel,
        grid=(grid,),
        in_specs=[_scalar_spec(), pl.BlockSpec((rb, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        interpret=True,
    )(beta.reshape(1), w2d)
    return jnp.sum(partials)


@functools.lru_cache(maxsize=None)
def make_waveq_reg(norm: int):
    """Build the custom-vjp regularizer function for one normalization variant."""

    @jax.custom_vjp
    def reg(w, beta):
        w2d, n = pad_to_tiles(w)
        return _reg_sum(w2d, beta) / (n * 2.0 ** (norm * beta))

    def reg_fwd(w, beta):
        return reg(w, beta), (w, beta)

    def reg_bwd(res, g):
        w, beta = res
        w2d, n = pad_to_tiles(w)
        rows = w2d.shape[0]
        rb = rows_per_block(rows)
        grid = rows // rb
        # Fold the shared 1/(N 2^{norm beta}) normalization into the cotangent.
        gn = (g / (n * 2.0 ** (norm * beta))).reshape(1)
        dw2d, dbeta_parts = pl.pallas_call(
            functools.partial(_bwd_kernel, norm),
            grid=(grid,),
            in_specs=[
                _scalar_spec(),
                _scalar_spec(),
                pl.BlockSpec((rb, TILE), lambda i: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((rb, TILE), lambda i: (i, 0)),
                pl.BlockSpec((1,), lambda i: (i,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct(w2d.shape, jnp.float32),
                jax.ShapeDtypeStruct((grid,), jnp.float32),
            ],
            interpret=True,
        )(beta.reshape(1), gn, w2d)
        dw = unpad_from_tiles(dw2d, n, w.shape)
        return dw, jnp.sum(dbeta_parts).reshape(beta.shape)

    reg.defvjp(reg_fwd, reg_bwd)
    return reg


def waveq_reg(w: jnp.ndarray, beta, norm: int = 1) -> jnp.ndarray:
    """Per-layer WaveQ regularizer (scalar), differentiable in w AND beta."""
    beta = jnp.asarray(beta, jnp.float32)
    return make_waveq_reg(norm)(w.astype(jnp.float32), beta)
