"""Layer DSL for the WaveQ model zoo (build-time JAX, never on the run path).

A model is a list of :class:`Op` nodes. Each op knows how to
  * ``init``  — create its parameters (He/Glorot style),
  * ``apply`` — run forward given a :class:`QuantCtx`, and
  * report metadata (param shapes, per-example MACs, whether it is a
    *quantizable* layer, i.e. owns a per-layer bitwidth slot beta_i).

Quantization policy (paper §4.1): all conv + FC layers are quantized
*except the first and last* layers of the network, which stay full
precision. The model builder marks those automatically.

Activations: ReLU followed by the DoReFa activation quantizer (clip to
[0,1] + linear quantize) when the program quantizes activations. Normal
layers use "affine" (per-channel scale+bias) instead of full BatchNorm so
that the AOT train step carries no running-stat state (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import dorefa_act, dorefa_weight, quant_matmul, wrpn_weight


@dataclasses.dataclass
class QuantCtx:
    """Per-program quantization context threaded through ``apply``.

    kw:   per-quant-layer weight quantization levels (2**b - 1), or None -> fp32
    ka:   scalar activation levels (2**a - 1), or None -> fp32 activations
    quantizer: 'dorefa' | 'wrpn' (weight quantizer family)
    """

    kw: Optional[jnp.ndarray] = None
    ka: Optional[jnp.ndarray] = None
    quantizer: str = "dorefa"

    def weight_q(self, w: jnp.ndarray, qidx: Optional[int]) -> jnp.ndarray:
        if self.kw is None or qidx is None:
            return w
        k = self.kw[qidx]
        if self.quantizer == "wrpn":
            return wrpn_weight(w, k)
        return dorefa_weight(w, k)

    def act_q(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.ka is None:
            return x
        # DoReFa's activation quantizer assumes activations pre-bounded to
        # [0, 1] (their nets bound them; Distiller's use BN). Ours are
        # affine+ReLU outputs, so quantize in units of the batch max — the
        # same per-layer scale treatment as the weight quantizer (§2.2
        # "Quantizer"): a_q = m * quantize_k(clip(a/m, 0, 1)).
        m = jax.lax.stop_gradient(jnp.maximum(jnp.max(x), 1e-6))
        return m * dorefa_act(x / m, self.ka)


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple
    init: str  # 'he' | 'ones' | 'zeros'
    qidx: Optional[int] = None  # slot in the per-layer bitwidth vector, if quantized
    kind: str = "other"  # conv | dwconv | fc | affine | bias
    macs: int = 0  # per-example MACs attributable to this parameter


class Op:
    """Base class: stateless ops override ``apply`` only."""

    def param_specs(self, builder) -> list[ParamSpec]:
        return []

    def apply(self, params: list, x: jnp.ndarray, ctx: QuantCtx) -> jnp.ndarray:
        raise NotImplementedError


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def init_param(key, spec: ParamSpec) -> jnp.ndarray:
    if spec.init == "ones":
        return jnp.ones(spec.shape, jnp.float32)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, jnp.float32)
    if spec.kind in ("conv", "dwconv"):
        fan_in = spec.shape[0] * spec.shape[1] * spec.shape[2]
    else:  # fc
        fan_in = spec.shape[0]
    w = _he(key, spec.shape, fan_in)
    if spec.init == "he_res":  # near-identity residual block at init
        w = w * 0.1
    return w


class Conv(Op):
    """3x3/kxk conv, HWIO kernel, no bias (affine follows)."""

    def __init__(self, cout: int, ksize: int = 3, stride: int = 1, quant: bool = True, pad: str = "SAME"):
        self.cout, self.ksize, self.stride, self.quant, self.pad = cout, ksize, stride, quant, pad

    def param_specs(self, b) -> list[ParamSpec]:
        cin = b.channels
        h, w = b.spatial
        ho = -(-h // self.stride) if self.pad == "SAME" else (h - self.ksize) // self.stride + 1
        wo = -(-w // self.stride) if self.pad == "SAME" else (w - self.ksize) // self.stride + 1
        macs = ho * wo * self.ksize * self.ksize * cin * self.cout
        spec = ParamSpec(
            name=f"conv{b.next_id('conv')}",
            shape=(self.ksize, self.ksize, cin, self.cout),
            init="he",
            qidx="pending" if self.quant else None,  # resolved by the builder
            kind="conv",
            macs=macs,
        )
        b.channels = self.cout
        b.spatial = (ho, wo)
        return [spec]

    def apply(self, params, x, ctx):
        (w,) = params
        wq = ctx.weight_q(w, self._qidx)
        return lax.conv_general_dilated(
            x, wq,
            window_strides=(self.stride, self.stride),
            padding=self.pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )


class DWConv(Op):
    """Depthwise conv (MobileNet building block)."""

    def __init__(self, ksize: int = 3, stride: int = 1, quant: bool = True):
        self.ksize, self.stride, self.quant = ksize, stride, quant

    def param_specs(self, b) -> list[ParamSpec]:
        c = b.channels
        h, w = b.spatial
        ho, wo = -(-h // self.stride), -(-w // self.stride)
        macs = ho * wo * self.ksize * self.ksize * c
        spec = ParamSpec(
            name=f"dwconv{b.next_id('dwconv')}",
            shape=(self.ksize, self.ksize, 1, c),
            init="he",
            qidx="pending" if self.quant else None,
            kind="dwconv",
            macs=macs,
        )
        b.spatial = (ho, wo)
        return [spec]

    def apply(self, params, x, ctx):
        (w,) = params
        wq = ctx.weight_q(w, self._qidx)
        c = x.shape[-1]
        return lax.conv_general_dilated(
            x, wq,
            window_strides=(self.stride, self.stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=c,
        )


class FC(Op):
    """Fully-connected layer; quantized path uses the fused Pallas quant_matmul."""

    def __init__(self, cout: int, quant: bool = True, bias: bool = True):
        self.cout, self.quant, self.bias = cout, quant, bias

    def param_specs(self, b) -> list[ParamSpec]:
        cin = b.flat_dim()
        specs = [
            ParamSpec(
                name=f"fc{b.next_id('fc')}",
                shape=(cin, self.cout),
                init="he",
                qidx="pending" if self.quant else None,
                kind="fc",
                macs=cin * self.cout,
            )
        ]
        if self.bias:
            specs.append(ParamSpec(f"{specs[0].name}_b", (self.cout,), "zeros", None, "bias", 0))
        b.set_flat(self.cout)
        return specs

    def apply(self, params, x, ctx):
        w = params[0]
        if ctx.kw is not None and self._qidx is not None:
            if ctx.quantizer == "wrpn":
                out = x @ wrpn_weight(w, ctx.kw[self._qidx])
            else:
                out = quant_matmul(x, w, ctx.kw[self._qidx])
        else:
            out = x @ w
        if self.bias:
            out = out + params[1]
        return out


class Affine(Op):
    """Per-channel scale + bias ("BN-lite": no running stats in the AOT state)."""

    def param_specs(self, b) -> list[ParamSpec]:
        c = b.channels
        i = b.next_id("affine")
        return [
            ParamSpec(f"affine{i}_s", (c,), "ones", None, "affine", 0),
            ParamSpec(f"affine{i}_b", (c,), "zeros", None, "affine", 0),
        ]

    def apply(self, params, x, ctx):
        s, bb = params
        return x * s + bb


class ReLU(Op):
    """ReLU followed by activation fake-quantization when the program asks."""

    def apply(self, params, x, ctx):
        return ctx.act_q(jax.nn.relu(x))


class MaxPool(Op):
    def __init__(self, size: int = 2):
        self.size = size

    def param_specs(self, b):
        h, w = b.spatial
        b.spatial = (h // self.size, w // self.size)
        return []

    def apply(self, params, x, ctx):
        s = self.size
        return lax.reduce_window(x, -jnp.inf, lax.max, (1, s, s, 1), (1, s, s, 1), "VALID")


class GlobalAvgPool(Op):
    def param_specs(self, b):
        b.spatial = (1, 1)
        return []

    def apply(self, params, x, ctx):
        return jnp.mean(x, axis=(1, 2), keepdims=True)


class Flatten(Op):
    def param_specs(self, b):
        b.flatten()
        return []

    def apply(self, params, x, ctx):
        return x.reshape(x.shape[0], -1)


class Residual(Op):
    """Pre-built residual block: main branch ops + optional projection shortcut."""

    def __init__(self, body: list[Op], project: Optional[Conv] = None):
        self.body = body
        self.project = project
        self._slices: list[tuple[int, int]] = []

    def param_specs(self, b) -> list[ParamSpec]:
        specs: list[ParamSpec] = []
        in_spatial, in_channels = b.spatial, b.channels
        for op in self.body:
            s = op.param_specs(b)
            self._slices.append((len(specs), len(s)))
            specs.extend(s)
        # Fixup-style: the last conv of the body initializes near zero so the
        # block starts as (almost) identity — without real BatchNorm this is
        # what keeps signal alive through deep residual stacks (resnet18l's
        # 8-block chain dies at init otherwise).
        for s in reversed(specs):
            if s.kind == "conv":
                s.init = "he_res"
                break
        if self.project is not None:
            save_sp, save_ch = b.spatial, b.channels
            b.spatial, b.channels = in_spatial, in_channels
            s = op_specs = self.project.param_specs(b)
            self._proj_slice = (len(specs), len(op_specs))
            specs.extend(s)
            b.spatial, b.channels = save_sp, save_ch
        return specs

    def apply(self, params, x, ctx):
        h = x
        off = 0
        for op, (start, n) in zip(self.body, self._slices):
            h = op.apply(params[start : start + n], h, ctx)
            off = start + n
        if self.project is not None:
            start, n = self._proj_slice
            sc = self.project.apply(params[start : start + n], x, ctx)
        else:
            sc = x
        return ctx.act_q(jax.nn.relu(h + sc))
