"""The WaveQ model zoo (build-time JAX).

Lite counterparts of the paper's benchmark networks, preserving each
topology's *heterogeneity* (conv stacks vs FC tails vs residual stages vs
depthwise-separable blocks) at widths/depths trainable on CPU in minutes —
see DESIGN.md §2 for the substitution argument.

  Table 2 nets : simplenet5, resnet20l, vgg11l, svhn8       (cifar/svhn-lite)
  Table 1 nets : alexnetl, resnet18l, mobilenetl            (imagenet-lite)
  plus         : mlp                                        (tests/quickstart)

Per-layer quantization policy (paper §4.1): first and last parameterized
layers stay full precision; every other conv/FC weight owns a bitwidth slot.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .layers import (FC, Affine, Conv, DWConv, Flatten, GlobalAvgPool,
                     MaxPool, Op, ParamSpec, QuantCtx, ReLU, Residual,
                     init_param)


class _ShapeTracker:
    """Mutable shape state threaded through param_specs during build."""

    def __init__(self, input_shape):
        self.spatial = (input_shape[0], input_shape[1])
        self.channels = input_shape[2]
        self._flat: Optional[int] = None
        self._ids: dict[str, int] = {}

    def next_id(self, kind: str) -> int:
        self._ids[kind] = self._ids.get(kind, 0) + 1
        return self._ids[kind]

    def flatten(self):
        self._flat = self.spatial[0] * self.spatial[1] * self.channels

    def flat_dim(self) -> int:
        if self._flat is None:
            self.flatten()
        return self._flat

    def set_flat(self, n: int):
        self._flat = n


@dataclasses.dataclass
class Model:
    """A fully-built architecture: specs + pure apply function."""

    name: str
    input_shape: tuple  # (H, W, C)
    num_classes: int
    ops: list
    specs: list  # list[ParamSpec]
    op_slices: list  # per-op (start, count) into the params list

    @property
    def num_params(self) -> int:
        return len(self.specs)

    @property
    def num_qlayers(self) -> int:
        return sum(1 for s in self.specs if s.qidx is not None)

    @property
    def qlayer_param_indices(self) -> list[int]:
        return [i for i, s in enumerate(self.specs) if s.qidx is not None]

    def init(self, seed: int = 0) -> list[jnp.ndarray]:
        keys = jax.random.split(jax.random.PRNGKey(seed), len(self.specs))
        return [init_param(k, s) for k, s in zip(keys, self.specs)]

    def apply(self, params: list, x: jnp.ndarray, ctx: QuantCtx) -> jnp.ndarray:
        h = x
        for op, (start, n) in zip(self.ops, self.op_slices):
            h = op.apply(params[start : start + n], h, ctx)
        return h

    def weight_count(self, spec: ParamSpec) -> int:
        n = 1
        for d in spec.shape:
            n *= d
        return n


def build(name: str, input_shape, num_classes: int, ops: list) -> Model:
    """Resolve shapes, assign quantization slots (skip first & last), flatten specs."""
    tracker = _ShapeTracker(input_shape)
    specs: list[ParamSpec] = []
    op_slices = []
    for op in ops:
        s = op.param_specs(tracker)
        op_slices.append((len(specs), len(s)))
        specs.extend(s)

    # Resolve 'pending' quantization slots: first & last quantizable layers go fp32.
    pending = [i for i, s in enumerate(specs) if s.qidx == "pending"]
    if len(pending) >= 3:
        keep = pending[1:-1]
    else:
        keep = pending  # tiny nets: quantize everything that asked
    drop = set(pending) - set(keep)
    qi = 0
    for i, s in enumerate(specs):
        if s.qidx == "pending":
            if i in drop:
                s.qidx = None
            else:
                s.qidx = qi
                qi += 1
    # Bind resolved slots onto ops (layer objects read self._qidx at apply time).
    for op, (start, n) in zip(ops, op_slices):
        _bind_qidx(op, specs[start : start + n])
    return Model(name, tuple(input_shape), num_classes, ops, specs, op_slices)


def _bind_qidx(op, specs):
    if isinstance(op, Residual):
        for sub, (start, n) in zip(op.body, op._slices):
            _bind_qidx(sub, specs[start : start + n])
        if op.project is not None:
            start, n = op._proj_slice
            _bind_qidx(op.project, specs[start : start + n])
    else:
        own = [s for s in specs if s.kind in ("conv", "dwconv", "fc")]
        op._qidx = own[0].qidx if own else None


def _res_block(cout: int, stride: int = 1, project: bool = False) -> Residual:
    body = [Conv(cout, 3, stride), Affine(), ReLU(), Conv(cout, 3, 1), Affine()]
    proj = Conv(cout, 1, stride) if project else None
    return Residual(body, proj)


def _sep_block(cout: int, stride: int = 1) -> list:
    """MobileNet-style depthwise-separable block."""
    return [DWConv(3, stride), Affine(), ReLU(), Conv(cout, 1, 1), Affine(), ReLU()]


# --------------------------------------------------------------------------
# Architectures
# --------------------------------------------------------------------------

def mlp(width_mult: int = 1) -> Model:
    w = 128 * width_mult
    return build("mlp", (8, 8, 3), 10, [
        Flatten(),
        FC(w), ReLU(),
        FC(w), ReLU(),
        FC(w), ReLU(),
        FC(10),
    ])


def simplenet5(width_mult: int = 1) -> Model:
    """The paper's SimpleNet-5 stand-in: 3 convs + 2 FCs on cifar-lite."""
    m = width_mult
    return build("simplenet5", (16, 16, 3), 10, [
        Conv(16 * m), Affine(), ReLU(),
        Conv(32 * m, stride=2), Affine(), ReLU(),
        Conv(32 * m, stride=2), Affine(), ReLU(),
        Flatten(),
        FC(64 * m), ReLU(),
        FC(10),
    ])


def resnet20l(width_mult: int = 1) -> Model:
    """ResNet-20-lite: 3 stages x 2 blocks, widths 8/16/32 (paper: 16/32/64 x3)."""
    m = width_mult
    return build("resnet20l", (16, 16, 3), 10, [
        Conv(8 * m), Affine(), ReLU(),
        _res_block(8 * m),
        _res_block(8 * m),
        _res_block(16 * m, stride=2, project=True),
        _res_block(16 * m),
        _res_block(32 * m, stride=2, project=True),
        _res_block(32 * m),
        GlobalAvgPool(), Flatten(),
        FC(10),
    ])


def vgg11l(width_mult: int = 1) -> Model:
    """VGG-11-lite: conv/pool ladder + 2-layer FC head."""
    m = width_mult
    return build("vgg11l", (16, 16, 3), 10, [
        Conv(16 * m), Affine(), ReLU(), MaxPool(),
        Conv(32 * m), Affine(), ReLU(), MaxPool(),
        Conv(64 * m), Affine(), ReLU(),
        Conv(64 * m), Affine(), ReLU(), MaxPool(),
        Flatten(),
        FC(128 * m), ReLU(),
        FC(10),
    ])


def svhn8(width_mult: int = 1) -> Model:
    """SVHN-8-lite: 6 convs + 2 FCs."""
    m = width_mult
    return build("svhn8", (16, 16, 3), 10, [
        Conv(16 * m), Affine(), ReLU(),
        Conv(16 * m), Affine(), ReLU(), MaxPool(),
        Conv(32 * m), Affine(), ReLU(),
        Conv(32 * m), Affine(), ReLU(), MaxPool(),
        Conv(48 * m), Affine(), ReLU(),
        Conv(48 * m), Affine(), ReLU(),
        GlobalAvgPool(), Flatten(),
        FC(64 * m), ReLU(),
        FC(10),
    ])


def alexnetl(width_mult: int = 1) -> Model:
    """AlexNet-lite: 5 convs + 3 FCs on imagenet-lite (24x24, 20 classes)."""
    m = width_mult
    return build("alexnetl", (24, 24, 3), 20, [
        Conv(16 * m, ksize=5, stride=2), Affine(), ReLU(),
        Conv(32 * m), Affine(), ReLU(), MaxPool(),
        Conv(48 * m), Affine(), ReLU(),
        Conv(48 * m), Affine(), ReLU(),
        Conv(32 * m), Affine(), ReLU(), MaxPool(),
        Flatten(),
        FC(128 * m), ReLU(),
        FC(128 * m), ReLU(),
        FC(20),
    ])


def resnet18l(width_mult: int = 1) -> Model:
    """ResNet-18-lite: 4 stages x 2 blocks, widths 8/16/32/64."""
    m = width_mult
    return build("resnet18l", (24, 24, 3), 20, [
        Conv(8 * m), Affine(), ReLU(),
        _res_block(8 * m),
        _res_block(8 * m),
        _res_block(16 * m, stride=2, project=True),
        _res_block(16 * m),
        _res_block(32 * m, stride=2, project=True),
        _res_block(32 * m),
        _res_block(64 * m, stride=2, project=True),
        _res_block(64 * m),
        GlobalAvgPool(), Flatten(),
        FC(20),
    ])


def mobilenetl(width_mult: int = 1) -> Model:
    """MobileNet-lite: stem conv + 6 depthwise-separable blocks."""
    m = width_mult
    ops: list = [Conv(16 * m, stride=2), Affine(), ReLU()]
    for cout, stride in [(16 * m, 1), (32 * m, 2), (32 * m, 1), (64 * m, 2), (64 * m, 1), (64 * m, 1)]:
        ops.extend(_sep_block(cout, stride))
    ops.extend([GlobalAvgPool(), Flatten(), FC(20)])
    return build("mobilenetl", (24, 24, 3), 20, ops)


ZOO: dict[str, Callable[..., Model]] = {
    "mlp": mlp,
    "simplenet5": simplenet5,
    "resnet20l": resnet20l,
    "vgg11l": vgg11l,
    "svhn8": svhn8,
    "alexnetl": alexnetl,
    "resnet18l": resnet18l,
    "mobilenetl": mobilenetl,
}

TABLE1_MODELS = ["alexnetl", "resnet18l", "mobilenetl"]
TABLE2_MODELS = ["simplenet5", "resnet20l", "vgg11l", "svhn8"]


def get_model(name: str, width_mult: int = 1) -> Model:
    return ZOO[name](width_mult=width_mult)
