"""Loss assembly: cross-entropy + the WaveQ regularizer (Eq. 2.2).

The total training objective in 'waveq' programs is

    E = CE(logits, y) + lambda_w * sum_i R1(w_i; beta_i) + lambda_beta * sum_i beta_i

where i ranges over the quantizable layers. The two lambda strengths are
*runtime inputs* fed by the rust coordinator every step — the 3-phase
schedule (paper Fig. 2e / Fig. 9) lives on the rust side, which is what
makes the schedule a coordination concern rather than a baked-in constant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import waveq_reg


def cross_entropy(logits: jnp.ndarray, y_onehot: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def accuracy(logits: jnp.ndarray, y_onehot: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y_onehot, axis=-1)).astype(jnp.float32)
    )


def waveq_penalty(qweights: list, beta: jnp.ndarray, norm: int = 1) -> jnp.ndarray:
    """sum_i R_norm(v_i; beta_i) over the quantizable layers (Pallas kernel).

    The sinusoid is applied to the *quantizer-normalized* weight
    ``v = tanh(w)/(2 max|tanh(W)|) + 1/2`` so that the sin^2 minima
    (v = j / (2^beta - 1)) coincide exactly with the DoReFa quantization
    levels of Eq. 2.3 — "matching the period to the quantization step"
    (§2.2) in the same coordinate system the quantizer rounds in. The
    normalization is differentiable (tanh chain) with the per-layer max
    treated as a constant, matching the quantizer's STE convention.
    """
    total = jnp.float32(0.0)
    for i, w in enumerate(qweights):
        t = jnp.tanh(w)
        m = jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(t)), 1e-8))
        v = t / (2.0 * m) + 0.5
        total = total + waveq_reg(v, beta[i], norm=norm)
    return total
