#!/usr/bin/env python3
"""Render BENCH_*/AUDIT/CHECK json reports as step-summary markdown.

Usage: bench_summary.py <dir-with-reports>

Consumes the machine-readable reports the `cargo bench` binaries emit
(`bench_support::write_report`): BENCH_kernels.json (blocked vs scalar
matmul/grad kernels, thread scaling), BENCH_runtime.json (per-program
step latency across the model zoo), BENCH_infer.json (frozen-artifact
serving throughput), BENCH_serve.json (concurrent `waveq serve`
latency/throughput vs batch-1 serial) and BENCH_dist.json (distributed
training: worker scaling + all-reduce cost), plus AUDIT_report.json from
`cargo run -p waveq-audit` (determinism/safety rules D1-D6 and the
unsafe inventory) and CHECK_report.json from `cargo run -p waveq-check`
(exhaustive interleaving model checking of the pool Latch and dist
tick-barrier protocols). Prints markdown to stdout; the perf-smoke,
lint and model-check CI jobs append it to $GITHUB_STEP_SUMMARY.
"""

import json
import sys
from pathlib import Path


def kernels_table(report: dict) -> None:
    summary = report.get("summary", {})
    print("## Kernel bench (blocked + multi-threaded vs scalar seed kernel)")
    print()
    print(f"threads available: {int(report.get('threads_available', 1))}, "
          f"scale: {report.get('scale', '?')}")
    print()
    for key, label in [
        ("matmul_speedup_t1", "matmul speedup, 1 thread"),
        ("matmul_speedup_tmax", "matmul speedup, max threads"),
        ("matmul_scaling_tmax_vs_t1", "matmul thread scaling (tmax vs t1)"),
        ("grad_weight_speedup_t1", "grad_weight speedup, 1 thread"),
        ("grad_input_speedup_t1", "grad_input speedup, 1 thread"),
        ("int8_speedup_vs_f32_t1", "int8 GEMM vs blocked f32, 1 thread"),
        ("matmul_max_rel_err", "blocked-vs-scalar max rel err"),
    ]:
        if key in summary:
            value = summary[key]
            formatted = f"{value:.2e}" if "err" in key else f"{value:.2f}x"
            print(f"- {label}: **{formatted}**")
    print()
    print("| kernel | shape (rows x din x dout) | variant | threads | "
          "GFLOP/s | speedup vs scalar |")
    print("|---|---|---|---|---|---|")
    for e in report.get("entries", []):
        shape = f"{int(e['rows'])} x {int(e['din'])} x {int(e['dout'])}"
        speedup = e.get("speedup_vs_scalar")
        speedup_s = f"{speedup:.2f}x" if speedup is not None else "-"
        print(f"| {e['kernel']} | {shape} | {e['variant']} | "
              f"{int(e['threads'])} | {e['gflops']:.2f} | {speedup_s} |")
    print()


def runtime_table(report: dict) -> None:
    print(f"## Runtime bench (backend: {report.get('platform', '?')})")
    print()
    print("| program | compile | step mean | steps/s |")
    print("|---|---|---|---|")
    for e in report.get("programs", []):
        print(f"| {e['program']} | {e['compile_s'] * 1e3:.2f} ms | "
              f"{e['step_mean_s'] * 1e3:.3f} ms | {e['steps_per_s']:.1f} |")
    sv = report.get("session_vs_legacy")
    if sv:
        print()
        print(f"### Session vs legacy dispatch ({sv.get('program', '?')}, "
              f"{int(sv.get('steps', 0))} steps)")
        print()
        print("| path | steps/s |")
        print("|---|---|")
        print(f"| legacy `Runtime::execute` (name lookup + output alloc) | "
              f"{sv['legacy_steps_per_s']:.1f} |")
        print(f"| `Session::step` (prepared handle, double-buffered state) | "
              f"{sv['session_steps_per_s']:.1f} |")
        print()
        print(f"- steady-state dispatch overhead (excl. kernel time): "
              f"**{sv['dispatch_overhead_us_per_step']:.1f} µs/step**")
    e2e = report.get("e2e_mlp_waveq_50steps")
    if e2e:
        print()
        print(f"- e2e mlp waveq 50 steps: **{e2e['steps_per_s']:.1f} steps/s** "
              f"(test_acc {e2e['test_acc']:.3f})")
    print()


def infer_table(report: dict) -> None:
    print("## Inference bench (frozen low-bit artifacts, batch-polymorphic sessions)")
    print()
    print(f"threads available: {int(report.get('threads_available', 1))}, "
          f"scale: {report.get('scale', '?')}")
    print()
    print("| model | bits | packed weights | vs f32 | precision | batch | imgs/s |")
    print("|---|---|---|---|---|---|---|")
    for m in report.get("models", []):
        bits = m.get("layer_bits", [])
        bits_s = f"{int(min(bits))}" if bits and min(bits) == max(bits) else str(
            [int(b) for b in bits])
        size_s = f"{int(m['packed_weight_bytes'])} B"
        red_s = f"{m['size_reduction']:.2f}x smaller"
        int_layers = m.get("int_gemm_layers")
        for i, e in enumerate(m.get("entries", [])):
            head = (f"| {m['model']} | {bits_s} | {size_s} | {red_s} "
                    if i == 0 else "| | | | ")
            prec = e.get("precision", "exact")
            if prec == "int8" and int_layers is not None:
                prec = f"int8 ({int(int_layers)} int GEMM layers)"
            print(f"{head}| {prec} | {int(e['batch'])} | {e['imgs_per_s']:.1f} |")
    print()


def serve_table(report: dict) -> None:
    print("## Serving bench (`waveq serve`: cross-request batching over TCP loopback)")
    print()
    serial = report.get("serial_batch1_imgs_per_s")
    print(f"model: {report.get('model', '?')}, workers: {int(report.get('workers', 0))}, "
          f"max_batch: {int(report.get('max_batch', 0))}, "
          f"deadline: {report.get('deadline_us', 0):.0f} µs, "
          f"threads available: {int(report.get('threads_available', 1))}")
    print()
    if serial is not None:
        print(f"- batch-1 serial baseline (no server stack): **{serial:.1f} imgs/s**")
        print()
    print("| clients | requests | imgs/s | vs serial | p50 | p99 | mean batch fill |")
    print("|---|---|---|---|---|---|---|")
    for lane in report.get("lanes", []):
        imgs = lane["imgs_per_s"]
        vs = f"{imgs / serial:.2f}x" if serial else "-"
        print(f"| {int(lane['clients'])} | {int(lane['requests'])} | {imgs:.1f} | {vs} | "
              f"{lane['p50_us'] / 1e3:.2f} ms | {lane['p99_us'] / 1e3:.2f} ms | "
              f"{lane['mean_batch_fill']:.2f} |")
    print()


def dist_table(report: dict) -> None:
    print("## Distributed training bench (tick coordinator, fixed-order all-reduce)")
    print()
    fused = report.get("fused_steps_per_s")
    print(f"model: {report.get('model', '?')}, steps: {int(report.get('steps', 0))}, "
          f"round_len: {int(report.get('round_len', 0))}, "
          f"threads available: {int(report.get('threads_available', 1))} "
          f"(WAVEQ_THREADS=1: parallelism is worker replicas, not kernel shards)")
    print()
    if fused is not None:
        print(f"- fused single-process baseline: **{fused:.2f} steps/s**")
        print()
    print("| workers | steps/s | scaling vs 1 worker | all-reduce µs/step | replays |")
    print("|---|---|---|---|---|")
    for lane in report.get("lanes", []):
        print(f"| {int(lane['workers'])} | {lane['steps_per_s']:.2f} | "
              f"{lane['scaling_x']:.2f}x | {lane['allreduce_us_per_step']:.0f} | "
              f"{int(lane.get('replays', 0))} |")
    print()


def audit_table(report: dict) -> None:
    clean = report.get("clean", False)
    verdict = "clean" if clean else "VIOLATIONS"
    print(f"## waveq-audit (determinism/safety lint): {verdict}")
    print()
    print(f"{int(report.get('files_scanned', 0))} files scanned under "
          f"`{report.get('root', '?')}`")
    print()
    print("| rule | invariant | violations | allowlisted |")
    print("|---|---|---|---|")
    for rule, info in report.get("rules", {}).items():
        print(f"| {rule} | {info.get('summary', '?')} | "
              f"{int(info.get('violations', 0))} | {int(info.get('allowed', 0))} |")
    print()
    for v in report.get("violations", []):
        where = f"{v['file']}:{int(v['line'])}"
        print(f"- **{v['rule']}** `{where}`: {v.get('message', v.get('pattern', ''))}")
    stale = report.get("unused_allow_entries", [])
    if stale:
        print(f"- warning: {len(stale)} stale allowlist entries "
              f"(lines {[int(e['allow_file_line']) for e in stale]})")
    inv = report.get("unsafe_inventory", [])
    justified = sum(1 for u in inv if u.get("justified"))
    print(f"- unsafe inventory: {len(inv)} site(s), {justified} with a "
          f"`// SAFETY:` justification")
    for u in inv:
        mark = "ok" if u.get("justified") else "MISSING SAFETY"
        print(f"  - `{u['file']}:{int(u['line'])}` {u.get('kind', '?')} ({mark})")
    print()


def check_table(report: dict) -> None:
    clean = report.get("clean", False)
    verdict = "clean" if clean else "FAILED"
    summary = report.get("summary", {})
    print(f"## waveq-check (interleaving model checker, "
          f"{report.get('mode', '?')} mode): {verdict}")
    print()
    print(f"{int(summary.get('states', 0))} states / "
          f"{int(summary.get('transitions', 0))} transitions explored across "
          f"{int(summary.get('runs', 0))} protocol runs and "
          f"{int(summary.get('fixtures', 0))} planted-bug fixtures")
    print()
    print("| run | model | states | transitions | depth | verdict |")
    print("|---|---|---|---|---|---|")
    for kind, runs in [("real", report.get("runs", [])),
                       ("fixture", report.get("fixtures", []))]:
        for r in runs:
            v = r.get("violation")
            if r.get("passed"):
                verdict = (f"caught `{v['property']}`" if kind == "fixture"
                           else "exhausted clean")
            elif v is None:
                verdict = ("**truncated**" if r.get("truncated")
                           else "**planted bug missed**")
            else:
                verdict = f"**{v['property']}**: {v.get('message', '')}"
            print(f"| {r['name']} | {r['model']} | {int(r['states'])} | "
                  f"{int(r['transitions'])} | {int(r['max_depth'])} | {verdict} |")
    # Show the offending interleaving for any real-protocol violation —
    # the trace is the whole point of a model checker.
    for r in report.get("runs", []):
        v = r.get("violation")
        if v and not r.get("passed"):
            print()
            print(f"### {r['name']}: {v['property']} interleaving")
            print()
            for step in v.get("trace", []):
                print(f"1. {step}")
    print()


def main() -> int:
    outdir = Path(sys.argv[1] if len(sys.argv) > 1 else ".")
    found = False
    audit = outdir / "AUDIT_report.json"
    if audit.exists():
        audit_table(json.loads(audit.read_text()))
        found = True
    check = outdir / "CHECK_report.json"
    if check.exists():
        check_table(json.loads(check.read_text()))
        found = True
    kernels = outdir / "BENCH_kernels.json"
    if kernels.exists():
        kernels_table(json.loads(kernels.read_text()))
        found = True
    runtime = outdir / "BENCH_runtime.json"
    if runtime.exists():
        runtime_table(json.loads(runtime.read_text()))
        found = True
    infer = outdir / "BENCH_infer.json"
    if infer.exists():
        infer_table(json.loads(infer.read_text()))
        found = True
    serve = outdir / "BENCH_serve.json"
    if serve.exists():
        serve_table(json.loads(serve.read_text()))
        found = True
    dist = outdir / "BENCH_dist.json"
    if dist.exists():
        dist_table(json.loads(dist.read_text()))
        found = True
    if not found:
        print(f"no BENCH_/AUDIT_/CHECK_ json reports under {outdir}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
