//! Minimal `anyhow` shim (string-backed), vendored so the workspace builds
//! offline with no registry access. Implements exactly the subset the
//! `waveq` crate uses:
//!
//! * [`Error`] — an opaque error holding a message plus a causal chain of
//!   context strings (most recent first, like anyhow's `{:#}` rendering).
//! * [`Result<T>`] — alias for `std::result::Result<T, Error>`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on results whose
//!   error is either a std error or already an [`Error`].
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `impl From<E: std::error::Error>` possible.

use std::fmt;

pub struct Error {
    /// Outermost context first; the root cause is the last entry.
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (without the cause chain).
    pub fn to_string_outer(&self) -> String {
        self.chain.first().cloned().unwrap_or_default()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Plain and `{:#}` alternate both render the full chain,
        // anyhow-style "outer: inner: root".
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach context to an error as it propagates.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(fails_io().is_err());
    }

    #[test]
    fn context_chains_render_outer_first() {
        let e = fails_io().with_context(|| "loading config").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("loading config: "), "{s}");
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let e = anyhow!("bad {} at {}", "value", 7);
        assert_eq!(e.to_string(), "bad value at 7");
        let s = String::from("plain");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn ensure_and_bail() {
        fn check(v: i32) -> Result<i32> {
            ensure!(v > 0, "v must be positive, got {v}");
            if v > 100 {
                bail!("v too large: {v}");
            }
            Ok(v)
        }
        assert!(check(5).is_ok());
        assert!(check(-1).is_err());
        assert!(check(101).is_err());
    }
}
