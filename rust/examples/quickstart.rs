//! Quickstart: train a small MLP with WaveQ's learned per-layer bitwidths
//! and compare against the fp32 and plain-DoReFa baselines.
//!
//!   cargo run --release --example quickstart
//!
//! Walks through the public API in ~60 lines: open the runtime, build a
//! config, run the trainer, inspect the learned assignment and energy.

use anyhow::Result;
use waveq::config::{Algo, RunConfig};
use waveq::coordinator::Trainer;
use waveq::energy::Stripes;
use waveq::runtime::Runtime;

fn main() -> Result<()> {
    waveq::util::logging::init();

    // 1. Open the runtime: AOT artifacts when built (with `--features
    //    pjrt`), otherwise the hermetic pure-Rust native backend.
    let rt = Runtime::open(&waveq::artifacts_dir())?;
    println!("platform: {}", rt.platform());

    // 2. One config per algorithm; everything else (data, schedule) defaults.
    let base = RunConfig {
        model: "mlp".into(),
        steps: 300,
        train_examples: 4096,
        test_examples: 1024,
        lr: 0.05,
        ..Default::default()
    };

    for algo in [Algo::Fp32, Algo::Dorefa, Algo::WaveqLearned] {
        let mut cfg = RunConfig { algo, weight_bits: 3, ..base.clone() };
        cfg.schedule.total_steps = cfg.steps;

        // 3. Run the coordinator: schedule, phase control, freeze, eval.
        let outcome = Trainer::new(&rt, cfg).run()?;

        // 4. Inspect what WaveQ learned.
        let meta = rt.manifest.model(&outcome.model_key)?;
        let saving = Stripes::default().saving_vs_baseline(
            meta,
            &outcome.assignment.bits,
            8,
        );
        println!(
            "{:<14} test_acc={:.4}  bits={:?} (avg {:.2})  energy saving {:.2}x{}",
            outcome.cfg.algo.name(),
            outcome.test_acc,
            outcome.assignment.bits,
            outcome.assignment.average_bits(),
            saving,
            outcome
                .freeze_step
                .map(|s| format!("  (beta frozen @ step {s})"))
                .unwrap_or_default(),
        );
    }
    Ok(())
}
