//! Regularizer landscape example: evaluate the lowered `reg_profile`
//! program (the same closed forms the training loss uses) and print ASCII
//! profiles of R_1(w; beta) — the paper's Figure 2 — plus the
//! vanishing/exploding-gradient comparison of the three normalization
//! variants (Figure 3).
//!
//!   cargo run --release --example regularizer_landscape

use anyhow::Result;
use waveq::runtime::{buffer_f32, to_vec_f32, Runtime};

const N_W: usize = 512;
const N_B: usize = 256;

fn main() -> Result<()> {
    waveq::util::logging::init();
    let rt = Runtime::open(&waveq::artifacts_dir())?;

    let w: Vec<f32> = (0..N_W).map(|i| -1.25 + 2.5 * i as f32 / (N_W - 1) as f32).collect();
    let b: Vec<f32> = (0..N_B).map(|i| 1.0 + 7.0 * i as f32 / (N_B - 1) as f32).collect();
    let outs = rt
        .prepare("reg_profile")?
        .call(&[buffer_f32(&w, &[N_W])?, buffer_f32(&b, &[N_B])?])?;
    let r1 = to_vec_f32(&outs[3])?; // (N_W, N_B), norm = 1

    // ASCII profile of R1 vs w at a few bitwidths.
    for target in [2.0f32, 3.0] {
        let bi = b
            .iter()
            .enumerate()
            .min_by(|(_, x), (_, y)| (*x - target).abs().partial_cmp(&(*y - target).abs()).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let k = 2f32.powf(b[bi]) - 1.0;
        println!("\nR1(w; beta={:.2})  —  minima every 1/k = {:.3}", b[bi], 1.0 / k);
        let rows = 12usize;
        let cols = 96usize;
        let max_r: f32 = (0..N_W).map(|wi| r1[wi * N_B + bi]).fold(0.0, f32::max);
        for row in 0..rows {
            let thresh = max_r * (rows - row) as f32 / rows as f32;
            let mut line = String::new();
            for col in 0..cols {
                let wi = col * (N_W - 1) / (cols - 1);
                line.push(if r1[wi * N_B + bi] >= thresh { '#' } else { ' ' });
            }
            println!("|{line}");
        }
        println!("+{}", "-".repeat(96));
        println!(" w from {:.2} to {:.2}", w[0], w[N_W - 1]);
    }

    // Figure-3 gradient-range comparison.
    println!("\nmax |dR/dbeta| over w, at low vs high beta:");
    for norm in 0..3usize {
        let d1 = to_vec_f32(&outs[norm * 3 + 1])?;
        let max_at = |lo: f32, hi: f32| -> f32 {
            let mut m = 0f32;
            for wi in 0..N_W {
                for bi in 0..N_B {
                    if (lo..=hi).contains(&b[bi]) {
                        m = m.max(d1[wi * N_B + bi].abs());
                    }
                }
            }
            m
        };
        println!(
            "  R{}: beta in [1, 2.5] -> {:.3e}   beta in [6.5, 8] -> {:.3e}",
            norm,
            max_at(1.0, 2.5),
            max_at(6.5, 8.0)
        );
    }
    println!("\n(R0 explodes, R1 stays bounded — the paper's production choice, R2 vanishes)");
    Ok(())
}
