//! End-to-end validation driver (DESIGN.md §4 headline): train simplenet5
//! on cifar-lite from scratch with learned-beta WaveQ for several hundred
//! steps, logging the loss curve, the beta trajectory, the phase-2 -> 3
//! freeze, the learned heterogeneous bitwidths, and the final comparison
//! against fp32 and plain DoReFa — plus the Stripes energy saving.
//!
//!   cargo run --release --example waveq_e2e
//!
//! The numbers this prints are the ones recorded in EXPERIMENTS.md §E2E.

use anyhow::Result;
use waveq::config::{Algo, RunConfig};
use waveq::coordinator::Trainer;
use waveq::energy::Stripes;
use waveq::runtime::Runtime;

fn main() -> Result<()> {
    waveq::util::logging::init();
    let rt = Runtime::open(&waveq::artifacts_dir())?;

    let steps = 500;
    let mk = |algo: Algo, bits: u32| {
        let mut cfg = RunConfig {
            model: "simplenet5".into(),
            algo,
            weight_bits: bits,
            act_bits: 4,
            steps,
            train_examples: 6144,
            test_examples: 1024,
            lr: 0.06,
            lr_beta: 0.05,
            beta_init: 6.0,
            seed: 42,
            ..Default::default()
        };
        cfg.schedule.total_steps = steps;
        cfg
    };

    // --- the headline run: learned heterogeneous WaveQ --------------------
    let mut trainer = Trainer::new(&rt, mk(Algo::WaveqLearned, 4));
    let waveq = trainer.run()?;

    println!("\n=== loss curve (every 25 steps) ===");
    for (step, loss) in waveq.metrics.get("loss").iter().step_by(25) {
        let beta = waveq
            .metrics
            .get("beta_mean")
            .iter()
            .find(|(s, _)| s == step)
            .map(|&(_, b)| format!("  beta_mean={b:.3}"))
            .unwrap_or_default();
        println!("step {step:>4}  loss {loss:.4}{beta}");
    }
    println!(
        "\nbeta froze at step {:?} -> per-layer bits {:?} (avg {:.2})",
        waveq.freeze_step,
        waveq.assignment.bits,
        waveq.assignment.average_bits()
    );

    // --- baselines ----------------------------------------------------------
    let fp32 = Trainer::new(&rt, mk(Algo::Fp32, 8)).run()?;
    let dorefa = Trainer::new(&rt, mk(Algo::Dorefa, 4)).run()?;

    let meta = rt.manifest.model(&waveq.model_key)?;
    let stripes = Stripes::default();
    let saving = stripes.saving_vs_baseline(meta, &waveq.assignment.bits, 4);
    let saving_w4 = stripes.saving_vs_baseline(meta, &vec![4; meta.num_qlayers], 4);

    println!("\n=== end-to-end summary (simplenet5 on cifar-lite) ===");
    println!("fp32            : test_acc {:.4}", fp32.test_acc);
    println!("DoReFa W4/A4    : test_acc {:.4}  energy saving {saving_w4:.2}x", dorefa.test_acc);
    println!(
        "WaveQ learned/A4: test_acc {:.4}  avg bits {:.2}  energy saving {saving:.2}x",
        waveq.test_acc,
        waveq.assignment.average_bits()
    );
    println!(
        "WaveQ vs DoReFa: {:+.2}%  |  WaveQ vs fp32: {:+.2}%",
        100.0 * (waveq.test_acc - dorefa.test_acc),
        100.0 * (waveq.test_acc - fp32.test_acc)
    );
    let st = rt.stats();
    println!(
        "runtime: {} XLA compiles ({:.1}s), {} step executions, {:.1} steps/s train throughput",
        st.compiles,
        st.compile_secs,
        st.executions,
        steps as f64 / waveq.train_secs
    );
    Ok(())
}
