//! Pareto sweep example: enumerate the per-layer bitwidth design space of a
//! small net, evaluate every assignment against a WaveQ-trained state, and
//! print the compute/accuracy frontier with the learned solution located on
//! it (the Figure-4 analysis, as a library-API walkthrough).
//!
//!   cargo run --release --example pareto_sweep

use anyhow::Result;
use waveq::config::{Algo, RunConfig};
use waveq::coordinator::{evaluate, test_batcher, BitAssignment, Trainer};
use waveq::energy::Stripes;
use waveq::pareto::{enumerate_assignments, pareto_frontier, DesignPoint};
use waveq::runtime::Runtime;

fn main() -> Result<()> {
    waveq::util::logging::init();
    let rt = Runtime::open(&waveq::artifacts_dir())?;

    // Train once with learned WaveQ.
    let mut cfg = RunConfig {
        model: "simplenet5".into(),
        algo: Algo::WaveqLearned,
        steps: 350,
        act_bits: 4,
        train_examples: 4096,
        test_examples: 512,
        ..Default::default()
    };
    cfg.schedule.total_steps = cfg.steps;
    let outcome = Trainer::new(&rt, cfg.clone()).run()?;
    let meta = rt.manifest.model(&outcome.model_key)?.clone();

    // Enumerate {2..8}^Q and evaluate each assignment.
    let stripes = Stripes::default();
    let test = test_batcher(&meta, 256, cfg.seed)?;
    let space = enumerate_assignments(meta.num_qlayers, 2, 8);
    println!("evaluating {} assignments over {} qlayers...", space.len(), meta.num_qlayers);
    let mut points = Vec::new();
    for bits in space {
        let assign = BitAssignment { bits: bits.clone(), alpha: vec![1.0; bits.len()] };
        let (_, acc) = evaluate(
            &rt,
            "eval_quant_simplenet5",
            &meta,
            &outcome.state.params,
            Some(&assign.kw()),
            cfg.ka(),
            &test,
        )?;
        points.push(DesignPoint {
            bits,
            compute: stripes.relative_compute(&meta, &assign.bits),
            accuracy: acc as f64,
        });
    }

    let frontier = pareto_frontier(&points);
    println!("\ncompute  accuracy  bits        (Pareto frontier)");
    for &i in &frontier {
        let p = &points[i];
        println!("{:.3}    {:.4}    {:?}", p.compute, p.accuracy, p.bits);
    }
    println!(
        "\nWaveQ learned solution: bits {:?}  compute {:.3}  accuracy {:.4}",
        outcome.assignment.bits,
        stripes.relative_compute(&meta, &outcome.assignment.bits),
        outcome.test_acc
    );
    Ok(())
}
