//! The 3-phase regularization-strength schedule (paper Fig. 2e / Fig. 9)
//! and the phase controller that drives it.
//!
//! Phase 1 (explore):   lambda_w ~ 0, lambda_beta ~ 0 — SGD roams freely.
//! Phase 2 (engage):    both strengths rise along an exponential ramp
//!                      `lambda(t) = lam_max * (exp(g u) - 1)/(exp(g) - 1)`,
//!                      u = progress in [0, 1] (the Fig. 9 curve);
//!                      lambda_w is kept >> lambda_beta so per-layer
//!                      bitwidths can be evaluated while weights settle.
//! Phase 3 (freeze):    the coordinator detects beta convergence
//!                      (max |delta beta| below tol over a window), fixes
//!                      b_i = ceil(beta_i), zeroes the beta updates via the
//!                      `beta_train` flag, decays lambda_beta to 0 and holds
//!                      lambda_w high so weights lock onto the final grid.
//!
//! The discussion section's from-scratch finding (Fig. 7) — constant
//! lambda_w traps weights near init; an exponential ramp lets them hop
//! between waves — is exactly this module's `lambda_w_at`.

/// Closed-form schedule parameters.
#[derive(Debug, Clone)]
pub struct ScheduleCfg {
    pub total_steps: usize,
    /// Fraction of steps in phase 1 (pure task loss).
    pub explore_frac: f64,
    /// Fraction of steps in phase 2 (ramping); the rest is phase 3.
    pub engage_frac: f64,
    /// Peak strengths. Chosen "such that the original loss and the penalty
    /// terms have approximately the same magnitude" (paper §2.2).
    pub lambda_w_max: f32,
    pub lambda_beta_max: f32,
    /// Exponential ramp sharpness (gamma in Fig. 9).
    pub gamma: f64,
    /// Phase-3 exponential decay rate for lambda_beta.
    pub beta_decay: f64,
}

impl Default for ScheduleCfg {
    fn default() -> Self {
        ScheduleCfg {
            total_steps: 1000,
            explore_frac: 0.15,
            engage_frac: 0.55,
            lambda_w_max: 1.0,
            lambda_beta_max: 0.02,
            gamma: 4.0,
            beta_decay: 20.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    Explore = 1,
    Engage = 2,
    Freeze = 3,
}

impl ScheduleCfg {
    pub fn explore_end(&self) -> usize {
        (self.total_steps as f64 * self.explore_frac) as usize
    }

    pub fn engage_end(&self) -> usize {
        (self.total_steps as f64 * (self.explore_frac + self.engage_frac)) as usize
    }

    /// Exponential ramp in [0,1] -> [0,1]: (e^{g u} - 1)/(e^g - 1)  (Fig. 9).
    fn ramp(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        ((self.gamma * u).exp() - 1.0) / (self.gamma.exp() - 1.0)
    }

    /// lambda_w at a step, given whether beta has been frozen yet.
    pub fn lambda_w_at(&self, step: usize, frozen: bool) -> f32 {
        let (e1, e2) = (self.explore_end(), self.engage_end());
        if step < e1 {
            0.0
        } else if step < e2 && !frozen {
            let u = (step - e1) as f64 / (e2 - e1).max(1) as f64;
            (self.lambda_w_max as f64 * self.ramp(u)) as f32
        } else {
            self.lambda_w_max // phase 3: hold high
        }
    }

    /// lambda_beta at a step. `freeze_step` is Some(s) once phase 3 started.
    pub fn lambda_beta_at(&self, step: usize, freeze_step: Option<usize>) -> f32 {
        if let Some(fs) = freeze_step {
            // Phase 3: exponential decay to zero.
            let dt = (step.saturating_sub(fs)) as f64 / self.total_steps.max(1) as f64;
            return (self.lambda_beta_max as f64 * (-self.beta_decay * dt).exp()) as f32;
        }
        let (e1, e2) = (self.explore_end(), self.engage_end());
        if step < e1 {
            0.0
        } else {
            let u = (step - e1) as f64 / (e2 - e1).max(1) as f64;
            (self.lambda_beta_max as f64 * self.ramp(u)) as f32
        }
    }
}

/// Watches the live beta vector and decides the phase-2 -> phase-3 flip.
#[derive(Debug, Clone)]
pub struct PhaseController {
    pub cfg: ScheduleCfg,
    prev_beta: Option<Vec<f32>>,
    stable_steps: usize,
    /// Step at which beta was frozen (phase 3 entry), if any.
    pub freeze_step: Option<usize>,
    /// beta must move less than this (max over layers) to count as stable.
    pub tol: f32,
    /// ... for this many consecutive steps.
    pub window: usize,
}

impl PhaseController {
    pub fn new(cfg: ScheduleCfg) -> Self {
        PhaseController {
            cfg,
            prev_beta: None,
            stable_steps: 0,
            freeze_step: None,
            tol: 2e-4,
            window: 30,
        }
    }

    pub fn phase(&self, step: usize) -> Phase {
        if self.freeze_step.is_some() {
            Phase::Freeze
        } else if step < self.cfg.explore_end() {
            Phase::Explore
        } else {
            Phase::Engage
        }
    }

    /// Per-step strengths + the beta_train flag fed into the AOT program.
    /// Call *before* the step; then report the post-step beta via
    /// [`PhaseController::observe_beta`].
    pub fn knobs(&self, step: usize) -> (f32, f32, f32) {
        let frozen = self.freeze_step.is_some();
        let lw = self.cfg.lambda_w_at(step, frozen);
        let lb = self.cfg.lambda_beta_at(step, self.freeze_step);
        let flag = if frozen || self.phase(step) == Phase::Explore { 0.0 } else { 1.0 };
        (lw, lb, flag)
    }

    /// Feed the post-step beta; may flip into phase 3. Returns true on flip.
    pub fn observe_beta(&mut self, step: usize, beta: &[f32]) -> bool {
        if self.freeze_step.is_some() || self.phase(step) != Phase::Engage {
            self.prev_beta = Some(beta.to_vec());
            return false;
        }
        if let Some(prev) = &self.prev_beta {
            let max_delta = prev
                .iter()
                .zip(beta)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if max_delta < self.tol {
                self.stable_steps += 1;
            } else {
                self.stable_steps = 0;
            }
        }
        self.prev_beta = Some(beta.to_vec());
        // Flip on stability, or unconditionally at the end of phase 2.
        if self.stable_steps >= self.window || step >= self.cfg.engage_end() {
            self.freeze_step = Some(step);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ScheduleCfg {
        ScheduleCfg { total_steps: 1000, ..Default::default() }
    }

    #[test]
    fn phase1_is_pure_task_loss() {
        let c = cfg();
        assert_eq!(c.lambda_w_at(0, false), 0.0);
        assert_eq!(c.lambda_beta_at(0, None), 0.0);
        assert_eq!(c.lambda_w_at(c.explore_end() - 1, false), 0.0);
    }

    #[test]
    fn ramp_is_monotone_and_bounded() {
        let c = cfg();
        let mut prev = -1.0f32;
        for s in c.explore_end()..c.engage_end() {
            let lw = c.lambda_w_at(s, false);
            assert!(lw >= prev, "not monotone at {s}");
            assert!(lw <= c.lambda_w_max);
            prev = lw;
        }
        // lambda_w dominates lambda_beta throughout phase 2 (paper §2.2).
        for s in c.explore_end()..c.engage_end() {
            assert!(c.lambda_w_at(s, false) >= c.lambda_beta_at(s, None));
        }
    }

    #[test]
    fn phase3_decays_beta_strength_and_holds_w() {
        let c = cfg();
        let fs = 700;
        assert_eq!(c.lambda_w_at(800, true), c.lambda_w_max);
        let l0 = c.lambda_beta_at(fs, Some(fs));
        let l1 = c.lambda_beta_at(fs + 100, Some(fs));
        let l2 = c.lambda_beta_at(fs + 300, Some(fs));
        assert!(l0 > l1 && l1 > l2);
        assert!(l2 < 0.01 * c.lambda_beta_max + 1e-9);
    }

    #[test]
    fn controller_freezes_on_stable_beta() {
        let mut pc = PhaseController::new(cfg());
        pc.window = 5;
        let start = pc.cfg.explore_end() + 1;
        let beta = vec![3.2f32, 4.7];
        let mut flipped_at = None;
        for s in start..start + 50 {
            if pc.observe_beta(s, &beta) {
                flipped_at = Some(s);
                break;
            }
        }
        // First observe has no prev; then 5 stable steps.
        assert_eq!(flipped_at, Some(start + 5));
        assert_eq!(pc.phase(start + 6), Phase::Freeze);
        let (_, _, flag) = pc.knobs(start + 6);
        assert_eq!(flag, 0.0);
    }

    #[test]
    fn controller_freezes_at_engage_end_regardless() {
        let mut pc = PhaseController::new(cfg());
        let end = pc.cfg.engage_end();
        // Wildly moving beta: no stability-based freeze.
        let mut s = pc.cfg.explore_end();
        let mut flipped = false;
        let mut i = 0.0f32;
        while s <= end {
            i += 1.0;
            if pc.observe_beta(s, &[i, -i]) {
                flipped = true;
                break;
            }
            s += 1;
        }
        assert!(flipped && s == end);
    }

    #[test]
    fn knobs_gate_beta_training_in_explore() {
        let pc = PhaseController::new(cfg());
        let (lw, lb, flag) = pc.knobs(0);
        assert_eq!((lw, lb, flag), (0.0, 0.0, 0.0));
        let (_, _, flag2) = pc.knobs(pc.cfg.explore_end() + 1);
        assert_eq!(flag2, 1.0);
    }
}
