//! Distributed data-parallel training: a tick-based coordinator over N
//! in-process worker replicas with a bit-identical fixed-order all-reduce.
//!
//! ## Contract
//!
//! An N-worker run produces **exactly the bits** of the 1-worker run — the
//! same parameters, velocities, beta/vbeta, and metrics after every step —
//! for any worker count that divides the reduction grid, at any
//! `WAVEQ_THREADS` setting, and across worker drop/rejoin. Three design
//! choices carry that guarantee:
//!
//! 1. **The chunk grid, not the worker count, is the reduction unit.**
//!    The global batch is cut into `kernels::GRAD_CHUNKS` fixed row chunks
//!    (the same grid the fused single-process train step reduces over);
//!    `data::shard_for` deals whole chunks to workers. Concatenating every
//!    worker's shard in chunk order reconstructs the 1-worker batch.
//! 2. **Fixed-order all-reduce.** Per-chunk gradients are combined by
//!    `kernels::allreduce_fixed_order` in ascending chunk order — a left
//!    fold per element, bitwise independent of which worker produced which
//!    chunk or when it arrived.
//! 3. **One shared optimizer step.** The coordinator broadcasts the
//!    reduced gradients and every replica (workers and the coordinator's
//!    own) applies the identical `apply_*` program, so replicas never
//!    drift: any replica's state *is* the global state.
//!
//! ## Ticks, drops, rejoins
//!
//! Training advances in rounds of `round_len` steps (see
//! [`state::RoundMachine`]). Each step is a tick: fan out `Step`
//! directives, barrier on gradients, reduce, fan out `Apply`, barrier on
//! acks. A worker that dies (send failure, `Fatal` reply, or its thread
//! finishing while the barrier waits) is dropped at the tick barrier; the
//! round is then **replayed** from the round-boundary snapshot with the
//! surviving membership re-sharded — the cached round batches are re-fed,
//! so the replayed arithmetic consumes the same bytes and the run's bits
//! match an uninterrupted run with the final membership. A worker rejoins
//! at a round boundary by loading the coordinator's state snapshot.

pub mod protocol;
pub mod state;
pub mod worker;

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use self::protocol::{probe_ms, BarrierCore, Roster, PROBE_ENV};
use self::state::{RoundMachine, RoundState};
use self::worker::{ChunkGrads, FromWorker, Member, ToWorker};
use super::bitwidth::BitAssignment;
use super::trainer::{eval_session, session_cfg, step_knobs};
use crate::config::{Algo, RunConfig};
use crate::data::{shard_for, spec_for_model, Batch, Batcher, Dataset, Prefetcher};
use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::native::kernels as kn;
use crate::runtime::{Buffer, ModelMeta, Runtime, Session, SessionCfg, StepKnobs, StepMetrics};
use crate::schedule::PhaseController;

/// How each step's knobs are produced.
#[derive(Debug, Clone)]
pub enum KnobPlan {
    /// The same knobs every step (bit-identity tests drive this).
    Fixed(StepKnobs),
    /// The trainer's schedule policy (`trainer::step_knobs`), including
    /// freeze detection on the coordinator's replica.
    Auto,
}

/// Deterministic fault injection for the drop/rejoin machinery. Real
/// faults (worker panics) are detected the same way; chaos events exist so
/// tests can pin *when* a drop lands and assert bit-identical replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosEvent {
    /// Drop worker slot `worker` at the tick barrier before global step
    /// `at_step` is dispatched.
    Kill { worker: usize, at_step: usize },
    /// Re-admit worker slot `worker` at the boundary entering round
    /// `at_round` (it loads the coordinator's state snapshot).
    Rejoin { worker: usize, at_round: usize },
}

#[derive(Debug, Clone)]
pub struct DistCfg {
    /// Worker replica count; must divide both the reduction grid
    /// (`kernels::GRAD_CHUNKS`) and the model's batch size.
    pub workers: usize,
    /// Steps per round (the replay/checkpoint/rejoin granularity).
    pub round_len: usize,
    pub knobs: KnobPlan,
    pub chaos: Vec<ChaosEvent>,
    /// Save the coordinator's state here at every round boundary, stamped
    /// with the number of completed rounds.
    pub checkpoint: Option<PathBuf>,
    pub quiet: bool,
}

impl DistCfg {
    pub fn new(workers: usize) -> DistCfg {
        DistCfg {
            workers,
            round_len: 20,
            knobs: KnobPlan::Auto,
            chaos: Vec::new(),
            checkpoint: None,
            quiet: false,
        }
    }
}

/// What a distributed run hands back (the dist analogue of
/// `trainer::TrainOutcome`).
pub struct DistOutcome {
    /// Final global state (any replica's — they are bitwise equal; this is
    /// the coordinator's).
    pub state: crate::runtime::SessionState,
    pub test_loss: f32,
    pub test_acc: f32,
    /// Per-step training loss/accuracy (post-replay: exactly one entry per
    /// global step, replayed steps overwrite their first attempt).
    pub loss: Vec<f32>,
    pub acc: Vec<f32>,
    pub freeze_step: Option<usize>,
    pub steps: usize,
    pub rounds: usize,
    /// Workers lost / rounds replayed / workers re-admitted.
    pub drops: usize,
    pub replays: usize,
    pub rejoins: usize,
    pub train_secs: f64,
    /// Wall-clock spent inside the fixed-order reduction.
    pub allreduce_secs: f64,
}

/// Outcome of one barrier: either every live worker answered, or some
/// died (by uid) and the round must replay.
enum Tick<T> {
    Complete(T),
    Lost(Vec<usize>),
}

/// Run a full distributed training job. `rt` backs the coordinator's own
/// replica (used for eval, checkpoints, freeze detection, and rejoin
/// snapshots); each worker owns a private `Runtime`.
pub fn run_distributed(rt: &Runtime, cfg: &RunConfig, dcfg: &DistCfg) -> Result<DistOutcome> {
    if dcfg.workers == 0 {
        return Err(anyhow!("--workers must be >= 1"));
    }
    if dcfg.round_len == 0 {
        return Err(anyhow!("round length must be >= 1"));
    }
    if !rt.grad_stage() {
        return Err(anyhow!(
            "backend '{}' has no split grads/apply train stages; distributed training needs them",
            rt.platform()
        ));
    }
    if dcfg.workers > kn::GRAD_CHUNKS || !kn::GRAD_CHUNKS.is_multiple_of(dcfg.workers) {
        return Err(anyhow!(
            "--workers {} must divide the {}-chunk reduction grid (use 1, 2, or 4)",
            dcfg.workers,
            kn::GRAD_CHUNKS
        ));
    }
    let model_key = cfg.algo.model_key(&cfg.model);
    let model = rt.manifest.model(&model_key)?.clone();
    if !model.batch.is_multiple_of(dcfg.workers) {
        return Err(anyhow!(
            "batch {} is not divisible by --workers {} (shards would be ragged)",
            model.batch,
            dcfg.workers
        ));
    }

    let scfg = session_cfg(cfg, model.num_qlayers);
    let mut own = Session::open(rt, &scfg)?;
    own.enable_grad_stage(rt)?;

    let dspec = spec_for_model(&model);
    let train_ds = Dataset::generate(dspec, cfg.train_examples, cfg.seed, 0);
    let batcher = Batcher::new(train_ds, model.batch, cfg.seed).map_err(|e| {
        anyhow!("train stream for '{}': {e} (--train-examples too small?)", model.name)
    })?;
    let prefetch = Prefetcher::spawn(batcher, 4, cfg.steps);

    let (from_tx, from_rx) = channel::<FromWorker>();
    let mut coord = Coordinator {
        cfg,
        dcfg,
        model,
        scfg,
        own,
        members: Roster::new(),
        from_tx,
        from_rx,
        probe: Duration::from_millis(probe_ms(std::env::var(PROBE_ENV).ok().as_deref())),
        gen: 0,
        controller: PhaseController::new(cfg.schedule.clone()),
        freeze_step: None,
        losses: Vec::with_capacity(cfg.steps),
        accs: Vec::with_capacity(cfg.steps),
        drops: 0,
        replays: 0,
        rejoins: 0,
        allreduce: Duration::ZERO,
    };
    let result = coord.run(prefetch);
    coord.shutdown();
    result
}

struct Coordinator<'rt, 'c> {
    cfg: &'c RunConfig,
    dcfg: &'c DistCfg,
    model: ModelMeta,
    scfg: SessionCfg,
    own: Session<'rt>,
    /// Live workers, always sorted by slot; a worker's shard position is
    /// its index here. Uid allocation and slot ordering live in the pure
    /// [`protocol::Roster`] core that `waveq-check` model-checks.
    members: Roster<Member>,
    from_tx: Sender<FromWorker>,
    from_rx: Receiver<FromWorker>,
    /// Barrier liveness-probe cadence (`protocol::DEFAULT_PROBE_MS`,
    /// overridable through `WAVEQ_DIST_PROBE_MS`), resolved once at
    /// construction.
    probe: Duration,
    /// Barrier generation: bumped on every membership change so replies
    /// from before a replay are discarded.
    gen: u64,
    controller: PhaseController,
    freeze_step: Option<usize>,
    losses: Vec<f32>,
    accs: Vec<f32>,
    drops: usize,
    replays: usize,
    rejoins: usize,
    allreduce: Duration,
}

impl Coordinator<'_, '_> {
    fn run(&mut self, mut prefetch: Prefetcher) -> Result<DistOutcome> {
        let t0 = Instant::now();

        // ---- launch membership -------------------------------------------
        for slot in 0..self.dcfg.workers {
            self.admit(slot)?;
        }
        let uids = self.members.uids();
        self.wait_ready(&uids)?;

        let mut machine = RoundMachine::new(self.cfg.steps, self.dcfg.round_len);
        machine.members_ready();
        if !self.dcfg.quiet {
            crate::info!(
                "dist: {} workers over {} steps (rounds of {}, {} reduction chunks)",
                self.members.len(),
                self.cfg.steps,
                self.dcfg.round_len,
                kn::GRAD_CHUNKS
            );
        }

        // ---- the round loop ----------------------------------------------
        while !machine.is_done() {
            match machine.state {
                RoundState::Warmup | RoundState::RoundTrain => {
                    self.run_round(&mut machine, &mut prefetch)?;
                }
                RoundState::Checkpoint => self.round_boundary(&mut machine)?,
                s => return Err(anyhow!("coordinator in unexpected state {s:?}")),
            }
        }
        let train_secs = t0.elapsed().as_secs_f64();

        // ---- final eval on the coordinator's replica ----------------------
        let (test_loss, test_acc) = eval_session(self.cfg, &mut self.own)?;
        if !self.dcfg.quiet {
            crate::info!(
                "dist: done {} steps in {train_secs:.1}s ({:.1} steps/s) test_acc={test_acc:.4} \
                 [drops {} replays {} rejoins {}]",
                self.cfg.steps,
                self.cfg.steps as f64 / train_secs,
                self.drops,
                self.replays,
                self.rejoins
            );
        }
        Ok(DistOutcome {
            state: self.own.state().clone(),
            test_loss,
            test_acc,
            loss: std::mem::take(&mut self.losses),
            acc: std::mem::take(&mut self.accs),
            freeze_step: self.freeze_step,
            steps: self.cfg.steps,
            rounds: machine.round,
            drops: self.drops,
            replays: self.replays,
            rejoins: self.rejoins,
            train_secs,
            allreduce_secs: self.allreduce.as_secs_f64(),
        })
    }

    /// Train one full round, replaying from the round-start snapshot on
    /// every membership loss until the round completes.
    fn run_round(&mut self, machine: &mut RoundMachine, prefetch: &mut Prefetcher) -> Result<()> {
        let round = machine.round;
        let round_start = machine.round_start();
        let round_end = machine.round_end();

        // Cache the round's batches once: a replay must re-feed the same
        // bytes, and the prefetcher cannot rewind.
        let mut batches = Vec::with_capacity(round_end - round_start);
        for s in round_start..round_end {
            batches.push(Arc::new(
                prefetch
                    .next()?
                    .ok_or_else(|| anyhow!("data pipeline ended early at step {s}"))?,
            ));
        }
        // Round-boundary snapshot: everything a replay must restore.
        let snap_state = self.own.state().clone();
        let snap_controller = self.controller.clone();
        let snap_freeze = self.freeze_step;

        'attempt: loop {
            for (i, batch) in batches.iter().enumerate() {
                let step = round_start + i;
                // Injected drops land at the tick barrier, before dispatch.
                let killed = self.chaos_kills_at(step);
                if !killed.is_empty() {
                    self.kill_members(&killed);
                    self.restore(&snap_state, &snap_controller, snap_freeze, round_start)?;
                    machine.replay();
                    continue 'attempt;
                }
                let knobs = match &self.dcfg.knobs {
                    KnobPlan::Fixed(k) => k.clone(),
                    KnobPlan::Auto => step_knobs(self.cfg, &self.controller, None, step),
                };
                match self.run_step(round, step, batch, &knobs)? {
                    Tick::Complete(m) => {
                        if !m.loss.is_finite() {
                            return Err(anyhow!("dist: loss diverged (NaN/inf) at step {step}"));
                        }
                        self.losses.push(m.loss);
                        self.accs.push(m.acc);
                        self.observe_freeze(step);
                        machine.step_done();
                    }
                    Tick::Lost(dead) => {
                        self.reap(&dead);
                        self.restore(&snap_state, &snap_controller, snap_freeze, round_start)?;
                        machine.replay();
                        continue 'attempt;
                    }
                }
            }
            return Ok(()); // round completed (machine is now in Checkpoint)
        }
    }

    /// One tick: fan out the grad stage by shard, barrier + reduce in
    /// fixed chunk order, broadcast the apply, barrier on acks.
    fn run_step(
        &mut self,
        round: usize,
        step: usize,
        batch: &Arc<Batch>,
        knobs: &StepKnobs,
    ) -> Result<Tick<StepMetrics>> {
        let denom = self.model.batch as f32;
        let n_live = self.members.len();
        let mut dead = Vec::new();
        for (pos, m) in self.members.iter().enumerate() {
            let chunks = shard_for(round, pos, n_live, kn::GRAD_CHUNKS);
            let msg = ToWorker::Step {
                gen: self.gen,
                step,
                denom,
                chunks,
                batch: Arc::clone(batch),
                knobs: knobs.clone(),
            };
            if m.tx.send(msg).is_err() {
                dead.push(m.uid);
            }
        }
        if !dead.is_empty() {
            return Ok(Tick::Lost(dead));
        }

        // ---- gradient barrier --------------------------------------------
        let mut by_chunk: Vec<Option<ChunkGrads>> = vec![None; kn::GRAD_CHUNKS];
        let mut barrier = BarrierCore::new(self.gen, self.members.uids());
        while !barrier.is_satisfied() {
            match self.recv(&barrier)? {
                Tick::Complete(FromWorker::Grads { worker, gen, step: s, parts }) if s == step => {
                    // `arrive` rejects stale generations and non-pending
                    // uids; chunks are only collected off accepted replies.
                    if barrier.arrive(worker, Some(gen)) {
                        for p in parts {
                            if p.chunk >= by_chunk.len() {
                                return Err(anyhow!(
                                    "worker returned chunk {} out of grid",
                                    p.chunk
                                ));
                            }
                            by_chunk[p.chunk] = Some(p);
                        }
                    }
                }
                Tick::Complete(_) => {} // wrong kind/step: discard
                Tick::Lost(d) => return Ok(Tick::Lost(d)),
            }
        }

        // ---- fixed-order all-reduce --------------------------------------
        let t0 = Instant::now();
        let (grads, ce_sum, acc_cnt) = self.reduce(&by_chunk)?;
        self.allreduce += t0.elapsed();

        // ---- shared apply -------------------------------------------------
        let grads = Arc::new(grads);
        for m in self.members.iter() {
            let msg = ToWorker::Apply {
                gen: self.gen,
                grads: Arc::clone(&grads),
                ce_sum,
                acc_cnt,
                denom,
                knobs: knobs.clone(),
            };
            if m.tx.send(msg).is_err() {
                dead.push(m.uid);
            }
        }
        if !dead.is_empty() {
            return Ok(Tick::Lost(dead));
        }
        let metrics = self.own.apply_update(&grads, ce_sum, acc_cnt, denom, knobs)?;
        let mut barrier = BarrierCore::new(self.gen, self.members.uids());
        while !barrier.is_satisfied() {
            match self.recv(&barrier)? {
                Tick::Complete(FromWorker::Applied { worker, gen }) => {
                    barrier.arrive(worker, Some(gen));
                }
                Tick::Complete(_) => {}
                Tick::Lost(d) => return Ok(Tick::Lost(d)),
            }
        }
        Ok(Tick::Complete(metrics))
    }

    /// Combine the collected per-chunk gradients in ascending chunk order
    /// through the named fixed-order helper — the arithmetic twin of the
    /// fused train step's internal reduction.
    fn reduce(&self, by_chunk: &[Option<ChunkGrads>]) -> Result<(Vec<Buffer>, f32, f32)> {
        for (c, slot) in by_chunk.iter().enumerate() {
            let (lo, hi) = kn::chunk_rows(c, self.model.batch);
            if lo != hi && slot.is_none() {
                return Err(anyhow!("reduction chunk {c} missing after the gradient barrier"));
            }
        }
        // Indexed by chunk, so iteration order IS ascending chunk order.
        let present: Vec<&ChunkGrads> = by_chunk.iter().flatten().collect();
        let mut grads = Vec::with_capacity(self.model.params.len());
        for (j, p) in self.model.params.iter().enumerate() {
            let n: usize = p.shape.iter().product();
            let mut dst = vec![0.0f32; n];
            let parts: Vec<&[f32]> = present.iter().map(|g| g.grads[j].as_slice()).collect();
            kn::allreduce_fixed_order(&mut dst, &parts);
            grads.push(Buffer::new(p.shape.clone(), dst)?);
        }
        let ces: Vec<[f32; 1]> = present.iter().map(|g| [g.ce_sum]).collect();
        let accs: Vec<[f32; 1]> = present.iter().map(|g| [g.acc_cnt]).collect();
        let mut ce_sum = 0.0f32;
        let mut acc_cnt = 0.0f32;
        kn::allreduce_fixed_order(
            std::slice::from_mut(&mut ce_sum),
            &ces.iter().map(|a| &a[..]).collect::<Vec<_>>(),
        );
        kn::allreduce_fixed_order(
            std::slice::from_mut(&mut acc_cnt),
            &accs.iter().map(|a| &a[..]).collect::<Vec<_>>(),
        );
        Ok((grads, ce_sum, acc_cnt))
    }

    /// Learned-mode freeze detection on the coordinator's replica, then
    /// broadcast the snapped beta so every worker replica snaps the same
    /// bits (matches `Trainer::run`'s post-step observation).
    fn observe_freeze(&mut self, step: usize) {
        if !matches!(self.dcfg.knobs, KnobPlan::Auto)
            || self.cfg.algo != Algo::WaveqLearned
            || self.freeze_step.is_some()
        {
            return;
        }
        if !self.controller.observe_beta(step, &self.own.state().beta) {
            return;
        }
        self.freeze_step = Some(step);
        let assign = BitAssignment::from_beta(&self.own.state().beta);
        let snapped = assign.snapped_beta();
        let st = self.own.state_mut();
        st.beta = snapped.clone();
        st.vbeta = vec![0.0; st.vbeta.len()];
        for m in self.members.iter() {
            // A failed send means the worker died; the next tick barrier
            // detects it and the round replays past this point anyway.
            let _ = m.tx.send(ToWorker::SnapBeta { beta: snapped.clone() });
        }
        if !self.dcfg.quiet {
            crate::info!(
                "dist: beta frozen at step {step} -> bits {:?} (avg {:.2})",
                assign.bits,
                assign.average_bits()
            );
        }
    }

    /// Round boundary: persist the coordinator's state, admit scheduled
    /// rejoins, advance the machine.
    fn round_boundary(&mut self, machine: &mut RoundMachine) -> Result<()> {
        let completed_rounds = machine.round + 1;
        if let Some(path) = &self.dcfg.checkpoint {
            Checkpoint::from_state(&self.model, self.own.state())?
                .with_round(completed_rounds)
                .save(path)?;
        }
        let joining: Vec<usize> = self
            .dcfg
            .chaos
            .iter()
            .filter_map(|e| match e {
                ChaosEvent::Rejoin { worker, at_round } if *at_round == completed_rounds => {
                    Some(*worker)
                }
                _ => None,
            })
            .collect();
        for slot in joining {
            if self.members.contains_slot(slot) {
                continue; // already live
            }
            let uid = self.admit(slot)?;
            self.wait_ready(&BTreeSet::from([uid]))?;
            self.gen += 1;
            let snapshot = Arc::new(self.own.state().clone());
            let m = self
                .members
                .find_uid(uid)
                .ok_or_else(|| anyhow!("rejoined worker {slot} vanished"))?;
            m.tx.send(ToWorker::Load { gen: self.gen, state: snapshot })
                .map_err(|_| anyhow!("rejoined worker {slot} died before loading state"))?;
            self.wait_loaded(uid)?;
            self.rejoins += 1;
            if !self.dcfg.quiet {
                crate::info!("dist: worker {slot} rejoined at round {completed_rounds}");
            }
        }
        machine.checkpoint_done();
        Ok(())
    }

    // ---- membership ------------------------------------------------------

    /// Spawn a worker into `slot`; the roster allocates its incarnation
    /// uid and keeps the membership sorted by slot. Returns the uid.
    fn admit(&mut self, slot: usize) -> Result<usize> {
        let scfg = self.scfg.clone();
        let from_tx = self.from_tx.clone();
        self.members.admit_with(slot, |uid| Member::spawn(slot, uid, scfg, from_tx))
    }

    fn chaos_kills_at(&self, step: usize) -> Vec<usize> {
        self.dcfg
            .chaos
            .iter()
            .filter_map(|e| match e {
                ChaosEvent::Kill { worker, at_step } if *at_step == step => Some(*worker),
                _ => None,
            })
            .filter(|slot| self.members.contains_slot(*slot))
            .collect()
    }

    /// Cleanly stop chaos-killed members (by slot) and reap them.
    fn kill_members(&mut self, slots: &[usize]) {
        let uids: Vec<usize> = self
            .members
            .iter()
            .filter(|m| slots.contains(&m.slot))
            .map(|m| m.uid)
            .collect();
        for m in self.members.iter().filter(|m| uids.contains(&m.uid)) {
            let _ = m.tx.send(ToWorker::Exit);
        }
        self.reap(&uids);
        if !self.dcfg.quiet {
            crate::warnlog!("dist: dropped worker slots {slots:?}; replaying the round");
        }
    }

    /// Remove dead members (by uid) from the membership and join their
    /// threads.
    fn reap(&mut self, uids: &[usize]) {
        for m in self.members.remove(uids) {
            self.drops += 1;
            if let Err(payload) = m.handle.join() {
                let msg = payload
                    .downcast_ref::<String>()
                    .map(String::as_str)
                    .or_else(|| payload.downcast_ref::<&'static str>().copied())
                    .unwrap_or("(non-string panic payload)");
                if !self.dcfg.quiet {
                    crate::warnlog!("dist: worker slot {} panicked: {msg}", m.slot);
                }
            }
        }
    }

    /// Restore the round-start snapshot everywhere after a membership
    /// loss: roll back the coordinator's replica, schedule controller, and
    /// metric series, then barrier every surviving worker on a state load.
    fn restore(
        &mut self,
        snap_state: &crate::runtime::SessionState,
        snap_controller: &PhaseController,
        snap_freeze: Option<usize>,
        round_start: usize,
    ) -> Result<()> {
        self.replays += 1;
        *self.own.state_mut() = snap_state.clone();
        self.controller = snap_controller.clone();
        self.freeze_step = snap_freeze;
        self.losses.truncate(round_start);
        self.accs.truncate(round_start);
        loop {
            if self.members.is_empty() {
                return Err(anyhow!("dist: every worker died; cannot continue the round"));
            }
            self.gen += 1;
            let snapshot = Arc::new(snap_state.clone());
            let mut dead = Vec::new();
            for m in self.members.iter() {
                if m.tx
                    .send(ToWorker::Load { gen: self.gen, state: Arc::clone(&snapshot) })
                    .is_err()
                {
                    dead.push(m.uid);
                }
            }
            if dead.is_empty() {
                let mut barrier = BarrierCore::new(self.gen, self.members.uids());
                let mut lost = Vec::new();
                while !barrier.is_satisfied() && lost.is_empty() {
                    match self.recv(&barrier)? {
                        Tick::Complete(FromWorker::Loaded { worker, gen }) => {
                            barrier.arrive(worker, Some(gen));
                        }
                        Tick::Complete(_) => {}
                        Tick::Lost(d) => lost = d,
                    }
                }
                if lost.is_empty() {
                    return Ok(());
                }
                dead = lost;
            }
            self.reap(&dead);
        }
    }

    // ---- barriers --------------------------------------------------------

    /// Receive one message from a *current* member, translating worker
    /// death (Fatal, disconnect, or a pending thread discovered finished
    /// on the probe timeout) into `Tick::Lost`. Stragglers from reaped
    /// incarnations are dropped here; deciding whether a returned message
    /// satisfies the barrier (right generation/step/kind) is the caller's
    /// job — `recv` only reads `barrier` for the probe's pending scan.
    fn recv(&self, barrier: &BarrierCore) -> Result<Tick<FromWorker>> {
        loop {
            match self.from_rx.recv_timeout(self.probe) {
                Ok(FromWorker::Fatal { worker, msg }) => {
                    if self.members.contains_uid(worker) {
                        if !self.dcfg.quiet {
                            crate::warnlog!("dist: worker uid {worker} failed: {msg}");
                        }
                        return Ok(Tick::Lost(vec![worker]));
                    }
                }
                Ok(m) => {
                    let uid = match &m {
                        FromWorker::Ready { worker }
                        | FromWorker::Grads { worker, .. }
                        | FromWorker::Applied { worker, .. }
                        | FromWorker::Loaded { worker, .. }
                        | FromWorker::Fatal { worker, .. } => *worker,
                    };
                    if !self.members.contains_uid(uid) {
                        continue; // straggler from a reaped incarnation
                    }
                    return Ok(Tick::Complete(m));
                }
                Err(RecvTimeoutError::Timeout) => {
                    let dead = barrier.finished_pending(|uid| {
                        self.members.find_uid(uid).is_some_and(|m| m.handle.is_finished())
                    });
                    if !dead.is_empty() {
                        return Ok(Tick::Lost(dead));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("dist: every worker channel disconnected"));
                }
            }
        }
    }

    /// Barrier on `Ready` from each uid in `expect` (launch / rejoin).
    fn wait_ready(&self, expect: &BTreeSet<usize>) -> Result<()> {
        let mut barrier = BarrierCore::new(self.gen, expect.iter().copied());
        while !barrier.is_satisfied() {
            match self.recv(&barrier)? {
                Tick::Complete(FromWorker::Ready { worker }) => {
                    barrier.arrive(worker, None); // Ready predates generations
                }
                Tick::Complete(_) => {}
                Tick::Lost(dead) => {
                    return Err(anyhow!("dist: worker(s) {dead:?} died before becoming ready"));
                }
            }
        }
        Ok(())
    }

    fn wait_loaded(&self, uid: usize) -> Result<()> {
        let mut barrier = BarrierCore::new(self.gen, [uid]);
        while !barrier.is_satisfied() {
            match self.recv(&barrier)? {
                Tick::Complete(FromWorker::Loaded { worker, gen }) => {
                    barrier.arrive(worker, Some(gen));
                }
                Tick::Complete(_) => {}
                Tick::Lost(dead) => {
                    return Err(anyhow!("dist: worker(s) {dead:?} died while loading state"));
                }
            }
        }
        Ok(())
    }

    /// Stop every remaining worker (end of run or error unwind).
    fn shutdown(&mut self) {
        for m in self.members.iter() {
            let _ = m.tx.send(ToWorker::Exit);
        }
        for m in self.members.drain_all() {
            let _ = m.handle.join();
        }
    }
}
