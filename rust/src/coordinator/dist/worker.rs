//! One in-process data-parallel worker: a dedicated thread owning its own
//! `Runtime` + grad-stage [`Session`] replica, driven tick-by-tick over
//! mpsc channels by the coordinator in `dist`.
//!
//! A worker never touches the data pipeline or the schedule: the
//! coordinator ships it the shared global batch (an `Arc`), the chunk
//! range it owns this round, and the already-resolved step knobs. The
//! worker's only arithmetic is the grad stage over its chunks and the
//! shared apply — both routed through the same native-backend programs the
//! single-process path runs, which is what makes every replica's state
//! bitwise equal to the fused 1-worker run.

use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::data::Batch;
use crate::runtime::native::kernels as kn;
use crate::runtime::native::pool;
use crate::runtime::{Buffer, Runtime, Session, SessionCfg, SessionState, StepKnobs};

/// The gradients one worker produced for one reduction chunk of the
/// global batch (data vectors in manifest parameter order), plus the
/// unnormalized CE parts of its rows.
#[derive(Debug, Clone)]
pub struct ChunkGrads {
    pub chunk: usize,
    pub grads: Vec<Vec<f32>>,
    pub ce_sum: f32,
    pub acc_cnt: f32,
}

/// Coordinator -> worker directives. Channel order is the tick order: a
/// worker handles each directive to completion before the next, so the
/// coordinator's FIFO *is* the barrier structure.
pub enum ToWorker {
    /// Run the grad stage over the owned `chunks` of `batch`.
    Step {
        gen: u64,
        step: usize,
        denom: f32,
        chunks: Range<usize>,
        batch: Arc<Batch>,
        knobs: StepKnobs,
    },
    /// Apply the chunk-reduced update shared by every replica.
    Apply {
        gen: u64,
        grads: Arc<Vec<Buffer>>,
        ce_sum: f32,
        acc_cnt: f32,
        denom: f32,
        knobs: StepKnobs,
    },
    /// Overwrite the replica state (round replay / rejoin admission).
    Load { gen: u64, state: Arc<SessionState> },
    /// Beta froze on the coordinator: snap it here too (learned mode).
    SnapBeta { beta: Vec<f32> },
    Exit,
}

/// Worker -> coordinator replies. `gen` echoes the directive's generation
/// so replies from before a replay/membership change are discarded.
pub enum FromWorker {
    Ready { worker: usize },
    Grads { worker: usize, gen: u64, step: usize, parts: Vec<ChunkGrads> },
    Applied { worker: usize, gen: u64 },
    Loaded { worker: usize, gen: u64 },
    Fatal { worker: usize, msg: String },
}

/// A live worker as the coordinator sees it. `slot` is the stable worker
/// identity (what chaos events and logs name, reused across rejoins);
/// `uid` is unique per incarnation, so stragglers from a dead worker's
/// first life can never be mistaken for its rejoined successor.
pub struct Member {
    pub slot: usize,
    pub uid: usize,
    pub tx: Sender<ToWorker>,
    pub handle: JoinHandle<()>,
}

impl super::protocol::RosterEntry for Member {
    fn slot(&self) -> usize {
        self.slot
    }
    fn uid(&self) -> usize {
        self.uid
    }
}

impl Member {
    /// Spawn a worker thread with its own runtime + session replica. The
    /// worker sends `Ready` once its session is open (or `Fatal` if the
    /// open fails) and then serves directives until `Exit` or channel
    /// close.
    pub fn spawn(
        slot: usize,
        uid: usize,
        scfg: SessionCfg,
        to_coord: Sender<FromWorker>,
    ) -> Result<Member> {
        let (tx, rx) = channel::<ToWorker>();
        let handle = pool::spawn_worker(&format!("waveq-dist-{slot}"), move || {
            worker_main(uid, scfg, &rx, &to_coord);
        })
        .map_err(|e| anyhow!("spawning dist worker {slot}: {e}"))?;
        Ok(Member { slot, uid, tx, handle })
    }
}

fn worker_main(uid: usize, scfg: SessionCfg, rx: &Receiver<ToWorker>, tx: &Sender<FromWorker>) {
    let id = uid; // messages identify this incarnation by uid
    // Each worker owns a full Runtime: the native backend's interior state
    // is single-threaded by design, and program "compilation" is a
    // manifest lookup, so replicas are cheap and fully isolated.
    let rt = Runtime::native();
    let mut session = match open_replica(&rt, &scfg) {
        Ok(s) => s,
        Err(e) => {
            let _ = tx.send(FromWorker::Fatal { worker: id, msg: e.to_string() });
            return;
        }
    };
    let mut outs = session.grad_outputs();
    if tx.send(FromWorker::Ready { worker: id }).is_err() {
        return;
    }
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // coordinator gone
        };
        let reply = match msg {
            ToWorker::Step { gen, step, denom, chunks, batch, knobs } => {
                match step_grads(&session, &mut outs, &chunks, &batch, &knobs, denom) {
                    Ok(parts) => FromWorker::Grads { worker: id, gen, step, parts },
                    Err(e) => FromWorker::Fatal { worker: id, msg: e.to_string() },
                }
            }
            ToWorker::Apply { gen, grads, ce_sum, acc_cnt, denom, knobs } => {
                match session.apply_update(&grads, ce_sum, acc_cnt, denom, &knobs) {
                    Ok(_) => FromWorker::Applied { worker: id, gen },
                    Err(e) => FromWorker::Fatal { worker: id, msg: e.to_string() },
                }
            }
            ToWorker::Load { gen, state } => {
                *session.state_mut() = (*state).clone();
                FromWorker::Loaded { worker: id, gen }
            }
            ToWorker::SnapBeta { beta } => {
                let st = session.state_mut();
                st.beta = beta;
                st.vbeta = vec![0.0; st.vbeta.len()];
                continue; // no reply: FIFO order covers it
            }
            ToWorker::Exit => return,
        };
        let fatal = matches!(reply, FromWorker::Fatal { .. });
        if tx.send(reply).is_err() || fatal {
            return;
        }
    }
}

fn open_replica<'rt>(rt: &'rt Runtime, scfg: &SessionCfg) -> Result<Session<'rt>> {
    let mut session = Session::open(rt, scfg)?;
    session.enable_grad_stage(rt)?;
    Ok(session)
}

/// Run the grad stage over each owned chunk of the global batch. One
/// program call per chunk — the chunk grid (not the worker count) is the
/// reduction unit, so the per-chunk gradients are bitwise the ones the
/// fused single-process step computes internally.
fn step_grads(
    session: &Session<'_>,
    outs: &mut [Buffer],
    chunks: &Range<usize>,
    batch: &Batch,
    knobs: &StepKnobs,
    denom: f32,
) -> Result<Vec<ChunkGrads>> {
    let model = session.model();
    let pix: usize = model.input_shape.iter().product();
    let ncls = model.num_classes;
    let rows = model.batch;
    let np = outs.len() - 2;
    let mut parts = Vec::with_capacity(chunks.len());
    for chunk in chunks.clone() {
        let (lo, hi) = kn::chunk_rows(chunk, rows);
        if lo == hi {
            continue;
        }
        let (xr, yr) = batch.rows(lo, hi, pix, ncls);
        session.step_grads_into(xr, yr, knobs, denom, outs)?;
        parts.push(ChunkGrads {
            chunk,
            grads: outs[..np].iter().map(|b| b.data.clone()).collect(),
            ce_sum: outs[np].data[0],
            acc_cnt: outs[np + 1].data[0],
        });
    }
    Ok(parts)
}
