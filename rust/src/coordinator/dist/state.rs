//! The coordinator's round state machine, kept pure (no channels, no
//! sessions) so every transition is unit-testable and the run loop in
//! `dist::run_distributed` only *reads* decisions off it.
//!
//! States:
//!   WaitingForMembers --members_ready--> Warmup (LR-ramp steps)
//!   Warmup --step_done (past warmup)--> RoundTrain
//!   RoundTrain --step_done (round boundary)--> Checkpoint
//!   Checkpoint --checkpoint_done--> RoundTrain | Warmup | Done
//!
//! A worker drop mid-round calls [`RoundMachine::replay`], which rewinds
//! the step cursor to the current round's first step without leaving the
//! training states — re-sharding and state restoration are the run loop's
//! job; the machine only guarantees the cursor lands exactly on the round
//! boundary the snapshots were taken at.

/// Linear LR warmup horizon (steps), matching `trainer::step_knobs`.
pub const WARMUP_STEPS: usize = 30;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoundState {
    /// Blocked until every launch worker reports Ready.
    WaitingForMembers,
    /// Training inside the LR-warmup horizon (steps < `warmup_steps`).
    Warmup,
    /// Steady-state training inside a round.
    RoundTrain,
    /// At a round boundary: persist state, admit rejoins, re-shard.
    Checkpoint,
    /// All steps trained and the final boundary handled.
    Done,
}

// `PartialEq`/`Eq`/`Hash` let `waveq-check` embed the machine verbatim in
// its hashed protocol states, so the checker replays the *real* round
// cursor/replay arithmetic instead of a reimplementation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RoundMachine {
    pub total_steps: usize,
    pub warmup_steps: usize,
    pub round_len: usize,
    pub state: RoundState,
    /// Next step to train (global step index).
    pub step: usize,
    /// Current round index; round r covers steps
    /// `[r * round_len, min((r+1) * round_len, total_steps))`.
    pub round: usize,
}

impl RoundMachine {
    pub fn new(total_steps: usize, round_len: usize) -> RoundMachine {
        assert!(round_len >= 1, "round_len must be >= 1");
        RoundMachine {
            total_steps,
            warmup_steps: WARMUP_STEPS,
            round_len,
            state: RoundState::WaitingForMembers,
            step: 0,
            round: 0,
        }
    }

    fn train_state(&self) -> RoundState {
        if self.step < self.warmup_steps {
            RoundState::Warmup
        } else {
            RoundState::RoundTrain
        }
    }

    pub fn round_start(&self) -> usize {
        self.round * self.round_len
    }

    pub fn round_end(&self) -> usize {
        ((self.round + 1) * self.round_len).min(self.total_steps)
    }

    /// All launch members reported Ready: enter training.
    pub fn members_ready(&mut self) {
        assert_eq!(self.state, RoundState::WaitingForMembers, "members_ready from {:?}", self.state);
        self.state = if self.total_steps == 0 { RoundState::Done } else { self.train_state() };
    }

    /// One global step trained and applied everywhere.
    pub fn step_done(&mut self) {
        assert!(
            matches!(self.state, RoundState::Warmup | RoundState::RoundTrain),
            "step_done from {:?}",
            self.state
        );
        self.step += 1;
        self.state =
            if self.step >= self.round_end() { RoundState::Checkpoint } else { self.train_state() };
    }

    /// Round boundary handled (checkpoint written, rejoins admitted).
    pub fn checkpoint_done(&mut self) {
        assert_eq!(self.state, RoundState::Checkpoint, "checkpoint_done from {:?}", self.state);
        self.round += 1;
        self.state =
            if self.step >= self.total_steps { RoundState::Done } else { self.train_state() };
    }

    /// A member dropped mid-round: rewind the cursor to the round's first
    /// step (where the replay snapshots were taken).
    pub fn replay(&mut self) {
        assert!(
            matches!(self.state, RoundState::Warmup | RoundState::RoundTrain),
            "replay from {:?}",
            self.state
        );
        self.step = self.round_start();
        self.state = self.train_state();
    }

    pub fn is_done(&self) -> bool {
        self.state == RoundState::Done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walks_waiting_warmup_roundtrain_checkpoint_done() {
        // 5 steps, rounds of 2, warmup shrunk to 3 for the test.
        let mut m = RoundMachine::new(5, 2);
        m.warmup_steps = 3;
        assert_eq!(m.state, RoundState::WaitingForMembers);
        m.members_ready();
        let mut seen = vec![m.state];
        while !m.is_done() {
            match m.state {
                RoundState::Warmup | RoundState::RoundTrain => m.step_done(),
                RoundState::Checkpoint => m.checkpoint_done(),
                s => panic!("unexpected state {s:?}"),
            }
            seen.push(m.state);
        }
        use RoundState::*;
        assert_eq!(
            seen,
            vec![
                Warmup,     // step 0
                Warmup,     // step 1
                Checkpoint, // round 0 boundary (steps 0..2)
                Warmup,     // step 2
                RoundTrain, // step 3 (past warmup)
                Checkpoint, // round 1 boundary (steps 2..4)
                RoundTrain, // step 4
                Checkpoint, // round 2 boundary (ragged: step 4 only)
                Done,
            ]
        );
        assert_eq!((m.step, m.round), (5, 3));
    }

    #[test]
    fn round_boundaries_cover_the_step_range_exactly() {
        let mut m = RoundMachine::new(13, 5);
        m.members_ready();
        let mut covered = Vec::new();
        while !m.is_done() {
            assert_eq!(m.step, m.round_start().max(covered.len()));
            let (lo, hi) = (m.round_start(), m.round_end());
            assert!(lo < hi && hi <= 13);
            for s in lo..hi {
                covered.push(s);
                m.step_done();
            }
            assert_eq!(m.state, RoundState::Checkpoint);
            m.checkpoint_done();
        }
        assert_eq!(covered, (0..13).collect::<Vec<_>>());
        assert_eq!(m.round, 3, "13 steps at round_len 5 = rounds of 5/5/3");
    }

    #[test]
    fn replay_rewinds_to_the_round_start_only() {
        let mut m = RoundMachine::new(20, 4);
        m.warmup_steps = 0;
        m.members_ready();
        // Finish round 0, then walk 3 steps into round 1.
        for _ in 0..4 {
            m.step_done();
        }
        m.checkpoint_done();
        for _ in 0..3 {
            m.step_done();
        }
        assert_eq!((m.round, m.step), (1, 7));
        m.replay();
        assert_eq!((m.round, m.step), (1, 4), "cursor lands on round 1's first step");
        assert_eq!(m.state, RoundState::RoundTrain);
        // The replayed round then completes normally.
        for _ in 0..4 {
            m.step_done();
        }
        assert_eq!(m.state, RoundState::Checkpoint);
    }

    #[test]
    fn zero_steps_finishes_at_members_ready() {
        let mut m = RoundMachine::new(0, 4);
        m.members_ready();
        assert!(m.is_done());
    }
}
