//! Pure decision cores of the dist tick-barrier/membership protocol:
//! the roster (per-incarnation uids over stable slots) and the barrier
//! (generation-gated reply accounting), with every channel and thread
//! stripped away.
//!
//! Production (`dist::Coordinator`) wraps these cores in the real sync
//! layer — mpsc channels, `JoinHandle` liveness probes — while
//! `waveq-check` drives the *same* cores from a virtual scheduler and
//! exhaustively explores every interleaving of coordinator and worker
//! steps. The accept/reject decisions verified there are the ones
//! executing here; the sync layers only differ in how a decision's
//! inputs arrive.

use std::collections::BTreeSet;

/// Default cadence of the barrier liveness probe, in milliseconds.
///
/// While a barrier waits, the coordinator wakes at this cadence whenever
/// the reply queue is empty and scans the pending uids for threads that
/// finished without replying (a panic unwound `worker_main`, so neither a
/// `Fatal` nor a disconnect error is coming — the channel's sender half
/// is cloned into every member). 100 ms is invisible next to a training
/// tick but bounds how long a dead worker can stall a round; tests and
/// latency-sensitive deployments lower it through [`PROBE_ENV`].
pub const DEFAULT_PROBE_MS: u64 = 100;

/// Env var overriding [`DEFAULT_PROBE_MS`] (positive integer, in ms).
pub const PROBE_ENV: &str = "WAVEQ_DIST_PROBE_MS";

/// Resolve the probe cadence from a raw [`PROBE_ENV`] value. Unset,
/// non-numeric, and zero values fall back to [`DEFAULT_PROBE_MS`] — the
/// probe is a liveness mechanism, so there is no "disabled" setting.
pub fn probe_ms(raw: Option<&str>) -> u64 {
    raw.and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&ms| ms >= 1)
        .unwrap_or(DEFAULT_PROBE_MS)
}

/// What the roster needs to know about a member. Production implements
/// this on `worker::Member` (slot + uid next to the channel and join
/// handle); the model checker implements it on a plain struct.
pub trait RosterEntry {
    /// Stable worker identity: shard position, chaos-event target, log
    /// name. Reused across rejoins.
    fn slot(&self) -> usize;
    /// Per-incarnation identity: unique across the run, so stragglers
    /// from a dead worker's first life can never be mistaken for its
    /// rejoined successor.
    fn uid(&self) -> usize;
}

/// Live membership: entries sorted by slot (a member's shard position is
/// its index), uids allocated once and never reused.
///
/// The comparison/hash derives only bite when `M` implements them —
/// production's `Member` (channel + join handle) does not, while the
/// model checker's plain member struct does, letting `waveq-check` embed
/// the real roster in its hashed protocol states.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Roster<M> {
    next_uid: usize,
    members: Vec<M>,
}

impl<M: RosterEntry> Roster<M> {
    pub fn new() -> Roster<M> {
        Roster { next_uid: 0, members: Vec::new() }
    }

    /// Admit a member into `slot`: allocate the next uid, build the entry
    /// through `make` (production spawns the worker thread there), insert
    /// sorted by slot. Returns the new uid.
    pub fn admit_with<E>(
        &mut self,
        slot: usize,
        make: impl FnOnce(usize) -> Result<M, E>,
    ) -> Result<usize, E> {
        let uid = self.next_uid;
        let member = make(uid)?;
        debug_assert_eq!(member.slot(), slot, "admitted member must carry its slot");
        debug_assert_eq!(member.uid(), uid, "admitted member must carry its uid");
        self.next_uid += 1;
        let at = self.members.partition_point(|m| m.slot() < slot);
        self.members.insert(at, member);
        Ok(uid)
    }

    /// Remove the named uids from the membership, returning them (sorted
    /// by slot, as they were stored) so the caller can join/log them.
    pub fn remove(&mut self, uids: &[usize]) -> Vec<M> {
        let mut removed = Vec::new();
        let mut kept = Vec::with_capacity(self.members.len());
        for m in self.members.drain(..) {
            if uids.contains(&m.uid()) {
                removed.push(m);
            } else {
                kept.push(m);
            }
        }
        self.members = kept;
        removed
    }

    /// Drain every member (shutdown).
    pub fn drain_all(&mut self) -> Vec<M> {
        std::mem::take(&mut self.members)
    }

    pub fn contains_uid(&self, uid: usize) -> bool {
        self.members.iter().any(|m| m.uid() == uid)
    }

    pub fn contains_slot(&self, slot: usize) -> bool {
        self.members.iter().any(|m| m.slot() == slot)
    }

    pub fn find_uid(&self, uid: usize) -> Option<&M> {
        self.members.iter().find(|m| m.uid() == uid)
    }

    /// The live uids, ordered by slot (ascending, like iteration).
    pub fn uids(&self) -> BTreeSet<usize> {
        self.members.iter().map(|m| m.uid()).collect()
    }

    pub fn iter(&self) -> std::slice::Iter<'_, M> {
        self.members.iter()
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

impl<M: RosterEntry> Default for Roster<M> {
    fn default() -> Roster<M> {
        Roster::new()
    }
}

/// One barrier's reply accounting: the uids still owed a reply, gated on
/// the generation the barrier was opened at.
///
/// The coordinator bumps its generation on every membership change; a
/// reply echoing an older generation was computed against a membership
/// that no longer exists, so [`BarrierCore::arrive`] rejects it even when
/// its uid is pending. Replies from non-pending uids (reaped incarnations
/// whose messages were already drained, duplicates) are rejected by the
/// set membership itself. The model checker proves no interleaving of
/// drops, replays, and stale queue contents lets a rejected reply satisfy
/// a barrier.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BarrierCore {
    gen: u64,
    pending: BTreeSet<usize>,
}

impl BarrierCore {
    pub fn new(gen: u64, expect: impl IntoIterator<Item = usize>) -> BarrierCore {
        BarrierCore { gen, pending: expect.into_iter().collect() }
    }

    /// Feed one reply. Returns `true` iff it satisfies part of this
    /// barrier: the uid is still pending and the echoed generation (when
    /// the reply kind carries one — launch `Ready` predates generations)
    /// matches the barrier's. The caller must not act on a reply's
    /// payload when this returns `false`.
    pub fn arrive(&mut self, uid: usize, echoed_gen: Option<u64>) -> bool {
        if let Some(g) = echoed_gen {
            if g != self.gen {
                return false;
            }
        }
        self.pending.remove(&uid)
    }

    pub fn is_satisfied(&self) -> bool {
        self.pending.is_empty()
    }

    pub fn gen(&self) -> u64 {
        self.gen
    }

    pub fn pending(&self) -> &BTreeSet<usize> {
        &self.pending
    }

    /// The liveness-probe scan: pending uids whose thread `is_finished`.
    /// A finished thread can never reply, so the barrier would otherwise
    /// wait forever; the caller reaps these and replays the round.
    pub fn finished_pending(&self, is_finished: impl Fn(usize) -> bool) -> Vec<usize> {
        self.pending.iter().copied().filter(|&uid| is_finished(uid)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Entry {
        slot: usize,
        uid: usize,
    }

    impl RosterEntry for Entry {
        fn slot(&self) -> usize {
            self.slot
        }
        fn uid(&self) -> usize {
            self.uid
        }
    }

    fn admit(r: &mut Roster<Entry>, slot: usize) -> usize {
        r.admit_with(slot, |uid| Ok::<_, ()>(Entry { slot, uid })).unwrap()
    }

    #[test]
    fn probe_ms_parses_overrides_and_falls_back() {
        assert_eq!(probe_ms(None), DEFAULT_PROBE_MS);
        assert_eq!(probe_ms(Some("25")), 25);
        assert_eq!(probe_ms(Some(" 7 ")), 7, "whitespace is trimmed");
        assert_eq!(probe_ms(Some("0")), DEFAULT_PROBE_MS, "the probe cannot be disabled");
        assert_eq!(probe_ms(Some("-3")), DEFAULT_PROBE_MS);
        assert_eq!(probe_ms(Some("fast")), DEFAULT_PROBE_MS);
        assert_eq!(probe_ms(Some("")), DEFAULT_PROBE_MS);
    }

    #[test]
    fn roster_allocates_fresh_uids_and_keeps_slot_order() {
        let mut r: Roster<Entry> = Roster::new();
        let u2 = admit(&mut r, 2);
        let u0 = admit(&mut r, 0);
        let u1 = admit(&mut r, 1);
        assert_eq!((u2, u0, u1), (0, 1, 2), "uids are allocation-ordered");
        let slots: Vec<usize> = r.iter().map(|m| m.slot).collect();
        assert_eq!(slots, vec![0, 1, 2], "members stay sorted by slot");

        // Drop slot 1, rejoin it: the new incarnation gets a fresh uid.
        let removed = r.remove(&[u1]);
        assert_eq!(removed.len(), 1);
        assert!(!r.contains_uid(u1));
        assert!(!r.contains_slot(1));
        let u1b = admit(&mut r, 1);
        assert_eq!(u1b, 3, "uids are never reused across incarnations");
        assert!(r.contains_slot(1));
        let slots: Vec<usize> = r.iter().map(|m| m.slot).collect();
        assert_eq!(slots, vec![0, 1, 2], "rejoin lands back in slot order");
        assert_eq!(r.uids(), BTreeSet::from([0, 2, 3]));
    }

    #[test]
    fn roster_admit_failure_allocates_nothing() {
        let mut r: Roster<Entry> = Roster::new();
        let err = r.admit_with(0, |_| Err::<Entry, &str>("spawn failed"));
        assert_eq!(err.unwrap_err(), "spawn failed");
        assert!(r.is_empty());
        assert_eq!(admit(&mut r, 0), 0, "a failed admission does not burn a uid");
    }

    #[test]
    fn barrier_rejects_stale_generation_and_unknown_uids() {
        let mut b = BarrierCore::new(7, [10, 11]);
        assert!(!b.is_satisfied());
        assert!(!b.arrive(10, Some(6)), "a stale-generation echo never satisfies");
        assert!(!b.arrive(12, Some(7)), "an unknown uid never satisfies");
        assert!(b.arrive(10, Some(7)));
        assert!(!b.arrive(10, Some(7)), "a duplicate reply never satisfies");
        assert!(b.arrive(11, None), "Ready-style replies carry no generation");
        assert!(b.is_satisfied());
    }

    #[test]
    fn barrier_probe_scan_names_only_finished_pending_uids() {
        let b = BarrierCore::new(0, [3, 4, 5]);
        let dead = b.finished_pending(|uid| uid == 4 || uid == 9);
        assert_eq!(dead, vec![4], "only pending uids are scanned");
        assert!(b.finished_pending(|_| false).is_empty());
    }
}
