//! Binary checkpointing of train state (own format; no serde offline).
//!
//! Layout (little-endian):
//!   magic "WVQCKPT1" | u32 n_tensors | per tensor:
//!     u32 name_len | name bytes | u32 rank | u64 dims[rank] | f32 data[]
//! plus a trailing beta section: u32 q | f32 beta[q] | f32 vbeta[q].

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"WVQCKPT1";

pub struct Checkpoint {
    pub tensors: Vec<(String, Tensor)>,
    pub beta: Vec<f32>,
    pub vbeta: Vec<f32>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &v in &t.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        f.write_all(&(self.beta.len() as u32).to_le_bytes())?;
        for &v in self.beta.iter().chain(&self.vbeta) {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(anyhow!("{} is not a waveq checkpoint", path.display()));
        }
        let n = read_u32(&mut f)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let count: usize = shape.iter().product();
            let mut data = vec![0f32; count];
            read_f32s(&mut f, &mut data)?;
            tensors.push((String::from_utf8(name)?, Tensor::new(shape, data)?));
        }
        let q = read_u32(&mut f)? as usize;
        let mut beta = vec![0f32; q];
        let mut vbeta = vec![0f32; q];
        read_f32s(&mut f, &mut beta)?;
        read_f32s(&mut f, &mut vbeta)?;
        Ok(Checkpoint { tensors, beta, vbeta })
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R, out: &mut [f32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (i, chunk) in buf.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let ck = Checkpoint {
            tensors: vec![
                (
                    "w1".into(),
                    Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-9, -7.25]).unwrap(),
                ),
                ("b1".into(), Tensor::new(vec![3], vec![0.1, 0.2, 0.3]).unwrap()),
                ("scalar".into(), Tensor::new(vec![], vec![42.0]).unwrap()),
            ],
            beta: vec![3.3, 4.7],
            vbeta: vec![0.01, -0.02],
        };
        let path = std::env::temp_dir().join("waveq_ckpt_test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 3);
        for ((n1, t1), (n2, t2)) in ck.tensors.iter().zip(&back.tensors) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
        assert_eq!(back.beta, ck.beta);
        assert_eq!(back.vbeta, ck.vbeta);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let path = std::env::temp_dir().join("waveq_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
