//! Checkpointing moved into the runtime layer (`runtime::checkpoint`) so
//! `Session::save_checkpoint` / `load_checkpoint` can use it without the
//! runtime reaching up into the coordinator. This alias keeps the
//! historical `coordinator::Checkpoint` path working.

pub use crate::runtime::checkpoint::Checkpoint;
