//! Metric series recorder: every figure in the paper is a dump of one or
//! more of these series (loss/acc curves, reg loss, lambda profiles, beta
//! trajectories, weight snapshots). Output formats: CSV (plotting) and JSON
//! (EXPERIMENTS.md tooling).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Default)]
pub struct MetricsRecorder {
    /// series name -> (step, value) points.
    pub series: BTreeMap<String, Vec<(usize, f64)>>,
}

impl MetricsRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, step: usize, name: &str, value: f64) {
        self.series.entry(name.to_string()).or_default().push((step, value));
    }

    pub fn add_f32(&mut self, step: usize, name: &str, value: f32) {
        self.add(step, name, value as f64);
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.series.get(name).and_then(|v| v.last()).map(|&(_, v)| v)
    }

    pub fn get(&self, name: &str) -> &[(usize, f64)] {
        self.series.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Mean of the final `n` values (smoothed end-of-training metric).
    pub fn tail_mean(&self, name: &str, n: usize) -> Option<f64> {
        let s = self.series.get(name)?;
        if s.is_empty() {
            return None;
        }
        let tail = &s[s.len().saturating_sub(n)..];
        Some(tail.iter().map(|&(_, v)| v).sum::<f64>() / tail.len() as f64)
    }

    /// Wide CSV: one row per step, one column per series (empty if absent).
    pub fn to_csv(&self) -> String {
        let names: Vec<&String> = self.series.keys().collect();
        let mut steps: Vec<usize> = self
            .series
            .values()
            .flat_map(|v| v.iter().map(|&(s, _)| s))
            .collect();
        steps.sort_unstable();
        steps.dedup();
        let mut lookup: BTreeMap<&str, BTreeMap<usize, f64>> = BTreeMap::new();
        for (name, pts) in &self.series {
            lookup.insert(name, pts.iter().cloned().collect());
        }
        let mut out = String::from("step");
        for n in &names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        for s in steps {
            out.push_str(&s.to_string());
            for n in &names {
                out.push(',');
                if let Some(v) = lookup[n.as_str()].get(&s) {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (name, pts) in &self.series {
            let arr = pts
                .iter()
                .map(|&(s, v)| Json::Arr(vec![Json::Num(s as f64), Json::Num(v)]))
                .collect();
            obj.insert(name.clone(), Json::Arr(arr));
        }
        Json::Obj(obj)
    }

    pub fn save_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv()).map_err(|e| anyhow!("writing {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut m = MetricsRecorder::new();
        m.add(0, "loss", 2.3);
        m.add(1, "loss", 1.9);
        m.add(1, "acc", 0.4);
        assert_eq!(m.last("loss"), Some(1.9));
        assert_eq!(m.get("acc").len(), 1);
        assert!((m.tail_mean("loss", 2).unwrap() - 2.1).abs() < 1e-12);
        assert_eq!(m.last("nope"), None);
    }

    #[test]
    fn csv_alignment() {
        let mut m = MetricsRecorder::new();
        m.add(0, "a", 1.0);
        m.add(2, "a", 3.0);
        m.add(2, "b", 9.0);
        let csv = m.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,");
        assert_eq!(lines[2], "2,3,9");
    }

    #[test]
    fn json_emission_parses_back() {
        let mut m = MetricsRecorder::new();
        m.add(5, "x", 0.25);
        let j = m.to_json().to_string();
        let back = Json::parse(&j).unwrap();
        let pts = back.get("x").unwrap().as_arr().unwrap();
        assert_eq!(pts[0].as_arr().unwrap()[0].as_usize().unwrap(), 5);
    }
}
