//! Backend-interchange train state.
//!
//! The state type now lives with the runtime session that owns it during
//! training (`runtime::session::SessionState` — parameters and optimizer
//! velocities as runtime buffers, plus the beta/vbeta host mirrors); this
//! module keeps the coordinator-facing `TrainState` name for the
//! checkpoint/outcome plumbing built on it.

pub use crate::runtime::session::SessionState as TrainState;
