//! Backend-interchange train state: parameters + optimizer velocities as
//! runtime buffers (moved into each step call and replaced by the step's
//! outputs — no per-step re-marshalling of weights), plus the small
//! host-side mirrors the coordinator actually inspects (beta, scalars).

use anyhow::{anyhow, Result};

use crate::runtime::{buffer_f32, to_vec_f32, Buffer, ModelMeta};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct TrainState {
    pub params: Vec<Buffer>,
    pub vels: Vec<Buffer>,
    /// Continuous per-layer bitwidth parameter (waveq programs only).
    pub beta: Vec<f32>,
    pub vbeta: Vec<f32>,
    pub step: usize,
}

impl TrainState {
    /// He/affine initialization matching the layer kinds in the manifest.
    pub fn init(model: &ModelMeta, seed: u64, beta_init: f32) -> Result<TrainState> {
        let mut rng = Rng::new(seed).split(0x1417);
        let mut params = Vec::with_capacity(model.params.len());
        let mut vels = Vec::with_capacity(model.params.len());
        for p in &model.params {
            let n: usize = p.shape.iter().product();
            // Fixup-style: residual-body tail convs start near zero so deep
            // residual chains begin as identity (manifest init = "he_res").
            let res_scale = if p.init == "he_res" { 0.1 } else { 1.0 };
            let data = match p.kind.as_str() {
                "conv" | "dwconv" => {
                    let fan_in: usize = p.shape[..p.shape.len() - 1].iter().product();
                    rng.normal_vec(n, res_scale * (2.0 / fan_in as f32).sqrt())
                }
                "fc" => {
                    let fan_in = p.shape[0];
                    rng.normal_vec(n, (2.0 / fan_in as f32).sqrt())
                }
                "affine" if p.name.ends_with("_s") => vec![1.0; n],
                _ => vec![0.0; n], // biases, affine shifts
            };
            params.push(buffer_f32(&data, &p.shape)?);
            vels.push(buffer_f32(&vec![0.0; n], &p.shape)?);
        }
        Ok(TrainState {
            params,
            vels,
            beta: vec![beta_init; model.num_qlayers],
            vbeta: vec![0.0; model.num_qlayers],
            step: 0,
        })
    }

    /// Host copy of one parameter (observers, checkpoints, histograms).
    pub fn param_tensor(&self, model: &ModelMeta, idx: usize) -> Result<Tensor> {
        let data = to_vec_f32(&self.params[idx])?;
        Tensor::new(model.params[idx].shape.clone(), data)
    }

    /// Host copies of all parameters.
    pub fn all_params(&self, model: &ModelMeta) -> Result<Vec<Tensor>> {
        (0..self.params.len()).map(|i| self.param_tensor(model, i)).collect()
    }

    /// Replace parameters from host tensors (checkpoint restore).
    pub fn set_params(&mut self, tensors: &[Tensor]) -> Result<()> {
        if tensors.len() != self.params.len() {
            return Err(anyhow!(
                "checkpoint has {} params, model wants {}",
                tensors.len(),
                self.params.len()
            ));
        }
        self.params = tensors
            .iter()
            .map(|t| buffer_f32(&t.data, &t.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}
