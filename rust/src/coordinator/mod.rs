//! L3 coordinator: training orchestration on top of the AOT runtime.
//!
//! * `trainer`    — the per-run event loop (schedule, freeze, metrics)
//! * `evaluator`  — batched held-out evaluation (shared with pareto/fig5)
//! * `state`      — device-interchange train state
//! * `bitwidth`   — Eq. 2.4 beta -> (b, alpha) management
//! * `metrics`    — series recorder behind every figure
//! * `checkpoint` — binary snapshots (fine-tune / from-scratch workflows)

pub mod bitwidth;
pub mod checkpoint;
pub mod evaluator;
pub mod metrics;
pub mod state;
pub mod trainer;

pub use bitwidth::{ceil_bits, BitAssignment};
pub use checkpoint::Checkpoint;
pub use evaluator::{evaluate, test_batcher};
pub use metrics::MetricsRecorder;
pub use state::TrainState;
pub use trainer::{Snapshot, TrackKind, TrackRequest, TrainOptions, TrainOutcome, Trainer};
