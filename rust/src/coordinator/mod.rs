//! L3 coordinator: training orchestration on top of the AOT runtime.
//!
//! * `trainer`    — the per-run event loop (schedule, freeze, metrics)
//! * `dist`       — data-parallel training: tick coordinator + worker replicas
//! * `evaluator`  — batched held-out evaluation (shared with pareto/fig5)
//! * `state`      — device-interchange train state
//! * `bitwidth`   — Eq. 2.4 beta -> (b, alpha) management
//! * `metrics`    — series recorder behind every figure
//! * `checkpoint` — binary snapshots (fine-tune / from-scratch workflows)

pub mod bitwidth;
pub mod checkpoint;
pub mod dist;
pub mod evaluator;
pub mod metrics;
pub mod state;
pub mod trainer;

pub use bitwidth::{ceil_bits, BitAssignment};
pub use checkpoint::Checkpoint;
pub use dist::{run_distributed, ChaosEvent, DistCfg, DistOutcome, KnobPlan};
pub use evaluator::{eval_batches, evaluate, test_batcher, test_batcher_with_batch};
pub use metrics::MetricsRecorder;
pub use state::TrainState;
pub use trainer::{
    session_cfg, step_knobs, Snapshot, TrackKind, TrackRequest, TrainOptions, TrainOutcome, Trainer,
};
