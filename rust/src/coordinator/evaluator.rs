//! Evaluation: run an eval program over the held-out stream, batch by
//! batch, and average loss/accuracy. Shared by the trainer's mid-training
//! probes, the Pareto enumerator (which evaluates hundreds of bitwidth
//! assignments against one trained state), and the Fig. 5 sensitivity scan.

use anyhow::{anyhow, Result};

use crate::data::{spec_for_model, Batcher, Dataset};
use crate::runtime::{buffer_f32, scalar_f32, to_scalar_f32, Buffer, ModelMeta, Runtime};

/// Deterministic held-out batcher for a model (stream 1 never overlaps train).
pub fn test_batcher(model: &ModelMeta, n_examples: usize, seed: u64) -> Batcher {
    let dspec = spec_for_model(model);
    let ds = Dataset::generate(dspec, n_examples, seed, 1);
    Batcher::new(ds, model.batch, seed)
}

/// Average (loss, acc) of `params` over all full test batches.
///
/// `kw = None` selects the fp32 eval signature; otherwise the per-layer
/// quantizer levels are fed to the quantized eval program.
pub fn evaluate(
    rt: &Runtime,
    eval_prog: &str,
    model: &ModelMeta,
    params: &[Buffer],
    kw: Option<&[f32]>,
    ka: f32,
    test: &Batcher,
) -> Result<(f32, f32)> {
    let sig = rt.sig(eval_prog)?.clone();
    let batches = test.sequential_batches();
    if batches.is_empty() {
        return Err(anyhow!("test set smaller than one batch"));
    }
    let out_loss = sig.output_index("loss")?;
    let out_acc = sig.output_index("acc")?;
    let (mut loss_sum, mut acc_sum) = (0f64, 0f64);
    for b in &batches {
        // Positional: [w..., x, y, (kw, ka)?]
        let x = buffer_f32(
            &b.x,
            &[model.batch, model.input_shape[0], model.input_shape[1], model.input_shape[2]],
        )?;
        let y = buffer_f32(&b.y, &[model.batch, model.num_classes])?;
        let extra: Vec<Buffer> = match kw {
            Some(kw) => {
                if kw.len() != model.num_qlayers {
                    return Err(anyhow!(
                        "{eval_prog}: kw has {} entries, model wants {}",
                        kw.len(),
                        model.num_qlayers
                    ));
                }
                vec![x, y, buffer_f32(kw, &[kw.len()])?, scalar_f32(ka)]
            }
            None => vec![x, y],
        };
        let mut args: Vec<&Buffer> = Vec::with_capacity(params.len() + extra.len());
        args.extend(params.iter());
        args.extend(extra.iter());
        let outs = rt.execute(eval_prog, &args)?;
        loss_sum += to_scalar_f32(&outs[out_loss])? as f64;
        acc_sum += to_scalar_f32(&outs[out_acc])? as f64;
    }
    let n = batches.len() as f64;
    Ok(((loss_sum / n) as f32, (acc_sum / n) as f32))
}
