//! Evaluation: run an eval program over the held-out stream, batch by
//! batch, and average loss/accuracy. Shared by the Pareto enumerator
//! (which evaluates hundreds of bitwidth assignments against one trained
//! state) and the Fig. 5 sensitivity scan; the trainer's own mid-training
//! probes go through `Session::eval` instead.
//!
//! The program is resolved *once* via [`Runtime::prepare`] and every batch
//! dispatches through the handle into preallocated buffers — no per-batch
//! name lookups or output allocation.

use anyhow::{anyhow, Result};

use crate::data::{spec_for_model, Batch, Batcher, Dataset};
use crate::runtime::{buffer_f32, Buffer, ModelMeta, Runtime};

/// Deterministic held-out batcher for a model (stream 1 never overlaps train).
pub fn test_batcher(model: &ModelMeta, n_examples: usize, seed: u64) -> Batcher {
    let dspec = spec_for_model(model);
    let ds = Dataset::generate(dspec, n_examples, seed, 1);
    Batcher::new(ds, model.batch, seed)
}

/// Average a per-batch `(loss, acc)` eval over all full test batches —
/// the accumulation shared by [`evaluate`] and the trainer's
/// `Session`-based mid-training probes.
pub fn eval_batches<F>(test: &Batcher, mut eval_batch: F) -> Result<(f32, f32)>
where
    F: FnMut(&Batch) -> Result<(f32, f32)>,
{
    let batches = test.sequential_batches();
    if batches.is_empty() {
        return Err(anyhow!("test set smaller than one batch"));
    }
    let (mut loss_sum, mut acc_sum) = (0f64, 0f64);
    for b in &batches {
        let (l, a) = eval_batch(b)?;
        loss_sum += l as f64;
        acc_sum += a as f64;
    }
    let n = batches.len() as f64;
    Ok(((loss_sum / n) as f32, (acc_sum / n) as f32))
}

/// Average (loss, acc) of `params` over all full test batches.
///
/// `kw = None` selects the fp32 eval signature; otherwise the per-layer
/// quantizer levels are fed to the quantized eval program.
pub fn evaluate(
    rt: &Runtime,
    eval_prog: &str,
    model: &ModelMeta,
    params: &[Buffer],
    kw: Option<&[f32]>,
    ka: f32,
    test: &Batcher,
) -> Result<(f32, f32)> {
    let prog = rt.prepare(eval_prog)?;
    let out_loss = prog.sig().output_index("loss")?;
    let out_acc = prog.sig().output_index("acc")?;

    // Preallocated I/O: positional layout [w..., x, y, (kw, ka)?].
    let mut x = Buffer::zeros(vec![
        model.batch,
        model.input_shape[0],
        model.input_shape[1],
        model.input_shape[2],
    ]);
    let mut y = Buffer::zeros(vec![model.batch, model.num_classes]);
    let quant = match kw {
        Some(kw) => {
            if kw.len() != model.num_qlayers {
                return Err(anyhow!(
                    "{eval_prog}: kw has {} entries, model wants {}",
                    kw.len(),
                    model.num_qlayers
                ));
            }
            Some((buffer_f32(kw, &[kw.len()])?, Buffer::scalar(ka)))
        }
        None => None,
    };
    let mut outs = vec![Buffer::scalar(0.0); prog.sig().outputs.len()];

    eval_batches(test, |b| {
        x.fill_from(&b.x)?;
        y.fill_from(&b.y)?;
        let mut args: Vec<&Buffer> = Vec::with_capacity(params.len() + 4);
        args.extend(params.iter());
        args.push(&x);
        args.push(&y);
        if let Some((kwb, kab)) = &quant {
            args.push(kwb);
            args.push(kab);
        }
        prog.call_into(&args, &mut outs)?;
        Ok((outs[out_loss].data[0], outs[out_acc].data[0]))
    })
}
