//! Evaluation: run an eval program over the held-out stream, batch by
//! batch, and average loss/accuracy. Shared by the Pareto enumerator
//! (which evaluates hundreds of bitwidth assignments against one trained
//! state) and the Fig. 5 sensitivity scan; the trainer's own mid-training
//! probes go through `Session::eval` instead.
//!
//! The program is resolved *once* via [`Runtime::prepare`] and every batch
//! dispatches through the handle into preallocated buffers — no per-batch
//! name lookups or output allocation.

use anyhow::{anyhow, Result};

use crate::data::{spec_for_model, Batch, Batcher, Dataset, SequentialBatches};
use crate::runtime::{buffer_f32, Buffer, ModelMeta, Runtime};

/// Deterministic held-out batcher for a model (stream 1 never overlaps
/// train). Errors when the model's manifest batch exceeds `n_examples`.
pub fn test_batcher(model: &ModelMeta, n_examples: usize, seed: u64) -> Result<Batcher> {
    test_batcher_with_batch(model, n_examples, seed, model.batch)
}

/// [`test_batcher`] at a caller-chosen batch size (the `waveq infer` CLI
/// serves frozen models at arbitrary batches). Stream id 1 is the
/// held-out convention — keeping it here, in one place, is what
/// guarantees every eval path scores data the training stream (id 0)
/// never saw. A batch larger than the held-out set is a clean error, not
/// a panic (both sizes come straight from CLI flags).
pub fn test_batcher_with_batch(
    model: &ModelMeta,
    n_examples: usize,
    seed: u64,
    batch: usize,
) -> Result<Batcher> {
    let dspec = spec_for_model(model);
    let ds = Dataset::generate(dspec, n_examples, seed, 1);
    Batcher::new(ds, batch, seed)
        .map_err(|e| anyhow!("held-out stream for '{}': {e}", model.name))
}

/// Average a per-batch `(loss, acc)` eval over the held-out set, weighted
/// by example count. With `include_tail` set, the ragged final batch is
/// evaluated too, so the metrics cover *every* held-out example — pass the
/// backend's batch-polymorphism capability (`Runtime::batch_polymorphic` /
/// `Session::batch_polymorphic`; always true for `InferenceSession`).
/// Fixed-shape backends pass `false` and keep the old drop-last behavior
/// instead of dispatching a batch their compiled programs cannot take.
/// Shared by [`evaluate`], the trainer's mid-training probes, and the
/// `waveq infer` CLI.
///
/// The batches stream *lazily* off the batcher ([`SequentialBatches`]):
/// peak memory is one live batch, not a `Vec<Batch>` copy of the whole
/// held-out set. The fold visits the same batches in the same order with
/// the same f64 accumulation as the old eager path, so the
/// example-weighted mean is bit-identical.
pub fn eval_batches<F>(test: &Batcher, include_tail: bool, mut eval_batch: F) -> Result<(f32, f32)>
where
    F: FnMut(&Batch) -> Result<(f32, f32)>,
{
    let batches: SequentialBatches<'_> = if include_tail {
        test.sequential_batches_all()
    } else {
        test.sequential_batches()
    };
    let (mut loss_sum, mut acc_sum, mut examples) = (0f64, 0f64, 0f64);
    for b in batches {
        let (l, a) = eval_batch(&b)?;
        // y is (rows, n_classes): its length weighs the batch by rows.
        let w = b.y.len() as f64;
        loss_sum += l as f64 * w;
        acc_sum += a as f64 * w;
        examples += w;
    }
    if examples == 0.0 {
        return Err(anyhow!("test set smaller than one batch"));
    }
    Ok(((loss_sum / examples) as f32, (acc_sum / examples) as f32))
}

/// Average (loss, acc) of `params` over the entire held-out set (full
/// batches + ragged tail).
///
/// `kw = None` selects the fp32 eval signature; otherwise the per-layer
/// quantizer levels are fed to the quantized eval program.
#[allow(clippy::too_many_arguments)]
pub fn evaluate(
    rt: &Runtime,
    eval_prog: &str,
    model: &ModelMeta,
    params: &[Buffer],
    kw: Option<&[f32]>,
    ka: f32,
    test: &Batcher,
) -> Result<(f32, f32)> {
    let prog = rt.prepare(eval_prog)?;
    let out_loss = prog.sig().output_index("loss")?;
    let out_acc = prog.sig().output_index("acc")?;

    // Preallocated I/O: positional layout [w..., x, y, (kw, ka)?].
    let mut x = Buffer::zeros(vec![
        model.batch,
        model.input_shape[0],
        model.input_shape[1],
        model.input_shape[2],
    ]);
    let mut y = Buffer::zeros(vec![model.batch, model.num_classes]);
    let quant = match kw {
        Some(kw) => {
            if kw.len() != model.num_qlayers {
                return Err(anyhow!(
                    "{eval_prog}: kw has {} entries, model wants {}",
                    kw.len(),
                    model.num_qlayers
                ));
            }
            Some((buffer_f32(kw, &[kw.len()])?, Buffer::scalar(ka)))
        }
        None => None,
    };
    let mut outs = vec![Buffer::scalar(0.0); prog.sig().outputs.len()];

    eval_batches(test, rt.batch_polymorphic(), |b| {
        // Full batches reuse the preallocated slots; the ragged tail
        // dispatches through fresh buffers at its true shape (the native
        // backend resolves the batch from the buffer length).
        let ragged: Option<(Buffer, Buffer)> = if b.x.len() == x.elem_count() {
            x.fill_from(&b.x)?;
            y.fill_from(&b.y)?;
            None
        } else {
            let rows = b.y.len() / model.num_classes;
            Some(model.batch_buffers(rows, &b.x, &b.y)?)
        };
        let mut args: Vec<&Buffer> = Vec::with_capacity(params.len() + 4);
        args.extend(params.iter());
        match &ragged {
            Some((xb, yb)) => {
                args.push(xb);
                args.push(yb);
            }
            None => {
                args.push(&x);
                args.push(&y);
            }
        }
        if let Some((kwb, kab)) = &quant {
            args.push(kwb);
            args.push(kab);
        }
        prog.call_into(&args, &mut outs)?;
        Ok((outs[out_loss].data[0], outs[out_acc].data[0]))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Runtime, SessionState};

    #[test]
    fn eval_batches_visits_every_example_and_weights_by_count() {
        // 100 mlp-lite examples at the manifest batch 64: one full batch
        // plus a 36-example tail. A synthetic per-batch (loss, acc) shows
        // the mean is example-weighted, not batch-weighted.
        let rt = Runtime::native();
        let model = rt.manifest.model("mlp").unwrap().clone();
        let test = test_batcher(&model, 100, 7).unwrap();
        let mut sizes = Vec::new();
        let (loss, acc) = eval_batches(&test, true, |b| {
            let rows = b.y.len() / model.num_classes;
            sizes.push(rows);
            Ok((rows as f32, 1.0))
        })
        .unwrap();
        assert_eq!(sizes, vec![64, 36], "tail batch must be visited");
        let want = (64.0 * 64.0 + 36.0 * 36.0) / 100.0;
        assert!((loss - want).abs() < 1e-4, "weighted mean {loss} vs {want}");
        assert!((acc - 1.0).abs() < 1e-6);
        // Fixed-shape backends opt out: drop-last semantics, full batches only.
        let mut sizes = Vec::new();
        eval_batches(&test, false, |b| {
            sizes.push(b.y.len() / model.num_classes);
            Ok((0.0, 0.0))
        })
        .unwrap();
        assert_eq!(sizes, vec![64], "include_tail = false must drop the tail");
    }

    #[test]
    fn evaluate_serves_the_ragged_tail_through_the_eval_program() {
        let rt = Runtime::native();
        let model = rt.manifest.model("mlp").unwrap().clone();
        let state = SessionState::init(&model, 3, 4.0).unwrap();
        let test = test_batcher(&model, 100, 7).unwrap();
        let (loss, acc) =
            evaluate(&rt, "eval_fp32_mlp", &model, &state.params, None, 255.0, &test).unwrap();
        assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
    }

    #[test]
    fn batch_larger_than_the_test_set_errors_instead_of_panicking() {
        let rt = Runtime::native();
        let model = rt.manifest.model("mlp").unwrap().clone();
        let err = test_batcher_with_batch(&model, 10, 7, 64).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "unexpected error: {err}");
        // The model-batch convenience wrapper hits the same guard.
        assert!(test_batcher(&model, model.batch - 1, 7).is_err());
    }
}
