//! The training coordinator: the rust-side event loop that drives the
//! backend's train programs (native or PJRT/AOT — same manifest contract)
//! through a [`Session`], applies the 3-phase regularization schedule,
//! watches beta for convergence, freezes bitwidths, and records every
//! metric series the paper's figures need.
//!
//! The session owns the training state and the hot-loop buffers; this loop
//! owns *policy*: schedule knobs, freeze detection, observers, evaluation
//! cadence. One `Trainer::run` = one training run of one (model, algorithm,
//! bitwidth) cell of Table 1/2. The experiment drivers compose many runs.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::bitwidth::BitAssignment;
use super::checkpoint::Checkpoint;
use super::evaluator::{eval_batches, test_batcher};
use super::metrics::MetricsRecorder;
use super::state::TrainState;
use crate::config::{levels, Algo, RunConfig};
use crate::data::{spec_for_model, Batcher, Dataset, Prefetcher};
use crate::runtime::{Runtime, Session, SessionCfg, StepKnobs};
use crate::schedule::PhaseController;
use crate::tensor::Histogram;

/// What to sample from the live state during training (figure drivers).
#[derive(Debug, Clone)]
pub enum TrackKind {
    /// First `count` weights of the parameter (Fig. 7 trajectories).
    Weights { count: usize },
    /// Histogram of the whole parameter (Fig. 6 distribution evolution).
    Histogram { bins: usize, lo: f32, hi: f32 },
}

#[derive(Debug, Clone)]
pub struct TrackRequest {
    pub param: usize,
    pub every: usize,
    pub kind: TrackKind,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub step: usize,
    pub param: usize,
    pub weights: Option<Vec<f32>>,
    pub histogram: Option<Histogram>,
}

#[derive(Debug, Clone, Default)]
pub struct TrainOptions {
    pub track: Vec<TrackRequest>,
    /// Fine-tune from a checkpoint instead of from scratch.
    pub init_from: Option<String>,
    /// Fig. 7 ablation: constant lambda_w from step 0 (no ramp).
    pub constant_lambda_w: Option<f32>,
    pub quiet: bool,
}

pub struct TrainOutcome {
    pub cfg: RunConfig,
    pub model_key: String,
    pub metrics: MetricsRecorder,
    pub snapshots: Vec<Snapshot>,
    /// Final per-layer assignment (learned at freeze, or the preset).
    pub assignment: BitAssignment,
    pub freeze_step: Option<usize>,
    pub test_loss: f32,
    pub test_acc: f32,
    pub train_secs: f64,
    pub state: TrainState,
}

/// The algo -> session mapping: WaveqPreset pins beta at the requested
/// bits, DoReFa/WRPN preset `kw = levels(bits)` per quantized layer. The
/// single definition shared by [`Trainer::run`] and the `waveq freeze`
/// CLI, so a checkpoint always reopens under exactly the session shape it
/// trained in.
pub fn session_cfg(cfg: &RunConfig, num_qlayers: usize) -> SessionCfg {
    SessionCfg {
        train_program: cfg.algo.train_program(&cfg.model),
        eval_program: cfg.algo.eval_program(&cfg.model),
        seed: cfg.seed,
        beta_init: match cfg.algo {
            Algo::WaveqPreset => cfg.weight_bits as f32,
            _ => cfg.beta_init,
        },
        preset_kw: matches!(cfg.algo, Algo::Dorefa | Algo::Wrpn)
            .then(|| vec![levels(cfg.weight_bits); num_qlayers]),
    }
}

/// The per-step knob policy — schedule lookup, algorithm gating, ablation
/// override, LR warmup — as one pure function of `(cfg, controller, step)`.
/// Shared by [`Trainer::run`] and the distributed coordinator so an
/// N-worker run feeds its sessions the *same f32 knob values* the
/// single-process loop would at every step (bit-identity depends on it).
pub fn step_knobs(
    cfg: &RunConfig,
    controller: &PhaseController,
    constant_lambda_w: Option<f32>,
    step: usize,
) -> StepKnobs {
    let (mut lam_w, mut lam_b, mut flag) = controller.knobs(step);
    match cfg.algo {
        Algo::WaveqPreset => {
            lam_b = 0.0;
            flag = 0.0;
        }
        Algo::WaveqLearned => {}
        _ => {
            lam_w = 0.0;
            lam_b = 0.0;
            flag = 0.0;
        }
    }
    if let Some(cw) = constant_lambda_w {
        lam_w = cw;
    }
    // Linear LR warmup over the first steps: with affine-only
    // normalization the residual nets see large early gradients; warmup
    // (plus the train-step's global-norm clip) keeps every model/bitwidth
    // cell stable at one shared base lr.
    let warmup = 30.0_f32;
    let lr_t = cfg.lr * ((step as f32 + 1.0) / warmup).min(1.0);
    StepKnobs {
        lr: lr_t,
        momentum: cfg.momentum,
        lr_beta: cfg.lr_beta,
        ka: cfg.ka(),
        lambda_w: lam_w,
        lambda_beta: lam_b,
        beta_train: flag,
    }
}

/// Evaluate a session's current state on the held-out stream: pick the
/// quantizer levels by algorithm, then average the session's eval over all
/// test batches. Shared by [`Trainer::run`]'s cadenced evals and the
/// distributed coordinator's round-boundary evals.
pub fn eval_session(cfg: &RunConfig, session: &mut Session<'_>) -> Result<(f32, f32)> {
    let kw = match cfg.algo {
        Algo::Fp32 => None,
        Algo::WaveqLearned => Some(BitAssignment::from_beta(&session.state().beta).kw()),
        _ => Some(vec![levels(cfg.weight_bits); session.model().num_qlayers]),
    };
    let test = test_batcher(session.model(), cfg.test_examples, cfg.seed)?;
    let tail = session.batch_polymorphic();
    eval_batches(&test, tail, |b| session.eval(&b.x, &b.y, kw.as_deref(), cfg.ka()))
}

pub struct Trainer<'a> {
    rt: &'a Runtime,
    pub cfg: RunConfig,
    pub opts: TrainOptions,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, cfg: RunConfig) -> Trainer<'a> {
        Trainer { rt, cfg, opts: TrainOptions::default() }
    }

    pub fn with_options(rt: &'a Runtime, cfg: RunConfig, opts: TrainOptions) -> Trainer<'a> {
        Trainer { rt, cfg, opts }
    }

    pub fn run(&mut self) -> Result<TrainOutcome> {
        let cfg = self.cfg.clone();
        let model_key = cfg.algo.model_key(&cfg.model);
        let model = self.rt.manifest.model(&model_key)?.clone();

        // ---- open the session (signature resolution + state init) --------
        let is_waveq = matches!(cfg.algo, Algo::WaveqPreset | Algo::WaveqLearned);
        let scfg = session_cfg(&cfg, model.num_qlayers);
        let train_prog = scfg.train_program.clone();
        let mut session = Session::open(self.rt, &scfg)?;
        if let Some(path) = &self.opts.init_from {
            let ck = Checkpoint::load(std::path::Path::new(path))
                .with_context(|| format!("loading init checkpoint {path}"))?;
            // v2 checkpoints carry the model name — refuse a mismatched
            // fine-tune (v1 files have no name and load as before).
            if !ck.model.is_empty() && ck.model != model_key {
                return Err(anyhow!(
                    "init checkpoint {path} is for model '{}', run wants '{model_key}'",
                    ck.model
                ));
            }
            let tensors: Vec<_> = ck.tensors.into_iter().map(|(_, t)| t).collect();
            session.state_mut().set_params(&tensors)?;
        }

        // ---- data pipeline ------------------------------------------------
        let dspec = spec_for_model(&model);
        let train_ds = Dataset::generate(dspec.clone(), cfg.train_examples, cfg.seed, 0);
        let batcher = Batcher::new(train_ds, model.batch, cfg.seed).map_err(|e| {
            anyhow!("train stream for '{}': {e} (--train-examples too small?)", model.name)
        })?;
        let mut prefetch = Prefetcher::spawn(batcher, 4, cfg.steps);

        let mut controller = PhaseController::new(cfg.schedule.clone());
        let mut metrics = MetricsRecorder::new();
        let mut snapshots = Vec::new();
        let mut freeze_step: Option<usize> = None;

        let t0 = Instant::now();

        // ---- the loop -----------------------------------------------------
        for step in 0..cfg.steps {
            let batch_data = prefetch
                .next()?
                .ok_or_else(|| anyhow!("data pipeline ended early at step {step}"))?;

            // Schedule knobs (rust-side coordination contribution).
            let knobs = step_knobs(&cfg, &controller, self.opts.constant_lambda_w, step);

            let m = session.step(&batch_data.x, &batch_data.y, &knobs)?;
            if !m.loss.is_finite() {
                return Err(anyhow!("{train_prog}: loss diverged (NaN/inf) at step {step}"));
            }

            metrics.add_f32(step, "loss", m.loss);
            metrics.add_f32(step, "acc", m.acc);
            metrics.add_f32(step, "lambda_w", knobs.lambda_w);
            metrics.add_f32(step, "lambda_beta", knobs.lambda_beta);
            if let Some(ce) = m.ce {
                metrics.add_f32(step, "ce", ce);
            }
            if let Some(reg_w) = m.reg_w {
                metrics.add_f32(step, "reg_w", reg_w);
            }
            if is_waveq && !session.state().beta.is_empty() {
                let beta = &session.state().beta;
                let mean_beta: f32 = beta.iter().sum::<f32>() / beta.len() as f32;
                metrics.add_f32(step, "beta_mean", mean_beta);
            }

            // Phase-3 detection (learned mode): freeze + snap beta.
            if cfg.algo == Algo::WaveqLearned
                && freeze_step.is_none()
                && controller.observe_beta(step, &session.state().beta)
            {
                freeze_step = Some(step);
                let assign = BitAssignment::from_beta(&session.state().beta);
                let st = session.state_mut();
                st.beta = assign.snapped_beta();
                st.vbeta = vec![0.0; st.vbeta.len()];
                if !self.opts.quiet {
                    crate::info!(
                        "{}: beta frozen at step {} -> bits {:?} (avg {:.2})",
                        train_prog,
                        step,
                        assign.bits,
                        assign.average_bits()
                    );
                }
            }

            // Observers (figure data).
            for req in &self.opts.track {
                if req.every > 0 && step.is_multiple_of(req.every) {
                    let t = session.state().param_tensor(&model, req.param)?;
                    let snap = match &req.kind {
                        TrackKind::Weights { count } => Snapshot {
                            step,
                            param: req.param,
                            weights: Some(t.data[..(*count).min(t.data.len())].to_vec()),
                            histogram: None,
                        },
                        TrackKind::Histogram { bins, lo, hi } => {
                            let mut h = Histogram::new(*lo, *hi, *bins);
                            h.add_slice(&t.data);
                            Snapshot { step, param: req.param, weights: None, histogram: Some(h) }
                        }
                    };
                    snapshots.push(snap);
                }
            }

            // Mid-training eval (Fig. 8 convergence curves).
            if cfg.eval_every > 0 && (step + 1).is_multiple_of(cfg.eval_every) {
                let (tl, tacc) = self.eval_now(&mut session)?;
                metrics.add_f32(step, "test_loss", tl);
                metrics.add_f32(step, "test_acc", tacc);
            }
        }

        let train_secs = t0.elapsed().as_secs_f64();

        // ---- final assignment + eval ---------------------------------------
        let assignment = match cfg.algo {
            Algo::WaveqLearned => BitAssignment::from_beta(&session.state().beta),
            Algo::Fp32 => BitAssignment::homogeneous(8, model.num_qlayers),
            _ => BitAssignment::homogeneous(cfg.weight_bits, model.num_qlayers),
        };
        let (test_loss, test_acc) = self.eval_now(&mut session)?;

        if !self.opts.quiet {
            crate::info!(
                "{}: done {} steps in {:.1}s ({:.1} steps/s) test_acc={:.4}",
                train_prog,
                cfg.steps,
                train_secs,
                cfg.steps as f64 / train_secs,
                test_acc
            );
        }

        Ok(TrainOutcome {
            cfg,
            model_key,
            metrics,
            snapshots,
            assignment,
            freeze_step,
            test_loss,
            test_acc,
            train_secs,
            state: session.into_state(),
        })
    }

    /// Evaluate the session's current state on the held-out stream (see
    /// [`eval_session`]).
    fn eval_now(&self, session: &mut Session<'_>) -> Result<(f32, f32)> {
        eval_session(&self.cfg, session)
    }
}
