//! The training coordinator: the rust-side event loop that drives the
//! backend's train programs (native or PJRT/AOT — same manifest contract),
//! applies the 3-phase regularization schedule, watches beta for
//! convergence, freezes bitwidths, and records every metric series the
//! paper's figures need.
//!
//! One `Trainer::run` = one training run of one (model, algorithm, bitwidth)
//! cell of Table 1/2. The experiment drivers compose many runs.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::bitwidth::BitAssignment;
use super::checkpoint::Checkpoint;
use super::evaluator::{evaluate, test_batcher};
use super::metrics::MetricsRecorder;
use super::state::TrainState;
use crate::config::{levels, Algo, RunConfig};
use crate::data::{spec_for_model, Batcher, Dataset, Prefetcher};
use crate::runtime::{buffer_f32, scalar_f32, to_scalar_f32, to_vec_f32, Buffer, Runtime};
use crate::schedule::PhaseController;
use crate::tensor::Histogram;

/// What to sample from the live state during training (figure drivers).
#[derive(Debug, Clone)]
pub enum TrackKind {
    /// First `count` weights of the parameter (Fig. 7 trajectories).
    Weights { count: usize },
    /// Histogram of the whole parameter (Fig. 6 distribution evolution).
    Histogram { bins: usize, lo: f32, hi: f32 },
}

#[derive(Debug, Clone)]
pub struct TrackRequest {
    pub param: usize,
    pub every: usize,
    pub kind: TrackKind,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub step: usize,
    pub param: usize,
    pub weights: Option<Vec<f32>>,
    pub histogram: Option<Histogram>,
}

#[derive(Debug, Clone, Default)]
pub struct TrainOptions {
    pub track: Vec<TrackRequest>,
    /// Fine-tune from a checkpoint instead of from scratch.
    pub init_from: Option<String>,
    /// Fig. 7 ablation: constant lambda_w from step 0 (no ramp).
    pub constant_lambda_w: Option<f32>,
    pub quiet: bool,
}

pub struct TrainOutcome {
    pub cfg: RunConfig,
    pub model_key: String,
    pub metrics: MetricsRecorder,
    pub snapshots: Vec<Snapshot>,
    /// Final per-layer assignment (learned at freeze, or the preset).
    pub assignment: BitAssignment,
    pub freeze_step: Option<usize>,
    pub test_loss: f32,
    pub test_acc: f32,
    pub train_secs: f64,
    pub state: TrainState,
}

/// Positional role of each train-program input (resolved once per run).
enum Slot {
    Param(usize),
    Vel(usize),
    Beta,
    VBeta,
    X,
    Y,
    Scalar(&'static str),
    /// Homogeneous preset kw vector (dorefa/wrpn programs).
    KwVec,
}

pub struct Trainer<'a> {
    rt: &'a Runtime,
    pub cfg: RunConfig,
    pub opts: TrainOptions,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, cfg: RunConfig) -> Trainer<'a> {
        Trainer { rt, cfg, opts: TrainOptions::default() }
    }

    pub fn with_options(rt: &'a Runtime, cfg: RunConfig, opts: TrainOptions) -> Trainer<'a> {
        Trainer { rt, cfg, opts }
    }

    pub fn run(&mut self) -> Result<TrainOutcome> {
        let cfg = self.cfg.clone();
        let model_key = cfg.algo.model_key(&cfg.model);
        let model = self.rt.manifest.model(&model_key)?.clone();
        let train_prog = cfg.algo.train_program(&cfg.model);
        let sig = self.rt.sig(&train_prog)?.clone();
        let batch = model.batch;

        // ---- resolve the positional layout once --------------------------
        let mut slots = Vec::with_capacity(sig.inputs.len());
        let (mut pi, mut vi) = (0usize, 0usize);
        for a in &sig.inputs {
            slots.push(match a.name.as_str() {
                n if n.starts_with("w:") => {
                    pi += 1;
                    Slot::Param(pi - 1)
                }
                n if n.starts_with("v:") => {
                    vi += 1;
                    Slot::Vel(vi - 1)
                }
                "beta" => Slot::Beta,
                "vbeta" => Slot::VBeta,
                "x" => Slot::X,
                "y" => Slot::Y,
                "kw" => Slot::KwVec,
                "lr" => Slot::Scalar("lr"),
                "mom" => Slot::Scalar("mom"),
                "lr_beta" => Slot::Scalar("lr_beta"),
                "ka" => Slot::Scalar("ka"),
                "lambda_w" => Slot::Scalar("lambda_w"),
                "lambda_beta" => Slot::Scalar("lambda_beta"),
                "beta_train" => Slot::Scalar("beta_train"),
                other => return Err(anyhow!("{train_prog}: unknown input '{other}'")),
            });
        }
        let n_params = pi;
        let out_loss = sig.output_index("loss")?;
        let out_acc = sig.output_index("acc")?;
        let out_ce = sig.output_index("ce").ok();
        let out_regw = sig.output_index("reg_w").ok();
        let out_beta = sig.output_index("beta").ok();

        // ---- data pipeline ------------------------------------------------
        let dspec = spec_for_model(&model);
        let train_ds = Dataset::generate(dspec.clone(), cfg.train_examples, cfg.seed, 0);
        let batcher = Batcher::new(train_ds, batch, cfg.seed);
        let mut prefetch = Prefetcher::spawn(batcher, 4, cfg.steps);

        // ---- state --------------------------------------------------------
        let is_waveq = matches!(cfg.algo, Algo::WaveqPreset | Algo::WaveqLearned);
        let beta_init = match cfg.algo {
            Algo::WaveqPreset => cfg.weight_bits as f32,
            _ => cfg.beta_init,
        };
        let mut state = TrainState::init(&model, cfg.seed, beta_init)?;
        if let Some(path) = &self.opts.init_from {
            let ck = Checkpoint::load(std::path::Path::new(path))
                .with_context(|| format!("loading init checkpoint {path}"))?;
            let tensors: Vec<_> = ck.tensors.into_iter().map(|(_, t)| t).collect();
            state.set_params(&tensors)?;
        }

        let mut controller = PhaseController::new(cfg.schedule.clone());
        let mut metrics = MetricsRecorder::new();
        let mut snapshots = Vec::new();
        let mut freeze_step: Option<usize> = None;
        let preset_kw = vec![levels(cfg.weight_bits); model.num_qlayers];
        let ka = cfg.ka();

        self.rt.warmup(&[train_prog.as_str()])?;
        let t0 = Instant::now();

        // ---- the loop -------------------------------------------------------
        for step in 0..cfg.steps {
            let batch_data = prefetch
                .next()?
                .ok_or_else(|| anyhow!("data pipeline ended early at step {step}"))?;

            // Schedule knobs (rust-side coordination contribution).
            let (mut lam_w, mut lam_b, mut flag) = controller.knobs(step);
            match cfg.algo {
                Algo::WaveqPreset => {
                    lam_b = 0.0;
                    flag = 0.0;
                }
                Algo::WaveqLearned => {}
                _ => {
                    lam_w = 0.0;
                    lam_b = 0.0;
                    flag = 0.0;
                }
            }
            if let Some(cw) = self.opts.constant_lambda_w {
                lam_w = cw;
            }
            // Linear LR warmup over the first steps: with affine-only
            // normalization the residual nets see large early gradients;
            // warmup (plus the train-step's global-norm clip) keeps every
            // model/bitwidth cell stable at one shared base lr.
            let warmup = 30.0_f32;
            let lr_t = cfg.lr * ((step as f32 + 1.0) / warmup).min(1.0);

            // Assemble positional args, moving state buffers in.
            let mut params = std::mem::take(&mut state.params);
            let mut vels = std::mem::take(&mut state.vels);
            let mut args: Vec<Buffer> = Vec::with_capacity(slots.len());
            for slot in &slots {
                args.push(match slot {
                    Slot::Param(i) => std::mem::replace(&mut params[*i], Buffer::scalar(0f32)),
                    Slot::Vel(i) => std::mem::replace(&mut vels[*i], Buffer::scalar(0f32)),
                    Slot::Beta => buffer_f32(&state.beta, &[state.beta.len()])?,
                    Slot::VBeta => buffer_f32(&state.vbeta, &[state.vbeta.len()])?,
                    Slot::X => buffer_f32(
                        &batch_data.x,
                        &[batch, model.input_shape[0], model.input_shape[1], model.input_shape[2]],
                    )?,
                    Slot::Y => buffer_f32(&batch_data.y, &[batch, model.num_classes])?,
                    Slot::KwVec => buffer_f32(&preset_kw, &[preset_kw.len()])?,
                    Slot::Scalar(name) => scalar_f32(match *name {
                        "lr" => lr_t,
                        "mom" => cfg.momentum,
                        "lr_beta" => cfg.lr_beta,
                        "ka" => ka,
                        "lambda_w" => lam_w,
                        "lambda_beta" => lam_b,
                        "beta_train" => flag,
                        _ => unreachable!(),
                    }),
                });
            }

            let mut outs = self.rt.execute(&train_prog, &args)?;

            // Unpack: params', vels' [, beta', vbeta'], scalars.
            state.vels = outs.drain(n_params..2 * n_params).collect();
            state.params = outs.drain(0..n_params).collect();
            // After the two drains the tail outputs start at index 0 offset:
            // outs now holds [beta?, vbeta?, loss, acc, ...] in original order
            // minus the first 2P entries.
            if let Some(bidx) = out_beta {
                let rel = bidx - 2 * n_params;
                state.beta = to_vec_f32(&outs[rel])?;
                state.vbeta = to_vec_f32(&outs[rel + 1])?;
            }
            let rel_loss = out_loss - 2 * n_params;
            let rel_acc = out_acc - 2 * n_params;
            let loss = to_scalar_f32(&outs[rel_loss])?;
            let acc = to_scalar_f32(&outs[rel_acc])?;
            if !loss.is_finite() {
                return Err(anyhow!("{train_prog}: loss diverged (NaN/inf) at step {step}"));
            }
            state.step = step + 1;

            metrics.add_f32(step, "loss", loss);
            metrics.add_f32(step, "acc", acc);
            metrics.add_f32(step, "lambda_w", lam_w);
            metrics.add_f32(step, "lambda_beta", lam_b);
            if let Some(i) = out_ce {
                metrics.add_f32(step, "ce", to_scalar_f32(&outs[i - 2 * n_params])?);
            }
            if let Some(i) = out_regw {
                metrics.add_f32(step, "reg_w", to_scalar_f32(&outs[i - 2 * n_params])?);
            }
            if is_waveq && !state.beta.is_empty() {
                let mean_beta: f32 =
                    state.beta.iter().sum::<f32>() / state.beta.len() as f32;
                metrics.add_f32(step, "beta_mean", mean_beta);
            }

            // Phase-3 detection (learned mode): freeze + snap beta.
            if cfg.algo == Algo::WaveqLearned
                && freeze_step.is_none()
                && controller.observe_beta(step, &state.beta)
            {
                freeze_step = Some(step);
                let assign = BitAssignment::from_beta(&state.beta);
                state.beta = assign.snapped_beta();
                state.vbeta = vec![0.0; state.vbeta.len()];
                if !self.opts.quiet {
                    crate::info!(
                        "{}: beta frozen at step {} -> bits {:?} (avg {:.2})",
                        train_prog,
                        step,
                        assign.bits,
                        assign.average_bits()
                    );
                }
            }

            // Observers (figure data).
            for (ti, req) in self.opts.track.iter().enumerate() {
                if req.every > 0 && step % req.every == 0 {
                    let t = state.param_tensor(&model, req.param)?;
                    let snap = match &req.kind {
                        TrackKind::Weights { count } => Snapshot {
                            step,
                            param: req.param,
                            weights: Some(t.data[..(*count).min(t.data.len())].to_vec()),
                            histogram: None,
                        },
                        TrackKind::Histogram { bins, lo, hi } => {
                            let mut h = Histogram::new(*lo, *hi, *bins);
                            h.add_slice(&t.data);
                            Snapshot { step, param: req.param, weights: None, histogram: Some(h) }
                        }
                    };
                    let _ = ti;
                    snapshots.push(snap);
                }
            }

            // Mid-training eval (Fig. 8 convergence curves).
            if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
                let (tl, tacc) = self.eval_now(&model_key, &state, &cfg)?;
                metrics.add_f32(step, "test_loss", tl);
                metrics.add_f32(step, "test_acc", tacc);
            }
        }

        let train_secs = t0.elapsed().as_secs_f64();

        // ---- final assignment + eval ---------------------------------------
        let assignment = match cfg.algo {
            Algo::WaveqLearned => BitAssignment::from_beta(&state.beta),
            Algo::Fp32 => BitAssignment::homogeneous(8, model.num_qlayers),
            _ => BitAssignment::homogeneous(cfg.weight_bits, model.num_qlayers),
        };
        let (test_loss, test_acc) = self.eval_now(&model_key, &state, &cfg)?;

        if !self.opts.quiet {
            crate::info!(
                "{}: done {} steps in {:.1}s ({:.1} steps/s) test_acc={:.4}",
                train_prog,
                cfg.steps,
                train_secs,
                cfg.steps as f64 / train_secs,
                test_acc
            );
        }

        Ok(TrainOutcome {
            cfg,
            model_key,
            metrics,
            snapshots,
            assignment,
            freeze_step,
            test_loss,
            test_acc,
            train_secs,
            state,
        })
    }

    /// Evaluate the current state on the held-out stream.
    fn eval_now(&self, model_key: &str, state: &TrainState, cfg: &RunConfig) -> Result<(f32, f32)> {
        let model = self.rt.manifest.model(model_key)?;
        let eval_prog = cfg.algo.eval_program(&cfg.model);
        let kw = match cfg.algo {
            Algo::Fp32 => None,
            Algo::WaveqLearned => Some(BitAssignment::from_beta(&state.beta).kw()),
            _ => Some(vec![levels(cfg.weight_bits); model.num_qlayers]),
        };
        let test = test_batcher(model, cfg.test_examples, cfg.seed);
        evaluate(self.rt, &eval_prog, model, &state.params, kw.as_deref(), cfg.ka(), &test)
    }
}
