//! Bitwidth manager: the beta -> (b, alpha) mapping of Eq. 2.4 and the
//! per-layer assignment bookkeeping used at and after the phase-3 freeze.
//!
//!   b_i     = ceil(beta_i), clamped to [2, 8]
//!   alpha_i = b_i / beta_i            (the jointly-learned scale factor)
//!   k_i     = 2^{b_i} - 1             (quantizer levels fed to eval programs)

#[derive(Debug, Clone)]
pub struct BitAssignment {
    pub bits: Vec<u32>,
    pub alpha: Vec<f32>,
}

impl BitAssignment {
    /// Eq. 2.4 applied to a live beta vector. Beta is clamped into the
    /// (1, 8] domain the training step itself enforces (`kernels::clip_beta`)
    /// before computing alpha: a beta that drifted to or below zero would
    /// otherwise blow alpha up to ~b/1e-6 (and flip its sign for negative
    /// beta), poisoning every alpha-scaled consumer downstream. Legitimate
    /// betas in (1, 2) keep their true alpha = ceil(beta)/beta (< 2).
    pub fn from_beta(beta: &[f32]) -> BitAssignment {
        let bits: Vec<u32> = beta.iter().map(|&b| ceil_bits(b)).collect();
        let alpha = beta
            .iter()
            .zip(&bits)
            .map(|(&be, &bi)| bi as f32 / be.clamp(1.0 + 1e-3, 8.0))
            .collect();
        BitAssignment { bits, alpha }
    }

    pub fn homogeneous(bits: u32, layers: usize) -> BitAssignment {
        BitAssignment { bits: vec![bits.clamp(2, 8); layers], alpha: vec![1.0; layers] }
    }

    /// Unweighted mean bitwidth (the paper's "W3.85"-style headline number).
    pub fn average_bits(&self) -> f64 {
        if self.bits.is_empty() {
            return 0.0;
        }
        self.bits.iter().map(|&b| b as f64).sum::<f64>() / self.bits.len() as f64
    }

    /// Quantizer level counts k_i = 2^{b_i} - 1.
    pub fn kw(&self) -> Vec<f32> {
        self.bits.iter().map(|&b| (2u64.pow(b) - 1) as f32).collect()
    }

    /// The beta vector that pins the quantizer exactly on this assignment
    /// (used to snap beta at freeze time so kw becomes integral).
    pub fn snapped_beta(&self) -> Vec<f32> {
        self.bits.iter().map(|&b| b as f32).collect()
    }

    /// Copy with one layer's bitwidth decremented (Fig. 5 sensitivity).
    pub fn decrement_layer(&self, layer: usize) -> BitAssignment {
        let mut out = self.clone();
        out.bits[layer] = out.bits[layer].saturating_sub(1).max(2);
        out
    }
}

/// Re-exported from the runtime kernels — the same mapping
/// `Session::freeze` packs artifacts with (one definition, Eq. 2.4).
pub use crate::runtime::native::kernels::ceil_bits;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_and_clamp() {
        assert_eq!(ceil_bits(3.2), 4);
        assert_eq!(ceil_bits(4.0), 4);
        assert_eq!(ceil_bits(0.5), 2);
        assert_eq!(ceil_bits(11.0), 8);
    }

    #[test]
    fn from_beta_eq_2_4() {
        let a = BitAssignment::from_beta(&[3.2, 4.0, 7.9]);
        assert_eq!(a.bits, vec![4, 4, 8]);
        assert!((a.alpha[0] - 4.0 / 3.2).abs() < 1e-6);
        assert!((a.alpha[1] - 1.0).abs() < 1e-6);
        // alpha >= 1 always (b = ceil(beta) >= beta)
        assert!(a.alpha.iter().all(|&x| x >= 1.0));
    }

    #[test]
    fn from_beta_clamps_degenerate_beta() {
        // Regression: beta <= 0 used to produce alpha ~ 2e6 (b / 1e-6) or a
        // negative alpha; with the clip_beta-domain clamp every alpha stays
        // finite and positive, bounded by 2/(1 + 1e-3) at the bottom.
        let a = BitAssignment::from_beta(&[0.0, -3.5, 1e-9, 0.5, 9.7]);
        assert_eq!(a.bits, vec![2, 2, 2, 2, 8]);
        for &al in &a.alpha {
            assert!((1.0..=2.0).contains(&al), "alpha {al} out of range");
            assert!(al.is_finite());
        }
        // Degenerate betas all resolve to alpha = 2 / clip_beta floor.
        for &al in &a.alpha[..4] {
            assert!((al - 2.0 / 1.001).abs() < 1e-3, "alpha {al}");
        }
        // In-domain betas are untouched by the clamp, including the live
        // (1, 2) band clip_beta allows: alpha = ceil(beta)/beta there.
        let b = BitAssignment::from_beta(&[3.2, 1.5]);
        assert!((b.alpha[0] - 4.0 / 3.2).abs() < 1e-6);
        assert!((b.alpha[1] - 2.0 / 1.5).abs() < 1e-6);
    }

    #[test]
    fn average_and_kw() {
        let a = BitAssignment { bits: vec![3, 4, 5], alpha: vec![1.0; 3] };
        assert!((a.average_bits() - 4.0).abs() < 1e-12);
        assert_eq!(a.kw(), vec![7.0, 15.0, 31.0]);
        assert_eq!(a.snapped_beta(), vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn decrement_saturates_at_two() {
        let a = BitAssignment::homogeneous(2, 3);
        let d = a.decrement_layer(1);
        assert_eq!(d.bits, vec![2, 2, 2]);
        let b = BitAssignment::homogeneous(5, 2).decrement_layer(0);
        assert_eq!(b.bits, vec![4, 5]);
    }
}
