//! Design-space enumeration + Pareto analysis (paper Figure 4).
//!
//! For moderate nets the per-layer bitwidth space {b_lo..b_hi}^L is small
//! enough to enumerate: each assignment is evaluated (accuracy via the
//! quantized eval program against a trained state; compute via the Stripes
//! relative-compute metric) and the non-dominated frontier is extracted.
//! The WaveQ solution is then located relative to that frontier — the
//! paper's quality argument for the learned assignments.

use anyhow::Result;

#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub bits: Vec<u32>,
    /// Relative compute (lower is better), e.g. Stripes MAC*bit vs 8-bit.
    pub compute: f64,
    /// Test accuracy (higher is better).
    pub accuracy: f64,
}

/// All assignments of {lo..=hi}^layers, in lexicographic order.
pub fn enumerate_assignments(layers: usize, lo: u32, hi: u32) -> Vec<Vec<u32>> {
    assert!(hi >= lo);
    let base = (hi - lo + 1) as usize;
    let total = base.pow(layers as u32);
    let mut out = Vec::with_capacity(total);
    for mut idx in 0..total {
        let mut v = vec![lo; layers];
        for slot in (0..layers).rev() {
            v[slot] = lo + (idx % base) as u32;
            idx /= base;
        }
        out.push(v);
    }
    out
}

/// Subsample a space too big to enumerate, stratified by average bits:
/// samples are spread round-robin over unit-width average-bit bands
/// covering [lo, hi], so the frontier's low-compute tail (average near
/// `lo`) is represented instead of everything piling up at the uniform
/// mean (layers * (lo + hi) / 2, where plain i.i.d. sampling concentrates).
pub fn sample_assignments(
    layers: usize,
    lo: u32,
    hi: u32,
    n: usize,
    rng: &mut crate::util::rng::Rng,
) -> Vec<Vec<u32>> {
    assert!(hi >= lo);
    let mut out = Vec::with_capacity(n);
    if layers == 0 {
        out.resize(n, Vec::new());
        return out;
    }
    let bands = ((hi - lo) as usize).max(1).min(n.max(1));
    let width = (hi - lo) as f64 / bands as f64;
    // The average moves in steps of 1/layers, so we can land within half a
    // step of any target.
    let tol = 0.5 / layers as f64;
    for i in 0..n {
        let band = i % bands;
        let a0 = lo as f64 + width * band as f64;
        // Target average drawn from the band's interior (middle 80%) so the
        // achieved average — within `tol` of the target — stays inside the
        // band rather than piling up on a shared boundary.
        let target = a0 + (0.1 + 0.8 * rng.uniform()) * width;
        let mut v: Vec<u32> =
            (0..layers).map(|_| lo + rng.below((hi - lo + 1) as u64) as u32).collect();
        // Repair toward the target: nudge random layers by +-1. Each step
        // moves the average 1/layers closer, so this terminates in
        // O(layers * (hi - lo)) steps and cannot oscillate (one step never
        // overshoots past target + tol).
        loop {
            let avg = v.iter().map(|&b| b as f64).sum::<f64>() / layers as f64;
            if avg < target - tol {
                let up: Vec<usize> = (0..layers).filter(|&j| v[j] < hi).collect();
                match up.is_empty() {
                    true => break,
                    false => v[up[rng.below_usize(up.len())]] += 1,
                }
            } else if avg > target + tol {
                let down: Vec<usize> = (0..layers).filter(|&j| v[j] > lo).collect();
                match down.is_empty() {
                    true => break,
                    false => v[down[rng.below_usize(down.len())]] -= 1,
                }
            } else {
                break;
            }
        }
        out.push(v);
    }
    out
}

/// Non-dominated frontier: minimize compute, maximize accuracy.
/// Returns indices into `points`, sorted by compute ascending.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // Sort by compute asc, accuracy desc as tiebreak.
    idx.sort_by(|&a, &b| {
        points[a]
            .compute
            .partial_cmp(&points[b].compute)
            .unwrap()
            .then(points[b].accuracy.partial_cmp(&points[a].accuracy).unwrap())
    });
    let mut frontier = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for &i in &idx {
        if points[i].accuracy > best_acc {
            frontier.push(i);
            best_acc = points[i].accuracy;
        }
    }
    frontier
}

/// Is `p` dominated by any point in `points` (strictly better on one axis,
/// at least as good on the other)?
pub fn is_dominated(p: &DesignPoint, points: &[DesignPoint]) -> bool {
    points.iter().any(|q| {
        (q.compute < p.compute && q.accuracy >= p.accuracy)
            || (q.compute <= p.compute && q.accuracy > p.accuracy)
    })
}

/// Distance (in accuracy) from point `p` to the frontier at p's compute
/// budget: how much accuracy the frontier achieves with <= p.compute,
/// minus p's accuracy. ~0 (or negative) means p sits on the frontier.
pub fn accuracy_gap_to_frontier(p: &DesignPoint, points: &[DesignPoint]) -> f64 {
    let best_at_budget = points
        .iter()
        .filter(|q| q.compute <= p.compute + 1e-12)
        .map(|q| q.accuracy)
        .fold(f64::NEG_INFINITY, f64::max);
    if best_at_budget.is_finite() {
        best_at_budget - p.accuracy
    } else {
        0.0
    }
}

/// Serialize the space + frontier as CSV (compute, accuracy, on_frontier, bits).
pub fn to_csv(points: &[DesignPoint], frontier: &[usize]) -> String {
    // BTreeSet, not HashSet: this feeds serialized output, and the audit's
    // D2 rule keeps hash-ordered collections out of such paths entirely
    // (membership tests are order-free, but the rule is written for the
    // whole file so iteration can never creep in).
    let on: std::collections::BTreeSet<usize> = frontier.iter().copied().collect();
    let mut s = String::from("compute,accuracy,on_frontier,bits\n");
    for (i, p) in points.iter().enumerate() {
        let bits: Vec<String> = p.bits.iter().map(|b| b.to_string()).collect();
        s.push_str(&format!(
            "{},{},{},{}\n",
            p.compute,
            p.accuracy,
            if on.contains(&i) { 1 } else { 0 },
            bits.join("-")
        ));
    }
    s
}

pub fn save_csv(points: &[DesignPoint], frontier: &[usize], path: &std::path::Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, to_csv(points, frontier))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(c: f64, a: f64) -> DesignPoint {
        DesignPoint { bits: vec![], compute: c, accuracy: a }
    }

    #[test]
    fn enumeration_counts_and_bounds() {
        let v = enumerate_assignments(3, 2, 4);
        assert_eq!(v.len(), 27);
        assert!(v.iter().all(|a| a.iter().all(|&b| (2..=4).contains(&b))));
        assert_eq!(v[0], vec![2, 2, 2]);
        assert_eq!(v[26], vec![4, 4, 4]);
        // all distinct
        let set: std::collections::BTreeSet<Vec<u32>> = v.iter().cloned().collect();
        assert_eq!(set.len(), 27);
    }

    #[test]
    fn frontier_is_non_dominated_and_monotone() {
        let pts = vec![pt(1.0, 0.5), pt(2.0, 0.7), pt(3.0, 0.6), pt(4.0, 0.9), pt(2.5, 0.7)];
        let f = pareto_frontier(&pts);
        // expected: (1.0,0.5), (2.0,0.7), (4.0,0.9)
        assert_eq!(f, vec![0, 1, 3]);
        for &i in &f {
            assert!(!is_dominated(&pts[i], &pts), "frontier point {i} dominated");
        }
        // accuracy strictly increases along the frontier
        for w in f.windows(2) {
            assert!(pts[w[1]].accuracy > pts[w[0]].accuracy);
            assert!(pts[w[1]].compute > pts[w[0]].compute);
        }
    }

    #[test]
    fn dominated_point_has_positive_gap() {
        let pts = vec![pt(1.0, 0.8), pt(1.5, 0.5)];
        assert!(is_dominated(&pts[1], &pts));
        assert!(accuracy_gap_to_frontier(&pts[1], &pts) > 0.29);
        assert!(accuracy_gap_to_frontier(&pts[0], &pts).abs() < 1e-12);
    }

    #[test]
    fn sampled_assignments_in_bounds() {
        let mut rng = crate::util::rng::Rng::new(1);
        let v = sample_assignments(5, 2, 6, 100, &mut rng);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|a| a.len() == 5 && a.iter().all(|&b| (2..=6).contains(&b))));
    }

    #[test]
    fn sampled_assignments_are_stratified_by_average_bits() {
        // Regression: the old implementation was plain i.i.d. uniform, so
        // for many layers the average-bits distribution concentrated near
        // (lo + hi) / 2 and the low-compute tail was empty. Stratification
        // must populate every unit band of the average-bits range.
        let (layers, lo, hi, n) = (8usize, 2u32, 8u32, 120usize);
        let mut rng = crate::util::rng::Rng::new(7);
        let v = sample_assignments(layers, lo, hi, n, &mut rng);
        assert_eq!(v.len(), n);
        let bands = (hi - lo) as usize;
        let mut counts = vec![0usize; bands];
        for a in &v {
            assert_eq!(a.len(), layers);
            assert!(a.iter().all(|&b| (lo..=hi).contains(&b)));
            let avg = a.iter().map(|&b| b as f64).sum::<f64>() / layers as f64;
            let band = (((avg - lo as f64).floor()) as usize).min(bands - 1);
            counts[band] += 1;
        }
        // Round-robin banding: every band gets roughly n / bands samples.
        for (b, &c) in counts.iter().enumerate() {
            assert!(c >= n / bands / 2, "band {b} has only {c} of {n} samples: {counts:?}");
        }
        // Degenerate calls stay well-formed.
        assert!(sample_assignments(0, 2, 8, 3, &mut rng).iter().all(|a| a.is_empty()));
        let flat = sample_assignments(4, 5, 5, 10, &mut rng);
        assert!(flat.iter().all(|a| a[..] == [5, 5, 5, 5]));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let pts = vec![
            DesignPoint { bits: vec![3, 4], compute: 0.5, accuracy: 0.9 },
        ];
        let csv = to_csv(&pts, &[0]);
        assert!(csv.starts_with("compute,accuracy"));
        assert!(csv.contains("3-4"));
        assert!(csv.contains(",1,"));
    }
}
