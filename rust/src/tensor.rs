//! Host-side tensor + histogram substrate.
//!
//! The heavy math runs inside the AOT XLA programs; this module covers what
//! the coordinator does on the host: state bookkeeping, statistics for the
//! figure drivers (weight-distribution evolution, trajectories), and small
//! reference computations for tests.

use anyhow::{anyhow, Result};

/// A dense f32 tensor with row-major layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {} elems, got {}", shape, n, data.len()));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn std(&self) -> f32 {
        if self.data.len() < 2 {
            return 0.0;
        }
        let mu = self.mean();
        let var = self.data.iter().map(|&x| (x - mu) * (x - mu)).sum::<f32>()
            / self.data.len() as f32;
        var.sqrt()
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// L2 distance to another tensor (tests, convergence tracking).
    pub fn l2_dist(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    /// Mean distance of each element to its nearest quantization level
    /// (the direct measure of what the WaveQ regularizer optimizes —
    /// used to verify that training actually moves weights onto the grid).
    pub fn mean_quantization_error(&self, bits: u32) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        let k = (2u64.pow(bits) - 1) as f32;
        let m = self.abs_max().max(1e-8);
        self.data
            .iter()
            .map(|&x| {
                let t = x / m; // normalize to [-1, 1]
                let q = ((t * 0.5 + 0.5) * k).round() / k * 2.0 - 1.0;
                (t - q).abs()
            })
            .sum::<f32>()
            / self.data.len() as f32
    }
}

/// Fixed-range histogram (weight-distribution figures).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub total: u64,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, bins: usize) -> Histogram {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0, underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f32) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        if x >= self.hi {
            self.overflow += 1;
            return;
        }
        let w = (self.hi - self.lo) / self.counts.len() as f32;
        let idx = ((x - self.lo) / w) as usize;
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn add_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn bin_center(&self, i: usize) -> f32 {
        let w = (self.hi - self.lo) / self.counts.len() as f32;
        self.lo + (i as f32 + 0.5) * w
    }

    /// Fraction of in-range mass within `tol` of any of the `levels`.
    /// Used by the Figure-6 driver to quantify clustering around centroids.
    pub fn mass_near_levels(&self, levels: &[f32], tol: f32) -> f64 {
        let in_range: u64 = self.counts.iter().sum();
        if in_range == 0 {
            return 0.0;
        }
        let mut near = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let x = self.bin_center(i);
            if levels.iter().any(|&l| (x - l).abs() <= tol) {
                near += c;
            }
        }
        near as f64 / in_range as f64
    }

    /// CSV dump: bin_center,count per line (figure data).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("bin_center,count\n");
        for (i, c) in self.counts.iter().enumerate() {
            s.push_str(&format!("{},{}\n", self.bin_center(i), c));
        }
        s
    }
}

/// The symmetric quantization grid {-1, ..., -1/k, 0, 1/k, ..., 1} (§2.2),
/// with k = 2^(b-1) - 1 levels per half (b includes the sign bit).
pub fn quant_levels(bits: u32) -> Vec<f32> {
    let k = (2u64.pow(bits.saturating_sub(1)) - 1).max(1) as i64;
    let mut v = Vec::with_capacity((2 * k + 1) as usize);
    for i in -k..=k {
        v.push(i as f32 / k as f32);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_stats() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -2.0, 3.0, -4.0]).unwrap();
        assert_eq!(t.mean(), -0.5);
        assert_eq!(t.min(), -4.0);
        assert_eq!(t.max(), 3.0);
        assert_eq!(t.abs_max(), 4.0);
        assert!(t.all_finite());
    }

    #[test]
    fn shape_mismatch_errors() {
        assert!(Tensor::new(vec![3], vec![1.0]).is_err());
    }

    #[test]
    fn quantization_error_is_zero_on_grid() {
        // The representable levels for bits=3 are (2j-k)/k, j=0..=k, k=7.
        let k = 7i64;
        let grid: Vec<f32> = (0..=k).map(|j| (2 * j - k) as f32 / k as f32).collect();
        let t = Tensor::new(vec![grid.len()], grid).unwrap();
        assert!(t.mean_quantization_error(3) < 1e-6);
    }

    #[test]
    fn quantization_error_decreases_with_bits() {
        let data: Vec<f32> = (0..1000).map(|i| ((i * 37) % 997) as f32 / 997.0 - 0.5).collect();
        let t = Tensor::new(vec![1000], data).unwrap();
        let e3 = t.mean_quantization_error(3);
        let e5 = t.mean_quantization_error(5);
        let e8 = t.mean_quantization_error(8);
        assert!(e3 > e5 && e5 > e8, "{e3} {e5} {e8}");
    }

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::new(-1.0, 1.0, 4);
        h.add_slice(&[-2.0, -0.9, -0.1, 0.1, 0.9, 2.0]);
        assert_eq!(h.total, 6);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts, vec![1, 1, 1, 1]);
        assert!((h.bin_center(0) - (-0.75)).abs() < 1e-6);
    }

    #[test]
    fn mass_near_levels_detects_clusters() {
        let mut h = Histogram::new(-1.0, 1.0, 200);
        for &l in &quant_levels(3) {
            for d in -2..=2 {
                h.add(l * 0.999 + d as f32 * 0.001);
            }
        }
        assert!(h.mass_near_levels(&quant_levels(3), 0.05) > 0.95);
        let mut u = Histogram::new(-1.0, 1.0, 200);
        for i in 0..1000 {
            u.add(i as f32 / 500.0 - 1.0);
        }
        assert!(u.mass_near_levels(&quant_levels(3), 0.02) < 0.5);
    }

    #[test]
    fn quant_levels_structure() {
        let l = quant_levels(3);
        assert_eq!(l.len(), 7);
        assert_eq!(l[0], -1.0);
        assert_eq!(*l.last().unwrap(), 1.0);
        assert!(l.contains(&0.0));
    }
}
