//! Bench harness entry points shared by the `harness = false` bench targets
//! (criterion is not resolvable offline; `util::timer::BenchRunner` provides
//! warmup/iters/percentiles).
//!
//! Conventions: every bench binary prints rows prefixed with `BENCH` so
//! `cargo bench` output is grep-able, and honours `WAVEQ_BENCH_SCALE`
//! (smoke|full) so CI-scale runs stay fast while `waveq experiment <id>`
//! regenerates paper-scale numbers.

pub use crate::util::timer::{BenchRunner, BenchStats};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Full,
}

pub fn scale() -> Scale {
    match std::env::var("WAVEQ_BENCH_SCALE").as_deref() {
        Ok("full") => Scale::Full,
        _ => Scale::Smoke,
    }
}

/// Steps for a training-driven bench at the current scale.
pub fn steps(smoke: usize, full: usize) -> usize {
    match scale() {
        Scale::Smoke => smoke,
        Scale::Full => full,
    }
}

pub fn header(name: &str) {
    println!("\n=== bench: {name} (scale={:?}) ===", scale());
}

pub fn row(cols: &[&str]) {
    println!("BENCH {}", cols.join(" | "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scale_is_smoke() {
        std::env::remove_var("WAVEQ_BENCH_SCALE");
        assert_eq!(scale(), Scale::Smoke);
        assert_eq!(steps(5, 500), 5);
    }
}
