//! Bench harness entry points shared by the `harness = false` bench targets
//! (criterion is not resolvable offline; `util::timer::BenchRunner` provides
//! warmup/iters/percentiles).
//!
//! Conventions: every bench binary prints rows prefixed with `BENCH` so
//! `cargo bench` output is grep-able, and honours `WAVEQ_BENCH_SCALE`
//! (smoke|full) so CI-scale runs stay fast while `waveq experiment <id>`
//! regenerates paper-scale numbers. Benches that feed the `perf-smoke` CI
//! lane additionally emit a machine-readable `BENCH_<name>.json` via
//! [`write_report`] so the repo accumulates a perf trajectory.

use std::path::PathBuf;

use crate::util::json::Json;

pub use crate::util::timer::{BenchRunner, BenchStats};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Full,
}

pub fn scale() -> Scale {
    match std::env::var("WAVEQ_BENCH_SCALE").as_deref() {
        Ok("full") => Scale::Full,
        _ => Scale::Smoke,
    }
}

/// Steps for a training-driven bench at the current scale.
pub fn steps(smoke: usize, full: usize) -> usize {
    match scale() {
        Scale::Smoke => smoke,
        Scale::Full => full,
    }
}

pub fn header(name: &str) {
    println!("\n=== bench: {name} (scale={:?}) ===", scale());
}

pub fn row(cols: &[&str]) {
    println!("BENCH {}", cols.join(" | "));
}

/// Directory machine-readable bench reports land in: `WAVEQ_BENCH_OUT`
/// when set, else the current working directory.
pub fn bench_out_dir() -> PathBuf {
    std::env::var("WAVEQ_BENCH_OUT").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("."))
}

/// Write `BENCH_<name>.json` (one JSON object per bench binary) into
/// [`bench_out_dir`]. The `perf-smoke` CI job uploads these as workflow
/// artifacts and renders them into the step summary. Returns the path.
pub fn write_report(name: &str, body: &Json) -> std::io::Result<PathBuf> {
    let dir = bench_out_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{body}\n"))?;
    println!("BENCH report -> {}", path.display());
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scale_is_smoke() {
        std::env::remove_var("WAVEQ_BENCH_SCALE");
        assert_eq!(scale(), Scale::Smoke);
        assert_eq!(steps(5, 500), 5);
    }

    #[test]
    fn write_report_emits_parseable_json_into_bench_out_dir() {
        let dir = std::env::temp_dir().join("waveq-bench-report-test");
        std::env::set_var("WAVEQ_BENCH_OUT", &dir);
        let body = Json::obj(vec![("bench", Json::Str("t".into())), ("n", Json::Num(3.0))]);
        let path = write_report("selftest", &body).unwrap();
        std::env::remove_var("WAVEQ_BENCH_OUT");
        assert!(path.ends_with("BENCH_selftest.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Json::parse(&text).unwrap(), body);
        let _ = std::fs::remove_file(&path);
    }
}
