//! Experiment/run configuration: JSON file + CLI overrides.
//!
//! One `RunConfig` fully determines a training run (model, algorithm,
//! dataset sizes, optimizer, schedule, seeds), so every experiment driver
//! and example goes through the same launcher path — the "real config
//! system" deliverable.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::schedule::ScheduleCfg;
use crate::util::argparse::Args;
use crate::util::json::Json;

/// Which training algorithm (i.e. which AOT program family) to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    Fp32,
    /// Plain DoReFa at a preset homogeneous bitwidth.
    Dorefa,
    /// WRPN (on the width-multiplied model) at a preset bitwidth.
    Wrpn,
    /// DoReFa + WaveQ with beta fixed at a preset bitwidth (lambda_beta = 0).
    WaveqPreset,
    /// DoReFa + WaveQ with learned per-layer beta (the headline mode).
    WaveqLearned,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s {
            "fp32" => Algo::Fp32,
            "dorefa" => Algo::Dorefa,
            "wrpn" => Algo::Wrpn,
            "waveq-preset" | "waveq_preset" => Algo::WaveqPreset,
            "waveq" | "waveq-learned" | "waveq_learned" => Algo::WaveqLearned,
            other => return Err(anyhow!("unknown algo '{other}'")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Fp32 => "fp32",
            Algo::Dorefa => "dorefa",
            Algo::Wrpn => "wrpn",
            Algo::WaveqPreset => "waveq-preset",
            Algo::WaveqLearned => "waveq-learned",
        }
    }

    /// AOT program name for a given base model.
    pub fn train_program(&self, model: &str) -> String {
        match self {
            Algo::Fp32 => format!("train_fp32_{model}"),
            Algo::Dorefa => format!("train_dorefa_{model}"),
            Algo::Wrpn => format!("train_wrpn_{model}_w2"),
            Algo::WaveqPreset | Algo::WaveqLearned => format!("train_waveq_{model}"),
        }
    }

    pub fn eval_program(&self, model: &str) -> String {
        match self {
            Algo::Fp32 => format!("eval_fp32_{model}"),
            Algo::Wrpn => format!("eval_wrpn_{model}_w2"),
            _ => format!("eval_quant_{model}"),
        }
    }

    /// Model key in the manifest (WRPN uses the widened variant).
    pub fn model_key(&self, model: &str) -> String {
        match self {
            Algo::Wrpn => format!("{model}_w2"),
            _ => model.to_string(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub algo: Algo,
    /// Preset weight bitwidth (Dorefa/Wrpn/WaveqPreset; init value for learned).
    pub weight_bits: u32,
    /// Activation bitwidth (32 => effectively fp32 activations).
    pub act_bits: u32,
    pub steps: usize,
    pub train_examples: usize,
    pub test_examples: usize,
    pub lr: f32,
    pub momentum: f32,
    pub lr_beta: f32,
    pub seed: u64,
    pub schedule: ScheduleCfg,
    /// Evaluate every N steps (0 = only at the end).
    pub eval_every: usize,
    /// Initial beta for learned mode.
    pub beta_init: f32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "simplenet5".into(),
            algo: Algo::WaveqLearned,
            weight_bits: 4,
            act_bits: 32,
            steps: 600,
            train_examples: 4096,
            test_examples: 1024,
            lr: 0.08,
            momentum: 0.9,
            lr_beta: 0.05,
            seed: 42,
            schedule: ScheduleCfg::default(),
            eval_every: 0,
            beta_init: 6.0,
        }
    }
}

impl RunConfig {
    /// k = 2^b - 1, the quantizer level count fed to the AOT programs.
    pub fn kw(&self) -> f32 {
        levels(self.weight_bits)
    }

    pub fn ka(&self) -> f32 {
        levels(self.act_bits)
    }

    /// Load from a JSON file then apply CLI overrides.
    pub fn load(path: Option<&str>, args: &Args) -> Result<RunConfig> {
        let mut cfg = match path {
            Some(p) => Self::from_json_file(Path::new(p))?,
            None => RunConfig::default(),
        };
        // `from_json` already syncs the schedule horizon to `steps` unless
        // the file set `schedule.total_steps` explicitly (detectable here as
        // the two disagreeing). An explicit file value wins over the sync,
        // but a CLI `--steps` override is fresher intent and re-syncs.
        let json_total_explicit = path.is_some() && cfg.schedule.total_steps != cfg.steps;
        cfg.apply_args(args)?;
        if !json_total_explicit || args.get("steps").is_some() {
            cfg.schedule.total_steps = cfg.steps;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("config parse: {e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        if let Some(v) = j.get("model").and_then(|x| x.as_str()) {
            c.model = v.to_string();
        }
        if let Some(v) = j.get("algo").and_then(|x| x.as_str()) {
            c.algo = Algo::parse(v)?;
        }
        if let Some(v) = j.get("weight_bits").and_then(|x| x.as_usize()) {
            c.weight_bits = v as u32;
        }
        if let Some(v) = j.get("act_bits").and_then(|x| x.as_usize()) {
            c.act_bits = v as u32;
        }
        if let Some(v) = j.get("steps").and_then(|x| x.as_usize()) {
            c.steps = v;
        }
        if let Some(v) = j.get("train_examples").and_then(|x| x.as_usize()) {
            c.train_examples = v;
        }
        if let Some(v) = j.get("test_examples").and_then(|x| x.as_usize()) {
            c.test_examples = v;
        }
        if let Some(v) = j.get("lr").and_then(|x| x.as_f64()) {
            c.lr = v as f32;
        }
        if let Some(v) = j.get("momentum").and_then(|x| x.as_f64()) {
            c.momentum = v as f32;
        }
        if let Some(v) = j.get("lr_beta").and_then(|x| x.as_f64()) {
            c.lr_beta = v as f32;
        }
        if let Some(v) = j.get("seed").and_then(|x| x.as_f64()) {
            c.seed = v as u64;
        }
        if let Some(v) = j.get("eval_every").and_then(|x| x.as_usize()) {
            c.eval_every = v;
        }
        if let Some(v) = j.get("beta_init").and_then(|x| x.as_f64()) {
            c.beta_init = v as f32;
        }
        if let Some(s) = j.get("schedule") {
            if let Some(v) = s.get("explore_frac").and_then(|x| x.as_f64()) {
                c.schedule.explore_frac = v;
            }
            if let Some(v) = s.get("engage_frac").and_then(|x| x.as_f64()) {
                c.schedule.engage_frac = v;
            }
            if let Some(v) = s.get("lambda_w_max").and_then(|x| x.as_f64()) {
                c.schedule.lambda_w_max = v as f32;
            }
            if let Some(v) = s.get("lambda_beta_max").and_then(|x| x.as_f64()) {
                c.schedule.lambda_beta_max = v as f32;
            }
            if let Some(v) = s.get("gamma").and_then(|x| x.as_f64()) {
                c.schedule.gamma = v;
            }
            if let Some(v) = s.get("beta_decay").and_then(|x| x.as_f64()) {
                c.schedule.beta_decay = v;
            }
        }
        // Keep the schedule horizon in lockstep with the run length (the
        // same sync `RunConfig::load` performs): a direct `from_json` caller
        // would otherwise train 500 steps against a default 1000-step
        // schedule and never leave the explore phase on time. An explicit
        // schedule.total_steps still wins.
        c.schedule.total_steps = c.steps;
        if let Some(v) = j
            .get("schedule")
            .and_then(|s| s.get("total_steps"))
            .and_then(|x| x.as_usize())
        {
            c.schedule.total_steps = v;
        }
        Ok(c)
    }

    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("model") {
            self.model = v.to_string();
        }
        if let Some(v) = args.get("algo") {
            self.algo = Algo::parse(v)?;
        }
        self.weight_bits = args.get_usize("bits", self.weight_bits as usize)? as u32;
        self.act_bits = args.get_usize("act-bits", self.act_bits as usize)? as u32;
        self.steps = args.get_usize("steps", self.steps)?;
        self.train_examples = args.get_usize("train-examples", self.train_examples)?;
        self.test_examples = args.get_usize("test-examples", self.test_examples)?;
        self.lr = args.get_f32("lr", self.lr)?;
        self.momentum = args.get_f32("momentum", self.momentum)?;
        self.lr_beta = args.get_f32("lr-beta", self.lr_beta)?;
        self.seed = args.get_u64("seed", self.seed)?;
        self.eval_every = args.get_usize("eval-every", self.eval_every)?;
        self.beta_init = args.get_f32("beta-init", self.beta_init)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if !(2..=32).contains(&self.weight_bits) {
            return Err(anyhow!("weight_bits must be in [2, 32]"));
        }
        if !(2..=32).contains(&self.act_bits) {
            return Err(anyhow!("act_bits must be in [2, 32]"));
        }
        if self.steps == 0 {
            return Err(anyhow!("steps must be > 0"));
        }
        if self.train_examples == 0 || self.test_examples == 0 {
            return Err(anyhow!("dataset sizes must be > 0"));
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            return Err(anyhow!("lr must be positive"));
        }
        Ok(())
    }
}

/// Per-model base learning rate (momentum 0.9). The deep residual /
/// depthwise stacks run close to their stability edge with affine-only
/// normalization, so they take a lower lr than the shallow nets; values
/// were swept on the fp32 baselines (EXPERIMENTS.md §Calibration).
pub fn model_lr(model: &str) -> f32 {
    match model {
        "resnet18l" => 0.02,
        "mobilenetl" => 0.03,
        "resnet20l" => 0.05,
        _ => 0.06,
    }
}

/// k = 2^b - 1 with the >=24-bit case mapped to "effectively fp32"
/// (f32 mantissa limit; round(x*k)/k becomes identity-within-eps).
pub fn levels(bits: u32) -> f32 {
    if bits >= 24 {
        16_777_215.0 // 2^24 - 1
    } else {
        (2u64.pow(bits) - 1) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::argparse::ArgSpec;

    #[test]
    fn levels_mapping() {
        assert_eq!(levels(3), 7.0);
        assert_eq!(levels(4), 15.0);
        assert_eq!(levels(32), 16_777_215.0);
    }

    #[test]
    fn algo_program_names() {
        assert_eq!(Algo::Dorefa.train_program("vgg11l"), "train_dorefa_vgg11l");
        assert_eq!(Algo::Wrpn.train_program("vgg11l"), "train_wrpn_vgg11l_w2");
        assert_eq!(Algo::WaveqLearned.train_program("mlp"), "train_waveq_mlp");
        assert_eq!(Algo::Wrpn.model_key("mlp"), "mlp_w2");
        assert_eq!(Algo::Fp32.eval_program("mlp"), "eval_fp32_mlp");
    }

    #[test]
    fn json_round_trip_and_overrides() {
        let j = Json::parse(
            r#"{"model": "vgg11l", "algo": "dorefa", "weight_bits": 3,
                "steps": 50, "lr": 0.01, "schedule": {"lambda_w_max": 2.5}}"#,
        )
        .unwrap();
        let mut cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.model, "vgg11l");
        assert_eq!(cfg.algo, Algo::Dorefa);
        assert_eq!(cfg.weight_bits, 3);
        assert_eq!(cfg.schedule.lambda_w_max, 2.5);
        // Regression: from_json must sync the schedule horizon to the run
        // length (not leave the 1000-step default on a 50-step run).
        assert_eq!(cfg.schedule.total_steps, 50);

        let spec = ArgSpec { value_flags: &["bits", "model"], switch_flags: &[] };
        let args = Args::parse(
            &["x".to_string(), "--bits".into(), "5".into()],
            &spec,
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.weight_bits, 5);
        assert_eq!(cfg.model, "vgg11l");
    }

    #[test]
    fn from_json_schedule_round_trip() {
        // Regression: beta_decay used to be silently dropped by from_json.
        let j = Json::parse(
            r#"{"steps": 500,
                "schedule": {"beta_decay": 7.5, "explore_frac": 0.2}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.schedule.beta_decay, 7.5);
        assert_eq!(cfg.schedule.explore_frac, 0.2);
        assert_eq!(cfg.schedule.total_steps, 500);
        // An explicit schedule.total_steps overrides the sync.
        let j = Json::parse(r#"{"steps": 500, "schedule": {"total_steps": 800}}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.schedule.total_steps, 800);
        // RunConfig::load with no config file syncs to the CLI-resolved steps.
        let spec = ArgSpec { value_flags: &["steps"], switch_flags: &[] };
        let args = Args::parse(&["x".to_string()], &spec).unwrap();
        let cfg = RunConfig::load(None, &args).unwrap();
        assert_eq!(cfg.schedule.total_steps, cfg.steps);
        // Through the --config path the explicit file value also wins...
        let path = std::env::temp_dir().join("waveq_cfg_total_steps.json");
        std::fs::write(&path, r#"{"steps": 500, "schedule": {"total_steps": 800}}"#).unwrap();
        let p = path.to_string_lossy().into_owned();
        let cfg = RunConfig::load(Some(&p), &args).unwrap();
        assert_eq!((cfg.steps, cfg.schedule.total_steps), (500, 800));
        // ...unless a CLI --steps override re-syncs the horizon.
        let args = Args::parse(
            &["x".to_string(), "--steps".into(), "200".into()],
            &spec,
        )
        .unwrap();
        let cfg = RunConfig::load(Some(&p), &args).unwrap();
        assert_eq!((cfg.steps, cfg.schedule.total_steps), (200, 200));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = RunConfig::default();
        c.weight_bits = 1;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.lr = -1.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.steps = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn algo_parse_all() {
        for (s, a) in [
            ("fp32", Algo::Fp32),
            ("dorefa", Algo::Dorefa),
            ("wrpn", Algo::Wrpn),
            ("waveq-preset", Algo::WaveqPreset),
            ("waveq", Algo::WaveqLearned),
        ] {
            assert_eq!(Algo::parse(s).unwrap(), a);
        }
        assert!(Algo::parse("xyz").is_err());
    }
}
