//! `waveq` — the WaveQ coordinator CLI (Layer 3 entrypoint).
//!
//! Subcommands:
//!   smoke                      end-to-end stack check (short WaveQ run)
//!   train       [flags]        one training run (any model/algo/bits)
//!   experiment  <id|all>       regenerate a paper table/figure (results/)
//!   energy      [flags]        Stripes energy report for an assignment
//!   info                       list artifacts, models, programs
//!
//! Common flags: --artifacts DIR --config FILE --seed N --scale smoke|full
//! Train flags:  --model M --algo A --bits B --act-bits A --steps N --lr F
//!               --lr-beta F --eval-every N --save CKPT

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use waveq::config::RunConfig;
use waveq::coordinator::{Checkpoint, Trainer};
use waveq::energy::Stripes;
use waveq::experiments::{self, ExpContext, Scale};
use waveq::runtime::Runtime;
use waveq::util::argparse::{ArgSpec, Args};

const VALUE_FLAGS: &[&str] = &[
    "artifacts", "config", "seed", "scale", "model", "algo", "bits", "act-bits",
    "steps", "lr", "momentum", "lr-beta", "eval-every", "save", "train-examples",
    "test-examples", "beta-init", "out", "init",
];
const SWITCH_FLAGS: &[&str] = &["quiet", "help"];

fn main() {
    waveq::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let spec = ArgSpec { value_flags: VALUE_FLAGS, switch_flags: SWITCH_FLAGS };
    let args = Args::parse(argv, &spec)?;
    if args.has("help") || args.subcommand.is_none() {
        print_help();
        return Ok(());
    }
    let artifacts = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(waveq::artifacts_dir);

    match args.subcommand.as_deref().unwrap() {
        "info" => {
            let rt = Runtime::open(&artifacts)?;
            println!("platform: {}", rt.platform());
            println!("programs ({}):", rt.manifest.programs.len());
            for (name, p) in &rt.manifest.programs {
                println!("  {name:<32} inputs={:<3} outputs={}", p.inputs.len(), p.outputs.len());
            }
            println!("models ({}):", rt.manifest.models.len());
            for (name, m) in &rt.manifest.models {
                println!(
                    "  {name:<20} input={:?} classes={} params={} qlayers={} macs={}",
                    m.input_shape,
                    m.num_classes,
                    m.num_params(),
                    m.num_qlayers,
                    m.total_macs()
                );
            }
            Ok(())
        }
        "smoke" => {
            let rt = Runtime::open(&artifacts)?;
            let ctx = exp_context(&rt, &args)?;
            experiments::run("smoke", &ctx)
        }
        "experiment" => {
            let name = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: waveq experiment <id|all>"))?
                .clone();
            let rt = Runtime::open(&artifacts)?;
            let ctx = exp_context(&rt, &args)?;
            experiments::run(&name, &ctx)
        }
        "train" => {
            let rt = Runtime::open(&artifacts)?;
            let cfg = RunConfig::load(args.get("config"), &args)?;
            let mut trainer = Trainer::new(&rt, cfg);
            trainer.opts.quiet = args.has("quiet");
            if let Some(ckpt) = args.get("init") {
                trainer.opts.init_from = Some(ckpt.to_string());
            }
            let outcome = trainer.run()?;
            println!(
                "model={} algo={} steps={} -> test_acc={:.4} test_loss={:.4} bits={:?} (avg {:.2})",
                outcome.cfg.model,
                outcome.cfg.algo.name(),
                outcome.cfg.steps,
                outcome.test_acc,
                outcome.test_loss,
                outcome.assignment.bits,
                outcome.assignment.average_bits()
            );
            if let Some(path) = args.get("save") {
                let model = rt.manifest.model(&outcome.model_key)?;
                let tensors = outcome
                    .state
                    .all_params(model)?
                    .into_iter()
                    .zip(&model.params)
                    .map(|(t, p)| (p.name.clone(), t))
                    .collect();
                Checkpoint {
                    tensors,
                    beta: outcome.state.beta.clone(),
                    vbeta: outcome.state.vbeta.clone(),
                }
                .save(std::path::Path::new(path))?;
                println!("saved checkpoint to {path}");
            }
            if let Some(out) = args.get("out") {
                outcome.metrics.save_csv(std::path::Path::new(out))?;
                println!("saved metrics to {out}");
            }
            Ok(())
        }
        "energy" => {
            let rt = Runtime::open(&artifacts)?;
            let model_name = args.get_or("model", "simplenet5").to_string();
            let bits = args.get_usize("bits", 4)? as u32;
            let act = args.get_usize("act-bits", 4)? as u32;
            let model = rt.manifest.model(&model_name)?;
            let stripes = Stripes::default();
            let report = stripes.evaluate_homogeneous(model, bits, act);
            println!("Stripes energy report: {model_name} W{bits}/A{act}");
            for l in &report.layers {
                println!(
                    "  {:<12} bits={} macs={:>10} cycles={:>12.0} energy={:>14.0}",
                    l.name, l.bits, l.macs, l.cycles, l.energy
                );
            }
            println!(
                "  total: cycles={:.0} energy={:.0}",
                report.total_cycles, report.total_energy
            );
            let saving = stripes.saving_vs_baseline(model, &vec![bits; model.num_qlayers], act);
            println!("  energy saving vs 16-bit bit-parallel baseline: {saving:.2}x");
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}' (try --help)")),
    }
}

fn exp_context<'a>(rt: &'a Runtime, args: &Args) -> Result<ExpContext<'a>> {
    let scale = match args.get_or("scale", "full") {
        "smoke" => Scale::Smoke,
        "full" => Scale::Full,
        other => return Err(anyhow!("--scale must be smoke|full, got '{other}'")),
    };
    let mut ctx = ExpContext::new(rt, scale, args.get_u64("seed", 42)?);
    if let Some(out) = args.get("out") {
        ctx.out_dir = PathBuf::from(out);
    }
    Ok(ctx)
}

fn print_help() {
    println!(
        "waveq — WaveQ quantized-training coordinator

USAGE: waveq <subcommand> [flags]

SUBCOMMANDS:
  smoke                 end-to-end stack check (~1 min)
  train                 one run: --model M --algo fp32|dorefa|wrpn|waveq-preset|waveq
                        --bits B --act-bits A --steps N --lr F --lr-beta F
                        [--config FILE] [--save ckpt.bin] [--out metrics.csv]
  experiment <id|all>   regenerate a paper artifact: {}
  energy                Stripes report: --model M --bits B --act-bits A
  info                  list artifacts/models/programs

COMMON FLAGS: --artifacts DIR (default ./artifacts)  --seed N  --scale smoke|full",
        experiments::ALL.join(", ")
    );
}
