//! `waveq` — the WaveQ coordinator CLI (Layer 3 entrypoint).
//!
//! Subcommands:
//!   smoke                      end-to-end stack check (short WaveQ run)
//!   train       [flags]        one training run (any model/algo/bits)
//!   freeze      [flags]        pack a checkpoint into a low-bit artifact
//!   infer       [flags]        serve a frozen artifact (acc + imgs/s)
//!   serve       [flags]        concurrent TCP serving with cross-request
//!                              batching (or a self-driving loopback bench)
//!   experiment  <id|all>       regenerate a paper table/figure (results/)
//!   energy      [flags]        Stripes energy report for an assignment
//!   info                       list artifacts, models, programs
//!
//! Common flags: --artifacts DIR --config FILE --seed N --scale smoke|full
//! Train flags:  --model M --algo A --bits B --act-bits A --steps N --lr F
//!               --lr-beta F --eval-every N --save CKPT
//!               --workers N --round-len N (data-parallel; bitwise equal to
//!               --workers 1 for any N that divides the reduction grid)
//! Freeze flags: --init CKPT --out ART --model M --algo A --bits B --act-bits A
//! Infer flags:  --artifact ART --batch N --max-batch N --test-examples N
//!               --precision exact|int8
//! Serve flags:  --artifact ART --workers N --max-batch N --deadline-us N
//!               --precision exact|int8
//!               --listen ADDR | --loopback --clients N --requests N

// The CLI crate has no sanctioned unsafe at all (the pool's opt-out lives
// in the library); `forbid` makes that unoverridable.
#![forbid(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use waveq::config::{Algo, RunConfig};
use waveq::coordinator::{
    eval_batches, run_distributed, session_cfg, test_batcher_with_batch, BitAssignment,
    Checkpoint, DistCfg, Trainer,
};
use waveq::data::{spec_for_model, Dataset};
use waveq::energy::Stripes;
use waveq::experiments::{self, ExpContext, Scale};
use waveq::runtime::serve::{loopback_bench, serve_tcp};
use waveq::runtime::{
    FrozenModel, InferCfg, InferenceSession, ModelMeta, NativeModel, Precision, Runtime, ServeCfg,
    Server, Session,
};
use waveq::util::argparse::{ArgSpec, Args};

const VALUE_FLAGS: &[&str] = &[
    "artifacts", "config", "seed", "scale", "model", "algo", "bits", "act-bits",
    "steps", "lr", "momentum", "lr-beta", "eval-every", "save", "train-examples",
    "test-examples", "beta-init", "out", "init", "artifact", "batch", "max-batch",
    "workers", "round-len", "deadline-us", "listen", "clients", "requests", "precision",
];
const SWITCH_FLAGS: &[&str] = &["quiet", "help", "loopback"];

fn main() {
    waveq::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let spec = ArgSpec { value_flags: VALUE_FLAGS, switch_flags: SWITCH_FLAGS };
    let args = Args::parse(argv, &spec)?;
    if args.has("help") || args.subcommand.is_none() {
        print_help();
        return Ok(());
    }
    let artifacts = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(waveq::artifacts_dir);

    match args.subcommand.as_deref().unwrap() {
        "info" => {
            let rt = Runtime::open(&artifacts)?;
            println!("platform: {}", rt.platform());
            println!("programs ({}):", rt.manifest.programs.len());
            for (name, p) in &rt.manifest.programs {
                println!("  {name:<32} inputs={:<3} outputs={}", p.inputs.len(), p.outputs.len());
            }
            println!("models ({}):", rt.manifest.models.len());
            for (name, m) in &rt.manifest.models {
                println!(
                    "  {name:<20} input={:?} classes={} params={} qlayers={} macs={}",
                    m.input_shape,
                    m.num_classes,
                    m.num_params(),
                    m.num_qlayers,
                    m.total_macs()
                );
            }
            Ok(())
        }
        "smoke" => {
            let rt = Runtime::open(&artifacts)?;
            let ctx = exp_context(&rt, &args)?;
            experiments::run("smoke", &ctx)
        }
        "experiment" => {
            let name = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: waveq experiment <id|all>"))?
                .clone();
            let rt = Runtime::open(&artifacts)?;
            let ctx = exp_context(&rt, &args)?;
            experiments::run(&name, &ctx)
        }
        "train" => {
            let rt = Runtime::open(&artifacts)?;
            let cfg = RunConfig::load(args.get("config"), &args)?;
            let workers = args.get_usize("workers", 1)?;
            if workers > 1 {
                if args.get("init").is_some() {
                    return Err(anyhow!("--init is not supported with --workers > 1 yet"));
                }
                if args.get("out").is_some() {
                    return Err(anyhow!("--out metrics CSV is not supported with --workers > 1"));
                }
                let mut dcfg = DistCfg::new(workers);
                dcfg.round_len = args.get_usize("round-len", dcfg.round_len)?;
                dcfg.quiet = args.has("quiet");
                // The coordinator re-saves this at every round boundary, so
                // the file on disk always holds the latest round's state.
                dcfg.checkpoint = args.get("save").map(PathBuf::from);
                let outcome = run_distributed(&rt, &cfg, &dcfg)?;
                let assignment = BitAssignment::from_beta(&outcome.state.beta);
                println!(
                    "model={} algo={} steps={} workers={workers} -> test_acc={:.4} \
                     test_loss={:.4} bits={:?} (avg {:.2})",
                    cfg.model,
                    cfg.algo.name(),
                    cfg.steps,
                    outcome.test_acc,
                    outcome.test_loss,
                    assignment.bits,
                    assignment.average_bits()
                );
                println!(
                    "rounds={} drops={} replays={} rejoins={} allreduce={:.1}ms total",
                    outcome.rounds,
                    outcome.drops,
                    outcome.replays,
                    outcome.rejoins,
                    outcome.allreduce_secs * 1e3
                );
                if let Some(path) = args.get("save") {
                    println!("saved checkpoint to {path} (step {})", outcome.state.step);
                }
                return Ok(());
            }
            let mut trainer = Trainer::new(&rt, cfg);
            trainer.opts.quiet = args.has("quiet");
            if let Some(ckpt) = args.get("init") {
                trainer.opts.init_from = Some(ckpt.to_string());
            }
            let outcome = trainer.run()?;
            println!(
                "model={} algo={} steps={} -> test_acc={:.4} test_loss={:.4} bits={:?} (avg {:.2})",
                outcome.cfg.model,
                outcome.cfg.algo.name(),
                outcome.cfg.steps,
                outcome.test_acc,
                outcome.test_loss,
                outcome.assignment.bits,
                outcome.assignment.average_bits()
            );
            if let Some(path) = args.get("save") {
                let model = rt.manifest.model(&outcome.model_key)?;
                Checkpoint::from_state(model, &outcome.state)?.save(Path::new(path))?;
                println!("saved checkpoint to {path} (step {})", outcome.state.step);
            }
            if let Some(out) = args.get("out") {
                outcome.metrics.save_csv(std::path::Path::new(out))?;
                println!("saved metrics to {out}");
            }
            Ok(())
        }
        "freeze" => {
            let rt = Runtime::open(&artifacts)?;
            let cfg = RunConfig::load(args.get("config"), &args)?;
            let init = args.get("init").ok_or_else(|| {
                anyhow!("freeze needs --init <ckpt.bin> (waveq train --save writes one)")
            })?;
            let out = args
                .get("out")
                .ok_or_else(|| anyhow!("freeze needs --out <artifact.wqm>"))?;
            // Preset-quantized runs are frozen at the preset k, but the
            // checkpoint does not record it — demand an explicit --bits
            // instead of silently re-quantizing at the default.
            if matches!(cfg.algo, Algo::Dorefa | Algo::Wrpn) && args.get("bits").is_none() {
                return Err(anyhow!(
                    "freezing a {} run needs an explicit --bits matching the trained \
                     bitwidth (checkpoints do not record the preset)",
                    cfg.algo.name()
                ));
            }
            let model_key = cfg.algo.model_key(&cfg.model);
            let model = rt.manifest.model(&model_key)?.clone();
            // The same algo -> session mapping Trainer::run opens with, so
            // the checkpoint reopens under the shape it trained in.
            let mut session = Session::open(&rt, &session_cfg(&cfg, model.num_qlayers))?;
            session.load_checkpoint(Path::new(init))?;
            let frozen = session.freeze(cfg.ka())?;
            frozen.save(Path::new(out))?;
            let file_bytes = std::fs::metadata(out).map(|m| m.len()).unwrap_or(0);
            println!(
                "froze {model_key} (step {}) -> {out}\n  quantized layers: bits {:?}\n  \
                 packed weights: {} B vs {} B f32 ({})\n  artifact file: {file_bytes} B",
                session.state().step,
                frozen.layer_bits(),
                frozen.packed_weight_bytes(),
                frozen.f32_weight_bytes(),
                reduction_label(&frozen),
            );
            Ok(())
        }
        "infer" => {
            let path = args
                .get("artifact")
                .ok_or_else(|| anyhow!("infer needs --artifact <artifact.wqm>"))?;
            let frozen = FrozenModel::load(Path::new(path))?;
            let nm = NativeModel::by_name(&frozen.base, frozen.width_mult)
                .ok_or_else(|| anyhow!("artifact names unknown model '{}'", frozen.base))?;
            let meta = nm.meta();
            let examples = args.get_usize("test-examples", 1024)?;
            if examples == 0 {
                return Err(anyhow!("--test-examples must be > 0"));
            }
            // An out-of-range batch is the user's mistake to hear about,
            // not something to clamp silently (and deeper down it would
            // have been a Batcher panic): refuse it with the fix spelled
            // out.
            let batch = args.get_usize("batch", meta.batch)?;
            if batch == 0 || batch > examples {
                return Err(anyhow!(
                    "--batch {batch} must be in 1..={examples} (--test-examples); \
                     pass a smaller --batch or more --test-examples"
                ));
            }
            // The arena is sized once at max_batch; nothing in this loop
            // dispatches more than --batch rows, so that is the default.
            let max_batch = args.get_usize("max-batch", batch)?.max(batch);
            let seed = args.get_u64("seed", 42)?;
            let precision = parse_precision(&args)?;
            let icfg = InferCfg { max_batch, precision };
            let mut session = InferenceSession::open(&frozen, &icfg)?;
            let int_layers = session.int_gemm_layers();
            let test = test_batcher_with_batch(&meta, examples, seed, batch)?;
            let t0 = Instant::now();
            let (loss, acc) = eval_batches(&test, true, |b| {
                let rows = b.y.len() / meta.num_classes;
                session.eval(&b.x, &b.y, rows)
            })?;
            let secs = t0.elapsed().as_secs_f64();
            println!(
                "served {} ({examples} examples, batch {batch}, max_batch {max_batch}, \
                 precision {precision}) in {secs:.3}s\n  \
                 test_loss={loss:.4} test_acc={acc:.4}  throughput={:.1} imgs/s\n  \
                 bits {:?}  int8 GEMM layers {int_layers}\n  \
                 packed weights {} B vs {} B f32 ({})",
                meta.name,
                examples as f64 / secs,
                frozen.layer_bits(),
                frozen.packed_weight_bytes(),
                frozen.f32_weight_bytes(),
                reduction_label(&frozen),
            );
            Ok(())
        }
        "serve" => {
            let path = args
                .get("artifact")
                .ok_or_else(|| anyhow!("serve needs --artifact <artifact.wqm>"))?;
            let frozen = FrozenModel::load(Path::new(path))?;
            let cfg = ServeCfg {
                workers: args.get_usize("workers", 2)?.max(1),
                max_batch: args.get_usize("max-batch", 8)?.max(1),
                deadline: Duration::from_micros(args.get_u64("deadline-us", 1000)?),
                precision: parse_precision(&args)?,
            };
            let server = Server::start(&frozen, &cfg)?;
            let meta = server.meta().clone();
            let id = server.identity();
            println!(
                "serving {} — workers={} max_batch={} deadline={:?} precision={} \
                 (int8 GEMM layers {}/{})",
                meta.name,
                cfg.workers,
                cfg.max_batch,
                cfg.deadline,
                id.precision,
                id.int_gemm_layers,
                id.layer_bits.len(),
            );
            if args.has("loopback") {
                // Self-driving mode: spin up concurrent loopback TCP
                // clients against our own listener and report latency /
                // throughput — no external load generator needed.
                let clients = args.get_usize("clients", 8)?.max(1);
                let per_client = args.get_usize("requests", 50)?.max(1);
                let xs = serve_inputs(&meta, 64, args.get_u64("seed", 42)?);
                let rep = loopback_bench(&server, clients, per_client, &xs)?;
                println!(
                    "loopback: {clients} clients x {per_client} reqs -> {:.1} imgs/s  \
                     p50={:.3?} p99={:.3?}  mean batch fill {:.2}",
                    rep.imgs_per_s(),
                    rep.lat.p50,
                    rep.lat.p99,
                    rep.mean_fill
                );
                server.shutdown();
                Ok(())
            } else {
                let addr = args.get_or("listen", "127.0.0.1:7878").to_string();
                let listener = std::net::TcpListener::bind(&addr)
                    .map_err(|e| anyhow!("binding {addr}: {e}"))?;
                println!("listening on {addr} (length-prefixed WQSV frames; ctrl-c to stop)");
                serve_tcp(&server, listener, None)?;
                server.shutdown();
                Ok(())
            }
        }
        "energy" => {
            let rt = Runtime::open(&artifacts)?;
            let model_name = args.get_or("model", "simplenet5").to_string();
            let bits = args.get_usize("bits", 4)? as u32;
            let act = args.get_usize("act-bits", 4)? as u32;
            let model = rt.manifest.model(&model_name)?;
            let stripes = Stripes::default();
            let report = stripes.evaluate_homogeneous(model, bits, act);
            println!("Stripes energy report: {model_name} W{bits}/A{act}");
            for l in &report.layers {
                println!(
                    "  {:<12} bits={} macs={:>10} cycles={:>12.0} energy={:>14.0}",
                    l.name, l.bits, l.macs, l.cycles, l.energy
                );
            }
            println!(
                "  total: cycles={:.0} energy={:.0}",
                report.total_cycles, report.total_energy
            );
            let saving = stripes.saving_vs_baseline(model, &vec![bits; model.num_qlayers], act);
            println!("  energy saving vs 16-bit bit-parallel baseline: {saving:.2}x");
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand '{other}' (try --help)")),
    }
}

/// `--precision exact|int8` (default exact: bitwise-identical serving).
fn parse_precision(args: &Args) -> Result<Precision> {
    let s = args.get_or("precision", "exact");
    Precision::parse(s)
        .ok_or_else(|| anyhow!("--precision must be exact|int8, got '{s}'"))
}

/// A pool of synthetic held-out examples for the loopback client mode.
fn serve_inputs(meta: &ModelMeta, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let ds = Dataset::generate(spec_for_model(meta), n, seed, 1);
    let pix = ds.pixels();
    (0..n).map(|i| ds.images[i * pix..(i + 1) * pix].to_vec()).collect()
}

/// Human label for an artifact's packed-vs-f32 size story.
fn reduction_label(frozen: &FrozenModel) -> String {
    match frozen.size_reduction() {
        Some(r) => format!("{r:.2}x smaller"),
        None => "no packed layers".to_string(),
    }
}

fn exp_context<'a>(rt: &'a Runtime, args: &Args) -> Result<ExpContext<'a>> {
    let scale = match args.get_or("scale", "full") {
        "smoke" => Scale::Smoke,
        "full" => Scale::Full,
        other => return Err(anyhow!("--scale must be smoke|full, got '{other}'")),
    };
    let mut ctx = ExpContext::new(rt, scale, args.get_u64("seed", 42)?);
    if let Some(out) = args.get("out") {
        ctx.out_dir = PathBuf::from(out);
    }
    Ok(ctx)
}

fn print_help() {
    println!(
        "waveq — WaveQ quantized-training coordinator

USAGE: waveq <subcommand> [flags]

SUBCOMMANDS:
  smoke                 end-to-end stack check (~1 min)
  train                 one run: --model M --algo fp32|dorefa|wrpn|waveq-preset|waveq
                        --bits B --act-bits A --steps N --lr F --lr-beta F
                        [--config FILE] [--save ckpt.bin] [--out metrics.csv]
                        [--workers N] [--round-len N] data-parallel training,
                        bitwise equal to --workers 1 (N must divide the grid)
  freeze                pack a trained checkpoint into a bit-packed low-bit
                        artifact: --init ckpt.bin --out model.wqm
                        --model M --algo A [--bits B] [--act-bits A]
  infer                 serve a frozen artifact over the held-out stream:
                        --artifact model.wqm [--batch N] [--max-batch N]
                        [--test-examples N] [--precision exact|int8]
  serve                 concurrent serving with cross-request batching:
                        --artifact model.wqm [--workers N] [--max-batch N]
                        [--deadline-us N] [--precision exact|int8] and either
                        --listen HOST:PORT (length-prefixed TCP) or --loopback
                        [--clients N] [--requests N] (self-driving run)
  experiment <id|all>   regenerate a paper artifact: {}
  energy                Stripes report: --model M --bits B --act-bits A
  info                  list artifacts/models/programs

COMMON FLAGS: --artifacts DIR (default ./artifacts)  --seed N  --scale smoke|full",
        experiments::ALL.join(", ")
    );
}
