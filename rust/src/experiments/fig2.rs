//! Figure 2 — the WaveQ regularizer landscape and the schedule profiles:
//! (a-c) R(w; beta) surfaces/profiles over w for several bitwidths,
//! (d) profile over beta, (e) lambda_w / lambda_beta schedules (Fig. 9).
//!
//! Surfaces come from the AOT `reg_profile` program (the same lowered
//! closed forms the training loss uses); schedules from `schedule::ScheduleCfg`.

use anyhow::Result;

use super::ExpContext;
use crate::runtime::{buffer_f32, to_vec_f32};
use crate::schedule::ScheduleCfg;

pub const N_W: usize = 512;
pub const N_B: usize = 256;

/// Grids matching train_step::make_reg_profile.
pub fn grids() -> (Vec<f32>, Vec<f32>) {
    let w: Vec<f32> = (0..N_W).map(|i| -1.25 + 2.5 * i as f32 / (N_W - 1) as f32).collect();
    let b: Vec<f32> = (0..N_B).map(|i| 1.0 + 7.0 * i as f32 / (N_B - 1) as f32).collect();
    (w, b)
}

/// Run reg_profile; returns 9 row-major (N_W, N_B) matrices:
/// [r_n0, d1_n0, d2_n0, r_n1, d1_n1, d2_n1, r_n2, d1_n2, d2_n2].
pub fn profiles(ctx: &ExpContext) -> Result<Vec<Vec<f32>>> {
    let (w, b) = grids();
    let args = vec![buffer_f32(&w, &[N_W])?, buffer_f32(&b, &[N_B])?];
    let outs = ctx.rt.prepare("reg_profile")?.call(&args)?;
    outs.iter().map(|o| to_vec_f32(o)).collect()
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let (w, b) = grids();
    let outs = profiles(ctx)?;
    let r1 = &outs[3]; // the production normalization (norm=1)

    // (a)-(c): R1(w) profiles at the paper's bitwidths (2, 3, ternary-ish, 4, 5).
    let mut csv = String::from("w,beta2,beta3,beta4,beta5\n");
    let col_of = |target: f32| -> usize {
        b.iter()
            .enumerate()
            .min_by(|(_, x), (_, y)| {
                (*x - target).abs().partial_cmp(&(*y - target).abs()).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap()
    };
    let cols = [col_of(2.0), col_of(3.0), col_of(4.0), col_of(5.0)];
    for (wi, &wv) in w.iter().enumerate() {
        csv.push_str(&format!(
            "{},{},{},{},{}\n",
            wv,
            r1[wi * N_B + cols[0]],
            r1[wi * N_B + cols[1]],
            r1[wi * N_B + cols[2]],
            r1[wi * N_B + cols[3]],
        ));
    }
    ctx.write("fig2", "r1_vs_w.csv", &csv)?;

    // Sanity of the landscape: minima at the quantization levels.
    let k = 3.0f32; // beta = 2 -> k = 3
    let bcol = col_of(2.0);
    let at = |wv: f32| -> f32 {
        let wi = w
            .iter()
            .enumerate()
            .min_by(|(_, x), (_, y)| ((*x - wv).abs()).partial_cmp(&(*y - wv).abs()).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        r1[wi * N_B + bcol]
    };
    println!(
        "fig2: R1 at grid point 1/k={:.3}: {:.5}; at midpoint 1/(2k): {:.5} (must be larger)",
        1.0 / k,
        at(1.0 / k),
        at(0.5 / k)
    );

    // (d): profile over beta at a fixed off-grid w.
    let wi_fixed = w
        .iter()
        .enumerate()
        .min_by(|(_, x), (_, y)| ((*x - 0.37).abs()).partial_cmp(&(*y - 0.37).abs()).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let mut csv = String::from("beta,r1\n");
    for (bi, &bv) in b.iter().enumerate() {
        csv.push_str(&format!("{},{}\n", bv, r1[wi_fixed * N_B + bi]));
    }
    ctx.write("fig2", "r1_vs_beta.csv", &csv)?;

    // (e): the 3-phase schedule profiles.
    let cfg = ScheduleCfg { total_steps: 1000, ..Default::default() };
    let freeze = cfg.engage_end();
    let mut csv = String::from("step,lambda_w,lambda_beta\n");
    for s in 0..cfg.total_steps {
        let frozen = s >= freeze;
        let lw = cfg.lambda_w_at(s, frozen);
        let lb = cfg.lambda_beta_at(s, frozen.then_some(freeze));
        csv.push_str(&format!("{s},{lw},{lb}\n"));
    }
    ctx.write("fig2", "lambda_profiles.csv", &csv)?;
    println!("fig2: wrote landscape + schedule profiles");
    Ok(())
}
