//! Table 2 — preset homogeneous weight quantization: WRPN vs DoReFa vs
//! DoReFa+WaveQ at W3/W4/W5 (A32) on SimpleNet/ResNet-20/VGG-11 (cifar-lite)
//! and SVHN-8 (svhn-lite), plus the fp32 reference row.
//!
//! The paper's shape to reproduce: DoReFa+WaveQ > plain DoReFa > WRPN at
//! every bitwidth, with the gap largest at 3 bits and shrinking toward 5
//! bits (where everything approaches fp32).

use anyhow::Result;

use super::{print_table, ExpContext, Scale};
use crate::config::{Algo, RunConfig};
use crate::coordinator::Trainer;
use crate::util::json::Json;

pub const MODELS: &[&str] = &["simplenet5", "resnet20l", "vgg11l", "svhn8"];
pub const BITS: &[u32] = &[3, 4, 5];
pub const ALGOS: &[Algo] = &[Algo::Wrpn, Algo::Dorefa, Algo::WaveqPreset];

pub fn base_config(ctx: &ExpContext, model: &str, algo: Algo, bits: u32) -> RunConfig {
    // Smoke runs need enough steps for the 3-phase schedule to act: below
    // ~300 steps the lambda_w ramp barely holds before eval and the WaveQ
    // rows are indistinguishable from plain DoReFa (sim-calibrated; the
    // paper's Table 2 gap only appears once the hold phase has settled
    // weights onto the grid).
    let steps = ctx.steps(360, 500);
    let mut cfg = RunConfig {
        model: model.into(),
        algo,
        weight_bits: bits,
        act_bits: 32,
        steps,
        train_examples: if ctx.scale == Scale::Full { 6144 } else { 2048 },
        test_examples: 1024,
        lr: quant_lr(model, algo),
        lr_beta: 0.05,
        seed: ctx.seed,
        ..Default::default()
    };
    cfg.schedule.total_steps = steps;
    // Preset mode ramps lambda_w only; peak strength is matched to the CE
    // loss magnitude. The compressed smoke schedule needs a stronger peak
    // (the ramp+hold window is ~3x shorter), while at paper scale the long
    // hold phase makes 1.0 sufficient and 2.0 over-regularizes.
    cfg.schedule.lambda_w_max = if ctx.scale == Scale::Full { 1.0 } else { 2.0 };
    cfg
}

/// Quantized from-scratch training sits closer to the stability edge than
/// fp32 on the deeper stacks; swept per model (EXPERIMENTS.md §Calibration).
pub fn quant_lr(model: &str, algo: Algo) -> f32 {
    let base = crate::config::model_lr(model);
    if algo == Algo::Fp32 || model == "simplenet5" {
        base
    } else {
        base * 0.3
    }
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut raw = Vec::new();

    // Full-precision reference row.
    let mut fp_row = vec!["W32/A32".to_string(), "fp32".to_string()];
    for model in MODELS {
        let cfg = base_config(ctx, model, Algo::Fp32, 8);
        let out = Trainer::new(ctx.rt, cfg).run()?;
        fp_row.push(format!("{:.2}", 100.0 * out.test_acc));
        raw.push((model.to_string(), "fp32".to_string(), 32u32, out.test_acc));
    }
    rows.push(fp_row);

    for &bits in BITS {
        let mut cells: Vec<Vec<String>> = ALGOS
            .iter()
            .map(|a| vec![format!("W{bits}/A32"), algo_label(*a).to_string()])
            .collect();
        let mut accs = vec![vec![0f32; MODELS.len()]; ALGOS.len()];
        for (mi, model) in MODELS.iter().enumerate() {
            for (ai, &algo) in ALGOS.iter().enumerate() {
                let cfg = base_config(ctx, model, algo, bits);
                let out = Trainer::new(ctx.rt, cfg).run()?;
                cells[ai].push(format!("{:.2}", 100.0 * out.test_acc));
                accs[ai][mi] = out.test_acc;
                raw.push((model.to_string(), algo_label(algo).to_string(), bits, out.test_acc));
            }
        }
        for c in cells {
            rows.push(c);
        }
        // Improvement row: WaveQ over the best plain baseline.
        let mut imp = vec![String::new(), "improvement".to_string()];
        for mi in 0..MODELS.len() {
            let best_plain = accs[0][mi].max(accs[1][mi]);
            imp.push(format!("{:+.2}", 100.0 * (accs[2][mi] - best_plain)));
        }
        rows.push(imp);
    }

    let mut headers = vec!["W/A", "method"];
    headers.extend(MODELS.iter().copied());
    print_table("Table 2 — preset homogeneous weight quantization (top-1 %)", &headers, &rows);

    let json = Json::Arr(
        raw.iter()
            .map(|(m, a, b, acc)| {
                Json::obj(vec![
                    ("model", Json::Str(m.clone())),
                    ("method", Json::Str(a.clone())),
                    ("weight_bits", Json::Num(*b as f64)),
                    ("top1", Json::Num(*acc as f64 * 100.0)),
                ])
            })
            .collect(),
    );
    ctx.write("table2", "table2.json", &json.to_string())?;
    Ok(())
}

pub fn algo_label(a: Algo) -> &'static str {
    match a {
        Algo::Wrpn => "WRPN",
        Algo::Dorefa => "DoReFa",
        Algo::WaveqPreset => "DoReFa+WaveQ",
        Algo::WaveqLearned => "DoReFa+WaveQ(learned)",
        Algo::Fp32 => "fp32",
    }
}
