//! Experiment registry: one driver per paper table/figure (DESIGN.md §4).
//!
//! Every driver prints the paper-shaped rows to stdout and dumps the raw
//! series/points as CSV+JSON under `results/<id>/` so figures can be
//! re-plotted. Drivers honour a smoke/full scale so the bench targets can
//! run them cheaply while `waveq experiment <id>` runs paper scale.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod smoke;
pub mod table1;
pub mod table2;

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::runtime::Runtime;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Short runs: exercises every code path, minutes not hours.
    Smoke,
    /// Paper scale (the numbers recorded in EXPERIMENTS.md).
    Full,
}

pub struct ExpContext<'a> {
    pub rt: &'a Runtime,
    pub out_dir: PathBuf,
    pub scale: Scale,
    pub seed: u64,
}

impl<'a> ExpContext<'a> {
    pub fn new(rt: &'a Runtime, scale: Scale, seed: u64) -> ExpContext<'a> {
        ExpContext { rt, out_dir: PathBuf::from("results"), scale, seed }
    }

    pub fn steps(&self, smoke: usize, full: usize) -> usize {
        match self.scale {
            Scale::Smoke => smoke,
            Scale::Full => full,
        }
    }

    pub fn out(&self, experiment: &str, file: &str) -> PathBuf {
        self.out_dir.join(experiment).join(file)
    }

    pub fn write(&self, experiment: &str, file: &str, contents: &str) -> Result<()> {
        let path = self.out(experiment, file);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, contents)?;
        crate::info!("wrote {}", path.display());
        Ok(())
    }
}

pub const ALL: &[&str] = &[
    "smoke", "table1", "table2", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
];

pub fn run(name: &str, ctx: &ExpContext) -> Result<()> {
    match name {
        "smoke" => smoke::run(ctx),
        "table1" => table1::run(ctx),
        "table2" => table2::run(ctx),
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "all" => {
            for n in ALL {
                crate::info!("=== experiment {n} ===");
                run(n, ctx)?;
            }
            Ok(())
        }
        other => Err(anyhow!("unknown experiment '{other}'; known: {ALL:?} or 'all'")),
    }
}

/// Markdown-ish table printer shared by drivers.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n## {title}");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let cols: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect();
        format!("| {} |", cols.join(" | "))
    };
    let header_cells: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&header_cells));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("{}", fmt_row(&sep));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}
