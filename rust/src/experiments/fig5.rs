//! Figure 5 — learned heterogeneous bitwidth assignments for the big nets
//! (alexnet-lite, resnet18-lite) and the decrement-one-layer sensitivity
//! scan: lowering any single layer's learned bitwidth by one should cost
//! accuracy (0.44% / 0.24% average in the paper), evidence that the learned
//! assignment sits at a genuine boundary.

use anyhow::Result;

use super::{print_table, ExpContext, Scale};
use crate::config::{Algo, RunConfig};
use crate::coordinator::{evaluate, test_batcher, Trainer};
use crate::util::json::Json;

pub const MODELS: &[&str] = &["alexnetl", "resnet18l"];

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut rows = Vec::new();
    let mut raw = Vec::new();
    for model in MODELS {
        let steps = ctx.steps(100, 600);
        let mut cfg = RunConfig {
            model: model.to_string(),
            algo: Algo::WaveqLearned,
            lr: crate::config::model_lr(model),
            act_bits: 4,
            steps,
            train_examples: if ctx.scale == Scale::Full { 6144 } else { 1024 },
            test_examples: if ctx.scale == Scale::Full { 1024 } else { 512 },
            seed: ctx.seed,
            ..Default::default()
        };
        cfg.schedule.total_steps = steps;
        let outcome = Trainer::new(ctx.rt, cfg.clone()).run()?;
        let meta = ctx.rt.manifest.model(&outcome.model_key)?.clone();

        // Per-layer assignment dump (the bar graph).
        let mut csv = String::from("qidx,param,bits,macs,weights\n");
        let qstats = meta.qlayer_stats();
        let qparams = meta.qlayer_param_indices();
        for (q, &b) in outcome.assignment.bits.iter().enumerate() {
            let p = &meta.params[qparams[q]];
            csv.push_str(&format!("{q},{},{b},{},{}\n", p.name, qstats[q].0, qstats[q].1));
        }
        ctx.write("fig5", &format!("{model}_bits.csv"), &csv)?;

        // Sensitivity: decrement one layer at a time, re-evaluate.
        let eval_prog = format!("eval_quant_{model}");
        let test = test_batcher(&meta, cfg.test_examples, ctx.seed)?;
        let base_acc = outcome.test_acc;
        let mut drops = Vec::new();
        let mut sens_csv = String::from("layer,bits_after,acc,drop\n");
        for layer in 0..outcome.assignment.bits.len() {
            let dec = outcome.assignment.decrement_layer(layer);
            if dec.bits == outcome.assignment.bits {
                continue; // already at the floor
            }
            let (_, acc) = evaluate(
                ctx.rt,
                &eval_prog,
                &meta,
                &outcome.state.params,
                Some(&dec.kw()),
                cfg.ka(),
                &test,
            )?;
            let drop = base_acc - acc;
            drops.push(drop as f64);
            sens_csv.push_str(&format!(
                "{layer},{},{:.4},{:.4}\n",
                dec.bits[layer], acc, drop
            ));
        }
        ctx.write("fig5", &format!("{model}_sensitivity.csv"), &sens_csv)?;

        let avg_drop = if drops.is_empty() {
            0.0
        } else {
            drops.iter().sum::<f64>() / drops.len() as f64
        };
        rows.push(vec![
            model.to_string(),
            format!("{:?}", outcome.assignment.bits),
            format!("{:.2}", outcome.assignment.average_bits()),
            format!("{:.2}", 100.0 * base_acc),
            format!("{:.3}%", 100.0 * avg_drop),
        ]);
        raw.push(Json::obj(vec![
            ("model", Json::Str(model.to_string())),
            ("bits", Json::arr_usize(
                &outcome.assignment.bits.iter().map(|&b| b as usize).collect::<Vec<_>>(),
            )),
            ("avg_bits", Json::Num(outcome.assignment.average_bits())),
            ("top1", Json::Num(base_acc as f64 * 100.0)),
            ("avg_decrement_drop_pct", Json::Num(100.0 * avg_drop)),
        ]));
    }
    print_table(
        "Figure 5 — learned assignments + decrement-one-layer sensitivity",
        &["model", "learned bits", "avg bits", "top-1 %", "avg drop on -1 bit"],
        &rows,
    );
    ctx.write("fig5", "summary.json", &Json::Arr(raw).to_string())?;
    Ok(())
}
