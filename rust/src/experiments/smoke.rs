//! End-to-end smoke: one short WaveQ-learned training run on the MLP,
//! verifying the full stack composes (artifacts load, train step executes,
//! schedule advances, beta freezes, eval runs) and that the numbers are
//! sane. This is the `make smoke` target and the first gate in CI.

use anyhow::{ensure, Result};

use super::ExpContext;
use crate::config::{Algo, RunConfig};
use crate::coordinator::Trainer;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let cfg = RunConfig {
        model: "mlp".into(),
        algo: Algo::WaveqLearned,
        steps: ctx.steps(60, 200),
        train_examples: 2048,
        test_examples: 512,
        lr: 0.05,
        lr_beta: 0.05,
        seed: ctx.seed,
        beta_init: 6.0,
        ..Default::default()
    };
    let mut schedule = cfg.schedule.clone();
    schedule.total_steps = cfg.steps;
    let cfg = RunConfig { schedule, ..cfg };

    let mut trainer = Trainer::new(ctx.rt, cfg);
    let outcome = trainer.run()?;

    let first_loss = outcome.metrics.get("loss").first().map(|&(_, v)| v).unwrap_or(0.0);
    let last_loss = outcome.metrics.tail_mean("loss", 10).unwrap_or(f64::MAX);
    println!(
        "smoke: loss {first_loss:.3} -> {last_loss:.3}, test_acc {:.3}, bits {:?} (avg {:.2}), freeze@{:?}",
        outcome.test_acc,
        outcome.assignment.bits,
        outcome.assignment.average_bits(),
        outcome.freeze_step,
    );
    ensure!(last_loss < first_loss, "loss did not decrease");
    ensure!(outcome.test_acc > 0.15, "test accuracy at chance level");
    ensure!(
        outcome.assignment.bits.iter().all(|&b| (2..=8).contains(&b)),
        "bit assignment out of range"
    );
    let stats = ctx.rt.stats();
    println!(
        "smoke: {} compiles ({:.1}s), {} executions ({:.3} ms median-ish mean)",
        stats.compiles,
        stats.compile_secs,
        stats.executions,
        1e3 * stats.execute_secs / stats.executions.max(1) as f64
    );
    println!("smoke: top programs by cumulative execute time:");
    println!("  {:<28} {:>10} {:>10} {:>10}", "program", "execs", "total", "mean");
    for (name, p) in stats.top_programs(5) {
        println!(
            "  {:<28} {:>10} {:>9.3}s {:>8.2}ms",
            name,
            p.executions,
            p.execute_secs,
            1e3 * p.execute_secs / p.executions.max(1) as f64
        );
    }
    println!("SMOKE OK");
    Ok(())
}
