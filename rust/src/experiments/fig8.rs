//! Figure 8 — convergence behaviour:
//!   (a, b) fine-tuning: accuracy rises while the WaveQ regularization loss
//!          falls across epochs (cifar-lite / svhn-lite) — the two
//!          objectives are optimized simultaneously;
//!   (c, d) from-scratch, VGG-11 at 2-bit: with WaveQ the accuracy briefly
//!          trails plain DoReFa early (extra objective) then overtakes it
//!          by a clear margin (~6% in the paper).

use anyhow::Result;

use super::{print_table, ExpContext, Scale};
use crate::config::{Algo, RunConfig};
use crate::coordinator::{Checkpoint, TrainOptions, Trainer};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut rows = Vec::new();

    // ---- (a, b): fine-tune a pretrained fp32 model with WaveQ ------------
    for model in ["simplenet5", "svhn8"] {
        let steps = ctx.steps(100, 500);
        let mk = |algo: Algo, steps: usize, seed: u64| {
            let mut cfg = RunConfig {
                model: model.to_string(),
                algo,
                lr: crate::config::model_lr(model),
                weight_bits: 3,
                act_bits: 32,
                steps,
                train_examples: if ctx.scale == Scale::Full { 4096 } else { 1024 },
                test_examples: 512,
                eval_every: (steps / 12).max(1),
                seed,
                ..Default::default()
            };
            cfg.schedule.total_steps = steps;
            // Fine-tuning starts from a converged model: engage earlier.
            cfg.schedule.explore_frac = 0.05;
            cfg.schedule.lambda_w_max = 2.0;
            cfg
        };

        // Pretrain fp32 + checkpoint.
        let pre = Trainer::new(ctx.rt, mk(Algo::Fp32, steps, ctx.seed)).run()?;
        let meta = ctx.rt.manifest.model(&pre.model_key)?.clone();
        let ck_path = ctx.out("fig8", &format!("{model}_fp32.ckpt"));
        std::fs::create_dir_all(ck_path.parent().unwrap())?;
        Checkpoint::from_state(&meta, &pre.state)?.save(&ck_path)?;

        // Fine-tune with WaveQ engaged.
        let opts = TrainOptions {
            init_from: Some(ck_path.to_string_lossy().into_owned()),
            ..Default::default()
        };
        let ft = Trainer::with_options(ctx.rt, mk(Algo::WaveqPreset, steps, ctx.seed), opts).run()?;
        ft.metrics.save_csv(&ctx.out("fig8", &format!("{model}_finetune.csv")))?;

        let reg_first = ft.metrics.get("reg_w").iter().find(|&&(_, v)| v > 0.0).map(|&(_, v)| v);
        let reg_last = ft.metrics.tail_mean("reg_w", 10);
        rows.push(vec![
            format!("{model} fine-tune W3"),
            format!("{:.2} -> {:.2}", 100.0 * pre.test_acc, 100.0 * ft.test_acc),
            format!(
                "{:.4} -> {:.4}",
                reg_first.unwrap_or(0.0),
                reg_last.unwrap_or(0.0)
            ),
            String::new(),
        ]);
    }

    // ---- (c, d): from-scratch VGG-11 2-bit, with vs without WaveQ ---------
    let steps = ctx.steps(120, 500);
    let mk = |algo: Algo| {
        let mut cfg = RunConfig {
            model: "vgg11l".to_string(),
            algo,
            weight_bits: 2,
            act_bits: 32,
            steps,
            train_examples: if ctx.scale == Scale::Full { 4096 } else { 1024 },
            test_examples: 512,
            eval_every: (steps / 12).max(1),
            seed: ctx.seed,
            ..Default::default()
        };
        cfg.schedule.total_steps = steps;
        cfg.schedule.lambda_w_max = 2.0;
        cfg
    };
    let plain = Trainer::new(ctx.rt, mk(Algo::Dorefa)).run()?;
    plain.metrics.save_csv(&ctx.out("fig8", "vgg11l_scratch_dorefa.csv"))?;
    let waveq = Trainer::new(ctx.rt, mk(Algo::WaveqPreset)).run()?;
    waveq.metrics.save_csv(&ctx.out("fig8", "vgg11l_scratch_waveq.csv"))?;
    rows.push(vec![
        "vgg11l from-scratch W2".into(),
        format!("DoReFa {:.2}", 100.0 * plain.test_acc),
        format!("+WaveQ {:.2}", 100.0 * waveq.test_acc),
        format!("{:+.2}", 100.0 * (waveq.test_acc - plain.test_acc)),
    ]);

    print_table(
        "Figure 8 — convergence (fine-tune + from-scratch)",
        &["setting", "accuracy", "reg loss / +WaveQ", "delta"],
        &rows,
    );
    Ok(())
}
