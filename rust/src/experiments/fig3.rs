//! Figure 3 — normalization variants R0/R1/R2 of Eq. 2.5 and their first
//! and second derivatives w.r.t. beta: the paper's claim is that R0 has
//! exploding-gradient regions, R2 has vanishing-gradient regions, and only
//! R1 (the production choice) is free of both.
//!
//! We verify numerically over the (w, beta) grid: max |dR/dbeta| grows
//! unboundedly with beta for R0, collapses to ~0 for R2, and stays within
//! a bounded band for R1.

use anyhow::{ensure, Result};

use super::fig2::{grids, profiles, N_B, N_W};
use super::{print_table, ExpContext};

pub struct VariantStats {
    pub norm: usize,
    /// max over w of |d1| at the low / high end of the beta range.
    pub d1_low_beta: f64,
    pub d1_high_beta: f64,
    pub growth_ratio: f64,
}

pub fn analyze(ctx: &ExpContext) -> Result<Vec<VariantStats>> {
    let (_, b) = grids();
    let outs = profiles(ctx)?;
    let mut stats = Vec::new();
    // beta windows: low = [1, 2.5], high = [6.5, 8].
    let lo_cols: Vec<usize> = (0..N_B).filter(|&i| b[i] <= 2.5).collect();
    let hi_cols: Vec<usize> = (0..N_B).filter(|&i| b[i] >= 6.5).collect();
    for norm in 0..3usize {
        let d1 = &outs[norm * 3 + 1];
        let max_abs_over = |cols: &[usize]| -> f64 {
            let mut m = 0f64;
            for wi in 0..N_W {
                for &ci in cols {
                    m = m.max(d1[wi * N_B + ci].abs() as f64);
                }
            }
            m
        };
        let lo = max_abs_over(&lo_cols);
        let hi = max_abs_over(&hi_cols);
        stats.push(VariantStats {
            norm,
            d1_low_beta: lo,
            d1_high_beta: hi,
            growth_ratio: hi / lo.max(1e-12),
        });
    }
    Ok(stats)
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let stats = analyze(ctx)?;
    let rows: Vec<Vec<String>> = stats
        .iter()
        .map(|s| {
            vec![
                format!("R{}", s.norm),
                format!("{:.3e}", s.d1_low_beta),
                format!("{:.3e}", s.d1_high_beta),
                format!("{:.3e}", s.growth_ratio),
                match s.norm {
                    0 => "explodes with beta".into(),
                    1 => "bounded (production)".into(),
                    _ => "vanishes with beta".into(),
                },
            ]
        })
        .collect();
    print_table(
        "Figure 3 — dR/dbeta ranges per normalization variant",
        &["variant", "max|d1| beta<=2.5", "max|d1| beta>=6.5", "high/low ratio", "paper claim"],
        &rows,
    );

    // Dump the full derivative surfaces for re-plotting.
    let (w, b) = grids();
    let outs = profiles(ctx)?;
    for norm in 0..3usize {
        let mut csv = String::from("w,beta,r,d1,d2\n");
        // Subsample 4x in each dim to keep files small.
        for wi in (0..N_W).step_by(4) {
            for bi in (0..N_B).step_by(4) {
                csv.push_str(&format!(
                    "{},{},{},{},{}\n",
                    w[wi],
                    b[bi],
                    outs[norm * 3][wi * N_B + bi],
                    outs[norm * 3 + 1][wi * N_B + bi],
                    outs[norm * 3 + 2][wi * N_B + bi],
                ));
            }
        }
        ctx.write("fig3", &format!("variant_n{norm}.csv"), &csv)?;
    }

    // The paper's qualitative claims, enforced:
    ensure!(
        stats[0].growth_ratio > 10.0,
        "R0 should explode with beta (ratio {})",
        stats[0].growth_ratio
    );
    ensure!(
        stats[2].growth_ratio < 0.2,
        "R2 should vanish with beta (ratio {})",
        stats[2].growth_ratio
    );
    ensure!(
        stats[1].growth_ratio > 0.2 && stats[1].growth_ratio < 10.0,
        "R1 should stay bounded (ratio {})",
        stats[1].growth_ratio
    );
    println!("fig3: variant claims verified (R0 explodes, R1 bounded, R2 vanishes)");
    Ok(())
}
