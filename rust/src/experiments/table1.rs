//! Table 1 — comparison on imagenet-lite (AlexNet/ResNet-18/MobileNet lite):
//! fp32 reference, W3/A3 and W4/A4 preset rows (DoReFa, WRPN at W4,
//! DoReFa+WaveQ), and the headline learned-heterogeneous row
//! W(learn)/A4 with average bitwidth + Stripes energy saving.
//!
//! Shape to reproduce: WaveQ beats plain DoReFa/WRPN at each preset width;
//! the learned row matches or beats W4 homogeneous accuracy with a *lower*
//! average bitwidth, and banks a >1x energy saving.

use anyhow::Result;

use super::table2::algo_label;
use super::{print_table, ExpContext, Scale};
use crate::config::{Algo, RunConfig};
use crate::coordinator::Trainer;
use crate::energy::Stripes;
use crate::util::json::Json;

pub const MODELS: &[&str] = &["alexnetl", "resnet18l", "mobilenetl"];

pub fn base_config(ctx: &ExpContext, model: &str, algo: Algo, wbits: u32, abits: u32) -> RunConfig {
    let steps = ctx.steps(80, 500);
    let mut cfg = RunConfig {
        model: model.into(),
        algo,
        weight_bits: wbits,
        act_bits: abits,
        steps,
        train_examples: if ctx.scale == Scale::Full { 6144 } else { 1024 },
        test_examples: if ctx.scale == Scale::Full { 1024 } else { 512 },
        lr: super::table2::quant_lr(model, algo),
        lr_beta: 0.05,
        seed: ctx.seed,
        beta_init: 6.0,
        ..Default::default()
    };
    cfg.schedule.total_steps = steps;
    cfg.schedule.lambda_w_max = 1.0;
    cfg
}

struct Cell {
    acc: f32,
    avg_bits: f64,
    energy_saving: f64,
}

fn train_cell(ctx: &ExpContext, model: &str, algo: Algo, wbits: u32, abits: u32) -> Result<Cell> {
    let cfg = base_config(ctx, model, algo, wbits, abits);
    let out = Trainer::new(ctx.rt, cfg).run()?;
    let meta = ctx.rt.manifest.model(&out.model_key)?;
    let stripes = Stripes::default();
    let saving = stripes.saving_vs_baseline(meta, &out.assignment.bits, abits.min(8));
    Ok(Cell { acc: out.test_acc, avg_bits: out.assignment.average_bits(), energy_saving: saving })
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut raw: Vec<Json> = Vec::new();
    let record = |raw: &mut Vec<Json>, model: &str, wa: &str, method: &str, c: &Cell| {
        raw.push(Json::obj(vec![
            ("model", Json::Str(model.into())),
            ("wa", Json::Str(wa.into())),
            ("method", Json::Str(method.into())),
            ("top1", Json::Num(c.acc as f64 * 100.0)),
            ("avg_bits", Json::Num(c.avg_bits)),
            ("energy_saving", Json::Num(c.energy_saving)),
        ]));
    };

    // fp32 reference.
    let mut row = vec!["W32/A32".into(), "Full Precision".to_string()];
    let mut fp32_acc = Vec::new();
    for model in MODELS {
        let c = train_cell(ctx, model, Algo::Fp32, 8, 32)?;
        row.push(format!("{:.2}", 100.0 * c.acc));
        record(&mut raw, model, "W32/A32", "fp32", &c);
        fp32_acc.push(c.acc);
    }
    rows.push(row);

    // Preset homogeneous sections.
    for &(wb, ab, algos) in &[
        (3u32, 3u32, &[Algo::Dorefa, Algo::WaveqPreset][..]),
        (4, 4, &[Algo::Wrpn, Algo::Dorefa, Algo::WaveqPreset][..]),
    ] {
        let mut accs = vec![vec![0f32; MODELS.len()]; algos.len()];
        for (ai, &algo) in algos.iter().enumerate() {
            let mut row = vec![format!("W{wb}/A{ab}"), algo_label(algo).to_string()];
            for (mi, model) in MODELS.iter().enumerate() {
                let c = train_cell(ctx, model, algo, wb, ab)?;
                row.push(format!("{:.2}", 100.0 * c.acc));
                accs[ai][mi] = c.acc;
                record(&mut raw, model, &format!("W{wb}/A{ab}"), algo_label(algo), &c);
            }
            rows.push(row);
        }
        let mut imp = vec![String::new(), "improvement".to_string()];
        for mi in 0..MODELS.len() {
            let waveq = accs[algos.len() - 1][mi];
            let best_plain = accs[..algos.len() - 1].iter().map(|r| r[mi]).fold(0f32, f32::max);
            imp.push(format!("{:+.2}", 100.0 * (waveq - best_plain)));
        }
        rows.push(imp);
    }

    // Learned heterogeneous headline row.
    let mut acc_row = vec!["W(learn)/A4".into(), "DoReFa+WaveQ".to_string()];
    let mut bits_row = vec![String::new(), "avg bits".to_string()];
    let mut energy_row = vec![String::new(), "energy saving".to_string()];
    for model in MODELS {
        let c = train_cell(ctx, model, Algo::WaveqLearned, 4, 4)?;
        acc_row.push(format!("{:.2}", 100.0 * c.acc));
        bits_row.push(format!("W{:.2}", c.avg_bits));
        energy_row.push(format!("{:.2}x", c.energy_saving));
        record(&mut raw, model, "W(learn)/A4", "DoReFa+WaveQ learned", &c);
    }
    rows.push(acc_row);
    rows.push(bits_row);
    rows.push(energy_row);

    let mut headers = vec!["W/A", "method"];
    headers.extend(MODELS.iter().copied());
    print_table("Table 1 — imagenet-lite comparison (top-1 %)", &headers, &rows);
    ctx.write("table1", "table1.json", &Json::Arr(raw).to_string())?;
    Ok(())
}
