//! Figure 6 — evolution of weight distributions over training: with the
//! WaveQ regularizer engaged, per-layer weight histograms develop clusters
//! that converge around the quantization centroids.
//!
//! We track a mid-network conv layer's histogram across training for
//! (cifar-lite, 3 bits), (svhn-lite, 4 bits), (alexnet-lite, 4 bits),
//! (resnet18-lite, 4 bits) and report the "mass near grid" statistic —
//! fraction of weights within half a quantization step of a centroid —
//! which must increase substantially from start to end of training.

use anyhow::Result;

use super::{print_table, ExpContext, Scale};
use crate::config::{Algo, RunConfig};
use crate::coordinator::{TrackKind, TrackRequest, TrainOptions, Trainer};
use crate::tensor::quant_levels;

/// (model, weight_bits) cells of the figure.
pub const CELLS: &[(&str, u32)] =
    &[("simplenet5", 3), ("svhn8", 4), ("alexnetl", 4), ("resnet18l", 4)];

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut rows = Vec::new();
    for &(model, bits) in CELLS {
        let steps = ctx.steps(120, 500);
        let mut cfg = RunConfig {
            model: model.to_string(),
            algo: Algo::WaveqPreset,
            lr: crate::config::model_lr(model),
            weight_bits: bits,
            act_bits: 32,
            steps,
            train_examples: if ctx.scale == Scale::Full { 4096 } else { 1024 },
            test_examples: 512,
            seed: ctx.seed,
            ..Default::default()
        };
        cfg.schedule.total_steps = steps;
        cfg.schedule.lambda_w_max = 2.0;

        // Track a mid-network quantized conv layer.
        let meta = ctx.rt.manifest.model(model)?.clone();
        let qparams = meta.qlayer_param_indices();
        let target = qparams[qparams.len() / 2];
        let opts = TrainOptions {
            track: vec![TrackRequest {
                param: target,
                every: (steps / 8).max(1),
                kind: TrackKind::Histogram { bins: 201, lo: -1.0, hi: 1.0 },
            }],
            ..Default::default()
        };
        let outcome = Trainer::with_options(ctx.rt, cfg, opts).run()?;

        // DoReFa's grid with k = 2^b - 1 steps over [-1, 1]: levels (2i-k)/k.
        // (k odd => zero excluded: a mid-rise quantizer, the paper's top row.)
        let k = (2u64.pow(bits) - 1) as f32;
        let levels: Vec<f32> = (0..=(k as i64)).map(|i| (2 * i) as f32 / k - 1.0).collect();
        let tol = 0.5 / k;

        let mut csv = String::from("step,mass_near_grid\n");
        let mut first_mass = None;
        let mut last_mass = 0.0;
        for snap in &outcome.snapshots {
            if let Some(h) = &snap.histogram {
                // Histogram is over raw weights; normalize levels by abs-max
                // via the histogram range proxy (weights live in ~[-1,1]
                // under the regularizer, matching the paper's plots).
                let mass = h.mass_near_levels(&levels, tol);
                if first_mass.is_none() {
                    first_mass = Some(mass);
                }
                last_mass = mass;
                csv.push_str(&format!("{},{}\n", snap.step, mass));
                ctx.write(
                    "fig6",
                    &format!("{model}_w{bits}_hist_step{}.csv", snap.step),
                    &h.to_csv(),
                )?;
            }
        }
        ctx.write("fig6", &format!("{model}_w{bits}_mass.csv"), &csv)?;
        rows.push(vec![
            model.to_string(),
            format!("{bits}"),
            format!("{:.3}", first_mass.unwrap_or(0.0)),
            format!("{:.3}", last_mass),
            format!("{:.2}", 100.0 * outcome.test_acc),
        ]);

        let _ = quant_levels(bits); // symmetric-grid helper kept for plotting parity
    }
    print_table(
        "Figure 6 — weight mass near quantization centroids (start -> end)",
        &["model", "bits", "mass@start", "mass@end", "top-1 %"],
        &rows,
    );
    Ok(())
}
