//! Figure 4 — the quantization design space (compute vs accuracy) for
//! CIFAR-10 (simplenet5), SVHN (svhn8) and VGG-11 (vgg11l):
//! enumerate/sample per-layer bitwidth assignments, evaluate each against a
//! WaveQ-trained state, extract the Pareto frontier, and locate the WaveQ
//! learned solution relative to it.
//!
//! Shape to reproduce: the learned assignment sits at (or within noise of)
//! the knee of the frontier — minimum average compute that still preserves
//! accuracy.

use anyhow::Result;

use super::{print_table, ExpContext, Scale};
use crate::config::{Algo, RunConfig};
use crate::coordinator::{evaluate, test_batcher, BitAssignment, Trainer};
use crate::energy::Stripes;
use crate::pareto::{
    accuracy_gap_to_frontier, enumerate_assignments, pareto_frontier, sample_assignments,
    save_csv, DesignPoint,
};
use crate::util::rng::Rng;

pub const MODELS: &[&str] = &["simplenet5", "svhn8", "vgg11l"];

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut rows = Vec::new();
    for model in MODELS {
        let row = run_model(ctx, model)?;
        rows.push(row);
    }
    print_table(
        "Figure 4 — Pareto analysis of the bitwidth design space",
        &["model", "points", "frontier", "waveq bits", "avg bits", "waveq acc", "gap to frontier"],
        &rows,
    );
    Ok(())
}

fn run_model(ctx: &ExpContext, model: &str) -> Result<Vec<String>> {
    // 1) Train with learned WaveQ to get both a quantization-friendly state
    //    and the learned assignment to locate in the space.
    let steps = ctx.steps(120, 500);
    let mut cfg = RunConfig {
        model: model.into(),
        algo: Algo::WaveqLearned,
        lr: crate::config::model_lr(model),
        steps,
        act_bits: 4,
        train_examples: if ctx.scale == Scale::Full { 6144 } else { 2048 },
        test_examples: if ctx.scale == Scale::Full { 1024 } else { 512 },
        seed: ctx.seed,
        ..Default::default()
    };
    cfg.schedule.total_steps = steps;
    let outcome = Trainer::new(ctx.rt, cfg.clone()).run()?;

    let meta = ctx.rt.manifest.model(&outcome.model_key)?.clone();
    let q = meta.num_qlayers;
    let stripes = Stripes::default();

    // 2) Enumerate (small spaces) or sample (large) the design space.
    let max_enum = if ctx.scale == Scale::Full { 800 } else { 120 };
    let space: Vec<Vec<u32>> = if 7usize.pow(q as u32) <= max_enum {
        enumerate_assignments(q, 2, 8)
    } else {
        let mut rng = Rng::new(ctx.seed).split(0xFA4);
        let mut v = sample_assignments(q, 2, 8, max_enum, &mut rng);
        // Always include the homogeneous anchors.
        for b in 2..=8u32 {
            v.push(vec![b; q]);
        }
        v
    };

    // 3) Evaluate each assignment against the trained state.
    let eval_prog = format!("eval_quant_{model}");
    let test = test_batcher(&meta, if ctx.scale == Scale::Full { 512 } else { 256 }, ctx.seed)?;
    let mut points = Vec::with_capacity(space.len());
    for bits in &space {
        let assign = BitAssignment { bits: bits.clone(), alpha: vec![1.0; q] };
        let (_, acc) = evaluate(
            ctx.rt,
            &eval_prog,
            &meta,
            &outcome.state.params,
            Some(&assign.kw()),
            cfg.ka(),
            &test,
        )?;
        points.push(DesignPoint {
            bits: bits.clone(),
            compute: stripes.relative_compute(&meta, bits),
            accuracy: acc as f64,
        });
    }

    // 4) Frontier + locate the WaveQ solution.
    let frontier = pareto_frontier(&points);
    let waveq_point = DesignPoint {
        bits: outcome.assignment.bits.clone(),
        compute: stripes.relative_compute(&meta, &outcome.assignment.bits),
        accuracy: outcome.test_acc as f64,
    };
    let gap = accuracy_gap_to_frontier(&waveq_point, &points);

    save_csv(&points, &frontier, &ctx.out("fig4", &format!("{model}_space.csv")))?;
    let mut waveq_csv = String::from("compute,accuracy,bits\n");
    waveq_csv.push_str(&format!(
        "{},{},{}\n",
        waveq_point.compute,
        waveq_point.accuracy,
        waveq_point.bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join("-")
    ));
    ctx.write("fig4", &format!("{model}_waveq.csv"), &waveq_csv)?;

    Ok(vec![
        model.to_string(),
        points.len().to_string(),
        frontier.len().to_string(),
        format!("{:?}", outcome.assignment.bits),
        format!("{:.2}", outcome.assignment.average_bits()),
        format!("{:.3}", waveq_point.accuracy),
        format!("{:+.3}", gap),
    ])
}
