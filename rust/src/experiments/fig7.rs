//! Figure 7 — weight trajectories during from-scratch training:
//!   (I)   no regularizer: weights roam freely (reference),
//!   (II)  constant lambda_w: weights get stuck near initialization
//!         (the quantization objective dominates from step 0),
//!   (III) scheduled (exponential-ramp) lambda_w: weights explore first,
//!         then hop wave-to-wave onto the grid — the paper's §5 finding
//!         motivating the 3-phase schedule.
//!
//! 10 tracked weights from a quantized layer, for 3/4/5-bit presets.

use anyhow::Result;

use super::{print_table, ExpContext, Scale};
use crate::config::{Algo, RunConfig};
use crate::coordinator::{TrackKind, TrackRequest, TrainOptions, TrainOutcome, Trainer};

pub const BITS: &[u32] = &[3, 4, 5];
const N_TRACKED: usize = 10;

fn traj_csv(outcome: &TrainOutcome) -> String {
    let mut csv = String::from("step");
    for i in 0..N_TRACKED {
        csv.push_str(&format!(",w{i}"));
    }
    csv.push('\n');
    for snap in &outcome.snapshots {
        if let Some(ws) = &snap.weights {
            csv.push_str(&snap.step.to_string());
            for v in ws {
                csv.push_str(&format!(",{v}"));
            }
            csv.push('\n');
        }
    }
    csv
}

/// Total movement of tracked weights over the 2nd half of training
/// (stuck weights barely move; wave-hopping weights keep moving).
fn late_movement(outcome: &TrainOutcome) -> f64 {
    let snaps: Vec<&Vec<f32>> = outcome
        .snapshots
        .iter()
        .filter_map(|s| s.weights.as_ref())
        .collect();
    if snaps.len() < 4 {
        return 0.0;
    }
    let half = snaps.len() / 2;
    let mut total = 0.0;
    for w in half..snaps.len() - 1 {
        for i in 0..snaps[w].len().min(N_TRACKED) {
            total += (snaps[w + 1][i] - snaps[w][i]).abs() as f64;
        }
    }
    total
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "simplenet5";
    let steps = ctx.steps(150, 500);
    let mut rows = Vec::new();

    let make_cfg = |algo: Algo, bits: u32| {
        let mut cfg = RunConfig {
            model: model.to_string(),
            algo,
            weight_bits: bits,
            act_bits: 32,
            steps,
            train_examples: if ctx.scale == Scale::Full { 4096 } else { 1024 },
            test_examples: 512,
            seed: ctx.seed,
            ..Default::default()
        };
        cfg.schedule.total_steps = steps;
        cfg.schedule.lambda_w_max = 2.0;
        cfg
    };
    let meta = ctx.rt.manifest.model(model)?.clone();
    let target = meta.qlayer_param_indices()[0];
    let track = TrainOptions {
        track: vec![TrackRequest {
            param: target,
            every: (steps / 120).max(1),
            kind: TrackKind::Weights { count: N_TRACKED },
        }],
        ..Default::default()
    };

    // Row I — no regularizer (plain fp32 training).
    let out_noreg = Trainer::with_options(ctx.rt, make_cfg(Algo::Fp32, 4), track.clone()).run()?;
    ctx.write("fig7", "noreg.csv", &traj_csv(&out_noreg))?;

    for &bits in BITS {
        // Row II — constant lambda_w from step 0.
        let mut opts = track.clone();
        opts.constant_lambda_w = Some(2.0);
        let out_const =
            Trainer::with_options(ctx.rt, make_cfg(Algo::WaveqPreset, bits), opts).run()?;
        ctx.write("fig7", &format!("const_w{bits}.csv"), &traj_csv(&out_const))?;

        // Row III — scheduled (exponential ramp) lambda_w.
        let out_sched =
            Trainer::with_options(ctx.rt, make_cfg(Algo::WaveqPreset, bits), track.clone()).run()?;
        ctx.write("fig7", &format!("sched_w{bits}.csv"), &traj_csv(&out_sched))?;

        rows.push(vec![
            format!("{bits}"),
            format!("{:.3}", late_movement(&out_const)),
            format!("{:.3}", late_movement(&out_sched)),
            format!("{:.2}", 100.0 * out_const.test_acc),
            format!("{:.2}", 100.0 * out_sched.test_acc),
        ]);
    }
    rows.push(vec![
        "fp32(ref)".into(),
        String::new(),
        format!("{:.3}", late_movement(&out_noreg)),
        String::new(),
        format!("{:.2}", 100.0 * out_noreg.test_acc),
    ]);
    print_table(
        "Figure 7 — weight trajectories: constant vs scheduled lambda_w",
        &["bits", "late movement (const)", "late movement (sched)", "acc const %", "acc sched %"],
        &rows,
    );
    println!("fig7: scheduled runs should show BOTH more late movement than constant-lambda runs\n      (wave hopping) and higher accuracy; trajectories in results/fig7/*.csv");
    Ok(())
}
