//! Property-testing mini-framework (proptest is not resolvable offline).
//!
//! Seeded case generation on top of `util::rng::Rng`: run a property over N
//! random cases; on failure report the case index + seed so the exact case
//! reproduces with `WAVEQ_PROP_SEED`. No shrinking — cases are kept small
//! by construction instead.

use crate::util::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        let seed = std::env::var("WAVEQ_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        PropConfig { cases: 64, seed }
    }
}

/// Run `prop` over `cfg.cases` random cases. `gen` builds a case from an Rng.
/// Panics with the seed + case index on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: &PropConfig,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {}):\n  input: {input:?}\n  {msg}",
                cfg.seed
            );
        }
    }
}

// ---- common generators -------------------------------------------------------

pub fn gen_f32_vec(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
    let n = 1 + rng.below_usize(max_len);
    (0..n).map(|_| rng.normal_f32() * scale).collect()
}

pub fn gen_bits(rng: &mut Rng) -> u32 {
    2 + rng.below(7) as u32 // [2, 8]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "abs is non-negative",
            &PropConfig { cases: 32, ..Default::default() },
            |r| r.normal_f32(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check(
            "always fails",
            &PropConfig { cases: 4, ..Default::default() },
            |r| r.next_u64(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn generators_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let b = gen_bits(&mut rng);
            assert!((2..=8).contains(&b));
            let v = gen_f32_vec(&mut rng, 50, 1.0);
            assert!(!v.is_empty() && v.len() <= 50);
        }
    }
}
