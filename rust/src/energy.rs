//! Stripes bit-serial accelerator model (Judd et al., MICRO 2016) — the
//! substrate behind the paper's Table-1 "Energy Saving" column and the
//! §4.2 claim that reduced bitwidths cut execution energy by ~77.5%.
//!
//! Stripes' defining property: compute is *bit-serial over weights per
//! Serial Inner Product unit*, so cycles and dynamic compute energy for a
//! layer scale ~linearly with that layer's weight bitwidth, while the
//! baseline (bit-parallel, e.g. 16-bit DaDianNao-style) pays the full width
//! regardless. We model, per layer:
//!
//!   cycles(l)      = macs(l) * bits(l) / (TILES * SIPS_PER_TILE * 16)
//!   E_compute(l)   = macs(l) * bits(l) * E_MAC_BIT
//!   E_weights(l)   = count(l) * bits(l) * E_SB_BIT       (on-chip SB traffic)
//!   E_acts(l)      = act_traffic(l) * ACT_BITS * E_NB_BIT (NB traffic)
//!   E_static       = cycles * P_STATIC
//!
//! The constants are normalized (relative energy units): the *ratios*
//! between configurations — which is all the paper reports (2.08x / 1.24x /
//! 1.78x and the 77.5% average) — depend only on the bit-scaling law this
//! model reproduces, not on the absolute fJ numbers of the authors' 65nm
//! library.

use crate::runtime::ModelMeta;

/// Energy/latency constants (normalized units per bit / per cycle).
#[derive(Debug, Clone)]
pub struct StripesCfg {
    /// Energy per MAC-bit (serial compute).
    pub e_mac_bit: f64,
    /// Energy per weight-bit moved through the synapse buffer.
    pub e_sb_bit: f64,
    /// Energy per activation-bit through the neuron buffers.
    pub e_nb_bit: f64,
    /// Static power per cycle (leakage + clock).
    pub p_static: f64,
    /// Parallel lanes: tiles x SIPs x 16-wide windows.
    pub lanes: f64,
    /// Baseline bit-parallel datapath width (DaDianNao-style comparator).
    pub baseline_bits: u32,
}

impl Default for StripesCfg {
    fn default() -> Self {
        StripesCfg {
            e_mac_bit: 1.0,
            e_sb_bit: 0.15,
            e_nb_bit: 0.10,
            p_static: 64.0,
            lanes: 4096.0,
            baseline_bits: 16,
        }
    }
}

#[derive(Debug, Clone)]
pub struct LayerEnergy {
    pub name: String,
    pub bits: u32,
    pub macs: u64,
    pub weights: u64,
    pub cycles: f64,
    pub energy: f64,
}

#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub layers: Vec<LayerEnergy>,
    pub total_cycles: f64,
    pub total_energy: f64,
}

pub struct Stripes {
    pub cfg: StripesCfg,
}

impl Default for Stripes {
    fn default() -> Self {
        Stripes { cfg: StripesCfg::default() }
    }
}

impl Stripes {
    pub fn new(cfg: StripesCfg) -> Stripes {
        Stripes { cfg }
    }

    fn layer(&self, name: &str, macs: u64, weights: u64, bits: u32, act_bits: u32) -> LayerEnergy {
        let b = bits as f64;
        let cycles = macs as f64 * b / self.cfg.lanes;
        // Activation traffic approximated by MACs / 16 (window reuse).
        let act_traffic = macs as f64 / 16.0;
        let energy = macs as f64 * b * self.cfg.e_mac_bit
            + weights as f64 * b * self.cfg.e_sb_bit
            + act_traffic * act_bits as f64 * self.cfg.e_nb_bit
            + cycles * self.cfg.p_static;
        LayerEnergy { name: name.to_string(), bits, macs, weights, cycles, energy }
    }

    /// Evaluate a model under a per-quant-layer bitwidth assignment.
    /// Non-quantized compute layers (first/last) run at `fallback_bits`.
    pub fn evaluate(
        &self,
        model: &ModelMeta,
        qbits: &[u32],
        act_bits: u32,
        fallback_bits: u32,
    ) -> EnergyReport {
        assert_eq!(qbits.len(), model.num_qlayers, "bitwidth vector length");
        let mut layers = Vec::new();
        for p in &model.params {
            if p.macs == 0 {
                continue; // affine/bias: negligible
            }
            let bits = match p.qidx {
                Some(q) => qbits[q],
                None => fallback_bits,
            };
            layers.push(self.layer(&p.name, p.macs, p.count, bits, act_bits));
        }
        let total_cycles = layers.iter().map(|l| l.cycles).sum();
        let total_energy = layers.iter().map(|l| l.energy).sum();
        EnergyReport { layers, total_cycles, total_energy }
    }

    /// Homogeneous-assignment convenience.
    pub fn evaluate_homogeneous(
        &self,
        model: &ModelMeta,
        bits: u32,
        act_bits: u32,
    ) -> EnergyReport {
        let qbits = vec![bits; model.num_qlayers];
        self.evaluate(model, &qbits, act_bits, self.cfg.baseline_bits.min(8))
    }

    /// Energy saving factor vs the bit-parallel baseline width
    /// (the paper's Table-1 "Energy Saving" column).
    pub fn saving_vs_baseline(&self, model: &ModelMeta, qbits: &[u32], act_bits: u32) -> f64 {
        let base = self.evaluate(
            model,
            &vec![self.cfg.baseline_bits; model.num_qlayers],
            self.cfg.baseline_bits,
            self.cfg.baseline_bits,
        );
        let ours = self.evaluate(model, qbits, act_bits, 8);
        base.total_energy / ours.total_energy
    }

    /// Average compute (MAC*bits) relative to 8-bit homogeneous — the
    /// x-axis of the Figure-4 Pareto plots ("computation").
    pub fn relative_compute(&self, model: &ModelMeta, qbits: &[u32]) -> f64 {
        let stats = model.qlayer_stats();
        let num: f64 = stats
            .iter()
            .zip(qbits)
            .map(|((macs, _), &b)| *macs as f64 * b as f64)
            .sum();
        let den: f64 = stats.iter().map(|(macs, _)| *macs as f64 * 8.0).sum();
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ModelMeta, ParamMeta};

    fn toy_model() -> ModelMeta {
        ModelMeta {
            name: "toy".into(),
            dataset: String::new(),
            input_shape: [8, 8, 3],
            num_classes: 10,
            batch: 16,
            width_mult: 1,
            num_qlayers: 2,
            params: vec![
                ParamMeta {
                    name: "conv1".into(),
                    shape: vec![3, 3, 3, 8],
                    kind: "conv".into(),
                    init: "he".into(),
                    qidx: None,
                    macs: 110_592,
                    count: 216,
                },
                ParamMeta {
                    name: "conv2".into(),
                    shape: vec![3, 3, 8, 8],
                    kind: "conv".into(),
                    init: "he".into(),
                    qidx: Some(0),
                    macs: 294_912,
                    count: 576,
                },
                ParamMeta {
                    name: "fc".into(),
                    shape: vec![512, 10],
                    kind: "fc".into(),
                    init: "he".into(),
                    qidx: Some(1),
                    macs: 5_120,
                    count: 5_120,
                },
                ParamMeta {
                    name: "affine_s".into(),
                    shape: vec![8],
                    kind: "affine".into(),
                    init: "ones".into(),
                    qidx: None,
                    macs: 0,
                    count: 8,
                },
            ],
        }
    }

    #[test]
    fn energy_monotone_in_bits() {
        let s = Stripes::default();
        let m = toy_model();
        let e3 = s.evaluate(&m, &[3, 3], 8, 8).total_energy;
        let e5 = s.evaluate(&m, &[5, 5], 8, 8).total_energy;
        let e8 = s.evaluate(&m, &[8, 8], 8, 8).total_energy;
        assert!(e3 < e5 && e5 < e8, "{e3} {e5} {e8}");
    }

    #[test]
    fn cycles_scale_linearly_with_bits() {
        let s = Stripes::default();
        let m = toy_model();
        let c2 = s.evaluate(&m, &[2, 2], 8, 8);
        let c4 = s.evaluate(&m, &[4, 4], 8, 8);
        // Only quantized layers double; conv1 (fallback) is unchanged.
        let q2: f64 = c2.layers.iter().filter(|l| l.name != "conv1").map(|l| l.cycles).sum();
        let q4: f64 = c4.layers.iter().filter(|l| l.name != "conv1").map(|l| l.cycles).sum();
        assert!((q4 / q2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn saving_vs_baseline_in_plausible_range() {
        let s = Stripes::default();
        let m = toy_model();
        let x = s.saving_vs_baseline(&m, &[4, 4], 4);
        // 16-bit baseline vs ~4-bit: expect a multiple-x saving, bounded by 16/4.
        assert!(x > 1.5 && x < 5.0, "saving {x}");
    }

    #[test]
    fn lower_avg_bits_always_saves_more() {
        let s = Stripes::default();
        let m = toy_model();
        let hi = s.saving_vs_baseline(&m, &[3, 3], 4);
        let lo = s.saving_vs_baseline(&m, &[6, 6], 4);
        assert!(hi > lo);
    }

    #[test]
    fn relative_compute_weighted_by_macs() {
        let s = Stripes::default();
        let m = toy_model();
        assert!((s.relative_compute(&m, &[8, 8]) - 1.0).abs() < 1e-12);
        let half = s.relative_compute(&m, &[4, 4]);
        assert!((half - 0.5).abs() < 1e-12);
        // conv2 dominates MACs: lowering fc's bits barely moves the needle.
        let fc_only = s.relative_compute(&m, &[8, 2]);
        assert!(fc_only > 0.95);
    }

    #[test]
    fn affine_layers_do_not_contribute() {
        let s = Stripes::default();
        let m = toy_model();
        let r = s.evaluate(&m, &[4, 4], 8, 8);
        assert_eq!(r.layers.len(), 3); // conv1, conv2, fc — no affine
    }
}
