//! Synthetic dataset family standing in for CIFAR-10 / SVHN / ImageNet
//! (DESIGN.md §2: the paper's claims are about *relative* behaviour of
//! quantized-training methods, which a controllable synthetic task
//! exercises; no real datasets are reachable in this offline environment).
//!
//! Each class owns a deterministic prototype built from a class-seeded mix
//! of 2-D sinusoidal gratings plus a Gaussian-blob constellation. An
//! instance is its class prototype under a random shift/amplitude jitter
//! plus pixel noise. Difficulty is controlled by (noise, jitter): enough
//! that fp32 nets don't saturate instantly and low-bit quantization visibly
//! costs accuracy — the regime Table 1/2 lives in.
//!
//! Everything is seeded: the same (name, seed) always produces bit-identical
//! data, so experiments are reproducible and train/test never overlap
//! (disjoint instance streams).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub n_classes: usize,
    pub noise: f32,
    pub jitter: f32,
    pub gratings: usize,
    pub blobs: usize,
    /// Class separability in (0, 1]: every instance is a blend of a pattern
    /// shared by ALL classes (weight 1 - sep) and its class-specific pattern
    /// (weight sep). Small sep => confusable classes => fine decision
    /// boundaries => weight/activation precision matters, which is the
    /// regime where the paper's low-bit accuracy gaps live.
    pub class_sep: f32,
}

/// Lite counterparts of the paper's datasets. Difficulty (noise, class_sep)
/// is calibrated so fp32 lands in the high-80s/90s while 2-3-bit plain
/// quantized training visibly degrades — matching the paper's Table-1/2 regime.
pub fn spec(name: &str) -> DatasetSpec {
    try_spec(name).unwrap_or_else(|| panic!("unknown dataset '{name}'"))
}

/// Fallible [`spec`]: `None` for names this module doesn't know.
pub fn try_spec(name: &str) -> Option<DatasetSpec> {
    Some(match name {
        "mlp-lite" => DatasetSpec {
            name: name.into(), h: 8, w: 8, c: 3, n_classes: 10,
            noise: 0.55, jitter: 1.0, gratings: 3, blobs: 1, class_sep: 0.6,
        },
        "cifar-lite" => DatasetSpec {
            name: name.into(), h: 16, w: 16, c: 3, n_classes: 10,
            noise: 0.8, jitter: 2.0, gratings: 4, blobs: 2, class_sep: 0.48,
        },
        "svhn-lite" => DatasetSpec {
            // digit-ish: high-contrast strokes (blobs dominate), clutter noise
            name: name.into(), h: 16, w: 16, c: 3, n_classes: 10,
            noise: 0.75, jitter: 1.5, gratings: 2, blobs: 4, class_sep: 0.5,
        },
        "imagenet-lite" => DatasetSpec {
            name: name.into(), h: 24, w: 24, c: 3, n_classes: 20,
            noise: 0.7, jitter: 2.0, gratings: 5, blobs: 3, class_sep: 0.62,
        },
        _ => return None,
    })
}

/// Dataset for a model, selected by the dataset *name* its manifest
/// metadata declares. Shape-based inference alone cannot work here:
/// cifar-lite and svhn-lite are both 16x16x3/10-way, so dispatching on
/// input shape silently trained `svhn8` on cifar-lite and left svhn-lite
/// dead. Falls back to [`spec_for_input`] for models that declare no
/// dataset (older manifests, custom shapes).
pub fn spec_for_model(meta: &crate::runtime::ModelMeta) -> DatasetSpec {
    try_spec(&meta.dataset)
        .unwrap_or_else(|| spec_for_input(meta.input_shape, meta.num_classes))
}

/// Dataset for a bare input shape (no manifest metadata). Ambiguous shapes
/// resolve to their most common owner (16x16x3 -> cifar-lite); prefer
/// [`spec_for_model`] whenever a `ModelMeta` is available.
pub fn spec_for_input(input: [usize; 3], n_classes: usize) -> DatasetSpec {
    match (input, n_classes) {
        ([8, 8, 3], 10) => spec("mlp-lite"),
        ([16, 16, 3], 10) => spec("cifar-lite"),
        ([24, 24, 3], 20) => spec("imagenet-lite"),
        _ => DatasetSpec {
            name: format!("custom-{}x{}x{}", input[0], input[1], input[2]),
            h: input[0], w: input[1], c: input[2], n_classes,
            noise: 0.6, jitter: 2.0, gratings: 4, blobs: 2, class_sep: 0.5,
        },
    }
}

struct Grating {
    fx: f32,
    fy: f32,
    phase: [f32; 3],
    amp: f32,
}

struct Blob {
    cx: f32,
    cy: f32,
    sigma: f32,
    amp: [f32; 3],
}

struct ClassProto {
    gratings: Vec<Grating>,
    blobs: Vec<Blob>,
}

/// Deterministic generator over (images, labels).
pub struct Generator {
    pub spec: DatasetSpec,
    protos: Vec<ClassProto>,
    rng: Rng,
}

impl Generator {
    /// `stream` separates train (0) from test (1) so they never overlap.
    /// Proto index 0 is the class-shared component; class c uses proto c+1.
    pub fn new(spec: DatasetSpec, seed: u64, stream: u64) -> Generator {
        let proto_rng = Rng::new(seed).split(0xDA7A);
        let mut protos = Vec::with_capacity(spec.n_classes + 1);
        for class in 0..spec.n_classes + 1 {
            let mut r = proto_rng.split(class as u64);
            let gratings = (0..spec.gratings)
                .map(|_| Grating {
                    fx: r.range_f64(0.3, 1.6) as f32 * if r.uniform() < 0.5 { -1.0 } else { 1.0 },
                    fy: r.range_f64(0.3, 1.6) as f32,
                    phase: [
                        r.range_f64(0.0, 6.28) as f32,
                        r.range_f64(0.0, 6.28) as f32,
                        r.range_f64(0.0, 6.28) as f32,
                    ],
                    amp: r.range_f64(0.4, 1.0) as f32,
                })
                .collect();
            let blobs = (0..spec.blobs)
                .map(|_| Blob {
                    cx: r.range_f64(0.2, 0.8) as f32,
                    cy: r.range_f64(0.2, 0.8) as f32,
                    sigma: r.range_f64(0.08, 0.2) as f32,
                    amp: [
                        r.range_f64(-1.5, 1.5) as f32,
                        r.range_f64(-1.5, 1.5) as f32,
                        r.range_f64(-1.5, 1.5) as f32,
                    ],
                })
                .collect();
            protos.push(ClassProto { gratings, blobs });
        }
        let rng = Rng::new(seed).split(0xBEEF ^ stream.wrapping_mul(0x9E37));
        Generator { spec, protos, rng }
    }

    /// Write one instance of `class` into `out` (len h*w*c, HWC layout).
    pub fn render(&mut self, class: usize, out: &mut [f32]) {
        let s = &self.spec;
        debug_assert_eq!(out.len(), s.h * s.w * s.c);
        let sep = s.class_sep.clamp(0.01, 1.0);
        let di = self.rng.range_f64(-s.jitter as f64, s.jitter as f64) as f32;
        let dj = self.rng.range_f64(-s.jitter as f64, s.jitter as f64) as f32;
        let gain = 0.8 + 0.4 * self.rng.uniform_f32();
        let norm = 1.0 / (s.gratings as f32).sqrt();
        for i in 0..s.h {
            for j in 0..s.w {
                let fi = i as f32 + di;
                let fj = j as f32 + dj;
                for ch in 0..s.c {
                    // Blend the shared proto (index 0) with the class proto.
                    let shared = self.proto_value(0, fi, fj, ch);
                    let own = self.proto_value(class + 1, fi, fj, ch);
                    let mut v = ((1.0 - sep) * shared + sep * own) * gain * norm;
                    v += s.noise * self.rng.normal_f32();
                    out[(i * s.w + j) * s.c + ch] = v;
                }
            }
        }
    }

    fn proto_value(&self, proto: usize, fi: f32, fj: f32, ch: usize) -> f32 {
        let s = &self.spec;
        let p = &self.protos[proto];
        let mut v = 0.0f32;
        for g in &p.gratings {
            v += g.amp * (g.fx * fi + g.fy * fj + g.phase[ch % 3]).sin();
        }
        for b in &p.blobs {
            let dx = fi / s.h as f32 - b.cx;
            let dy = fj / s.w as f32 - b.cy;
            let d2 = dx * dx + dy * dy;
            v += b.amp[ch % 3] * (-d2 / (2.0 * b.sigma * b.sigma)).exp();
        }
        v
    }

    /// Generate `n` (image, label) pairs; labels are balanced round-robin
    /// with a shuffled order per call.
    pub fn batch(&mut self, n: usize) -> (Vec<f32>, Vec<u8>) {
        let s = &self.spec;
        let pix = s.h * s.w * s.c;
        let mut images = vec![0.0f32; n * pix];
        let mut labels = vec![0u8; n];
        for idx in 0..n {
            let class = self.rng.below_usize(self.spec.n_classes);
            labels[idx] = class as u8;
            let start = idx * pix;
            let spec_clone_end = start + pix;
            // Split borrow: render needs &mut self and the slice.
            let mut tmp = vec![0.0f32; pix];
            self.render(class, &mut tmp);
            images[start..spec_clone_end].copy_from_slice(&tmp);
        }
        (images, labels)
    }
}

/// A fully-materialized dataset (fixed train or test split).
pub struct Dataset {
    pub spec: DatasetSpec,
    pub n: usize,
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn generate(spec: DatasetSpec, n: usize, seed: u64, stream: u64) -> Dataset {
        let mut gen = Generator::new(spec.clone(), seed, stream);
        let (images, labels) = gen.batch(n);
        Dataset { spec, n, images, labels }
    }

    pub fn pixels(&self) -> usize {
        self.spec.h * self.spec.w * self.spec.c
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let p = self.pixels();
        &self.images[i * p..(i + 1) * p]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::generate(spec("cifar-lite"), 16, 7, 0);
        let b = Dataset::generate(spec("cifar-lite"), 16, 7, 0);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn train_test_streams_differ() {
        let a = Dataset::generate(spec("cifar-lite"), 16, 7, 0);
        let b = Dataset::generate(spec("cifar-lite"), 16, 7, 1);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn labels_in_range_and_roughly_balanced() {
        let d = Dataset::generate(spec("cifar-lite"), 2000, 3, 0);
        let mut counts = vec![0usize; d.spec.n_classes];
        for &l in &d.labels {
            assert!((l as usize) < d.spec.n_classes);
            counts[l as usize] += 1;
        }
        for c in counts {
            assert!(c > 120 && c < 280, "class count {c}");
        }
    }

    #[test]
    fn images_are_normalized_ish() {
        let d = Dataset::generate(spec("cifar-lite"), 256, 3, 0);
        let t = crate::tensor::Tensor::new(vec![d.images.len()], d.images.clone()).unwrap();
        assert!(t.all_finite());
        assert!(t.mean().abs() < 0.5, "mean {}", t.mean());
        assert!(t.std() > 0.3 && t.std() < 3.0, "std {}", t.std());
    }

    #[test]
    fn classes_are_distinguishable() {
        // Same-class instances must be closer (on average) than cross-class.
        let sp = spec("cifar-lite");
        let mut gen = Generator::new(sp.clone(), 11, 0);
        let pix = sp.h * sp.w * sp.c;
        let mut c0a = vec![0.0; pix];
        let mut c0b = vec![0.0; pix];
        let mut c1 = vec![0.0; pix];
        gen.render(0, &mut c0a);
        gen.render(0, &mut c0b);
        gen.render(1, &mut c1);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
        };
        assert!(dist(&c0a, &c0b) < dist(&c0a, &c1));
    }

    #[test]
    fn spec_for_input_matches_known_shapes() {
        assert_eq!(spec_for_input([16, 16, 3], 10).name, "cifar-lite");
        assert_eq!(spec_for_input([24, 24, 3], 20).name, "imagenet-lite");
        let custom = spec_for_input([12, 12, 1], 4);
        assert_eq!(custom.n_classes, 4);
    }

    #[test]
    fn spec_for_model_dispatches_by_name_with_shape_fallback() {
        let mut meta = crate::runtime::ModelMeta {
            name: "svhn8".into(),
            dataset: "svhn-lite".into(),
            input_shape: [16, 16, 3],
            num_classes: 10,
            batch: 32,
            width_mult: 1,
            num_qlayers: 0,
            params: vec![],
        };
        // Regression (svhn-lite dead-code bug): the name must win over the
        // shape, which would resolve to cifar-lite.
        assert_eq!(spec_for_model(&meta).name, "svhn-lite");
        // No declared dataset -> shape fallback.
        meta.dataset = String::new();
        assert_eq!(spec_for_model(&meta).name, "cifar-lite");
        // Unknown declared name -> shape fallback, not a panic.
        meta.dataset = "cifar100-lite".into();
        meta.input_shape = [12, 12, 1];
        meta.num_classes = 4;
        assert_eq!(spec_for_model(&meta).name, "custom-12x12x1");
    }
}
