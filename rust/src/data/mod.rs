//! Synthetic data substrate: dataset family (`synth`) + batching/prefetch
//! pipeline (`batcher`). See DESIGN.md §2 for the dataset substitutions.

pub mod batcher;
pub mod synth;

pub use batcher::{shard_for, Batch, Batcher, Prefetcher, SequentialBatches};
pub use synth::{spec, spec_for_input, spec_for_model, try_spec, Dataset, DatasetSpec, Generator};
