//! Batching + background prefetch pipeline (std::thread + mpsc; tokio is
//! not resolvable offline, and the coordinator's loop is synchronous anyway).
//!
//! The trainer consumes `(x, y_onehot)` host buffers shaped for the AOT
//! program; generation (epoch shuffling, one-hot encoding) happens on a
//! producer thread so data prep overlaps XLA execution — the same overlap
//! a tf.data/DataLoader pipeline provides.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::synth::Dataset;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Batch {
    /// (B, H, W, C) flattened.
    pub x: Vec<f32>,
    /// (B, n_classes) one-hot flattened.
    pub y: Vec<f32>,
    pub epoch: usize,
}

impl Batch {
    /// Borrow rows `lo..hi` of the batch as `(x, y)` row slices, given the
    /// per-row element counts. The distributed coordinator hands each
    /// worker such a view of the shared global batch; concatenating every
    /// worker's view in shard order reconstructs `(self.x, self.y)`
    /// exactly, which is what makes the N-worker run consume the same
    /// bytes as the 1-worker run.
    pub fn rows(&self, lo: usize, hi: usize, pix: usize, n_classes: usize) -> (&[f32], &[f32]) {
        (&self.x[lo * pix..hi * pix], &self.y[lo * n_classes..hi * n_classes])
    }
}

/// The deterministic shard map shared by the distributed coordinator and
/// its tests: which reduction chunks (of the fixed `n_chunks`-chunk batch
/// grid) the live worker at `position` owns in `round`.
///
/// Properties the coordinator relies on:
/// - For a fixed `(round, n_live)`, the ranges over `position = 0..n_live`
///   partition `0..n_chunks` exactly — every chunk is computed once.
/// - The assignment *rotates* with `round`, so over `n_live` consecutive
///   rounds each worker visits every slot (exercising all data shards).
/// - It is a pure function of its arguments: the coordinator and a test
///   (or a rejoining worker) always agree on who owns what without any
///   negotiation.
///
/// Chunks — not raw rows — are the sharding unit so that the per-chunk
/// gradients a worker produces are bitwise the ones the single-process
/// fused path computes for the same global batch (see
/// `runtime::native::kernels::chunk_rows`); `n_chunks` is passed in rather
/// than imported to keep this crate layer free of runtime dependencies.
pub fn shard_for(
    round: usize,
    position: usize,
    n_live: usize,
    n_chunks: usize,
) -> std::ops::Range<usize> {
    assert!(position < n_live, "worker position {position} out of {n_live}");
    let slot = (position + round) % n_live;
    slot * n_chunks / n_live..(slot + 1) * n_chunks / n_live
}

/// Synchronous batcher: deterministic epoch shuffles over a fixed dataset.
pub struct Batcher {
    ds: Dataset,
    batch: usize,
    order: Vec<u32>,
    cursor: usize,
    pub epoch: usize,
    rng: Rng,
}

impl Batcher {
    /// Errors (instead of panicking) when `batch` is 0 or exceeds the
    /// dataset — both are reachable from user-supplied CLI flags
    /// (`--batch`, `--test-examples`, `--train-examples`).
    pub fn new(ds: Dataset, batch: usize, seed: u64) -> Result<Batcher> {
        if batch == 0 {
            return Err(anyhow!("batch size must be >= 1"));
        }
        if batch > ds.n {
            return Err(anyhow!(
                "batch {} exceeds the dataset's {} examples",
                batch,
                ds.n
            ));
        }
        let mut b = Batcher {
            order: (0..ds.n as u32).collect(),
            ds,
            batch,
            cursor: 0,
            epoch: 0,
            rng: Rng::new(seed).split(0xBA7C),
        };
        b.rng.shuffle(&mut b.order);
        Ok(b)
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.ds.n / self.batch
    }

    /// Next batch; reshuffles at epoch boundaries (drop-last semantics).
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch > self.ds.n {
            self.cursor = 0;
            self.epoch += 1;
            self.rng.shuffle(&mut self.order);
        }
        let pix = self.ds.pixels();
        let ncls = self.ds.spec.n_classes;
        let mut x = vec![0.0f32; self.batch * pix];
        let mut y = vec![0.0f32; self.batch * ncls];
        for bi in 0..self.batch {
            let idx = self.order[self.cursor + bi] as usize;
            x[bi * pix..(bi + 1) * pix].copy_from_slice(self.ds.image(idx));
            y[bi * ncls + self.ds.labels[idx] as usize] = 1.0;
        }
        self.cursor += self.batch;
        Batch { x, y, epoch: self.epoch }
    }

    /// All full batches of the dataset in index order (drop-last), as a
    /// *lazy* iterator: each [`Batch`] is materialized only when the
    /// consumer asks for it, so streaming evaluations peak at one batch of
    /// f32 copies instead of the whole held-out set.
    pub fn sequential_batches(&self) -> SequentialBatches<'_> {
        let full = (self.ds.n / self.batch) * self.batch;
        SequentialBatches { batcher: self, n: full, start: 0 }
    }

    /// Every batch of the dataset in index order, *including* the final
    /// ragged batch when the dataset size is not a batch multiple — the
    /// batch-polymorphic evaluation paths serve the tail at its true size
    /// so reported metrics cover every held-out example. Lazy, like
    /// [`Batcher::sequential_batches`].
    pub fn sequential_batches_all(&self) -> SequentialBatches<'_> {
        SequentialBatches { batcher: self, n: self.ds.n, start: 0 }
    }
}

/// Lazy iterator over a dataset's batches in index order (see
/// [`Batcher::sequential_batches`]). Yields the same batches, in the same
/// order, with the same contents as the eager `Vec<Batch>` it replaced —
/// consumers that fold over it reproduce the old results bit-for-bit —
/// but holds only the one live batch in memory.
pub struct SequentialBatches<'a> {
    batcher: &'a Batcher,
    /// Total rows to serve (`ds.n` rounded down to a batch multiple for
    /// drop-last, `ds.n` itself when the ragged tail is included).
    n: usize,
    start: usize,
}

impl Iterator for SequentialBatches<'_> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.start >= self.n {
            return None;
        }
        let ds = &self.batcher.ds;
        let pix = ds.pixels();
        let ncls = ds.spec.n_classes;
        let rows = self.batcher.batch.min(self.n - self.start);
        let mut x = vec![0.0f32; rows * pix];
        let mut y = vec![0.0f32; rows * ncls];
        for bi in 0..rows {
            let idx = self.start + bi;
            x[bi * pix..(bi + 1) * pix].copy_from_slice(ds.image(idx));
            y[bi * ncls + ds.labels[idx] as usize] = 1.0;
        }
        self.start += rows;
        Some(Batch { x, y, epoch: 0 })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = (self.n - self.start).div_ceil(self.batcher.batch.max(1));
        (left, Some(left))
    }
}

impl ExactSizeIterator for SequentialBatches<'_> {}

/// Background prefetcher: producer thread + bounded channel.
pub struct Prefetcher {
    rx: Receiver<Batch>,
    /// Taken (joined) once the channel closes, so a producer panic is
    /// surfaced instead of masquerading as an early end-of-stream.
    handle: Option<JoinHandle<()>>,
    /// Sticky panic message: once the producer is known to have died, every
    /// later `next` keeps erroring instead of reporting a clean end.
    failed: Option<String>,
}

impl Prefetcher {
    /// `depth` = number of batches buffered ahead of the consumer.
    pub fn spawn(mut batcher: Batcher, depth: usize, total_batches: usize) -> Prefetcher {
        Self::spawn_source(move || batcher.next_batch(), depth, total_batches)
    }

    /// Generic producer (tests inject failing sources through this).
    pub fn spawn_source(
        mut source: impl FnMut() -> Batch + Send + 'static,
        depth: usize,
        total_batches: usize,
    ) -> Prefetcher {
        let (tx, rx) = sync_channel(depth);
        let handle = std::thread::spawn(move || {
            for _ in 0..total_batches {
                if tx.send(source()).is_err() {
                    return; // consumer dropped early
                }
            }
        });
        Prefetcher { rx, handle: Some(handle), failed: None }
    }

    /// Next batch; `Ok(None)` once the producer delivered everything. If
    /// the producer thread *panicked*, the panic message is propagated as
    /// an error rather than a silent early end-of-stream — and stays an
    /// error on every later call (a retrying caller must not mistake the
    /// truncated stream for a clean end).
    pub fn next(&mut self) -> Result<Option<Batch>> {
        if let Some(msg) = &self.failed {
            return Err(anyhow!("data producer thread panicked: {msg}"));
        }
        match self.rx.recv() {
            Ok(b) => Ok(Some(b)),
            Err(_) => match self.handle.take() {
                Some(h) => match h.join() {
                    Ok(()) => Ok(None),
                    Err(payload) => {
                        let msg = payload
                            .downcast_ref::<String>()
                            .map(String::as_str)
                            .or_else(|| payload.downcast_ref::<&'static str>().copied())
                            .unwrap_or("(non-string panic payload)")
                            .to_string();
                        let err = anyhow!("data producer thread panicked: {msg}");
                        self.failed = Some(msg);
                        Err(err)
                    }
                },
                None => Ok(None), // already joined on a previous call
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::synth::{spec, Dataset};
    use super::*;

    fn small_ds() -> Dataset {
        Dataset::generate(spec("mlp-lite"), 64, 1, 0)
    }

    #[test]
    fn batches_have_valid_onehots() {
        let mut b = Batcher::new(small_ds(), 16, 0).unwrap();
        for _ in 0..8 {
            let batch = b.next_batch();
            assert_eq!(batch.y.len(), 16 * 10);
            for r in 0..16 {
                let row = &batch.y[r * 10..(r + 1) * 10];
                assert_eq!(row.iter().sum::<f32>(), 1.0);
                assert!(row.iter().all(|&v| v == 0.0 || v == 1.0));
            }
        }
    }

    #[test]
    fn epoch_covers_every_sample_once() {
        let ds = small_ds();
        let n = ds.n;
        let mut b = Batcher::new(ds, 16, 0).unwrap();
        let mut seen = vec![0usize; n];
        for _ in 0..b.batches_per_epoch() {
            let start = b.cursor;
            let _ = b.next_batch();
            for i in start..start + 16 {
                seen[b.order[i] as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<f32> = {
            let mut b = Batcher::new(small_ds(), 16, 9).unwrap();
            b.next_batch().x
        };
        let c: Vec<f32> = {
            let mut b = Batcher::new(small_ds(), 16, 9).unwrap();
            b.next_batch().x
        };
        assert_eq!(a, c);
    }

    #[test]
    fn prefetcher_delivers_all_batches() {
        let b = Batcher::new(small_ds(), 16, 0).unwrap();
        let mut pf = Prefetcher::spawn(b, 2, 10);
        let mut count = 0;
        while let Some(batch) = pf.next().unwrap() {
            assert_eq!(batch.x.len(), 16 * 8 * 8 * 3);
            count += 1;
        }
        assert_eq!(count, 10);
        // Polling past the end keeps returning a clean None.
        assert!(pf.next().unwrap().is_none());
    }

    #[test]
    fn prefetcher_surfaces_producer_panics() {
        // A source that dies mid-stream: the delivered batches arrive, then
        // `next` must report the panic message instead of a silent end.
        let mut calls = 0usize;
        let mut src_batcher = Batcher::new(small_ds(), 16, 0).unwrap();
        let mut pf = Prefetcher::spawn_source(
            move || {
                calls += 1;
                if calls > 2 {
                    panic!("synthetic producer failure at batch {calls}");
                }
                src_batcher.next_batch()
            },
            1,
            10,
        );
        assert!(pf.next().unwrap().is_some());
        assert!(pf.next().unwrap().is_some());
        let err = pf.next().unwrap_err().to_string();
        assert!(
            err.contains("producer thread panicked") && err.contains("synthetic producer failure"),
            "unexpected error: {err}"
        );
        // The failure is sticky: polling again must NOT look like a clean end.
        let again = pf.next().unwrap_err().to_string();
        assert!(again.contains("producer thread panicked"), "{again}");
    }

    #[test]
    fn oversized_or_zero_batch_is_a_clean_error() {
        // 64-example dataset: batch 65 must error, not abort the process
        // (reachable from `waveq infer --batch N --test-examples M`, N > M).
        let err = Batcher::new(small_ds(), 65, 0).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "unexpected error: {err}");
        let err = Batcher::new(small_ds(), 0, 0).unwrap_err().to_string();
        assert!(err.contains(">= 1"), "unexpected error: {err}");
        assert!(Batcher::new(small_ds(), 64, 0).is_ok(), "batch == n is fine");
    }

    #[test]
    fn sequential_batches_cover_in_order() {
        let ds = small_ds();
        let labels = ds.labels.clone();
        let b = Batcher::new(ds, 16, 0).unwrap();
        let mut batches = b.sequential_batches();
        assert_eq!(batches.len(), 4);
        // first batch's one-hots match the first 16 labels
        let first = batches.next().unwrap();
        for (i, &l) in labels[..16].iter().enumerate() {
            assert_eq!(first.y[i * 10 + l as usize], 1.0);
        }
        assert_eq!(batches.count(), 3, "three batches follow the first");
    }

    #[test]
    fn sequential_batches_all_includes_the_ragged_tail() {
        // 40 examples at batch 16: two full batches + an 8-example tail.
        let ds = Dataset::generate(spec("mlp-lite"), 40, 1, 0);
        let labels = ds.labels.clone();
        let b = Batcher::new(ds, 16, 0).unwrap();
        assert_eq!(b.sequential_batches().count(), 2, "drop-last path unchanged");
        let all: Vec<Batch> = b.sequential_batches_all().collect();
        assert_eq!(all.len(), 3);
        assert_eq!(all[2].x.len(), 8 * 8 * 8 * 3);
        assert_eq!(all[2].y.len(), 8 * 10);
        // The tail holds examples 32..40 in order.
        for (i, &l) in labels[32..40].iter().enumerate() {
            assert_eq!(all[2].y[i * 10 + l as usize], 1.0);
        }
        // An exact multiple produces no tail.
        let b = Batcher::new(small_ds(), 16, 0).unwrap();
        assert_eq!(b.sequential_batches_all().count(), 4);
    }

    #[test]
    fn shard_map_partitions_chunks_and_rotates_with_round() {
        for n_chunks in [4usize, 8, 16] {
            for n_live in [1usize, 2, 4] {
                if !n_chunks.is_multiple_of(n_live) {
                    continue;
                }
                for round in 0..7 {
                    // Partition: concatenating every position's range in
                    // *slot* order covers 0..n_chunks contiguously.
                    let mut owned = vec![0usize; n_chunks];
                    for pos in 0..n_live {
                        for c in shard_for(round, pos, n_live, n_chunks) {
                            owned[c] += 1;
                        }
                    }
                    assert!(
                        owned.iter().all(|&c| c == 1),
                        "round {round} n_live {n_live}: chunks not partitioned: {owned:?}"
                    );
                }
                // Rotation: over n_live consecutive rounds, position 0
                // visits every slot exactly once.
                let mut starts: Vec<usize> =
                    (0..n_live).map(|r| shard_for(r, 0, n_live, n_chunks).start).collect();
                starts.sort_unstable();
                let expect: Vec<usize> = (0..n_live).map(|s| s * n_chunks / n_live).collect();
                assert_eq!(starts, expect, "n_live {n_live} rotation misses slots");
            }
        }
    }

    #[test]
    fn batch_rows_views_concatenate_back_to_the_batch() {
        let mut b = Batcher::new(small_ds(), 16, 0).unwrap();
        let batch = b.next_batch();
        let (pix, ncls) = (8 * 8 * 3, 10);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for (lo, hi) in [(0, 5), (5, 11), (11, 16)] {
            let (xr, yr) = batch.rows(lo, hi, pix, ncls);
            x.extend_from_slice(xr);
            y.extend_from_slice(yr);
        }
        assert_eq!(x, batch.x);
        assert_eq!(y, batch.y);
    }

    #[test]
    fn sequential_iterator_reports_exact_len_as_it_advances() {
        let ds = Dataset::generate(spec("mlp-lite"), 40, 1, 0);
        let b = Batcher::new(ds, 16, 0).unwrap();
        let mut it = b.sequential_batches_all();
        assert_eq!(it.len(), 3);
        it.next();
        assert_eq!(it.len(), 2);
        it.next();
        assert_eq!(it.len(), 1, "the ragged tail still counts as one batch");
        it.next();
        assert_eq!(it.len(), 0);
        assert!(it.next().is_none());
    }
}
