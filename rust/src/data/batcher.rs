//! Batching + background prefetch pipeline (std::thread + mpsc; tokio is
//! not resolvable offline, and the coordinator's loop is synchronous anyway).
//!
//! The trainer consumes `(x, y_onehot)` host buffers shaped for the AOT
//! program; generation (epoch shuffling, one-hot encoding) happens on a
//! producer thread so data prep overlaps XLA execution — the same overlap
//! a tf.data/DataLoader pipeline provides.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use super::synth::Dataset;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Batch {
    /// (B, H, W, C) flattened.
    pub x: Vec<f32>,
    /// (B, n_classes) one-hot flattened.
    pub y: Vec<f32>,
    pub epoch: usize,
}

/// Synchronous batcher: deterministic epoch shuffles over a fixed dataset.
pub struct Batcher {
    ds: Dataset,
    batch: usize,
    order: Vec<u32>,
    cursor: usize,
    pub epoch: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(ds: Dataset, batch: usize, seed: u64) -> Batcher {
        assert!(batch <= ds.n, "batch {} > dataset {}", batch, ds.n);
        let mut b = Batcher {
            order: (0..ds.n as u32).collect(),
            ds,
            batch,
            cursor: 0,
            epoch: 0,
            rng: Rng::new(seed).split(0xBA7C),
        };
        b.rng.shuffle(&mut b.order);
        b
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.ds.n / self.batch
    }

    /// Next batch; reshuffles at epoch boundaries (drop-last semantics).
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch > self.ds.n {
            self.cursor = 0;
            self.epoch += 1;
            self.rng.shuffle(&mut self.order);
        }
        let pix = self.ds.pixels();
        let ncls = self.ds.spec.n_classes;
        let mut x = vec![0.0f32; self.batch * pix];
        let mut y = vec![0.0f32; self.batch * ncls];
        for bi in 0..self.batch {
            let idx = self.order[self.cursor + bi] as usize;
            x[bi * pix..(bi + 1) * pix].copy_from_slice(self.ds.image(idx));
            y[bi * ncls + self.ds.labels[idx] as usize] = 1.0;
        }
        self.cursor += self.batch;
        Batch { x, y, epoch: self.epoch }
    }

    /// All full batches of the dataset in index order (evaluation).
    pub fn sequential_batches(&self) -> Vec<Batch> {
        let pix = self.ds.pixels();
        let ncls = self.ds.spec.n_classes;
        let mut out = Vec::new();
        let mut start = 0;
        while start + self.batch <= self.ds.n {
            let mut x = vec![0.0f32; self.batch * pix];
            let mut y = vec![0.0f32; self.batch * ncls];
            for bi in 0..self.batch {
                let idx = start + bi;
                x[bi * pix..(bi + 1) * pix].copy_from_slice(self.ds.image(idx));
                y[bi * ncls + self.ds.labels[idx] as usize] = 1.0;
            }
            out.push(Batch { x, y, epoch: 0 });
            start += self.batch;
        }
        out
    }
}

/// Background prefetcher: producer thread + bounded channel.
pub struct Prefetcher {
    rx: Receiver<Batch>,
    _handle: JoinHandle<()>,
}

impl Prefetcher {
    /// `depth` = number of batches buffered ahead of the consumer.
    pub fn spawn(mut batcher: Batcher, depth: usize, total_batches: usize) -> Prefetcher {
        let (tx, rx) = sync_channel(depth);
        let handle = std::thread::spawn(move || {
            for _ in 0..total_batches {
                if tx.send(batcher.next_batch()).is_err() {
                    return; // consumer dropped early
                }
            }
        });
        Prefetcher { rx, _handle: handle }
    }

    pub fn next(&self) -> Option<Batch> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::super::synth::{spec, Dataset};
    use super::*;

    fn small_ds() -> Dataset {
        Dataset::generate(spec("mlp-lite"), 64, 1, 0)
    }

    #[test]
    fn batches_have_valid_onehots() {
        let mut b = Batcher::new(small_ds(), 16, 0);
        for _ in 0..8 {
            let batch = b.next_batch();
            assert_eq!(batch.y.len(), 16 * 10);
            for r in 0..16 {
                let row = &batch.y[r * 10..(r + 1) * 10];
                assert_eq!(row.iter().sum::<f32>(), 1.0);
                assert!(row.iter().all(|&v| v == 0.0 || v == 1.0));
            }
        }
    }

    #[test]
    fn epoch_covers_every_sample_once() {
        let ds = small_ds();
        let n = ds.n;
        let mut b = Batcher::new(ds, 16, 0);
        let mut seen = vec![0usize; n];
        for _ in 0..b.batches_per_epoch() {
            let start = b.cursor;
            let _ = b.next_batch();
            for i in start..start + 16 {
                seen[b.order[i] as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<f32> = {
            let mut b = Batcher::new(small_ds(), 16, 9);
            b.next_batch().x
        };
        let c: Vec<f32> = {
            let mut b = Batcher::new(small_ds(), 16, 9);
            b.next_batch().x
        };
        assert_eq!(a, c);
    }

    #[test]
    fn prefetcher_delivers_all_batches() {
        let b = Batcher::new(small_ds(), 16, 0);
        let pf = Prefetcher::spawn(b, 2, 10);
        let mut count = 0;
        while let Some(batch) = pf.next() {
            assert_eq!(batch.x.len(), 16 * 8 * 8 * 3);
            count += 1;
        }
        assert_eq!(count, 10);
    }

    #[test]
    fn sequential_batches_cover_in_order() {
        let ds = small_ds();
        let labels = ds.labels.clone();
        let b = Batcher::new(ds, 16, 0);
        let batches = b.sequential_batches();
        assert_eq!(batches.len(), 4);
        // first batch's one-hots match the first 16 labels
        for (i, &l) in labels[..16].iter().enumerate() {
            assert_eq!(batches[0].y[i * 10 + l as usize], 1.0);
        }
    }
}
