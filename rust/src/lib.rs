//! WaveQ — gradient-based deep quantization through sinusoidal adaptive
//! regularization (Elthakeb et al., 2020), as a three-layer rust+JAX+Pallas
//! system. This crate is Layer 3: the coordinator that owns training
//! orchestration, the 3-phase regularization-strength schedule, bitwidth
//! management, data pipelines, the Stripes energy model, Pareto analysis,
//! and the experiment drivers that regenerate every table/figure.
//!
//! Execution is pluggable behind [`runtime::Backend`]:
//!
//! | backend            | feature        | needs                | programs            |
//! |--------------------|----------------|----------------------|---------------------|
//! | `runtime::native`  | (default)      | nothing — pure Rust  | full model zoo      |
//! | `runtime::pjrt`    | `pjrt`         | `make artifacts` +   | every AOT program   |
//! |                    |                | vendored `xla` crate |                     |
//!
//! The native backend executes the WaveQ train/eval programs for the whole
//! model zoo — conv2d via im2col, depthwise conv, pooling, affine norm,
//! residual blocks, quantized forward/backward, the sinusoidal regularizer
//! with analytic w- and beta-gradients, SGD+momentum — directly on the host
//! against the same manifest signatures the AOT HLO programs export, so
//! `cargo test`, the examples, and every `waveq experiment` driver
//! (Tables 1–2, Figures 2–8) run end-to-end with zero Python/XLA artifacts. With the `pjrt`
//! feature, Python (L2 JAX model zoo + L1 Pallas kernels) runs at build
//! time: `make artifacts` lowers every program to HLO text which
//! `runtime::pjrt` loads through the PJRT C API.

// Unsafe stays confined to the worker pool: `runtime::native::pool` opts
// back in with a module-level `#![allow(unsafe_code)]` and carries a
// `// SAFETY:` justification on every site (inventoried by waveq-audit,
// rule D4). `deny` (not `forbid`) so exactly that one opt-out compiles.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod experiments;
pub mod pareto;
pub mod runtime;
pub mod schedule;
pub mod tensor;
pub mod testing;
pub mod util;

use std::path::PathBuf;

/// Default artifacts directory: `$WAVEQ_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("WAVEQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
