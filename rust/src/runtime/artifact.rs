//! Frozen-model artifacts: the deployable product of a WaveQ training run.
//!
//! A [`FrozenModel`] is a self-describing binary holding the graph identity
//! (zoo base name + width multiplier, which deterministically rebuilds the
//! op graph via `NativeModel::by_name`) plus every parameter tensor — each
//! *quantized* layer stored as bit-packed integer codes at its learned
//! bitwidth (2–8 bit codes + the DoReFa/WRPN layer scale), and f32 raw data
//! only for the non-quantized parameters (biases, affine scales/shifts, and
//! the first/last compute layers the paper keeps at full precision).
//!
//! Layout (little-endian):
//!
//!   magic "WVQFRZN1"
//!   u32 base_len | base bytes          (zoo base name, e.g. "simplenet5")
//!   u32 width_mult
//!   u8  has_act | f32 act_levels?      (activation quantizer level count)
//!   u32 n_params | per parameter:
//!     u32 name_len | name bytes
//!     u32 rank | u64 dims[rank]
//!     u8 tag                           (0 = raw f32, 1 = packed codes)
//!     tag 0: f32 data[count]
//!     tag 1: u8 bits | f32 scale | u8 packed[ceil(count * bits / 8)]
//!
//! The **exact-unpack contract**: decoding a packed parameter reproduces
//! the f32 grid values the quantizer (`kernels::dorefa_quantize` /
//! `wrpn_quantize`) computes from the live weights *bit-for-bit* — see
//! [`super::native::kernels::decode_codes_into`]. That is what makes a
//! frozen [`super::infer::InferenceSession`] bitwise identical to
//! evaluating the live training state.
//!
//! The in-memory `codes` stay integral end to end: an `InferenceSession`
//! packs them straight into GEMM panels — fused element-wise decode for
//! the f32 path (`kernels::PackedB::pack_codes`), recentred i8 codes for
//! the int8 path (`kernels::PackedQuant`) — so no full-size decoded f32
//! copy of a packed weight ever needs to be resident.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::io::{read_count, read_f32, read_f32s, read_shape, read_string, read_u32};
use super::native::kernels as kn;

pub const FROZEN_MAGIC: &[u8; 8] = b"WVQFRZN1";

/// How one parameter tensor is stored in the artifact.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamStorage {
    /// Raw f32 (non-quantized parameters).
    F32(Vec<f32>),
    /// Bit-packed quantizer codes at the layer's learned bitwidth, plus
    /// the quantizer scale (DoReFa: max|tanh W|; WRPN: max|W|).
    Packed { bits: u8, scale: f32, codes: Vec<u16> },
}

/// One parameter tensor of a frozen model.
#[derive(Debug, Clone, PartialEq)]
pub struct FrozenParam {
    pub name: String,
    pub shape: Vec<usize>,
    pub storage: ParamStorage,
}

impl FrozenParam {
    pub fn count(&self) -> usize {
        self.shape.iter().product()
    }

    /// Decode to the f32 values the forward pass consumes: packed params
    /// land on the quantizer grid (bitwise — the exact-unpack contract),
    /// f32 params are returned verbatim.
    pub fn decode(&self) -> Vec<f32> {
        match &self.storage {
            ParamStorage::F32(data) => data.clone(),
            ParamStorage::Packed { bits, scale, codes } => {
                let k = (2u32.pow(*bits as u32) - 1) as f32;
                let mut out = vec![0.0f32; codes.len()];
                kn::decode_codes_into(codes, k, *scale, &mut out);
                out
            }
        }
    }
}

/// A frozen, deployable model: graph identity + packed parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct FrozenModel {
    /// Zoo base name (`NativeModel::by_name` key).
    pub base: String,
    pub width_mult: usize,
    /// Activation fake-quant level count (`ka`); `None` = fp32 activations.
    pub act_levels: Option<f32>,
    /// Parameters in the model's manifest order.
    pub params: Vec<FrozenParam>,
}

impl FrozenModel {
    /// Bytes of bit-packed weight payload: `sum(ceil(n_l * b_l / 8))` over
    /// the quantized layers — the storage the learned assignment earns.
    pub fn packed_weight_bytes(&self) -> usize {
        self.params
            .iter()
            .filter_map(|p| match &p.storage {
                ParamStorage::Packed { bits, codes, .. } => {
                    Some((codes.len() * *bits as usize).div_ceil(8))
                }
                ParamStorage::F32(_) => None,
            })
            .sum()
    }

    /// What the same quantized layers would occupy as raw f32.
    pub fn f32_weight_bytes(&self) -> usize {
        self.params
            .iter()
            .filter(|p| matches!(p.storage, ParamStorage::Packed { .. }))
            .map(|p| 4 * p.count())
            .sum()
    }

    /// How many times smaller the packed layers are than their f32 form
    /// (`None` when nothing is packed, e.g. an fp32 freeze) — the single
    /// definition of the size headline the CLI and benches report.
    pub fn size_reduction(&self) -> Option<f64> {
        let packed = self.packed_weight_bytes();
        if packed == 0 {
            return None;
        }
        Some(self.f32_weight_bytes() as f64 / packed as f64)
    }

    /// Per-quantized-layer bitwidths, in parameter order.
    pub fn layer_bits(&self) -> Vec<u32> {
        self.params
            .iter()
            .filter_map(|p| match &p.storage {
                ParamStorage::Packed { bits, .. } => Some(*bits as u32),
                ParamStorage::F32(_) => None,
            })
            .collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(FROZEN_MAGIC)?;
        f.write_all(&(self.base.len() as u32).to_le_bytes())?;
        f.write_all(self.base.as_bytes())?;
        f.write_all(&(self.width_mult as u32).to_le_bytes())?;
        match self.act_levels {
            Some(ka) => {
                f.write_all(&[1u8])?;
                f.write_all(&ka.to_le_bytes())?;
            }
            None => f.write_all(&[0u8])?,
        }
        f.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for p in &self.params {
            f.write_all(&(p.name.len() as u32).to_le_bytes())?;
            f.write_all(p.name.as_bytes())?;
            f.write_all(&(p.shape.len() as u32).to_le_bytes())?;
            for &d in &p.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            match &p.storage {
                ParamStorage::F32(data) => {
                    if data.len() != p.count() {
                        return Err(anyhow!(
                            "frozen param {}: {} f32 values for shape {:?}",
                            p.name,
                            data.len(),
                            p.shape
                        ));
                    }
                    f.write_all(&[0u8])?;
                    for &v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                ParamStorage::Packed { bits, scale, codes } => {
                    if codes.len() != p.count() {
                        return Err(anyhow!(
                            "frozen param {}: {} codes for shape {:?}",
                            p.name,
                            codes.len(),
                            p.shape
                        ));
                    }
                    if !(2..=8).contains(bits) {
                        return Err(anyhow!("frozen param {}: {bits}-bit codes", p.name));
                    }
                    f.write_all(&[1u8])?;
                    f.write_all(&[*bits])?;
                    f.write_all(&scale.to_le_bytes())?;
                    f.write_all(&pack_codes(codes, *bits)?)?;
                }
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<FrozenModel> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != FROZEN_MAGIC {
            return Err(anyhow!("{} is not a waveq frozen-model artifact", path.display()));
        }
        let base = read_string(&mut f)?;
        let width_mult = read_u32(&mut f)? as usize;
        let mut has_act = [0u8; 1];
        f.read_exact(&mut has_act)?;
        let act_levels = match has_act[0] {
            0 => None,
            1 => Some(read_f32(&mut f)?),
            other => return Err(anyhow!("bad act-levels flag {other}")),
        };
        let n = read_count(&mut f, "param")?;
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            let name = read_string(&mut f)?;
            let (shape, count) = read_shape(&mut f)?;
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let storage = match tag[0] {
                0 => {
                    let mut data = vec![0f32; count];
                    read_f32s(&mut f, &mut data)?;
                    ParamStorage::F32(data)
                }
                1 => {
                    let mut head = [0u8; 1];
                    f.read_exact(&mut head)?;
                    let bits = head[0];
                    if !(2..=8).contains(&bits) {
                        return Err(anyhow!("param {name}: {bits}-bit codes out of range"));
                    }
                    let scale = read_f32(&mut f)?;
                    let mut packed = vec![0u8; (count * bits as usize).div_ceil(8)];
                    f.read_exact(&mut packed)?;
                    let codes = unpack_codes(&packed, bits, count)?;
                    ParamStorage::Packed { bits, scale, codes }
                }
                other => return Err(anyhow!("param {name}: unknown storage tag {other}")),
            };
            params.push(FrozenParam { name, shape, storage });
        }
        Ok(FrozenModel { base, width_mult, act_levels, params })
    }
}

// ---- bit packing -----------------------------------------------------------

/// Pack integer codes at `bits` per code into an LSB-first bitstream of
/// exactly `ceil(n * bits / 8)` bytes.
pub fn pack_codes(codes: &[u16], bits: u8) -> Result<Vec<u8>> {
    if !(1..=16).contains(&bits) {
        return Err(anyhow!("pack_codes: bits {bits} out of range"));
    }
    let limit = 1u32 << bits;
    let mut out = Vec::with_capacity((codes.len() * bits as usize).div_ceil(8));
    let mut acc = 0u32;
    let mut nbits = 0u32;
    for &c in codes {
        if (c as u32) >= limit {
            return Err(anyhow!("pack_codes: code {c} does not fit {bits} bits"));
        }
        acc |= (c as u32) << nbits;
        nbits += bits as u32;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xFF) as u8);
    }
    Ok(out)
}

/// Inverse of [`pack_codes`]: read `n` codes of `bits` each.
pub fn unpack_codes(bytes: &[u8], bits: u8, n: usize) -> Result<Vec<u16>> {
    if !(1..=16).contains(&bits) {
        return Err(anyhow!("unpack_codes: bits {bits} out of range"));
    }
    let want = (n * bits as usize).div_ceil(8);
    if bytes.len() < want {
        return Err(anyhow!("unpack_codes: {} bytes, need {want} for {n} codes", bytes.len()));
    }
    let mask = (1u32 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut acc = 0u32;
    let mut nbits = 0u32;
    let mut i = 0usize;
    for _ in 0..n {
        while nbits < bits as u32 {
            acc |= (bytes[i] as u32) << nbits;
            i += 1;
            nbits += 8;
        }
        out.push((acc & mask) as u16);
        acc >>= bits;
        nbits -= bits as u32;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_round_trips_every_bitwidth_and_tail_length() {
        let mut rng = Rng::new(7);
        for bits in 2..=8u8 {
            for &n in &[0usize, 1, 2, 3, 5, 7, 8, 9, 13, 64, 100, 257] {
                let codes: Vec<u16> = (0..n).map(|_| rng.below(1u64 << bits) as u16).collect();
                let packed = pack_codes(&codes, bits).unwrap();
                assert_eq!(packed.len(), (n * bits as usize).div_ceil(8), "b={bits} n={n}");
                assert_eq!(unpack_codes(&packed, bits, n).unwrap(), codes, "b={bits} n={n}");
            }
        }
    }

    #[test]
    fn pack_rejects_out_of_range_codes() {
        assert!(pack_codes(&[4], 2).is_err());
        assert!(pack_codes(&[3], 2).is_ok());
        assert!(pack_codes(&[0], 0).is_err());
        assert!(unpack_codes(&[0xFF], 8, 2).is_err(), "short byte stream");
    }

    #[test]
    fn artifact_round_trips_and_rejects_corrupt_magic() {
        let model = FrozenModel {
            base: "simplenet5".into(),
            width_mult: 1,
            act_levels: Some(255.0),
            params: vec![
                FrozenParam {
                    name: "conv1".into(),
                    shape: vec![3, 3, 3, 4],
                    storage: ParamStorage::F32((0..108).map(|i| i as f32 * 0.25).collect()),
                },
                FrozenParam {
                    name: "conv2".into(),
                    shape: vec![2, 5],
                    storage: ParamStorage::Packed {
                        bits: 3,
                        scale: 0.7,
                        codes: vec![0, 7, 3, 1, 6, 2, 5, 4, 7, 0],
                    },
                },
            ],
        };
        let path = std::env::temp_dir().join("waveq_frozen_test.bin");
        model.save(&path).unwrap();
        let back = FrozenModel::load(&path).unwrap();
        assert_eq!(back, model);
        assert_eq!(back.packed_weight_bytes(), (10 * 3usize).div_ceil(8));
        assert_eq!(back.f32_weight_bytes(), 40);
        assert_eq!(back.size_reduction(), Some(10.0));
        assert_eq!(back.layer_bits(), vec![3]);

        // Corrupt magic -> clean rejection.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = FrozenModel::load(&path).unwrap_err();
        assert!(format!("{err}").contains("not a waveq frozen-model artifact"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_shape_fields_error_instead_of_allocating() {
        let model = FrozenModel {
            base: "mlp".into(),
            width_mult: 1,
            act_levels: None,
            params: vec![FrozenParam {
                name: "w".into(),
                shape: vec![2, 3],
                storage: ParamStorage::F32(vec![0.0; 6]),
            }],
        };
        let path = std::env::temp_dir().join("waveq_frozen_corrupt_dim.bin");
        model.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Layout: magic 8 | base_len 4 | "mlp" 3 | width 4 | has_act 1
        //         | n_params 4 | name_len 4 | "w" 1 | rank 4 | dims...
        let dim0 = 8 + 4 + 3 + 4 + 1 + 4 + 4 + 1 + 4;
        assert_eq!(
            u64::from_le_bytes(bytes[dim0..dim0 + 8].try_into().unwrap()),
            2,
            "dim offset drifted; update this test alongside the layout"
        );
        // One flipped dim demanding ~2^64 elements must error cleanly,
        // not abort the process on a giant allocation.
        let mut corrupt = bytes.clone();
        corrupt[dim0..dim0 + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &corrupt).unwrap();
        let err = FrozenModel::load(&path).unwrap_err();
        assert!(format!("{err}").contains("implausible"), "{err}");
        // Same for a corrupt rank field.
        let rank_off = dim0 - 4;
        let mut corrupt = bytes;
        corrupt[rank_off..rank_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &corrupt).unwrap();
        let err = FrozenModel::load(&path).unwrap_err();
        assert!(format!("{err}").contains("implausible"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn decode_lands_on_the_quantizer_grid() {
        let p = FrozenParam {
            name: "w".into(),
            shape: vec![4],
            storage: ParamStorage::Packed { bits: 2, scale: 1.5, codes: vec![0, 1, 2, 3] },
        };
        // k = 3: grid m * (2c/3 - 1) for c in 0..=3.
        let got = p.decode();
        let want: Vec<f32> = (0..4).map(|c| 1.5 * (2.0 * (c as f32 / 3.0) - 1.0)).collect();
        assert_eq!(got, want);
    }
}
