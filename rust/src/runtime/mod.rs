//! L3 runtime: manifest-described programs behind a pluggable backend.
//!
//! `manifest` — the python→rust contract (signatures, layouts, MACs).
//! `buffer`   — the backend-neutral host buffer type + helpers.
//! `backend`  — the `Backend` trait, the `Runtime` facade, and the typed
//!              `Program` handles `Runtime::prepare` returns.
//! `session`  — stateful training sessions: backend-resident state +
//!              zero-alloc steady-state stepping over prepared handles.
//! `native`   — hermetic pure-Rust reference backend (always available).
//! `pjrt`     — PJRT load/compile/execute over AOT HLO artifacts
//!              (behind the non-default `pjrt` cargo feature).

pub mod backend;
pub mod buffer;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod session;

pub use backend::{Backend, Program, ProgramStats, Runtime, RuntimeStats};
pub use buffer::{buffer_f32, scalar_f32, to_scalar_f32, to_vec_f32, Buffer};
pub use manifest::{ArgSpec, Manifest, ModelMeta, ParamMeta, ProgramSig};
pub use native::{NativeBackend, NativeModel};
pub use session::{Session, SessionCfg, SessionState, StepKnobs, StepMetrics};
