//! L3 runtime: manifest-described programs behind a pluggable backend.
//!
//! `manifest`   — the python→rust contract (signatures, layouts, MACs).
//! `buffer`     — the backend-neutral host buffer type + helpers.
//! `backend`    — the `Backend` trait, the `Runtime` facade, and the typed
//!                `Program` handles `Runtime::prepare` returns.
//! `session`    — stateful training sessions: backend-resident state +
//!                zero-alloc steady-state stepping over prepared handles.
//! `checkpoint` — binary training snapshots (`WVQCKPT2`, step + model
//!                aware; loads the v1 format too).
//! `artifact`   — frozen-model artifacts: bit-packed low-bit weights with
//!                an exact-unpack (bitwise) decode contract.
//! `infer`      — forward-only, batch-polymorphic serving sessions over
//!                frozen artifacts (the freeze-and-serve stage).
//! `serve`      — concurrent serving: N session workers over a shared
//!                request queue with cross-request batching, plus the
//!                length-prefixed TCP front end (`waveq serve`).
//! `native`     — hermetic pure-Rust reference backend (always available).
//! `pjrt`       — PJRT load/compile/execute over AOT HLO artifacts
//!                (behind the non-default `pjrt` cargo feature).

pub mod artifact;
pub mod backend;
pub mod buffer;
pub mod checkpoint;
pub mod infer;
pub(crate) mod io;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod serve;
pub mod session;

pub use artifact::{FrozenModel, FrozenParam, ParamStorage};
pub use backend::{Backend, Program, ProgramStats, Runtime, RuntimeStats};
pub use buffer::{buffer_f32, scalar_f32, to_scalar_f32, to_vec_f32, Buffer};
pub use checkpoint::Checkpoint;
pub use infer::{InferCfg, InferenceSession, Precision};
pub use manifest::{ArgSpec, Manifest, ModelMeta, ParamMeta, ProgramSig};
pub use native::{NativeBackend, NativeModel};
pub use serve::{
    LoopbackReport, Server, ServeCfg, ServeClient, ServeIdentity, ServeSnapshot, TcpClient,
    HELLO_VERSION,
};
pub use session::{Session, SessionCfg, SessionState, StepKnobs, StepMetrics};
