//! L3 runtime: the bridge from AOT artifacts to executable programs.
//!
//! `manifest` — the python→rust contract (signatures, layouts, MACs).
//! `client`   — PJRT load/compile/execute with caching + literal helpers.

pub mod client;
pub mod manifest;

pub use client::{literal_f32, scalar_f32, to_scalar_f32, to_vec_f32, Runtime, RuntimeStats};
pub use manifest::{ArgSpec, Manifest, ModelMeta, ParamMeta, ProgramSig};
