//! PJRT backend: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled lazily and cached per program name, so the
//! coordinator's hot loop never recompiles.
//!
//! All programs return a single tuple (lowered with `return_tuple=True`);
//! [`PjrtBackend::execute`] decomposes it into one [`Buffer`] per named
//! output. `execute_into` is served by the trait's default copy-out
//! fallback: PJRT owns its device buffers, so results are fetched and then
//! moved into the caller's output slots (no in-place write).
//!
//! Only built with `--features pjrt`, which additionally requires the
//! vendored `xla` crate closure in Cargo.toml (see the feature note there);
//! the default build uses `runtime::native` and needs no artifacts.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::{Backend, RuntimeStats};
use super::buffer::Buffer;
use super::manifest::ProgramSig;

pub struct PjrtBackend {
    client: PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl PjrtBackend {
    /// Open the artifacts directory the manifest's program files live in.
    pub fn open(dir: &Path) -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtBackend {
            client,
            dir: dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Compile (or fetch cached) executable for a program.
    fn executable(&self, sig: &ProgramSig) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&sig.name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(&sig.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", sig.name))?;
        let exe = Rc::new(exe);
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.borrow_mut().insert(sig.name.clone(), exe.clone());
        Ok(exe)
    }
}

fn to_literal(b: &Buffer) -> Result<Literal> {
    if b.shape.is_empty() {
        return Ok(Literal::scalar(b.data[0]));
    }
    let lit = Literal::vec1(&b.data);
    let dims: Vec<i64> = b.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

fn from_literal(lit: &Literal) -> Result<Buffer> {
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
    let shape: Vec<usize> = lit
        .array_shape()
        .map_err(|e| anyhow!("shape: {e:?}"))?
        .dims()
        .iter()
        .map(|&d| d as usize)
        .collect();
    Buffer::new(shape, data)
}

impl Backend for PjrtBackend {
    fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, sig: &ProgramSig) -> Result<()> {
        self.executable(sig).map(|_| ())
    }

    fn execute(&self, sig: &ProgramSig, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        let exe = self.executable(sig)?;
        let literals = args.iter().map(|b| to_literal(b)).collect::<Result<Vec<_>>>()?;
        let t0 = Instant::now();
        let result = exe
            .execute::<Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", sig.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} result: {e:?}", sig.name))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {} result: {e:?}", sig.name))?;
        self.stats
            .borrow_mut()
            .record_execute(&sig.name, t0.elapsed().as_secs_f64());
        outs.iter().map(from_literal).collect()
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}
