//! The pluggable execution backend abstraction and the typed program
//! handles the coordinator dispatches through.
//!
//! A [`Backend`] turns manifest-described programs into results: `compile`
//! prepares a program (cache warm / lazy-compile), `execute` runs it on
//! host [`Buffer`]s allocating its outputs, and `execute_into` runs it
//! writing into *caller-owned* output buffers (the zero-allocation
//! steady-state path). Two implementations exist:
//!
//! * [`super::native::NativeBackend`] — pure Rust, hermetic, executes the
//!   WaveQ train/eval program family directly on the host (always
//!   available; the default). Writes `execute_into` outputs in place.
//! * `super::pjrt::PjrtBackend` — compiles AOT HLO-text artifacts through
//!   the XLA PJRT C API (behind the non-default `pjrt` cargo feature).
//!   Keeps the default copy-out `execute_into` fallback.
//!
//! [`Runtime`] is the coordinator-facing facade: it owns the [`Manifest`]
//! (the program/model contract), keeps cumulative stats, and forwards to
//! whichever backend it was opened with. The steady-state call interface
//! is [`Runtime::prepare`], which resolves a program name *once* into a
//! [`Program`] handle whose `call`/`call_into` are lookup-free; the
//! stringly-typed [`Runtime::execute`] remains only as the legacy shim the
//! property/determinism tests use as their oracle path.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::buffer::Buffer;
use super::manifest::{Manifest, ProgramSig};
use super::native::NativeBackend;

/// Per-program slice of [`RuntimeStats`].
#[derive(Debug, Default, Clone)]
pub struct ProgramStats {
    pub executions: usize,
    pub execute_secs: f64,
}

/// Cumulative compile/execute counters — surfaced by `waveq smoke`/metrics.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
    /// Per-program execute counts and cumulative seconds, by program name.
    pub per_program: BTreeMap<String, ProgramStats>,
}

impl RuntimeStats {
    /// Record one execution of `program` taking `secs` (total + per-program).
    pub fn record_execute(&mut self, program: &str, secs: f64) {
        self.executions += 1;
        self.execute_secs += secs;
        let p = self.per_program.entry(program.to_string()).or_default();
        p.executions += 1;
        p.execute_secs += secs;
    }

    /// The `n` programs with the largest cumulative execute time, descending.
    pub fn top_programs(&self, n: usize) -> Vec<(String, ProgramStats)> {
        let mut v: Vec<(String, ProgramStats)> =
            self.per_program.iter().map(|(k, s)| (k.clone(), s.clone())).collect();
        v.sort_by(|a, b| {
            b.1.execute_secs
                .partial_cmp(&a.1.execute_secs)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        v.truncate(n);
        v
    }
}

/// An execution engine for manifest-described programs.
///
/// Implementations own their compile caches and timing; the [`Program`]
/// handle has already validated input arity against the manifest before
/// `execute`/`execute_into` is called.
pub trait Backend {
    /// Human-readable platform tag ("native", "cpu", ...).
    fn platform_name(&self) -> String;

    /// Prepare a program for execution (idempotent; cheap when cached).
    fn compile(&self, sig: &ProgramSig) -> Result<()>;

    /// Whether this backend serves a program at batch sizes other than the
    /// manifest shape (resolving the batch from the buffer lengths). The
    /// native backend does; AOT-compiled backends (pjrt) execute fixed
    /// shapes and keep the default `false` — callers with ragged batches
    /// (the held-out tail) must check this before dispatching them.
    fn batch_polymorphic(&self) -> bool {
        false
    }

    /// Whether the split train stages (`grads_*` / `apply_*` program pairs)
    /// exist for this backend's train programs. The distributed coordinator
    /// requires them; backends without the split keep the default `false`
    /// and only serve fused single-process training.
    fn grad_stage(&self) -> bool {
        false
    }

    /// Backend-specific per-thread warmup for a program (idempotent). The
    /// native backend pre-sizes the *calling thread's* buffer arena for
    /// train-path programs; others keep the no-op default.
    fn warm(&self, _sig: &ProgramSig) -> Result<()> {
        Ok(())
    }

    /// Execute a program on host buffers; one buffer per named output, in
    /// the manifest's output order.
    fn execute(&self, sig: &ProgramSig, args: &[&Buffer]) -> Result<Vec<Buffer>>;

    /// Execute writing into caller-owned output buffers: one per named
    /// output, manifest order, already shaped. Backends that can write in
    /// place override this (the native backend does); the default falls
    /// back to [`Backend::execute`] and moves the produced buffers out —
    /// the pjrt copy-out path.
    fn execute_into(&self, sig: &ProgramSig, args: &[&Buffer], outs: &mut [Buffer]) -> Result<()> {
        let produced = self.execute(sig, args)?;
        if produced.len() != outs.len() {
            return Err(anyhow!(
                "{}: program produced {} outputs, caller provided {} buffers",
                sig.name,
                produced.len(),
                outs.len()
            ));
        }
        for (i, (dst, src)) in outs.iter_mut().zip(produced).enumerate() {
            if dst.shape != src.shape {
                return Err(anyhow!(
                    "{}: output {} ('{}') has shape {:?}, caller buffer is {:?}",
                    sig.name,
                    i,
                    sig.outputs.get(i).map(String::as_str).unwrap_or("?"),
                    src.shape,
                    dst.shape
                ));
            }
            *dst = src;
        }
        Ok(())
    }

    /// Cumulative compile/execute counters.
    fn stats(&self) -> RuntimeStats;
}

/// A prepared program handle: name resolved, signature cloned, and backend
/// compile cache warmed exactly once at [`Runtime::prepare`] time, so
/// [`Program::call`] / [`Program::call_into`] do no lookups — the
/// steady-state training loop dispatches through the handle with nothing
/// but an arity check in front of the backend.
pub struct Program<'rt> {
    backend: &'rt dyn Backend,
    sig: ProgramSig,
}

impl Program<'_> {
    pub fn name(&self) -> &str {
        &self.sig.name
    }

    /// Whether the owning backend serves this program at non-manifest
    /// batch sizes (see [`Backend::batch_polymorphic`]).
    pub fn batch_polymorphic(&self) -> bool {
        self.backend.batch_polymorphic()
    }

    /// The resolved positional signature (inputs and output names).
    pub fn sig(&self) -> &ProgramSig {
        &self.sig
    }

    /// Per-thread backend warmup for this program (see [`Backend::warm`]).
    pub fn warm(&self) -> Result<()> {
        self.backend.warm(&self.sig)
    }

    fn check_arity(&self, n: usize) -> Result<()> {
        if n != self.sig.inputs.len() {
            return Err(anyhow!(
                "{}: got {} args, signature has {}",
                self.sig.name,
                n,
                self.sig.inputs.len()
            ));
        }
        Ok(())
    }

    /// Execute, allocating one output buffer per named output.
    /// Accepts owned or borrowed buffers (`&[Buffer]` or `&[&Buffer]`).
    pub fn call<B: std::borrow::Borrow<Buffer>>(&self, args: &[B]) -> Result<Vec<Buffer>> {
        self.check_arity(args.len())?;
        let refs: Vec<&Buffer> = args.iter().map(|a| a.borrow()).collect();
        let outs = self.backend.execute(&self.sig, &refs)?;
        if outs.len() != self.sig.outputs.len() {
            return Err(anyhow!(
                "{}: got {} outputs, manifest says {}",
                self.sig.name,
                outs.len(),
                self.sig.outputs.len()
            ));
        }
        Ok(outs)
    }

    /// Execute into caller-owned, pre-shaped output buffers — no output
    /// allocation on backends that support in-place writes (native). The
    /// buffers must match the program's outputs in count and shape.
    pub fn call_into(&self, args: &[&Buffer], outs: &mut [Buffer]) -> Result<()> {
        self.check_arity(args.len())?;
        if outs.len() != self.sig.outputs.len() {
            return Err(anyhow!(
                "{}: caller provided {} output buffers, signature has {}",
                self.sig.name,
                outs.len(),
                self.sig.outputs.len()
            ));
        }
        self.backend.execute_into(&self.sig, args, outs)
    }
}

/// Backend-neutral runtime: manifest + stats + a boxed [`Backend`].
pub struct Runtime {
    backend: Box<dyn Backend>,
    pub manifest: Manifest,
}

impl Runtime {
    /// A hermetic runtime on the pure-Rust reference backend: the manifest
    /// is generated in-process (no artifacts directory, no Python, no XLA).
    pub fn native() -> Runtime {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        Runtime { backend: Box::new(backend), manifest }
    }

    /// Open an artifacts directory.
    ///
    /// With the `pjrt` feature the programs execute through PJRT on the AOT
    /// HLO artifacts in `dir`. Without it, the manifest is still loaded
    /// (signature lookups, model metadata, error paths all work) and
    /// execution is served by the native backend for the programs it
    /// implements. If `manifest.json` is absent the fully-native runtime
    /// is returned, so a clean clone works with zero built artifacts.
    pub fn open(dir: &Path) -> Result<Runtime> {
        if !dir.join("manifest.json").exists() {
            return Ok(Runtime::native());
        }
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { backend: open_backend(dir)?, manifest })
    }

    pub fn platform(&self) -> String {
        self.backend.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.backend.stats()
    }

    /// Whether the backend serves non-manifest batch sizes (see
    /// [`Backend::batch_polymorphic`]).
    pub fn batch_polymorphic(&self) -> bool {
        self.backend.batch_polymorphic()
    }

    /// Whether the split `grads_*`/`apply_*` train stages exist (see
    /// [`Backend::grad_stage`]). The distributed coordinator checks this
    /// before fanning a step out.
    pub fn grad_stage(&self) -> bool {
        self.backend.grad_stage()
    }

    pub fn sig(&self, program: &str) -> Result<&ProgramSig> {
        self.manifest.program(program)
    }

    /// Resolve and pre-compile a program *once*, returning a typed handle
    /// whose `call`/`call_into` skip the name lookup entirely. This is the
    /// steady-state dispatch path; [`super::session::Session`] builds on it.
    pub fn prepare(&self, program: &str) -> Result<Program<'_>> {
        let sig = self.manifest.program(program)?.clone();
        self.backend
            .compile(&sig)
            .with_context(|| format!("preparing {program}"))?;
        Ok(Program { backend: self.backend.as_ref(), sig })
    }

    /// Legacy stringly-typed dispatch: re-resolves the program by name and
    /// allocates every output on each call. Kept as the oracle path for
    /// the property/determinism tests; steady-state code should call
    /// [`Runtime::prepare`] once and dispatch through the [`Program`].
    pub fn execute<B: std::borrow::Borrow<Buffer>>(
        &self,
        program: &str,
        args: &[B],
    ) -> Result<Vec<Buffer>> {
        let sig = self.manifest.program(program)?;
        if args.len() != sig.inputs.len() {
            return Err(anyhow!(
                "{program}: got {} args, signature has {}",
                args.len(),
                sig.inputs.len()
            ));
        }
        let refs: Vec<&Buffer> = args.iter().map(|a| a.borrow()).collect();
        let outs = self.backend.execute(sig, &refs)?;
        if outs.len() != sig.outputs.len() {
            return Err(anyhow!(
                "{program}: got {} outputs, manifest says {}",
                outs.len(),
                sig.outputs.len()
            ));
        }
        Ok(outs)
    }

    /// Pre-compile a set of programs (amortize compilation outside the loop).
    pub fn warmup(&self, programs: &[&str]) -> Result<()> {
        for p in programs {
            let sig = self.manifest.program(p)?;
            self.backend
                .compile(sig)
                .with_context(|| format!("warming up {p}"))?;
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
fn open_backend(dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::pjrt::PjrtBackend::open(dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn open_backend(_dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(NativeBackend::new()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::buffer::scalar_f32;

    #[test]
    fn native_runtime_has_programs_and_models() {
        let rt = Runtime::native();
        assert_eq!(rt.platform(), "native");
        assert!(rt.manifest.program("train_waveq_mlp").is_ok());
        assert!(rt.manifest.model("mlp").is_ok());
        assert!(rt.sig("nope").is_err());
    }

    #[test]
    fn arity_mismatch_is_rejected_before_dispatch() {
        let rt = Runtime::native();
        let args = vec![scalar_f32(0.0)];
        let err = rt.execute("train_fp32_mlp", &args).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("got 1 args"), "{msg}");
        // Same guard on the prepared-handle path.
        let prog = rt.prepare("train_fp32_mlp").unwrap();
        let err = prog.call(&args).unwrap_err();
        assert!(format!("{err}").contains("got 1 args"), "{err}");
    }

    #[test]
    fn warmup_unknown_program_errors() {
        let rt = Runtime::native();
        assert!(rt.warmup(&["definitely_missing"]).is_err());
    }

    #[test]
    fn prepare_unknown_program_errors() {
        let rt = Runtime::native();
        let err = rt.prepare("definitely_missing").unwrap_err();
        assert!(format!("{err:#}").contains("definitely_missing"), "{err:#}");
    }

    #[test]
    fn stats_track_per_program_time() {
        let rt = Runtime::native();
        let prog = rt.prepare("reg_profile").unwrap();
        let args = vec![
            crate::runtime::buffer::buffer_f32(&[0.1, 0.2], &[2]).unwrap(),
            crate::runtime::buffer::buffer_f32(&[2.0, 3.0, 4.0], &[3]).unwrap(),
        ];
        prog.call(&args).unwrap();
        prog.call(&args).unwrap();
        let stats = rt.stats();
        let per = stats.per_program.get("reg_profile").expect("per-program entry");
        assert_eq!(per.executions, 2);
        assert!(per.execute_secs >= 0.0);
        let top = stats.top_programs(5);
        assert_eq!(top[0].0, "reg_profile");
    }
}
