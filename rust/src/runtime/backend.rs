//! The pluggable execution backend abstraction.
//!
//! A [`Backend`] turns manifest-described programs into results: `compile`
//! prepares a program (cache warm / lazy-compile), `execute` runs it on
//! host [`Buffer`]s. Two implementations exist:
//!
//! * [`super::native::NativeBackend`] — pure Rust, hermetic, executes the
//!   WaveQ MLP train/eval program family directly on the host (always
//!   available; the default).
//! * `super::pjrt::PjrtBackend` — compiles AOT HLO-text artifacts through
//!   the XLA PJRT C API (behind the non-default `pjrt` cargo feature).
//!
//! [`Runtime`] is the coordinator-facing facade: it owns the [`Manifest`]
//! (the program/model contract), validates call arity against it, keeps
//! cumulative stats, and forwards to whichever backend it was opened with.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::buffer::Buffer;
use super::manifest::{Manifest, ProgramSig};
use super::native::NativeBackend;

/// Cumulative (compiles, executions) — surfaced by `waveq smoke`/metrics.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// An execution engine for manifest-described programs.
///
/// Implementations own their compile caches and timing; the [`Runtime`]
/// facade has already validated input arity against the manifest before
/// `execute` is called.
pub trait Backend {
    /// Human-readable platform tag ("native", "cpu", ...).
    fn platform_name(&self) -> String;

    /// Prepare a program for execution (idempotent; cheap when cached).
    fn compile(&self, sig: &ProgramSig) -> Result<()>;

    /// Execute a program on host buffers; one buffer per named output, in
    /// the manifest's output order.
    fn execute(&self, sig: &ProgramSig, args: &[&Buffer]) -> Result<Vec<Buffer>>;

    /// Cumulative compile/execute counters.
    fn stats(&self) -> RuntimeStats;
}

/// Backend-neutral runtime: manifest + stats + a boxed [`Backend`].
pub struct Runtime {
    backend: Box<dyn Backend>,
    pub manifest: Manifest,
}

impl Runtime {
    /// A hermetic runtime on the pure-Rust reference backend: the manifest
    /// is generated in-process (no artifacts directory, no Python, no XLA).
    pub fn native() -> Runtime {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        Runtime { backend: Box::new(backend), manifest }
    }

    /// Open an artifacts directory.
    ///
    /// With the `pjrt` feature the programs execute through PJRT on the AOT
    /// HLO artifacts in `dir`. Without it, the manifest is still loaded
    /// (signature lookups, model metadata, error paths all work) and
    /// execution is served by the native backend for the programs it
    /// implements. If `manifest.json` is absent the fully-native runtime
    /// is returned, so a clean clone works with zero built artifacts.
    pub fn open(dir: &Path) -> Result<Runtime> {
        if !dir.join("manifest.json").exists() {
            return Ok(Runtime::native());
        }
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { backend: open_backend(dir)?, manifest })
    }

    pub fn platform(&self) -> String {
        self.backend.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.backend.stats()
    }

    pub fn sig(&self, program: &str) -> Result<&ProgramSig> {
        self.manifest.program(program)
    }

    /// Execute a program on host buffers; returns one buffer per output.
    /// Accepts owned or borrowed buffers (`&[Buffer]` or `&[&Buffer]`).
    pub fn execute<B: std::borrow::Borrow<Buffer>>(
        &self,
        program: &str,
        args: &[B],
    ) -> Result<Vec<Buffer>> {
        let sig = self.manifest.program(program)?;
        if args.len() != sig.inputs.len() {
            return Err(anyhow!(
                "{program}: got {} args, signature has {}",
                args.len(),
                sig.inputs.len()
            ));
        }
        let refs: Vec<&Buffer> = args.iter().map(|a| a.borrow()).collect();
        let outs = self.backend.execute(sig, &refs)?;
        if outs.len() != sig.outputs.len() {
            return Err(anyhow!(
                "{program}: got {} outputs, manifest says {}",
                outs.len(),
                sig.outputs.len()
            ));
        }
        Ok(outs)
    }

    /// Pre-compile a set of programs (amortize compilation outside the loop).
    pub fn warmup(&self, programs: &[&str]) -> Result<()> {
        for p in programs {
            let sig = self.manifest.program(p)?;
            self.backend
                .compile(sig)
                .with_context(|| format!("warming up {p}"))?;
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
fn open_backend(dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::pjrt::PjrtBackend::open(dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn open_backend(_dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(NativeBackend::new()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::buffer::scalar_f32;

    #[test]
    fn native_runtime_has_programs_and_models() {
        let rt = Runtime::native();
        assert_eq!(rt.platform(), "native");
        assert!(rt.manifest.program("train_waveq_mlp").is_ok());
        assert!(rt.manifest.model("mlp").is_ok());
        assert!(rt.sig("nope").is_err());
    }

    #[test]
    fn arity_mismatch_is_rejected_before_dispatch() {
        let rt = Runtime::native();
        let args = vec![scalar_f32(0.0)];
        let err = rt.execute("train_fp32_mlp", &args).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("got 1 args"), "{msg}");
    }

    #[test]
    fn warmup_unknown_program_errors() {
        let rt = Runtime::native();
        assert!(rt.warmup(&["definitely_missing"]).is_err());
    }
}
