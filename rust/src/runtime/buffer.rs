//! Backend-neutral host buffer: the interchange value between the
//! coordinator and any [`super::backend::Backend`].
//!
//! A `Buffer` is a dense row-major f32 array with an explicit shape — the
//! same data model as `tensor::Tensor`, but kept as a distinct type so the
//! runtime contract (what crosses the backend boundary) is independent of
//! the host-side analysis substrate. The free helpers (`buffer_f32`,
//! `scalar_f32`, `to_vec_f32`, `to_scalar_f32`) mirror the shapes of the
//! old XLA literal helpers so call sites read identically on either
//! backend.

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;

/// A dense f32 array with row-major layout, owned on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct Buffer {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Buffer {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Buffer> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!(
                "buffer shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            ));
        }
        Ok(Buffer { shape, data })
    }

    /// Rank-0 scalar.
    pub fn scalar(v: f32) -> Buffer {
        Buffer { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: Vec<usize>) -> Buffer {
        let n = shape.iter().product();
        Buffer { shape, data: vec![0.0; n] }
    }

    pub fn elem_count(&self) -> usize {
        self.data.len()
    }

    pub fn is_scalar(&self) -> bool {
        self.shape.is_empty()
    }

    /// Overwrite the contents from a host slice without reallocating
    /// (shape unchanged) — the session's zero-alloc input refresh.
    pub fn fill_from(&mut self, data: &[f32]) -> Result<()> {
        if data.len() != self.data.len() {
            return Err(anyhow!(
                "fill_from: {} elems into a buffer of {} (shape {:?})",
                data.len(),
                self.data.len(),
                self.shape
            ));
        }
        self.data.copy_from_slice(data);
        Ok(())
    }

    /// View as the host-side analysis tensor (clones the data).
    pub fn to_tensor(&self) -> Result<Tensor> {
        Tensor::new(self.shape.clone(), self.data.clone())
    }

    pub fn from_tensor(t: &Tensor) -> Buffer {
        Buffer { shape: t.shape.clone(), data: t.data.clone() }
    }
}

/// Build an f32 buffer of the given shape from a host slice.
pub fn buffer_f32(data: &[f32], shape: &[usize]) -> Result<Buffer> {
    Buffer::new(shape.to_vec(), data.to_vec())
}

pub fn scalar_f32(v: f32) -> Buffer {
    Buffer::scalar(v)
}

/// Extract an f32 vector from a buffer.
pub fn to_vec_f32(b: &Buffer) -> Result<Vec<f32>> {
    Ok(b.data.clone())
}

/// Extract a scalar f32 (first element, like the old literal helper).
pub fn to_scalar_f32(b: &Buffer) -> Result<f32> {
    b.data
        .first()
        .copied()
        .ok_or_else(|| anyhow!("to_scalar_f32: empty buffer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_preserves_data_and_shape() {
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5 - 3.0).collect();
        let b = buffer_f32(&data, &[2, 3, 4]).unwrap();
        assert_eq!(b.shape, vec![2, 3, 4]);
        assert_eq!(to_vec_f32(&b).unwrap(), data);
        assert!(buffer_f32(&data, &[5, 5]).is_err());
    }

    #[test]
    fn scalar_helpers() {
        let s = scalar_f32(2.5);
        assert!(s.is_scalar());
        assert_eq!(to_scalar_f32(&s).unwrap(), 2.5);
    }

    #[test]
    fn fill_from_checks_length() {
        let mut b = buffer_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        b.fill_from(&[5.0, 6.0, 7.0, 8.0]).unwrap();
        assert_eq!(b.data, vec![5.0, 6.0, 7.0, 8.0]);
        assert!(b.fill_from(&[1.0]).is_err());
    }

    #[test]
    fn tensor_conversion() {
        let b = buffer_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let t = b.to_tensor().unwrap();
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(Buffer::from_tensor(&t), b);
    }
}
