//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled lazily and cached per program name, so the
//! coordinator's hot loop never recompiles.
//!
//! All programs return a single tuple (lowered with `return_tuple=True`);
//! [`Runtime::execute`] decomposes it into one `Literal` per named output.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{Manifest, ProgramSig};

pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// Cumulative (compiles, executions) — surfaced by `waveq smoke`/metrics.
    stats: RefCell<RuntimeStats>,
}

#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

impl Runtime {
    /// Open the artifacts directory (must contain manifest.json).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn sig(&self, program: &str) -> Result<&ProgramSig> {
        self.manifest.program(program)
    }

    /// Compile (or fetch cached) executable for a program.
    pub fn executable(&self, program: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(program) {
            return Ok(exe.clone());
        }
        let sig = self.manifest.program(program)?;
        let path = self.dir.join(&sig.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {program}: {e:?}"))?;
        let exe = Rc::new(exe);
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.borrow_mut().insert(program.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a program on host literals; returns one literal per output.
    /// Accepts owned or borrowed literals (`&[Literal]` or `&[&Literal]`).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        program: &str,
        args: &[L],
    ) -> Result<Vec<Literal>> {
        let sig = self.manifest.program(program)?;
        if args.len() != sig.inputs.len() {
            return Err(anyhow!(
                "{program}: got {} args, signature has {}",
                args.len(),
                sig.inputs.len()
            ));
        }
        let exe = self.executable(program)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<L>(args)
            .map_err(|e| anyhow!("executing {program}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {program} result: {e:?}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {program} result: {e:?}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        if outs.len() != sig.outputs.len() {
            return Err(anyhow!(
                "{program}: got {} outputs, manifest says {}",
                outs.len(),
                sig.outputs.len()
            ));
        }
        Ok(outs)
    }

    /// Pre-compile a set of programs (amortize XLA compile outside the loop).
    pub fn warmup(&self, programs: &[&str]) -> Result<()> {
        for p in programs {
            self.executable(p)
                .with_context(|| format!("warming up {p}"))?;
        }
        Ok(())
    }
}

// ---- Literal helpers --------------------------------------------------------

/// Build an f32 literal of the given shape from a host slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("literal_f32: {} elems for shape {:?}", data.len(), shape));
    }
    let lit = Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

/// Extract a scalar f32.
pub fn to_scalar_f32(lit: &Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar: {e:?}"))
}
