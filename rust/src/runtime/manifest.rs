//! Artifact manifest: the contract between `python/compile/aot.py` (writer)
//! and the rust coordinator (reader).
//!
//! The manifest describes, for every AOT program, the exact positional input
//! signature and output names, plus per-model parameter layout and MAC
//! counts (consumed by the Stripes energy model and the bitwidth manager).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::buffer::Buffer;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ProgramSig {
    pub name: String,
    pub file: String,
    pub model: Option<String>,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<String>,
}

impl ProgramSig {
    /// Index of the input named `name` (errors with program context).
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|a| a.name == name)
            .ok_or_else(|| anyhow!("program {} has no input '{name}'", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o == name)
            .ok_or_else(|| anyhow!("program {} has no output '{name}'", self.name))
    }
}

#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: String,
    /// Initialization kind: he | he_res (fixup-scaled) | ones | zeros.
    pub init: String,
    /// Slot in the per-layer bitwidth vector, if this weight is quantized.
    pub qidx: Option<usize>,
    /// Per-example MACs attributable to this parameter.
    pub macs: u64,
    pub count: u64,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    /// Dataset this model trains on (`data::synth` spec name, e.g.
    /// "svhn-lite"). Empty for manifests that predate the field; consumers
    /// fall back to shape-based inference (`data::spec_for_model`).
    pub dataset: String,
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    pub batch: usize,
    pub width_mult: usize,
    pub num_qlayers: usize,
    pub params: Vec<ParamMeta>,
}

impl ModelMeta {
    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_macs(&self) -> u64 {
        self.params.iter().map(|p| p.macs).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.params.iter().map(|p| p.count).sum()
    }

    /// (macs, weight count) for each quantizable layer, indexed by qidx.
    pub fn qlayer_stats(&self) -> Vec<(u64, u64)> {
        let mut v = vec![(0u64, 0u64); self.num_qlayers];
        for p in &self.params {
            if let Some(q) = p.qidx {
                v[q] = (p.macs, p.count);
            }
        }
        v
    }

    /// Build x/y buffers shaped for `rows` examples of this model — the
    /// single definition of the ragged-batch dispatch shape, shared by
    /// `Session::eval` and the coordinator's `evaluate` so the two paths
    /// cannot drift.
    pub fn batch_buffers(&self, rows: usize, x: &[f32], y: &[f32]) -> Result<(Buffer, Buffer)> {
        let is = self.input_shape;
        Ok((
            Buffer::new(vec![rows, is[0], is[1], is[2]], x.to_vec())?,
            Buffer::new(vec![rows, self.num_classes], y.to_vec())?,
        ))
    }

    /// Param indices of quantizable layers in qidx order.
    pub fn qlayer_param_indices(&self) -> Vec<usize> {
        let mut pairs: Vec<(usize, usize)> = self
            .params
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.qidx.map(|q| (q, i)))
            .collect();
        pairs.sort_unstable();
        pairs.into_iter().map(|(_, i)| i).collect()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub programs: BTreeMap<String, ProgramSig>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> Result<Manifest> {
        let mut programs = BTreeMap::new();
        for (name, p) in json
            .req("programs")
            .map_err(|e| anyhow!(e))?
            .as_obj()
            .ok_or_else(|| anyhow!("programs must be an object"))?
        {
            programs.insert(name.clone(), parse_program(name, p)?);
        }
        let mut models = BTreeMap::new();
        for (name, m) in json
            .req("models")
            .map_err(|e| anyhow!(e))?
            .as_obj()
            .ok_or_else(|| anyhow!("models must be an object"))?
        {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest { programs, models })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSig> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no program '{name}'"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no model '{name}'"))
    }
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    Ok(j.as_arr()
        .ok_or_else(|| anyhow!("shape must be an array"))?
        .iter()
        .filter_map(|d| d.as_usize())
        .collect())
}

fn parse_program(name: &str, p: &Json) -> Result<ProgramSig> {
    let inputs = p
        .req("inputs")
        .map_err(|e| anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow!("inputs must be an array"))?
        .iter()
        .map(|a| {
            Ok(ArgSpec {
                name: a.req("name").map_err(|e| anyhow!(e))?.as_str().unwrap_or("").to_string(),
                shape: shape_of(a.req("shape").map_err(|e| anyhow!(e))?)?,
                dtype: a
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("float32")
                    .to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let outputs = p
        .req("outputs")
        .map_err(|e| anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow!("outputs must be an array"))?
        .iter()
        .filter_map(|o| o.as_str().map(String::from))
        .collect();
    Ok(ProgramSig {
        name: name.to_string(),
        file: p
            .req("file")
            .map_err(|e| anyhow!(e))?
            .as_str()
            .ok_or_else(|| anyhow!("file must be a string"))?
            .to_string(),
        model: p.get("model").and_then(|m| m.as_str()).map(String::from),
        inputs,
        outputs,
    })
}

fn parse_model(name: &str, m: &Json) -> Result<ModelMeta> {
    let ishape = shape_of(m.req("input_shape").map_err(|e| anyhow!(e))?)?;
    if ishape.len() != 3 {
        return Err(anyhow!("model {name}: input_shape must have 3 dims"));
    }
    let params = m
        .req("params")
        .map_err(|e| anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow!("params must be an array"))?
        .iter()
        .map(|p| {
            Ok(ParamMeta {
                name: p.req("name").map_err(|e| anyhow!(e))?.as_str().unwrap_or("").to_string(),
                shape: shape_of(p.req("shape").map_err(|e| anyhow!(e))?)?,
                kind: p.get("kind").and_then(|k| k.as_str()).unwrap_or("other").to_string(),
                init: p.get("init").and_then(|k| k.as_str()).unwrap_or("he").to_string(),
                qidx: p.get("qidx").and_then(|q| q.as_usize()),
                macs: p.get("macs").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
                count: p.get("count").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelMeta {
        name: name.to_string(),
        dataset: m
            .get("dataset")
            .and_then(|d| d.as_str())
            .unwrap_or("")
            .to_string(),
        input_shape: [ishape[0], ishape[1], ishape[2]],
        num_classes: m.get("num_classes").and_then(|x| x.as_usize()).unwrap_or(10),
        batch: m.get("batch").and_then(|x| x.as_usize()).unwrap_or(64),
        width_mult: m.get("width_mult").and_then(|x| x.as_usize()).unwrap_or(1),
        num_qlayers: m.get("num_qlayers").and_then(|x| x.as_usize()).unwrap_or(0),
        params,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "programs": {
        "train_fp32_mlp": {
          "file": "train_fp32_mlp.hlo.txt",
          "model": "mlp",
          "inputs": [
            {"name": "w:fc1", "shape": [192, 128], "dtype": "float32"},
            {"name": "x", "shape": [128, 8, 8, 3], "dtype": "float32"}
          ],
          "outputs": ["w:fc1", "loss", "acc"]
        }
      },
      "models": {
        "mlp": {
          "name": "mlp", "input_shape": [8, 8, 3], "num_classes": 10,
          "batch": 128, "width_mult": 1, "num_qlayers": 2,
          "params": [
            {"name": "fc1", "shape": [192, 128], "kind": "fc", "qidx": null,
             "macs": 24576, "count": 24576},
            {"name": "fc2", "shape": [128, 128], "kind": "fc", "qidx": 0,
             "macs": 16384, "count": 16384}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        let p = m.program("train_fp32_mlp").unwrap();
        assert_eq!(p.inputs.len(), 2);
        assert_eq!(p.inputs[0].elem_count(), 192 * 128);
        assert_eq!(p.input_index("x").unwrap(), 1);
        assert_eq!(p.output_index("loss").unwrap(), 1);
        let model = m.model("mlp").unwrap();
        assert_eq!(model.num_params(), 2);
        assert_eq!(model.num_qlayers, 2);
        assert_eq!(model.qlayer_param_indices(), vec![1]);
        assert_eq!(model.total_macs(), 24576 + 16384);
    }

    #[test]
    fn missing_program_errors() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert!(m.program("nope").is_err());
        assert!(m.model("nope").is_err());
    }
}
