//! Binary checkpointing of train state (own format; no serde offline).
//!
//! v3 layout (little-endian), magic `WVQCKPT3`:
//!   magic | u32 n_tensors | per tensor:
//!     u32 name_len | name bytes | u32 rank | u64 dims[rank] | f32 data[]
//!   | u32 q | f32 beta[q] | f32 vbeta[q]
//!   | u64 step | u64 round | u32 model_len | model bytes
//!
//! v3 adds the distributed coordinator's round counter after `step`, so a
//! rejoining worker (or a resumed run) lands on the exact round boundary
//! the file was written at. The v2 trailer (step counter + model name) is
//! what v1 (`WVQCKPT1`) lacked: a restored run could not resume its
//! schedule position, and nothing stopped a vgg checkpoint from being
//! loaded into a resnet session. v1 and v2 files still load (missing
//! fields default to 0 / empty); `save` always writes v3.
//!
//! Lives in the runtime layer so [`super::session::Session`] can offer
//! `save_checkpoint` / `load_checkpoint` without reaching up into the
//! coordinator; `coordinator::checkpoint` re-exports [`Checkpoint`] for
//! existing call sites.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::io::{read_count, read_f32s, read_shape, read_string, read_u64};
use super::manifest::ModelMeta;
use super::session::SessionState;
use crate::tensor::Tensor;

const MAGIC_V1: &[u8; 8] = b"WVQCKPT1";
const MAGIC_V2: &[u8; 8] = b"WVQCKPT2";
const MAGIC_V3: &[u8; 8] = b"WVQCKPT3";

pub struct Checkpoint {
    pub tensors: Vec<(String, Tensor)>,
    pub beta: Vec<f32>,
    pub vbeta: Vec<f32>,
    /// Step counter at save time (0 for v1 files, which did not record it).
    pub step: usize,
    /// Distributed-training round at save time (0 for pre-v3 files and
    /// single-process runs).
    pub round: usize,
    /// Model the state belongs to (empty for v1 files).
    pub model: String,
}

impl Checkpoint {
    /// Snapshot a session's live state: parameters named by the model's
    /// manifest layout, plus beta/vbeta and the v2 trailer.
    pub fn from_state(model: &ModelMeta, state: &SessionState) -> Result<Checkpoint> {
        let tensors = state
            .all_params(model)?
            .into_iter()
            .zip(&model.params)
            .map(|(t, p)| (p.name.clone(), t))
            .collect();
        Ok(Checkpoint {
            tensors,
            beta: state.beta.clone(),
            vbeta: state.vbeta.clone(),
            step: state.step,
            round: 0,
            model: model.name.clone(),
        })
    }

    /// Stamp the distributed coordinator's round counter (defaults to 0).
    pub fn with_round(mut self, round: usize) -> Checkpoint {
        self.round = round;
        self
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC_V3)?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &v in &t.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        f.write_all(&(self.beta.len() as u32).to_le_bytes())?;
        for &v in self.beta.iter().chain(&self.vbeta) {
            f.write_all(&v.to_le_bytes())?;
        }
        f.write_all(&(self.step as u64).to_le_bytes())?;
        f.write_all(&(self.round as u64).to_le_bytes())?;
        f.write_all(&(self.model.len() as u32).to_le_bytes())?;
        f.write_all(self.model.as_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        let version = match &magic {
            m if m == MAGIC_V3 => 3,
            m if m == MAGIC_V2 => 2,
            m if m == MAGIC_V1 => 1,
            _ => return Err(anyhow!("{} is not a waveq checkpoint", path.display())),
        };
        let n = read_count(&mut f, "tensor")?;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name = read_string(&mut f)?;
            let (shape, count) = read_shape(&mut f)?;
            let mut data = vec![0f32; count];
            read_f32s(&mut f, &mut data)?;
            tensors.push((name, Tensor::new(shape, data)?));
        }
        let q = read_count(&mut f, "beta slot")?;
        let mut beta = vec![0f32; q];
        let mut vbeta = vec![0f32; q];
        read_f32s(&mut f, &mut beta)?;
        read_f32s(&mut f, &mut vbeta)?;
        let (step, round, model) = match version {
            3 => {
                let step = read_u64(&mut f)? as usize;
                let round = read_u64(&mut f)? as usize;
                (step, round, read_string(&mut f)?)
            }
            2 => (read_u64(&mut f)? as usize, 0, read_string(&mut f)?),
            _ => (0, 0, String::new()),
        };
        Ok(Checkpoint { tensors, beta, vbeta, step, round, model })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            tensors: vec![
                (
                    "w1".into(),
                    Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-9, -7.25]).unwrap(),
                ),
                ("b1".into(), Tensor::new(vec![3], vec![0.1, 0.2, 0.3]).unwrap()),
                ("scalar".into(), Tensor::new(vec![], vec![42.0]).unwrap()),
            ],
            beta: vec![3.3, 4.7],
            vbeta: vec![0.01, -0.02],
            step: 412,
            round: 17,
            model: "simplenet5".into(),
        }
    }

    #[test]
    fn round_trip_preserves_tensors_and_trailer() {
        let ck = sample();
        let path = std::env::temp_dir().join("waveq_ckpt_test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 3);
        for ((n1, t1), (n2, t2)) in ck.tensors.iter().zip(&back.tensors) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
        assert_eq!(back.beta, ck.beta);
        assert_eq!(back.vbeta, ck.vbeta);
        assert_eq!(back.step, 412);
        assert_eq!(back.round, 17);
        assert_eq!(back.model, "simplenet5");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_checkpoints_still_load_with_round_zero() {
        // The exact bytes the v2 writer emitted: one (1,)-tensor, q = 1,
        // then `u64 step | u32 model_len | model` with no round field.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"WVQCKPT2");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // n_tensors
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.extend_from_slice(b"w");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // rank
        bytes.extend_from_slice(&1u64.to_le_bytes()); // dim
        bytes.extend_from_slice(&2.5f32.to_le_bytes()); // data
        bytes.extend_from_slice(&1u32.to_le_bytes()); // q
        bytes.extend_from_slice(&4.0f32.to_le_bytes()); // beta
        bytes.extend_from_slice(&0.5f32.to_le_bytes()); // vbeta
        bytes.extend_from_slice(&99u64.to_le_bytes()); // step
        bytes.extend_from_slice(&3u32.to_le_bytes()); // model_len
        bytes.extend_from_slice(b"mlp");
        let path = std::env::temp_dir().join("waveq_ckpt_v2_test.bin");
        std::fs::write(&path, &bytes).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.step, 99);
        assert_eq!(ck.round, 0);
        assert_eq!(ck.model, "mlp");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_checkpoints_still_load_with_empty_trailer() {
        // Hand-assemble the v1 byte layout (what the pre-v2 writer emitted):
        // one (1,)-tensor "w", q = 1 beta section, no trailer.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"WVQCKPT1");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // n_tensors
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.extend_from_slice(b"w");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // rank
        bytes.extend_from_slice(&1u64.to_le_bytes()); // dim
        bytes.extend_from_slice(&2.5f32.to_le_bytes()); // data
        bytes.extend_from_slice(&1u32.to_le_bytes()); // q
        bytes.extend_from_slice(&4.0f32.to_le_bytes()); // beta
        bytes.extend_from_slice(&0.5f32.to_le_bytes()); // vbeta
        let path = std::env::temp_dir().join("waveq_ckpt_v1_test.bin");
        std::fs::write(&path, &bytes).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.tensors.len(), 1);
        assert_eq!(ck.tensors[0].0, "w");
        assert_eq!(ck.tensors[0].1.data, vec![2.5]);
        assert_eq!(ck.beta, vec![4.0f32]);
        assert_eq!(ck.vbeta, vec![0.5f32]);
        assert_eq!((ck.step, ck.model.as_str()), (0, ""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let path = std::env::temp_dir().join("waveq_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
