//! Forward-only inference sessions over frozen artifacts — the third
//! lifecycle stage of the runtime after `prepare` (programs) and `Session`
//! (training): **freeze and serve**.
//!
//! An [`InferenceSession`] opens a [`FrozenModel`] (bit-packed low-bit
//! weights, see [`super::artifact`]) under an [`InferCfg`] and serves
//! logits with none of the training path's baggage:
//!
//! * **no backward buffers, no optimizer state** — the op graph is walked
//!   forward-only; nothing is taped;
//! * **no steady-state allocation** — intermediates live in a shape-planned
//!   arena ([`NativeModel::infer_plan`]) sized once at `max_batch`, and
//!   weights are GEMM-packed once at open, so a dispatch is pure kernel
//!   work over preallocated storage;
//! * **batch-size polymorphic** — `infer(&x, batch)` serves any batch in
//!   `1..=max_batch` through the same persistent worker pool; the arena is
//!   sliced to the live batch, never reallocated.
//!
//! # The two-tier precision contract
//!
//! Precision is a first-class type, not a flag, because the two tiers make
//! different promises:
//!
//! * [`Precision::Exact`] (the default) — **bitwise identity**. GEMM
//!   weights are decoded *into their panels* ([`kn::PackedB::pack_codes`],
//!   the fused dequantize-into-panel path — the decoded f32 copy of a
//!   packed GEMM weight is never resident) and every kernel the walk
//!   dispatches is the *same* kernel (same tiles, same shard minimums,
//!   same reduction order) the native backend's eval programs run — so the
//!   logits are bitwise identical to evaluating the live training state,
//!   at any `WAVEQ_THREADS` and any batch. `tests/infer.rs` asserts this
//!   across the whole model zoo.
//!
//! * [`Precision::Int8`] (opt-in) — **bounded error, integer compute**.
//!   Layers whose weights are packed at <= 7 bits *and* whose input
//!   activations sit on the act-quant grid dispatch through
//!   [`kn::matmul_quant_into`]: the frozen codes stay integral end-to-end
//!   (recentred to i8 at open), activations are recovered as their exact
//!   u8 quantizer codes (`kn::act_codes_into` — no second quantization),
//!   products accumulate in i32 along the same fixed sequential-k chain,
//!   and one f32 rescale lands the output. Per logit the path stays within
//!   `2e-4 * (1 + sum_k |a_k||w_k|)` of the Exact logits (property-tested
//!   in `kernels.rs` over the GEMM shape grid; in practice the gap is the
//!   f32 GEMM's own rounding, ~1e-5 relative) and is bit-deterministic at
//!   any `WAVEQ_THREADS`. Layers the contract cannot cover — the first
//!   conv (raw f32 input), f32-stored params, 8-bit weight grids, float
//!   activations — fall back to the Exact kernels, so a session mixes
//!   paths per layer and `int_gemm_layers()` reports how many went
//!   integer.

use anyhow::{anyhow, Result};

use super::artifact::{FrozenModel, ParamStorage};
use super::manifest::ModelMeta;
use super::native::kernels as kn;
use super::native::models::OpNode;
use super::native::{pool, relu_quant, NativeModel};

/// The numeric contract an inference session (or server) operates under.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Bitwise-identical to evaluating the live training state on the
    /// fake-quant grid — the contract every existing test pins.
    #[default]
    Exact,
    /// Integer GEMM over the packed codes where the artifact permits,
    /// within the documented per-logit error bound; Exact fallback per
    /// layer otherwise.
    Int8,
}

impl Precision {
    /// Parse the CLI / config spelling.
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "exact" => Some(Precision::Exact),
            "int8" => Some(Precision::Int8),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Precision::Exact => "exact",
            Precision::Int8 => "int8",
        }
    }

    /// Wire encoding in the serve hello frame (v2).
    pub fn wire_code(self) -> u8 {
        match self {
            Precision::Exact => 0,
            Precision::Int8 => 1,
        }
    }

    pub fn from_wire(code: u8) -> Option<Precision> {
        match code {
            0 => Some(Precision::Exact),
            1 => Some(Precision::Int8),
            _ => None,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// How to open an [`InferenceSession`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InferCfg {
    /// Largest batch one dispatch serves; the arena is sized to it.
    pub max_batch: usize,
    /// Numeric tier; see the module docs for the two contracts.
    pub precision: Precision,
}

impl Default for InferCfg {
    fn default() -> InferCfg {
        InferCfg { max_batch: 1, precision: Precision::Exact }
    }
}

/// A forward-only, batch-polymorphic serving session over a frozen model.
pub struct InferenceSession {
    model: NativeModel,
    meta: ModelMeta,
    max_batch: usize,
    precision: Precision,
    act_levels: Option<f32>,
    /// Decoded f32 params in manifest order — depthwise filters, affine
    /// scales, biases. GEMM slots stay empty: their only resident forms
    /// are the packed panels below.
    weights: Vec<Vec<f32>>,
    /// Pre-packed f32 GEMM right operands (conv / projection / fc),
    /// indexed by parameter slot — decoded straight into panels from the
    /// frozen codes, or packed from the artifact's raw f32.
    packed: Vec<Option<kn::PackedB>>,
    /// The integer twins: recentred i8 code panels for slots the Int8
    /// contract covers. All `None` under `Precision::Exact`.
    quant: Vec<Option<kn::PackedQuant>>,
    /// Ping-pong activation arena (each side holds `plan.act * max_batch`).
    bufs: [Vec<f32>; 2],
    /// im2col scratch, residual save stack, projected-shortcut scratch.
    cols: Vec<f32>,
    skip: Vec<f32>,
    shortcut: Vec<f32>,
    /// u8 activation-code scratch for the integer GEMMs (empty when no
    /// layer dispatches integer).
    qcodes: Vec<u8>,
}

impl InferenceSession {
    /// Rebuild the op graph from the artifact's identity, pack every GEMM
    /// weight once (f32 panels always; i8 code panels for the layers
    /// `cfg.precision` lets go integer), and size the arena for
    /// `cfg.max_batch`.
    pub fn open(frozen: &FrozenModel, cfg: &InferCfg) -> Result<InferenceSession> {
        let max_batch = cfg.max_batch;
        if max_batch == 0 {
            return Err(anyhow!("InferenceSession: max_batch must be >= 1"));
        }
        if frozen.width_mult == 0 {
            return Err(anyhow!("artifact has width_mult 0"));
        }
        let model = NativeModel::by_name(&frozen.base, frozen.width_mult)
            .ok_or_else(|| anyhow!("artifact names unknown model '{}'", frozen.base))?;
        if frozen.params.len() != model.params.len() {
            return Err(anyhow!(
                "artifact carries {} params, model '{}' has {}",
                frozen.params.len(),
                model.name,
                model.params.len()
            ));
        }
        for (p, fp) in model.params.iter().zip(&frozen.params) {
            if fp.name != p.name || fp.shape != p.shape {
                return Err(anyhow!(
                    "artifact param '{}' {:?} does not match model param '{}' {:?}",
                    fp.name,
                    fp.shape,
                    p.name,
                    p.shape
                ));
            }
            if matches!(fp.storage, ParamStorage::Packed { .. }) && p.qidx.is_none() {
                return Err(anyhow!(
                    "artifact stores non-quantized param '{}' as packed codes",
                    fp.name
                ));
            }
        }

        // Which GEMM slots the Int8 contract covers: the weight must be
        // packed codes on an i8-representable grid (bits <= 7) and the
        // layer's input must sit on the act-quant grid — a static fact of
        // the op graph, decided by walking it with an "on the grid" flag.
        // Raw input starts float; Relu (and the post-add Relu inside
        // SkipAdd) puts the activation on the grid when the artifact
        // carries act levels; conv/fc/affine/GAP outputs leave it; maxpool
        // and flatten preserve it (max-pooling keeps the buffer max, so
        // even the recovered scale is bit-identical).
        let act_ok = matches!(frozen.act_levels, Some(ka) if ka <= 255.0);
        let mut int_ok = vec![false; model.params.len()];
        if cfg.precision == Precision::Int8 {
            let mut on_grid = false;
            let mut saves_grid: Vec<bool> = Vec::new();
            let storage_ok = |idx: usize| {
                matches!(&frozen.params[idx].storage,
                    ParamStorage::Packed { bits, .. } if *bits <= 7)
            };
            for op in &model.ops {
                match op {
                    OpNode::Conv { geom, pidx } => {
                        if !geom.depthwise {
                            int_ok[*pidx] = act_ok && on_grid && storage_ok(*pidx);
                        }
                        on_grid = false;
                    }
                    OpNode::Fc { widx, .. } => {
                        int_ok[*widx] = act_ok && on_grid && storage_ok(*widx);
                        on_grid = false;
                    }
                    OpNode::Affine { .. } | OpNode::GlobalAvgPool { .. } => on_grid = false,
                    OpNode::Relu => on_grid = act_ok,
                    OpNode::MaxPool { .. } | OpNode::Flatten => {}
                    OpNode::SkipSave => saves_grid.push(on_grid),
                    OpNode::SkipProj { pidx, .. } => {
                        let g = *saves_grid.last().expect("SkipProj without SkipSave");
                        int_ok[*pidx] = act_ok && g && storage_ok(*pidx);
                    }
                    OpNode::SkipAdd => {
                        saves_grid.pop().expect("SkipAdd without SkipSave");
                        on_grid = act_ok;
                    }
                }
            }
        }

        // Pack the GEMM weights once (conv / projection / fc): f32 panels
        // decoded straight from the codes (bitwise = pack(decode), with no
        // intermediate f32 tensor), plus i8 code panels where int_ok.
        let mut packed: Vec<Option<kn::PackedB>> = model.params.iter().map(|_| None).collect();
        let mut quant: Vec<Option<kn::PackedQuant>> = model.params.iter().map(|_| None).collect();
        let mut pack_slot = |idx: usize, kdim: usize, n: usize| {
            if packed[idx].is_some() {
                return;
            }
            match &frozen.params[idx].storage {
                ParamStorage::F32(data) => packed[idx] = Some(kn::PackedB::pack(data, kdim, n)),
                ParamStorage::Packed { bits, scale, codes } => {
                    let k_levels = 2u32.pow(*bits as u32) - 1;
                    packed[idx] =
                        Some(kn::PackedB::pack_codes(codes, k_levels as f32, *scale, kdim, n));
                    if int_ok[idx] {
                        quant[idx] =
                            Some(kn::PackedQuant::pack_codes(codes, k_levels, *scale, kdim, n));
                    }
                }
            }
        };
        for op in &model.ops {
            match op {
                OpNode::Conv { geom, pidx } if !geom.depthwise => {
                    pack_slot(*pidx, geom.kdim(), geom.cout);
                }
                OpNode::SkipProj { geom, pidx } => pack_slot(*pidx, geom.kdim(), geom.cout),
                OpNode::Fc { din, dout, widx, .. } => pack_slot(*widx, *din, *dout),
                _ => {}
            }
        }

        // Decode the non-GEMM params (depthwise filters, affine, biases);
        // GEMM slots live only as panels.
        let weights: Vec<Vec<f32>> = frozen
            .params
            .iter()
            .zip(&packed)
            .map(|(fp, pb)| if pb.is_some() { Vec::new() } else { fp.decode() })
            .collect();

        let plan = model.infer_plan();
        let any_int = quant.iter().any(Option::is_some);
        pool::ensure_started();
        let meta = model.meta();
        Ok(InferenceSession {
            bufs: [vec![0.0; plan.act * max_batch], vec![0.0; plan.act * max_batch]],
            cols: vec![0.0; plan.cols * max_batch],
            skip: vec![0.0; plan.skip * max_batch],
            shortcut: vec![0.0; plan.shortcut * max_batch],
            qcodes: vec![0u8; if any_int { plan.quant * max_batch } else { 0 }],
            model,
            meta,
            max_batch,
            precision: cfg.precision,
            act_levels: frozen.act_levels,
            weights,
            packed,
            quant,
        })
    }

    /// The manifest-side description of the served model.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// The numeric tier this session was opened under.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// How many GEMM layers dispatch through the integer path (0 under
    /// `Precision::Exact`, and for artifacts the Int8 contract cannot
    /// cover — fp32 activations, 8-bit weight grids).
    pub fn int_gemm_layers(&self) -> usize {
        self.quant.iter().filter(|q| q.is_some()).count()
    }

    /// Activation fake-quant level count the session applies (`None` =
    /// fp32 activations), as captured at freeze time.
    pub fn act_levels(&self) -> Option<f32> {
        self.act_levels
    }

    /// Run the forward pass on `batch` examples (`1..=max_batch`; `x` is
    /// NHWC-flattened, `batch * pixels` long) and return the logits slice
    /// (`batch * num_classes`). No allocation; any `WAVEQ_THREADS`.
    pub fn infer(&mut self, x: &[f32], batch: usize) -> Result<&[f32]> {
        if batch == 0 || batch > self.max_batch {
            return Err(anyhow!(
                "infer: batch {batch} outside this session's 1..={}",
                self.max_batch
            ));
        }
        let pix = self.model.pixels();
        if x.len() != batch * pix {
            return Err(anyhow!(
                "infer: x has {} elems, batch {batch} of {} needs {}",
                x.len(),
                self.model.name,
                batch * pix
            ));
        }
        let InferenceSession {
            model, act_levels, weights, packed, quant, bufs, cols, skip, shortcut, qcodes, ..
        } = self;
        let [buf_a, buf_b] = bufs;
        let act_ka = *act_levels;

        buf_a[..x.len()].copy_from_slice(x);
        let mut in_a = true; // which side holds the live activation
        let mut cur_len = x.len();
        // Residual bookkeeping: (offset, len) of saved activations in the
        // skip arena (a stack), plus the pending projected shortcut.
        let mut saves: Vec<(usize, usize)> = Vec::new();
        let mut skip_top = 0usize;
        let mut shortcut_len: Option<usize> = None;

        for op in &model.ops {
            match op {
                OpNode::Conv { geom, pidx } => {
                    let out_len = geom.rows(batch) * geom.cout;
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    if geom.depthwise {
                        kn::dwconv_fwd_into(
                            &s[..cur_len],
                            &weights[*pidx],
                            batch,
                            geom,
                            &mut d[..out_len],
                        );
                    } else {
                        let rows = geom.rows(batch);
                        let ccols = &mut cols[..rows * geom.kdim()];
                        kn::im2col_into(&s[..cur_len], batch, geom, ccols);
                        match quant[*pidx].as_ref() {
                            Some(pq) => {
                                // The scale comes from the *source*
                                // activation: a strided patch matrix can
                                // miss the buffer max, the buffer can't.
                                let ka = act_ka.expect("int slot without act levels");
                                let m = kn::act_scale(&s[..cur_len]);
                                let qc = &mut qcodes[..rows * geom.kdim()];
                                kn::act_codes_into(ccols, m, ka, qc);
                                let scale = m / ka;
                                kn::matmul_quant_into(qc, pq, rows, scale, None, &mut d[..out_len]);
                            }
                            None => {
                                let pb =
                                    packed[*pidx].as_ref().expect("conv weight packed at open");
                                kn::matmul_packed_into(ccols, pb, rows, None, &mut d[..out_len]);
                            }
                        }
                    }
                    cur_len = out_len;
                    in_a = !in_a;
                }
                OpNode::Fc { din, dout, widx, bidx } => {
                    debug_assert_eq!(cur_len, batch * din);
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    match quant[*widx].as_ref() {
                        Some(pq) => {
                            let ka = act_ka.expect("int slot without act levels");
                            let m = kn::act_scale(&s[..cur_len]);
                            let qc = &mut qcodes[..cur_len];
                            kn::act_codes_into(&s[..cur_len], m, ka, qc);
                            kn::matmul_quant_into(
                                qc,
                                pq,
                                batch,
                                m / ka,
                                Some(&weights[*bidx]),
                                &mut d[..batch * dout],
                            );
                        }
                        None => {
                            let pb = packed[*widx].as_ref().expect("fc weight packed at open");
                            kn::matmul_packed_into(
                                &s[..cur_len],
                                pb,
                                batch,
                                Some(&weights[*bidx]),
                                &mut d[..batch * dout],
                            );
                        }
                    }
                    cur_len = batch * dout;
                    in_a = !in_a;
                }
                OpNode::Affine { c, hw, sidx, bidx } => {
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    kn::affine_fwd_into(
                        &s[..cur_len],
                        &weights[*sidx],
                        &weights[*bidx],
                        batch * hw,
                        *c,
                        &mut d[..cur_len],
                    );
                    in_a = !in_a;
                }
                OpNode::Relu => {
                    let cur = if in_a { &mut *buf_a } else { &mut *buf_b };
                    let _ = relu_quant(&mut cur[..cur_len], act_ka, false);
                }
                OpNode::MaxPool { h, w, c, size } => {
                    let out_len = batch * (h / size) * (w / size) * c;
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    kn::maxpool_infer_into(
                        &s[..cur_len],
                        batch,
                        *h,
                        *w,
                        *c,
                        *size,
                        &mut d[..out_len],
                    );
                    cur_len = out_len;
                    in_a = !in_a;
                }
                OpNode::GlobalAvgPool { h, w, c } => {
                    let out_len = batch * c;
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    kn::gap_fwd_into(&s[..cur_len], batch, *h, *w, *c, &mut d[..out_len]);
                    cur_len = out_len;
                    in_a = !in_a;
                }
                OpNode::Flatten => {}
                OpNode::SkipSave => {
                    let cur = if in_a { &*buf_a } else { &*buf_b };
                    skip[skip_top..skip_top + cur_len].copy_from_slice(&cur[..cur_len]);
                    saves.push((skip_top, cur_len));
                    skip_top += cur_len;
                }
                OpNode::SkipProj { geom, pidx } => {
                    let &(off, len) = saves.last().expect("SkipProj without SkipSave");
                    let rows = geom.rows(batch);
                    let out_len = rows * geom.cout;
                    let ccols = &mut cols[..rows * geom.kdim()];
                    kn::im2col_into(&skip[off..off + len], batch, geom, ccols);
                    match quant[*pidx].as_ref() {
                        Some(pq) => {
                            let ka = act_ka.expect("int slot without act levels");
                            let m = kn::act_scale(&skip[off..off + len]);
                            let qc = &mut qcodes[..rows * geom.kdim()];
                            kn::act_codes_into(ccols, m, ka, qc);
                            let out = &mut shortcut[..out_len];
                            kn::matmul_quant_into(qc, pq, rows, m / ka, None, out);
                        }
                        None => {
                            let pb = packed[*pidx].as_ref().expect("proj weight packed at open");
                            kn::matmul_packed_into(ccols, pb, rows, None, &mut shortcut[..out_len]);
                        }
                    }
                    shortcut_len = Some(out_len);
                }
                OpNode::SkipAdd => {
                    let (off, len) = saves.pop().expect("SkipAdd without SkipSave");
                    skip_top = off;
                    let add: &[f32] = match shortcut_len.take() {
                        Some(sl) => &shortcut[..sl],
                        None => &skip[off..off + len],
                    };
                    let cur = if in_a { &mut *buf_a } else { &mut *buf_b };
                    debug_assert_eq!(cur_len, add.len());
                    for (hv, &sv) in cur[..cur_len].iter_mut().zip(add.iter()) {
                        *hv += sv;
                    }
                    let _ = relu_quant(&mut cur[..cur_len], act_ka, false);
                }
            }
        }
        debug_assert_eq!(cur_len, batch * model.num_classes);
        Ok(if in_a { &buf_a[..cur_len] } else { &buf_b[..cur_len] })
    }

    /// Convenience: logits -> (mean cross-entropy, accuracy) against a
    /// one-hot `y` (`batch * num_classes`) — the same `softmax_ce` the
    /// backend's eval programs run, so the scalars are bitwise comparable
    /// to `Session::eval`.
    pub fn eval(&mut self, x: &[f32], y: &[f32], batch: usize) -> Result<(f32, f32)> {
        let nc = self.model.num_classes;
        if y.len() != batch * nc {
            return Err(anyhow!(
                "eval: y has {} elems, batch {batch} needs {}",
                y.len(),
                batch * nc
            ));
        }
        let logits = self.infer(x, batch)?;
        let (loss, acc, _dlogits) = kn::softmax_ce(logits, y, batch, nc);
        Ok((loss, acc))
    }
}

/// Ping-pong selector: (source slice, destination slice) of the two arena
/// sides, by which side currently holds the live activation.
fn pick<'a>(a: &'a mut [f32], b: &'a mut [f32], in_a: bool) -> (&'a [f32], &'a mut [f32]) {
    if in_a {
        (&*a, b)
    } else {
        (&*b, a)
    }
}
