//! Forward-only inference sessions over frozen artifacts — the third
//! lifecycle stage of the runtime after `prepare` (programs) and `Session`
//! (training): **freeze and serve**.
//!
//! An [`InferenceSession`] opens a [`FrozenModel`] (bit-packed low-bit
//! weights, see [`super::artifact`]) and serves logits with none of the
//! training path's baggage:
//!
//! * **no backward buffers, no optimizer state** — the op graph is walked
//!   forward-only; nothing is taped;
//! * **no steady-state allocation** — intermediates live in a shape-planned
//!   arena ([`NativeModel::infer_plan`]) sized once at `max_batch`, and
//!   weights are decoded *and GEMM-packed* once at open, so a dispatch is
//!   pure kernel work over preallocated storage;
//! * **batch-size polymorphic** — `infer(&x, batch)` serves any batch in
//!   `1..=max_batch` through the same persistent worker pool; the arena is
//!   sliced to the live batch, never reallocated.
//!
//! Bit-identity contract: decoded weights reproduce the quantizer grid
//! bit-for-bit (the artifact's exact-unpack contract) and every kernel the
//! walk dispatches is the *same* kernel (same tiles, same shard minimums,
//! same reduction order) the native backend's eval programs run — so the
//! logits are bitwise identical to evaluating the live training state, at
//! any `WAVEQ_THREADS` and any batch. `tests/infer.rs` asserts this across
//! the whole model zoo.

use anyhow::{anyhow, Result};

use super::artifact::{FrozenModel, ParamStorage};
use super::manifest::ModelMeta;
use super::native::kernels as kn;
use super::native::models::OpNode;
use super::native::{pool, relu_quant, NativeModel};

/// A forward-only, batch-polymorphic serving session over a frozen model.
pub struct InferenceSession {
    model: NativeModel,
    meta: ModelMeta,
    max_batch: usize,
    act_levels: Option<f32>,
    /// Decoded f32 weights per parameter, manifest order (quantized layers
    /// land exactly on their fake-quant grid). Slots that dispatch through
    /// a [`kn::PackedB`] are emptied after packing — the packed panels are
    /// the only resident copy of the big GEMM weights, so the session's
    /// footprint stays at one copy per weight, not two.
    weights: Vec<Vec<f32>>,
    /// Pre-packed GEMM right operands for conv / projection / fc weights,
    /// indexed by parameter slot.
    packed: Vec<Option<kn::PackedB>>,
    /// Ping-pong activation arena (each side holds `plan.act * max_batch`).
    bufs: [Vec<f32>; 2],
    /// im2col scratch, residual save stack, projected-shortcut scratch.
    cols: Vec<f32>,
    skip: Vec<f32>,
    shortcut: Vec<f32>,
}

impl InferenceSession {
    /// Rebuild the op graph from the artifact's identity, decode + pack
    /// every weight once, and size the arena for `max_batch`.
    pub fn open(frozen: &FrozenModel, max_batch: usize) -> Result<InferenceSession> {
        if max_batch == 0 {
            return Err(anyhow!("InferenceSession: max_batch must be >= 1"));
        }
        if frozen.width_mult == 0 {
            return Err(anyhow!("artifact has width_mult 0"));
        }
        let model = NativeModel::by_name(&frozen.base, frozen.width_mult)
            .ok_or_else(|| anyhow!("artifact names unknown model '{}'", frozen.base))?;
        if frozen.params.len() != model.params.len() {
            return Err(anyhow!(
                "artifact carries {} params, model '{}' has {}",
                frozen.params.len(),
                model.name,
                model.params.len()
            ));
        }
        let mut weights = Vec::with_capacity(model.params.len());
        for (p, fp) in model.params.iter().zip(&frozen.params) {
            if fp.name != p.name || fp.shape != p.shape {
                return Err(anyhow!(
                    "artifact param '{}' {:?} does not match model param '{}' {:?}",
                    fp.name,
                    fp.shape,
                    p.name,
                    p.shape
                ));
            }
            if matches!(fp.storage, ParamStorage::Packed { .. }) && p.qidx.is_none() {
                return Err(anyhow!(
                    "artifact stores non-quantized param '{}' as packed codes",
                    fp.name
                ));
            }
            weights.push(fp.decode());
        }

        // Pack the GEMM weights once (conv / projection / fc); depthwise
        // convs and the small per-channel params dispatch unpacked.
        let mut packed: Vec<Option<kn::PackedB>> = model.params.iter().map(|_| None).collect();
        for op in &model.ops {
            match op {
                OpNode::Conv { geom, pidx } if !geom.depthwise => {
                    packed[*pidx] =
                        Some(kn::PackedB::pack(&weights[*pidx], geom.kdim(), geom.cout));
                }
                OpNode::SkipProj { geom, pidx } => {
                    packed[*pidx] =
                        Some(kn::PackedB::pack(&weights[*pidx], geom.kdim(), geom.cout));
                }
                OpNode::Fc { din, dout, widx, .. } => {
                    packed[*widx] = Some(kn::PackedB::pack(&weights[*widx], *din, *dout));
                }
                _ => {}
            }
        }

        // The GEMM slots are only ever read through their packed panels:
        // drop the decoded f32 copy so the big weights exist once.
        for (w, pb) in weights.iter_mut().zip(&packed) {
            if pb.is_some() {
                *w = Vec::new();
            }
        }

        let plan = model.infer_plan();
        pool::ensure_started();
        let meta = model.meta();
        Ok(InferenceSession {
            bufs: [vec![0.0; plan.act * max_batch], vec![0.0; plan.act * max_batch]],
            cols: vec![0.0; plan.cols * max_batch],
            skip: vec![0.0; plan.skip * max_batch],
            shortcut: vec![0.0; plan.shortcut * max_batch],
            model,
            meta,
            max_batch,
            act_levels: frozen.act_levels,
            weights,
            packed,
        })
    }

    /// The manifest-side description of the served model.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Activation fake-quant level count the session applies (`None` =
    /// fp32 activations), as captured at freeze time.
    pub fn act_levels(&self) -> Option<f32> {
        self.act_levels
    }

    /// Run the forward pass on `batch` examples (`1..=max_batch`; `x` is
    /// NHWC-flattened, `batch * pixels` long) and return the logits slice
    /// (`batch * num_classes`). No allocation; any `WAVEQ_THREADS`.
    pub fn infer(&mut self, x: &[f32], batch: usize) -> Result<&[f32]> {
        if batch == 0 || batch > self.max_batch {
            return Err(anyhow!(
                "infer: batch {batch} outside this session's 1..={}",
                self.max_batch
            ));
        }
        let pix = self.model.pixels();
        if x.len() != batch * pix {
            return Err(anyhow!(
                "infer: x has {} elems, batch {batch} of {} needs {}",
                x.len(),
                self.model.name,
                batch * pix
            ));
        }
        let InferenceSession {
            model, act_levels, weights, packed, bufs, cols, skip, shortcut, ..
        } = self;
        let [buf_a, buf_b] = bufs;
        let act_ka = *act_levels;

        buf_a[..x.len()].copy_from_slice(x);
        let mut in_a = true; // which side holds the live activation
        let mut cur_len = x.len();
        // Residual bookkeeping: (offset, len) of saved activations in the
        // skip arena (a stack), plus the pending projected shortcut.
        let mut saves: Vec<(usize, usize)> = Vec::new();
        let mut skip_top = 0usize;
        let mut shortcut_len: Option<usize> = None;

        for op in &model.ops {
            match op {
                OpNode::Conv { geom, pidx } => {
                    let out_len = geom.rows(batch) * geom.cout;
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    if geom.depthwise {
                        kn::dwconv_fwd_into(
                            &s[..cur_len],
                            &weights[*pidx],
                            batch,
                            geom,
                            &mut d[..out_len],
                        );
                    } else {
                        let rows = geom.rows(batch);
                        let ccols = &mut cols[..rows * geom.kdim()];
                        kn::im2col_into(&s[..cur_len], batch, geom, ccols);
                        let pb = packed[*pidx].as_ref().expect("conv weight packed at open");
                        kn::matmul_packed_into(ccols, pb, rows, None, &mut d[..out_len]);
                    }
                    cur_len = out_len;
                    in_a = !in_a;
                }
                OpNode::Fc { din, dout, widx, bidx } => {
                    debug_assert_eq!(cur_len, batch * din);
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    let pb = packed[*widx].as_ref().expect("fc weight packed at open");
                    kn::matmul_packed_into(
                        &s[..cur_len],
                        pb,
                        batch,
                        Some(&weights[*bidx]),
                        &mut d[..batch * dout],
                    );
                    cur_len = batch * dout;
                    in_a = !in_a;
                }
                OpNode::Affine { c, hw, sidx, bidx } => {
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    kn::affine_fwd_into(
                        &s[..cur_len],
                        &weights[*sidx],
                        &weights[*bidx],
                        batch * hw,
                        *c,
                        &mut d[..cur_len],
                    );
                    in_a = !in_a;
                }
                OpNode::Relu => {
                    let cur = if in_a { &mut *buf_a } else { &mut *buf_b };
                    let _ = relu_quant(&mut cur[..cur_len], act_ka, false);
                }
                OpNode::MaxPool { h, w, c, size } => {
                    let out_len = batch * (h / size) * (w / size) * c;
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    kn::maxpool_infer_into(
                        &s[..cur_len],
                        batch,
                        *h,
                        *w,
                        *c,
                        *size,
                        &mut d[..out_len],
                    );
                    cur_len = out_len;
                    in_a = !in_a;
                }
                OpNode::GlobalAvgPool { h, w, c } => {
                    let out_len = batch * c;
                    let (s, d) = pick(buf_a, buf_b, in_a);
                    kn::gap_fwd_into(&s[..cur_len], batch, *h, *w, *c, &mut d[..out_len]);
                    cur_len = out_len;
                    in_a = !in_a;
                }
                OpNode::Flatten => {}
                OpNode::SkipSave => {
                    let cur = if in_a { &*buf_a } else { &*buf_b };
                    skip[skip_top..skip_top + cur_len].copy_from_slice(&cur[..cur_len]);
                    saves.push((skip_top, cur_len));
                    skip_top += cur_len;
                }
                OpNode::SkipProj { geom, pidx } => {
                    let &(off, len) = saves.last().expect("SkipProj without SkipSave");
                    let rows = geom.rows(batch);
                    let out_len = rows * geom.cout;
                    let ccols = &mut cols[..rows * geom.kdim()];
                    kn::im2col_into(&skip[off..off + len], batch, geom, ccols);
                    let pb = packed[*pidx].as_ref().expect("proj weight packed at open");
                    kn::matmul_packed_into(ccols, pb, rows, None, &mut shortcut[..out_len]);
                    shortcut_len = Some(out_len);
                }
                OpNode::SkipAdd => {
                    let (off, len) = saves.pop().expect("SkipAdd without SkipSave");
                    skip_top = off;
                    let add: &[f32] = match shortcut_len.take() {
                        Some(sl) => &shortcut[..sl],
                        None => &skip[off..off + len],
                    };
                    let cur = if in_a { &mut *buf_a } else { &mut *buf_b };
                    debug_assert_eq!(cur_len, add.len());
                    for (hv, &sv) in cur[..cur_len].iter_mut().zip(add.iter()) {
                        *hv += sv;
                    }
                    let _ = relu_quant(&mut cur[..cur_len], act_ka, false);
                }
            }
        }
        debug_assert_eq!(cur_len, batch * model.num_classes);
        Ok(if in_a { &buf_a[..cur_len] } else { &buf_b[..cur_len] })
    }

    /// Convenience: logits -> (mean cross-entropy, accuracy) against a
    /// one-hot `y` (`batch * num_classes`) — the same `softmax_ce` the
    /// backend's eval programs run, so the scalars are bitwise comparable
    /// to `Session::eval`.
    pub fn eval(&mut self, x: &[f32], y: &[f32], batch: usize) -> Result<(f32, f32)> {
        let nc = self.model.num_classes;
        if y.len() != batch * nc {
            return Err(anyhow!(
                "eval: y has {} elems, batch {batch} needs {}",
                y.len(),
                batch * nc
            ));
        }
        let logits = self.infer(x, batch)?;
        let (loss, acc, _dlogits) = kn::softmax_ce(logits, y, batch, nc);
        Ok((loss, acc))
    }
}

/// Ping-pong selector: (source slice, destination slice) of the two arena
/// sides, by which side currently holds the live activation.
fn pick<'a>(a: &'a mut [f32], b: &'a mut [f32], in_a: bool) -> (&'a [f32], &'a mut [f32]) {
    if in_a {
        (&*a, b)
    } else {
        (&*b, a)
    }
}
