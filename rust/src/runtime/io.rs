//! Little-endian binary readers shared by the runtime's on-disk formats
//! (`checkpoint` / `artifact`): one definition, so corruption guards and
//! bounds policy cannot drift between the two.

use std::io::Read;

use anyhow::{anyhow, Result};

pub(crate) fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn read_f32<R: Read>(r: &mut R) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

pub(crate) fn read_f32s<R: Read>(r: &mut R, out: &mut [f32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (o, chunk) in out.iter_mut().zip(buf.chunks_exact(4)) {
        *o = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    Ok(())
}

/// A length-prefixed UTF-8 string, with an allocation bound so a corrupt
/// length field cannot demand gigabytes.
pub(crate) fn read_string<R: Read>(r: &mut R) -> Result<String> {
    let len = read_u32(r)? as usize;
    if len > 1 << 20 {
        return Err(anyhow!("string length {len} is implausible (corrupt file?)"));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

/// Most elements a single stored tensor may claim (2^28 = 1 GiB of f32);
/// the largest real zoo tensor is ~10^7 elements, so anything past this is
/// a corrupt dim field, not data.
pub(crate) const MAX_TENSOR_ELEMS: usize = 1 << 28;

/// Most entries a stored collection (params, tensors, beta slots) may
/// claim; bounds the `Vec::with_capacity` a corrupt count field drives.
pub(crate) const MAX_ENTRIES: usize = 1 << 16;

/// A tensor shape (u32 rank + u64 dims), bounded against corruption:
/// a flipped rank or dim byte errors cleanly instead of demanding huge
/// allocations (or overflowing the element-count product downstream).
/// Returns `(shape, element_count)`.
pub(crate) fn read_shape<R: Read>(r: &mut R) -> Result<(Vec<usize>, usize)> {
    let rank = read_u32(r)? as usize;
    if rank > 8 {
        return Err(anyhow!("tensor rank {rank} is implausible (corrupt file?)"));
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u64(r)? as usize);
    }
    let mut count = 1usize;
    for &d in &shape {
        count = count
            .checked_mul(d)
            .filter(|&c| c <= MAX_TENSOR_ELEMS)
            .ok_or_else(|| anyhow!("tensor shape {shape:?} is implausible (corrupt file?)"))?;
    }
    Ok((shape, count))
}

/// A u32 collection count, bounded by [`MAX_ENTRIES`].
pub(crate) fn read_count<R: Read>(r: &mut R, what: &str) -> Result<usize> {
    let n = read_u32(r)? as usize;
    if n > MAX_ENTRIES {
        return Err(anyhow!("{what} count {n} is implausible (corrupt file?)"));
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape_bytes(dims: &[u64]) -> Vec<u8> {
        let mut b = (dims.len() as u32).to_le_bytes().to_vec();
        for d in dims {
            b.extend_from_slice(&d.to_le_bytes());
        }
        b
    }

    #[test]
    fn read_shape_bounds_rank_and_element_count() {
        let good = shape_bytes(&[3, 3, 16, 32]);
        let (shape, count) = read_shape(&mut good.as_slice()).unwrap();
        assert_eq!((shape, count), (vec![3, 3, 16, 32], 4608));
        assert_eq!(read_shape(&mut shape_bytes(&[]).as_slice()).unwrap(), (vec![], 1));

        // Corrupt rank field.
        let bad_rank = u32::MAX.to_le_bytes().to_vec();
        assert!(read_shape(&mut bad_rank.as_slice()).is_err());
        // One flipped dim demanding ~2^64 elements.
        let bad_dim = shape_bytes(&[3, u64::MAX]);
        assert!(read_shape(&mut bad_dim.as_slice()).is_err());
        // Product overflowing usize via individually-plausible dims.
        let overflow = shape_bytes(&[1 << 32, 1 << 32]);
        assert!(read_shape(&mut overflow.as_slice()).is_err());
    }

    #[test]
    fn read_count_bounds_collection_sizes() {
        let ok = 12u32.to_le_bytes();
        assert_eq!(read_count(&mut ok.as_slice(), "param").unwrap(), 12);
        let bad = u32::MAX.to_le_bytes();
        assert!(read_count(&mut bad.as_slice(), "param").is_err());
    }
}
