//! Concurrent serving over frozen artifacts — the `waveq serve` engine.
//!
//! [`Server::start`] opens N [`InferenceSession`]s over one [`FrozenModel`]
//! (each session decodes and GEMM-packs its own weights; sessions share no
//! mutable state) and parks each on a shared request queue. Clients submit
//! single-example requests through cloned [`ServeClient`] handles; an idle
//! worker gathers up to `max_batch` of them into its arena — waiting at
//! most `deadline` after the first request lands — dispatches the batch
//! once, and fans the per-example logit rows back to each requester.
//!
//! Identity contract: the native kernels compute every output element with
//! a reduction order fixed by tile constants, independent of both thread
//! count *and* batch size, and the per-example rows of a batched forward
//! never mix (im2col rows, GEMM rows, pooling windows and the GAP/affine
//! loops are all per-example). So the logits a request receives are
//! **bitwise identical** whether it was served alone (batch 1) or packed
//! into a full cross-request batch — `tests/serve.rs` asserts this through
//! the TCP front end under concurrent load.
//!
//! Gathering happens *under the queue lock*, so exactly one worker fills a
//! batch at a time; it releases the lock before the forward pass, letting
//! the next worker gather while it computes. The kernel pool underneath
//! (`native::pool`) is shared by every worker — concurrent `run_rows`
//! dispatches are safe (each owns a private completion latch) and never
//! change the bits (see the pool docs).
//!
//! Latency/throughput knobs: `deadline` trades single-stream latency for
//! cross-stream batching (a lone synchronous client pays the deadline on
//! every request; 8 concurrent clients fill batches long before it).
//! `deadline == 0` disables waiting — the gatherer drains whatever is
//! already queued and goes. `max_batch == 1` turns the server into plain
//! request-at-a-time serving.
//!
//! Wire protocol ([`serve_tcp`]): length-prefixed little-endian frames.
//! On accept the server writes a versioned hello (v2): magic `b"WQSV"`,
//! u32 version, u32 pixels, u32 classes, then what the server *is* — a u8
//! [`Precision`] code, the artifact identity (u32 length + base name,
//! u32 width_mult), and the per-quantized-layer bit assignment (u32 count
//! + that many u8s) — so a client can verify what it is talking to before
//! sending a single example. Each request is `u32 count` (must equal
//! pixels) + `count` f32s; each response is `u32 count == classes` + the
//! logits, or the error marker `u32 0xFFFF_FFFF` + u32 length + a UTF-8
//! message. A `count == 0` request frame closes the connection cleanly.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::artifact::FrozenModel;
use super::infer::{InferCfg, InferenceSession, Precision};
use super::manifest::ModelMeta;
use crate::util::timer::BenchStats;

/// Hello magic the TCP front end writes on accept.
pub const MAGIC: &[u8; 4] = b"WQSV";
/// Hello frame version this build speaks (see the module docs). v1 had no
/// version field; v2 added it along with precision + artifact identity.
pub const HELLO_VERSION: u32 = 2;
/// Response-frame count value marking an error payload.
const ERR_MARK: u32 = u32::MAX;

/// Server shape: worker count, batch arena size, the batching window, and
/// the numeric tier every worker session opens under.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Inference worker threads; each owns one `InferenceSession`.
    pub workers: usize,
    /// Cross-request batch capacity (the per-worker arena size).
    pub max_batch: usize,
    /// How long a gatherer waits for its batch to fill after the first
    /// request arrives. Zero = dispatch whatever is already queued.
    pub deadline: Duration,
    /// Numeric contract of every worker session (see `runtime::infer`);
    /// advertised to clients in the hello frame and in [`ServeSnapshot`].
    pub precision: Precision,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            workers: 2,
            max_batch: 8,
            deadline: Duration::from_millis(1),
            precision: Precision::Exact,
        }
    }
}

/// What a serve instance *is*: the artifact identity plus the active
/// numeric tier — advertised in the hello frame, embedded in every
/// [`ServeSnapshot`], and printed by `waveq serve` stats output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeIdentity {
    /// Zoo base name of the served artifact.
    pub base: String,
    pub width_mult: usize,
    /// The numeric tier every worker session runs at.
    pub precision: Precision,
    /// Per-quantized-layer bitwidths in parameter order (the artifact's
    /// `layer_bits`, narrowed to u8 — bits are 2..=8).
    pub layer_bits: Vec<u8>,
    /// How many GEMM layers actually dispatch through the integer path in
    /// each worker (0 under `Precision::Exact`).
    pub int_gemm_layers: usize,
}

impl ServeIdentity {
    /// `base` x `width_mult` in the zoo's display spelling.
    pub fn model_label(&self) -> String {
        format!("{}_w{}", self.base, self.width_mult)
    }
}

/// One queued inference request: a single example plus its reply channel.
struct Request {
    x: Vec<f32>,
    resp: Sender<Response>,
}

/// Per-request reply: the example's logits, or a serve-side error message.
type Response = std::result::Result<Vec<f32>, String>;

/// Batching counters, updated by workers as batches dispatch.
#[derive(Default)]
pub struct ServeStats {
    requests: AtomicU64,
    batches: AtomicU64,
    full_batches: AtomicU64,
}

impl ServeStats {
    fn record(&self, n: usize, max_batch: usize) {
        self.requests.fetch_add(n as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        if n == max_batch {
            self.full_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self, identity: &ServeIdentity) -> ServeSnapshot {
        ServeSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            full_batches: self.full_batches.load(Ordering::Relaxed),
            identity: identity.clone(),
        }
    }
}

/// Point-in-time copy of [`ServeStats`], stamped with the server's
/// identity so a stats reader can verify what it is talking to.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeSnapshot {
    /// Examples served.
    pub requests: u64,
    /// Forward passes dispatched.
    pub batches: u64,
    /// Dispatches that filled the whole `max_batch` arena.
    pub full_batches: u64,
    /// Artifact identity + active precision of the serving instance.
    pub identity: ServeIdentity,
}

impl ServeSnapshot {
    /// Mean examples per dispatched batch (1.0 = no cross-request batching
    /// happened, `max_batch` = every dispatch went out full).
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// A running serve instance: N session workers over a shared request queue.
pub struct Server {
    queue: Sender<Request>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<ServeStats>,
    meta: ModelMeta,
    pix: usize,
    cfg: ServeCfg,
    identity: Arc<ServeIdentity>,
}

impl Server {
    /// Open `cfg.workers` inference sessions over `frozen` (errors surface
    /// here, before any thread exists) and start the worker threads. Every
    /// worker session opens at `cfg.precision`.
    pub fn start(frozen: &FrozenModel, cfg: &ServeCfg) -> Result<Server> {
        if cfg.workers == 0 {
            return Err(anyhow!("serve: workers must be >= 1"));
        }
        let icfg = InferCfg { max_batch: cfg.max_batch, precision: cfg.precision };
        let mut sessions = Vec::with_capacity(cfg.workers);
        for _ in 0..cfg.workers {
            sessions.push(InferenceSession::open(frozen, &icfg)?);
        }
        let identity = Arc::new(ServeIdentity {
            base: frozen.base.clone(),
            width_mult: frozen.width_mult,
            precision: cfg.precision,
            layer_bits: frozen.layer_bits().iter().map(|&b| b as u8).collect(),
            int_gemm_layers: sessions[0].int_gemm_layers(),
        });
        let meta = sessions[0].meta().clone();
        let pix: usize = meta.input_shape.iter().product();
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(ServeStats::default());
        let mut workers = Vec::with_capacity(cfg.workers);
        for (i, session) in sessions.into_iter().enumerate() {
            let rx = Arc::clone(&rx);
            let stats = Arc::clone(&stats);
            let deadline = cfg.deadline;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("waveq-serve-{i}"))
                    .spawn(move || worker_loop(session, &rx, deadline, &stats))
                    .map_err(|e| anyhow!("spawning serve worker {i}: {e}"))?,
            );
        }
        Ok(Server { queue: tx, workers, stats, meta, pix, cfg: cfg.clone(), identity })
    }

    /// A handle clients submit requests through. Cheap to clone; safe to
    /// move to any thread (TCP connection handlers each own one).
    pub fn client(&self) -> ServeClient {
        let queue = self.queue.clone();
        ServeClient {
            queue,
            pix: self.pix,
            num_classes: self.meta.num_classes,
            identity: Arc::clone(&self.identity),
        }
    }

    /// The manifest-side description of the served model.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn cfg(&self) -> &ServeCfg {
        &self.cfg
    }

    /// Artifact identity + active precision, as advertised to clients.
    pub fn identity(&self) -> &ServeIdentity {
        &self.identity
    }

    /// Batching counters so far (how full the dispatched batches ran),
    /// stamped with the server's identity.
    pub fn stats(&self) -> ServeSnapshot {
        self.stats.snapshot(&self.identity)
    }

    /// Stop accepting work and join the workers. Blocks until every
    /// outstanding [`ServeClient`] is dropped — their queue handles keep
    /// the workers alive until then.
    pub fn shutdown(self) {
        let Server { queue, workers, .. } = self;
        drop(queue);
        for w in workers {
            let _ = w.join();
        }
    }
}

/// Cloneable request-submission handle for a [`Server`].
#[derive(Clone)]
pub struct ServeClient {
    queue: Sender<Request>,
    pix: usize,
    num_classes: usize,
    identity: Arc<ServeIdentity>,
}

impl ServeClient {
    /// Flattened input length the model takes (`x.len()` for `infer_one`).
    pub fn pixels(&self) -> usize {
        self.pix
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// The server's advertised identity (written into the hello frame).
    pub fn identity(&self) -> &ServeIdentity {
        &self.identity
    }

    /// Submit one example and block until its logits come back. The reply
    /// is bitwise identical however the server batched the request.
    pub fn infer_one(&self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.pix {
            return Err(anyhow!(
                "infer_one: x has {} values, the served model takes {}",
                x.len(),
                self.pix
            ));
        }
        let (tx, rx) = channel();
        self.queue
            .send(Request { x: x.to_vec(), resp: tx })
            .map_err(|_| anyhow!("server has shut down"))?;
        match rx.recv() {
            Ok(Ok(logits)) => Ok(logits),
            Ok(Err(msg)) => Err(anyhow!("serve: {msg}")),
            Err(_) => Err(anyhow!("server dropped the request (shutting down?)")),
        }
    }
}

/// One serve worker: gather a batch under the queue lock, release the
/// lock, run the forward pass, fan the logit rows back out.
fn worker_loop(
    mut session: InferenceSession,
    rx: &Mutex<Receiver<Request>>,
    deadline: Duration,
    stats: &ServeStats,
) {
    let pix: usize = session.meta().input_shape.iter().product();
    let nc = session.meta().num_classes;
    let max_batch = session.max_batch();
    let mut arena = vec![0.0f32; max_batch * pix];
    let mut pending: Vec<Sender<Response>> = Vec::with_capacity(max_batch);
    loop {
        pending.clear();
        {
            // Holding the lock across the whole gather means exactly one
            // worker fills a batch at a time (no interleaved stealing);
            // idle peers queue on the mutex and take over the moment this
            // gatherer releases it to go compute.
            let q = rx.lock().unwrap_or_else(|e| e.into_inner());
            let first = match q.recv() {
                Ok(r) => r,
                Err(_) => return, // server shut down, queue drained
            };
            let t0 = Instant::now();
            admit(&mut arena, &mut pending, first, pix);
            while pending.len() < max_batch {
                let req = match deadline.checked_sub(t0.elapsed()) {
                    Some(left) if !left.is_zero() => match q.recv_timeout(left) {
                        Ok(r) => r,
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    },
                    // Deadline spent (or zero): drain without waiting.
                    _ => match q.try_recv() {
                        Ok(r) => r,
                        Err(_) => break,
                    },
                };
                admit(&mut arena, &mut pending, req, pix);
            }
        }
        let n = pending.len();
        if n == 0 {
            continue; // every gathered request was malformed and answered
        }
        stats.record(n, max_batch);
        match session.infer(&arena[..n * pix], n) {
            Ok(logits) => {
                // A dead responder (client gave up) is its own problem —
                // the rest of the batch still gets its rows.
                for (i, resp) in pending.drain(..).enumerate() {
                    let _ = resp.send(Ok(logits[i * nc..(i + 1) * nc].to_vec()));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for resp in pending.drain(..) {
                    let _ = resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Copy a request into its batch-arena slot, or answer it with an error
/// right away when the payload length is wrong — a malformed request must
/// not poison the batch it would have joined.
fn admit(arena: &mut [f32], pending: &mut Vec<Sender<Response>>, req: Request, pix: usize) {
    if req.x.len() != pix {
        let msg = format!("request has {} values, the served model takes {pix}", req.x.len());
        let _ = req.resp.send(Err(msg));
        return;
    }
    let slot = pending.len();
    arena[slot * pix..(slot + 1) * pix].copy_from_slice(&req.x);
    pending.push(req.resp);
}

// --- TCP front end ---------------------------------------------------------

fn write_u32<W: Write>(w: &mut W, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)
}

fn read_f32s<R: Read>(r: &mut R, out: &mut [f32]) -> std::io::Result<()> {
    let mut bytes = vec![0u8; out.len() * 4];
    r.read_exact(&mut bytes)?;
    for (v, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

/// Accept connections on `listener` and serve each on its own thread
/// through a cloned [`ServeClient`]. `max_conns` bounds how many
/// connections are accepted before returning (tests and the loopback
/// bench); `None` accepts forever (the CLI). Joins every connection
/// handler before returning.
pub fn serve_tcp(server: &Server, listener: TcpListener, max_conns: Option<usize>) -> Result<()> {
    let mut handles = Vec::new();
    let mut accepted = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        let client = server.client();
        accepted += 1;
        handles.push(
            std::thread::Builder::new()
                .name(format!("waveq-conn-{accepted}"))
                .spawn(move || {
                    let _ = serve_conn(stream, &client);
                })
                .map_err(|e| anyhow!("spawning connection handler: {e}"))?,
        );
        if max_conns.is_some_and(|m| accepted >= m) {
            break;
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Write the v2 hello: magic, version, model dims, then the server's
/// identity (precision code, artifact base + width_mult, per-layer bits).
fn write_hello<W: Write>(
    w: &mut W,
    pix: usize,
    num_classes: usize,
    id: &ServeIdentity,
) -> std::io::Result<()> {
    w.write_all(MAGIC)?;
    write_u32(w, HELLO_VERSION)?;
    write_u32(w, pix as u32)?;
    write_u32(w, num_classes as u32)?;
    w.write_all(&[id.precision.wire_code()])?;
    write_u32(w, id.base.len() as u32)?;
    w.write_all(id.base.as_bytes())?;
    write_u32(w, id.width_mult as u32)?;
    write_u32(w, id.layer_bits.len() as u32)?;
    w.write_all(&id.layer_bits)?;
    write_u32(w, id.int_gemm_layers as u32)?;
    w.flush()
}

/// Serve one connection: hello, then request/response frames until the
/// client sends a zero-count frame or closes the socket.
fn serve_conn(mut stream: TcpStream, client: &ServeClient) -> std::io::Result<()> {
    let _ = stream.set_nodelay(true); // latency over throughput on this path
    let pix = client.pixels();
    write_hello(&mut stream, pix, client.num_classes(), client.identity())?;
    let mut x = vec![0.0f32; pix];
    loop {
        let count = match read_u32(&mut stream) {
            Ok(c) => c,
            // A plain close instead of a zero-frame is a fine goodbye.
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e),
        };
        if count == 0 {
            return Ok(());
        }
        if count as usize != pix {
            // Protocol violation: report and drop the connection — the
            // stream position is unknowable after a bad frame.
            write_error(&mut stream, &format!("frame has {count} values, model takes {pix}"))?;
            return Ok(());
        }
        read_f32s(&mut stream, &mut x)?;
        match client.infer_one(&x) {
            Ok(logits) => {
                write_u32(&mut stream, logits.len() as u32)?;
                write_f32s(&mut stream, &logits)?;
            }
            Err(e) => write_error(&mut stream, &format!("{e:#}"))?,
        }
        stream.flush()?;
    }
}

fn write_error<W: Write>(w: &mut W, msg: &str) -> std::io::Result<()> {
    write_u32(w, ERR_MARK)?;
    write_u32(w, msg.len() as u32)?;
    w.write_all(msg.as_bytes())?;
    w.flush()
}

/// Client side of the wire protocol — used by the loopback bench, the CLI
/// client mode, and the integration tests.
pub struct TcpClient {
    stream: TcpStream,
    pix: usize,
    num_classes: usize,
    identity: ServeIdentity,
}

impl TcpClient {
    /// Connect and read the v2 hello (magic, version, model dims, then the
    /// server's precision + artifact identity + per-layer bits). Rejects
    /// endpoints speaking any other hello version.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<TcpClient> {
        let mut stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let mut magic = [0u8; 4];
        stream.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(anyhow!("not a waveq serve endpoint (bad hello magic {magic:?})"));
        }
        let version = read_u32(&mut stream)?;
        if version != HELLO_VERSION {
            return Err(anyhow!(
                "serve endpoint speaks hello v{version}, this client speaks v{HELLO_VERSION}"
            ));
        }
        let pix = read_u32(&mut stream)? as usize;
        let num_classes = read_u32(&mut stream)? as usize;
        let mut pcode = [0u8; 1];
        stream.read_exact(&mut pcode)?;
        let precision = Precision::from_wire(pcode[0])
            .ok_or_else(|| anyhow!("hello carries unknown precision code {}", pcode[0]))?;
        let base_len = read_u32(&mut stream)? as usize;
        if base_len > 4096 {
            return Err(anyhow!("hello carries an implausible {base_len}-byte model name"));
        }
        let mut base = vec![0u8; base_len];
        stream.read_exact(&mut base)?;
        let base = String::from_utf8(base)
            .map_err(|_| anyhow!("hello model name is not UTF-8"))?;
        let width_mult = read_u32(&mut stream)? as usize;
        let nbits = read_u32(&mut stream)? as usize;
        if nbits > 1 << 20 {
            return Err(anyhow!("hello carries an implausible {nbits}-layer bit assignment"));
        }
        let mut layer_bits = vec![0u8; nbits];
        stream.read_exact(&mut layer_bits)?;
        let int_gemm_layers = read_u32(&mut stream)? as usize;
        let identity = ServeIdentity { base, width_mult, precision, layer_bits, int_gemm_layers };
        Ok(TcpClient { stream, pix, num_classes, identity })
    }

    pub fn pixels(&self) -> usize {
        self.pix
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// The precision the server advertised in its hello.
    pub fn precision(&self) -> Precision {
        self.identity.precision
    }

    /// The full advertised identity (artifact base/width, per-layer bits).
    pub fn identity(&self) -> &ServeIdentity {
        &self.identity
    }

    /// Send one example, block for its logits.
    pub fn infer_one(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.pix {
            return Err(anyhow!(
                "infer_one: x has {} values, the served model takes {}",
                x.len(),
                self.pix
            ));
        }
        write_u32(&mut self.stream, x.len() as u32)?;
        write_f32s(&mut self.stream, x)?;
        self.stream.flush()?;
        let count = read_u32(&mut self.stream)?;
        if count == ERR_MARK {
            let len = read_u32(&mut self.stream)? as usize;
            let mut msg = vec![0u8; len];
            self.stream.read_exact(&mut msg)?;
            return Err(anyhow!("server: {}", String::from_utf8_lossy(&msg)));
        }
        if count as usize != self.num_classes {
            return Err(anyhow!(
                "protocol error: response has {count} values, expected {}",
                self.num_classes
            ));
        }
        let mut logits = vec![0.0f32; self.num_classes];
        read_f32s(&mut self.stream, &mut logits)?;
        Ok(logits)
    }

    /// Close the connection with the protocol's goodbye frame.
    pub fn close(mut self) -> Result<()> {
        write_u32(&mut self.stream, 0)?;
        self.stream.flush()?;
        Ok(())
    }
}

// --- loopback bench driver --------------------------------------------------

/// What [`loopback_bench`] measured: per-request latency percentiles and
/// aggregate throughput for `clients` concurrent TCP clients.
#[derive(Debug, Clone)]
pub struct LoopbackReport {
    pub clients: usize,
    /// Total requests served (`clients * per_client`).
    pub requests: usize,
    /// Wall time from first request to last response.
    pub secs: f64,
    /// Per-request round-trip latency distribution (p50/p95/p99 inside).
    pub lat: BenchStats,
    /// Mean examples per dispatched batch during the run.
    pub mean_fill: f64,
}

impl LoopbackReport {
    pub fn imgs_per_s(&self) -> f64 {
        self.requests as f64 / self.secs
    }
}

/// Drive `server` over a 127.0.0.1 TCP loopback with `clients` concurrent
/// connections, each issuing `per_client` single-example requests drawn
/// round-robin from `xs`. Exercises the full stack — framing, queueing,
/// cross-request batching — and reports latency/throughput.
pub fn loopback_bench(
    server: &Server,
    clients: usize,
    per_client: usize,
    xs: &[Vec<f32>],
) -> Result<LoopbackReport> {
    if clients == 0 || per_client == 0 || xs.is_empty() {
        return Err(anyhow!("loopback_bench: clients, per_client and xs must be non-empty"));
    }
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let stats0 = server.stats();
    let t0 = Instant::now();
    let mut lats: Vec<Duration> = Vec::with_capacity(clients * per_client);
    std::thread::scope(|s| -> Result<()> {
        let acceptor = s.spawn(|| serve_tcp(server, listener, Some(clients)));
        let mut joins = Vec::with_capacity(clients);
        for c in 0..clients {
            joins.push(s.spawn(move || -> Result<Vec<Duration>> {
                let mut conn = TcpClient::connect(addr)?;
                let mut out = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let x = &xs[(c * per_client + i) % xs.len()];
                    let t = Instant::now();
                    let _ = conn.infer_one(x)?;
                    out.push(t.elapsed());
                }
                conn.close()?;
                Ok(out)
            }));
        }
        for j in joins {
            lats.extend(j.join().expect("loopback client thread")?);
        }
        acceptor.join().expect("loopback acceptor thread")?;
        Ok(())
    })?;
    let secs = t0.elapsed().as_secs_f64();
    let stats1 = server.stats();
    let delta = ServeSnapshot {
        requests: stats1.requests - stats0.requests,
        batches: stats1.batches - stats0.batches,
        full_batches: stats1.full_batches - stats0.full_batches,
        identity: stats1.identity,
    };
    let name = format!("serve loopback x{clients}");
    Ok(LoopbackReport {
        clients,
        requests: clients * per_client,
        secs,
        lat: BenchStats::from_samples(&name, lats),
        mean_fill: delta.mean_fill(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_helpers_round_trip() {
        let mut buf: Vec<u8> = Vec::new();
        write_u32(&mut buf, 0xDEAD_BEEF).unwrap();
        write_f32s(&mut buf, &[1.5, -2.25, 0.0]).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_u32(&mut cur).unwrap(), 0xDEAD_BEEF);
        let mut xs = [0.0f32; 3];
        read_f32s(&mut cur, &mut xs).unwrap();
        assert_eq!(xs, [1.5, -2.25, 0.0]);
    }

    #[test]
    fn error_frames_carry_the_message() {
        let mut buf: Vec<u8> = Vec::new();
        write_error(&mut buf, "bad things").unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_u32(&mut cur).unwrap(), ERR_MARK);
        let len = read_u32(&mut cur).unwrap() as usize;
        let mut msg = vec![0u8; len];
        cur.read_exact(&mut msg).unwrap();
        assert_eq!(std::str::from_utf8(&msg).unwrap(), "bad things");
    }

    fn test_identity() -> ServeIdentity {
        ServeIdentity {
            base: "test".into(),
            width_mult: 1,
            precision: Precision::Exact,
            layer_bits: vec![32, 2, 2, 32],
            int_gemm_layers: 0,
        }
    }

    #[test]
    fn snapshot_mean_fill() {
        let s = ServeStats::default();
        let id = test_identity();
        assert_eq!(s.snapshot(&id).mean_fill(), 0.0);
        s.record(4, 4);
        s.record(2, 4);
        let snap = s.snapshot(&id);
        assert_eq!(snap.requests, 6);
        assert_eq!(snap.batches, 2);
        assert_eq!(snap.full_batches, 1);
        assert!((snap.mean_fill() - 3.0).abs() < 1e-12);
        assert_eq!(snap.identity, id);
    }

    #[test]
    fn hello_frame_round_trips_the_identity() {
        let id = ServeIdentity {
            base: "resnet20l".into(),
            width_mult: 2,
            precision: Precision::Int8,
            layer_bits: vec![32, 2, 3, 2, 32],
            int_gemm_layers: 3,
        };
        let mut buf: Vec<u8> = Vec::new();
        write_hello(&mut buf, 1024, 10, &id).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        let mut magic = [0u8; 4];
        cur.read_exact(&mut magic).unwrap();
        assert_eq!(&magic, MAGIC);
        assert_eq!(read_u32(&mut cur).unwrap(), HELLO_VERSION);
        assert_eq!(read_u32(&mut cur).unwrap(), 1024);
        assert_eq!(read_u32(&mut cur).unwrap(), 10);
        let mut prec = [0u8; 1];
        cur.read_exact(&mut prec).unwrap();
        assert_eq!(Precision::from_wire(prec[0]).unwrap(), Precision::Int8);
        let base_len = read_u32(&mut cur).unwrap() as usize;
        let mut base = vec![0u8; base_len];
        cur.read_exact(&mut base).unwrap();
        assert_eq!(std::str::from_utf8(&base).unwrap(), "resnet20l");
        assert_eq!(read_u32(&mut cur).unwrap(), 2);
        let nbits = read_u32(&mut cur).unwrap() as usize;
        let mut bits = vec![0u8; nbits];
        cur.read_exact(&mut bits).unwrap();
        assert_eq!(bits, id.layer_bits);
        assert_eq!(read_u32(&mut cur).unwrap(), 3);
        assert_eq!(id.model_label(), "resnet20l_w2");
    }
}
