//! The native model zoo: op-graph definitions of every WaveQ benchmark
//! network, mirroring `python/compile/models.py` one-for-one (same layer
//! order, same parameter names/shapes/inits, same quantization-slot policy)
//! so the native backend exports the same manifest contract the AOT
//! pipeline writes.
//!
//! A model is a flat list of [`OpNode`]s over NHWC activations plus a flat
//! parameter table (`Vec<ParamMeta>`, the manifest layout). Residual blocks
//! are linearized with explicit skip markers:
//!
//!   SkipSave, <body ops>, [SkipProj], SkipAdd
//!
//! `SkipSave` pushes the current activation; `SkipProj` (when the block
//! changes shape) runs a 1x1 strided conv on the saved activation;
//! `SkipAdd` pops, adds, and applies ReLU + activation fake-quant — exactly
//! `layers.Residual.apply`.
//!
//! Quantization policy (paper §4.1): every conv/dwconv/fc weight asks for a
//! bitwidth slot; the first and last quantizable layers of the network are
//! resolved back to full precision.

use std::collections::BTreeMap;

use super::kernels::{conv_geom, ConvGeom};
use crate::runtime::manifest::{ArgSpec, ModelMeta, ParamMeta};

/// One node of a native model's op graph, with build-time resolved shapes.
#[derive(Debug, Clone)]
pub enum OpNode {
    /// Standard or depthwise 2-D convolution (SAME padding, no bias);
    /// `geom.depthwise` selects the kernel. Param = HWIO weight.
    Conv { geom: ConvGeom, pidx: usize },
    /// Fully-connected layer with bias.
    Fc { din: usize, dout: usize, widx: usize, bidx: usize },
    /// Per-channel scale + bias over (batch * hw, c) ("BN-lite").
    Affine { c: usize, hw: usize, sidx: usize, bidx: usize },
    /// ReLU followed by activation fake-quant when the program asks.
    Relu,
    /// VALID max pooling with stride = size.
    MaxPool { h: usize, w: usize, c: usize, size: usize },
    GlobalAvgPool { h: usize, w: usize, c: usize },
    Flatten,
    /// Push the current activation onto the skip stack (residual entry).
    SkipSave,
    /// 1x1 strided projection of the saved skip activation.
    SkipProj { geom: ConvGeom, pidx: usize },
    /// Pop the skip (projected or identity), add, ReLU + act-quant.
    SkipAdd,
}

/// A native model: op graph + flat manifest-style parameter table.
#[derive(Debug, Clone)]
pub struct NativeModel {
    pub name: String,
    /// Dataset this model trains on (`data::synth` spec name).
    pub dataset: String,
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    pub batch: usize,
    pub width_mult: usize,
    pub ops: Vec<OpNode>,
    pub params: Vec<ParamMeta>,
}

impl NativeModel {
    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    pub fn num_qlayers(&self) -> usize {
        self.params.iter().filter(|p| p.qidx.is_some()).count()
    }

    pub fn pixels(&self) -> usize {
        self.input_shape[0] * self.input_shape[1] * self.input_shape[2]
    }

    /// The manifest-side description of this model.
    pub fn meta(&self) -> ModelMeta {
        ModelMeta {
            name: self.name.clone(),
            dataset: self.dataset.clone(),
            input_shape: self.input_shape,
            num_classes: self.num_classes,
            batch: self.batch,
            width_mult: self.width_mult,
            num_qlayers: self.num_qlayers(),
            params: self.params.clone(),
        }
    }

    pub fn param_names(&self, prefix: &str) -> Vec<String> {
        self.params.iter().map(|p| format!("{prefix}:{}", p.name)).collect()
    }

    pub fn param_specs(&self, prefix: &str) -> Vec<ArgSpec> {
        self.params
            .iter()
            .map(|p| ArgSpec {
                name: format!("{prefix}:{}", p.name),
                shape: p.shape.clone(),
                dtype: "float32".into(),
            })
            .collect()
    }

    /// (rows, kdim, cout) of every im2col matmul this model's forward pass
    /// performs at batch size `batch` — standard convs and projection
    /// shortcuts, in op order. These are the shapes `bench_kernels`
    /// measures the blocked matmul on.
    pub fn conv_matmul_shapes(&self, batch: usize) -> Vec<(usize, usize, usize)> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                OpNode::Conv { geom, .. } if !geom.depthwise => {
                    Some((geom.rows(batch), geom.kdim(), geom.cout))
                }
                OpNode::SkipProj { geom, .. } => Some((geom.rows(batch), geom.kdim(), geom.cout)),
                _ => None,
            })
            .collect()
    }

    /// Per-example buffer demands of a forward-only pass over this op
    /// graph — the shape plan `runtime::infer`'s arena is sized from, in
    /// f32 elements *per example* (the session multiplies by `max_batch`):
    /// `act` bounds every op input/output activation, `cols` the largest
    /// im2col patch matrix, `skip` the deepest concurrently-live residual
    /// save stack, and `shortcut` the largest projected shortcut.
    pub fn infer_plan(&self) -> InferPlan {
        let mut cur = self.pixels();
        let mut plan = InferPlan { act: cur, cols: 0, quant: 0, skip: 0, shortcut: 0 };
        let mut live_skip = 0usize;
        let mut saves: Vec<usize> = Vec::new();
        for op in &self.ops {
            match op {
                OpNode::Conv { geom, .. } => {
                    if !geom.depthwise {
                        plan.cols = plan.cols.max(geom.h_out * geom.w_out * geom.kdim());
                        plan.quant = plan.quant.max(geom.h_out * geom.w_out * geom.kdim());
                    }
                    cur = geom.h_out * geom.w_out * geom.cout;
                }
                OpNode::Fc { din, dout, .. } => {
                    plan.quant = plan.quant.max(*din);
                    cur = *dout;
                }
                OpNode::Affine { .. } | OpNode::Relu | OpNode::Flatten => {}
                OpNode::MaxPool { h, w, c, size } => cur = (h / size) * (w / size) * c,
                OpNode::GlobalAvgPool { c, .. } => cur = *c,
                OpNode::SkipSave => {
                    live_skip += cur;
                    plan.skip = plan.skip.max(live_skip);
                    saves.push(cur);
                }
                OpNode::SkipProj { geom, .. } => {
                    plan.cols = plan.cols.max(geom.h_out * geom.w_out * geom.kdim());
                    plan.quant = plan.quant.max(geom.h_out * geom.w_out * geom.kdim());
                    plan.shortcut = plan.shortcut.max(geom.h_out * geom.w_out * geom.cout);
                }
                OpNode::SkipAdd => {
                    live_skip -= saves.pop().expect("SkipAdd without SkipSave");
                }
            }
            plan.act = plan.act.max(cur);
        }
        plan
    }

    // ---- zoo constructors (mirror python/compile/models.py) ----------------

    /// The WaveQ test MLP on mlp-lite (8x8x3 -> 10).
    pub fn mlp(width_mult: usize) -> NativeModel {
        let w = 128 * width_mult;
        let mut b = Builder::new([8, 8, 3]);
        b.flatten();
        b.fc(w);
        b.relu();
        b.fc(w);
        b.relu();
        b.fc(w);
        b.relu();
        b.fc(10);
        b.finish("mlp", "mlp-lite", 10, 64, width_mult)
    }

    /// SimpleNet-5 stand-in: 3 convs + 2 FCs on cifar-lite.
    pub fn simplenet5(width_mult: usize) -> NativeModel {
        let m = width_mult;
        let mut b = Builder::new([16, 16, 3]);
        b.conv(16 * m, 3, 1);
        b.affine();
        b.relu();
        b.conv(32 * m, 3, 2);
        b.affine();
        b.relu();
        b.conv(32 * m, 3, 2);
        b.affine();
        b.relu();
        b.flatten();
        b.fc(64 * m);
        b.relu();
        b.fc(10);
        b.finish("simplenet5", "cifar-lite", 10, 32, width_mult)
    }

    /// ResNet-20-lite: 3 stages x 2 blocks, widths 8/16/32.
    pub fn resnet20l(width_mult: usize) -> NativeModel {
        let m = width_mult;
        let mut b = Builder::new([16, 16, 3]);
        b.conv(8 * m, 3, 1);
        b.affine();
        b.relu();
        b.res_block(8 * m, 1, false);
        b.res_block(8 * m, 1, false);
        b.res_block(16 * m, 2, true);
        b.res_block(16 * m, 1, false);
        b.res_block(32 * m, 2, true);
        b.res_block(32 * m, 1, false);
        b.gap();
        b.flatten();
        b.fc(10);
        b.finish("resnet20l", "cifar-lite", 10, 32, width_mult)
    }

    /// VGG-11-lite: conv/pool ladder + 2-layer FC head.
    pub fn vgg11l(width_mult: usize) -> NativeModel {
        let m = width_mult;
        let mut b = Builder::new([16, 16, 3]);
        b.conv(16 * m, 3, 1);
        b.affine();
        b.relu();
        b.maxpool(2);
        b.conv(32 * m, 3, 1);
        b.affine();
        b.relu();
        b.maxpool(2);
        b.conv(64 * m, 3, 1);
        b.affine();
        b.relu();
        b.conv(64 * m, 3, 1);
        b.affine();
        b.relu();
        b.maxpool(2);
        b.flatten();
        b.fc(128 * m);
        b.relu();
        b.fc(10);
        b.finish("vgg11l", "cifar-lite", 10, 32, width_mult)
    }

    /// SVHN-8-lite: 6 convs + 2 FCs on svhn-lite.
    pub fn svhn8(width_mult: usize) -> NativeModel {
        let m = width_mult;
        let mut b = Builder::new([16, 16, 3]);
        b.conv(16 * m, 3, 1);
        b.affine();
        b.relu();
        b.conv(16 * m, 3, 1);
        b.affine();
        b.relu();
        b.maxpool(2);
        b.conv(32 * m, 3, 1);
        b.affine();
        b.relu();
        b.conv(32 * m, 3, 1);
        b.affine();
        b.relu();
        b.maxpool(2);
        b.conv(48 * m, 3, 1);
        b.affine();
        b.relu();
        b.conv(48 * m, 3, 1);
        b.affine();
        b.relu();
        b.gap();
        b.flatten();
        b.fc(64 * m);
        b.relu();
        b.fc(10);
        b.finish("svhn8", "svhn-lite", 10, 32, width_mult)
    }

    /// AlexNet-lite: 5 convs + 3 FCs on imagenet-lite (24x24, 20 classes).
    pub fn alexnetl(width_mult: usize) -> NativeModel {
        let m = width_mult;
        let mut b = Builder::new([24, 24, 3]);
        b.conv(16 * m, 5, 2);
        b.affine();
        b.relu();
        b.conv(32 * m, 3, 1);
        b.affine();
        b.relu();
        b.maxpool(2);
        b.conv(48 * m, 3, 1);
        b.affine();
        b.relu();
        b.conv(48 * m, 3, 1);
        b.affine();
        b.relu();
        b.conv(32 * m, 3, 1);
        b.affine();
        b.relu();
        b.maxpool(2);
        b.flatten();
        b.fc(128 * m);
        b.relu();
        b.fc(128 * m);
        b.relu();
        b.fc(20);
        b.finish("alexnetl", "imagenet-lite", 20, 16, width_mult)
    }

    /// ResNet-18-lite: 4 stages x 2 blocks, widths 8/16/32/64.
    pub fn resnet18l(width_mult: usize) -> NativeModel {
        let m = width_mult;
        let mut b = Builder::new([24, 24, 3]);
        b.conv(8 * m, 3, 1);
        b.affine();
        b.relu();
        b.res_block(8 * m, 1, false);
        b.res_block(8 * m, 1, false);
        b.res_block(16 * m, 2, true);
        b.res_block(16 * m, 1, false);
        b.res_block(32 * m, 2, true);
        b.res_block(32 * m, 1, false);
        b.res_block(64 * m, 2, true);
        b.res_block(64 * m, 1, false);
        b.gap();
        b.flatten();
        b.fc(20);
        b.finish("resnet18l", "imagenet-lite", 20, 16, width_mult)
    }

    /// MobileNet-lite: stem conv + 6 depthwise-separable blocks.
    pub fn mobilenetl(width_mult: usize) -> NativeModel {
        let m = width_mult;
        let mut b = Builder::new([24, 24, 3]);
        b.conv(16 * m, 3, 2);
        b.affine();
        b.relu();
        for (cout, stride) in
            [(16 * m, 1), (32 * m, 2), (32 * m, 1), (64 * m, 2), (64 * m, 1), (64 * m, 1)]
        {
            b.sep_block(cout, stride);
        }
        b.gap();
        b.flatten();
        b.fc(20);
        b.finish("mobilenetl", "imagenet-lite", 20, 16, width_mult)
    }

    /// Build a zoo model by base name.
    pub fn by_name(name: &str, width_mult: usize) -> Option<NativeModel> {
        Some(match name {
            "mlp" => Self::mlp(width_mult),
            "simplenet5" => Self::simplenet5(width_mult),
            "resnet20l" => Self::resnet20l(width_mult),
            "vgg11l" => Self::vgg11l(width_mult),
            "svhn8" => Self::svhn8(width_mult),
            "alexnetl" => Self::alexnetl(width_mult),
            "resnet18l" => Self::resnet18l(width_mult),
            "mobilenetl" => Self::mobilenetl(width_mult),
            _ => return None,
        })
    }
}

/// Per-example arena demands of a forward-only pass (see
/// [`NativeModel::infer_plan`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InferPlan {
    /// Largest activation entering or leaving any op.
    pub act: usize,
    /// Largest im2col patch matrix (standard convs + projections).
    pub cols: usize,
    /// Largest u8 activation-code buffer the `Precision::Int8` path can
    /// quantize a GEMM left operand into: the patch matrices again, plus
    /// the FC input widths (quantized straight from the activation).
    pub quant: usize,
    /// Deepest concurrently-live residual save stack.
    pub skip: usize,
    /// Largest projected-shortcut activation.
    pub shortcut: usize,
}

/// Base names of every zoo member, in registration order.
pub const ZOO_NAMES: &[&str] = &[
    "mlp", "simplenet5", "resnet20l", "vgg11l", "svhn8", "alexnetl", "resnet18l", "mobilenetl",
];

/// WRPN width multiplier (the paper's WRPN-2x configuration).
pub const WRPN_WIDTH: usize = 2;

// ---- the builder (mirrors models._ShapeTracker + build) --------------------

struct Builder {
    h: usize,
    w: usize,
    c: usize,
    input_shape: [usize; 3],
    flat: Option<usize>,
    ids: BTreeMap<&'static str, usize>,
    ops: Vec<OpNode>,
    params: Vec<ParamMeta>,
    /// Param indices awaiting quantization-slot resolution, in spec order.
    pending: Vec<usize>,
}

impl Builder {
    fn new(input_shape: [usize; 3]) -> Builder {
        Builder {
            h: input_shape[0],
            w: input_shape[1],
            c: input_shape[2],
            input_shape,
            flat: None,
            ids: BTreeMap::new(),
            ops: Vec::new(),
            params: Vec::new(),
            pending: Vec::new(),
        }
    }

    fn next_id(&mut self, kind: &'static str) -> usize {
        let e = self.ids.entry(kind).or_insert(0);
        *e += 1;
        *e
    }

    #[allow(clippy::too_many_arguments)]
    fn push_param(
        &mut self,
        name: String,
        shape: Vec<usize>,
        kind: &str,
        init: &str,
        macs: u64,
        quantizable: bool,
    ) -> usize {
        let count: usize = shape.iter().product();
        let idx = self.params.len();
        self.params.push(ParamMeta {
            name,
            shape,
            kind: kind.into(),
            init: init.into(),
            qidx: None,
            macs,
            count: count as u64,
        });
        if quantizable {
            self.pending.push(idx);
        }
        idx
    }

    fn conv(&mut self, cout: usize, ksize: usize, stride: usize) {
        let geom = conv_geom(self.h, self.w, self.c, cout, ksize, stride, false);
        let macs = (geom.h_out * geom.w_out * ksize * ksize * self.c * cout) as u64;
        let name = format!("conv{}", self.next_id("conv"));
        let pidx =
            self.push_param(name, vec![ksize, ksize, self.c, cout], "conv", "he", macs, true);
        self.h = geom.h_out;
        self.w = geom.w_out;
        self.c = cout;
        self.ops.push(OpNode::Conv { geom, pidx });
    }

    fn dwconv(&mut self, ksize: usize, stride: usize) {
        let c = self.c;
        let geom = conv_geom(self.h, self.w, c, c, ksize, stride, true);
        let macs = (geom.h_out * geom.w_out * ksize * ksize * c) as u64;
        let name = format!("dwconv{}", self.next_id("dwconv"));
        let pidx = self.push_param(name, vec![ksize, ksize, 1, c], "dwconv", "he", macs, true);
        self.h = geom.h_out;
        self.w = geom.w_out;
        self.ops.push(OpNode::Conv { geom, pidx });
    }

    fn fc(&mut self, dout: usize) {
        let din = match self.flat {
            Some(n) => n,
            None => {
                let n = self.h * self.w * self.c;
                self.flat = Some(n);
                n
            }
        };
        let name = format!("fc{}", self.next_id("fc"));
        let widx = self.push_param(
            name.clone(),
            vec![din, dout],
            "fc",
            "he",
            (din * dout) as u64,
            true,
        );
        let bidx = self.push_param(format!("{name}_b"), vec![dout], "bias", "zeros", 0, false);
        self.flat = Some(dout);
        self.ops.push(OpNode::Fc { din, dout, widx, bidx });
    }

    fn affine(&mut self) {
        let c = self.c;
        let i = self.next_id("affine");
        let sidx = self.push_param(format!("affine{i}_s"), vec![c], "affine", "ones", 0, false);
        let bidx = self.push_param(format!("affine{i}_b"), vec![c], "affine", "zeros", 0, false);
        self.ops.push(OpNode::Affine { c, hw: self.h * self.w, sidx, bidx });
    }

    fn relu(&mut self) {
        self.ops.push(OpNode::Relu);
    }

    fn maxpool(&mut self, size: usize) {
        self.ops.push(OpNode::MaxPool { h: self.h, w: self.w, c: self.c, size });
        self.h /= size;
        self.w /= size;
    }

    fn gap(&mut self) {
        self.ops.push(OpNode::GlobalAvgPool { h: self.h, w: self.w, c: self.c });
        self.h = 1;
        self.w = 1;
    }

    fn flatten(&mut self) {
        self.flat = Some(self.h * self.w * self.c);
        self.ops.push(OpNode::Flatten);
    }

    /// Residual block: [Conv(cout, 3, stride), Affine, ReLU, Conv(cout, 3, 1),
    /// Affine] + optional 1x1 projection, then add + ReLU. The last body conv
    /// initializes near zero ("he_res", fixup-style) so deep stacks start as
    /// near-identity — same as `models._res_block`.
    fn res_block(&mut self, cout: usize, stride: usize, project: bool) {
        let (h0, w0, c0) = (self.h, self.w, self.c);
        self.ops.push(OpNode::SkipSave);
        self.conv(cout, 3, stride);
        self.affine();
        self.relu();
        self.conv(cout, 3, 1);
        // Fixup: the body conv just added starts near zero.
        let last = self
            .params
            .iter_mut()
            .rev()
            .find(|p| p.kind == "conv")
            .expect("res_block body has a conv");
        last.init = "he_res".into();
        self.affine();
        if project {
            let geom = conv_geom(h0, w0, c0, cout, 1, stride, false);
            let macs = (geom.h_out * geom.w_out * c0 * cout) as u64;
            let name = format!("conv{}", self.next_id("conv"));
            let pidx = self.push_param(name, vec![1, 1, c0, cout], "conv", "he", macs, true);
            self.ops.push(OpNode::SkipProj { geom, pidx });
        }
        self.ops.push(OpNode::SkipAdd);
    }

    /// MobileNet-style depthwise-separable block.
    fn sep_block(&mut self, cout: usize, stride: usize) {
        self.dwconv(3, stride);
        self.affine();
        self.relu();
        self.conv(cout, 1, 1);
        self.affine();
        self.relu();
    }

    /// Resolve pending quantization slots (first & last stay fp32, like
    /// `models.build`) and seal the model.
    fn finish(
        mut self,
        base: &str,
        dataset: &str,
        num_classes: usize,
        batch: usize,
        width_mult: usize,
    ) -> NativeModel {
        let n = self.pending.len();
        let mut qi = 0usize;
        for (i, &p) in self.pending.iter().enumerate() {
            let keep = n < 3 || (i != 0 && i != n - 1);
            if keep {
                self.params[p].qidx = Some(qi);
                qi += 1;
            }
        }
        let name =
            if width_mult == 1 { base.to_string() } else { format!("{base}_w{width_mult}") };
        NativeModel {
            name,
            dataset: dataset.into(),
            input_shape: self.input_shape,
            num_classes,
            batch,
            width_mult,
            ops: self.ops,
            params: self.params,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_layout_matches_the_fc_era() {
        // The op-graph mlp must reproduce the original FcLayer-based layout
        // exactly: names, shapes, qidx slots, macs.
        let m = NativeModel::mlp(1);
        let names: Vec<&str> = m.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["fc1", "fc1_b", "fc2", "fc2_b", "fc3", "fc3_b", "fc4", "fc4_b"]
        );
        assert_eq!(m.params[0].shape, vec![192, 128]);
        assert_eq!(m.params[2].qidx, Some(0));
        assert_eq!(m.params[4].qidx, Some(1));
        assert_eq!(m.params[0].qidx, None);
        assert_eq!(m.params[6].qidx, None);
        assert_eq!(m.num_qlayers(), 2);
        assert_eq!(m.params[2].macs, 128 * 128);
        assert_eq!(m.batch, 64);
        let w2 = NativeModel::mlp(2);
        assert_eq!(w2.name, "mlp_w2");
        assert_eq!(w2.params[0].shape, vec![192, 256]);
    }

    #[test]
    fn zoo_qlayer_counts_match_the_policy() {
        // #quantizable = #(conv + dwconv + fc weights) - 2 (first & last fp32).
        for (name, quantizable) in [
            ("mlp", 4),
            ("simplenet5", 5),
            ("resnet20l", 16),
            ("vgg11l", 6),
            ("svhn8", 8),
            ("alexnetl", 8),
            ("resnet18l", 21),
            ("mobilenetl", 14),
        ] {
            let m = NativeModel::by_name(name, 1).unwrap();
            let compute = m
                .params
                .iter()
                .filter(|p| matches!(p.kind.as_str(), "conv" | "dwconv" | "fc"))
                .count();
            assert_eq!(compute, quantizable, "{name} compute-layer count");
            assert_eq!(m.num_qlayers(), quantizable - 2, "{name} qlayer count");
            // qidx values are 0..q in param order.
            let slots: Vec<usize> = m.params.iter().filter_map(|p| p.qidx).collect();
            assert_eq!(slots, (0..m.num_qlayers()).collect::<Vec<_>>(), "{name} slots");
        }
    }

    #[test]
    fn resnet_blocks_linearize_with_skip_markers() {
        let m = NativeModel::resnet20l(1);
        let saves = m.ops.iter().filter(|o| matches!(o, OpNode::SkipSave)).count();
        let adds = m.ops.iter().filter(|o| matches!(o, OpNode::SkipAdd)).count();
        let projs = m.ops.iter().filter(|o| matches!(o, OpNode::SkipProj { .. })).count();
        assert_eq!((saves, adds, projs), (6, 6, 2));
        // The fixup init lands on the last body conv of every block.
        let he_res = m.params.iter().filter(|p| p.init == "he_res").count();
        assert_eq!(he_res, 6);
    }

    #[test]
    fn spatial_bookkeeping_produces_consistent_fc_dims() {
        // vgg11l: 16 -> 8 -> 4 -> 2 via three pools; head fc is 2*2*64 -> 128.
        let m = NativeModel::vgg11l(1);
        let fc = m.params.iter().find(|p| p.name == "fc1").unwrap();
        assert_eq!(fc.shape, vec![2 * 2 * 64, 128]);
        // alexnetl: 24 -(s2)-> 12 -> pool 6 -> pool 3; head fc is 3*3*32 -> 128.
        let m = NativeModel::alexnetl(1);
        let fc = m.params.iter().find(|p| p.name == "fc1").unwrap();
        assert_eq!(fc.shape, vec![3 * 3 * 32, 128]);
        // GAP models feed c channels to the head.
        let m = NativeModel::svhn8(1);
        let fc = m.params.iter().find(|p| p.name == "fc1").unwrap();
        assert_eq!(fc.shape, vec![48, 64]);
        let m = NativeModel::mobilenetl(1);
        let fc = m.params.iter().find(|p| p.name == "fc1").unwrap();
        assert_eq!(fc.shape, vec![64, 20]);
    }

    #[test]
    fn datasets_are_assigned_by_model() {
        for (name, ds) in [
            ("mlp", "mlp-lite"),
            ("simplenet5", "cifar-lite"),
            ("vgg11l", "cifar-lite"),
            ("svhn8", "svhn-lite"),
            ("alexnetl", "imagenet-lite"),
            ("mobilenetl", "imagenet-lite"),
        ] {
            let m = NativeModel::by_name(name, 1).unwrap();
            assert_eq!(m.dataset, ds, "{name}");
            assert_eq!(m.meta().dataset, ds, "{name} meta");
        }
    }

    #[test]
    fn conv_matmul_shapes_cover_convs_and_projections() {
        let m = NativeModel::resnet20l(1);
        let shapes = m.conv_matmul_shapes(32);
        // Stem + 6 blocks x 2 body convs + 2 projections = 15 matmuls.
        assert_eq!(shapes.len(), 15);
        // Stem: 16x16 spatial at batch 32, 3x3x3 patches, 8 filters.
        assert_eq!(shapes[0], (32 * 16 * 16, 27, 8));
        // Every shape is non-degenerate.
        assert!(shapes.iter().all(|&(r, k, c)| r > 0 && k > 0 && c > 0));
        // Pure-FC models have no conv matmuls.
        assert!(NativeModel::mlp(1).conv_matmul_shapes(32).is_empty());
    }

    #[test]
    fn infer_plan_bounds_every_buffer_the_forward_pass_touches() {
        // mlp: pure FC ladder — input 192 is the largest activation, no
        // im2col, no residual machinery.
        let plan = NativeModel::mlp(1).infer_plan();
        // quant: the widest FC input (the flattened 192-pixel input).
        assert_eq!(plan, InferPlan { act: 192, cols: 0, quant: 192, skip: 0, shortcut: 0 });
        // simplenet5: conv1 output 16*16*16 dominates activations; conv2's
        // patches 8*8*(3*3*16) dominate the cols scratch and (over the FC
        // input widths) the int8 code scratch too.
        let plan = NativeModel::simplenet5(1).infer_plan();
        assert_eq!(plan.act, 16 * 16 * 16);
        assert_eq!(plan.cols, 8 * 8 * 9 * 16);
        assert_eq!(plan.quant, plan.cols);
        assert_eq!((plan.skip, plan.shortcut), (0, 0));
        // resnet20l: stage-1 blocks save the 16x16x8 stem activation; the
        // projections emit at most 8*8*16 (stage 2 entry).
        let plan = NativeModel::resnet20l(1).infer_plan();
        assert_eq!(plan.skip, 16 * 16 * 8);
        assert_eq!(plan.shortcut, 8 * 8 * 16);
        // Depthwise convs don't use the cols scratch, but mobilenet's 1x1
        // pointwise convs do; every bound is positive.
        let plan = NativeModel::mobilenetl(1).infer_plan();
        assert!(plan.act > 0 && plan.cols > 0);
    }

    #[test]
    fn macs_and_counts_are_positive_for_compute_layers() {
        for name in ZOO_NAMES {
            let m = NativeModel::by_name(name, 1).unwrap();
            for p in &m.params {
                let is_compute = matches!(p.kind.as_str(), "conv" | "dwconv" | "fc");
                assert_eq!(p.macs > 0, is_compute, "{name}/{}", p.name);
                assert!(p.count > 0, "{name}/{}", p.name);
                assert_eq!(p.count as usize, p.shape.iter().product::<usize>());
            }
        }
    }
}
