//! Pure-Rust reference kernels for the native backend: the same math the
//! Pallas/JAX programs lower (see `python/compile/kernels/ref.py`, the
//! ground-truth oracles), executed directly on host slices.
//!
//! Conventions (paper §2.2 "Quantizer", Eq. 2.3):
//!   * `k = 2^b - 1` quantization levels over [0, 1]: `quantize_k`;
//!   * DoReFa weights are tanh-normalized into [0, 1], quantized, then
//!     mapped back to [-c, c] with the per-layer scale c = max|tanh(W)|;
//!   * WaveQ regularizer (Eq. 2.2 / 2.5, production normalization n=1):
//!       R(v; beta) = mean_j sin^2(pi * v_j * k) / 2^beta,  k = 2^beta - 1
//!     applied to the quantizer-normalized coordinate
//!       v = tanh(w) / (2 max|tanh(W)|) + 1/2
//!     so the sin^2 minima coincide exactly with the DoReFa levels.
//!
//! Scalar reductions and the regularizer run in f64 (the XLA programs
//! accumulate in higher precision too, and the finite-difference property
//! tests need the head-room); elementwise tensors stay f32.
//!
//! The dense linear algebra (matmul / matmul_bias / grad_weight /
//! grad_input) is cache-blocked, register-tiled, and multi-threaded via
//! [`super::pool`]; im2col / col2im / the depthwise convs split the batch
//! dimension across the same workers. All of it is bitwise deterministic
//! for any `WAVEQ_THREADS` value — see the "dense linear algebra" section
//! below and `pool`'s module docs for the contract.

use super::pool;

pub const LN2: f64 = std::f64::consts::LN_2;
pub const PI: f64 = std::f64::consts::PI;

/// Linear quantizer over [0, 1] with k steps: round(x*k)/k (Eq. 2.3).
pub fn quantize_k(x: f32, k: f32) -> f32 {
    (x * k).round() / k
}

/// Per-layer DoReFa scale: max|tanh(W)| with a floor (ref.py convention).
pub fn max_abs_tanh(w: &[f32]) -> f32 {
    w.iter().fold(0.0f32, |m, &x| m.max(x.tanh().abs())).max(1e-8)
}

/// Per-layer WRPN scale: max|W| with the same floor. One definition shared
/// by [`wrpn_quantize`] and [`wrpn_codes`] — the artifact exact-unpack
/// contract needs the freeze-time and eval-time scales bit-identical.
pub fn max_abs(w: &[f32]) -> f32 {
    w.iter().fold(0.0f32, |acc, &x| acc.max(x.abs())).max(1e-8)
}

/// DoReFa weight fake-quantization (STE backward).
///
/// Returns `(wq, ste, m)`: the quantized weights, the per-element STE
/// factor `dwq/dw = 1 - tanh(w)^2`, and the layer scale `m`.
pub fn dorefa_quantize(w: &[f32], k: f32) -> (Vec<f32>, Vec<f32>, f32) {
    let (wq, ste, _v, m) = dorefa_quantize_full(w, k);
    (wq, ste, m)
}

/// Like [`dorefa_quantize`] but also returns the quantizer-normalized
/// coordinates `v_j = tanh(w_j)/(2m) + 1/2` in [0, 1] — the coordinate
/// system the WaveQ regularizer's sinusoid lives in (its sin^2 minima are
/// exactly the `quantize_k` levels of these v).
pub fn dorefa_quantize_full(w: &[f32], k: f32) -> (Vec<f32>, Vec<f32>, Vec<f32>, f32) {
    let m = max_abs_tanh(w);
    let mut wq = Vec::with_capacity(w.len());
    let mut ste = Vec::with_capacity(w.len());
    let mut coords = Vec::with_capacity(w.len());
    for &x in w {
        let t = x.tanh();
        let v = t / (2.0 * m) + 0.5;
        wq.push(m * (2.0 * quantize_k(v, k) - 1.0));
        ste.push(1.0 - t * t);
        coords.push(v);
    }
    (wq, ste, coords, m)
}

/// WRPN weight fake-quantization: clip + linear quantize with scale
/// c = max|W|. With that scale the clip never bites, so the STE backward
/// is the identity (see `python/compile/kernels/wrpn.py`).
pub fn wrpn_quantize(w: &[f32], k: f32) -> (Vec<f32>, f32) {
    let m = max_abs(w);
    let wq = w
        .iter()
        .map(|&x| {
            let v = x.clamp(-m, m) / (2.0 * m) + 0.5;
            m * (2.0 * quantize_k(v, k) - 1.0)
        })
        .collect();
    (wq, m)
}

/// Activation-range scale: max over the buffer with the act-quant floor.
/// One definition shared by [`act_quantize`] (fake-quant path) and the
/// integer inference path ([`act_codes_into`] callers), so both paths see
/// the bit-identical scale for the same buffer. Serial fold in element
/// order — the fixed-order reduction contract (audit rule D3).
pub fn act_scale(a: &[f32]) -> f32 {
    a.iter().fold(0.0f32, |acc, &x| acc.max(x)).max(1e-6)
}

/// DoReFa activation fake-quantization in units of the batch max, applied
/// in place to post-ReLU activations: a_q = m * quantize_k(clip(a/m, 0, 1)).
/// Backward is the ReLU mask (the [0, 1] STE window always contains a/m).
pub fn act_quantize(a: &mut [f32], ka: f32) {
    let m = act_scale(a);
    for x in a.iter_mut() {
        *x = m * quantize_k((*x / m).clamp(0.0, 1.0), ka);
    }
}

/// Recover u8 activation codes on the scale-`m` grid:
/// `j = round(clip(a/m, 0, 1) * ka)`, the `quantize_k` numerator.
///
/// For a buffer that [`act_quantize`] produced (values `m * j/ka` with the
/// same `m`), the recovered codes are *exactly* the quantizer's: `j <= ka
/// <= 255` keeps the float round trip well inside the 0.5 rounding margin,
/// and `act_scale` of such a buffer reproduces `m` bit-for-bit (the max
/// element maps through `quantize_k(1.0, ka) = 1.0` untouched). Requires
/// `ka <= 255`; callers gate wider act grids back to the f32 path.
pub fn act_codes_into(a: &[f32], m: f32, ka: f32, codes: &mut [u8]) {
    debug_assert_eq!(a.len(), codes.len());
    debug_assert!(ka <= 255.0);
    for (c, &x) in codes.iter_mut().zip(a.iter()) {
        *c = ((x / m).clamp(0.0, 1.0) * ka).round() as u8;
    }
}

// ---- frozen-artifact quantizer codes (runtime::artifact pack contract) -----

/// Integer codes of the DoReFa quantizer grid: `c_j = round(v_j * k)` with
/// `v_j = tanh(w_j) / (2m) + 1/2` and `m = max|tanh(W)|` — exactly the
/// `quantize_k` numerator inside [`dorefa_quantize`], so `c_j` lies in
/// `[0, k]` and fits the `b = log2(k + 1)` bits of the layer's assignment.
/// Returns `(codes, m)`; [`decode_codes_into`] reproduces the quantizer's
/// f32 grid values from them bit-for-bit.
pub fn dorefa_codes(w: &[f32], k: f32) -> (Vec<u16>, f32) {
    let m = max_abs_tanh(w);
    let codes = w
        .iter()
        .map(|&x| ((x.tanh() / (2.0 * m) + 0.5) * k).round() as u16)
        .collect();
    (codes, m)
}

/// Integer codes of the WRPN quantizer grid (scale `m = max|W|`, clip that
/// never bites) — the [`wrpn_quantize`] counterpart of [`dorefa_codes`].
pub fn wrpn_codes(w: &[f32], k: f32) -> (Vec<u16>, f32) {
    let m = max_abs(w);
    let codes = w
        .iter()
        .map(|&x| ((x.clamp(-m, m) / (2.0 * m) + 0.5) * k).round() as u16)
        .collect();
    (codes, m)
}

/// Decode quantizer codes back onto the f32 grid: `w_q = m * (2 c/k - 1)`.
/// `c as f32` is exact (codes are <= 255) and the expression evaluates the
/// same operations in the same order as the `quantize_k`-based quantizers,
/// so the decoded weights are **bitwise identical** to the fake-quantized
/// weights the train/eval programs compute from the live f32 parameters —
/// the exact-unpack contract `runtime::artifact` is built on.
pub fn decode_codes_into(codes: &[u16], k: f32, m: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes.iter()) {
        *o = m * (2.0 * (c as f32 / k) - 1.0);
    }
}

// ---- WaveQ sinusoidal regularizer (normalization variant n = 1) ------------

/// R(v; beta) = mean_j sin^2(pi v_j k) / 2^beta, k = 2^beta - 1.
pub fn waveq_reg(v: &[f32], beta: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let k = 2f64.powf(beta) - 1.0;
    let sum: f64 = v
        .iter()
        .map(|&x| {
            let s = (PI * x as f64 * k).sin();
            s * s
        })
        .sum();
    sum / v.len() as f64 / 2f64.powf(beta)
}

/// Analytic dR/dv_j = sin(2 pi v_j k) * pi k / (N * 2^beta).
pub fn waveq_reg_grad_v(v: &[f32], beta: f64) -> Vec<f32> {
    let k = 2f64.powf(beta) - 1.0;
    let scale = PI * k / (v.len().max(1) as f64 * 2f64.powf(beta));
    v.iter()
        .map(|&x| ((2.0 * PI * x as f64 * k).sin() * scale) as f32)
        .collect()
}

/// Analytic dR/dbeta (scalar), n = 1:
///   mean_j [ sin(2 pi v k) * pi v * ln2 * 2^beta - ln2 * sin^2(pi v k) ] / 2^beta
pub fn waveq_reg_grad_beta(v: &[f32], beta: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let k = 2f64.powf(beta) - 1.0;
    let two_b = 2f64.powf(beta);
    let sum: f64 = v
        .iter()
        .map(|&xf| {
            let x = xf as f64;
            let s = (PI * x * k).sin();
            let t1 = (2.0 * PI * x * k).sin() * PI * x * LN2 * two_b;
            let t2 = LN2 * s * s;
            t1 - t2
        })
        .sum();
    sum / v.len() as f64 / two_b
}

// ---- reg_profile closed forms (Figures 2 & 3; all three normalizations) ----

/// Pointwise R_n(w, beta) = sin^2(pi w k) / 2^(n beta).
pub fn reg_point(w: f64, beta: f64, norm: u32) -> f64 {
    let k = 2f64.powf(beta) - 1.0;
    let s = (PI * w * k).sin();
    s * s / 2f64.powf(norm as f64 * beta)
}

/// Pointwise dR_n/dbeta.
pub fn reg_point_d1(w: f64, beta: f64, norm: u32) -> f64 {
    let n = norm as f64;
    let k = 2f64.powf(beta) - 1.0;
    let two_b = 2f64.powf(beta);
    let s = (PI * w * k).sin();
    let u = PI * w * LN2 * two_b;
    ((2.0 * PI * w * k).sin() * u - n * LN2 * s * s) / 2f64.powf(n * beta)
}

/// Pointwise d^2 R_n / dbeta^2:
///   [ 2 u^2 cos(2 pi w k) + (1 - 2n) ln2 u sin(2 pi w k) + n^2 ln2^2 sin^2 ]
///   / 2^(n beta),  u = pi w ln2 2^beta.
pub fn reg_point_d2(w: f64, beta: f64, norm: u32) -> f64 {
    let n = norm as f64;
    let k = 2f64.powf(beta) - 1.0;
    let two_b = 2f64.powf(beta);
    let s = (PI * w * k).sin();
    let u = PI * w * LN2 * two_b;
    let c2 = (2.0 * PI * w * k).cos();
    let s2 = (2.0 * PI * w * k).sin();
    (2.0 * u * u * c2 + (1.0 - 2.0 * n) * LN2 * u * s2 + n * n * LN2 * LN2 * s * s)
        / 2f64.powf(n * beta)
}

// ---- convolution / pooling geometry (NHWC, HWIO weights) -------------------

/// Build-time resolved geometry of a 2-D convolution with XLA-style SAME
/// padding (low = total/2, high = total - low), matching
/// `lax.conv_general_dilated(..., padding="SAME")` in `python/compile/layers.py`.
#[derive(Debug, Clone)]
pub struct ConvGeom {
    pub ksize: usize,
    pub stride: usize,
    /// Input channels (for depthwise this equals `cout`; the weight still
    /// has HWIO shape [k, k, 1, c]).
    pub cin: usize,
    pub cout: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub h_out: usize,
    pub w_out: usize,
    pub pad_top: usize,
    pub pad_left: usize,
    pub depthwise: bool,
}

impl ConvGeom {
    /// Rows of the im2col matrix for a given batch.
    pub fn rows(&self, batch: usize) -> usize {
        batch * self.h_out * self.w_out
    }

    /// Columns of the im2col matrix (= flattened HWI weight leading dims).
    pub fn kdim(&self) -> usize {
        self.ksize * self.ksize * self.cin
    }
}

/// Resolve SAME-padding conv geometry: h_out = ceil(h / stride).
#[allow(clippy::too_many_arguments)]
pub fn conv_geom(
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    ksize: usize,
    stride: usize,
    depthwise: bool,
) -> ConvGeom {
    let h_out = h.div_ceil(stride);
    let w_out = w.div_ceil(stride);
    let pad_h = ((h_out - 1) * stride + ksize).saturating_sub(h);
    let pad_w = ((w_out - 1) * stride + ksize).saturating_sub(w);
    ConvGeom {
        ksize,
        stride,
        cin,
        cout,
        h_in: h,
        w_in: w,
        h_out,
        w_out,
        pad_top: pad_h / 2,
        pad_left: pad_w / 2,
        depthwise,
    }
}

/// Unfold an NHWC input into im2col patch rows: (batch * h_out * w_out,
/// k * k * cin), zero-padded at the borders. The row layout matches the
/// row-major flattening of an HWIO weight's leading [k, k, cin] dims, so
/// `conv = matmul(cols, w_flat)`. Images are split across the worker pool;
/// each image's rows are written by exactly one worker.
pub fn im2col(x: &[f32], batch: usize, g: &ConvGeom) -> Vec<f32> {
    let mut cols = vec![0.0f32; batch * g.h_out * g.w_out * g.kdim()];
    // Freshly zeroed allocation: skip the in-shard clear (training hot path).
    im2col_body(x, batch, g, &mut cols, false);
    cols
}

/// [`im2col`] into a caller-owned slice (the inference arena). Each shard
/// is zeroed before the patch copy, so a reused buffer produces the same
/// bits as the freshly-allocated path — including the padded borders.
pub fn im2col_into(x: &[f32], batch: usize, g: &ConvGeom, cols: &mut [f32]) {
    im2col_body(x, batch, g, cols, true);
}

fn im2col_body(x: &[f32], batch: usize, g: &ConvGeom, cols: &mut [f32], zero_shards: bool) {
    let k = g.ksize;
    let kk = g.kdim();
    let plane = g.h_in * g.w_in * g.cin;
    let width = g.h_out * g.w_out * kk;
    debug_assert_eq!(cols.len(), batch * width);
    pool::run_rows(cols, batch, width, CONV_MIN_BATCH, |b0, shard| {
        if zero_shards {
            shard.fill(0.0);
        }
        for (bi, dst) in shard.chunks_mut(width).enumerate() {
            let xb = &x[(b0 + bi) * plane..(b0 + bi + 1) * plane];
            for oh in 0..g.h_out {
                for ow in 0..g.w_out {
                    let row = &mut dst[(oh * g.w_out + ow) * kk..][..kk];
                    for kh in 0..k {
                        let ih = (oh * g.stride + kh) as isize - g.pad_top as isize;
                        if !(0..g.h_in as isize).contains(&ih) {
                            continue;
                        }
                        for kw in 0..k {
                            let iw = (ow * g.stride + kw) as isize - g.pad_left as isize;
                            if !(0..g.w_in as isize).contains(&iw) {
                                continue;
                            }
                            let src = ((ih as usize) * g.w_in + iw as usize) * g.cin;
                            let d = (kh * k + kw) * g.cin;
                            row[d..d + g.cin].copy_from_slice(&xb[src..src + g.cin]);
                        }
                    }
                }
            }
        }
    });
}

/// Transpose of [`im2col`]: scatter-add patch-row gradients back onto the
/// input layout (the dx of the convolution given dcols = dz @ w^T). Split
/// by image like [`im2col`]; the scatter-adds within one image run in the
/// fixed (oh, ow, kh, kw) order regardless of the worker count.
pub fn col2im(dcols: &[f32], batch: usize, g: &ConvGeom) -> Vec<f32> {
    let k = g.ksize;
    let kk = g.kdim();
    let plane = g.h_in * g.w_in * g.cin;
    let mut dx = vec![0.0f32; batch * plane];
    pool::run_rows(&mut dx, batch, plane, CONV_MIN_BATCH, |b0, shard| {
        for (bi, dxb) in shard.chunks_mut(plane).enumerate() {
            let b = b0 + bi;
            for oh in 0..g.h_out {
                for ow in 0..g.w_out {
                    let row = &dcols[((b * g.h_out + oh) * g.w_out + ow) * kk..][..kk];
                    for kh in 0..k {
                        let ih = (oh * g.stride + kh) as isize - g.pad_top as isize;
                        if !(0..g.h_in as isize).contains(&ih) {
                            continue;
                        }
                        for kw in 0..k {
                            let iw = (ow * g.stride + kw) as isize - g.pad_left as isize;
                            if !(0..g.w_in as isize).contains(&iw) {
                                continue;
                            }
                            let dst = ((ih as usize) * g.w_in + iw as usize) * g.cin;
                            let src = (kh * k + kw) * g.cin;
                            for c in 0..g.cin {
                                dxb[dst + c] += row[src + c];
                            }
                        }
                    }
                }
            }
        }
    });
    dx
}

/// Depthwise conv forward: out(b, oh, ow, c) += x(b, ih, iw, c) * w(kh, kw, 0, c).
/// Images are split across the worker pool (one image per output shard).
pub fn dwconv_fwd(x: &[f32], w: &[f32], batch: usize, g: &ConvGeom) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * g.h_out * g.w_out * g.cout];
    // Freshly zeroed allocation: skip the in-shard clear (training hot path).
    dwconv_fwd_body(x, w, batch, g, &mut out, false);
    out
}

/// [`dwconv_fwd`] into a caller-owned slice (the inference arena); shards
/// are zeroed before the accumulation, matching the allocating path's bits.
pub fn dwconv_fwd_into(x: &[f32], w: &[f32], batch: usize, g: &ConvGeom, out: &mut [f32]) {
    dwconv_fwd_body(x, w, batch, g, out, true);
}

fn dwconv_fwd_body(
    x: &[f32],
    w: &[f32],
    batch: usize,
    g: &ConvGeom,
    out: &mut [f32],
    zero_shards: bool,
) {
    let (k, c) = (g.ksize, g.cout);
    let plane_in = g.h_in * g.w_in * c;
    let width = g.h_out * g.w_out * c;
    debug_assert_eq!(out.len(), batch * width);
    pool::run_rows(out, batch, width, CONV_MIN_BATCH, |b0, shard| {
        if zero_shards {
            shard.fill(0.0);
        }
        for (bi, ob) in shard.chunks_mut(width).enumerate() {
            let xb = &x[(b0 + bi) * plane_in..(b0 + bi + 1) * plane_in];
            for oh in 0..g.h_out {
                for ow in 0..g.w_out {
                    let orow = &mut ob[(oh * g.w_out + ow) * c..][..c];
                    for kh in 0..k {
                        let ih = (oh * g.stride + kh) as isize - g.pad_top as isize;
                        if !(0..g.h_in as isize).contains(&ih) {
                            continue;
                        }
                        for kw in 0..k {
                            let iw = (ow * g.stride + kw) as isize - g.pad_left as isize;
                            if !(0..g.w_in as isize).contains(&iw) {
                                continue;
                            }
                            let xrow = &xb[((ih as usize) * g.w_in + iw as usize) * c..][..c];
                            let wrow = &w[(kh * k + kw) * c..][..c];
                            for ch in 0..c {
                                orow[ch] += xrow[ch] * wrow[ch];
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Depthwise conv weight gradient: dW(kh, kw, 0, c) = sum x * dz.
///
/// Parallelized by sharding the *channel* dimension across the worker pool:
/// each worker owns a contiguous channel range and reduces its channels over
/// the full (batch, oh, ow, kh, kw) nest in exactly the order the serial
/// kernel used, accumulating into a channel-major scratch; a final cheap
/// transpose restores the (k*k, c) output layout. Because a channel's
/// reduction chain never crosses a shard boundary, the result is bitwise
/// identical for any thread count — and to the historical serial kernel.
pub fn dwconv_grad_w(x: &[f32], dz: &[f32], batch: usize, g: &ConvGeom) -> Vec<f32> {
    let (k, c) = (g.ksize, g.cout);
    let kk = k * k;
    let plane_in = g.h_in * g.w_in * c;
    // Channel-major scratch: row ch holds dW(.., .., 0, ch) over the taps.
    let mut dwt = vec![0.0f32; c * kk];
    pool::run_rows(&mut dwt, c, kk, DWGRADW_MIN_CH, |c0, shard| {
        let nch = shard.len() / kk;
        for b in 0..batch {
            let xb = &x[b * plane_in..(b + 1) * plane_in];
            for oh in 0..g.h_out {
                for ow in 0..g.w_out {
                    let drow = &dz[((b * g.h_out + oh) * g.w_out + ow) * c..][..c];
                    for kh in 0..k {
                        let ih = (oh * g.stride + kh) as isize - g.pad_top as isize;
                        if !(0..g.h_in as isize).contains(&ih) {
                            continue;
                        }
                        for kw in 0..k {
                            let iw = (ow * g.stride + kw) as isize - g.pad_left as isize;
                            if !(0..g.w_in as isize).contains(&iw) {
                                continue;
                            }
                            let xrow = &xb[((ih as usize) * g.w_in + iw as usize) * c..][..c];
                            let tap = kh * k + kw;
                            for ci in 0..nch {
                                let ch = c0 + ci;
                                shard[ci * kk + tap] += xrow[ch] * drow[ch];
                            }
                        }
                    }
                }
            }
        }
    });
    // Transpose the (c, k*k) scratch back to the (k*k, c) weight layout.
    let mut dw = vec![0.0f32; kk * c];
    for ch in 0..c {
        for tap in 0..kk {
            dw[tap * c + ch] = dwt[ch * kk + tap];
        }
    }
    dw
}

/// Depthwise conv input gradient: dx(b, ih, iw, c) += w(kh, kw, 0, c) * dz.
/// Images are split across the worker pool; each image's scatter-adds run
/// in the fixed (oh, ow, kh, kw) order regardless of the worker count.
pub fn dwconv_grad_x(dz: &[f32], w: &[f32], batch: usize, g: &ConvGeom) -> Vec<f32> {
    let (k, c) = (g.ksize, g.cout);
    let plane_in = g.h_in * g.w_in * c;
    let mut dx = vec![0.0f32; batch * plane_in];
    pool::run_rows(&mut dx, batch, plane_in, CONV_MIN_BATCH, |b0, shard| {
        for (bi, dxb) in shard.chunks_mut(plane_in).enumerate() {
            let b = b0 + bi;
            for oh in 0..g.h_out {
                for ow in 0..g.w_out {
                    let drow = &dz[((b * g.h_out + oh) * g.w_out + ow) * c..][..c];
                    for kh in 0..k {
                        let ih = (oh * g.stride + kh) as isize - g.pad_top as isize;
                        if !(0..g.h_in as isize).contains(&ih) {
                            continue;
                        }
                        for kw in 0..k {
                            let iw = (ow * g.stride + kw) as isize - g.pad_left as isize;
                            if !(0..g.w_in as isize).contains(&iw) {
                                continue;
                            }
                            let xrow = &mut dxb[((ih as usize) * g.w_in + iw as usize) * c..][..c];
                            let wrow = &w[(kh * k + kw) * c..][..c];
                            for ch in 0..c {
                                xrow[ch] += wrow[ch] * drow[ch];
                            }
                        }
                    }
                }
            }
        }
    });
    dx
}

/// 2x2-style max pooling (VALID, stride = size, NHWC). Returns the pooled
/// output and, per output element, the flat index of its argmax in `x`
/// (first maximum wins ties) for the backward scatter.
pub fn maxpool_fwd(
    x: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
    size: usize,
) -> (Vec<f32>, Vec<u32>) {
    let (ho, wo) = (h / size, w / size);
    let mut out = vec![0.0f32; batch * ho * wo * c];
    let mut arg = vec![0u32; batch * ho * wo * c];
    for b in 0..batch {
        for oh in 0..ho {
            for ow in 0..wo {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0u32;
                    for kh in 0..size {
                        for kw in 0..size {
                            let idx = ((b * h + oh * size + kh) * w + ow * size + kw) * c + ch;
                            if x[idx] > best {
                                best = x[idx];
                                best_idx = idx as u32;
                            }
                        }
                    }
                    let o = ((b * ho + oh) * wo + ow) * c + ch;
                    out[o] = best;
                    arg[o] = best_idx;
                }
            }
        }
    }
    (out, arg)
}

/// Forward-only max pooling into a caller-owned slice: the same
/// element-visit order and comparisons as [`maxpool_fwd`] without the
/// argmax bookkeeping (inference runs no backward scatter), so the pooled
/// values are bitwise identical to the training path.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_infer_into(
    x: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
    size: usize,
    out: &mut [f32],
) {
    let (ho, wo) = (h / size, w / size);
    debug_assert_eq!(out.len(), batch * ho * wo * c);
    for b in 0..batch {
        for oh in 0..ho {
            for ow in 0..wo {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    for kh in 0..size {
                        for kw in 0..size {
                            let idx = ((b * h + oh * size + kh) * w + ow * size + kw) * c + ch;
                            if x[idx] > best {
                                best = x[idx];
                            }
                        }
                    }
                    out[((b * ho + oh) * wo + ow) * c + ch] = best;
                }
            }
        }
    }
}

/// Max pooling backward: route each output gradient to its argmax input.
pub fn maxpool_bwd(dz: &[f32], argmax: &[u32], in_len: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; in_len];
    for (&g, &i) in dz.iter().zip(argmax.iter()) {
        dx[i as usize] += g;
    }
    dx
}

/// Global average pool over the spatial dims: (b, h, w, c) -> (b, c).
pub fn gap_fwd(x: &[f32], batch: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * c];
    gap_fwd_into(x, batch, h, w, c, &mut out);
    out
}

/// [`gap_fwd`] into a caller-owned slice (rows are zeroed before the sum).
pub fn gap_fwd_into(x: &[f32], batch: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    let hw = h * w;
    debug_assert_eq!(out.len(), batch * c);
    for b in 0..batch {
        let xb = &x[b * hw * c..(b + 1) * hw * c];
        let orow = &mut out[b * c..(b + 1) * c];
        orow.fill(0.0);
        for p in 0..hw {
            for ch in 0..c {
                orow[ch] += xb[p * c + ch];
            }
        }
        for v in orow.iter_mut() {
            *v /= hw as f32;
        }
    }
}

/// Global average pool backward: broadcast dz / (h * w) over the plane.
pub fn gap_bwd(dz: &[f32], batch: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let hw = h * w;
    let inv = 1.0 / hw as f32;
    let mut dx = vec![0.0f32; batch * hw * c];
    for b in 0..batch {
        let drow = &dz[b * c..(b + 1) * c];
        let xb = &mut dx[b * hw * c..(b + 1) * hw * c];
        for p in 0..hw {
            for ch in 0..c {
                xb[p * c + ch] = drow[ch] * inv;
            }
        }
    }
    dx
}

/// Per-channel affine ("BN-lite"): out = x * s + b over (rows, c).
pub fn affine_fwd(x: &[f32], s: &[f32], b: &[f32], rows: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * c];
    affine_fwd_into(x, s, b, rows, c, &mut out);
    out
}

/// [`affine_fwd`] into a caller-owned slice (pure overwrite).
pub fn affine_fwd_into(x: &[f32], s: &[f32], b: &[f32], rows: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), rows * c);
    for r in 0..rows {
        let xrow = &x[r * c..(r + 1) * c];
        let orow = &mut out[r * c..(r + 1) * c];
        for ch in 0..c {
            orow[ch] = xrow[ch] * s[ch] + b[ch];
        }
    }
}

/// Affine backward: (dx = dz * s, ds = sum x * dz, db = sum dz).
pub fn affine_bwd(
    x: &[f32],
    dz: &[f32],
    s: &[f32],
    rows: usize,
    c: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * c];
    let mut ds = vec![0.0f32; c];
    let mut db = vec![0.0f32; c];
    for r in 0..rows {
        let xrow = &x[r * c..(r + 1) * c];
        let drow = &dz[r * c..(r + 1) * c];
        let orow = &mut dx[r * c..(r + 1) * c];
        for ch in 0..c {
            orow[ch] = drow[ch] * s[ch];
            ds[ch] += xrow[ch] * drow[ch];
            db[ch] += drow[ch];
        }
    }
    (dx, ds, db)
}

// ---- dense linear algebra (row-major) --------------------------------------
//
// The production kernels are cache-blocked and register-tiled: the right
// operand is packed once per call into NR-wide column panels, and each
// MR x NR output tile accumulates in a bank of f32 registers shaped for
// auto-vectorization (NR = 16 -> two 8-lane vectors per tile row). Output
// row ranges are split across the `pool` workers. Every output element is
// reduced over k in a single chain of increasing k, an order fixed by the
// tile constants alone — so results are bitwise identical for any thread
// count (`WAVEQ_THREADS`) and any tile position. The seed's triple-loop
// kernels live on in [`scalar`] as the numerics oracle for the property
// tests and the baseline `bench_kernels` measures speedup against.

/// Rows per register tile.
const MR: usize = 4;
/// Columns per register tile (two 8-lane f32 vectors).
const NR: usize = 16;

/// Minimum output rows per worker shard for the row-parallel GEMMs.
const GEMM_MIN_ROWS: usize = 32;
/// Minimum dW rows per worker shard (each row is a full-depth reduction).
const GRADW_MIN_ROWS: usize = 8;
/// Minimum batch images per worker shard for im2col/col2im/dwconv.
const CONV_MIN_BATCH: usize = 4;
/// Minimum channels per worker shard for the depthwise weight gradient.
const DWGRADW_MIN_CH: usize = 8;

/// Fused (on targets with FMA) or separate multiply-add. The choice is a
/// compile-time constant, so any given binary is internally consistent and
/// the thread-count determinism guarantee is unaffected.
#[inline(always)]
fn fma(a: f32, b: f32, c: f32) -> f32 {
    #[cfg(target_feature = "fma")]
    {
        a.mul_add(b, c)
    }
    #[cfg(not(target_feature = "fma"))]
    {
        a * b + c
    }
}

/// Strided view of the left GEMM operand: element (m, k) sits at
/// `buf[m * ms + k * ks]`, so one microkernel serves both `x @ w`
/// (ms = row stride, ks = 1) and `h^T @ dz` (ms = 1, ks = row stride).
#[derive(Clone, Copy)]
struct AView<'a> {
    buf: &'a [f32],
    ms: usize,
    ks: usize,
}

/// One MR x NR register tile: out[m][..nw] = init + sum_k a(m, k) * B(k, ..).
///
/// The panel is k-major and NR-wide (zero-padded past `nw`), so the inner
/// loop is a broadcast multiply-accumulate over NR contiguous lanes — the
/// shape LLVM auto-vectorizes. Each output element is one add chain in
/// increasing k: the fixed reduction order behind the determinism contract.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_tile(
    a: AView<'_>,
    mr: usize,
    k: usize,
    panel: &[f32],
    init: &[f32; NR],
    out: &mut [f32],
    ldo: usize,
    nw: usize,
) {
    debug_assert!((1..=MR).contains(&mr) && (1..=NR).contains(&nw));
    let mut acc = [[0.0f32; NR]; MR];
    for row in acc.iter_mut().take(mr) {
        *row = *init;
    }
    for kk in 0..k {
        let prow = &panel[kk * NR..kk * NR + NR];
        for (m, row) in acc.iter_mut().enumerate().take(mr) {
            let av = a.buf[m * a.ms + kk * a.ks];
            for (ac, &pv) in row.iter_mut().zip(prow.iter()) {
                *ac = fma(av, pv, *ac);
            }
        }
    }
    for (m, row) in acc.iter().enumerate().take(mr) {
        out[m * ldo..m * ldo + nw].copy_from_slice(&row[..nw]);
    }
}

/// Pack a row-major (k x n) matrix into k-major NR-wide column panels,
/// zero-padded in the final panel.
fn pack_b(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    let panels = n.div_ceil(NR);
    let mut packed = vec![0.0f32; panels * k * NR];
    for j in 0..panels {
        let n0 = j * NR;
        let nw = NR.min(n - n0);
        let dst = &mut packed[j * k * NR..(j + 1) * k * NR];
        for kk in 0..k {
            dst[kk * NR..kk * NR + nw].copy_from_slice(&b[kk * n + n0..kk * n + n0 + nw]);
        }
    }
    packed
}

/// Pack the transpose of a row-major (n x k) matrix into the same layout
/// as [`pack_b`] — i.e. the panels of the (k x n) matrix B^T.
fn pack_bt(b: &[f32], n: usize, k: usize) -> Vec<f32> {
    let panels = n.div_ceil(NR);
    let mut packed = vec![0.0f32; panels * k * NR];
    for j in 0..panels {
        let n0 = j * NR;
        let nw = NR.min(n - n0);
        let dst = &mut packed[j * k * NR..(j + 1) * k * NR];
        for ni in 0..nw {
            let src = &b[(n0 + ni) * k..(n0 + ni) * k + k];
            for (kk, &v) in src.iter().enumerate() {
                dst[kk * NR + ni] = v;
            }
        }
    }
    packed
}

/// Row-parallel blocked GEMM over a pre-packed right operand:
/// out(r, j) = bias(j) + sum_k a(r, k) * B(k, j).
///
/// Row blocks are outermost so the big left operand streams from memory
/// exactly once per panel sweep while the packed panels stay cache-hot.
#[allow(clippy::too_many_arguments)]
fn gemm_packed(
    a: &[f32],
    row_stride: usize,
    k_stride: usize,
    rows: usize,
    k: usize,
    n: usize,
    packed: &[f32],
    bias: Option<&[f32]>,
    min_rows: usize,
    out: &mut [f32],
) {
    let panels = n.div_ceil(NR);
    pool::run_rows(out, rows, n, min_rows, |r0, shard| {
        let nrows = shard.len() / n;
        let mut r = 0;
        while r < nrows {
            let mr = MR.min(nrows - r);
            let tile = AView { buf: &a[(r0 + r) * row_stride..], ms: row_stride, ks: k_stride };
            for j in 0..panels {
                let n0 = j * NR;
                let nw = NR.min(n - n0);
                let mut init = [0.0f32; NR];
                if let Some(bv) = bias {
                    init[..nw].copy_from_slice(&bv[n0..n0 + nw]);
                }
                let panel = &packed[j * k * NR..(j + 1) * k * NR];
                micro_tile(tile, mr, k, panel, &init, &mut shard[r * n + n0..], n, nw);
            }
            r += mr;
        }
    });
}

/// A GEMM right operand packed *once* into the NR-wide panel layout of
/// `pack_b`. Frozen-model weights are packed at [`super::super::infer`]
/// load time, so the steady-state inference dispatch skips the per-call
/// pack the training kernels pay on every step.
pub struct PackedB {
    panels: Vec<f32>,
    k: usize,
    n: usize,
}

impl PackedB {
    /// Pack a row-major (k x n) matrix.
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedB {
        debug_assert_eq!(b.len(), k * n);
        PackedB { panels: pack_b(b, k, n), k, n }
    }

    /// Pack straight from frozen quantizer codes, decoding each element
    /// with the exact [`decode_codes_into`] expression while writing its
    /// panel slot. Bitwise identical to `PackedB::pack(&decoded, k, n)` —
    /// but the decoded f32 copy of the weight is never materialized, so
    /// the resident footprint of a packed layer is the panels plus the
    /// integral codes, not a third full-size f32 tensor.
    pub fn pack_codes(codes: &[u16], k_levels: f32, m: f32, kdim: usize, n: usize) -> PackedB {
        debug_assert_eq!(codes.len(), kdim * n);
        let npanels = n.div_ceil(NR);
        let mut packed = vec![0.0f32; npanels * kdim * NR];
        for j in 0..npanels {
            let n0 = j * NR;
            let nw = NR.min(n - n0);
            let dst = &mut packed[j * kdim * NR..(j + 1) * kdim * NR];
            for kk in 0..kdim {
                let src = &codes[kk * n + n0..kk * n + n0 + nw];
                for (ni, &c) in src.iter().enumerate() {
                    dst[kk * NR + ni] = m * (2.0 * (c as f32 / k_levels) - 1.0);
                }
            }
        }
        PackedB { panels: packed, k: kdim, n }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

/// out(r, j) = bias(j) + sum_k x(r, k) * B(k, j) over a pre-packed right
/// operand, written into a caller-owned slice. Dispatches the exact
/// `gemm_packed` call (tiles, shard minimum, packed layout) that
/// [`matmul`] / [`matmul_bias`] make, so the output bits are identical to
/// those kernels for any thread count.
pub fn matmul_packed_into(
    x: &[f32],
    pb: &PackedB,
    rows: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * pb.n);
    gemm_packed(x, pb.k, 1, rows, pb.k, pb.n, &pb.panels, bias, GEMM_MIN_ROWS, out);
}

// ---- integer GEMM (u8 activation codes x i8 weight codes -> i32) -----------
//
// The `Precision::Int8` inference path: frozen weight codes are recentred
// onto the signed grid `q = 2c - k` (the DoReFa/WRPN level index around
// zero, |q| <= k <= 127 for bit widths <= 7) and packed once into i8
// panels; activations arrive as the u8 `quantize_k` numerators
// (`act_codes_into`). Products accumulate in i32 — integer adds are
// associative, but the kernel still reduces every output element over k in
// a single in-order chain with the exact tile constants and pool sharding
// of the f32 GEMM, so the path is bit-deterministic at any `WAVEQ_THREADS`
// by the same argument (and by the stronger integer one). A single f32
// rescale `(m_w / k_w) * (m_a / k_a)` plus the bias is applied per output
// element at the end. Not bitwise-f32: the error contract lives with
// `Precision` in `runtime::infer`.

/// A GEMM right operand held as recentred i8 quantizer codes in the
/// NR-wide k-major panel layout of [`PackedB`], plus the per-layer weight
/// rescale `m / k` — the integer twin packed at `runtime::infer` load time
/// for layers the int8 path can execute.
pub struct PackedQuant {
    panels: Vec<i8>,
    k: usize,
    n: usize,
    w_scale: f32,
}

impl PackedQuant {
    /// Pack frozen codes `c in [0, k_levels]` as `q = 2c - k_levels`.
    ///
    /// Requires `k_levels <= 127` (bit widths 2..=7) so `q` fits i8, and
    /// `kdim < 66_000` so the worst-case `sum_k |q * j| <= kdim * 127 * 255`
    /// stays inside i32 — both are static per-layer facts the caller gates
    /// on, not data-dependent conditions.
    pub fn pack_codes(codes: &[u16], k_levels: u32, m: f32, kdim: usize, n: usize) -> PackedQuant {
        assert!((1..=127).contains(&k_levels), "k_levels {k_levels} exceeds the i8 grid");
        assert!(kdim < 66_000, "kdim {kdim} could overflow the i32 accumulator");
        debug_assert_eq!(codes.len(), kdim * n);
        let npanels = n.div_ceil(NR);
        let mut panels = vec![0i8; npanels * kdim * NR];
        for j in 0..npanels {
            let n0 = j * NR;
            let nw = NR.min(n - n0);
            let dst = &mut panels[j * kdim * NR..(j + 1) * kdim * NR];
            for kk in 0..kdim {
                let src = &codes[kk * n + n0..kk * n + n0 + nw];
                for (ni, &c) in src.iter().enumerate() {
                    dst[kk * NR + ni] = (2 * c as i32 - k_levels as i32) as i8;
                }
            }
        }
        PackedQuant { panels, k: kdim, n, w_scale: m / k_levels as f32 }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The weight grid step `m / k_levels`; the dispatch-time rescale is
    /// this times the activation grid step.
    pub fn w_scale(&self) -> f32 {
        self.w_scale
    }
}

/// One MR x NR integer register tile:
/// `out[m][..nw] = init + scale * sum_k a(m, k) * P(k, ..)` with the sum
/// in i32. Same fixed increasing-k chain per output element as
/// [`micro_tile`]; the single f32 multiply-add happens after the integer
/// reduction completes.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_tile_quant(
    a: &[u8],
    lda: usize,
    mr: usize,
    k: usize,
    panel: &[i8],
    init: &[f32; NR],
    scale: f32,
    out: &mut [f32],
    ldo: usize,
    nw: usize,
) {
    debug_assert!((1..=MR).contains(&mr) && (1..=NR).contains(&nw));
    let mut acc = [[0i32; NR]; MR];
    for kk in 0..k {
        let prow = &panel[kk * NR..kk * NR + NR];
        for (m, row) in acc.iter_mut().enumerate().take(mr) {
            let av = a[m * lda + kk] as i32;
            for (ac, &pv) in row.iter_mut().zip(prow.iter()) {
                *ac += av * pv as i32;
            }
        }
    }
    for (m, row) in acc.iter().enumerate().take(mr) {
        let orow = &mut out[m * ldo..m * ldo + nw];
        for ((o, &ac), &iv) in orow.iter_mut().zip(row.iter()).zip(init.iter()) {
            *o = fma(scale, ac as f32, iv);
        }
    }
}

/// out(r, j) = bias(j) + scale * sum_k codes(r, k) * Q(k, j) over the
/// recentred i8 panels, written into a caller-owned slice. Row sharding
/// and tile walk mirror [`matmul_packed_into`] exactly.
pub fn matmul_quant_into(
    acodes: &[u8],
    pq: &PackedQuant,
    rows: usize,
    a_scale: f32,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * pq.n);
    let (k, n) = (pq.k, pq.n);
    let scale = pq.w_scale * a_scale;
    let npanels = n.div_ceil(NR);
    pool::run_rows(out, rows, n, GEMM_MIN_ROWS, |r0, shard| {
        let nrows = shard.len() / n;
        let mut r = 0;
        while r < nrows {
            let mr = MR.min(nrows - r);
            let a = &acodes[(r0 + r) * k..];
            for j in 0..npanels {
                let n0 = j * NR;
                let nw = NR.min(n - n0);
                let mut init = [0.0f32; NR];
                if let Some(bv) = bias {
                    init[..nw].copy_from_slice(&bv[n0..n0 + nw]);
                }
                let panel = &pq.panels[j * k * NR..(j + 1) * k * NR];
                micro_tile_quant(a, k, mr, k, panel, &init, scale, &mut shard[r * n + n0..], n, nw);
            }
            r += mr;
        }
    });
}

/// out(r, o) = x(r, i) @ w(i, o)   (no bias; conv-via-im2col path)
pub fn matmul(x: &[f32], w: &[f32], rows: usize, din: usize, dout: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * dout];
    matmul_into(x, w, rows, din, dout, &mut out);
    out
}

/// [`matmul`] into a caller-owned (pre-zeroed) slice — the train arena path.
pub fn matmul_into(x: &[f32], w: &[f32], rows: usize, din: usize, dout: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), rows * dout);
    let packed = pack_b(w, din, dout);
    gemm_packed(x, din, 1, rows, din, dout, &packed, None, GEMM_MIN_ROWS, out);
}

/// out(b, o) = x(b, i) @ w(i, o) + bias(o)
pub fn matmul_bias(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    di: usize,
    dout: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; b * dout];
    matmul_bias_into(x, w, bias, b, di, dout, &mut out);
    out
}

/// [`matmul_bias`] into a caller-owned (pre-zeroed) slice.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias_into(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    di: usize,
    dout: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), b * dout);
    let packed = pack_b(w, di, dout);
    gemm_packed(x, di, 1, b, di, dout, &packed, Some(bias), GEMM_MIN_ROWS, out);
}

/// dW(i, o) = sum_b h(b, i) * dz(b, o)   (h^T @ dz)
///
/// Runs as a GEMM whose left operand is the *columns* of h (ms = 1,
/// ks = di), parallelized over dW rows: the reduction over the batch/rows
/// dimension stays a single in-order chain per element.
pub fn grad_weight(h: &[f32], dz: &[f32], b: usize, di: usize, dout: usize) -> Vec<f32> {
    let mut dw = vec![0.0f32; di * dout];
    let packed = pack_b(dz, b, dout);
    gemm_packed(h, 1, di, di, b, dout, &packed, None, GRADW_MIN_ROWS, &mut dw);
    dw
}

/// db(o) = sum_b dz(b, o)
pub fn grad_bias(dz: &[f32], b: usize, dout: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; dout];
    for r in 0..b {
        for (o, v) in db.iter_mut().enumerate() {
            *v += dz[r * dout + o];
        }
    }
    db
}

/// dh(b, i) = dz(b, o) @ w(i, o)^T
pub fn grad_input(dz: &[f32], w: &[f32], b: usize, di: usize, dout: usize) -> Vec<f32> {
    let mut dh = vec![0.0f32; b * di];
    let packed = pack_bt(w, di, dout);
    gemm_packed(dz, dout, 1, b, dout, di, &packed, None, GEMM_MIN_ROWS, &mut dh);
    dh
}

/// The seed's scalar triple-loop kernels, kept verbatim: the numerics
/// oracle the blocked kernels' property tests compare against, and the
/// single-thread baseline `bench_kernels` measures speedup over for
/// `BENCH_kernels.json`.
pub mod scalar {
    /// out(r, o) = x(r, i) @ w(i, o) — naive row-major triple loop.
    pub fn matmul(x: &[f32], w: &[f32], rows: usize, din: usize, dout: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * dout];
        for r in 0..rows {
            let xrow = &x[r * din..(r + 1) * din];
            let orow = &mut out[r * dout..(r + 1) * dout];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv != 0.0 {
                    let wrow = &w[i * dout..(i + 1) * dout];
                    for (o, &wv) in wrow.iter().enumerate() {
                        orow[o] += xv * wv;
                    }
                }
            }
        }
        out
    }

    /// out(b, o) = x(b, i) @ w(i, o) + bias(o)
    pub fn matmul_bias(
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        b: usize,
        di: usize,
        dout: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; b * dout];
        for r in 0..b {
            let xrow = &x[r * di..(r + 1) * di];
            let orow = &mut out[r * dout..(r + 1) * dout];
            orow.copy_from_slice(bias);
            for (i, &xv) in xrow.iter().enumerate() {
                if xv != 0.0 {
                    let wrow = &w[i * dout..(i + 1) * dout];
                    for (o, &wv) in wrow.iter().enumerate() {
                        orow[o] += xv * wv;
                    }
                }
            }
        }
        out
    }

    /// dW(i, o) = sum_b h(b, i) * dz(b, o)   (h^T @ dz)
    pub fn grad_weight(h: &[f32], dz: &[f32], b: usize, di: usize, dout: usize) -> Vec<f32> {
        let mut dw = vec![0.0f32; di * dout];
        for r in 0..b {
            let hrow = &h[r * di..(r + 1) * di];
            let drow = &dz[r * dout..(r + 1) * dout];
            for (i, &hv) in hrow.iter().enumerate() {
                if hv != 0.0 {
                    let wrow = &mut dw[i * dout..(i + 1) * dout];
                    for (o, &dv) in drow.iter().enumerate() {
                        wrow[o] += hv * dv;
                    }
                }
            }
        }
        dw
    }

    /// dh(b, i) = dz(b, o) @ w(i, o)^T
    pub fn grad_input(dz: &[f32], w: &[f32], b: usize, di: usize, dout: usize) -> Vec<f32> {
        let mut dh = vec![0.0f32; b * di];
        for r in 0..b {
            let drow = &dz[r * dout..(r + 1) * dout];
            let hrow = &mut dh[r * di..(r + 1) * di];
            for i in 0..di {
                let wrow = &w[i * dout..(i + 1) * dout];
                let mut acc = 0.0f32;
                for (o, &wv) in wrow.iter().enumerate() {
                    acc += drow[o] * wv;
                }
                hrow[i] = acc;
            }
        }
        dh
    }
}

// ---- loss ------------------------------------------------------------------

/// Softmax cross-entropy (mean over batch) + accuracy + dL/dlogits.
///
/// `y` is one-hot (batch, classes); dlogits = (softmax - y) / batch.
pub fn softmax_ce(logits: &[f32], y: &[f32], b: usize, c: usize) -> (f32, f32, Vec<f32>) {
    let mut dlogits = vec![0.0f32; b * c];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for r in 0..b {
        let row = &logits[r * c..(r + 1) * c];
        let yrow = &y[r * c..(r + 1) * c];
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0f64;
        for &v in row {
            z += ((v - mx) as f64).exp();
        }
        let logz = z.ln();
        let mut pred = 0usize;
        let mut label = 0usize;
        for j in 0..c {
            let logp = (row[j] - mx) as f64 - logz;
            let p = logp.exp();
            dlogits[r * c + j] = (p as f32 - yrow[j]) / b as f32;
            loss -= yrow[j] as f64 * logp;
            if row[j] > row[pred] {
                pred = j;
            }
            if yrow[j] > yrow[label] {
                label = j;
            }
        }
        if pred == label {
            correct += 1;
        }
    }
    ((loss / b as f64) as f32, correct as f32 / b as f32, dlogits)
}

/// Softmax cross-entropy over a row span of a larger global batch:
/// unnormalized loss sum + correct-prediction count + dL/dlogits scaled by
/// an explicit `denom` (the *global* batch size, not `rows`).
///
/// This is the per-chunk building block of the chunked train path: the
/// global batch is cut into [`GRAD_CHUNKS`] fixed row spans, each span runs
/// this kernel independently, and the chunk sums are reduced in chunk-index
/// order ([`allreduce_fixed_order`]). With `rows == denom` the dlogits are
/// bit-identical to [`softmax_ce`]'s; the loss/acc come back unreduced
/// (f64-accumulated within the span, truncated to f32 at the boundary) so
/// every reduction crosses chunks the same way no matter which worker
/// computed the span.
pub fn softmax_ce_parts(
    logits: &[f32],
    y: &[f32],
    rows: usize,
    c: usize,
    denom: f32,
) -> (f32, f32, Vec<f32>) {
    let mut dlogits = vec![0.0f32; rows * c];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for r in 0..rows {
        let row = &logits[r * c..(r + 1) * c];
        let yrow = &y[r * c..(r + 1) * c];
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0f64;
        for &v in row {
            z += ((v - mx) as f64).exp();
        }
        let logz = z.ln();
        let mut pred = 0usize;
        let mut label = 0usize;
        for j in 0..c {
            let logp = (row[j] - mx) as f64 - logz;
            let p = logp.exp();
            dlogits[r * c + j] = (p as f32 - yrow[j]) / denom;
            loss -= yrow[j] as f64 * logp;
            if row[j] > row[pred] {
                pred = j;
            }
            if yrow[j] > yrow[label] {
                label = j;
            }
        }
        if pred == label {
            correct += 1;
        }
    }
    (loss as f32, correct as f32, dlogits)
}

// ---- chunked gradient reduction --------------------------------------------

/// Number of fixed gradient chunks every global train batch is split into.
/// The chunk grid depends only on the batch — never on the worker count —
/// so an N-worker run reduces the exact same chunk sequence as the fused
/// 1-worker step. A distributed worker count must divide this evenly at
/// configuration time; a *degraded* membership (after a drop) may be any
/// size, because whole chunks are reassigned and the reduction below stays
/// indexed by chunk, not by worker.
pub const GRAD_CHUNKS: usize = 4;

/// Row span `[lo, hi)` of chunk `chunk` within a `batch`-row global batch
/// (floor partition; spans concatenate to exactly `0..batch`).
pub fn chunk_rows(chunk: usize, batch: usize) -> (usize, usize) {
    (chunk * batch / GRAD_CHUNKS, (chunk + 1) * batch / GRAD_CHUNKS)
}

/// Fixed-order all-reduce: element-wise left fold of `parts` onto `dst` in
/// part-index order — `dst[i] = ((dst[i] + parts[0][i]) + parts[1][i]) + …`.
/// The *only* cross-chunk (and cross-worker) gradient reduction in the
/// repo: callers order `parts` by global chunk index, so the float
/// association is a fixed function of the batch alone and the N-worker
/// all-reduce is bit-identical to the 1-worker chunk loop. Audit rule D3
/// recognizes this as a named fixed-order reduction helper.
pub fn allreduce_fixed_order(dst: &mut [f32], parts: &[&[f32]]) {
    for p in parts {
        assert_eq!(p.len(), dst.len(), "allreduce_fixed_order: ragged part");
    }
    for (i, d) in dst.iter_mut().enumerate() {
        *d = parts.iter().fold(*d, |acc, p| acc + p[i]);
    }
}

// ---- optimizer -------------------------------------------------------------

pub const GRAD_CLIP_NORM: f32 = 5.0;

/// Scale the gradient list so its global L2 norm is <= max_norm (optim.py).
pub fn clip_by_global_norm(grads: &mut [Vec<f32>], max_norm: f32) {
    let mut total = 1e-12f64;
    for g in grads.iter() {
        for &v in g {
            total += (v as f64) * (v as f64);
        }
    }
    let total = total.sqrt();
    let scale = (max_norm as f64 / total).min(1.0) as f32;
    if scale < 1.0 {
        for g in grads.iter_mut() {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
    }
}

/// One parameter's momentum update, in place: v' = mu v + g ; w' = w - lr v'.
/// The slice form lets the backend update caller-owned output buffers
/// directly (the `execute_into` zero-copy path).
pub fn sgd_momentum_step(w: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mom: f32) {
    for ((wv, vv), &gv) in w.iter_mut().zip(v.iter_mut()).zip(g.iter()) {
        *vv = mom * *vv + gv;
        *wv -= lr * *vv;
    }
}

/// v' = mu v + g ; w' = w - lr v'  (in place on params/vels).
pub fn sgd_momentum(
    params: &mut [Vec<f32>],
    vels: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    lr: f32,
    mom: f32,
) {
    for ((w, v), g) in params.iter_mut().zip(vels.iter_mut()).zip(grads.iter()) {
        sgd_momentum_step(w, v, g, lr, mom);
    }
}

/// Keep beta in (1, 8] so b = ceil(beta) lands in [2, 8] (optim.clip_beta).
pub fn clip_beta(beta: f32) -> f32 {
    beta.clamp(1.0 + 1e-3, 8.0)
}

/// Eq. 2.4 bitwidth from a continuous beta: b = ceil(beta), clamped to
/// [2, 8]. The single definition shared by the coordinator's
/// `BitAssignment` and `Session::freeze`, so a frozen artifact's packed
/// bitwidths always match the assignment the coordinator reports.
pub fn ceil_bits(beta: f32) -> u32 {
    (beta.ceil() as i64).clamp(2, 8) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_k_snaps_to_grid() {
        assert_eq!(quantize_k(0.5, 1.0), 1.0); // round-half-away at 0.5 * 1
        assert_eq!(quantize_k(0.26, 2.0), 0.5);
        assert_eq!(quantize_k(0.0, 7.0), 0.0);
        assert_eq!(quantize_k(1.0, 7.0), 1.0);
    }

    #[test]
    fn dorefa_output_bounded_by_scale() {
        let w: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.1).collect();
        let (wq, ste, m) = dorefa_quantize(&w, 7.0);
        for (&q, &s) in wq.iter().zip(&ste) {
            assert!(q.abs() <= m + 1e-6, "|{q}| > {m}");
            assert!((0.0..=1.0).contains(&s));
        }
    }

    #[test]
    fn wrpn_identity_ste_and_bound() {
        let w = vec![-0.8f32, -0.2, 0.0, 0.3, 0.9];
        let (wq, m) = wrpn_quantize(&w, 3.0);
        assert!((m - 0.9).abs() < 1e-6);
        for &q in &wq {
            assert!(q.abs() <= m + 1e-6);
        }
    }

    #[test]
    fn act_quantize_noop_at_high_levels() {
        let mut a = vec![0.0f32, 0.25, 0.5, 2.0];
        let orig = a.clone();
        act_quantize(&mut a, 16_777_215.0);
        for (&x, &o) in a.iter().zip(&orig) {
            assert!((x - o).abs() < 1e-5, "{x} vs {o}");
        }
    }

    #[test]
    fn waveq_reg_zero_on_grid() {
        // Minima at v = j / k for integer j.
        let beta = 3.0f64;
        let k = 2f64.powf(beta) - 1.0;
        let grid: Vec<f32> = (0..=7).map(|j| (j as f64 / k) as f32).collect();
        assert!(waveq_reg(&grid, beta) < 1e-9);
    }

    #[test]
    fn waveq_grad_beta_matches_finite_difference() {
        let v = vec![0.13f32, 0.41, 0.77, 0.05, 0.6];
        for &beta in &[2.3f64, 4.0, 6.7] {
            let h = 1e-5;
            let fd = (waveq_reg(&v, beta + h) - waveq_reg(&v, beta - h)) / (2.0 * h);
            let an = waveq_reg_grad_beta(&v, beta);
            assert!((fd - an).abs() < 1e-4 * (1.0 + an.abs()), "beta={beta}: fd={fd} an={an}");
        }
    }

    #[test]
    fn reg_profile_derivatives_match_finite_difference() {
        for norm in 0..3u32 {
            for &(w, b) in &[(0.3f64, 2.5f64), (-0.7, 5.0), (0.9, 7.2)] {
                let h = 1e-5;
                let fd1 = (reg_point(w, b + h, norm) - reg_point(w, b - h, norm)) / (2.0 * h);
                let an1 = reg_point_d1(w, b, norm);
                assert!(
                    (fd1 - an1).abs() < 1e-3 * (1.0 + an1.abs()),
                    "d1 n={norm} w={w} b={b}: fd={fd1} an={an1}"
                );
                let fd2 =
                    (reg_point_d1(w, b + h, norm) - reg_point_d1(w, b - h, norm)) / (2.0 * h);
                let an2 = reg_point_d2(w, b, norm);
                assert!(
                    (fd2 - an2).abs() < 1e-2 * (1.0 + an2.abs()),
                    "d2 n={norm} w={w} b={b}: fd={fd2} an={an2}"
                );
            }
        }
    }

    #[test]
    fn matmul_and_grads_agree_with_manual() {
        // x (2,3) @ w (3,2) + bias
        let x = vec![1.0, 2.0, 3.0, 0.5, -1.0, 2.0];
        let w = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let bias = vec![0.1, -0.1];
        let out = matmul_bias(&x, &w, &bias, 2, 3, 2);
        for (o, e) in out.iter().zip([4.1f32, 4.9, 2.6, 0.9]) {
            assert!((o - e).abs() < 1e-6, "{o} vs {e}");
        }
        let dz = vec![1.0, 0.0, 0.0, 1.0];
        let dw = grad_weight(&x, &dz, 2, 3, 2);
        assert_eq!(dw, vec![1.0, 0.5, 2.0, -1.0, 3.0, 2.0]);
        let db = grad_bias(&dz, 2, 2);
        assert_eq!(db, vec![1.0, 1.0]);
        let dh = grad_input(&dz, &w, 2, 3, 2);
        assert_eq!(dh, vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let logits = vec![0.0f32; 4 * 10];
        let mut y = vec![0.0f32; 4 * 10];
        for r in 0..4 {
            y[r * 10 + r] = 1.0;
        }
        let (loss, _acc, dl) = softmax_ce(&logits, &y, 4, 10);
        assert!((loss - (10f32).ln()).abs() < 1e-5);
        // Gradient sums to zero per row.
        for r in 0..4 {
            let s: f32 = dl[r * 10..(r + 1) * 10].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn clip_caps_global_norm() {
        let mut g = vec![vec![3.0f32, 4.0], vec![0.0f32; 2]];
        clip_by_global_norm(&mut g, 2.5);
        let norm: f32 = g.iter().flatten().map(|v| v * v).sum::<f32>().sqrt();
        assert!((norm - 2.5).abs() < 1e-4);
        let mut small = vec![vec![0.3f32]];
        clip_by_global_norm(&mut small, 5.0);
        assert_eq!(small[0][0], 0.3);
    }

    #[test]
    fn sgd_momentum_updates() {
        let mut p = vec![vec![1.0f32]];
        let mut v = vec![vec![0.5f32]];
        let g = vec![vec![1.0f32]];
        sgd_momentum(&mut p, &mut v, &g, 0.1, 0.9);
        assert!((v[0][0] - 1.45).abs() < 1e-6);
        assert!((p[0][0] - (1.0 - 0.145)).abs() < 1e-6);
    }

    #[test]
    fn clip_beta_range() {
        assert_eq!(clip_beta(0.2), 1.001);
        assert_eq!(clip_beta(9.5), 8.0);
        assert_eq!(clip_beta(4.2), 4.2);
    }

    // ---- conv / pool / affine kernels --------------------------------------

    fn filled(n: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n).map(f).collect()
    }

    /// Forward conv via im2col + matmul (the path the executor takes).
    fn conv_ref(x: &[f32], w: &[f32], batch: usize, g: &ConvGeom) -> Vec<f32> {
        let cols = im2col(x, batch, g);
        matmul(&cols, w, g.rows(batch), g.kdim(), g.cout)
    }

    #[test]
    fn conv_geom_same_padding_matches_xla() {
        // k=3 s=1: h preserved, symmetric pad 1.
        let g = conv_geom(16, 16, 3, 8, 3, 1, false);
        assert_eq!((g.h_out, g.w_out, g.pad_top, g.pad_left), (16, 16, 1, 1));
        // k=3 s=2 on even h: ceil(16/2)=8, total pad 1 => low 0 / high 1.
        let g = conv_geom(16, 16, 3, 8, 3, 2, false);
        assert_eq!((g.h_out, g.pad_top), (8, 0));
        // k=5 s=2 on 24: ho 12, total pad 3 => low 1 / high 2.
        let g = conv_geom(24, 24, 3, 16, 5, 2, false);
        assert_eq!((g.h_out, g.pad_top), (12, 1));
        // 1x1 s=2 projection: no padding ever.
        let g = conv_geom(16, 16, 8, 16, 1, 2, false);
        assert_eq!((g.h_out, g.pad_top), (8, 0));
    }

    #[test]
    fn conv_identity_kernel_passes_input_through() {
        // 3x3 kernel with only the center tap set, 1 channel in/out, s=1:
        // SAME conv must reproduce the input exactly.
        let g = conv_geom(5, 4, 1, 1, 3, 1, false);
        let x = filled(5 * 4, |i| (i as f32 * 0.7).sin());
        let mut w = vec![0.0f32; 9];
        w[4] = 1.0; // center of the 3x3
        let out = conv_ref(&x, &w, 1, &g);
        for (o, e) in out.iter().zip(&x) {
            assert!((o - e).abs() < 1e-6, "{o} vs {e}");
        }
    }

    #[test]
    fn conv_matches_direct_convolution() {
        // Naive direct conv as the oracle for the im2col path.
        let (b, h, w_, cin, cout, k, s) = (2usize, 5usize, 4usize, 3usize, 2usize, 3usize, 2usize);
        let g = conv_geom(h, w_, cin, cout, k, s, false);
        let x = filled(b * h * w_ * cin, |i| ((i * 37 % 17) as f32 - 8.0) * 0.1);
        let wt = filled(k * k * cin * cout, |i| ((i * 23 % 13) as f32 - 6.0) * 0.05);
        let got = conv_ref(&x, &wt, b, &g);
        for bi in 0..b {
            for oh in 0..g.h_out {
                for ow in 0..g.w_out {
                    for co in 0..cout {
                        let mut acc = 0.0f32;
                        for kh in 0..k {
                            for kw in 0..k {
                                let ih = (oh * s + kh) as isize - g.pad_top as isize;
                                let iw = (ow * s + kw) as isize - g.pad_left as isize;
                                if !(0..h as isize).contains(&ih)
                                    || !(0..w_ as isize).contains(&iw)
                                {
                                    continue;
                                }
                                for ci in 0..cin {
                                    let xi = ((bi * h + ih as usize) * w_ + iw as usize) * cin + ci;
                                    let wi = ((kh * k + kw) * cin + ci) * cout + co;
                                    acc += x[xi] * wt[wi];
                                }
                            }
                        }
                        let o = ((bi * g.h_out + oh) * g.w_out + ow) * cout + co;
                        assert!((got[o] - acc).abs() < 1e-5, "({bi},{oh},{ow},{co})");
                    }
                }
            }
        }
    }

    #[test]
    fn conv_gradients_match_finite_difference() {
        // Loss = sum(conv(x, w) * r); conv is linear, so FD is exact up to
        // float roundoff. Checks dW (= cols^T @ dz) and dx (= col2im(dz @ w^T)).
        let (b, h, w_, cin, cout, k, s) = (2usize, 4usize, 4usize, 2usize, 3usize, 3usize, 2usize);
        let g = conv_geom(h, w_, cin, cout, k, s, false);
        let x = filled(b * h * w_ * cin, |i| ((i * 31 % 19) as f32 - 9.0) * 0.07);
        let wt = filled(k * k * cin * cout, |i| ((i * 29 % 11) as f32 - 5.0) * 0.06);
        let r = filled(g.rows(b) * cout, |i| ((i * 13 % 7) as f32 - 3.0) * 0.2);
        let loss = |x: &[f32], wt: &[f32]| -> f64 {
            conv_ref(x, wt, b, &g)
                .iter()
                .zip(&r)
                .map(|(&o, &rv)| (o * rv) as f64)
                .sum()
        };
        let cols = im2col(&x, b, &g);
        let dw = grad_weight(&cols, &r, g.rows(b), g.kdim(), cout);
        let dcols = grad_input(&r, &wt, g.rows(b), g.kdim(), cout);
        let dx = col2im(&dcols, b, &g);
        let eps = 1e-2f32;
        for &i in &[0usize, 7, wt.len() - 1] {
            let mut wp = wt.clone();
            wp[i] += eps;
            let mut wm = wt.clone();
            wm[i] -= eps;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert!((fd - dw[i] as f64).abs() < 1e-3, "dW[{i}]: fd={fd} an={}", dw[i]);
        }
        for &i in &[0usize, 11, x.len() - 1] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&xp, &wt) - loss(&xm, &wt)) / (2.0 * eps as f64);
            assert!((fd - dx[i] as f64).abs() < 1e-3, "dx[{i}]: fd={fd} an={}", dx[i]);
        }
    }

    #[test]
    fn dwconv_gradients_match_finite_difference() {
        let (b, h, w_, c, k, s) = (2usize, 4usize, 3usize, 3usize, 3usize, 1usize);
        let g = conv_geom(h, w_, c, c, k, s, true);
        let x = filled(b * h * w_ * c, |i| ((i * 41 % 23) as f32 - 11.0) * 0.05);
        let wt = filled(k * k * c, |i| ((i * 17 % 9) as f32 - 4.0) * 0.1);
        let r = filled(g.rows(b) * c, |i| ((i * 19 % 5) as f32 - 2.0) * 0.3);
        let loss = |x: &[f32], wt: &[f32]| -> f64 {
            dwconv_fwd(x, wt, b, &g)
                .iter()
                .zip(&r)
                .map(|(&o, &rv)| (o * rv) as f64)
                .sum()
        };
        let dw = dwconv_grad_w(&x, &r, b, &g);
        let dx = dwconv_grad_x(&r, &wt, b, &g);
        let eps = 1e-2f32;
        for &i in &[0usize, 5, wt.len() - 1] {
            let mut wp = wt.clone();
            wp[i] += eps;
            let mut wm = wt.clone();
            wm[i] -= eps;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert!((fd - dw[i] as f64).abs() < 1e-3, "dW[{i}]: fd={fd} an={}", dw[i]);
        }
        for &i in &[0usize, 9, x.len() - 1] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&xp, &wt) - loss(&xm, &wt)) / (2.0 * eps as f64);
            assert!((fd - dx[i] as f64).abs() < 1e-3, "dx[{i}]: fd={fd} an={}", dx[i]);
        }
    }

    #[test]
    fn maxpool_selects_max_and_routes_gradient() {
        // One batch, 4x4x1, pool 2 -> 2x2.
        #[rustfmt::skip]
        let x = vec![
            1.0, 5.0, 2.0, 0.0,
            3.0, 4.0, 1.0, 7.0,
            0.0, 1.0, 2.0, 2.0,
            9.0, 0.0, 3.0, 1.0,
        ];
        let (out, arg) = maxpool_fwd(&x, 1, 4, 4, 1, 2);
        assert_eq!(out, vec![5.0, 7.0, 9.0, 3.0]);
        let dz = vec![1.0, 2.0, 3.0, 4.0];
        let dx = maxpool_bwd(&dz, &arg, x.len());
        assert_eq!(dx[1], 1.0); // 5.0 at flat index 1
        assert_eq!(dx[7], 2.0); // 7.0
        assert_eq!(dx[12], 3.0); // 9.0
        assert_eq!(dx[14], 4.0); // 3.0
        assert_eq!(dx.iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn gap_averages_and_spreads_gradient() {
        // (1, 2, 2, 2): channel means.
        let x = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let out = gap_fwd(&x, 1, 2, 2, 2);
        assert_eq!(out, vec![2.5, 25.0]);
        let dx = gap_bwd(&[4.0, 8.0], 1, 2, 2, 2);
        assert_eq!(dx, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn affine_forward_and_gradients() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // rows=2, c=2
        let s = vec![2.0, -1.0];
        let b = vec![0.5, 0.0];
        let out = affine_fwd(&x, &s, &b, 2, 2);
        assert_eq!(out, vec![2.5, -2.0, 6.5, -4.0]);
        let dz = vec![1.0, 1.0, 2.0, -1.0];
        let (dx, ds, db) = affine_bwd(&x, &dz, &s, 2, 2);
        assert_eq!(dx, vec![2.0, -1.0, 4.0, 1.0]); // dz * s
        assert_eq!(ds, vec![1.0 + 6.0, 2.0 - 4.0]); // sum x * dz
        assert_eq!(db, vec![3.0, 0.0]); // sum dz
    }

    #[test]
    fn matmul_agrees_with_matmul_bias_at_zero_bias() {
        let x = vec![1.0, 2.0, 3.0, 0.5, -1.0, 2.0];
        let w = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let a = matmul(&x, &w, 2, 3, 2);
        let b = matmul_bias(&x, &w, &[0.0, 0.0], 2, 3, 2);
        assert_eq!(a, b);
    }

    // ---- blocked kernels vs the scalar oracle -------------------------------

    /// Seed-deterministic fill via the crate's own RNG.
    fn prand(n: usize, seed: u64) -> Vec<f32> {
        crate::util::rng::Rng::new(seed).normal_vec(n, 0.5)
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() <= tol * (1.0 + w.abs()),
                "{what}[{i}]: got {g}, oracle {w}"
            );
        }
    }

    /// Shapes that exercise full tiles, row tails (rows % MR != 0), column
    /// tails (cols % NR != 0), sub-tile problems, and zoo-sized matmuls.
    const GEMM_SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 7),
        (4, 16, 16),
        (7, 33, 17),
        (13, 2, 19),
        (64, 192, 128),
        (130, 27, 16),
        (96, 144, 33),
    ];

    #[test]
    fn blocked_matmul_matches_scalar_oracle() {
        for &(rows, din, dout) in GEMM_SHAPES {
            let x = prand(rows * din, 1);
            let w = prand(din * dout, 2);
            let bias = prand(dout, 3);
            let shape = format!("({rows},{din},{dout})");
            assert_close(
                &matmul(&x, &w, rows, din, dout),
                &scalar::matmul(&x, &w, rows, din, dout),
                1e-4,
                &format!("matmul{shape}"),
            );
            assert_close(
                &matmul_bias(&x, &w, &bias, rows, din, dout),
                &scalar::matmul_bias(&x, &w, &bias, rows, din, dout),
                1e-4,
                &format!("matmul_bias{shape}"),
            );
        }
    }

    #[test]
    fn blocked_gradients_match_scalar_oracle() {
        for &(rows, din, dout) in GEMM_SHAPES {
            let h = prand(rows * din, 4);
            let dz = prand(rows * dout, 5);
            let w = prand(din * dout, 6);
            let shape = format!("({rows},{din},{dout})");
            assert_close(
                &grad_weight(&h, &dz, rows, din, dout),
                &scalar::grad_weight(&h, &dz, rows, din, dout),
                1e-4,
                &format!("grad_weight{shape}"),
            );
            assert_close(
                &grad_input(&dz, &w, rows, din, dout),
                &scalar::grad_input(&dz, &w, rows, din, dout),
                1e-4,
                &format!("grad_input{shape}"),
            );
        }
    }

    #[test]
    fn blocked_kernels_handle_exact_zero_rows() {
        // The scalar oracle skips zero x entries; the blocked kernel must
        // produce the same values when inputs contain exact zeros (padded
        // im2col borders, post-ReLU activations).
        let (rows, din, dout) = (9, 21, 18);
        let mut x = prand(rows * din, 7);
        for (i, v) in x.iter_mut().enumerate() {
            if i.is_multiple_of(3) {
                *v = 0.0;
            }
        }
        let w = prand(din * dout, 8);
        assert_close(
            &matmul(&x, &w, rows, din, dout),
            &scalar::matmul(&x, &w, rows, din, dout),
            1e-4,
            "matmul-with-zeros",
        );
    }

    #[test]
    fn kernels_are_bitwise_deterministic_across_thread_counts() {
        // The contract the pool + fixed reduction order guarantee: the same
        // bits for WAVEQ_THREADS = 1, 2, 4 (and any other value). Exact
        // f32 bit equality, not approximate closeness.
        let (rows, din, dout) = (97, 66, 35);
        let x = prand(rows * din, 9);
        let w = prand(din * dout, 10);
        let bias = prand(dout, 11);
        let dz = prand(rows * dout, 12);
        let bits = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
        let mut refs: Option<(Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>)> = None;
        let _guard = pool::env_lock();
        for threads in ["1", "2", "4"] {
            std::env::set_var("WAVEQ_THREADS", threads);
            let got = (
                bits(&matmul(&x, &w, rows, din, dout)),
                bits(&matmul_bias(&x, &w, &bias, rows, din, dout)),
                bits(&grad_weight(&x, &dz, rows, din, dout)),
                bits(&grad_input(&dz, &w, rows, din, dout)),
            );
            match &refs {
                None => refs = Some(got),
                Some(r) => {
                    assert_eq!(r.0, got.0, "matmul bits differ at WAVEQ_THREADS={threads}");
                    assert_eq!(r.1, got.1, "matmul_bias bits differ at WAVEQ_THREADS={threads}");
                    assert_eq!(r.2, got.2, "grad_weight bits differ at WAVEQ_THREADS={threads}");
                    assert_eq!(r.3, got.3, "grad_input bits differ at WAVEQ_THREADS={threads}");
                }
            }
        }
        std::env::remove_var("WAVEQ_THREADS");
    }

    /// Miri-sized end-to-end check: a blocked matmul big enough to split
    /// into two pool shards (rows >= 2 * GEMM_MIN_ROWS at two threads),
    /// checked against the scalar oracle. The CI sanitizers lane runs this
    /// under `cargo miri test`, so the pool's raw-pointer Task plumbing is
    /// exercised by the interpreter on every push in a few seconds.
    #[test]
    fn miri_smoke() {
        let (rows, din, dout) = (2 * GEMM_MIN_ROWS, 5, 6);
        let x = prand(rows * din, 21);
        let w = prand(din * dout, 22);
        let _guard = pool::env_lock();
        std::env::set_var("WAVEQ_THREADS", "2");
        let got = matmul(&x, &w, rows, din, dout);
        std::env::remove_var("WAVEQ_THREADS");
        assert_close(&got, &scalar::matmul(&x, &w, rows, din, dout), 1e-4, "miri-smoke-matmul");
    }

    #[test]
    fn conv_support_kernels_are_deterministic_across_thread_counts() {
        let g = conv_geom(9, 7, 3, 5, 3, 2, false);
        let batch = 10usize;
        let x = prand(batch * 9 * 7 * 3, 13);
        let dcols = prand(g.rows(batch) * g.kdim(), 14);
        let gd = conv_geom(6, 6, 4, 4, 3, 1, true);
        let xd = prand(batch * 6 * 6 * 4, 15);
        let wd = prand(3 * 3 * 4, 16);
        let dzd = prand(gd.rows(batch) * 4, 17);
        let bits = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
        let run = || {
            (
                bits(&im2col(&x, batch, &g)),
                bits(&col2im(&dcols, batch, &g)),
                bits(&dwconv_fwd(&xd, &wd, batch, &gd)),
                bits(&dwconv_grad_x(&dzd, &wd, batch, &gd)),
            )
        };
        let _guard = pool::env_lock();
        std::env::set_var("WAVEQ_THREADS", "1");
        let a = run();
        std::env::set_var("WAVEQ_THREADS", "4");
        let b = run();
        std::env::remove_var("WAVEQ_THREADS");
        assert_eq!(a.0, b.0, "im2col bits differ across thread counts");
        assert_eq!(a.1, b.1, "col2im bits differ across thread counts");
        assert_eq!(a.2, b.2, "dwconv_fwd bits differ across thread counts");
        assert_eq!(a.3, b.3, "dwconv_grad_x bits differ across thread counts");
    }

    // ---- frozen-artifact codes + inference-arena kernel variants ------------

    #[test]
    fn quantizer_codes_decode_bitwise_to_the_fake_quantized_grid() {
        // The exact-unpack contract: decode(codes(w)) must reproduce the
        // quantizer's f32 output bit-for-bit, for every bitwidth and for
        // scales from tiny to huge (where tanh saturates).
        for bits in 2..=8u32 {
            let k = (2u32.pow(bits) - 1) as f32;
            for (seed, scale) in [(1u64, 1e-6f32), (2, 0.01), (3, 0.4), (4, 1.0), (5, 50.0)] {
                let w: Vec<f32> = prand(97, seed).iter().map(|v| v * scale).collect();
                let (wq, _ste, m) = dorefa_quantize(&w, k);
                let (codes, mc) = dorefa_codes(&w, k);
                assert_eq!(m.to_bits(), mc.to_bits(), "dorefa scale b={bits}");
                assert!(codes.iter().all(|&c| (c as u32) < 2u32.pow(bits)), "b={bits}");
                let mut dec = vec![0.0f32; w.len()];
                decode_codes_into(&codes, k, m, &mut dec);
                for (i, (&d, &q)) in dec.iter().zip(&wq).enumerate() {
                    assert_eq!(d.to_bits(), q.to_bits(), "dorefa b={bits} elem {i}: {d} vs {q}");
                }

                let (wq, mw) = wrpn_quantize(&w, k);
                let (codes, mc) = wrpn_codes(&w, k);
                assert_eq!(mw.to_bits(), mc.to_bits(), "wrpn scale b={bits}");
                assert!(codes.iter().all(|&c| (c as u32) < 2u32.pow(bits)), "b={bits}");
                let mut dec = vec![0.0f32; w.len()];
                decode_codes_into(&codes, k, mw, &mut dec);
                for (i, (&d, &q)) in dec.iter().zip(&wq).enumerate() {
                    assert_eq!(d.to_bits(), q.to_bits(), "wrpn b={bits} elem {i}: {d} vs {q}");
                }
            }
        }
    }

    #[test]
    fn packed_matmul_is_bitwise_identical_to_matmul() {
        for &(rows, din, dout) in GEMM_SHAPES {
            let x = prand(rows * din, 21);
            let w = prand(din * dout, 22);
            let bias = prand(dout, 23);
            let pb = PackedB::pack(&w, din, dout);
            assert_eq!((pb.k(), pb.n()), (din, dout));
            let mut got = vec![f32::NAN; rows * dout];
            matmul_packed_into(&x, &pb, rows, None, &mut got);
            let want = matmul(&x, &w, rows, din, dout);
            let bits = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
            assert_eq!(bits(&got), bits(&want), "packed matmul ({rows},{din},{dout})");
            matmul_packed_into(&x, &pb, rows, Some(&bias), &mut got);
            let want = matmul_bias(&x, &w, &bias, rows, din, dout);
            assert_eq!(bits(&got), bits(&want), "packed matmul_bias ({rows},{din},{dout})");
        }
    }

    #[test]
    fn into_variants_match_allocating_kernels_on_dirty_buffers() {
        // The arena reuses buffers across dispatches: every _into variant
        // must produce the allocating kernel's exact bits over a buffer
        // full of garbage (stale values, NaNs).
        let bits = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
        let g = conv_geom(9, 7, 3, 5, 3, 2, false);
        let batch = 6usize;
        let x = prand(batch * 9 * 7 * 3, 31);
        let mut dirty = vec![f32::NAN; g.rows(batch) * g.kdim()];
        im2col_into(&x, batch, &g, &mut dirty);
        assert_eq!(bits(&dirty), bits(&im2col(&x, batch, &g)), "im2col_into");

        let gd = conv_geom(6, 6, 4, 4, 3, 2, true);
        let xd = prand(batch * 6 * 6 * 4, 32);
        let wd = prand(3 * 3 * 4, 33);
        let mut dirty = vec![f32::NAN; gd.rows(batch) * 4];
        dwconv_fwd_into(&xd, &wd, batch, &gd, &mut dirty);
        assert_eq!(bits(&dirty), bits(&dwconv_fwd(&xd, &wd, batch, &gd)), "dwconv_fwd_into");

        let xp = prand(batch * 8 * 6 * 3, 34);
        let mut dirty = vec![f32::NAN; batch * 4 * 3 * 3];
        maxpool_infer_into(&xp, batch, 8, 6, 3, 2, &mut dirty);
        let (want, _arg) = maxpool_fwd(&xp, batch, 8, 6, 3, 2);
        assert_eq!(bits(&dirty), bits(&want), "maxpool_infer_into");

        let mut dirty = vec![f32::NAN; batch * 3];
        gap_fwd_into(&xp, batch, 8, 6, 3, &mut dirty);
        assert_eq!(bits(&dirty), bits(&gap_fwd(&xp, batch, 8, 6, 3)), "gap_fwd_into");

        let (s, b) = (prand(3, 35), prand(3, 36));
        let mut dirty = vec![f32::NAN; batch * 8 * 6 * 3];
        affine_fwd_into(&xp, &s, &b, batch * 8 * 6, 3, &mut dirty);
        let want = affine_fwd(&xp, &s, &b, batch * 8 * 6, 3);
        assert_eq!(bits(&dirty), bits(&want), "affine_fwd_into");
    }

    // ---- integer GEMM (the Precision::Int8 inference path) ------------------

    #[test]
    fn act_codes_recover_the_quantizer_grid_exactly() {
        // Re-quantizing a buffer act_quantize already wrote must recover
        // the original scale bit-for-bit and the exact integer codes: the
        // int8 inference path leans on this to avoid double quantization.
        for ka in [3.0f32, 15.0, 255.0] {
            let mut a: Vec<f32> = prand(257, 41).iter().map(|v| v.abs()).collect();
            a[0] = 0.0;
            let m_pre = act_scale(&a);
            let expect: Vec<u8> =
                a.iter().map(|&x| ((x / m_pre).clamp(0.0, 1.0) * ka).round() as u8).collect();
            act_quantize(&mut a, ka);
            let m = act_scale(&a);
            assert_eq!(m.to_bits(), m_pre.to_bits(), "ka={ka}: scale not recovered");
            let mut codes = vec![0u8; a.len()];
            act_codes_into(&a, m, ka, &mut codes);
            assert_eq!(codes, expect, "ka={ka}: codes not recovered");
            for (i, (&c, &v)) in codes.iter().zip(a.iter()).enumerate() {
                let dec = m * (c as f32 / ka);
                assert_eq!(dec.to_bits(), v.to_bits(), "ka={ka} elem {i}: {dec} vs {v}");
            }
        }
    }

    #[test]
    fn pack_codes_matches_packing_the_decoded_weights_bitwise() {
        // The fused dequantize-into-panel constructor is the Exact path's
        // way of never materializing the decoded f32 weights: its panels
        // must equal pack(decode(codes)) bit-for-bit.
        let bits = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
        for &(_, din, dout) in GEMM_SHAPES {
            for b in [2u32, 5, 8] {
                let k = (2u32.pow(b) - 1) as f32;
                let w = prand(din * dout, 43 + b as u64);
                let (codes, m) = dorefa_codes(&w, k);
                let mut dec = vec![0.0f32; w.len()];
                decode_codes_into(&codes, k, m, &mut dec);
                let via_f32 = PackedB::pack(&dec, din, dout);
                let fused = PackedB::pack_codes(&codes, k, m, din, dout);
                assert_eq!((fused.k(), fused.n()), (din, dout));
                assert_eq!(
                    bits(&via_f32.panels),
                    bits(&fused.panels),
                    "pack_codes panels drifted (k={din}, n={dout}, b={b})"
                );
            }
        }
    }

    #[test]
    fn quant_matmul_tracks_the_f32_grid_path_within_the_documented_bound() {
        // The Precision::Int8 error contract: per output element,
        // |int8 - f32| <= 2e-4 * (1 + sum_k |a_k| |w_kj|). The int side is
        // exact integer arithmetic plus one rescale; the slack is almost
        // entirely the f32 GEMM's own rounding chain.
        for &(rows, din, dout) in GEMM_SHAPES {
            for (b, ka) in [(3u32, 15.0f32), (7, 255.0)] {
                let k_levels = 2u32.pow(b) - 1;
                let kw = k_levels as f32;
                let w = prand(din * dout, 51 + b as u64);
                let (codes, mw) = dorefa_codes(&w, kw);
                let bias = prand(dout, 52);
                let mut a: Vec<f32> = prand(rows * din, 53).iter().map(|v| v.abs()).collect();
                act_quantize(&mut a, ka);

                let pb = PackedB::pack_codes(&codes, kw, mw, din, dout);
                let mut f32_out = vec![f32::NAN; rows * dout];
                matmul_packed_into(&a, &pb, rows, Some(&bias), &mut f32_out);

                let pq = PackedQuant::pack_codes(&codes, k_levels, mw, din, dout);
                assert_eq!((pq.k(), pq.n()), (din, dout));
                let ma = act_scale(&a);
                let mut acodes = vec![0u8; rows * din];
                act_codes_into(&a, ma, ka, &mut acodes);
                let mut int_out = vec![f32::NAN; rows * dout];
                matmul_quant_into(&acodes, &pq, rows, ma / ka, Some(&bias), &mut int_out);

                let mut dec = vec![0.0f32; w.len()];
                decode_codes_into(&codes, kw, mw, &mut dec);
                for r in 0..rows {
                    for j in 0..dout {
                        let mut mag = 0.0f64;
                        for kk in 0..din {
                            mag += (a[r * din + kk].abs() * dec[kk * dout + j].abs()) as f64;
                        }
                        let diff = (int_out[r * dout + j] - f32_out[r * dout + j]).abs() as f64;
                        assert!(
                            diff <= 2e-4 * (1.0 + mag),
                            "({rows},{din},{dout}) b={b} out[{r},{j}]: |{}-{}| > bound {mag}",
                            int_out[r * dout + j],
                            f32_out[r * dout + j]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quant_matmul_is_bitwise_deterministic_across_thread_counts() {
        // Same contract as the f32 GEMM, with a stronger argument: the i32
        // accumulation is exactly associative, and the rescale is applied
        // per element after the reduction completes.
        let (rows, din, dout) = (97, 66, 35);
        let kw = 127u32;
        let w = prand(din * dout, 61);
        let (codes, mw) = dorefa_codes(&w, kw as f32);
        let pq = PackedQuant::pack_codes(&codes, kw, mw, din, dout);
        let bias = prand(dout, 62);
        let mut a: Vec<f32> = prand(rows * din, 63).iter().map(|v| v.abs()).collect();
        act_quantize(&mut a, 255.0);
        let ma = act_scale(&a);
        let mut acodes = vec![0u8; rows * din];
        act_codes_into(&a, ma, 255.0, &mut acodes);
        let bits = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
        let mut reference: Option<Vec<u32>> = None;
        let _guard = pool::env_lock();
        for threads in ["1", "2", "4"] {
            std::env::set_var("WAVEQ_THREADS", threads);
            let mut out = vec![f32::NAN; rows * dout];
            matmul_quant_into(&acodes, &pq, rows, ma / 255.0, Some(&bias), &mut out);
            let got = bits(&out);
            match &reference {
                None => reference = Some(got),
                Some(r) => {
                    assert_eq!(r, &got, "quant matmul bits differ at WAVEQ_THREADS={threads}")
                }
            }
        }
        std::env::remove_var("WAVEQ_THREADS");
    }

    #[test]
    fn chunk_rows_partitions_every_batch_exactly() {
        for batch in [0usize, 1, 2, 3, 4, 5, 7, 8, 17, 64, 100] {
            let mut cursor = 0usize;
            for chunk in 0..GRAD_CHUNKS {
                let (lo, hi) = chunk_rows(chunk, batch);
                assert_eq!(lo, cursor, "batch {batch} chunk {chunk} not contiguous");
                assert!(hi >= lo, "batch {batch} chunk {chunk} inverted");
                cursor = hi;
            }
            assert_eq!(cursor, batch, "batch {batch} chunks do not cover it");
        }
    }

    #[test]
    fn allreduce_fixed_order_is_a_left_fold_and_accumulation_matches_one_call() {
        let a = prand(33, 71);
        let b = prand(33, 72);
        let c = prand(33, 73);
        // One call with all parts...
        let mut once = vec![0.0f32; 33];
        allreduce_fixed_order(&mut once, &[&a, &b, &c]);
        // ...equals per-part accumulation in the same order (the fused
        // chunk loop) ...
        let mut acc = vec![0.0f32; 33];
        for p in [&a, &b, &c] {
            allreduce_fixed_order(&mut acc, &[p]);
        }
        // ...equals the hand-written left fold.
        for i in 0..33 {
            let want = ((0.0 + a[i]) + b[i]) + c[i];
            assert_eq!(once[i].to_bits(), want.to_bits(), "elem {i}");
            assert_eq!(acc[i].to_bits(), want.to_bits(), "elem {i} accumulated");
        }
    }

    #[test]
    fn softmax_ce_parts_whole_batch_matches_softmax_ce_dlogits() {
        let (b, c) = (12usize, 7usize);
        let logits = prand(b * c, 81);
        let mut y = vec![0.0f32; b * c];
        for r in 0..b {
            y[r * c + r % c] = 1.0;
        }
        let (_loss, _acc, want) = softmax_ce(&logits, &y, b, c);
        let (ce_sum, acc_cnt, got) = softmax_ce_parts(&logits, &y, b, c, b as f32);
        assert_eq!(
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "whole-batch dlogits must be bit-identical"
        );
        assert!(ce_sum.is_finite() && acc_cnt >= 0.0 && acc_cnt <= b as f32);
    }

    #[test]
    fn softmax_ce_parts_chunked_dlogits_concatenate_to_the_whole_batch() {
        let (b, c) = (16usize, 5usize);
        let logits = prand(b * c, 91);
        let mut y = vec![0.0f32; b * c];
        for r in 0..b {
            y[r * c + (r * 3) % c] = 1.0;
        }
        let denom = b as f32;
        let (_l, _a, whole) = softmax_ce_parts(&logits, &y, b, c, denom);
        let mut cat: Vec<f32> = Vec::new();
        let mut cnt = 0.0f32;
        for chunk in 0..GRAD_CHUNKS {
            let (lo, hi) = chunk_rows(chunk, b);
            let (_cl, ca, d) =
                softmax_ce_parts(&logits[lo * c..hi * c], &y[lo * c..hi * c], hi - lo, c, denom);
            cat.extend_from_slice(&d);
            cnt += ca;
        }
        assert_eq!(
            whole.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            cat.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "chunked dlogits must concatenate to the whole-batch result"
        );
        let (_l2, whole_cnt, _d2) = softmax_ce_parts(&logits, &y, b, c, denom);
        assert_eq!(cnt, whole_cnt, "chunked correct-counts must sum to the whole batch's");
    }
}
