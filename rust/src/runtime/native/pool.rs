//! In-crate worker pool for the native kernels, built on
//! [`std::thread::scope`] only (the crate resolves offline from
//! `rust/vendor/`, so no rayon/crossbeam).
//!
//! The pool parallelizes over *output rows*: a row-major `rows x width`
//! output buffer is split into contiguous row shards, each handed to one
//! scoped worker. Every output element is produced by exactly one shard,
//! and the kernels compute each element with a reduction order fixed by
//! tile constants (see `kernels`), so results are **bitwise identical for
//! any thread count** — `WAVEQ_THREADS=1` and `WAVEQ_THREADS=8` produce
//! the same bits, which the determinism tests assert.
//!
//! Thread count resolution: the `WAVEQ_THREADS` env var when set to a
//! positive integer, else [`std::thread::available_parallelism`]. The env
//! var is re-read on every dispatch so tests (and operators) can change it
//! at runtime without rebuilding.

use std::num::NonZeroUsize;

/// Hard cap on the worker budget, however large the override or machine.
const MAX_THREADS: usize = 64;

/// Worker budget: `WAVEQ_THREADS` override, else available parallelism.
pub fn num_threads() -> usize {
    let configured = std::env::var("WAVEQ_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1);
    configured
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
        })
        .min(MAX_THREADS)
}

/// Run `f` over contiguous row shards of `out` (a row-major `rows x width`
/// buffer), in parallel when the worker budget and problem size allow.
///
/// `f(first_row, shard)` receives the absolute index of its first row and
/// the mutable sub-slice holding rows `first_row ..
/// first_row + shard.len() / width`. Shards never overlap, shard boundaries
/// never split a row, and the worker budget is capped at
/// `rows / min_rows`, so tiny problems stay on the calling thread with
/// zero spawn overhead.
///
/// Determinism contract: `f` must compute each output element with an
/// arithmetic order that does not depend on `first_row` or the shard size —
/// the kernels guarantee this by fixing the per-element reduction order —
/// making the result independent of the thread count.
pub fn run_rows<F>(out: &mut [f32], rows: usize, width: usize, min_rows: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * width);
    if rows == 0 {
        return;
    }
    let budget = num_threads().min(rows / min_rows.max(1)).max(1);
    if budget == 1 {
        f(0, out);
        return;
    }
    let per = rows.div_ceil(budget);
    let f = &f;
    std::thread::scope(|s| {
        let mut iter = out.chunks_mut(per * width).enumerate();
        // Run the first shard on the calling thread; spawn the rest.
        let first = iter.next();
        for (i, chunk) in iter {
            s.spawn(move || f(i * per, chunk));
        }
        if let Some((_, chunk)) = first {
            f(0, chunk);
        }
    });
}

/// Serializes tests that mutate `WAVEQ_THREADS`: unit tests in this crate
/// run concurrently and the env var is process-global. Determinism makes
/// racing *values* harmless, but tests asserting a specific thread count
/// must hold this lock.
#[cfg(test)]
pub(crate) fn env_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_honours_env_override() {
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "3");
        assert_eq!(num_threads(), 3);
        std::env::set_var("WAVEQ_THREADS", "not-a-number");
        assert!(num_threads() >= 1); // falls back to available parallelism
        std::env::set_var("WAVEQ_THREADS", "0");
        assert!(num_threads() >= 1);
        std::env::remove_var("WAVEQ_THREADS");
        assert!(num_threads() >= 1);
    }

    #[test]
    fn shards_cover_every_row_exactly_once() {
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "4");
        let (rows, width) = (37, 5);
        let mut out = vec![0.0f32; rows * width];
        run_rows(&mut out, rows, width, 1, |r0, shard| {
            let n = shard.len() / width;
            for r in 0..n {
                for c in 0..width {
                    shard[r * width + c] += ((r0 + r) * width + c) as f32;
                }
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as f32, "element {i} written wrongly or twice");
        }
        std::env::remove_var("WAVEQ_THREADS");
    }

    #[test]
    fn small_problems_stay_on_one_shard() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "8");
        let calls = AtomicUsize::new(0);
        let mut out = vec![0.0f32; 4 * 2];
        // min_rows=64 > rows=4 forces a single whole-buffer shard.
        run_rows(&mut out, 4, 2, 64, |r0, shard| {
            calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!(r0, 0);
            assert_eq!(shard.len(), 8);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1);
        std::env::remove_var("WAVEQ_THREADS");
    }
}
