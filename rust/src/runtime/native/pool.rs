//! Persistent channel-fed worker pool for the native kernels, built on
//! `std` only (the crate resolves offline from `rust/vendor/`, so no
//! rayon/crossbeam).
//!
//! Workers are spawned once (lazily, on the first dispatch; `NativeBackend::
//! new` warms them eagerly via [`ensure_started`]) and then *parked* on a
//! shared job channel between dispatches — replacing the per-dispatch
//! `std::thread::scope` spawns, whose create/join cost was paid on every one
//! of the ~100 kernel dispatches of a conv-zoo train step. A dispatch now
//! costs a few channel sends and one latch wait.
//!
//! The pool parallelizes over *output rows*: a row-major `rows x width`
//! output buffer is split into contiguous row shards, each handed to one
//! worker (the calling thread runs the first shard itself). Every output
//! element is produced by exactly one shard, and the kernels compute each
//! element with a reduction order fixed by tile constants (see `kernels`),
//! so results are **bitwise identical for any thread count** —
//! `WAVEQ_THREADS=1` and `WAVEQ_THREADS=8` produce the same bits, which the
//! determinism tests assert. Which worker executes a shard is scheduling
//! noise; the shard boundaries (and therefore the arithmetic) depend only
//! on the budget.
//!
//! Thread count resolution: the `WAVEQ_THREADS` env var when set to a
//! positive integer, else [`std::thread::available_parallelism`]. The
//! *worker complement* is fixed when the pool first starts and honors the
//! override at that moment — `WAVEQ_THREADS=1 waveq …` parks one worker,
//! not a full core count of idle threads, and an override *above* the
//! core count gets that many real workers (capped at [`MAX_THREADS`]).
//! The *per-dispatch shard budget* is still re-read on every dispatch so
//! tests (and operators) can change it at runtime without rebuilding; a
//! budget larger than the spawned worker count simply queues more shards
//! than workers (served sequentially — still deterministic, because shard
//! boundaries depend only on the budget, never on which worker runs them).
//!
//! Concurrent dispatchers: `run_rows` is safe to call from any number of
//! threads at once — each dispatch owns a private completion latch, tasks
//! from interleaved dispatches coexist on the shared queue, and workers
//! never block on a latch (they only run tasks to completion), so there
//! is no lock ordering between dispatches and no deadlock. This is what
//! lets N serving sessions (`runtime::serve`) drive the one process-wide
//! pool simultaneously; the concurrent-caller determinism tests assert
//! the bits match the serial run.
//!
//! Safety: tasks carry raw pointers into the caller's stack (the closure,
//! the output shard, the completion latch). [`run_rows`] blocks on the
//! latch until every queued shard has finished — on the panic path too —
//! so no task can outlive the borrows it erased.

// The one module allowed to use `unsafe` (the crate root denies it
// everywhere else, and waveq-audit rule D4 flags any other opt-out):
// lifetime-erased Tasks are the price of a persistent channel-fed pool,
// and confining them here keeps the audit surface a single file.
#![allow(unsafe_code)]

use std::num::NonZeroUsize;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard cap on the worker budget, however large the override or machine.
const MAX_THREADS: usize = 64;

/// Worker budget: `WAVEQ_THREADS` override, else available parallelism.
pub fn num_threads() -> usize {
    let configured = std::env::var("WAVEQ_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1);
    configured
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
        })
        .min(MAX_THREADS)
}

/// One queued shard of a dispatch, with every borrow erased to a raw
/// pointer. Sound because `run_rows` waits on `latch` before any of the
/// pointed-to data can go out of scope.
struct Task {
    f: *const (dyn Fn(usize, &mut [f32]) + Sync),
    first_row: usize,
    out: *mut f32,
    len: usize,
    latch: *const Latch,
}

// SAFETY: every pointer in a `Task` targets data owned by the `run_rows`
// frame that queued it (the closure object, the output shard, the latch),
// and that frame blocks in `Latch::wait` — on the panic path too — until
// this task has arrived, so the pointees outlive the task on any thread
// it moves to. The closure itself is `Sync` (bound on `run_rows`), so the
// worker may call it through the shared pointer, and shards are disjoint
// `chunks_mut` slices, so no two tasks alias the same output element.
unsafe impl Send for Task {}

/// A worker shard's panic payload, carried back to the dispatcher so the
/// original message/location survive (as `thread::scope` joins did).
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// The pure decision core of [`Latch`]: the countdown/payload state
/// machine with every `std` primitive stripped away. Production wraps it
/// in a `Mutex` + `Condvar` (the real sync layer); `waveq-check` drives
/// the same core from a virtual scheduler and exhaustively explores every
/// interleaving, so the notify/wait decisions verified there are the ones
/// executing here.
///
/// Protocol: constructed with the number of outstanding worker shards;
/// each shard calls [`LatchCore::arrive`] exactly once (the call that
/// returns `true` must wake every waiter); the dispatcher blocks while
/// [`LatchCore::is_complete`] is false, then takes the first panic
/// payload.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LatchCore<P> {
    remaining: usize,
    payload: Option<P>,
}

impl<P> LatchCore<P> {
    pub fn new(n: usize) -> LatchCore<P> {
        LatchCore { remaining: n, payload: None }
    }

    /// Record one shard arrival, keeping the *first* panic payload.
    /// Returns `true` when this arrival was the last one — the sync layer
    /// must then wake every waiter (a dropped wakeup here is a dispatcher
    /// deadlock; the model checker's lost-wakeup property pins it).
    pub fn arrive(&mut self, panic: Option<P>) -> bool {
        self.remaining -= 1;
        if self.payload.is_none() {
            self.payload = panic;
        }
        self.remaining == 0
    }

    /// The dispatcher's wait predicate (checked under the lock).
    pub fn is_complete(&self) -> bool {
        self.remaining == 0
    }

    /// Shards that have not arrived yet.
    pub fn outstanding(&self) -> usize {
        self.remaining
    }

    /// Take the first panic payload (dispatcher, after completion).
    pub fn take_payload(&mut self) -> Option<P> {
        self.payload.take()
    }
}

/// Counts a dispatch's outstanding worker shards; the dispatching thread
/// blocks in [`Latch::wait`] until all of them have arrived. The
/// counter/payload logic lives in [`LatchCore`]; this wrapper supplies
/// the real sync layer (poison-tolerant `Mutex` + `Condvar`).
struct Latch {
    core: Mutex<LatchCore<PanicPayload>>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { core: Mutex::new(LatchCore::new(n)), cv: Condvar::new() }
    }

    fn arrive(&self, panic: Option<PanicPayload>) {
        // Poison-tolerant: the counter/payload pair stays consistent under
        // a panicking peer, and an `arrive` that cannot complete would
        // deadlock the dispatcher in `wait` forever.
        let mut core = self.core.lock().unwrap_or_else(|e| e.into_inner());
        if core.arrive(panic) {
            self.cv.notify_all();
        }
    }

    /// Block until every shard arrived; returns the first panic payload.
    fn wait(&self) -> Option<PanicPayload> {
        let mut core = self.core.lock().unwrap_or_else(|e| e.into_inner());
        while !core.is_complete() {
            core = self.cv.wait(core).unwrap_or_else(|e| e.into_inner());
        }
        core.take_payload()
    }
}

struct Pool {
    queue: Sender<Task>,
    /// Worker threads actually spawned (the `WAVEQ_THREADS`-resolved
    /// budget at first start). A later, larger per-dispatch budget queues
    /// more shards than this — correct, just less parallel.
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        // Honor the WAVEQ_THREADS override for the spawned complement,
        // not just the shard budget: `WAVEQ_THREADS=1` must not park a
        // full core count of idle workers, and an override above the
        // core count must get real threads. At least one worker always
        // exists so shards queued by a *later* raised budget are served.
        let workers = num_threads().max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("waveq-pool-{i}"))
                .spawn(move || worker_loop(&rx))
                .expect("spawning waveq pool worker");
        }
        Pool { queue: tx, workers }
    })
}

/// Spawn the persistent workers now (idempotent). Called by
/// `NativeBackend::new` so the spawn cost never lands inside a timed step.
pub fn ensure_started() {
    let _ = pool();
}

/// Number of persistent workers the pool spawned (fixed at first start;
/// see the module docs for how this interacts with the per-dispatch
/// budget). Starts the pool if it has not started yet.
pub fn worker_count() -> usize {
    pool().workers
}

fn worker_loop(rx: &Mutex<Receiver<Task>>) {
    loop {
        // Hold the receiver lock only for the dequeue; a worker parked in
        // `recv` wakes, releases the lock, and runs its task while the
        // next worker parks. Poison-tolerant: `run_task` catches shard
        // panics, so a poisoned receiver lock can only mean a panic in
        // this dequeue path itself — the queue state is still sound.
        let task = match rx.lock().unwrap_or_else(|e| e.into_inner()).recv() {
            Ok(t) => t,
            Err(_) => return, // channel closed (process teardown)
        };
        run_task(task);
    }
}

std::thread_local! {
    /// Set while this thread is executing a pool task. A nested `run_rows`
    /// from inside a shard closure must not queue sub-tasks: with a fixed
    /// worker count every worker could be blocked in `Latch::wait` while
    /// the sub-tasks sit unserved — a deadlock the old per-dispatch
    /// `thread::scope` design could not hit. Nested calls run serially
    /// instead (bitwise-identical by the determinism contract).
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn run_task(task: Task) {
    // Catch panics so a failed shard reports through the latch instead of
    // killing the worker (the pool must survive for later dispatches) or
    // deadlocking the dispatcher.
    //
    // SAFETY: `task.out`/`task.len` name a `chunks_mut` sub-slice of the
    // dispatcher's output buffer — valid, properly aligned, and disjoint
    // from every other task's slice — and `task.f` points at a live `Sync`
    // closure; both stay alive until this task arrives at the latch (see
    // the `Send` impl above), which happens strictly after this call.
    let result = catch_unwind(AssertUnwindSafe(|| unsafe {
        let shard = std::slice::from_raw_parts_mut(task.out, task.len);
        IN_POOL_TASK.with(|t| t.set(true));
        (*task.f)(task.first_row, shard);
    }));
    IN_POOL_TASK.with(|t| t.set(false));
    // SAFETY: the latch lives on the dispatcher's stack and the dispatcher
    // is blocked in `Latch::wait` until this `arrive` — the last use of
    // any of the task's pointers — completes; `Latch` is `Sync` (Mutex +
    // Condvar), so calling it from a worker thread is sound.
    unsafe { (*task.latch).arrive(result.err()) };
}

/// Run `f` over contiguous row shards of `out` (a row-major `rows x width`
/// buffer), in parallel when the worker budget and problem size allow.
///
/// `f(first_row, shard)` receives the absolute index of its first row and
/// the mutable sub-slice holding rows `first_row ..
/// first_row + shard.len() / width`. Shards never overlap, shard boundaries
/// never split a row, and the worker budget is capped at
/// `rows / min_rows`, so tiny problems stay on the calling thread with
/// zero dispatch overhead.
///
/// Determinism contract: `f` must compute each output element with an
/// arithmetic order that does not depend on `first_row` or the shard size —
/// the kernels guarantee this by fixing the per-element reduction order —
/// making the result independent of the thread count.
pub fn run_rows<F>(out: &mut [f32], rows: usize, width: usize, min_rows: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * width);
    if rows == 0 {
        return;
    }
    // Nested dispatch from inside a pool task would deadlock the fixed
    // worker set (see IN_POOL_TASK); run such calls serially — identical
    // bits by the determinism contract, since shard boundaries never
    // change the per-element arithmetic.
    let budget = if IN_POOL_TASK.with(|t| t.get()) {
        1
    } else {
        num_threads().min(rows / min_rows.max(1)).max(1)
    };
    if budget == 1 {
        f(0, out);
        return;
    }
    let per = rows.div_ceil(budget);
    let n_shards = rows.div_ceil(per);
    if n_shards == 1 {
        f(0, out);
        return;
    }
    let f_obj: &(dyn Fn(usize, &mut [f32]) + Sync) = &f;
    let latch = Latch::new(n_shards - 1);
    let p = pool();
    let mut iter = out.chunks_mut(per * width).enumerate();
    // Queue all but the first shard; run the first on the calling thread.
    let first = iter.next();
    for (i, chunk) in iter {
        let task = Task {
            f: f_obj as *const _,
            first_row: i * per,
            out: chunk.as_mut_ptr(),
            len: chunk.len(),
            latch: &latch,
        };
        p.queue.send(task).expect("waveq pool queue closed");
    }
    let caller = match first {
        Some((_, chunk)) => catch_unwind(AssertUnwindSafe(|| f_obj(0, chunk))),
        None => Ok(()),
    };
    // Wait for the workers even when the caller's own shard panicked:
    // queued tasks hold pointers into `out`, `f`, and `latch`.
    let worker_panic = latch.wait();
    if let Err(payload) = caller {
        resume_unwind(payload);
    }
    if let Some(payload) = worker_panic {
        // Re-raise the shard's own payload so the message survives, as it
        // did under the per-dispatch `thread::scope` joins.
        resume_unwind(payload);
    }
}

/// Spawn one named long-lived worker thread. This is the crate's single
/// thread-creation chokepoint outside the pool itself: modules that need a
/// dedicated thread (the distributed coordinator's replicas, the data
/// prefetcher's source) route through here so the audit's parallelism
/// roots stay confined to the files that already own threading.
pub fn spawn_worker(
    name: &str,
    f: impl FnOnce() + Send + 'static,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new().name(name.to_string()).spawn(f)
}

/// Serializes tests that mutate `WAVEQ_THREADS`: unit tests in this crate
/// run concurrently and the env var is process-global. Determinism makes
/// racing *values* harmless, but tests asserting a specific thread count
/// must hold this lock.
#[cfg(test)]
pub(crate) fn env_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_honours_env_override() {
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "3");
        assert_eq!(num_threads(), 3);
        std::env::set_var("WAVEQ_THREADS", "not-a-number");
        assert!(num_threads() >= 1); // falls back to available parallelism
        std::env::set_var("WAVEQ_THREADS", "0");
        assert!(num_threads() >= 1);
        std::env::remove_var("WAVEQ_THREADS");
        assert!(num_threads() >= 1);
    }

    #[test]
    fn latch_core_counts_down_and_keeps_first_payload() {
        let mut core: LatchCore<&'static str> = LatchCore::new(3);
        assert!(!core.is_complete());
        assert_eq!(core.outstanding(), 3);
        assert!(!core.arrive(None), "arrival 1 of 3 must not signal completion");
        assert!(!core.arrive(Some("first")), "arrival 2 of 3 must not signal completion");
        assert!(core.arrive(Some("second")), "the last arrival must signal completion");
        assert!(core.is_complete());
        assert_eq!(core.outstanding(), 0);
        assert_eq!(core.take_payload(), Some("first"), "the first panic payload wins");
        assert_eq!(core.take_payload(), None, "the payload is taken exactly once");
    }

    #[test]
    fn latch_core_with_zero_shards_is_born_complete() {
        let mut core: LatchCore<()> = LatchCore::new(0);
        assert!(core.is_complete());
        assert_eq!(core.take_payload(), None);
    }

    #[test]
    fn shards_cover_every_row_exactly_once() {
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "4");
        let (rows, width) = (37, 5);
        let mut out = vec![0.0f32; rows * width];
        run_rows(&mut out, rows, width, 1, |r0, shard| {
            let n = shard.len() / width;
            for r in 0..n {
                for c in 0..width {
                    shard[r * width + c] += ((r0 + r) * width + c) as f32;
                }
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as f32, "element {i} written wrongly or twice");
        }
        std::env::remove_var("WAVEQ_THREADS");
    }

    #[test]
    fn small_problems_stay_on_one_shard() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "8");
        let calls = AtomicUsize::new(0);
        let mut out = vec![0.0f32; 4 * 2];
        // min_rows=64 > rows=4 forces a single whole-buffer shard.
        run_rows(&mut out, 4, 2, 64, |r0, shard| {
            calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!(r0, 0);
            assert_eq!(shard.len(), 8);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1);
        std::env::remove_var("WAVEQ_THREADS");
    }

    #[test]
    fn persistent_pool_serves_many_dispatches() {
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "4");
        // Far more dispatches than workers: each must complete and cover
        // its rows exactly once (workers are reused, not respawned).
        // Miri runs each simulated instruction ~1000x slower; a handful of
        // rounds still crosses the reuse path it is there to check.
        let rounds = if cfg!(miri) { 6 } else { 50 };
        for round in 0..rounds {
            let (rows, width) = (16 + round % 7, 3);
            let mut out = vec![0.0f32; rows * width];
            run_rows(&mut out, rows, width, 1, |r0, shard| {
                for (i, v) in shard.iter_mut().enumerate() {
                    *v = (r0 * width + i) as f32;
                }
            });
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i as f32, "round {round} element {i}");
            }
        }
        std::env::remove_var("WAVEQ_THREADS");
    }

    #[test]
    fn nested_dispatch_runs_serially_instead_of_deadlocking() {
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "4");
        let (rows, width) = (32, 4);
        let mut out = vec![0.0f32; rows * width];
        run_rows(&mut out, rows, width, 1, |r0, shard| {
            // A dispatch from inside a shard must fall back to serial
            // execution (IN_POOL_TASK) rather than queue sub-tasks that
            // no free worker can serve.
            let mut inner = vec![0.0f32; 8 * 2];
            run_rows(&mut inner, 8, 2, 1, |ir0, ishard| {
                for (i, v) in ishard.iter_mut().enumerate() {
                    *v = (ir0 * 2 + i) as f32;
                }
            });
            let inner_sum: f32 = inner.iter().sum();
            for (i, v) in shard.iter_mut().enumerate() {
                *v = (r0 * width + i) as f32 + inner_sum - 120.0; // sum 0..16 = 120
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as f32, "element {i}");
        }
        std::env::remove_var("WAVEQ_THREADS");
    }

    #[test]
    fn concurrent_dispatchers_share_the_pool_without_interference() {
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "4");
        ensure_started();
        // N threads each drive many dispatches at once. Tasks from the
        // interleaved dispatches coexist on the one shared queue; every
        // dispatch must still see exactly its own rows, exactly once —
        // the property `runtime::serve` workers rely on.
        // Scaled down under Miri (interpreted execution): fewer
        // dispatchers and rounds still interleave tasks on the queue.
        let (dispatchers, rounds) = if cfg!(miri) { (3, 4) } else { (8, 30) };
        std::thread::scope(|s| {
            for t in 0..dispatchers {
                s.spawn(move || {
                    for round in 0..rounds {
                        let (rows, width) = (24 + (t + round) % 9, 3);
                        let mut out = vec![0.0f32; rows * width];
                        let tag = (t * 1000 + round) as f32;
                        run_rows(&mut out, rows, width, 1, |r0, shard| {
                            for (i, v) in shard.iter_mut().enumerate() {
                                *v = tag + (r0 * width + i) as f32;
                            }
                        });
                        for (i, &v) in out.iter().enumerate() {
                            assert_eq!(
                                v,
                                tag + i as f32,
                                "dispatcher {t} round {round} element {i}"
                            );
                        }
                    }
                });
            }
        });
        std::env::remove_var("WAVEQ_THREADS");
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let _guard = env_lock();
        std::env::set_var("WAVEQ_THREADS", "4");
        let result = catch_unwind(|| {
            let mut out = vec![0.0f32; 64 * 4];
            run_rows(&mut out, 64, 4, 1, |r0, _| {
                if r0 > 0 {
                    panic!("boom");
                }
            });
        });
        assert!(result.is_err(), "shard panic must propagate to the dispatcher");
        // The pool must keep serving dispatches after a task panic.
        let mut out = vec![0.0f32; 64 * 4];
        run_rows(&mut out, 64, 4, 1, |r0, shard| {
            for (i, v) in shard.iter_mut().enumerate() {
                *v = (r0 * 4 + i) as f32;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
        std::env::remove_var("WAVEQ_THREADS");
    }
}
