//! The pure-Rust reference backend: executes the WaveQ MLP program family
//! end-to-end on the host, satisfying the same manifest signatures the AOT
//! HLO programs export (`python/compile/train_step.py`):
//!
//!   train_fp32_mlp    : [w*P, v*P, x, y, lr, mom]                 -> [w', v', loss, acc]
//!   train_dorefa_mlp  : [w*P, v*P, x, y, lr, mom, kw(Q,), ka]     -> [w', v', loss, acc]
//!   train_wrpn_mlp_w2 : same as dorefa, on the width-doubled model
//!   train_waveq_mlp   : [w*P, v*P, beta, vbeta, x, y, lr, mom,
//!                        lr_beta, ka, lam_w, lam_beta, beta_train] -> [w', v', beta', vbeta',
//!                                                                     loss, acc, ce, reg_w]
//!   eval_fp32_mlp     : [w*P, x, y]                               -> [loss, acc]
//!   eval_quant_mlp    : [w*P, x, y, kw(Q,), ka]                   -> [loss, acc]
//!   eval_wrpn_mlp_w2  : [w*P, x, y, kw(Q,), ka]                   -> [loss, acc]
//!   reg_profile       : [wgrid, bgrid]                            -> 9 x (n_w, n_b) surfaces
//!
//! The quantized forward uses the DoReFa/WRPN rules of `kernels`, the
//! backward is the straight-through estimator, and the 'waveq' programs add
//! the sinusoidal regularizer `lambda_w * sin^2(pi v 2^beta)`-family term
//! with its *analytic* gradient in both w and beta — the heart of the paper,
//! executed here with no Python, XLA, or artifacts involved.
//!
//! The backend also exports its own [`Manifest`] so the coordinator
//! (trainer / evaluator / energy / pareto layers) runs identically on
//! either backend.

pub mod kernels;

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use anyhow::{anyhow, Result};

use self::kernels as kn;
use super::backend::{Backend, RuntimeStats};
use super::buffer::Buffer;
use super::manifest::{ArgSpec, Manifest, ModelMeta, ParamMeta, ProgramSig};

/// One fully-connected layer (weight + bias) of a native model.
#[derive(Debug, Clone)]
pub struct FcLayer {
    pub name: String,
    pub din: usize,
    pub dout: usize,
    /// Slot in the per-layer bitwidth vector, if this weight is quantized.
    pub qidx: Option<usize>,
}

/// A native model: an MLP as a stack of FC layers with ReLU (+ optional
/// activation fake-quant) between them. Mirrors `python/compile/models.mlp`
/// including the §4.1 policy: first and last layers stay full precision.
#[derive(Debug, Clone)]
pub struct NativeModel {
    pub name: String,
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    pub batch: usize,
    pub width_mult: usize,
    pub layers: Vec<FcLayer>,
}

impl NativeModel {
    /// The WaveQ test MLP on mlp-lite (8x8x3 -> 10): 3 hidden layers of
    /// width 128 * width_mult; the two middle FCs own bitwidth slots.
    pub fn mlp(width_mult: usize) -> NativeModel {
        let w = 128 * width_mult;
        let din = 8 * 8 * 3;
        let name = if width_mult == 1 { "mlp".to_string() } else { format!("mlp_w{width_mult}") };
        let mk = |n: &str, i, o, q| FcLayer { name: n.to_string(), din: i, dout: o, qidx: q };
        NativeModel {
            name,
            input_shape: [8, 8, 3],
            num_classes: 10,
            batch: 64,
            width_mult,
            layers: vec![
                mk("fc1", din, w, None),
                mk("fc2", w, w, Some(0)),
                mk("fc3", w, w, Some(1)),
                mk("fc4", w, 10, None),
            ],
        }
    }

    pub fn num_qlayers(&self) -> usize {
        self.layers.iter().filter(|l| l.qidx.is_some()).count()
    }

    /// Number of parameter tensors (weight + bias per layer).
    pub fn num_params(&self) -> usize {
        2 * self.layers.len()
    }

    /// The manifest-side description of this model.
    pub fn meta(&self) -> ModelMeta {
        let mut params = Vec::with_capacity(self.num_params());
        for l in &self.layers {
            params.push(ParamMeta {
                name: l.name.clone(),
                shape: vec![l.din, l.dout],
                kind: "fc".into(),
                init: "he".into(),
                qidx: l.qidx,
                macs: (l.din * l.dout) as u64,
                count: (l.din * l.dout) as u64,
            });
            params.push(ParamMeta {
                name: format!("{}_b", l.name),
                shape: vec![l.dout],
                kind: "bias".into(),
                init: "zeros".into(),
                qidx: None,
                macs: 0,
                count: l.dout as u64,
            });
        }
        ModelMeta {
            name: self.name.clone(),
            input_shape: self.input_shape,
            num_classes: self.num_classes,
            batch: self.batch,
            width_mult: self.width_mult,
            num_qlayers: self.num_qlayers(),
            params,
        }
    }

    fn pixels(&self) -> usize {
        self.input_shape[0] * self.input_shape[1] * self.input_shape[2]
    }

    fn param_names(&self, prefix: &str) -> Vec<String> {
        let mut v = Vec::with_capacity(self.num_params());
        for l in &self.layers {
            v.push(format!("{prefix}:{}", l.name));
            v.push(format!("{prefix}:{}_b", l.name));
        }
        v
    }

    fn param_specs(&self, prefix: &str) -> Vec<ArgSpec> {
        let mut v = Vec::with_capacity(self.num_params());
        for l in &self.layers {
            v.push(ArgSpec {
                name: format!("{prefix}:{}", l.name),
                shape: vec![l.din, l.dout],
                dtype: "float32".into(),
            });
            v.push(ArgSpec {
                name: format!("{prefix}:{}_b", l.name),
                shape: vec![l.dout],
                dtype: "float32".into(),
            });
        }
        v
    }
}

/// Which weight-quantizer family a program uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QuantFamily {
    Fp32,
    Dorefa,
    Wrpn,
    Waveq,
}

#[derive(Debug, Clone)]
enum ProgramKind {
    Train { model: String, quant: QuantFamily },
    Eval { model: String, quant: QuantFamily },
    RegProfile,
}

/// Grid sizes of the reg_profile surfaces (match `make_reg_profile`).
pub const REG_PROFILE_NW: usize = 512;
pub const REG_PROFILE_NB: usize = 256;

/// The hermetic pure-Rust execution backend.
pub struct NativeBackend {
    models: BTreeMap<String, NativeModel>,
    programs: BTreeMap<String, ProgramKind>,
    compiled: RefCell<BTreeSet<String>>,
    stats: RefCell<RuntimeStats>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        let mut models = BTreeMap::new();
        for m in [NativeModel::mlp(1), NativeModel::mlp(2)] {
            models.insert(m.name.clone(), m);
        }
        let mut programs = BTreeMap::new();
        programs.insert(
            "train_fp32_mlp".to_string(),
            ProgramKind::Train { model: "mlp".into(), quant: QuantFamily::Fp32 },
        );
        programs.insert(
            "train_dorefa_mlp".to_string(),
            ProgramKind::Train { model: "mlp".into(), quant: QuantFamily::Dorefa },
        );
        programs.insert(
            "train_waveq_mlp".to_string(),
            ProgramKind::Train { model: "mlp".into(), quant: QuantFamily::Waveq },
        );
        programs.insert(
            "train_wrpn_mlp_w2".to_string(),
            ProgramKind::Train { model: "mlp_w2".into(), quant: QuantFamily::Wrpn },
        );
        programs.insert(
            "eval_fp32_mlp".to_string(),
            ProgramKind::Eval { model: "mlp".into(), quant: QuantFamily::Fp32 },
        );
        programs.insert(
            "eval_quant_mlp".to_string(),
            ProgramKind::Eval { model: "mlp".into(), quant: QuantFamily::Dorefa },
        );
        programs.insert(
            "eval_wrpn_mlp_w2".to_string(),
            ProgramKind::Eval { model: "mlp_w2".into(), quant: QuantFamily::Wrpn },
        );
        programs.insert("reg_profile".to_string(), ProgramKind::RegProfile);
        NativeBackend {
            models,
            programs,
            compiled: RefCell::new(BTreeSet::new()),
            stats: RefCell::new(RuntimeStats::default()),
        }
    }

    /// The manifest describing every native program and model — the same
    /// contract `python/compile/aot.py` writes for the AOT artifacts.
    pub fn manifest(&self) -> Manifest {
        let mut programs = BTreeMap::new();
        for (name, kind) in &self.programs {
            programs.insert(name.clone(), self.sig_for(name, kind));
        }
        let models = self.models.iter().map(|(k, m)| (k.clone(), m.meta())).collect();
        Manifest { programs, models }
    }

    fn sig_for(&self, name: &str, kind: &ProgramKind) -> ProgramSig {
        let scalar = |n: &str| ArgSpec { name: n.into(), shape: vec![], dtype: "float32".into() };
        let vec_q = |n: &str, q: usize| ArgSpec { name: n.into(), shape: vec![q], dtype: "float32".into() };
        match kind {
            ProgramKind::RegProfile => ProgramSig {
                name: name.to_string(),
                file: format!("{name}.native"),
                model: None,
                inputs: vec![
                    vec_q("wgrid", REG_PROFILE_NW),
                    vec_q("bgrid", REG_PROFILE_NB),
                ],
                outputs: (0..3u32)
                    .flat_map(|n| ["r", "d1", "d2"].into_iter().map(move |q| format!("{q}_n{n}")))
                    .collect(),
            },
            ProgramKind::Train { model, quant } => {
                let m = &self.models[model];
                let q = m.num_qlayers();
                let x = ArgSpec {
                    name: "x".into(),
                    shape: vec![m.batch, m.input_shape[0], m.input_shape[1], m.input_shape[2]],
                    dtype: "float32".into(),
                };
                let y = ArgSpec {
                    name: "y".into(),
                    shape: vec![m.batch, m.num_classes],
                    dtype: "float32".into(),
                };
                let mut inputs = m.param_specs("w");
                inputs.extend(m.param_specs("v"));
                let mut outputs = m.param_names("w");
                outputs.extend(m.param_names("v"));
                match quant {
                    QuantFamily::Fp32 => {
                        inputs.extend([x, y, scalar("lr"), scalar("mom")]);
                        outputs.extend(["loss".into(), "acc".into()]);
                    }
                    QuantFamily::Dorefa | QuantFamily::Wrpn => {
                        inputs.extend([x, y, scalar("lr"), scalar("mom"), vec_q("kw", q), scalar("ka")]);
                        outputs.extend(["loss".into(), "acc".into()]);
                    }
                    QuantFamily::Waveq => {
                        inputs.extend([vec_q("beta", q), vec_q("vbeta", q), x, y]);
                        inputs.extend([
                            scalar("lr"),
                            scalar("mom"),
                            scalar("lr_beta"),
                            scalar("ka"),
                            scalar("lambda_w"),
                            scalar("lambda_beta"),
                            scalar("beta_train"),
                        ]);
                        outputs.extend([
                            "beta".into(),
                            "vbeta".into(),
                            "loss".into(),
                            "acc".into(),
                            "ce".into(),
                            "reg_w".into(),
                        ]);
                    }
                }
                ProgramSig {
                    name: name.to_string(),
                    file: format!("{name}.native"),
                    model: Some(model.clone()),
                    inputs,
                    outputs,
                }
            }
            ProgramKind::Eval { model, quant } => {
                let m = &self.models[model];
                let q = m.num_qlayers();
                let x = ArgSpec {
                    name: "x".into(),
                    shape: vec![m.batch, m.input_shape[0], m.input_shape[1], m.input_shape[2]],
                    dtype: "float32".into(),
                };
                let y = ArgSpec {
                    name: "y".into(),
                    shape: vec![m.batch, m.num_classes],
                    dtype: "float32".into(),
                };
                let mut inputs = m.param_specs("w");
                inputs.extend([x, y]);
                if *quant != QuantFamily::Fp32 {
                    inputs.extend([vec_q("kw", q), scalar("ka")]);
                }
                ProgramSig {
                    name: name.to_string(),
                    file: format!("{name}.native"),
                    model: Some(model.clone()),
                    inputs,
                    outputs: vec!["loss".into(), "acc".into()],
                }
            }
        }
    }

    fn model(&self, key: &str) -> Result<&NativeModel> {
        self.models
            .get(key)
            .ok_or_else(|| anyhow!("native backend has no model '{key}'"))
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn platform_name(&self) -> String {
        "native".to_string()
    }

    fn compile(&self, sig: &ProgramSig) -> Result<()> {
        if !self.programs.contains_key(&sig.name) {
            return Err(anyhow!("native backend has no program '{}'", sig.name));
        }
        if self.compiled.borrow_mut().insert(sig.name.clone()) {
            self.stats.borrow_mut().compiles += 1;
        }
        Ok(())
    }

    fn execute(&self, sig: &ProgramSig, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        let kind = self
            .programs
            .get(&sig.name)
            .ok_or_else(|| anyhow!("native backend has no program '{}'", sig.name))?
            .clone();
        self.compile(sig)?;
        let t0 = Instant::now();
        let out = match &kind {
            ProgramKind::RegProfile => run_reg_profile(args),
            ProgramKind::Train { model, quant } => {
                run_train(&sig.name, self.model(model)?, *quant, args)
            }
            ProgramKind::Eval { model, quant } => {
                run_eval(&sig.name, self.model(model)?, *quant, args)
            }
        };
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        out
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}

// ---- program implementations ------------------------------------------------

/// Per-layer quantization state captured during the forward pass.
struct LayerQuant {
    /// Effective (possibly fake-quantized) weight used in the matmul.
    wq: Vec<f32>,
    /// STE factor dwq/dw per element; None = identity.
    ste: Option<Vec<f32>>,
    /// WaveQ only: (normalized coords v, scale m, beta_q) of this layer.
    waveq: Option<(Vec<f32>, f32, f64)>,
}

fn param_slices<'a>(
    prog: &str,
    model: &NativeModel,
    args: &'a [&Buffer],
    offset: usize,
) -> Result<Vec<&'a [f32]>> {
    let mut out = Vec::with_capacity(model.num_params());
    for (i, l) in model.layers.iter().enumerate() {
        let w = args[offset + 2 * i];
        let b = args[offset + 2 * i + 1];
        if w.elem_count() != l.din * l.dout {
            return Err(anyhow!(
                "{prog}: param {} has {} elems, expected {}x{}",
                l.name,
                w.elem_count(),
                l.din,
                l.dout
            ));
        }
        if b.elem_count() != l.dout {
            return Err(anyhow!(
                "{prog}: param {}_b has {} elems, expected {}",
                l.name,
                b.elem_count(),
                l.dout
            ));
        }
        out.push(w.data.as_slice());
        out.push(b.data.as_slice());
    }
    Ok(out)
}

/// Resolve batch size from the x/y buffers and validate consistency.
fn batch_of(prog: &str, model: &NativeModel, x: &Buffer, y: &Buffer) -> Result<usize> {
    let pix = model.pixels();
    if x.elem_count() == 0 || x.elem_count() % pix != 0 {
        return Err(anyhow!(
            "{prog}: x has {} elems, not a multiple of {} ({}x{}x{})",
            x.elem_count(),
            pix,
            model.input_shape[0],
            model.input_shape[1],
            model.input_shape[2]
        ));
    }
    let batch = x.elem_count() / pix;
    if y.elem_count() != batch * model.num_classes {
        return Err(anyhow!(
            "{prog}: y has {} elems, expected {} x {}",
            y.elem_count(),
            batch,
            model.num_classes
        ));
    }
    Ok(batch)
}

fn scalar_arg(prog: &str, name: &str, b: &Buffer) -> Result<f32> {
    b.data
        .first()
        .copied()
        .ok_or_else(|| anyhow!("{prog}: scalar input '{name}' is empty"))
}

fn kw_arg(prog: &str, model: &NativeModel, b: &Buffer) -> Result<Vec<f32>> {
    if b.elem_count() != model.num_qlayers() {
        return Err(anyhow!(
            "{prog}: kw has {} entries, model wants {}",
            b.elem_count(),
            model.num_qlayers()
        ));
    }
    Ok(b.data.clone())
}

/// Quantize one layer's weight for the forward pass.
fn quantize_layer(
    layer: &FcLayer,
    w: &[f32],
    quant: QuantFamily,
    kw: &[f32],
    beta: &[f32],
) -> LayerQuant {
    match (quant, layer.qidx) {
        (QuantFamily::Fp32, _) | (_, None) => {
            LayerQuant { wq: w.to_vec(), ste: None, waveq: None }
        }
        (QuantFamily::Dorefa, Some(q)) => {
            let (wq, ste, _m) = kn::dorefa_quantize(w, kw[q]);
            LayerQuant { wq, ste: Some(ste), waveq: None }
        }
        (QuantFamily::Wrpn, Some(q)) => {
            let (wq, _m) = kn::wrpn_quantize(w, kw[q]);
            LayerQuant { wq, ste: None, waveq: None }
        }
        (QuantFamily::Waveq, Some(q)) => {
            let b = beta[q] as f64;
            let k = (2f64.powf(b) - 1.0) as f32;
            let (wq, ste, v, m) = kn::dorefa_quantize_full(w, k);
            LayerQuant { wq, ste: Some(ste), waveq: Some((v, m, b)) }
        }
    }
}

struct ForwardPass {
    /// hs[l] = input activations of layer l (hs[0] is x); len = L.
    hs: Vec<Vec<f32>>,
    /// ReLU masks of the hidden layers (len = L - 1), 1.0 where z > 0.
    masks: Vec<Vec<f32>>,
    quants: Vec<LayerQuant>,
    logits: Vec<f32>,
}

/// Run the MLP forward; `act_ka = None` means fp32 activations (no fake
/// quantization after ReLU).
fn forward(
    model: &NativeModel,
    params: &[&[f32]],
    x: &[f32],
    batch: usize,
    quant: QuantFamily,
    kw: &[f32],
    beta: &[f32],
    act_ka: Option<f32>,
) -> ForwardPass {
    let nl = model.layers.len();
    let mut hs: Vec<Vec<f32>> = Vec::with_capacity(nl);
    let mut masks: Vec<Vec<f32>> = Vec::with_capacity(nl - 1);
    let mut quants: Vec<LayerQuant> = Vec::with_capacity(nl);
    let mut h = x.to_vec();
    let mut logits = Vec::new();
    for (li, l) in model.layers.iter().enumerate() {
        let lq = quantize_layer(l, params[2 * li], quant, kw, beta);
        let mut z = kn::matmul_bias(&h, &lq.wq, params[2 * li + 1], batch, l.din, l.dout);
        quants.push(lq);
        hs.push(h);
        if li + 1 < nl {
            let mut mask = vec![0.0f32; z.len()];
            for (zi, mi) in z.iter_mut().zip(mask.iter_mut()) {
                if *zi > 0.0 {
                    *mi = 1.0;
                } else {
                    *zi = 0.0;
                }
            }
            if let Some(ka) = act_ka {
                kn::act_quantize(&mut z, ka);
            }
            masks.push(mask);
            h = z;
        } else {
            logits = z;
            h = Vec::new();
        }
    }
    ForwardPass { hs, masks, quants, logits }
}

fn run_eval(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    args: &[&Buffer],
) -> Result<Vec<Buffer>> {
    let np = model.num_params();
    let expected = np + 2 + if quant == QuantFamily::Fp32 { 0 } else { 2 };
    if args.len() != expected {
        return Err(anyhow!("{prog}: native dispatch got {} args, wants {expected}", args.len()));
    }
    let params = param_slices(prog, model, args, 0)?;
    let x = args[np];
    let y = args[np + 1];
    let batch = batch_of(prog, model, x, y)?;
    let (kw, act_ka) = if quant == QuantFamily::Fp32 {
        (Vec::new(), None)
    } else {
        (kw_arg(prog, model, args[np + 2])?, Some(scalar_arg(prog, "ka", args[np + 3])?))
    };
    let fwd = forward(model, &params, &x.data, batch, quant, &kw, &[], act_ka);
    let (loss, acc, _dl) = kn::softmax_ce(&fwd.logits, &y.data, batch, model.num_classes);
    Ok(vec![Buffer::scalar(loss), Buffer::scalar(acc)])
}

fn run_train(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    args: &[&Buffer],
) -> Result<Vec<Buffer>> {
    let nl = model.layers.len();
    let np = model.num_params();
    let nq = model.num_qlayers();
    let expected = 2 * np
        + match quant {
            QuantFamily::Fp32 => 4,                      // x, y, lr, mom
            QuantFamily::Dorefa | QuantFamily::Wrpn => 6, // + kw, ka
            QuantFamily::Waveq => 11, // beta, vbeta, x, y + 7 scalars
        };
    if args.len() != expected {
        return Err(anyhow!("{prog}: native dispatch got {} args, wants {expected}", args.len()));
    }
    let params = param_slices(prog, model, args, 0)?;
    let vels = param_slices(prog, model, args, np)?;

    // Tail inputs, positionally after [w*, v*] (train_step.py layouts).
    let tail = &args[2 * np..];
    let beta_in: Vec<f32>;
    let vbeta_in: Vec<f32>;
    let x: &Buffer;
    let y: &Buffer;
    let lr: f32;
    let mom: f32;
    let lr_beta: f32;
    let ka: Option<f32>;
    let lam_w: f32;
    let lam_beta: f32;
    let beta_train: f32;
    match quant {
        QuantFamily::Fp32 => {
            beta_in = Vec::new();
            vbeta_in = Vec::new();
            x = tail[0];
            y = tail[1];
            lr = scalar_arg(prog, "lr", tail[2])?;
            mom = scalar_arg(prog, "mom", tail[3])?;
            lr_beta = 0.0;
            ka = None;
            lam_w = 0.0;
            lam_beta = 0.0;
            beta_train = 0.0;
        }
        QuantFamily::Dorefa | QuantFamily::Wrpn => {
            beta_in = Vec::new();
            vbeta_in = Vec::new();
            x = tail[0];
            y = tail[1];
            lr = scalar_arg(prog, "lr", tail[2])?;
            mom = scalar_arg(prog, "mom", tail[3])?;
            lr_beta = 0.0;
            ka = Some(scalar_arg(prog, "ka", tail[5])?);
            lam_w = 0.0;
            lam_beta = 0.0;
            beta_train = 0.0;
        }
        QuantFamily::Waveq => {
            if tail[0].elem_count() != nq || tail[1].elem_count() != nq {
                return Err(anyhow!(
                    "{prog}: beta/vbeta have {}/{} entries, model wants {nq}",
                    tail[0].elem_count(),
                    tail[1].elem_count()
                ));
            }
            beta_in = tail[0].data.clone();
            vbeta_in = tail[1].data.clone();
            x = tail[2];
            y = tail[3];
            lr = scalar_arg(prog, "lr", tail[4])?;
            mom = scalar_arg(prog, "mom", tail[5])?;
            lr_beta = scalar_arg(prog, "lr_beta", tail[6])?;
            ka = Some(scalar_arg(prog, "ka", tail[7])?);
            lam_w = scalar_arg(prog, "lambda_w", tail[8])?;
            lam_beta = scalar_arg(prog, "lambda_beta", tail[9])?;
            beta_train = scalar_arg(prog, "beta_train", tail[10])?;
        }
    }
    let kw = match quant {
        QuantFamily::Dorefa | QuantFamily::Wrpn => kw_arg(prog, model, tail[4])?,
        _ => Vec::new(),
    };
    let batch = batch_of(prog, model, x, y)?;

    // ---- forward ---------------------------------------------------------
    let fwd = forward(model, &params, &x.data, batch, quant, &kw, &beta_in, ka);
    let (ce, acc, dlogits) = kn::softmax_ce(&fwd.logits, &y.data, batch, model.num_classes);

    // ---- regularizer (waveq only) ---------------------------------------
    let mut reg_w = 0.0f64;
    let mut dreg_dbeta = vec![0.0f64; nq];
    if quant == QuantFamily::Waveq {
        for lq in &fwd.quants {
            if let Some((v, _m, b)) = &lq.waveq {
                reg_w += kn::waveq_reg(v, *b);
            }
        }
        for (l, lq) in model.layers.iter().zip(&fwd.quants) {
            if let (Some(q), Some((v, _m, b))) = (l.qidx, &lq.waveq) {
                dreg_dbeta[q] = kn::waveq_reg_grad_beta(v, *b);
            }
        }
    }
    let loss = ce + lam_w * reg_w as f32 + lam_beta * beta_in.iter().sum::<f32>();

    // ---- backward --------------------------------------------------------
    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); np];
    let mut dz = dlogits;
    for li in (0..nl).rev() {
        let l = &model.layers[li];
        let lq = &fwd.quants[li];
        let mut dw = kn::grad_weight(&fwd.hs[li], &dz, batch, l.din, l.dout);
        let db = kn::grad_bias(&dz, batch, l.dout);
        if let Some(ste) = &lq.ste {
            for (g, &s) in dw.iter_mut().zip(ste.iter()) {
                *g *= s;
            }
        }
        // WaveQ: lambda_w * dR/dw, chained v -> w through the tanh
        // normalization (per-layer max treated as constant, like the STE).
        if lam_w != 0.0 {
            if let Some((v, m, b)) = &lq.waveq {
                let gv = kn::waveq_reg_grad_v(v, *b);
                let ste = lq.ste.as_ref().expect("waveq layers carry an STE");
                for ((g, &gvj), &s) in dw.iter_mut().zip(gv.iter()).zip(ste.iter()) {
                    *g += lam_w * gvj * s / (2.0 * m);
                }
            }
        }
        grads[2 * li] = dw;
        grads[2 * li + 1] = db;
        if li > 0 {
            let mut dh = kn::grad_input(&dz, &lq.wq, batch, l.din, l.dout);
            for (g, &mk) in dh.iter_mut().zip(fwd.masks[li - 1].iter()) {
                *g *= mk;
            }
            dz = dh;
        }
    }

    // ---- updates ---------------------------------------------------------
    kn::clip_by_global_norm(&mut grads, kn::GRAD_CLIP_NORM);
    let mut new_params: Vec<Vec<f32>> = params.iter().map(|p| p.to_vec()).collect();
    let mut new_vels: Vec<Vec<f32>> = vels.iter().map(|v| v.to_vec()).collect();
    kn::sgd_momentum(&mut new_params, &mut new_vels, &grads, lr, mom);

    let (mut new_beta, mut new_vbeta) = (beta_in.clone(), vbeta_in.clone());
    if quant == QuantFamily::Waveq {
        for q in 0..nq {
            let gb = (lam_w as f64 * dreg_dbeta[q] + lam_beta as f64) as f32 * beta_train;
            new_vbeta[q] = mom * vbeta_in[q] + gb;
            new_beta[q] = kn::clip_beta(beta_in[q] - lr_beta * new_vbeta[q]);
        }
    }

    // ---- pack outputs ----------------------------------------------------
    let mut outs: Vec<Buffer> = Vec::with_capacity(2 * np + 8);
    for (i, l) in model.layers.iter().enumerate() {
        outs.push(Buffer::new(vec![l.din, l.dout], std::mem::take(&mut new_params[2 * i]))?);
        outs.push(Buffer::new(vec![l.dout], std::mem::take(&mut new_params[2 * i + 1]))?);
    }
    for (i, l) in model.layers.iter().enumerate() {
        outs.push(Buffer::new(vec![l.din, l.dout], std::mem::take(&mut new_vels[2 * i]))?);
        outs.push(Buffer::new(vec![l.dout], std::mem::take(&mut new_vels[2 * i + 1]))?);
    }
    if quant == QuantFamily::Waveq {
        outs.push(Buffer::new(vec![nq], new_beta)?);
        outs.push(Buffer::new(vec![nq], new_vbeta)?);
    }
    outs.push(Buffer::scalar(loss));
    outs.push(Buffer::scalar(acc));
    if quant == QuantFamily::Waveq {
        outs.push(Buffer::scalar(ce));
        outs.push(Buffer::scalar(reg_w as f32));
    }
    Ok(outs)
}

fn run_reg_profile(args: &[&Buffer]) -> Result<Vec<Buffer>> {
    if args.len() != 2 {
        return Err(anyhow!("reg_profile: native dispatch got {} args, wants 2", args.len()));
    }
    let wgrid = &args[0].data;
    let bgrid = &args[1].data;
    let (nw, nb) = (wgrid.len(), bgrid.len());
    let mut outs = Vec::with_capacity(9);
    for norm in 0..3u32 {
        let mut r = vec![0.0f32; nw * nb];
        let mut d1 = vec![0.0f32; nw * nb];
        let mut d2 = vec![0.0f32; nw * nb];
        for (wi, &wv) in wgrid.iter().enumerate() {
            for (bi, &bv) in bgrid.iter().enumerate() {
                let (w, b) = (wv as f64, bv as f64);
                r[wi * nb + bi] = kn::reg_point(w, b, norm) as f32;
                d1[wi * nb + bi] = kn::reg_point_d1(w, b, norm) as f32;
                d2[wi * nb + bi] = kn::reg_point_d2(w, b, norm) as f32;
            }
        }
        outs.push(Buffer::new(vec![nw, nb], r)?);
        outs.push(Buffer::new(vec![nw, nb], d1)?);
        outs.push(Buffer::new(vec![nw, nb], d2)?);
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::buffer::{buffer_f32, scalar_f32};
    use crate::util::rng::Rng;

    fn dummy_train_args(backend: &NativeBackend, prog: &str) -> Vec<Buffer> {
        let manifest = backend.manifest();
        let sig = manifest.program(prog).unwrap();
        let mut rng = Rng::new(7);
        sig.inputs
            .iter()
            .map(|a| {
                if a.shape.is_empty() {
                    return scalar_f32(match a.name.as_str() {
                        "lr" => 0.01,
                        "mom" => 0.9,
                        "lr_beta" => 0.01,
                        "ka" => 16_777_215.0,
                        "lambda_w" => 0.1,
                        "lambda_beta" => 0.01,
                        "beta_train" => 1.0,
                        _ => 0.5,
                    });
                }
                let n = a.elem_count();
                let data: Vec<f32> = match a.name.as_str() {
                    "beta" => vec![4.0; n],
                    "kw" => vec![7.0; n],
                    "y" => {
                        let classes = *a.shape.last().unwrap();
                        let mut v = vec![0.0; n];
                        for r in 0..a.shape[0] {
                            v[r * classes + r % classes] = 1.0;
                        }
                        v
                    }
                    name if name.starts_with("w:") => rng.normal_vec(n, 0.1),
                    _ => vec![0.0; n],
                };
                buffer_f32(&data, &a.shape).unwrap()
            })
            .collect()
    }

    #[test]
    fn every_native_program_executes_with_matching_arity() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        for (name, sig) in &manifest.programs {
            let args = dummy_train_args(&backend, name);
            let refs: Vec<&Buffer> = args.iter().collect();
            let outs = backend
                .execute(sig, &refs)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(outs.len(), sig.outputs.len(), "{name} output arity");
            if let Ok(i) = sig.output_index("loss") {
                let loss = outs[i].data[0];
                assert!(loss.is_finite(), "{name} loss not finite");
            }
        }
    }

    #[test]
    fn train_step_is_deterministic() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("train_waveq_mlp").unwrap();
        let args = dummy_train_args(&backend, "train_waveq_mlp");
        let refs: Vec<&Buffer> = args.iter().collect();
        let li = sig.output_index("loss").unwrap();
        let a = backend.execute(sig, &refs).unwrap()[li].data[0];
        let b = backend.execute(sig, &refs).unwrap()[li].data[0];
        assert_eq!(a, b, "same inputs must give bit-identical loss");
    }

    #[test]
    fn waveq_reg_term_raises_loss_over_ce() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("train_waveq_mlp").unwrap();
        let args = dummy_train_args(&backend, "train_waveq_mlp");
        let refs: Vec<&Buffer> = args.iter().collect();
        let outs = backend.execute(sig, &refs).unwrap();
        let loss = outs[sig.output_index("loss").unwrap()].data[0];
        let ce = outs[sig.output_index("ce").unwrap()].data[0];
        let reg = outs[sig.output_index("reg_w").unwrap()].data[0];
        assert!(reg > 0.0, "random weights should not sit on the grid");
        assert!(loss > ce, "loss must include the positive penalty terms");
    }

    #[test]
    fn beta_moves_only_when_beta_train_set() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("train_waveq_mlp").unwrap();
        let bidx = sig.output_index("beta").unwrap();
        let bin = sig.input_index("beta").unwrap();
        let fin = sig.input_index("beta_train").unwrap();

        let mut args = dummy_train_args(&backend, "train_waveq_mlp");
        // Use a beta off the integer grid so dR/dbeta is generically nonzero.
        args[bin] = buffer_f32(&[3.7, 5.2], &[2]).unwrap();
        args[fin] = scalar_f32(0.0);
        let refs: Vec<&Buffer> = args.iter().collect();
        let frozen = backend.execute(sig, &refs).unwrap()[bidx].data.clone();
        assert_eq!(frozen, vec![3.7, 5.2], "beta must not move when gated off");

        args[fin] = scalar_f32(1.0);
        let refs: Vec<&Buffer> = args.iter().collect();
        let live = backend.execute(sig, &refs).unwrap()[bidx].data.clone();
        assert_ne!(live, vec![3.7, 5.2], "beta must move when training is enabled");
        for &b in &live {
            assert!((1.0..=8.0).contains(&b), "beta {b} escaped its clip range");
        }
    }

    #[test]
    fn reg_profile_surfaces_have_grid_zeros() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("reg_profile").unwrap();
        let w: Vec<f32> = (0..REG_PROFILE_NW)
            .map(|i| -1.25 + 2.5 * i as f32 / (REG_PROFILE_NW - 1) as f32)
            .collect();
        let b: Vec<f32> = (0..REG_PROFILE_NB)
            .map(|i| 1.0 + 7.0 * i as f32 / (REG_PROFILE_NB - 1) as f32)
            .collect();
        let args = vec![
            buffer_f32(&w, &[REG_PROFILE_NW]).unwrap(),
            buffer_f32(&b, &[REG_PROFILE_NB]).unwrap(),
        ];
        let refs: Vec<&Buffer> = args.iter().collect();
        let outs = backend.execute(sig, &refs).unwrap();
        assert_eq!(outs.len(), 9);
        // R_n1 is non-negative and bounded by its 1/2^beta envelope.
        let r1 = &outs[3];
        for (wi, _) in w.iter().enumerate() {
            for (bi, &bv) in b.iter().enumerate() {
                let v = r1.data[wi * REG_PROFILE_NB + bi];
                assert!(v >= 0.0, "R1 negative at ({wi},{bi})");
                assert!(v <= 2f32.powf(-bv) + 1e-6, "R1 above envelope at ({wi},{bi})");
            }
        }
        // All derivative surfaces are finite.
        for o in &outs {
            assert!(o.data.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn unknown_program_is_a_clean_error() {
        let backend = NativeBackend::new();
        let sig = ProgramSig {
            name: "train_waveq_resnet99".into(),
            file: "nope".into(),
            model: None,
            inputs: vec![],
            outputs: vec![],
        };
        let err = backend.execute(&sig, &[]).unwrap_err();
        assert!(format!("{err}").contains("no program"), "{err}");
    }
}
