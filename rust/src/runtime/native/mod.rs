//! The pure-Rust reference backend: executes the WaveQ program family
//! end-to-end on the host for the *entire* model zoo (mlp, simplenet5,
//! resnet20l, vgg11l, svhn8, alexnetl, resnet18l, mobilenetl — mirroring
//! `python/compile/models.py`), satisfying the same manifest signatures the
//! AOT HLO programs export (`python/compile/train_step.py`):
//!
//!   train_fp32_<m>    : [w*P, v*P, x, y, lr, mom]                 -> [w', v', loss, acc]
//!   train_dorefa_<m>  : [w*P, v*P, x, y, lr, mom, kw(Q,), ka]     -> [w', v', loss, acc]
//!   train_wrpn_<m>_w2 : same as dorefa, on the width-doubled model
//!   train_waveq_<m>   : [w*P, v*P, beta, vbeta, x, y, lr, mom,
//!                        lr_beta, ka, lam_w, lam_beta, beta_train] -> [w', v', beta', vbeta',
//!                                                                     loss, acc, ce, reg_w]
//!   eval_fp32_<m>     : [w*P, x, y]                               -> [loss, acc]
//!   eval_quant_<m>    : [w*P, x, y, kw(Q,), ka]                   -> [loss, acc]
//!   eval_wrpn_<m>_w2  : [w*P, x, y, kw(Q,), ka]                   -> [loss, acc]
//!   reg_profile       : [wgrid, bgrid]                            -> 9 x (n_w, n_b) surfaces
//!
//! The fused `train_*` step is internally *chunked*: the batch is cut into
//! [`kernels::GRAD_CHUNKS`] fixed row spans, each span runs an independent
//! forward/backward, and the span sums reduce in chunk-index order through
//! [`kernels::allreduce_fixed_order`]. The same spans are exposed as
//! standalone stages for the distributed coordinator (one chunk per
//! dispatch; `denom` is the *global* batch size the loss normalizes by):
//!
//!   grads_fp32_<m>    : [w*P, x, y, denom]                        -> [g*P, ce_sum, acc_cnt]
//!   grads_dorefa_<m>  : [w*P, x, y, denom, kw(Q,), ka]            -> [g*P, ce_sum, acc_cnt]
//!   grads_wrpn_<m>_w2 : same as dorefa, on the width-doubled model
//!   grads_waveq_<m>   : [w*P, beta, x, y, denom, ka]              -> [g*P, ce_sum, acc_cnt]
//!   apply_fp32_<m>    : [w*P, v*P, g*P, ce_sum, acc_cnt, denom,
//!                        lr, mom]                                 -> [w', v', loss, acc]
//!   apply_dorefa_<m>  / apply_wrpn_<m>_w2 : same layout as apply_fp32
//!   apply_waveq_<m>   : [w*P, v*P, beta, vbeta, g*P, ce_sum,
//!                        acc_cnt, denom, lr, mom, lr_beta, lam_w,
//!                        lam_beta, beta_train]                    -> [w', v', beta', vbeta',
//!                                                                     loss, acc, ce, reg_w]
//!
//! Because the fused path and the split stages share the exact same chunk
//! grid, per-chunk kernels, reduction order, and apply code, an N-worker
//! run that reduces whole chunks in index order is bit-identical to the
//! 1-worker fused step — the property `tests/dist.rs` pins down.
//!
//! Models are op graphs (`models::OpNode`): conv2d via im2col + the shared
//! blocked, multi-threaded matmul kernels (`kernels`/`pool`; worker count
//! from `WAVEQ_THREADS`, bitwise deterministic for any value), depthwise
//! conv, max/global-avg pooling, per-channel affine, residual add — each
//! with a hand-derived backward. The quantized
//! forward uses the DoReFa/WRPN rules of `kernels`, the backward is the
//! straight-through estimator, and the 'waveq' programs add the sinusoidal
//! regularizer `lambda_w * sin^2(pi v 2^beta)`-family term with its
//! *analytic* gradient in both w and beta — the heart of the paper,
//! executed here with no Python, XLA, or artifacts involved.
//!
//! The backend also exports its own [`Manifest`] so the coordinator
//! (trainer / evaluator / energy / pareto layers) runs identically on
//! either backend.

pub mod kernels;
pub mod models;
pub mod pool;

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use anyhow::{anyhow, Result};

use self::kernels as kn;
use self::models::{OpNode, WRPN_WIDTH, ZOO_NAMES};
pub use self::models::NativeModel;
use super::backend::{Backend, RuntimeStats};
use super::buffer::Buffer;
use super::manifest::{ArgSpec, Manifest, ProgramSig};

/// Which weight-quantizer family a program uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QuantFamily {
    Fp32,
    Dorefa,
    Wrpn,
    Waveq,
}

#[derive(Debug, Clone)]
enum ProgramKind {
    Train { model: String, quant: QuantFamily },
    /// Grad-producing half of the split train step: forward + STE backward
    /// over one chunk's rows, no regularizer, no optimizer.
    TrainGrads { model: String, quant: QuantFamily },
    /// Optimizer-applying half: regularizer terms + clip + SGD/momentum +
    /// beta update on already-reduced gradients.
    ApplyUpdate { model: String, quant: QuantFamily },
    Eval { model: String, quant: QuantFamily },
    RegProfile,
}

/// Grid sizes of the reg_profile surfaces (match `make_reg_profile`).
pub const REG_PROFILE_NW: usize = 512;
pub const REG_PROFILE_NB: usize = 256;

/// The hermetic pure-Rust execution backend.
pub struct NativeBackend {
    models: BTreeMap<String, NativeModel>,
    programs: BTreeMap<String, ProgramKind>,
    compiled: RefCell<BTreeSet<String>>,
    stats: RefCell<RuntimeStats>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        // Spawn the persistent kernel worker pool now (idempotent), so the
        // thread-creation cost never lands inside a timed train step.
        pool::ensure_started();
        let mut models = BTreeMap::new();
        let mut programs = BTreeMap::new();
        for base in ZOO_NAMES {
            let m = NativeModel::by_name(base, 1).expect("zoo name");
            let wide = NativeModel::by_name(base, WRPN_WIDTH).expect("zoo name");
            let wide_key = wide.name.clone();
            models.insert(m.name.clone(), m);
            models.insert(wide_key.clone(), wide);
            for (prog, quant) in [
                (format!("train_fp32_{base}"), QuantFamily::Fp32),
                (format!("train_dorefa_{base}"), QuantFamily::Dorefa),
                (format!("train_waveq_{base}"), QuantFamily::Waveq),
            ] {
                programs.insert(prog, ProgramKind::Train { model: base.to_string(), quant });
            }
            programs.insert(
                format!("train_wrpn_{base}_w{WRPN_WIDTH}"),
                ProgramKind::Train { model: wide_key.clone(), quant: QuantFamily::Wrpn },
            );
            // Split train stages: every train program has a grads_/apply_ pair.
            for (fam, quant) in [
                ("fp32", QuantFamily::Fp32),
                ("dorefa", QuantFamily::Dorefa),
                ("waveq", QuantFamily::Waveq),
            ] {
                programs.insert(
                    format!("grads_{fam}_{base}"),
                    ProgramKind::TrainGrads { model: base.to_string(), quant },
                );
                programs.insert(
                    format!("apply_{fam}_{base}"),
                    ProgramKind::ApplyUpdate { model: base.to_string(), quant },
                );
            }
            programs.insert(
                format!("grads_wrpn_{base}_w{WRPN_WIDTH}"),
                ProgramKind::TrainGrads { model: wide_key.clone(), quant: QuantFamily::Wrpn },
            );
            programs.insert(
                format!("apply_wrpn_{base}_w{WRPN_WIDTH}"),
                ProgramKind::ApplyUpdate { model: wide_key.clone(), quant: QuantFamily::Wrpn },
            );
            programs.insert(
                format!("eval_fp32_{base}"),
                ProgramKind::Eval { model: base.to_string(), quant: QuantFamily::Fp32 },
            );
            programs.insert(
                format!("eval_quant_{base}"),
                ProgramKind::Eval { model: base.to_string(), quant: QuantFamily::Dorefa },
            );
            programs.insert(
                format!("eval_wrpn_{base}_w{WRPN_WIDTH}"),
                ProgramKind::Eval { model: wide_key, quant: QuantFamily::Wrpn },
            );
        }
        programs.insert("reg_profile".to_string(), ProgramKind::RegProfile);
        NativeBackend {
            models,
            programs,
            compiled: RefCell::new(BTreeSet::new()),
            stats: RefCell::new(RuntimeStats::default()),
        }
    }

    /// The manifest describing every native program and model — the same
    /// contract `python/compile/aot.py` writes for the AOT artifacts.
    pub fn manifest(&self) -> Manifest {
        let mut programs = BTreeMap::new();
        for (name, kind) in &self.programs {
            programs.insert(name.clone(), self.sig_for(name, kind));
        }
        let models = self.models.iter().map(|(k, m)| (k.clone(), m.meta())).collect();
        Manifest { programs, models }
    }

    fn sig_for(&self, name: &str, kind: &ProgramKind) -> ProgramSig {
        let scalar = |n: &str| ArgSpec { name: n.into(), shape: vec![], dtype: "float32".into() };
        let vec_q = |n: &str, q: usize| ArgSpec {
            name: n.into(),
            shape: vec![q],
            dtype: "float32".into(),
        };
        match kind {
            ProgramKind::RegProfile => ProgramSig {
                name: name.to_string(),
                file: format!("{name}.native"),
                model: None,
                inputs: vec![
                    vec_q("wgrid", REG_PROFILE_NW),
                    vec_q("bgrid", REG_PROFILE_NB),
                ],
                outputs: (0..3u32)
                    .flat_map(|n| ["r", "d1", "d2"].into_iter().map(move |q| format!("{q}_n{n}")))
                    .collect(),
            },
            ProgramKind::Train { model, quant } => {
                let m = &self.models[model];
                let q = m.num_qlayers();
                let x = ArgSpec {
                    name: "x".into(),
                    shape: vec![m.batch, m.input_shape[0], m.input_shape[1], m.input_shape[2]],
                    dtype: "float32".into(),
                };
                let y = ArgSpec {
                    name: "y".into(),
                    shape: vec![m.batch, m.num_classes],
                    dtype: "float32".into(),
                };
                let mut inputs = m.param_specs("w");
                inputs.extend(m.param_specs("v"));
                let mut outputs = m.param_names("w");
                outputs.extend(m.param_names("v"));
                match quant {
                    QuantFamily::Fp32 => {
                        inputs.extend([x, y, scalar("lr"), scalar("mom")]);
                        outputs.extend(["loss".into(), "acc".into()]);
                    }
                    QuantFamily::Dorefa | QuantFamily::Wrpn => {
                        let kw = vec_q("kw", q);
                        inputs.extend([x, y, scalar("lr"), scalar("mom"), kw, scalar("ka")]);
                        outputs.extend(["loss".into(), "acc".into()]);
                    }
                    QuantFamily::Waveq => {
                        inputs.extend([vec_q("beta", q), vec_q("vbeta", q), x, y]);
                        inputs.extend([
                            scalar("lr"),
                            scalar("mom"),
                            scalar("lr_beta"),
                            scalar("ka"),
                            scalar("lambda_w"),
                            scalar("lambda_beta"),
                            scalar("beta_train"),
                        ]);
                        outputs.extend([
                            "beta".into(),
                            "vbeta".into(),
                            "loss".into(),
                            "acc".into(),
                            "ce".into(),
                            "reg_w".into(),
                        ]);
                    }
                }
                ProgramSig {
                    name: name.to_string(),
                    file: format!("{name}.native"),
                    model: Some(model.clone()),
                    inputs,
                    outputs,
                }
            }
            ProgramKind::TrainGrads { model, quant } => {
                let m = &self.models[model];
                let q = m.num_qlayers();
                let x = ArgSpec {
                    name: "x".into(),
                    shape: vec![m.batch, m.input_shape[0], m.input_shape[1], m.input_shape[2]],
                    dtype: "float32".into(),
                };
                let y = ArgSpec {
                    name: "y".into(),
                    shape: vec![m.batch, m.num_classes],
                    dtype: "float32".into(),
                };
                let mut inputs = m.param_specs("w");
                match quant {
                    QuantFamily::Fp32 => inputs.extend([x, y, scalar("denom")]),
                    QuantFamily::Dorefa | QuantFamily::Wrpn => {
                        inputs.extend([x, y, scalar("denom"), vec_q("kw", q), scalar("ka")]);
                    }
                    QuantFamily::Waveq => {
                        inputs.push(vec_q("beta", q));
                        inputs.extend([x, y, scalar("denom"), scalar("ka")]);
                    }
                }
                let mut outputs = m.param_names("g");
                outputs.extend(["ce_sum".into(), "acc_cnt".into()]);
                ProgramSig {
                    name: name.to_string(),
                    file: format!("{name}.native"),
                    model: Some(model.clone()),
                    inputs,
                    outputs,
                }
            }
            ProgramKind::ApplyUpdate { model, quant } => {
                let m = &self.models[model];
                let q = m.num_qlayers();
                let mut inputs = m.param_specs("w");
                inputs.extend(m.param_specs("v"));
                if *quant == QuantFamily::Waveq {
                    inputs.extend([vec_q("beta", q), vec_q("vbeta", q)]);
                }
                inputs.extend(m.param_specs("g"));
                inputs.extend([
                    scalar("ce_sum"),
                    scalar("acc_cnt"),
                    scalar("denom"),
                    scalar("lr"),
                    scalar("mom"),
                ]);
                let mut outputs = m.param_names("w");
                outputs.extend(m.param_names("v"));
                if *quant == QuantFamily::Waveq {
                    inputs.extend([
                        scalar("lr_beta"),
                        scalar("lambda_w"),
                        scalar("lambda_beta"),
                        scalar("beta_train"),
                    ]);
                    outputs.extend(["beta".into(), "vbeta".into()]);
                }
                outputs.extend(["loss".into(), "acc".into()]);
                if *quant == QuantFamily::Waveq {
                    outputs.extend(["ce".into(), "reg_w".into()]);
                }
                ProgramSig {
                    name: name.to_string(),
                    file: format!("{name}.native"),
                    model: Some(model.clone()),
                    inputs,
                    outputs,
                }
            }
            ProgramKind::Eval { model, quant } => {
                let m = &self.models[model];
                let q = m.num_qlayers();
                let x = ArgSpec {
                    name: "x".into(),
                    shape: vec![m.batch, m.input_shape[0], m.input_shape[1], m.input_shape[2]],
                    dtype: "float32".into(),
                };
                let y = ArgSpec {
                    name: "y".into(),
                    shape: vec![m.batch, m.num_classes],
                    dtype: "float32".into(),
                };
                let mut inputs = m.param_specs("w");
                inputs.extend([x, y]);
                if *quant != QuantFamily::Fp32 {
                    inputs.extend([vec_q("kw", q), scalar("ka")]);
                }
                ProgramSig {
                    name: name.to_string(),
                    file: format!("{name}.native"),
                    model: Some(model.clone()),
                    inputs,
                    outputs: vec!["loss".into(), "acc".into()],
                }
            }
        }
    }

    fn model(&self, key: &str) -> Result<&NativeModel> {
        self.models
            .get(key)
            .ok_or_else(|| anyhow!("native backend has no model '{key}'"))
    }

    fn kind_of(&self, name: &str) -> Result<ProgramKind> {
        self.programs
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("native backend has no program '{name}'"))
    }

    /// Correctly-shaped zero buffers for a program's outputs — the
    /// allocating `execute` path; `execute_into` writes into caller-owned
    /// buffers instead.
    fn output_template(&self, kind: &ProgramKind, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        Ok(match kind {
            ProgramKind::RegProfile => {
                if args.len() != 2 {
                    return Err(anyhow!(
                        "reg_profile: native dispatch got {} args, wants 2",
                        args.len()
                    ));
                }
                let (nw, nb) = (args[0].elem_count(), args[1].elem_count());
                (0..9).map(|_| Buffer::zeros(vec![nw, nb])).collect()
            }
            ProgramKind::Train { model, quant } | ProgramKind::ApplyUpdate { model, quant } => {
                let m = self.model(model)?;
                let nq = m.num_qlayers();
                let mut outs: Vec<Buffer> = Vec::with_capacity(2 * m.num_params() + 8);
                for _ in 0..2 {
                    outs.extend(m.params.iter().map(|p| Buffer::zeros(p.shape.clone())));
                }
                if *quant == QuantFamily::Waveq {
                    outs.push(Buffer::zeros(vec![nq]));
                    outs.push(Buffer::zeros(vec![nq]));
                }
                outs.push(Buffer::scalar(0.0));
                outs.push(Buffer::scalar(0.0));
                if *quant == QuantFamily::Waveq {
                    outs.push(Buffer::scalar(0.0));
                    outs.push(Buffer::scalar(0.0));
                }
                outs
            }
            ProgramKind::TrainGrads { model, .. } => {
                let m = self.model(model)?;
                let mut outs: Vec<Buffer> =
                    m.params.iter().map(|p| Buffer::zeros(p.shape.clone())).collect();
                outs.push(Buffer::scalar(0.0));
                outs.push(Buffer::scalar(0.0));
                outs
            }
            ProgramKind::Eval { .. } => vec![Buffer::scalar(0.0), Buffer::scalar(0.0)],
        })
    }

    fn dispatch(
        &self,
        sig: &ProgramSig,
        kind: &ProgramKind,
        args: &[&Buffer],
        outs: &mut [Buffer],
    ) -> Result<()> {
        self.compile(sig)?;
        let t0 = Instant::now();
        let result = match kind {
            ProgramKind::RegProfile => run_reg_profile_into(args, outs),
            ProgramKind::Train { model, quant } => {
                run_train_into(&sig.name, self.model(model)?, *quant, args, outs)
            }
            ProgramKind::TrainGrads { model, quant } => {
                run_grads_into(&sig.name, self.model(model)?, *quant, args, outs)
            }
            ProgramKind::ApplyUpdate { model, quant } => {
                run_apply_into(&sig.name, self.model(model)?, *quant, args, outs)
            }
            ProgramKind::Eval { model, quant } => {
                run_eval_into(&sig.name, self.model(model)?, *quant, args, outs)
            }
        };
        self.stats
            .borrow_mut()
            .record_execute(&sig.name, t0.elapsed().as_secs_f64());
        result
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn platform_name(&self) -> String {
        "native".to_string()
    }

    fn compile(&self, sig: &ProgramSig) -> Result<()> {
        if !self.programs.contains_key(&sig.name) {
            return Err(anyhow!("native backend has no program '{}'", sig.name));
        }
        if self.compiled.borrow_mut().insert(sig.name.clone()) {
            self.stats.borrow_mut().compiles += 1;
        }
        Ok(())
    }

    /// The native executor resolves the batch from the x/y buffer lengths
    /// (`batch_of`), so any batch size dispatches through one program.
    fn batch_polymorphic(&self) -> bool {
        true
    }

    /// The split `grads_*`/`apply_*` train stages exist for every train
    /// program — the distributed coordinator keys off this.
    fn grad_stage(&self) -> bool {
        true
    }

    /// Pre-size the *calling thread's* buffer arena for a train-path
    /// program, so steady-state stepping leases its forward/backward
    /// intermediates without growing the pool. `Session::open` calls this
    /// once; worker threads each warm their own arena.
    fn warm(&self, sig: &ProgramSig) -> Result<()> {
        if let Ok(
            ProgramKind::Train { model, .. } | ProgramKind::TrainGrads { model, .. },
        ) = self.kind_of(&sig.name)
        {
            warm_arena(self.model(&model)?);
        }
        Ok(())
    }

    fn execute(&self, sig: &ProgramSig, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        let kind = self.kind_of(&sig.name)?;
        let mut outs = self.output_template(&kind, args)?;
        self.dispatch(sig, &kind, args, &mut outs)?;
        Ok(outs)
    }

    /// Write results into caller-owned buffers: parameters/velocities are
    /// updated in place in the output storage (copy state, apply SGD on the
    /// destination), scalars overwrite their slot — no output allocation.
    fn execute_into(&self, sig: &ProgramSig, args: &[&Buffer], outs: &mut [Buffer]) -> Result<()> {
        let kind = self.kind_of(&sig.name)?;
        self.dispatch(sig, &kind, args, outs)
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}

// ---- program implementations ------------------------------------------------

// ---- per-thread buffer arena ------------------------------------------------
//
// The train path's transient buffers (im2col cols, layer outputs, quantized
// weights, STE masks, gradients) were the last steady-state allocations.
// They now cycle through a thread-local free list: `lease` hands out a
// zeroed best-fit buffer, `reclaim` returns it. `warm_arena` pre-sizes the
// list once at `Session::open` (per thread — each distributed worker warms
// its own), so N replicas never multiply transient allocs, and values are
// untouched: a leased buffer is fully zeroed/overwritten before use, so
// which allocation backs it cannot affect any result bit.

std::thread_local! {
    static ARENA: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// Upper bound on pooled buffers per thread — enough for the deepest zoo
/// model's traces plus gradients, small enough to bound idle memory.
const ARENA_MAX_BUFS: usize = 128;

/// A zeroed length-`n` buffer, reusing the smallest pooled allocation that
/// fits (or growing one if none does).
fn lease(n: usize) -> Vec<f32> {
    let mut v = ARENA.with(|a| {
        let mut pool = a.borrow_mut();
        let mut best: Option<usize> = None;
        for (i, b) in pool.iter().enumerate() {
            if b.capacity() >= n
                && best.is_none_or(|j: usize| b.capacity() < pool[j].capacity())
            {
                best = Some(i);
            }
        }
        match best {
            Some(i) => pool.swap_remove(i),
            None => pool.pop().unwrap_or_default(),
        }
    });
    v.clear();
    v.resize(n, 0.0);
    v
}

/// Return a buffer's allocation to the calling thread's pool.
fn reclaim(v: Vec<f32>) {
    if v.capacity() == 0 {
        return;
    }
    ARENA.with(|a| {
        let mut pool = a.borrow_mut();
        if pool.len() < ARENA_MAX_BUFS {
            pool.push(v);
        }
    });
}

/// Return every buffer a recorded forward pass holds (cols, saved inputs,
/// masks, quantizer state, logits) once its backward has consumed it.
fn recycle(fwd: GraphForward) {
    fn reclaim_lq(lq: LayerQuant) {
        reclaim(lq.wq);
        if let Some(s) = lq.ste {
            reclaim(s);
        }
        if let Some((v, _m, _b)) = lq.waveq {
            reclaim(v);
        }
    }
    for tr in fwd.traces {
        match tr {
            Trace::Conv { cols, lq } | Trace::SkipProj { cols, lq } => {
                reclaim(cols);
                reclaim_lq(lq);
            }
            Trace::DwConv { input, lq } | Trace::Fc { input, lq } => {
                reclaim(input);
                reclaim_lq(lq);
            }
            Trace::Affine { input } => reclaim(input),
            Trace::Relu { mask } | Trace::SkipAdd { mask } => reclaim(mask),
            Trace::None | Trace::MaxPool { .. } | Trace::Gap => {}
        }
    }
    reclaim(fwd.logits);
}

/// Pre-size the calling thread's arena for one model's train-path
/// transients at its nominal batch (cols + layer outputs + the input
/// copy). Leases everything first, then reclaims, so the pool ends up
/// holding distinct allocations rather than one buffer resized repeatedly.
fn warm_arena(model: &NativeModel) {
    let batch = model.batch;
    let mut sizes: Vec<usize> = vec![batch * model.pixels()];
    for op in &model.ops {
        match op {
            OpNode::Conv { geom, .. } | OpNode::SkipProj { geom, .. } => {
                if !geom.depthwise {
                    sizes.push(geom.rows(batch) * geom.kdim());
                }
                sizes.push(geom.rows(batch) * geom.cout);
            }
            OpNode::Fc { dout, .. } => sizes.push(batch * dout),
            _ => {}
        }
    }
    let bufs: Vec<Vec<f32>> = sizes.iter().map(|&n| lease(n)).collect();
    for b in bufs {
        reclaim(b);
    }
}

/// Per-parameter quantization state captured during the forward pass.
struct LayerQuant {
    /// Effective (possibly fake-quantized) weight used in the op.
    wq: Vec<f32>,
    /// STE factor dwq/dw per element; None = identity.
    ste: Option<Vec<f32>>,
    /// WaveQ only: (normalized coords v, scale m, beta_q) of this weight.
    waveq: Option<(Vec<f32>, f32, f64)>,
}

fn param_slices<'a>(
    prog: &str,
    model: &NativeModel,
    args: &'a [&Buffer],
    offset: usize,
) -> Result<Vec<&'a [f32]>> {
    let mut out = Vec::with_capacity(model.num_params());
    for (i, p) in model.params.iter().enumerate() {
        let b = args[offset + i];
        let want: usize = p.shape.iter().product();
        if b.elem_count() != want {
            return Err(anyhow!(
                "{prog}: param {} has {} elems, expected {:?}",
                p.name,
                b.elem_count(),
                p.shape
            ));
        }
        out.push(b.data.as_slice());
    }
    Ok(out)
}

/// Resolve batch size from the x/y buffers and validate consistency.
fn batch_of(prog: &str, model: &NativeModel, x: &Buffer, y: &Buffer) -> Result<usize> {
    let pix = model.pixels();
    if x.elem_count() == 0 || !x.elem_count().is_multiple_of(pix) {
        return Err(anyhow!(
            "{prog}: x has {} elems, not a multiple of {} ({}x{}x{})",
            x.elem_count(),
            pix,
            model.input_shape[0],
            model.input_shape[1],
            model.input_shape[2]
        ));
    }
    let batch = x.elem_count() / pix;
    if y.elem_count() != batch * model.num_classes {
        return Err(anyhow!(
            "{prog}: y has {} elems, expected {} x {}",
            y.elem_count(),
            batch,
            model.num_classes
        ));
    }
    Ok(batch)
}

fn scalar_arg(prog: &str, name: &str, b: &Buffer) -> Result<f32> {
    b.data
        .first()
        .copied()
        .ok_or_else(|| anyhow!("{prog}: scalar input '{name}' is empty"))
}

fn kw_arg(prog: &str, model: &NativeModel, b: &Buffer) -> Result<Vec<f32>> {
    if b.elem_count() != model.num_qlayers() {
        return Err(anyhow!(
            "{prog}: kw has {} entries, model wants {}",
            b.elem_count(),
            model.num_qlayers()
        ));
    }
    Ok(b.data.clone())
}

/// Quantize one parameter tensor for the forward pass.
fn quantize_param(
    qidx: Option<usize>,
    w: &[f32],
    quant: QuantFamily,
    kw: &[f32],
    beta: &[f32],
) -> LayerQuant {
    match (quant, qidx) {
        (QuantFamily::Fp32, _) | (_, None) => {
            LayerQuant { wq: w.to_vec(), ste: None, waveq: None }
        }
        (QuantFamily::Dorefa, Some(q)) => {
            let (wq, ste, _m) = kn::dorefa_quantize(w, kw[q]);
            LayerQuant { wq, ste: Some(ste), waveq: None }
        }
        (QuantFamily::Wrpn, Some(q)) => {
            let (wq, _m) = kn::wrpn_quantize(w, kw[q]);
            LayerQuant { wq, ste: None, waveq: None }
        }
        (QuantFamily::Waveq, Some(q)) => {
            let b = beta[q] as f64;
            let k = (2f64.powf(b) - 1.0) as f32;
            let (wq, ste, v, m) = kn::dorefa_quantize_full(w, k);
            LayerQuant { wq, ste: Some(ste), waveq: Some((v, m, b)) }
        }
    }
}

/// Per-op forward residuals: exactly what the matching backward needs.
enum Trace {
    None,
    Conv { cols: Vec<f32>, lq: LayerQuant },
    DwConv { input: Vec<f32>, lq: LayerQuant },
    Fc { input: Vec<f32>, lq: LayerQuant },
    Affine { input: Vec<f32> },
    Relu { mask: Vec<f32> },
    MaxPool { argmax: Vec<u32>, in_len: usize },
    Gap,
    SkipProj { cols: Vec<f32>, lq: LayerQuant },
    SkipAdd { mask: Vec<f32> },
}

impl Trace {
    fn quant(&self) -> Option<&LayerQuant> {
        match self {
            Trace::Conv { lq, .. }
            | Trace::DwConv { lq, .. }
            | Trace::Fc { lq, .. }
            | Trace::SkipProj { lq, .. } => Some(lq),
            _ => None,
        }
    }
}

struct GraphForward {
    /// One trace per op, in op order.
    traces: Vec<Trace>,
    logits: Vec<f32>,
}

/// ReLU in place, recording the mask when a backward pass will need it,
/// then optional activation fake-quant (`act_ka = None` means fp32
/// activations). Returns an empty mask when `record` is off.
/// `pub(crate)` so `runtime::infer`'s forward-only path runs the *same*
/// code (bitwise, including -0.0 handling) as this backend's eval pass.
pub(crate) fn relu_quant(h: &mut [f32], act_ka: Option<f32>, record: bool) -> Vec<f32> {
    let mut mask = if record { lease(h.len()) } else { Vec::new() };
    if record {
        for (zi, mi) in h.iter_mut().zip(mask.iter_mut()) {
            if *zi > 0.0 {
                *mi = 1.0;
            } else {
                *zi = 0.0;
            }
        }
    } else {
        for zi in h.iter_mut() {
            if *zi < 0.0 {
                *zi = 0.0;
            }
        }
    }
    if let Some(ka) = act_ka {
        kn::act_quantize(h, ka);
    }
    mask
}

/// Run the op graph forward. With `record` set, a tape of per-op residuals
/// is kept for [`backward`]; eval-only callers pass `false` so the cols /
/// mask / input buffers are dropped as soon as each op completes (peak
/// memory stays at the live activation, not the sum over layers).
#[allow(clippy::too_many_arguments)]
fn forward(
    model: &NativeModel,
    params: &[&[f32]],
    x: &[f32],
    batch: usize,
    quant: QuantFamily,
    kw: &[f32],
    beta: &[f32],
    act_ka: Option<f32>,
    record: bool,
) -> GraphForward {
    // Every transient below cycles through the thread-local arena: `lease`
    // hands out zeroed storage (same precondition as a fresh `vec![0.0;…]`,
    // so the bits never depend on the reuse), and whatever an op drops is
    // reclaimed immediately; recorded traces are reclaimed by [`recycle`]
    // once the backward has consumed them.
    let mut h = lease(x.len());
    h.copy_from_slice(x);
    let mut traces: Vec<Trace> = Vec::with_capacity(model.ops.len());
    // Saved activations of open residual blocks (innermost last).
    let mut skips: Vec<Vec<f32>> = Vec::new();
    // Projected shortcut pending its SkipAdd.
    let mut shortcut: Option<Vec<f32>> = None;
    for op in &model.ops {
        match op {
            OpNode::Conv { geom, pidx } => {
                let lq = quantize_param(model.params[*pidx].qidx, params[*pidx], quant, kw, beta);
                if geom.depthwise {
                    let mut out = lease(geom.rows(batch) * geom.cout);
                    kn::dwconv_fwd_into(&h, &lq.wq, batch, geom, &mut out);
                    let input = std::mem::replace(&mut h, out);
                    if record {
                        traces.push(Trace::DwConv { input, lq });
                    } else {
                        reclaim(input);
                        traces.push(Trace::None);
                    }
                } else {
                    let (rows, kdim) = (geom.rows(batch), geom.kdim());
                    let mut cols = lease(rows * kdim);
                    kn::im2col_into(&h, batch, geom, &mut cols);
                    let mut out = lease(rows * geom.cout);
                    kn::matmul_into(&cols, &lq.wq, rows, kdim, geom.cout, &mut out);
                    reclaim(std::mem::replace(&mut h, out));
                    if record {
                        traces.push(Trace::Conv { cols, lq });
                    } else {
                        reclaim(cols);
                        traces.push(Trace::None);
                    }
                }
            }
            OpNode::Fc { din, dout, widx, bidx } => {
                let lq = quantize_param(model.params[*widx].qidx, params[*widx], quant, kw, beta);
                let mut out = lease(batch * dout);
                kn::matmul_bias_into(&h, &lq.wq, params[*bidx], batch, *din, *dout, &mut out);
                let input = std::mem::replace(&mut h, out);
                if record {
                    traces.push(Trace::Fc { input, lq });
                } else {
                    reclaim(input);
                    traces.push(Trace::None);
                }
            }
            OpNode::Affine { c, hw, sidx, bidx } => {
                let mut out = lease(h.len());
                kn::affine_fwd_into(&h, params[*sidx], params[*bidx], batch * hw, *c, &mut out);
                let input = std::mem::replace(&mut h, out);
                if record {
                    traces.push(Trace::Affine { input });
                } else {
                    reclaim(input);
                    traces.push(Trace::None);
                }
            }
            OpNode::Relu => {
                let mask = relu_quant(&mut h, act_ka, record);
                traces.push(if record { Trace::Relu { mask } } else { Trace::None });
            }
            OpNode::MaxPool { h: ph, w: pw, c, size } => {
                let in_len = h.len();
                let (out, argmax) = kn::maxpool_fwd(&h, batch, *ph, *pw, *c, *size);
                reclaim(std::mem::replace(&mut h, out));
                traces.push(if record { Trace::MaxPool { argmax, in_len } } else { Trace::None });
            }
            OpNode::GlobalAvgPool { h: ph, w: pw, c } => {
                let mut out = lease(batch * c);
                kn::gap_fwd_into(&h, batch, *ph, *pw, *c, &mut out);
                reclaim(std::mem::replace(&mut h, out));
                traces.push(Trace::Gap);
            }
            OpNode::Flatten => traces.push(Trace::None),
            OpNode::SkipSave => {
                let mut saved = lease(h.len());
                saved.copy_from_slice(&h);
                skips.push(saved);
                traces.push(Trace::None);
            }
            OpNode::SkipProj { geom, pidx } => {
                let saved = skips.last().expect("SkipProj without SkipSave");
                let lq = quantize_param(model.params[*pidx].qidx, params[*pidx], quant, kw, beta);
                let (rows, kdim) = (geom.rows(batch), geom.kdim());
                let mut cols = lease(rows * kdim);
                kn::im2col_into(saved, batch, geom, &mut cols);
                let mut proj = lease(rows * geom.cout);
                kn::matmul_into(&cols, &lq.wq, rows, kdim, geom.cout, &mut proj);
                shortcut = Some(proj);
                if record {
                    traces.push(Trace::SkipProj { cols, lq });
                } else {
                    reclaim(cols);
                    traces.push(Trace::None);
                }
            }
            OpNode::SkipAdd => {
                let saved = skips.pop().expect("SkipAdd without SkipSave");
                let sc = match shortcut.take() {
                    Some(proj) => {
                        reclaim(saved);
                        proj
                    }
                    None => saved,
                };
                debug_assert_eq!(h.len(), sc.len());
                for (hv, &sv) in h.iter_mut().zip(sc.iter()) {
                    *hv += sv;
                }
                reclaim(sc);
                let mask = relu_quant(&mut h, act_ka, record);
                traces.push(if record { Trace::SkipAdd { mask } } else { Trace::None });
            }
        }
    }
    GraphForward { traces, logits: h }
}

/// STE backward through a quantized weight + the WaveQ regularizer's
/// analytic w-gradient, chained v -> w through the tanh normalization
/// (per-layer max treated as constant, like the STE).
fn apply_quant_grad(dw: &mut [f32], lq: &LayerQuant, lam_w: f32) {
    if let Some(ste) = &lq.ste {
        for (g, &s) in dw.iter_mut().zip(ste.iter()) {
            *g *= s;
        }
    }
    if lam_w != 0.0 {
        if let Some((v, m, b)) = &lq.waveq {
            let gv = kn::waveq_reg_grad_v(v, *b);
            let ste = lq.ste.as_ref().expect("waveq layers carry an STE");
            for ((g, &gvj), &s) in dw.iter_mut().zip(gv.iter()).zip(ste.iter()) {
                *g += lam_w * gvj * s / (2.0 * m);
            }
        }
    }
}

/// Reverse sweep over the op graph: one gradient tensor per parameter.
fn backward(
    model: &NativeModel,
    fwd: &GraphForward,
    dlogits: Vec<f32>,
    batch: usize,
    params: &[&[f32]],
    lam_w: f32,
) -> Vec<Vec<f32>> {
    // Empty placeholders only: every parameter belongs to exactly one op,
    // so the reverse sweep assigns each slot exactly once (asserted below).
    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); model.params.len()];
    let mut dh = dlogits;
    // Gradients flowing to shortcut branches of open residual blocks.
    let mut skip_grads: Vec<Vec<f32>> = Vec::new();
    for (op, tr) in model.ops.iter().zip(fwd.traces.iter()).rev() {
        match (op, tr) {
            (OpNode::Fc { din, dout, widx, bidx }, Trace::Fc { input, lq }) => {
                let mut dw = kn::grad_weight(input, &dh, batch, *din, *dout);
                let db = kn::grad_bias(&dh, batch, *dout);
                apply_quant_grad(&mut dw, lq, lam_w);
                grads[*widx] = dw;
                grads[*bidx] = db;
                dh = kn::grad_input(&dh, &lq.wq, batch, *din, *dout);
            }
            (OpNode::Conv { geom, pidx }, Trace::Conv { cols, lq }) => {
                let (rows, kdim) = (geom.rows(batch), geom.kdim());
                let mut dw = kn::grad_weight(cols, &dh, rows, kdim, geom.cout);
                apply_quant_grad(&mut dw, lq, lam_w);
                grads[*pidx] = dw;
                let dcols = kn::grad_input(&dh, &lq.wq, rows, kdim, geom.cout);
                dh = kn::col2im(&dcols, batch, geom);
            }
            (OpNode::Conv { geom, pidx }, Trace::DwConv { input, lq }) => {
                let mut dw = kn::dwconv_grad_w(input, &dh, batch, geom);
                apply_quant_grad(&mut dw, lq, lam_w);
                grads[*pidx] = dw;
                dh = kn::dwconv_grad_x(&dh, &lq.wq, batch, geom);
            }
            (OpNode::Affine { c, hw, sidx, bidx }, Trace::Affine { input }) => {
                let (dx, ds, db) = kn::affine_bwd(input, &dh, params[*sidx], batch * hw, *c);
                grads[*sidx] = ds;
                grads[*bidx] = db;
                dh = dx;
            }
            (OpNode::Relu, Trace::Relu { mask }) => {
                for (g, &m) in dh.iter_mut().zip(mask.iter()) {
                    *g *= m;
                }
            }
            (OpNode::MaxPool { .. }, Trace::MaxPool { argmax, in_len }) => {
                dh = kn::maxpool_bwd(&dh, argmax, *in_len);
            }
            (OpNode::GlobalAvgPool { h, w, c }, Trace::Gap) => {
                dh = kn::gap_bwd(&dh, batch, *h, *w, *c);
            }
            (OpNode::Flatten, Trace::None) => {}
            (OpNode::SkipAdd, Trace::SkipAdd { mask }) => {
                for (g, &m) in dh.iter_mut().zip(mask.iter()) {
                    *g *= m;
                }
                // The post-mask gradient feeds both the body (dh continues)
                // and the shortcut (pushed for SkipProj/SkipSave).
                skip_grads.push(dh.clone());
            }
            (OpNode::SkipProj { geom, pidx }, Trace::SkipProj { cols, lq }) => {
                let g = skip_grads.pop().expect("SkipProj without SkipAdd gradient");
                let (rows, kdim) = (geom.rows(batch), geom.kdim());
                let mut dw = kn::grad_weight(cols, &g, rows, kdim, geom.cout);
                apply_quant_grad(&mut dw, lq, lam_w);
                grads[*pidx] = dw;
                let dcols = kn::grad_input(&g, &lq.wq, rows, kdim, geom.cout);
                skip_grads.push(kn::col2im(&dcols, batch, geom));
            }
            (OpNode::SkipSave, Trace::None) => {
                let g = skip_grads.pop().expect("SkipSave without skip gradient");
                debug_assert_eq!(dh.len(), g.len());
                for (a, &b) in dh.iter_mut().zip(g.iter()) {
                    *a += b;
                }
            }
            _ => unreachable!("op/trace mismatch in native backward"),
        }
    }
    debug_assert!(
        grads
            .iter()
            .zip(&model.params)
            .all(|(g, p)| g.len() == p.shape.iter().product::<usize>()),
        "native backward left a parameter gradient unassigned"
    );
    grads
}

/// Validate one caller-owned output buffer against the shape the program
/// writes (the `execute_into` contract).
fn check_out(prog: &str, name: &str, out: &Buffer, shape: &[usize]) -> Result<()> {
    if out.shape.as_slice() != shape {
        return Err(anyhow!(
            "{prog}: output '{name}' buffer has shape {:?}, program writes {:?}",
            out.shape,
            shape
        ));
    }
    Ok(())
}

fn run_eval_into(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    args: &[&Buffer],
    outs: &mut [Buffer],
) -> Result<()> {
    let np = model.num_params();
    let expected = np + 2 + if quant == QuantFamily::Fp32 { 0 } else { 2 };
    if args.len() != expected {
        return Err(anyhow!("{prog}: native dispatch got {} args, wants {expected}", args.len()));
    }
    if outs.len() != 2 {
        return Err(anyhow!("{prog}: got {} output buffers, program writes 2", outs.len()));
    }
    check_out(prog, "loss", &outs[0], &[])?;
    check_out(prog, "acc", &outs[1], &[])?;
    let params = param_slices(prog, model, args, 0)?;
    let x = args[np];
    let y = args[np + 1];
    let batch = batch_of(prog, model, x, y)?;
    let (kw, act_ka) = if quant == QuantFamily::Fp32 {
        (Vec::new(), None)
    } else {
        (kw_arg(prog, model, args[np + 2])?, Some(scalar_arg(prog, "ka", args[np + 3])?))
    };
    let fwd = forward(model, &params, &x.data, batch, quant, &kw, &[], act_ka, false);
    let (loss, acc, _dl) = kn::softmax_ce(&fwd.logits, &y.data, batch, model.num_classes);
    reclaim(fwd.logits);
    outs[0].data[0] = loss;
    outs[1].data[0] = acc;
    Ok(())
}

fn run_train_into(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    args: &[&Buffer],
    outs: &mut [Buffer],
) -> Result<()> {
    let np = model.num_params();
    let nq = model.num_qlayers();
    let expected = 2 * np
        + match quant {
            QuantFamily::Fp32 => 4,                      // x, y, lr, mom
            QuantFamily::Dorefa | QuantFamily::Wrpn => 6, // + kw, ka
            QuantFamily::Waveq => 11, // beta, vbeta, x, y + 7 scalars
        };
    if args.len() != expected {
        return Err(anyhow!("{prog}: native dispatch got {} args, wants {expected}", args.len()));
    }
    check_train_outs(prog, model, quant, outs)?;
    let params = param_slices(prog, model, args, 0)?;
    let vels = param_slices(prog, model, args, np)?;

    // Tail inputs, positionally after [w*, v*] (train_step.py layouts).
    // Tuple order: (beta_in, vbeta_in, x, y, lr, mom, lr_beta, ka, lam_w,
    // lam_beta, beta_train) — families without a field bind its neutral
    // value (empty beta vecs, lr_beta/lambdas 0, ka None).
    let tail = &args[2 * np..];
    let (beta_in, vbeta_in, x, y, lr, mom, lr_beta, ka, lam_w, lam_beta, beta_train) = match quant
    {
        QuantFamily::Fp32 => (
            Vec::new(),
            Vec::new(),
            tail[0],
            tail[1],
            scalar_arg(prog, "lr", tail[2])?,
            scalar_arg(prog, "mom", tail[3])?,
            0.0,
            None,
            0.0,
            0.0,
            0.0,
        ),
        QuantFamily::Dorefa | QuantFamily::Wrpn => (
            Vec::new(),
            Vec::new(),
            tail[0],
            tail[1],
            scalar_arg(prog, "lr", tail[2])?,
            scalar_arg(prog, "mom", tail[3])?,
            0.0,
            Some(scalar_arg(prog, "ka", tail[5])?),
            0.0,
            0.0,
            0.0,
        ),
        QuantFamily::Waveq => {
            if tail[0].elem_count() != nq || tail[1].elem_count() != nq {
                return Err(anyhow!(
                    "{prog}: beta/vbeta have {}/{} entries, model wants {nq}",
                    tail[0].elem_count(),
                    tail[1].elem_count()
                ));
            }
            (
                tail[0].data.clone(),
                tail[1].data.clone(),
                tail[2],
                tail[3],
                scalar_arg(prog, "lr", tail[4])?,
                scalar_arg(prog, "mom", tail[5])?,
                scalar_arg(prog, "lr_beta", tail[6])?,
                Some(scalar_arg(prog, "ka", tail[7])?),
                scalar_arg(prog, "lambda_w", tail[8])?,
                scalar_arg(prog, "lambda_beta", tail[9])?,
                scalar_arg(prog, "beta_train", tail[10])?,
            )
        }
    };
    let kw = match quant {
        QuantFamily::Dorefa | QuantFamily::Wrpn => kw_arg(prog, model, tail[4])?,
        _ => Vec::new(),
    };
    let batch = batch_of(prog, model, x, y)?;

    // ---- chunked forward/backward over the fixed reduction grid ----------
    // Same unit of work (chunk_grads) and same reduction
    // (allreduce_fixed_order, chunk-index order) as the distributed
    // coordinator, so N workers reproduce this path's bits by construction.
    let denom = batch as f32;
    let pix = model.pixels();
    let nc = model.num_classes;
    let mut grads: Vec<Vec<f32>> = model
        .params
        .iter()
        .map(|p| lease(p.shape.iter().product()))
        .collect();
    let mut ce_sum = 0.0f32;
    let mut acc_cnt = 0.0f32;
    for chunk in 0..kn::GRAD_CHUNKS {
        let (lo, hi) = kn::chunk_rows(chunk, batch);
        if lo == hi {
            continue;
        }
        let (cgrads, c_ce, c_acc) = chunk_grads(
            model,
            &params,
            quant,
            &kw,
            &beta_in,
            ka,
            &x.data[lo * pix..hi * pix],
            &y.data[lo * nc..hi * nc],
            hi - lo,
            denom,
        );
        for (dst, cg) in grads.iter_mut().zip(cgrads.iter()) {
            kn::allreduce_fixed_order(dst, &[cg.as_slice()]);
        }
        kn::allreduce_fixed_order(
            std::slice::from_mut(&mut ce_sum),
            &[std::slice::from_ref(&c_ce)],
        );
        kn::allreduce_fixed_order(
            std::slice::from_mut(&mut acc_cnt),
            &[std::slice::from_ref(&c_acc)],
        );
        for cg in cgrads {
            reclaim(cg);
        }
    }

    // ---- regularizer + optimizer (the shared apply stage) ----------------
    let knobs = ApplyKnobs {
        ce_sum,
        acc_cnt,
        denom,
        lr,
        mom,
        lr_beta,
        lam_w,
        lam_beta,
        beta_train,
    };
    apply_update_into(model, quant, &params, &vels, &beta_in, &vbeta_in, grads, &knobs, outs)
}

/// One chunk's gradient contribution: forward + CE parts + STE backward
/// over `rows` rows, with the loss gradient denominated by the *global*
/// batch (`denom`). No regularizer (the apply stage owns it, once) and no
/// optimizer — this is the unit both the fused train path and every
/// distributed worker compute, so their bits agree by construction.
#[allow(clippy::too_many_arguments)]
fn chunk_grads(
    model: &NativeModel,
    params: &[&[f32]],
    quant: QuantFamily,
    kw: &[f32],
    beta: &[f32],
    act_ka: Option<f32>,
    x_rows: &[f32],
    y_rows: &[f32],
    rows: usize,
    denom: f32,
) -> (Vec<Vec<f32>>, f32, f32) {
    let fwd = forward(model, params, x_rows, rows, quant, kw, beta, act_ka, true);
    let (ce_sum, acc_cnt, dlogits) =
        kn::softmax_ce_parts(&fwd.logits, y_rows, rows, model.num_classes, denom);
    let grads = backward(model, &fwd, dlogits, rows, params, 0.0);
    recycle(fwd);
    (grads, ce_sum, acc_cnt)
}

/// WaveQ regularizer contributions for the apply stage: re-quantizes each
/// quantized layer once (quantization depends only on params/beta, never on
/// the batch, so these match the per-chunk forward's bits), accumulates the
/// f64 regularizer sum and analytic dR/dbeta in op order, and — when the
/// weight regularizer is active — adds its w-gradient to the reduced data
/// gradients, pre-clip, exactly once per step.
fn reg_terms(
    model: &NativeModel,
    params: &[&[f32]],
    beta: &[f32],
    lam_w: f32,
    grads: &mut [Vec<f32>],
) -> (f64, Vec<f64>) {
    let mut reg_w = 0.0f64;
    let mut dreg = vec![0.0f64; model.num_qlayers()];
    for op in &model.ops {
        let pidx = match op {
            OpNode::Conv { pidx, .. } | OpNode::SkipProj { pidx, .. } => *pidx,
            OpNode::Fc { widx, .. } => *widx,
            _ => continue,
        };
        let Some(q) = model.params[pidx].qidx else { continue };
        let LayerQuant { wq, ste, waveq } =
            quantize_param(Some(q), params[pidx], QuantFamily::Waveq, &[], beta);
        let (v, m, b) = waveq.expect("waveq quantization carries (v, m, beta)");
        let ste = ste.expect("waveq layers carry an STE");
        reg_w += kn::waveq_reg(&v, b);
        dreg[q] = kn::waveq_reg_grad_beta(&v, b);
        if lam_w != 0.0 {
            let gv = kn::waveq_reg_grad_v(&v, b);
            for ((g, &gvj), &s) in grads[pidx].iter_mut().zip(gv.iter()).zip(ste.iter()) {
                *g += lam_w * gvj * s / (2.0 * m);
            }
        }
        reclaim(wq);
        reclaim(ste);
        reclaim(v);
    }
    (reg_w, dreg)
}

/// Apply-stage knobs: the reduced CE parts plus every optimizer scalar.
struct ApplyKnobs {
    ce_sum: f32,
    acc_cnt: f32,
    denom: f32,
    lr: f32,
    mom: f32,
    lr_beta: f32,
    lam_w: f32,
    lam_beta: f32,
    beta_train: f32,
}

/// The optimizer-applying half of the step, shared by the fused train path
/// and the `apply_*` programs: regularizer terms, global-norm clip,
/// SGD/momentum into the caller-owned outputs, beta/vbeta update, and the
/// step scalars. Runs exactly once per step on already-reduced gradients —
/// identical bits whether one process or the coordinator produced the sum.
#[allow(clippy::too_many_arguments)]
fn apply_update_into(
    model: &NativeModel,
    quant: QuantFamily,
    params: &[&[f32]],
    vels: &[&[f32]],
    beta_in: &[f32],
    vbeta_in: &[f32],
    mut grads: Vec<Vec<f32>>,
    k: &ApplyKnobs,
    outs: &mut [Buffer],
) -> Result<()> {
    let np = model.num_params();
    let nq = model.num_qlayers();
    let (mut reg_w, mut dreg) = (0.0f64, vec![0.0f64; nq]);
    if quant == QuantFamily::Waveq {
        (reg_w, dreg) = reg_terms(model, params, beta_in, k.lam_w, &mut grads);
    }
    let ce = k.ce_sum / k.denom;
    let acc = k.acc_cnt / k.denom;
    let loss = ce + k.lam_w * reg_w as f32 + k.lam_beta * beta_in.iter().sum::<f32>();

    kn::clip_by_global_norm(&mut grads, kn::GRAD_CLIP_NORM);
    let (pouts, rest) = outs.split_at_mut(np);
    let (vouts, tail_outs) = rest.split_at_mut(np);
    for i in 0..np {
        pouts[i].data.copy_from_slice(params[i]);
        vouts[i].data.copy_from_slice(vels[i]);
        kn::sgd_momentum_step(&mut pouts[i].data, &mut vouts[i].data, &grads[i], k.lr, k.mom);
    }
    if quant == QuantFamily::Waveq {
        for q in 0..nq {
            let gb = (k.lam_w as f64 * dreg[q] + k.lam_beta as f64) as f32 * k.beta_train;
            let nv = k.mom * vbeta_in[q] + gb;
            tail_outs[1].data[q] = nv;
            tail_outs[0].data[q] = kn::clip_beta(beta_in[q] - k.lr_beta * nv);
        }
    }
    let si = if quant == QuantFamily::Waveq { 2 } else { 0 };
    tail_outs[si].data[0] = loss;
    tail_outs[si + 1].data[0] = acc;
    if quant == QuantFamily::Waveq {
        tail_outs[si + 2].data[0] = ce;
        tail_outs[si + 3].data[0] = reg_w as f32;
    }
    for g in grads {
        reclaim(g);
    }
    Ok(())
}

/// Validate caller-owned outputs of the fused-train / apply-stage layout:
/// `[w*, v*, (beta, vbeta), loss, acc, (ce, reg_w)]`.
fn check_train_outs(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    outs: &[Buffer],
) -> Result<()> {
    let np = model.num_params();
    let nq = model.num_qlayers();
    let n_scalars = if quant == QuantFamily::Waveq { 4 } else { 2 };
    let n_beta = if quant == QuantFamily::Waveq { 2 } else { 0 };
    let expected_outs = 2 * np + n_beta + n_scalars;
    if outs.len() != expected_outs {
        return Err(anyhow!(
            "{prog}: got {} output buffers, program writes {expected_outs}",
            outs.len()
        ));
    }
    for (i, p) in model.params.iter().enumerate() {
        check_out(prog, &p.name, &outs[i], &p.shape)?;
        check_out(prog, &p.name, &outs[np + i], &p.shape)?;
    }
    if quant == QuantFamily::Waveq {
        check_out(prog, "beta", &outs[2 * np], &[nq])?;
        check_out(prog, "vbeta", &outs[2 * np + 1], &[nq])?;
    }
    for i in 0..n_scalars {
        check_out(prog, "scalar", &outs[2 * np + n_beta + i], &[])?;
    }
    Ok(())
}

/// The grad-producing half of the split train step: STE data gradients plus
/// CE parts over exactly the rows the caller passed (one reduction chunk),
/// loss denominated by the global batch (`denom`). Bit-equality with the
/// fused path holds when callers shard rows on the `chunk_rows` grid and
/// reduce parts in chunk-index order.
fn run_grads_into(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    args: &[&Buffer],
    outs: &mut [Buffer],
) -> Result<()> {
    let np = model.num_params();
    let nq = model.num_qlayers();
    let expected = np
        + match quant {
            QuantFamily::Fp32 => 3,                       // x, y, denom
            QuantFamily::Dorefa | QuantFamily::Wrpn => 5, // + kw, ka
            QuantFamily::Waveq => 5,                      // beta + x, y, denom, ka
        };
    if args.len() != expected {
        return Err(anyhow!("{prog}: native dispatch got {} args, wants {expected}", args.len()));
    }
    if outs.len() != np + 2 {
        return Err(anyhow!(
            "{prog}: got {} output buffers, program writes {}",
            outs.len(),
            np + 2
        ));
    }
    for (i, p) in model.params.iter().enumerate() {
        check_out(prog, &p.name, &outs[i], &p.shape)?;
    }
    check_out(prog, "ce_sum", &outs[np], &[])?;
    check_out(prog, "acc_cnt", &outs[np + 1], &[])?;
    let params = param_slices(prog, model, args, 0)?;
    let tail = &args[np..];
    let (beta_in, x, y, denom_b, kw, ka) = match quant {
        QuantFamily::Fp32 => (Vec::new(), tail[0], tail[1], tail[2], Vec::new(), None),
        QuantFamily::Dorefa | QuantFamily::Wrpn => (
            Vec::new(),
            tail[0],
            tail[1],
            tail[2],
            kw_arg(prog, model, tail[3])?,
            Some(scalar_arg(prog, "ka", tail[4])?),
        ),
        QuantFamily::Waveq => {
            if tail[0].elem_count() != nq {
                return Err(anyhow!(
                    "{prog}: beta has {} entries, model wants {nq}",
                    tail[0].elem_count()
                ));
            }
            (
                tail[0].data.clone(),
                tail[1],
                tail[2],
                tail[3],
                Vec::new(),
                Some(scalar_arg(prog, "ka", tail[4])?),
            )
        }
    };
    let denom = scalar_arg(prog, "denom", denom_b)?;
    if !(denom > 0.0) {
        return Err(anyhow!("{prog}: denom must be positive, got {denom}"));
    }
    let batch = batch_of(prog, model, x, y)?;
    let (grads, ce_sum, acc_cnt) =
        chunk_grads(model, &params, quant, &kw, &beta_in, ka, &x.data, &y.data, batch, denom);
    for (i, g) in grads.into_iter().enumerate() {
        outs[i].data.copy_from_slice(&g);
        reclaim(g);
    }
    outs[np].data[0] = ce_sum;
    outs[np + 1].data[0] = acc_cnt;
    Ok(())
}

/// The optimizer-applying half as a dispatchable program (`apply_*`):
/// parses `[w*, v*, (beta, vbeta), g*, ce_sum, acc_cnt, denom, lr, mom,
/// (lr_beta, lambda_w, lambda_beta, beta_train)]` and delegates to
/// [`apply_update_into`], writing the fused-train output layout.
fn run_apply_into(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    args: &[&Buffer],
    outs: &mut [Buffer],
) -> Result<()> {
    let np = model.num_params();
    let nq = model.num_qlayers();
    let n_beta = if quant == QuantFamily::Waveq { 2 } else { 0 };
    let n_knobs = if quant == QuantFamily::Waveq { 9 } else { 5 };
    let expected = 3 * np + n_beta + n_knobs;
    if args.len() != expected {
        return Err(anyhow!("{prog}: native dispatch got {} args, wants {expected}", args.len()));
    }
    check_train_outs(prog, model, quant, outs)?;
    let params = param_slices(prog, model, args, 0)?;
    let vels = param_slices(prog, model, args, np)?;
    let (beta_in, vbeta_in) = if quant == QuantFamily::Waveq {
        let (b, vb) = (args[2 * np], args[2 * np + 1]);
        if b.elem_count() != nq || vb.elem_count() != nq {
            return Err(anyhow!(
                "{prog}: beta/vbeta have {}/{} entries, model wants {nq}",
                b.elem_count(),
                vb.elem_count()
            ));
        }
        (b.data.clone(), vb.data.clone())
    } else {
        (Vec::new(), Vec::new())
    };
    let gs = param_slices(prog, model, args, 2 * np + n_beta)?;
    let grads: Vec<Vec<f32>> = gs
        .iter()
        .map(|s| {
            let mut g = lease(s.len());
            g.copy_from_slice(s);
            g
        })
        .collect();
    let sc = &args[3 * np + n_beta..];
    let denom = scalar_arg(prog, "denom", sc[2])?;
    if !(denom > 0.0) {
        return Err(anyhow!("{prog}: denom must be positive, got {denom}"));
    }
    let waveq = quant == QuantFamily::Waveq;
    let knobs = ApplyKnobs {
        ce_sum: scalar_arg(prog, "ce_sum", sc[0])?,
        acc_cnt: scalar_arg(prog, "acc_cnt", sc[1])?,
        denom,
        lr: scalar_arg(prog, "lr", sc[3])?,
        mom: scalar_arg(prog, "mom", sc[4])?,
        lr_beta: if waveq { scalar_arg(prog, "lr_beta", sc[5])? } else { 0.0 },
        lam_w: if waveq { scalar_arg(prog, "lambda_w", sc[6])? } else { 0.0 },
        lam_beta: if waveq { scalar_arg(prog, "lambda_beta", sc[7])? } else { 0.0 },
        beta_train: if waveq { scalar_arg(prog, "beta_train", sc[8])? } else { 0.0 },
    };
    apply_update_into(model, quant, &params, &vels, &beta_in, &vbeta_in, grads, &knobs, outs)
}

fn run_reg_profile_into(args: &[&Buffer], outs: &mut [Buffer]) -> Result<()> {
    if args.len() != 2 {
        return Err(anyhow!("reg_profile: native dispatch got {} args, wants 2", args.len()));
    }
    let wgrid = &args[0].data;
    let bgrid = &args[1].data;
    let (nw, nb) = (wgrid.len(), bgrid.len());
    if outs.len() != 9 {
        return Err(anyhow!("reg_profile: got {} output buffers, program writes 9", outs.len()));
    }
    for (i, o) in outs.iter().enumerate() {
        check_out("reg_profile", &format!("surface {i}"), o, &[nw, nb])?;
    }
    for norm in 0..3u32 {
        let base = norm as usize * 3;
        let (head, tail) = outs.split_at_mut(base + 1);
        let (d1s, d2s) = tail.split_at_mut(1);
        let r = &mut head[base].data;
        let d1 = &mut d1s[0].data;
        let d2 = &mut d2s[0].data;
        for (wi, &wv) in wgrid.iter().enumerate() {
            for (bi, &bv) in bgrid.iter().enumerate() {
                let (w, b) = (wv as f64, bv as f64);
                r[wi * nb + bi] = kn::reg_point(w, b, norm) as f32;
                d1[wi * nb + bi] = kn::reg_point_d1(w, b, norm) as f32;
                d2[wi * nb + bi] = kn::reg_point_d2(w, b, norm) as f32;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::buffer::{buffer_f32, scalar_f32};
    use crate::util::rng::Rng;

    fn dummy_train_args(backend: &NativeBackend, prog: &str) -> Vec<Buffer> {
        let manifest = backend.manifest();
        let sig = manifest.program(prog).unwrap();
        let mut rng = Rng::new(7);
        sig.inputs
            .iter()
            .map(|a| {
                if a.shape.is_empty() {
                    return scalar_f32(match a.name.as_str() {
                        "lr" => 0.01,
                        "mom" => 0.9,
                        "lr_beta" => 0.01,
                        "ka" => 16_777_215.0,
                        "lambda_w" => 0.1,
                        "lambda_beta" => 0.01,
                        "beta_train" => 1.0,
                        "denom" => 64.0,
                        _ => 0.5,
                    });
                }
                let n = a.elem_count();
                let data: Vec<f32> = match a.name.as_str() {
                    "beta" => vec![4.0; n],
                    "kw" => vec![7.0; n],
                    "y" => {
                        let classes = *a.shape.last().unwrap();
                        let mut v = vec![0.0; n];
                        for r in 0..a.shape[0] {
                            v[r * classes + r % classes] = 1.0;
                        }
                        v
                    }
                    name if name.starts_with("w:affine") && name.ends_with("_s") => vec![1.0; n],
                    name if name.starts_with("w:") => rng.normal_vec(n, 0.1),
                    _ => vec![0.0; n],
                };
                buffer_f32(&data, &a.shape).unwrap()
            })
            .collect()
    }

    #[test]
    fn every_native_program_executes_with_matching_arity() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        for (name, sig) in &manifest.programs {
            let args = dummy_train_args(&backend, name);
            let refs: Vec<&Buffer> = args.iter().collect();
            let outs = backend
                .execute(sig, &refs)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(outs.len(), sig.outputs.len(), "{name} output arity");
            if let Ok(i) = sig.output_index("loss") {
                let loss = outs[i].data[0];
                assert!(loss.is_finite(), "{name} loss not finite");
            }
        }
    }

    #[test]
    fn train_step_is_deterministic() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("train_waveq_mlp").unwrap();
        let args = dummy_train_args(&backend, "train_waveq_mlp");
        let refs: Vec<&Buffer> = args.iter().collect();
        let li = sig.output_index("loss").unwrap();
        let a = backend.execute(sig, &refs).unwrap()[li].data[0];
        let b = backend.execute(sig, &refs).unwrap()[li].data[0];
        assert_eq!(a, b, "same inputs must give bit-identical loss");
    }

    #[test]
    fn waveq_reg_term_raises_loss_over_ce() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        for prog in ["train_waveq_mlp", "train_waveq_simplenet5"] {
            let sig = manifest.program(prog).unwrap();
            let args = dummy_train_args(&backend, prog);
            let refs: Vec<&Buffer> = args.iter().collect();
            let outs = backend.execute(sig, &refs).unwrap();
            let loss = outs[sig.output_index("loss").unwrap()].data[0];
            let ce = outs[sig.output_index("ce").unwrap()].data[0];
            let reg = outs[sig.output_index("reg_w").unwrap()].data[0];
            assert!(reg > 0.0, "{prog}: random weights should not sit on the grid");
            assert!(loss > ce, "{prog}: loss must include the positive penalty terms");
        }
    }

    #[test]
    fn beta_moves_only_when_beta_train_set() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("train_waveq_mlp").unwrap();
        let bidx = sig.output_index("beta").unwrap();
        let bin = sig.input_index("beta").unwrap();
        let fin = sig.input_index("beta_train").unwrap();

        let mut args = dummy_train_args(&backend, "train_waveq_mlp");
        // Use a beta off the integer grid so dR/dbeta is generically nonzero.
        args[bin] = buffer_f32(&[3.7, 5.2], &[2]).unwrap();
        args[fin] = scalar_f32(0.0);
        let refs: Vec<&Buffer> = args.iter().collect();
        let frozen = backend.execute(sig, &refs).unwrap()[bidx].data.clone();
        assert_eq!(frozen, vec![3.7, 5.2], "beta must not move when gated off");

        args[fin] = scalar_f32(1.0);
        let refs: Vec<&Buffer> = args.iter().collect();
        let live = backend.execute(sig, &refs).unwrap()[bidx].data.clone();
        assert_ne!(live, vec![3.7, 5.2], "beta must move when training is enabled");
        for &b in &live {
            assert!((1.0..=8.0).contains(&b), "beta {b} escaped its clip range");
        }
    }

    #[test]
    fn conv_graph_gradients_match_finite_difference() {
        // End-to-end FD check through the op graph: fp32 simplenet5, one
        // small batch, perturb single weights of a conv, an affine scale,
        // and the head fc; the analytic parameter gradient (pre-clip) must
        // match (loss(w+h) - loss(w-h)) / 2h. fp32 keeps the graph smooth
        // (no quantizer staircase), so FD is trustworthy.
        let model = NativeModel::simplenet5(1);
        let batch = 4usize;
        let mut rng = Rng::new(11);
        let mut params_data: Vec<Vec<f32>> = model
            .params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                match p.kind.as_str() {
                    "affine" if p.name.ends_with("_s") => vec![1.0; n],
                    "affine" | "bias" => vec![0.0; n],
                    _ => rng.normal_vec(n, 0.2),
                }
            })
            .collect();
        let x: Vec<f32> = rng.normal_vec(batch * model.pixels(), 1.0);
        let mut y = vec![0.0f32; batch * model.num_classes];
        for r in 0..batch {
            y[r * model.num_classes + r % model.num_classes] = 1.0;
        }
        let loss_of = |params_data: &Vec<Vec<f32>>| -> f64 {
            let ps: Vec<&[f32]> = params_data.iter().map(|v| v.as_slice()).collect();
            let fwd = forward(&model, &ps, &x, batch, QuantFamily::Fp32, &[], &[], None, false);
            let (ce, _, _) = kn::softmax_ce(&fwd.logits, &y, batch, model.num_classes);
            ce as f64
        };
        let ps: Vec<&[f32]> = params_data.iter().map(|v| v.as_slice()).collect();
        let fwd = forward(&model, &ps, &x, batch, QuantFamily::Fp32, &[], &[], None, true);
        let (_, _, dl) = kn::softmax_ce(&fwd.logits, &y, batch, model.num_classes);
        let grads = backward(&model, &fwd, dl, batch, &ps, 0.0);
        drop(ps);
        // (param index, element) probes: conv2 weight, affine2 scale, fc1 w.
        let probes: Vec<(usize, usize)> = vec![
            (model.params.iter().position(|p| p.name == "conv2").unwrap(), 3),
            (model.params.iter().position(|p| p.name == "affine2_s").unwrap(), 1),
            (model.params.iter().position(|p| p.name == "fc1").unwrap(), 17),
            (model.params.iter().position(|p| p.name == "fc2_b").unwrap(), 0),
        ];
        let h = 1e-3f32;
        for (pi, ei) in probes {
            let orig = params_data[pi][ei];
            params_data[pi][ei] = orig + h;
            let lp = loss_of(&params_data);
            params_data[pi][ei] = orig - h;
            let lm = loss_of(&params_data);
            params_data[pi][ei] = orig;
            let fd = (lp - lm) / (2.0 * h as f64);
            let an = grads[pi][ei] as f64;
            assert!(
                (fd - an).abs() < 2e-3 * (1.0 + an.abs()),
                "param {pi} elem {ei}: fd={fd} an={an}"
            );
        }
    }

    #[test]
    fn residual_graph_gradients_match_finite_difference() {
        // Same FD check through resnet20l's skip/projection machinery.
        let model = NativeModel::resnet20l(1);
        let batch = 2usize;
        let mut rng = Rng::new(5);
        let mut params_data: Vec<Vec<f32>> = model
            .params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                match p.kind.as_str() {
                    "affine" if p.name.ends_with("_s") => vec![1.0; n],
                    "affine" | "bias" => vec![0.0; n],
                    _ => rng.normal_vec(n, 0.3),
                }
            })
            .collect();
        let x: Vec<f32> = rng.normal_vec(batch * model.pixels(), 1.0);
        let mut y = vec![0.0f32; batch * model.num_classes];
        for r in 0..batch {
            y[r * model.num_classes + r] = 1.0;
        }
        let loss_of = |params_data: &Vec<Vec<f32>>| -> f64 {
            let ps: Vec<&[f32]> = params_data.iter().map(|v| v.as_slice()).collect();
            let fwd = forward(&model, &ps, &x, batch, QuantFamily::Fp32, &[], &[], None, false);
            let (ce, _, _) = kn::softmax_ce(&fwd.logits, &y, batch, model.num_classes);
            ce as f64
        };
        let ps: Vec<&[f32]> = params_data.iter().map(|v| v.as_slice()).collect();
        let fwd = forward(&model, &ps, &x, batch, QuantFamily::Fp32, &[], &[], None, true);
        let (_, _, dl) = kn::softmax_ce(&fwd.logits, &y, batch, model.num_classes);
        let grads = backward(&model, &fwd, dl, batch, &ps, 0.0);
        drop(ps);
        // Probe the stem, a residual-body conv, and a projection conv.
        // conv4 = 2nd body conv of block 1; conv8 = projection of block 3
        // (blocks: conv2/conv3, conv4... stem=conv1; block1 body conv2,conv3;
        // block2 conv4,conv5; block3 body conv6,conv7 + proj conv8).
        let probes: Vec<(usize, usize)> = ["conv1", "conv3", "conv8", "fc1"]
            .iter()
            .map(|n| (model.params.iter().position(|p| &p.name == n).unwrap(), 2))
            .collect();
        let h = 1e-3f32;
        for (pi, ei) in probes {
            let orig = params_data[pi][ei];
            params_data[pi][ei] = orig + h;
            let lp = loss_of(&params_data);
            params_data[pi][ei] = orig - h;
            let lm = loss_of(&params_data);
            params_data[pi][ei] = orig;
            let fd = (lp - lm) / (2.0 * h as f64);
            let an = grads[pi][ei] as f64;
            assert!(
                (fd - an).abs() < 2e-3 * (1.0 + an.abs()),
                "param {} elem {ei}: fd={fd} an={an}",
                model.params[pi].name
            );
        }
    }

    #[test]
    fn reg_profile_surfaces_have_grid_zeros() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("reg_profile").unwrap();
        let w: Vec<f32> = (0..REG_PROFILE_NW)
            .map(|i| -1.25 + 2.5 * i as f32 / (REG_PROFILE_NW - 1) as f32)
            .collect();
        let b: Vec<f32> = (0..REG_PROFILE_NB)
            .map(|i| 1.0 + 7.0 * i as f32 / (REG_PROFILE_NB - 1) as f32)
            .collect();
        let args = vec![
            buffer_f32(&w, &[REG_PROFILE_NW]).unwrap(),
            buffer_f32(&b, &[REG_PROFILE_NB]).unwrap(),
        ];
        let refs: Vec<&Buffer> = args.iter().collect();
        let outs = backend.execute(sig, &refs).unwrap();
        assert_eq!(outs.len(), 9);
        // R_n1 is non-negative and bounded by its 1/2^beta envelope.
        let r1 = &outs[3];
        for (wi, _) in w.iter().enumerate() {
            for (bi, &bv) in b.iter().enumerate() {
                let v = r1.data[wi * REG_PROFILE_NB + bi];
                assert!(v >= 0.0, "R1 negative at ({wi},{bi})");
                assert!(v <= 2f32.powf(-bv) + 1e-6, "R1 above envelope at ({wi},{bi})");
            }
        }
        // All derivative surfaces are finite.
        for o in &outs {
            assert!(o.data.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn unknown_program_is_a_clean_error() {
        let backend = NativeBackend::new();
        let sig = ProgramSig {
            name: "train_waveq_resnet99".into(),
            file: "nope".into(),
            model: None,
            inputs: vec![],
            outputs: vec![],
        };
        let err = backend.execute(&sig, &[]).unwrap_err();
        assert!(format!("{err}").contains("no program"), "{err}");
    }

    #[test]
    fn arena_lease_reuses_reclaimed_allocations_and_zeroes_them() {
        let mut a = lease(1000);
        a[0] = 42.0;
        let ptr = a.as_ptr();
        reclaim(a);
        // Best-fit: a same-size lease must reuse the pooled allocation,
        // fully zeroed regardless of what the previous user wrote.
        let b = lease(1000);
        assert_eq!(b.as_ptr(), ptr, "lease did not reuse the reclaimed allocation");
        assert!(b.iter().all(|&v| v == 0.0), "leased buffer not zeroed");
        // A smaller request also fits in the same allocation.
        reclaim(b);
        let c = lease(10);
        assert_eq!(c.as_ptr(), ptr, "smaller lease did not best-fit the pooled buffer");
        assert_eq!(c.len(), 10);
        reclaim(c);
    }

    #[test]
    fn split_grads_apply_stages_reproduce_the_fused_train_step() {
        // The tentpole contract, stated smallest: running grads_* once per
        // chunk, reducing in chunk order, then apply_* once, must give the
        // exact bits of the fused train_* step — for every quant family.
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        for (train, grads_p, apply_p) in [
            ("train_fp32_mlp", "grads_fp32_mlp", "apply_fp32_mlp"),
            ("train_dorefa_mlp", "grads_dorefa_mlp", "apply_dorefa_mlp"),
            ("train_waveq_mlp", "grads_waveq_mlp", "apply_waveq_mlp"),
        ] {
            let tsig = manifest.program(train).unwrap();
            let gsig = manifest.program(grads_p).unwrap();
            let asig = manifest.program(apply_p).unwrap();
            let targs = dummy_train_args(&backend, train);
            let trefs: Vec<&Buffer> = targs.iter().collect();
            let fused = backend.execute(tsig, &trefs).unwrap();

            // Stage 1: one grads_* call per chunk, reduced in chunk order.
            let x = &targs[tsig.input_index("x").unwrap()];
            let y = &targs[tsig.input_index("y").unwrap()];
            let batch = x.shape[0];
            let pix: usize = x.shape[1..].iter().product();
            let nc = y.shape[1];
            let np = backend.model(&tsig.model.clone().unwrap()).unwrap().num_params();
            let mut reduced: Vec<Buffer> = (0..np)
                .map(|i| Buffer::zeros(tsig.inputs[i].shape.clone()))
                .collect();
            let (mut ce_sum, mut acc_cnt) = (0.0f32, 0.0f32);
            for chunk in 0..kn::GRAD_CHUNKS {
                let (lo, hi) = kn::chunk_rows(chunk, batch);
                if lo == hi {
                    continue;
                }
                let gargs: Vec<Buffer> = gsig
                    .inputs
                    .iter()
                    .map(|a| match a.name.as_str() {
                        "x" => buffer_f32(
                            &x.data[lo * pix..hi * pix],
                            &[hi - lo, x.shape[1], x.shape[2], x.shape[3]],
                        )
                        .unwrap(),
                        "y" => {
                            buffer_f32(&y.data[lo * nc..hi * nc], &[hi - lo, nc]).unwrap()
                        }
                        "denom" => scalar_f32(batch as f32),
                        name => {
                            let i = tsig.input_index(name).unwrap();
                            targs[i].clone()
                        }
                    })
                    .collect();
                let grefs: Vec<&Buffer> = gargs.iter().collect();
                let part = backend.execute(gsig, &grefs).unwrap();
                for i in 0..np {
                    kn::allreduce_fixed_order(&mut reduced[i].data, &[&part[i].data]);
                }
                ce_sum += part[np].data[0];
                acc_cnt += part[np + 1].data[0];
            }

            // Stage 2: one apply_* call on the reduced gradients.
            let aargs: Vec<Buffer> = asig
                .inputs
                .iter()
                .enumerate()
                .map(|(i, a)| match a.name.as_str() {
                    "ce_sum" => scalar_f32(ce_sum),
                    "acc_cnt" => scalar_f32(acc_cnt),
                    "denom" => scalar_f32(batch as f32),
                    name if name.starts_with("g:") => {
                        let first_g = asig
                            .inputs
                            .iter()
                            .position(|s| s.name.starts_with("g:"))
                            .unwrap();
                        reduced[i - first_g].clone()
                    }
                    name => {
                        let i = tsig.input_index(name).unwrap();
                        targs[i].clone()
                    }
                })
                .collect();
            let arefs: Vec<&Buffer> = aargs.iter().collect();
            let applied = backend.execute(asig, &arefs).unwrap();

            assert_eq!(fused.len(), applied.len(), "{train}: output arity mismatch");
            for (i, (f, a)) in fused.iter().zip(applied.iter()).enumerate() {
                let fb: Vec<u32> = f.data.iter().map(|v| v.to_bits()).collect();
                let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(fb, ab, "{train}: output {i} ({}) bits differ", tsig.outputs[i]);
            }
        }
    }
}
