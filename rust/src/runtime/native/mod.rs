//! The pure-Rust reference backend: executes the WaveQ program family
//! end-to-end on the host for the *entire* model zoo (mlp, simplenet5,
//! resnet20l, vgg11l, svhn8, alexnetl, resnet18l, mobilenetl — mirroring
//! `python/compile/models.py`), satisfying the same manifest signatures the
//! AOT HLO programs export (`python/compile/train_step.py`):
//!
//!   train_fp32_<m>    : [w*P, v*P, x, y, lr, mom]                 -> [w', v', loss, acc]
//!   train_dorefa_<m>  : [w*P, v*P, x, y, lr, mom, kw(Q,), ka]     -> [w', v', loss, acc]
//!   train_wrpn_<m>_w2 : same as dorefa, on the width-doubled model
//!   train_waveq_<m>   : [w*P, v*P, beta, vbeta, x, y, lr, mom,
//!                        lr_beta, ka, lam_w, lam_beta, beta_train] -> [w', v', beta', vbeta',
//!                                                                     loss, acc, ce, reg_w]
//!   eval_fp32_<m>     : [w*P, x, y]                               -> [loss, acc]
//!   eval_quant_<m>    : [w*P, x, y, kw(Q,), ka]                   -> [loss, acc]
//!   eval_wrpn_<m>_w2  : [w*P, x, y, kw(Q,), ka]                   -> [loss, acc]
//!   reg_profile       : [wgrid, bgrid]                            -> 9 x (n_w, n_b) surfaces
//!
//! Models are op graphs (`models::OpNode`): conv2d via im2col + the shared
//! blocked, multi-threaded matmul kernels (`kernels`/`pool`; worker count
//! from `WAVEQ_THREADS`, bitwise deterministic for any value), depthwise
//! conv, max/global-avg pooling, per-channel affine, residual add — each
//! with a hand-derived backward. The quantized
//! forward uses the DoReFa/WRPN rules of `kernels`, the backward is the
//! straight-through estimator, and the 'waveq' programs add the sinusoidal
//! regularizer `lambda_w * sin^2(pi v 2^beta)`-family term with its
//! *analytic* gradient in both w and beta — the heart of the paper,
//! executed here with no Python, XLA, or artifacts involved.
//!
//! The backend also exports its own [`Manifest`] so the coordinator
//! (trainer / evaluator / energy / pareto layers) runs identically on
//! either backend.

pub mod kernels;
pub mod models;
pub mod pool;

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use anyhow::{anyhow, Result};

use self::kernels as kn;
use self::models::{OpNode, WRPN_WIDTH, ZOO_NAMES};
pub use self::models::NativeModel;
use super::backend::{Backend, RuntimeStats};
use super::buffer::Buffer;
use super::manifest::{ArgSpec, Manifest, ProgramSig};

/// Which weight-quantizer family a program uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QuantFamily {
    Fp32,
    Dorefa,
    Wrpn,
    Waveq,
}

#[derive(Debug, Clone)]
enum ProgramKind {
    Train { model: String, quant: QuantFamily },
    Eval { model: String, quant: QuantFamily },
    RegProfile,
}

/// Grid sizes of the reg_profile surfaces (match `make_reg_profile`).
pub const REG_PROFILE_NW: usize = 512;
pub const REG_PROFILE_NB: usize = 256;

/// The hermetic pure-Rust execution backend.
pub struct NativeBackend {
    models: BTreeMap<String, NativeModel>,
    programs: BTreeMap<String, ProgramKind>,
    compiled: RefCell<BTreeSet<String>>,
    stats: RefCell<RuntimeStats>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        // Spawn the persistent kernel worker pool now (idempotent), so the
        // thread-creation cost never lands inside a timed train step.
        pool::ensure_started();
        let mut models = BTreeMap::new();
        let mut programs = BTreeMap::new();
        for base in ZOO_NAMES {
            let m = NativeModel::by_name(base, 1).expect("zoo name");
            let wide = NativeModel::by_name(base, WRPN_WIDTH).expect("zoo name");
            let wide_key = wide.name.clone();
            models.insert(m.name.clone(), m);
            models.insert(wide_key.clone(), wide);
            for (prog, quant) in [
                (format!("train_fp32_{base}"), QuantFamily::Fp32),
                (format!("train_dorefa_{base}"), QuantFamily::Dorefa),
                (format!("train_waveq_{base}"), QuantFamily::Waveq),
            ] {
                programs.insert(prog, ProgramKind::Train { model: base.to_string(), quant });
            }
            programs.insert(
                format!("train_wrpn_{base}_w{WRPN_WIDTH}"),
                ProgramKind::Train { model: wide_key.clone(), quant: QuantFamily::Wrpn },
            );
            programs.insert(
                format!("eval_fp32_{base}"),
                ProgramKind::Eval { model: base.to_string(), quant: QuantFamily::Fp32 },
            );
            programs.insert(
                format!("eval_quant_{base}"),
                ProgramKind::Eval { model: base.to_string(), quant: QuantFamily::Dorefa },
            );
            programs.insert(
                format!("eval_wrpn_{base}_w{WRPN_WIDTH}"),
                ProgramKind::Eval { model: wide_key, quant: QuantFamily::Wrpn },
            );
        }
        programs.insert("reg_profile".to_string(), ProgramKind::RegProfile);
        NativeBackend {
            models,
            programs,
            compiled: RefCell::new(BTreeSet::new()),
            stats: RefCell::new(RuntimeStats::default()),
        }
    }

    /// The manifest describing every native program and model — the same
    /// contract `python/compile/aot.py` writes for the AOT artifacts.
    pub fn manifest(&self) -> Manifest {
        let mut programs = BTreeMap::new();
        for (name, kind) in &self.programs {
            programs.insert(name.clone(), self.sig_for(name, kind));
        }
        let models = self.models.iter().map(|(k, m)| (k.clone(), m.meta())).collect();
        Manifest { programs, models }
    }

    fn sig_for(&self, name: &str, kind: &ProgramKind) -> ProgramSig {
        let scalar = |n: &str| ArgSpec { name: n.into(), shape: vec![], dtype: "float32".into() };
        let vec_q = |n: &str, q: usize| ArgSpec {
            name: n.into(),
            shape: vec![q],
            dtype: "float32".into(),
        };
        match kind {
            ProgramKind::RegProfile => ProgramSig {
                name: name.to_string(),
                file: format!("{name}.native"),
                model: None,
                inputs: vec![
                    vec_q("wgrid", REG_PROFILE_NW),
                    vec_q("bgrid", REG_PROFILE_NB),
                ],
                outputs: (0..3u32)
                    .flat_map(|n| ["r", "d1", "d2"].into_iter().map(move |q| format!("{q}_n{n}")))
                    .collect(),
            },
            ProgramKind::Train { model, quant } => {
                let m = &self.models[model];
                let q = m.num_qlayers();
                let x = ArgSpec {
                    name: "x".into(),
                    shape: vec![m.batch, m.input_shape[0], m.input_shape[1], m.input_shape[2]],
                    dtype: "float32".into(),
                };
                let y = ArgSpec {
                    name: "y".into(),
                    shape: vec![m.batch, m.num_classes],
                    dtype: "float32".into(),
                };
                let mut inputs = m.param_specs("w");
                inputs.extend(m.param_specs("v"));
                let mut outputs = m.param_names("w");
                outputs.extend(m.param_names("v"));
                match quant {
                    QuantFamily::Fp32 => {
                        inputs.extend([x, y, scalar("lr"), scalar("mom")]);
                        outputs.extend(["loss".into(), "acc".into()]);
                    }
                    QuantFamily::Dorefa | QuantFamily::Wrpn => {
                        let kw = vec_q("kw", q);
                        inputs.extend([x, y, scalar("lr"), scalar("mom"), kw, scalar("ka")]);
                        outputs.extend(["loss".into(), "acc".into()]);
                    }
                    QuantFamily::Waveq => {
                        inputs.extend([vec_q("beta", q), vec_q("vbeta", q), x, y]);
                        inputs.extend([
                            scalar("lr"),
                            scalar("mom"),
                            scalar("lr_beta"),
                            scalar("ka"),
                            scalar("lambda_w"),
                            scalar("lambda_beta"),
                            scalar("beta_train"),
                        ]);
                        outputs.extend([
                            "beta".into(),
                            "vbeta".into(),
                            "loss".into(),
                            "acc".into(),
                            "ce".into(),
                            "reg_w".into(),
                        ]);
                    }
                }
                ProgramSig {
                    name: name.to_string(),
                    file: format!("{name}.native"),
                    model: Some(model.clone()),
                    inputs,
                    outputs,
                }
            }
            ProgramKind::Eval { model, quant } => {
                let m = &self.models[model];
                let q = m.num_qlayers();
                let x = ArgSpec {
                    name: "x".into(),
                    shape: vec![m.batch, m.input_shape[0], m.input_shape[1], m.input_shape[2]],
                    dtype: "float32".into(),
                };
                let y = ArgSpec {
                    name: "y".into(),
                    shape: vec![m.batch, m.num_classes],
                    dtype: "float32".into(),
                };
                let mut inputs = m.param_specs("w");
                inputs.extend([x, y]);
                if *quant != QuantFamily::Fp32 {
                    inputs.extend([vec_q("kw", q), scalar("ka")]);
                }
                ProgramSig {
                    name: name.to_string(),
                    file: format!("{name}.native"),
                    model: Some(model.clone()),
                    inputs,
                    outputs: vec!["loss".into(), "acc".into()],
                }
            }
        }
    }

    fn model(&self, key: &str) -> Result<&NativeModel> {
        self.models
            .get(key)
            .ok_or_else(|| anyhow!("native backend has no model '{key}'"))
    }

    fn kind_of(&self, name: &str) -> Result<ProgramKind> {
        self.programs
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("native backend has no program '{name}'"))
    }

    /// Correctly-shaped zero buffers for a program's outputs — the
    /// allocating `execute` path; `execute_into` writes into caller-owned
    /// buffers instead.
    fn output_template(&self, kind: &ProgramKind, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        Ok(match kind {
            ProgramKind::RegProfile => {
                if args.len() != 2 {
                    return Err(anyhow!(
                        "reg_profile: native dispatch got {} args, wants 2",
                        args.len()
                    ));
                }
                let (nw, nb) = (args[0].elem_count(), args[1].elem_count());
                (0..9).map(|_| Buffer::zeros(vec![nw, nb])).collect()
            }
            ProgramKind::Train { model, quant } => {
                let m = self.model(model)?;
                let nq = m.num_qlayers();
                let mut outs: Vec<Buffer> = Vec::with_capacity(2 * m.num_params() + 8);
                for _ in 0..2 {
                    outs.extend(m.params.iter().map(|p| Buffer::zeros(p.shape.clone())));
                }
                if *quant == QuantFamily::Waveq {
                    outs.push(Buffer::zeros(vec![nq]));
                    outs.push(Buffer::zeros(vec![nq]));
                }
                outs.push(Buffer::scalar(0.0));
                outs.push(Buffer::scalar(0.0));
                if *quant == QuantFamily::Waveq {
                    outs.push(Buffer::scalar(0.0));
                    outs.push(Buffer::scalar(0.0));
                }
                outs
            }
            ProgramKind::Eval { .. } => vec![Buffer::scalar(0.0), Buffer::scalar(0.0)],
        })
    }

    fn dispatch(
        &self,
        sig: &ProgramSig,
        kind: &ProgramKind,
        args: &[&Buffer],
        outs: &mut [Buffer],
    ) -> Result<()> {
        self.compile(sig)?;
        let t0 = Instant::now();
        let result = match kind {
            ProgramKind::RegProfile => run_reg_profile_into(args, outs),
            ProgramKind::Train { model, quant } => {
                run_train_into(&sig.name, self.model(model)?, *quant, args, outs)
            }
            ProgramKind::Eval { model, quant } => {
                run_eval_into(&sig.name, self.model(model)?, *quant, args, outs)
            }
        };
        self.stats
            .borrow_mut()
            .record_execute(&sig.name, t0.elapsed().as_secs_f64());
        result
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn platform_name(&self) -> String {
        "native".to_string()
    }

    fn compile(&self, sig: &ProgramSig) -> Result<()> {
        if !self.programs.contains_key(&sig.name) {
            return Err(anyhow!("native backend has no program '{}'", sig.name));
        }
        if self.compiled.borrow_mut().insert(sig.name.clone()) {
            self.stats.borrow_mut().compiles += 1;
        }
        Ok(())
    }

    /// The native executor resolves the batch from the x/y buffer lengths
    /// (`batch_of`), so any batch size dispatches through one program.
    fn batch_polymorphic(&self) -> bool {
        true
    }

    fn execute(&self, sig: &ProgramSig, args: &[&Buffer]) -> Result<Vec<Buffer>> {
        let kind = self.kind_of(&sig.name)?;
        let mut outs = self.output_template(&kind, args)?;
        self.dispatch(sig, &kind, args, &mut outs)?;
        Ok(outs)
    }

    /// Write results into caller-owned buffers: parameters/velocities are
    /// updated in place in the output storage (copy state, apply SGD on the
    /// destination), scalars overwrite their slot — no output allocation.
    fn execute_into(&self, sig: &ProgramSig, args: &[&Buffer], outs: &mut [Buffer]) -> Result<()> {
        let kind = self.kind_of(&sig.name)?;
        self.dispatch(sig, &kind, args, outs)
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}

// ---- program implementations ------------------------------------------------

/// Per-parameter quantization state captured during the forward pass.
struct LayerQuant {
    /// Effective (possibly fake-quantized) weight used in the op.
    wq: Vec<f32>,
    /// STE factor dwq/dw per element; None = identity.
    ste: Option<Vec<f32>>,
    /// WaveQ only: (normalized coords v, scale m, beta_q) of this weight.
    waveq: Option<(Vec<f32>, f32, f64)>,
}

fn param_slices<'a>(
    prog: &str,
    model: &NativeModel,
    args: &'a [&Buffer],
    offset: usize,
) -> Result<Vec<&'a [f32]>> {
    let mut out = Vec::with_capacity(model.num_params());
    for (i, p) in model.params.iter().enumerate() {
        let b = args[offset + i];
        let want: usize = p.shape.iter().product();
        if b.elem_count() != want {
            return Err(anyhow!(
                "{prog}: param {} has {} elems, expected {:?}",
                p.name,
                b.elem_count(),
                p.shape
            ));
        }
        out.push(b.data.as_slice());
    }
    Ok(out)
}

/// Resolve batch size from the x/y buffers and validate consistency.
fn batch_of(prog: &str, model: &NativeModel, x: &Buffer, y: &Buffer) -> Result<usize> {
    let pix = model.pixels();
    if x.elem_count() == 0 || !x.elem_count().is_multiple_of(pix) {
        return Err(anyhow!(
            "{prog}: x has {} elems, not a multiple of {} ({}x{}x{})",
            x.elem_count(),
            pix,
            model.input_shape[0],
            model.input_shape[1],
            model.input_shape[2]
        ));
    }
    let batch = x.elem_count() / pix;
    if y.elem_count() != batch * model.num_classes {
        return Err(anyhow!(
            "{prog}: y has {} elems, expected {} x {}",
            y.elem_count(),
            batch,
            model.num_classes
        ));
    }
    Ok(batch)
}

fn scalar_arg(prog: &str, name: &str, b: &Buffer) -> Result<f32> {
    b.data
        .first()
        .copied()
        .ok_or_else(|| anyhow!("{prog}: scalar input '{name}' is empty"))
}

fn kw_arg(prog: &str, model: &NativeModel, b: &Buffer) -> Result<Vec<f32>> {
    if b.elem_count() != model.num_qlayers() {
        return Err(anyhow!(
            "{prog}: kw has {} entries, model wants {}",
            b.elem_count(),
            model.num_qlayers()
        ));
    }
    Ok(b.data.clone())
}

/// Quantize one parameter tensor for the forward pass.
fn quantize_param(
    qidx: Option<usize>,
    w: &[f32],
    quant: QuantFamily,
    kw: &[f32],
    beta: &[f32],
) -> LayerQuant {
    match (quant, qidx) {
        (QuantFamily::Fp32, _) | (_, None) => {
            LayerQuant { wq: w.to_vec(), ste: None, waveq: None }
        }
        (QuantFamily::Dorefa, Some(q)) => {
            let (wq, ste, _m) = kn::dorefa_quantize(w, kw[q]);
            LayerQuant { wq, ste: Some(ste), waveq: None }
        }
        (QuantFamily::Wrpn, Some(q)) => {
            let (wq, _m) = kn::wrpn_quantize(w, kw[q]);
            LayerQuant { wq, ste: None, waveq: None }
        }
        (QuantFamily::Waveq, Some(q)) => {
            let b = beta[q] as f64;
            let k = (2f64.powf(b) - 1.0) as f32;
            let (wq, ste, v, m) = kn::dorefa_quantize_full(w, k);
            LayerQuant { wq, ste: Some(ste), waveq: Some((v, m, b)) }
        }
    }
}

/// Per-op forward residuals: exactly what the matching backward needs.
enum Trace {
    None,
    Conv { cols: Vec<f32>, lq: LayerQuant },
    DwConv { input: Vec<f32>, lq: LayerQuant },
    Fc { input: Vec<f32>, lq: LayerQuant },
    Affine { input: Vec<f32> },
    Relu { mask: Vec<f32> },
    MaxPool { argmax: Vec<u32>, in_len: usize },
    Gap,
    SkipProj { cols: Vec<f32>, lq: LayerQuant },
    SkipAdd { mask: Vec<f32> },
}

impl Trace {
    fn quant(&self) -> Option<&LayerQuant> {
        match self {
            Trace::Conv { lq, .. }
            | Trace::DwConv { lq, .. }
            | Trace::Fc { lq, .. }
            | Trace::SkipProj { lq, .. } => Some(lq),
            _ => None,
        }
    }
}

struct GraphForward {
    /// One trace per op, in op order.
    traces: Vec<Trace>,
    logits: Vec<f32>,
}

/// ReLU in place, recording the mask when a backward pass will need it,
/// then optional activation fake-quant (`act_ka = None` means fp32
/// activations). Returns an empty mask when `record` is off.
/// `pub(crate)` so `runtime::infer`'s forward-only path runs the *same*
/// code (bitwise, including -0.0 handling) as this backend's eval pass.
pub(crate) fn relu_quant(h: &mut [f32], act_ka: Option<f32>, record: bool) -> Vec<f32> {
    let mut mask = if record { vec![0.0f32; h.len()] } else { Vec::new() };
    if record {
        for (zi, mi) in h.iter_mut().zip(mask.iter_mut()) {
            if *zi > 0.0 {
                *mi = 1.0;
            } else {
                *zi = 0.0;
            }
        }
    } else {
        for zi in h.iter_mut() {
            if *zi < 0.0 {
                *zi = 0.0;
            }
        }
    }
    if let Some(ka) = act_ka {
        kn::act_quantize(h, ka);
    }
    mask
}

/// Run the op graph forward. With `record` set, a tape of per-op residuals
/// is kept for [`backward`]; eval-only callers pass `false` so the cols /
/// mask / input buffers are dropped as soon as each op completes (peak
/// memory stays at the live activation, not the sum over layers).
fn forward(
    model: &NativeModel,
    params: &[&[f32]],
    x: &[f32],
    batch: usize,
    quant: QuantFamily,
    kw: &[f32],
    beta: &[f32],
    act_ka: Option<f32>,
    record: bool,
) -> GraphForward {
    let mut h = x.to_vec();
    let mut traces: Vec<Trace> = Vec::with_capacity(model.ops.len());
    // Saved activations of open residual blocks (innermost last).
    let mut skips: Vec<Vec<f32>> = Vec::new();
    // Projected shortcut pending its SkipAdd.
    let mut shortcut: Option<Vec<f32>> = None;
    for op in &model.ops {
        match op {
            OpNode::Conv { geom, pidx } => {
                let lq = quantize_param(model.params[*pidx].qidx, params[*pidx], quant, kw, beta);
                if geom.depthwise {
                    let out = kn::dwconv_fwd(&h, &lq.wq, batch, geom);
                    let input = std::mem::replace(&mut h, out);
                    traces.push(if record { Trace::DwConv { input, lq } } else { Trace::None });
                } else {
                    let cols = kn::im2col(&h, batch, geom);
                    h = kn::matmul(&cols, &lq.wq, geom.rows(batch), geom.kdim(), geom.cout);
                    traces.push(if record { Trace::Conv { cols, lq } } else { Trace::None });
                }
            }
            OpNode::Fc { din, dout, widx, bidx } => {
                let lq = quantize_param(model.params[*widx].qidx, params[*widx], quant, kw, beta);
                let out = kn::matmul_bias(&h, &lq.wq, params[*bidx], batch, *din, *dout);
                let input = std::mem::replace(&mut h, out);
                traces.push(if record { Trace::Fc { input, lq } } else { Trace::None });
            }
            OpNode::Affine { c, hw, sidx, bidx } => {
                let out = kn::affine_fwd(&h, params[*sidx], params[*bidx], batch * hw, *c);
                let input = std::mem::replace(&mut h, out);
                traces.push(if record { Trace::Affine { input } } else { Trace::None });
            }
            OpNode::Relu => {
                let mask = relu_quant(&mut h, act_ka, record);
                traces.push(if record { Trace::Relu { mask } } else { Trace::None });
            }
            OpNode::MaxPool { h: ph, w: pw, c, size } => {
                let in_len = h.len();
                let (out, argmax) = kn::maxpool_fwd(&h, batch, *ph, *pw, *c, *size);
                h = out;
                traces.push(if record { Trace::MaxPool { argmax, in_len } } else { Trace::None });
            }
            OpNode::GlobalAvgPool { h: ph, w: pw, c } => {
                h = kn::gap_fwd(&h, batch, *ph, *pw, *c);
                traces.push(Trace::Gap);
            }
            OpNode::Flatten => traces.push(Trace::None),
            OpNode::SkipSave => {
                skips.push(h.clone());
                traces.push(Trace::None);
            }
            OpNode::SkipProj { geom, pidx } => {
                let saved = skips.last().expect("SkipProj without SkipSave");
                let lq = quantize_param(model.params[*pidx].qidx, params[*pidx], quant, kw, beta);
                let cols = kn::im2col(saved, batch, geom);
                shortcut =
                    Some(kn::matmul(&cols, &lq.wq, geom.rows(batch), geom.kdim(), geom.cout));
                traces.push(if record { Trace::SkipProj { cols, lq } } else { Trace::None });
            }
            OpNode::SkipAdd => {
                let saved = skips.pop().expect("SkipAdd without SkipSave");
                let sc = shortcut.take().unwrap_or(saved);
                debug_assert_eq!(h.len(), sc.len());
                for (hv, &sv) in h.iter_mut().zip(sc.iter()) {
                    *hv += sv;
                }
                let mask = relu_quant(&mut h, act_ka, record);
                traces.push(if record { Trace::SkipAdd { mask } } else { Trace::None });
            }
        }
    }
    GraphForward { traces, logits: h }
}

/// STE backward through a quantized weight + the WaveQ regularizer's
/// analytic w-gradient, chained v -> w through the tanh normalization
/// (per-layer max treated as constant, like the STE).
fn apply_quant_grad(dw: &mut [f32], lq: &LayerQuant, lam_w: f32) {
    if let Some(ste) = &lq.ste {
        for (g, &s) in dw.iter_mut().zip(ste.iter()) {
            *g *= s;
        }
    }
    if lam_w != 0.0 {
        if let Some((v, m, b)) = &lq.waveq {
            let gv = kn::waveq_reg_grad_v(v, *b);
            let ste = lq.ste.as_ref().expect("waveq layers carry an STE");
            for ((g, &gvj), &s) in dw.iter_mut().zip(gv.iter()).zip(ste.iter()) {
                *g += lam_w * gvj * s / (2.0 * m);
            }
        }
    }
}

/// Reverse sweep over the op graph: one gradient tensor per parameter.
fn backward(
    model: &NativeModel,
    fwd: &GraphForward,
    dlogits: Vec<f32>,
    batch: usize,
    params: &[&[f32]],
    lam_w: f32,
) -> Vec<Vec<f32>> {
    // Empty placeholders only: every parameter belongs to exactly one op,
    // so the reverse sweep assigns each slot exactly once (asserted below).
    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); model.params.len()];
    let mut dh = dlogits;
    // Gradients flowing to shortcut branches of open residual blocks.
    let mut skip_grads: Vec<Vec<f32>> = Vec::new();
    for (op, tr) in model.ops.iter().zip(fwd.traces.iter()).rev() {
        match (op, tr) {
            (OpNode::Fc { din, dout, widx, bidx }, Trace::Fc { input, lq }) => {
                let mut dw = kn::grad_weight(input, &dh, batch, *din, *dout);
                let db = kn::grad_bias(&dh, batch, *dout);
                apply_quant_grad(&mut dw, lq, lam_w);
                grads[*widx] = dw;
                grads[*bidx] = db;
                dh = kn::grad_input(&dh, &lq.wq, batch, *din, *dout);
            }
            (OpNode::Conv { geom, pidx }, Trace::Conv { cols, lq }) => {
                let (rows, kdim) = (geom.rows(batch), geom.kdim());
                let mut dw = kn::grad_weight(cols, &dh, rows, kdim, geom.cout);
                apply_quant_grad(&mut dw, lq, lam_w);
                grads[*pidx] = dw;
                let dcols = kn::grad_input(&dh, &lq.wq, rows, kdim, geom.cout);
                dh = kn::col2im(&dcols, batch, geom);
            }
            (OpNode::Conv { geom, pidx }, Trace::DwConv { input, lq }) => {
                let mut dw = kn::dwconv_grad_w(input, &dh, batch, geom);
                apply_quant_grad(&mut dw, lq, lam_w);
                grads[*pidx] = dw;
                dh = kn::dwconv_grad_x(&dh, &lq.wq, batch, geom);
            }
            (OpNode::Affine { c, hw, sidx, bidx }, Trace::Affine { input }) => {
                let (dx, ds, db) = kn::affine_bwd(input, &dh, params[*sidx], batch * hw, *c);
                grads[*sidx] = ds;
                grads[*bidx] = db;
                dh = dx;
            }
            (OpNode::Relu, Trace::Relu { mask }) => {
                for (g, &m) in dh.iter_mut().zip(mask.iter()) {
                    *g *= m;
                }
            }
            (OpNode::MaxPool { .. }, Trace::MaxPool { argmax, in_len }) => {
                dh = kn::maxpool_bwd(&dh, argmax, *in_len);
            }
            (OpNode::GlobalAvgPool { h, w, c }, Trace::Gap) => {
                dh = kn::gap_bwd(&dh, batch, *h, *w, *c);
            }
            (OpNode::Flatten, Trace::None) => {}
            (OpNode::SkipAdd, Trace::SkipAdd { mask }) => {
                for (g, &m) in dh.iter_mut().zip(mask.iter()) {
                    *g *= m;
                }
                // The post-mask gradient feeds both the body (dh continues)
                // and the shortcut (pushed for SkipProj/SkipSave).
                skip_grads.push(dh.clone());
            }
            (OpNode::SkipProj { geom, pidx }, Trace::SkipProj { cols, lq }) => {
                let g = skip_grads.pop().expect("SkipProj without SkipAdd gradient");
                let (rows, kdim) = (geom.rows(batch), geom.kdim());
                let mut dw = kn::grad_weight(cols, &g, rows, kdim, geom.cout);
                apply_quant_grad(&mut dw, lq, lam_w);
                grads[*pidx] = dw;
                let dcols = kn::grad_input(&g, &lq.wq, rows, kdim, geom.cout);
                skip_grads.push(kn::col2im(&dcols, batch, geom));
            }
            (OpNode::SkipSave, Trace::None) => {
                let g = skip_grads.pop().expect("SkipSave without skip gradient");
                debug_assert_eq!(dh.len(), g.len());
                for (a, &b) in dh.iter_mut().zip(g.iter()) {
                    *a += b;
                }
            }
            _ => unreachable!("op/trace mismatch in native backward"),
        }
    }
    debug_assert!(
        grads
            .iter()
            .zip(&model.params)
            .all(|(g, p)| g.len() == p.shape.iter().product::<usize>()),
        "native backward left a parameter gradient unassigned"
    );
    grads
}

/// Validate one caller-owned output buffer against the shape the program
/// writes (the `execute_into` contract).
fn check_out(prog: &str, name: &str, out: &Buffer, shape: &[usize]) -> Result<()> {
    if out.shape.as_slice() != shape {
        return Err(anyhow!(
            "{prog}: output '{name}' buffer has shape {:?}, program writes {:?}",
            out.shape,
            shape
        ));
    }
    Ok(())
}

fn run_eval_into(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    args: &[&Buffer],
    outs: &mut [Buffer],
) -> Result<()> {
    let np = model.num_params();
    let expected = np + 2 + if quant == QuantFamily::Fp32 { 0 } else { 2 };
    if args.len() != expected {
        return Err(anyhow!("{prog}: native dispatch got {} args, wants {expected}", args.len()));
    }
    if outs.len() != 2 {
        return Err(anyhow!("{prog}: got {} output buffers, program writes 2", outs.len()));
    }
    check_out(prog, "loss", &outs[0], &[])?;
    check_out(prog, "acc", &outs[1], &[])?;
    let params = param_slices(prog, model, args, 0)?;
    let x = args[np];
    let y = args[np + 1];
    let batch = batch_of(prog, model, x, y)?;
    let (kw, act_ka) = if quant == QuantFamily::Fp32 {
        (Vec::new(), None)
    } else {
        (kw_arg(prog, model, args[np + 2])?, Some(scalar_arg(prog, "ka", args[np + 3])?))
    };
    let fwd = forward(model, &params, &x.data, batch, quant, &kw, &[], act_ka, false);
    let (loss, acc, _dl) = kn::softmax_ce(&fwd.logits, &y.data, batch, model.num_classes);
    outs[0].data[0] = loss;
    outs[1].data[0] = acc;
    Ok(())
}

fn run_train_into(
    prog: &str,
    model: &NativeModel,
    quant: QuantFamily,
    args: &[&Buffer],
    outs: &mut [Buffer],
) -> Result<()> {
    let np = model.num_params();
    let nq = model.num_qlayers();
    let expected = 2 * np
        + match quant {
            QuantFamily::Fp32 => 4,                      // x, y, lr, mom
            QuantFamily::Dorefa | QuantFamily::Wrpn => 6, // + kw, ka
            QuantFamily::Waveq => 11, // beta, vbeta, x, y + 7 scalars
        };
    if args.len() != expected {
        return Err(anyhow!("{prog}: native dispatch got {} args, wants {expected}", args.len()));
    }
    let n_scalars = if quant == QuantFamily::Waveq { 4 } else { 2 };
    let n_beta = if quant == QuantFamily::Waveq { 2 } else { 0 };
    let expected_outs = 2 * np + n_beta + n_scalars;
    if outs.len() != expected_outs {
        return Err(anyhow!(
            "{prog}: got {} output buffers, program writes {expected_outs}",
            outs.len()
        ));
    }
    for (i, p) in model.params.iter().enumerate() {
        check_out(prog, &p.name, &outs[i], &p.shape)?;
        check_out(prog, &p.name, &outs[np + i], &p.shape)?;
    }
    if quant == QuantFamily::Waveq {
        check_out(prog, "beta", &outs[2 * np], &[nq])?;
        check_out(prog, "vbeta", &outs[2 * np + 1], &[nq])?;
    }
    for i in 0..n_scalars {
        check_out(prog, "scalar", &outs[2 * np + n_beta + i], &[])?;
    }
    let params = param_slices(prog, model, args, 0)?;
    let vels = param_slices(prog, model, args, np)?;

    // Tail inputs, positionally after [w*, v*] (train_step.py layouts).
    // Tuple order: (beta_in, vbeta_in, x, y, lr, mom, lr_beta, ka, lam_w,
    // lam_beta, beta_train) — families without a field bind its neutral
    // value (empty beta vecs, lr_beta/lambdas 0, ka None).
    let tail = &args[2 * np..];
    let (beta_in, vbeta_in, x, y, lr, mom, lr_beta, ka, lam_w, lam_beta, beta_train) = match quant
    {
        QuantFamily::Fp32 => (
            Vec::new(),
            Vec::new(),
            tail[0],
            tail[1],
            scalar_arg(prog, "lr", tail[2])?,
            scalar_arg(prog, "mom", tail[3])?,
            0.0,
            None,
            0.0,
            0.0,
            0.0,
        ),
        QuantFamily::Dorefa | QuantFamily::Wrpn => (
            Vec::new(),
            Vec::new(),
            tail[0],
            tail[1],
            scalar_arg(prog, "lr", tail[2])?,
            scalar_arg(prog, "mom", tail[3])?,
            0.0,
            Some(scalar_arg(prog, "ka", tail[5])?),
            0.0,
            0.0,
            0.0,
        ),
        QuantFamily::Waveq => {
            if tail[0].elem_count() != nq || tail[1].elem_count() != nq {
                return Err(anyhow!(
                    "{prog}: beta/vbeta have {}/{} entries, model wants {nq}",
                    tail[0].elem_count(),
                    tail[1].elem_count()
                ));
            }
            (
                tail[0].data.clone(),
                tail[1].data.clone(),
                tail[2],
                tail[3],
                scalar_arg(prog, "lr", tail[4])?,
                scalar_arg(prog, "mom", tail[5])?,
                scalar_arg(prog, "lr_beta", tail[6])?,
                Some(scalar_arg(prog, "ka", tail[7])?),
                scalar_arg(prog, "lambda_w", tail[8])?,
                scalar_arg(prog, "lambda_beta", tail[9])?,
                scalar_arg(prog, "beta_train", tail[10])?,
            )
        }
    };
    let kw = match quant {
        QuantFamily::Dorefa | QuantFamily::Wrpn => kw_arg(prog, model, tail[4])?,
        _ => Vec::new(),
    };
    let batch = batch_of(prog, model, x, y)?;

    // ---- forward ---------------------------------------------------------
    let fwd = forward(model, &params, &x.data, batch, quant, &kw, &beta_in, ka, true);
    let (ce, acc, dlogits) = kn::softmax_ce(&fwd.logits, &y.data, batch, model.num_classes);

    // ---- regularizer (waveq only) ---------------------------------------
    let mut reg_w = 0.0f64;
    let mut dreg_dbeta = vec![0.0f64; nq];
    if quant == QuantFamily::Waveq {
        for tr in &fwd.traces {
            if let Some(lq) = tr.quant() {
                if let Some((v, _m, b)) = &lq.waveq {
                    reg_w += kn::waveq_reg(v, *b);
                }
            }
        }
        for (op, tr) in model.ops.iter().zip(fwd.traces.iter()) {
            let pidx = match op {
                OpNode::Conv { pidx, .. } | OpNode::SkipProj { pidx, .. } => *pidx,
                OpNode::Fc { widx, .. } => *widx,
                _ => continue,
            };
            if let (Some(q), Some(lq)) = (model.params[pidx].qidx, tr.quant()) {
                if let Some((v, _m, b)) = &lq.waveq {
                    dreg_dbeta[q] = kn::waveq_reg_grad_beta(v, *b);
                }
            }
        }
    }
    let loss = ce + lam_w * reg_w as f32 + lam_beta * beta_in.iter().sum::<f32>();

    // ---- backward --------------------------------------------------------
    let mut grads = backward(model, &fwd, dlogits, batch, &params, lam_w);

    // ---- updates (into the caller-owned output buffers) ------------------
    kn::clip_by_global_norm(&mut grads, kn::GRAD_CLIP_NORM);
    let (pouts, rest) = outs.split_at_mut(np);
    let (vouts, tail_outs) = rest.split_at_mut(np);
    for i in 0..np {
        pouts[i].data.copy_from_slice(params[i]);
        vouts[i].data.copy_from_slice(vels[i]);
        kn::sgd_momentum_step(&mut pouts[i].data, &mut vouts[i].data, &grads[i], lr, mom);
    }

    if quant == QuantFamily::Waveq {
        for q in 0..nq {
            let gb = (lam_w as f64 * dreg_dbeta[q] + lam_beta as f64) as f32 * beta_train;
            let nv = mom * vbeta_in[q] + gb;
            tail_outs[1].data[q] = nv;
            tail_outs[0].data[q] = kn::clip_beta(beta_in[q] - lr_beta * nv);
        }
    }
    let si = if quant == QuantFamily::Waveq { 2 } else { 0 };
    tail_outs[si].data[0] = loss;
    tail_outs[si + 1].data[0] = acc;
    if quant == QuantFamily::Waveq {
        tail_outs[si + 2].data[0] = ce;
        tail_outs[si + 3].data[0] = reg_w as f32;
    }
    Ok(())
}

fn run_reg_profile_into(args: &[&Buffer], outs: &mut [Buffer]) -> Result<()> {
    if args.len() != 2 {
        return Err(anyhow!("reg_profile: native dispatch got {} args, wants 2", args.len()));
    }
    let wgrid = &args[0].data;
    let bgrid = &args[1].data;
    let (nw, nb) = (wgrid.len(), bgrid.len());
    if outs.len() != 9 {
        return Err(anyhow!("reg_profile: got {} output buffers, program writes 9", outs.len()));
    }
    for (i, o) in outs.iter().enumerate() {
        check_out("reg_profile", &format!("surface {i}"), o, &[nw, nb])?;
    }
    for norm in 0..3u32 {
        let base = norm as usize * 3;
        let (head, tail) = outs.split_at_mut(base + 1);
        let (d1s, d2s) = tail.split_at_mut(1);
        let r = &mut head[base].data;
        let d1 = &mut d1s[0].data;
        let d2 = &mut d2s[0].data;
        for (wi, &wv) in wgrid.iter().enumerate() {
            for (bi, &bv) in bgrid.iter().enumerate() {
                let (w, b) = (wv as f64, bv as f64);
                r[wi * nb + bi] = kn::reg_point(w, b, norm) as f32;
                d1[wi * nb + bi] = kn::reg_point_d1(w, b, norm) as f32;
                d2[wi * nb + bi] = kn::reg_point_d2(w, b, norm) as f32;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::buffer::{buffer_f32, scalar_f32};
    use crate::util::rng::Rng;

    fn dummy_train_args(backend: &NativeBackend, prog: &str) -> Vec<Buffer> {
        let manifest = backend.manifest();
        let sig = manifest.program(prog).unwrap();
        let mut rng = Rng::new(7);
        sig.inputs
            .iter()
            .map(|a| {
                if a.shape.is_empty() {
                    return scalar_f32(match a.name.as_str() {
                        "lr" => 0.01,
                        "mom" => 0.9,
                        "lr_beta" => 0.01,
                        "ka" => 16_777_215.0,
                        "lambda_w" => 0.1,
                        "lambda_beta" => 0.01,
                        "beta_train" => 1.0,
                        _ => 0.5,
                    });
                }
                let n = a.elem_count();
                let data: Vec<f32> = match a.name.as_str() {
                    "beta" => vec![4.0; n],
                    "kw" => vec![7.0; n],
                    "y" => {
                        let classes = *a.shape.last().unwrap();
                        let mut v = vec![0.0; n];
                        for r in 0..a.shape[0] {
                            v[r * classes + r % classes] = 1.0;
                        }
                        v
                    }
                    name if name.starts_with("w:affine") && name.ends_with("_s") => vec![1.0; n],
                    name if name.starts_with("w:") => rng.normal_vec(n, 0.1),
                    _ => vec![0.0; n],
                };
                buffer_f32(&data, &a.shape).unwrap()
            })
            .collect()
    }

    #[test]
    fn every_native_program_executes_with_matching_arity() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        for (name, sig) in &manifest.programs {
            let args = dummy_train_args(&backend, name);
            let refs: Vec<&Buffer> = args.iter().collect();
            let outs = backend
                .execute(sig, &refs)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(outs.len(), sig.outputs.len(), "{name} output arity");
            if let Ok(i) = sig.output_index("loss") {
                let loss = outs[i].data[0];
                assert!(loss.is_finite(), "{name} loss not finite");
            }
        }
    }

    #[test]
    fn train_step_is_deterministic() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("train_waveq_mlp").unwrap();
        let args = dummy_train_args(&backend, "train_waveq_mlp");
        let refs: Vec<&Buffer> = args.iter().collect();
        let li = sig.output_index("loss").unwrap();
        let a = backend.execute(sig, &refs).unwrap()[li].data[0];
        let b = backend.execute(sig, &refs).unwrap()[li].data[0];
        assert_eq!(a, b, "same inputs must give bit-identical loss");
    }

    #[test]
    fn waveq_reg_term_raises_loss_over_ce() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        for prog in ["train_waveq_mlp", "train_waveq_simplenet5"] {
            let sig = manifest.program(prog).unwrap();
            let args = dummy_train_args(&backend, prog);
            let refs: Vec<&Buffer> = args.iter().collect();
            let outs = backend.execute(sig, &refs).unwrap();
            let loss = outs[sig.output_index("loss").unwrap()].data[0];
            let ce = outs[sig.output_index("ce").unwrap()].data[0];
            let reg = outs[sig.output_index("reg_w").unwrap()].data[0];
            assert!(reg > 0.0, "{prog}: random weights should not sit on the grid");
            assert!(loss > ce, "{prog}: loss must include the positive penalty terms");
        }
    }

    #[test]
    fn beta_moves_only_when_beta_train_set() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("train_waveq_mlp").unwrap();
        let bidx = sig.output_index("beta").unwrap();
        let bin = sig.input_index("beta").unwrap();
        let fin = sig.input_index("beta_train").unwrap();

        let mut args = dummy_train_args(&backend, "train_waveq_mlp");
        // Use a beta off the integer grid so dR/dbeta is generically nonzero.
        args[bin] = buffer_f32(&[3.7, 5.2], &[2]).unwrap();
        args[fin] = scalar_f32(0.0);
        let refs: Vec<&Buffer> = args.iter().collect();
        let frozen = backend.execute(sig, &refs).unwrap()[bidx].data.clone();
        assert_eq!(frozen, vec![3.7, 5.2], "beta must not move when gated off");

        args[fin] = scalar_f32(1.0);
        let refs: Vec<&Buffer> = args.iter().collect();
        let live = backend.execute(sig, &refs).unwrap()[bidx].data.clone();
        assert_ne!(live, vec![3.7, 5.2], "beta must move when training is enabled");
        for &b in &live {
            assert!((1.0..=8.0).contains(&b), "beta {b} escaped its clip range");
        }
    }

    #[test]
    fn conv_graph_gradients_match_finite_difference() {
        // End-to-end FD check through the op graph: fp32 simplenet5, one
        // small batch, perturb single weights of a conv, an affine scale,
        // and the head fc; the analytic parameter gradient (pre-clip) must
        // match (loss(w+h) - loss(w-h)) / 2h. fp32 keeps the graph smooth
        // (no quantizer staircase), so FD is trustworthy.
        let model = NativeModel::simplenet5(1);
        let batch = 4usize;
        let mut rng = Rng::new(11);
        let mut params_data: Vec<Vec<f32>> = model
            .params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                match p.kind.as_str() {
                    "affine" if p.name.ends_with("_s") => vec![1.0; n],
                    "affine" | "bias" => vec![0.0; n],
                    _ => rng.normal_vec(n, 0.2),
                }
            })
            .collect();
        let x: Vec<f32> = rng.normal_vec(batch * model.pixels(), 1.0);
        let mut y = vec![0.0f32; batch * model.num_classes];
        for r in 0..batch {
            y[r * model.num_classes + r % model.num_classes] = 1.0;
        }
        let loss_of = |params_data: &Vec<Vec<f32>>| -> f64 {
            let ps: Vec<&[f32]> = params_data.iter().map(|v| v.as_slice()).collect();
            let fwd = forward(&model, &ps, &x, batch, QuantFamily::Fp32, &[], &[], None, false);
            let (ce, _, _) = kn::softmax_ce(&fwd.logits, &y, batch, model.num_classes);
            ce as f64
        };
        let ps: Vec<&[f32]> = params_data.iter().map(|v| v.as_slice()).collect();
        let fwd = forward(&model, &ps, &x, batch, QuantFamily::Fp32, &[], &[], None, true);
        let (_, _, dl) = kn::softmax_ce(&fwd.logits, &y, batch, model.num_classes);
        let grads = backward(&model, &fwd, dl, batch, &ps, 0.0);
        drop(ps);
        // (param index, element) probes: conv2 weight, affine2 scale, fc1 w.
        let probes: Vec<(usize, usize)> = vec![
            (model.params.iter().position(|p| p.name == "conv2").unwrap(), 3),
            (model.params.iter().position(|p| p.name == "affine2_s").unwrap(), 1),
            (model.params.iter().position(|p| p.name == "fc1").unwrap(), 17),
            (model.params.iter().position(|p| p.name == "fc2_b").unwrap(), 0),
        ];
        let h = 1e-3f32;
        for (pi, ei) in probes {
            let orig = params_data[pi][ei];
            params_data[pi][ei] = orig + h;
            let lp = loss_of(&params_data);
            params_data[pi][ei] = orig - h;
            let lm = loss_of(&params_data);
            params_data[pi][ei] = orig;
            let fd = (lp - lm) / (2.0 * h as f64);
            let an = grads[pi][ei] as f64;
            assert!(
                (fd - an).abs() < 2e-3 * (1.0 + an.abs()),
                "param {pi} elem {ei}: fd={fd} an={an}"
            );
        }
    }

    #[test]
    fn residual_graph_gradients_match_finite_difference() {
        // Same FD check through resnet20l's skip/projection machinery.
        let model = NativeModel::resnet20l(1);
        let batch = 2usize;
        let mut rng = Rng::new(5);
        let mut params_data: Vec<Vec<f32>> = model
            .params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                match p.kind.as_str() {
                    "affine" if p.name.ends_with("_s") => vec![1.0; n],
                    "affine" | "bias" => vec![0.0; n],
                    _ => rng.normal_vec(n, 0.3),
                }
            })
            .collect();
        let x: Vec<f32> = rng.normal_vec(batch * model.pixels(), 1.0);
        let mut y = vec![0.0f32; batch * model.num_classes];
        for r in 0..batch {
            y[r * model.num_classes + r] = 1.0;
        }
        let loss_of = |params_data: &Vec<Vec<f32>>| -> f64 {
            let ps: Vec<&[f32]> = params_data.iter().map(|v| v.as_slice()).collect();
            let fwd = forward(&model, &ps, &x, batch, QuantFamily::Fp32, &[], &[], None, false);
            let (ce, _, _) = kn::softmax_ce(&fwd.logits, &y, batch, model.num_classes);
            ce as f64
        };
        let ps: Vec<&[f32]> = params_data.iter().map(|v| v.as_slice()).collect();
        let fwd = forward(&model, &ps, &x, batch, QuantFamily::Fp32, &[], &[], None, true);
        let (_, _, dl) = kn::softmax_ce(&fwd.logits, &y, batch, model.num_classes);
        let grads = backward(&model, &fwd, dl, batch, &ps, 0.0);
        drop(ps);
        // Probe the stem, a residual-body conv, and a projection conv.
        // conv4 = 2nd body conv of block 1; conv8 = projection of block 3
        // (blocks: conv2/conv3, conv4... stem=conv1; block1 body conv2,conv3;
        // block2 conv4,conv5; block3 body conv6,conv7 + proj conv8).
        let probes: Vec<(usize, usize)> = ["conv1", "conv3", "conv8", "fc1"]
            .iter()
            .map(|n| (model.params.iter().position(|p| &p.name == n).unwrap(), 2))
            .collect();
        let h = 1e-3f32;
        for (pi, ei) in probes {
            let orig = params_data[pi][ei];
            params_data[pi][ei] = orig + h;
            let lp = loss_of(&params_data);
            params_data[pi][ei] = orig - h;
            let lm = loss_of(&params_data);
            params_data[pi][ei] = orig;
            let fd = (lp - lm) / (2.0 * h as f64);
            let an = grads[pi][ei] as f64;
            assert!(
                (fd - an).abs() < 2e-3 * (1.0 + an.abs()),
                "param {} elem {ei}: fd={fd} an={an}",
                model.params[pi].name
            );
        }
    }

    #[test]
    fn reg_profile_surfaces_have_grid_zeros() {
        let backend = NativeBackend::new();
        let manifest = backend.manifest();
        let sig = manifest.program("reg_profile").unwrap();
        let w: Vec<f32> = (0..REG_PROFILE_NW)
            .map(|i| -1.25 + 2.5 * i as f32 / (REG_PROFILE_NW - 1) as f32)
            .collect();
        let b: Vec<f32> = (0..REG_PROFILE_NB)
            .map(|i| 1.0 + 7.0 * i as f32 / (REG_PROFILE_NB - 1) as f32)
            .collect();
        let args = vec![
            buffer_f32(&w, &[REG_PROFILE_NW]).unwrap(),
            buffer_f32(&b, &[REG_PROFILE_NB]).unwrap(),
        ];
        let refs: Vec<&Buffer> = args.iter().collect();
        let outs = backend.execute(sig, &refs).unwrap();
        assert_eq!(outs.len(), 9);
        // R_n1 is non-negative and bounded by its 1/2^beta envelope.
        let r1 = &outs[3];
        for (wi, _) in w.iter().enumerate() {
            for (bi, &bv) in b.iter().enumerate() {
                let v = r1.data[wi * REG_PROFILE_NB + bi];
                assert!(v >= 0.0, "R1 negative at ({wi},{bi})");
                assert!(v <= 2f32.powf(-bv) + 1e-6, "R1 above envelope at ({wi},{bi})");
            }
        }
        // All derivative surfaces are finite.
        for o in &outs {
            assert!(o.data.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn unknown_program_is_a_clean_error() {
        let backend = NativeBackend::new();
        let sig = ProgramSig {
            name: "train_waveq_resnet99".into(),
            file: "nope".into(),
            model: None,
            inputs: vec![],
            outputs: vec![],
        };
        let err = backend.execute(&sig, &[]).unwrap_err();
        assert!(format!("{err}").contains("no program"), "{err}");
    }
}
