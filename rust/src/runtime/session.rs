//! Stateful training sessions over prepared program handles: the
//! backend-resident training state (parameters, momenta, the continuous
//! bitwidth beta and its velocity, the step counter) lives *inside* the
//! [`Session`], keyed by the manifest's `ParamMeta` order, and one
//! [`Session::step`] call runs one train-program dispatch with
//!
//! * **no name lookups** — the train/eval programs are resolved once via
//!   [`Runtime::prepare`] at [`Session::open`] time;
//! * **no steady-state allocation of tensors** — inputs are refreshed into
//!   preallocated buffers and outputs land in a double-buffered output set
//!   that is *flipped* with the state (`std::mem::swap`) instead of
//!   reallocated;
//! * **no full-state host copies** — only the small beta/vbeta mirrors and
//!   the scalar metrics cross back to the coordinator each step.
//!
//! The trainer's old loop (manual positional `args` vec assembly plus
//! manifest-ordered output re-threading) shrinks to `session.step(..)`;
//! the legacy stringly-typed [`Runtime::execute`] path remains only as the
//! tests' oracle, and `tests/session.rs` asserts the two are bitwise
//! identical over a 50-step WaveQ run.
//!
//! # Example
//!
//! ```
//! use waveq::runtime::{Runtime, Session, SessionCfg, StepKnobs};
//!
//! let rt = Runtime::native();
//! let mut session = Session::open(
//!     &rt,
//!     &SessionCfg {
//!         train_program: "train_waveq_mlp".into(),
//!         eval_program: "eval_quant_mlp".into(),
//!         seed: 7,
//!         beta_init: 6.0,
//!         preset_kw: None,
//!     },
//! )
//! .unwrap();
//!
//! // One synthetic batch, shaped by the session's model metadata.
//! let m = session.model().clone();
//! let pix: usize = m.input_shape.iter().product();
//! let x = vec![0.1f32; m.batch * pix];
//! let mut y = vec![0.0f32; m.batch * m.num_classes];
//! for r in 0..m.batch {
//!     y[r * m.num_classes + r % m.num_classes] = 1.0;
//! }
//!
//! let knobs = StepKnobs {
//!     lr: 0.05,
//!     momentum: 0.9,
//!     lr_beta: 0.01,
//!     ka: 255.0,
//!     lambda_w: 0.1,
//!     lambda_beta: 0.01,
//!     beta_train: 1.0,
//! };
//! let metrics = session.step(&x, &y, &knobs).unwrap();
//! assert!(metrics.loss.is_finite());
//! assert_eq!(session.state().step, 1);
//!
//! // Evaluate the current state on the same batch (quantized eval needs
//! // the per-layer level counts kw and the activation levels ka).
//! let kw = vec![7.0f32; m.num_qlayers];
//! let (eval_loss, eval_acc) = session.eval(&x, &y, Some(&kw), 255.0).unwrap();
//! assert!(eval_loss.is_finite() && (0.0..=1.0).contains(&eval_acc));
//! ```

use std::path::Path;

use anyhow::{anyhow, Result};

use super::artifact::{FrozenModel, FrozenParam, ParamStorage};
use super::backend::{Program, Runtime};
use super::buffer::{buffer_f32, to_vec_f32, Buffer};
use super::checkpoint::Checkpoint;
use super::manifest::ModelMeta;
use super::native::kernels as kn;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Backend-interchange train state: parameters + optimizer velocities as
/// runtime buffers in the manifest's `ParamMeta` order, plus the small
/// host-side mirrors the coordinator actually inspects (beta, scalars).
/// Owned by a [`Session`] during training; extracted via
/// [`Session::into_state`] for checkpointing and analysis. `Clone` so the
/// distributed coordinator can snapshot round boundaries for replay.
#[derive(Clone)]
pub struct SessionState {
    pub params: Vec<Buffer>,
    pub vels: Vec<Buffer>,
    /// Continuous per-layer bitwidth parameter (waveq programs only).
    pub beta: Vec<f32>,
    pub vbeta: Vec<f32>,
    pub step: usize,
}

impl SessionState {
    /// He/affine initialization matching the layer kinds in the manifest.
    pub fn init(model: &ModelMeta, seed: u64, beta_init: f32) -> Result<SessionState> {
        let mut rng = Rng::new(seed).split(0x1417);
        let mut params = Vec::with_capacity(model.params.len());
        let mut vels = Vec::with_capacity(model.params.len());
        for p in &model.params {
            let n: usize = p.shape.iter().product();
            // Fixup-style: residual-body tail convs start near zero so deep
            // residual chains begin as identity (manifest init = "he_res").
            let res_scale = if p.init == "he_res" { 0.1 } else { 1.0 };
            let data = match p.kind.as_str() {
                "conv" | "dwconv" => {
                    let fan_in: usize = p.shape[..p.shape.len() - 1].iter().product();
                    rng.normal_vec(n, res_scale * (2.0 / fan_in as f32).sqrt())
                }
                "fc" => {
                    let fan_in = p.shape[0];
                    rng.normal_vec(n, (2.0 / fan_in as f32).sqrt())
                }
                "affine" if p.name.ends_with("_s") => vec![1.0; n],
                _ => vec![0.0; n], // biases, affine shifts
            };
            params.push(buffer_f32(&data, &p.shape)?);
            vels.push(buffer_f32(&vec![0.0; n], &p.shape)?);
        }
        Ok(SessionState {
            params,
            vels,
            beta: vec![beta_init; model.num_qlayers],
            vbeta: vec![0.0; model.num_qlayers],
            step: 0,
        })
    }

    /// Host copy of one parameter (observers, checkpoints, histograms).
    pub fn param_tensor(&self, model: &ModelMeta, idx: usize) -> Result<Tensor> {
        let data = to_vec_f32(&self.params[idx])?;
        Tensor::new(model.params[idx].shape.clone(), data)
    }

    /// Host copies of all parameters.
    pub fn all_params(&self, model: &ModelMeta) -> Result<Vec<Tensor>> {
        (0..self.params.len()).map(|i| self.param_tensor(model, i)).collect()
    }

    /// Replace parameters from host tensors (checkpoint restore).
    pub fn set_params(&mut self, tensors: &[Tensor]) -> Result<()> {
        if tensors.len() != self.params.len() {
            return Err(anyhow!(
                "checkpoint has {} params, model wants {}",
                tensors.len(),
                self.params.len()
            ));
        }
        self.params = tensors
            .iter()
            .map(|t| buffer_f32(&t.data, &t.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}

/// Positional role of each train-program input (resolved once at open).
enum Slot {
    Param(usize),
    Vel(usize),
    Beta,
    VBeta,
    X,
    Y,
    /// Homogeneous preset kw vector (dorefa/wrpn programs); filled once at
    /// open from [`SessionCfg::preset_kw`].
    KwVec,
    Scalar(ScalarKnob),
}

#[derive(Debug, Clone, Copy)]
enum ScalarKnob {
    Lr,
    Mom,
    LrBeta,
    Ka,
    LambdaW,
    LambdaBeta,
    BetaTrain,
}

/// The per-step schedule knobs the coordinator feeds a train dispatch.
/// Knobs a program does not take (e.g. `lr_beta` on an fp32 program) are
/// simply ignored.
#[derive(Debug, Clone, Default)]
pub struct StepKnobs {
    pub lr: f32,
    pub momentum: f32,
    pub lr_beta: f32,
    /// Activation quantizer level count (`2^a_bits - 1`).
    pub ka: f32,
    pub lambda_w: f32,
    pub lambda_beta: f32,
    /// 1.0 while beta is learning, 0.0 once frozen (phase 3).
    pub beta_train: f32,
}

impl StepKnobs {
    fn get(&self, k: ScalarKnob) -> f32 {
        match k {
            ScalarKnob::Lr => self.lr,
            ScalarKnob::Mom => self.momentum,
            ScalarKnob::LrBeta => self.lr_beta,
            ScalarKnob::Ka => self.ka,
            ScalarKnob::LambdaW => self.lambda_w,
            ScalarKnob::LambdaBeta => self.lambda_beta,
            ScalarKnob::BetaTrain => self.beta_train,
        }
    }
}

/// The scalar outputs of one train step.
#[derive(Debug, Clone, Copy)]
pub struct StepMetrics {
    pub loss: f32,
    pub acc: f32,
    /// Cross-entropy alone (waveq programs only).
    pub ce: Option<f32>,
    /// The sinusoidal regularization term (waveq programs only).
    pub reg_w: Option<f32>,
}

/// What a [`Session`] is opened over.
#[derive(Debug, Clone)]
pub struct SessionCfg {
    pub train_program: String,
    pub eval_program: String,
    /// Seed for the parameter initialization.
    pub seed: u64,
    /// Initial value of every beta slot (waveq programs).
    pub beta_init: f32,
    /// Per-layer quantizer level counts for programs taking a `kw` input
    /// (dorefa / wrpn); must be `Some` with `num_qlayers` entries there.
    pub preset_kw: Option<Vec<f32>>,
}

/// The prepared split-stage handles (see [`Session::enable_grad_stage`]).
struct GradStage<'rt> {
    grads: Program<'rt>,
    apply: Program<'rt>,
}

/// A stateful training session: prepared train/eval handles + the training
/// state they advance + every preallocated I/O buffer of the hot loop.
pub struct Session<'rt> {
    train: Program<'rt>,
    eval: Program<'rt>,
    /// Split grads/apply handles, resolved by [`Session::enable_grad_stage`].
    grad_stage: Option<GradStage<'rt>>,
    model: ModelMeta,
    slots: Vec<Slot>,
    n_params: usize,
    x_idx: usize,
    y_idx: usize,
    // Train-program output indices (absolute, in manifest order).
    out_beta: Option<usize>,
    out_loss: usize,
    out_acc: usize,
    out_ce: Option<usize>,
    out_regw: Option<usize>,
    // Eval-program layout.
    eval_quant: bool,
    eval_out_loss: usize,
    eval_out_acc: usize,
    // State + preallocated I/O.
    state: SessionState,
    /// One input buffer per slot (placeholders for Param/Vel slots, whose
    /// storage lives in `state`).
    bufs: Vec<Buffer>,
    /// Double-buffered outputs, flipped with `state` after each step.
    outs: Vec<Buffer>,
    eval_kw_buf: Buffer,
    eval_ka_buf: Buffer,
    eval_outs: Vec<Buffer>,
}

impl<'rt> Session<'rt> {
    /// Resolve both programs once, pre-validate the positional layout, and
    /// initialize the backend-resident state (He init at `cfg.seed`).
    pub fn open(rt: &'rt Runtime, cfg: &SessionCfg) -> Result<Session<'rt>> {
        let train = rt.prepare(&cfg.train_program)?;
        // Pre-size this thread's backend arena for the train path, so the
        // steady-state loop leases every forward/backward transient instead
        // of allocating (each distributed worker thread warms its own).
        train.warm()?;
        let eval = rt.prepare(&cfg.eval_program)?;
        let model_key = train
            .sig()
            .model
            .clone()
            .ok_or_else(|| anyhow!("{}: program declares no model", cfg.train_program))?;
        let model = rt.manifest.model(&model_key)?.clone();
        let nq = model.num_qlayers;

        // ---- resolve the train program's positional layout ---------------
        let mut slots = Vec::with_capacity(train.sig().inputs.len());
        let (mut pi, mut vi) = (0usize, 0usize);
        let (mut x_idx, mut y_idx) = (None, None);
        for (i, a) in train.sig().inputs.iter().enumerate() {
            slots.push(match a.name.as_str() {
                n if n.starts_with("w:") => {
                    pi += 1;
                    Slot::Param(pi - 1)
                }
                n if n.starts_with("v:") => {
                    vi += 1;
                    Slot::Vel(vi - 1)
                }
                "beta" => Slot::Beta,
                "vbeta" => Slot::VBeta,
                "x" => {
                    x_idx = Some(i);
                    Slot::X
                }
                "y" => {
                    y_idx = Some(i);
                    Slot::Y
                }
                "kw" => Slot::KwVec,
                "lr" => Slot::Scalar(ScalarKnob::Lr),
                "mom" => Slot::Scalar(ScalarKnob::Mom),
                "lr_beta" => Slot::Scalar(ScalarKnob::LrBeta),
                "ka" => Slot::Scalar(ScalarKnob::Ka),
                "lambda_w" => Slot::Scalar(ScalarKnob::LambdaW),
                "lambda_beta" => Slot::Scalar(ScalarKnob::LambdaBeta),
                "beta_train" => Slot::Scalar(ScalarKnob::BetaTrain),
                other => return Err(anyhow!("{}: unknown input '{other}'", cfg.train_program)),
            });
        }
        let n_params = pi;
        if n_params != model.num_params() || vi != n_params {
            return Err(anyhow!(
                "{}: signature has {n_params} w / {vi} v inputs, model '{model_key}' has {}",
                cfg.train_program,
                model.num_params()
            ));
        }
        let (x_idx, y_idx) = match (x_idx, y_idx) {
            (Some(x), Some(y)) => (x, y),
            _ => return Err(anyhow!("{}: not a train program (no x/y)", cfg.train_program)),
        };

        // ---- preallocate one input buffer per slot ------------------------
        let mut bufs = Vec::with_capacity(slots.len());
        for slot in &slots {
            bufs.push(match slot {
                // Placeholders — the real storage lives in `state`.
                Slot::Param(_) | Slot::Vel(_) => Buffer::scalar(0.0),
                Slot::Beta | Slot::VBeta => Buffer::zeros(vec![nq]),
                Slot::X => Buffer::zeros(vec![
                    model.batch,
                    model.input_shape[0],
                    model.input_shape[1],
                    model.input_shape[2],
                ]),
                Slot::Y => Buffer::zeros(vec![model.batch, model.num_classes]),
                Slot::KwVec => {
                    let kw = cfg.preset_kw.as_deref().ok_or_else(|| {
                        anyhow!("{}: program takes kw but cfg.preset_kw is None", cfg.train_program)
                    })?;
                    if kw.len() != nq {
                        return Err(anyhow!(
                            "{}: preset_kw has {} entries, model wants {nq}",
                            cfg.train_program,
                            kw.len()
                        ));
                    }
                    buffer_f32(kw, &[nq])?
                }
                Slot::Scalar(_) => Buffer::scalar(0.0),
            });
        }

        // ---- output indices + double-buffered output set ------------------
        let sig = train.sig();
        let out_loss = sig.output_index("loss")?;
        let out_acc = sig.output_index("acc")?;
        let out_ce = sig.output_index("ce").ok();
        let out_regw = sig.output_index("reg_w").ok();
        let out_beta = sig.output_index("beta").ok();
        let mut outs = Vec::with_capacity(sig.outputs.len());
        let (mut wo, mut vo) = (0usize, 0usize);
        for name in &sig.outputs {
            outs.push(match name.as_str() {
                n if n.starts_with("w:") => {
                    wo += 1;
                    Buffer::zeros(model.params[wo - 1].shape.clone())
                }
                n if n.starts_with("v:") => {
                    vo += 1;
                    Buffer::zeros(model.params[vo - 1].shape.clone())
                }
                "beta" | "vbeta" => Buffer::zeros(vec![nq]),
                _ => Buffer::scalar(0.0), // loss, acc, ce, reg_w
            });
        }
        if wo != n_params || vo != n_params {
            return Err(anyhow!(
                "{}: outputs carry {wo} w / {vo} v tensors, expected {n_params}",
                cfg.train_program
            ));
        }

        // ---- eval layout --------------------------------------------------
        let esig = eval.sig();
        let eval_quant = esig.inputs.iter().any(|a| a.name == "kw");
        let want = n_params + 2 + if eval_quant { 2 } else { 0 };
        if esig.inputs.len() != want {
            return Err(anyhow!(
                "{}: eval signature has {} inputs, expected {want} for model '{model_key}'",
                cfg.eval_program,
                esig.inputs.len()
            ));
        }
        let eval_out_loss = esig.output_index("loss")?;
        let eval_out_acc = esig.output_index("acc")?;
        let eval_outs = vec![Buffer::scalar(0.0); esig.outputs.len()];

        let state = SessionState::init(&model, cfg.seed, cfg.beta_init)?;
        Ok(Session {
            train,
            eval,
            grad_stage: None,
            model,
            slots,
            n_params,
            x_idx,
            y_idx,
            out_beta,
            out_loss,
            out_acc,
            out_ce,
            out_regw,
            eval_quant,
            eval_out_loss,
            eval_out_acc,
            state,
            bufs,
            outs,
            eval_kw_buf: Buffer::zeros(vec![nq]),
            eval_ka_buf: Buffer::scalar(0.0),
            eval_outs,
        })
    }

    /// The model this session trains (manifest metadata).
    pub fn model(&self) -> &ModelMeta {
        &self.model
    }

    /// Name of the prepared train program.
    pub fn train_program(&self) -> &str {
        self.train.name()
    }

    /// Whether this session's backend serves [`Session::eval`] at
    /// non-manifest batch sizes (the held-out ragged tail). True on the
    /// native backend; false on AOT fixed-shape backends.
    pub fn batch_polymorphic(&self) -> bool {
        self.eval.batch_polymorphic()
    }

    /// The live training state (read-only).
    pub fn state(&self) -> &SessionState {
        &self.state
    }

    /// Mutable access to the live state — the coordinator's freeze step
    /// snaps beta / zeroes vbeta through this.
    pub fn state_mut(&mut self) -> &mut SessionState {
        &mut self.state
    }

    /// Extract the state (end of training; checkpointing).
    pub fn into_state(self) -> SessionState {
        self.state
    }

    /// Replace the whole state (checkpoint restore). Shapes must match the
    /// session's model.
    pub fn load_state(&mut self, state: SessionState) -> Result<()> {
        if state.params.len() != self.n_params || state.vels.len() != self.n_params {
            return Err(anyhow!(
                "load_state: got {} params / {} vels, model wants {}",
                state.params.len(),
                state.vels.len(),
                self.n_params
            ));
        }
        for (i, p) in self.model.params.iter().enumerate() {
            if state.params[i].shape != p.shape || state.vels[i].shape != p.shape {
                return Err(anyhow!(
                    "load_state: param {} has shape {:?}, model wants {:?}",
                    p.name,
                    state.params[i].shape,
                    p.shape
                ));
            }
        }
        if state.beta.len() != self.model.num_qlayers
            || state.vbeta.len() != self.model.num_qlayers
        {
            return Err(anyhow!(
                "load_state: beta/vbeta have {}/{} entries, model wants {}",
                state.beta.len(),
                state.vbeta.len(),
                self.model.num_qlayers
            ));
        }
        self.state = state;
        Ok(())
    }

    /// Run one train step on a host batch: refresh the preallocated input
    /// buffers, dispatch through the prepared handle into the back output
    /// buffers, then flip state and outputs. Steady state allocates no
    /// tensor storage and copies no parameters.
    pub fn step(&mut self, x: &[f32], y: &[f32], knobs: &StepKnobs) -> Result<StepMetrics> {
        let (out_beta, out_loss, out_acc) = (self.out_beta, self.out_loss, self.out_acc);
        let (out_ce, out_regw) = (self.out_ce, self.out_regw);
        let Session { train, slots, state, bufs, outs, n_params, .. } = self;
        let np = *n_params;
        // Refresh inputs.
        for (i, slot) in slots.iter().enumerate() {
            match slot {
                Slot::X => bufs[i].fill_from(x)?,
                Slot::Y => bufs[i].fill_from(y)?,
                Slot::Beta => bufs[i].fill_from(&state.beta)?,
                Slot::VBeta => bufs[i].fill_from(&state.vbeta)?,
                Slot::Scalar(k) => bufs[i].data[0] = knobs.get(*k),
                Slot::Param(_) | Slot::Vel(_) | Slot::KwVec => {}
            }
        }
        // Assemble positional refs (state buffers are borrowed, not moved).
        let args: Vec<&Buffer> = slots
            .iter()
            .enumerate()
            .map(|(i, slot)| match slot {
                Slot::Param(p) => &state.params[*p],
                Slot::Vel(v) => &state.vels[*v],
                _ => &bufs[i],
            })
            .collect();
        train.call_into(&args, outs)?;
        // Flip: the freshly-written outputs become the state; the old state
        // buffers become the next step's output storage.
        for i in 0..np {
            std::mem::swap(&mut state.params[i], &mut outs[i]);
            std::mem::swap(&mut state.vels[i], &mut outs[np + i]);
        }
        if let Some(bi) = out_beta {
            state.beta.copy_from_slice(&outs[bi].data);
            state.vbeta.copy_from_slice(&outs[bi + 1].data);
        }
        state.step += 1;
        Ok(StepMetrics {
            loss: outs[out_loss].data[0],
            acc: outs[out_acc].data[0],
            ce: out_ce.map(|i| outs[i].data[0]),
            reg_w: out_regw.map(|i| outs[i].data[0]),
        })
    }

    /// Resolve the split `grads_*`/`apply_*` stage handles matching this
    /// session's train program (distributed training). Errors cleanly when
    /// the backend has no split stages ([`Runtime::grad_stage`]).
    pub fn enable_grad_stage(&mut self, rt: &'rt Runtime) -> Result<()> {
        if !rt.grad_stage() {
            return Err(anyhow!(
                "{}: backend '{}' has no split grads/apply train stages",
                self.train.name(),
                rt.platform()
            ));
        }
        let base = self
            .train
            .name()
            .strip_prefix("train_")
            .ok_or_else(|| anyhow!("{}: not a train_* program", self.train.name()))?
            .to_string();
        let grads = rt.prepare(&format!("grads_{base}"))?;
        grads.warm()?;
        let apply = rt.prepare(&format!("apply_{base}"))?;
        self.grad_stage = Some(GradStage { grads, apply });
        Ok(())
    }

    /// Whether [`Session::enable_grad_stage`] has been called.
    pub fn grad_stage_enabled(&self) -> bool {
        self.grad_stage.is_some()
    }

    /// Correctly-shaped zero buffers for one grads-stage dispatch: one per
    /// parameter gradient, then the ce_sum / acc_cnt scalars.
    pub fn grad_outputs(&self) -> Vec<Buffer> {
        let mut outs: Vec<Buffer> = self
            .model
            .params
            .iter()
            .map(|p| Buffer::zeros(p.shape.clone()))
            .collect();
        outs.push(Buffer::scalar(0.0));
        outs.push(Buffer::scalar(0.0));
        outs
    }

    /// Run the grad-producing stage over the given rows (one reduction
    /// chunk of the global batch) with the loss denominated by the *global*
    /// batch size `denom`, writing into `outs` (the shape of
    /// [`Session::grad_outputs`]). Touches no state: parameters stay put
    /// and the step counter does not advance — [`Session::apply_update`]
    /// completes the step.
    pub fn step_grads_into(
        &self,
        x: &[f32],
        y: &[f32],
        knobs: &StepKnobs,
        denom: f32,
        outs: &mut [Buffer],
    ) -> Result<()> {
        let stage = self
            .grad_stage
            .as_ref()
            .ok_or_else(|| anyhow!("{}: call enable_grad_stage first", self.train.name()))?;
        let pix: usize = self.model.input_shape.iter().product();
        if x.is_empty() || !x.len().is_multiple_of(pix) {
            return Err(anyhow!(
                "{}: x has {} elems, not a multiple of {pix}",
                stage.grads.name(),
                x.len()
            ));
        }
        let rows = x.len() / pix;
        let (xb, yb) = self.model.batch_buffers(rows, x, y)?;
        let (mut xb, mut yb) = (Some(xb), Some(yb));
        enum Src {
            Param(usize),
            Scratch(usize),
        }
        let sig = stage.grads.sig();
        let mut plan: Vec<Src> = Vec::with_capacity(sig.inputs.len());
        let mut scratch: Vec<Buffer> = Vec::new();
        let mut pi = 0usize;
        for a in &sig.inputs {
            let owned = match a.name.as_str() {
                n if n.starts_with("w:") => {
                    plan.push(Src::Param(pi));
                    pi += 1;
                    continue;
                }
                "beta" => buffer_f32(&self.state.beta, &[self.state.beta.len()])?,
                "x" => xb.take().ok_or_else(|| anyhow!("duplicate x input"))?,
                "y" => yb.take().ok_or_else(|| anyhow!("duplicate y input"))?,
                "denom" => Buffer::scalar(denom),
                "ka" => Buffer::scalar(knobs.ka),
                "kw" => self
                    .slots
                    .iter()
                    .enumerate()
                    .find_map(|(i, s)| match s {
                        Slot::KwVec => Some(self.bufs[i].clone()),
                        _ => None,
                    })
                    .ok_or_else(|| {
                        anyhow!("{}: train program has no kw to feed the grads stage", sig.name)
                    })?,
                other => return Err(anyhow!("{}: unknown grads input '{other}'", sig.name)),
            };
            scratch.push(owned);
            plan.push(Src::Scratch(scratch.len() - 1));
        }
        let args: Vec<&Buffer> = plan
            .iter()
            .map(|s| match s {
                Src::Param(i) => &self.state.params[*i],
                Src::Scratch(i) => &scratch[*i],
            })
            .collect();
        stage.grads.call_into(&args, outs)
    }

    /// Complete one step from already-reduced gradients: dispatch the apply
    /// stage (regularizer, clip, SGD/momentum, beta update) into the
    /// session's double-buffered outputs and flip state exactly like
    /// [`Session::step`]. `grads` is one buffer per parameter in manifest
    /// order; `ce_sum`/`acc_cnt` are the chunk-reduced CE parts and `denom`
    /// the global batch size they are normalized by.
    pub fn apply_update(
        &mut self,
        grads: &[Buffer],
        ce_sum: f32,
        acc_cnt: f32,
        denom: f32,
        knobs: &StepKnobs,
    ) -> Result<StepMetrics> {
        let (out_beta, out_loss, out_acc) = (self.out_beta, self.out_loss, self.out_acc);
        let (out_ce, out_regw) = (self.out_ce, self.out_regw);
        let Session { grad_stage, state, outs, n_params, .. } = self;
        let np = *n_params;
        let stage = grad_stage
            .as_ref()
            .ok_or_else(|| anyhow!("apply_update: call enable_grad_stage first"))?;
        if grads.len() != np {
            return Err(anyhow!(
                "{}: got {} gradient buffers, model has {np} params",
                stage.apply.name(),
                grads.len()
            ));
        }
        enum Src {
            Param(usize),
            Vel(usize),
            Grad(usize),
            Scratch(usize),
        }
        let sig = stage.apply.sig();
        let mut plan: Vec<Src> = Vec::with_capacity(sig.inputs.len());
        let mut scratch: Vec<Buffer> = Vec::new();
        let (mut pi, mut vi, mut gi) = (0usize, 0usize, 0usize);
        for a in &sig.inputs {
            let owned = match a.name.as_str() {
                n if n.starts_with("w:") => {
                    plan.push(Src::Param(pi));
                    pi += 1;
                    continue;
                }
                n if n.starts_with("v:") => {
                    plan.push(Src::Vel(vi));
                    vi += 1;
                    continue;
                }
                n if n.starts_with("g:") => {
                    plan.push(Src::Grad(gi));
                    gi += 1;
                    continue;
                }
                "beta" => buffer_f32(&state.beta, &[state.beta.len()])?,
                "vbeta" => buffer_f32(&state.vbeta, &[state.vbeta.len()])?,
                "ce_sum" => Buffer::scalar(ce_sum),
                "acc_cnt" => Buffer::scalar(acc_cnt),
                "denom" => Buffer::scalar(denom),
                "lr" => Buffer::scalar(knobs.lr),
                "mom" => Buffer::scalar(knobs.momentum),
                "lr_beta" => Buffer::scalar(knobs.lr_beta),
                "lambda_w" => Buffer::scalar(knobs.lambda_w),
                "lambda_beta" => Buffer::scalar(knobs.lambda_beta),
                "beta_train" => Buffer::scalar(knobs.beta_train),
                other => return Err(anyhow!("{}: unknown apply input '{other}'", sig.name)),
            };
            scratch.push(owned);
            plan.push(Src::Scratch(scratch.len() - 1));
        }
        let args: Vec<&Buffer> = plan
            .iter()
            .map(|s| match s {
                Src::Param(i) => &state.params[*i],
                Src::Vel(i) => &state.vels[*i],
                Src::Grad(i) => &grads[*i],
                Src::Scratch(i) => &scratch[*i],
            })
            .collect();
        // The apply stage writes the fused-train output layout, so the
        // session's double-buffered outputs and flip discipline are reused
        // verbatim.
        stage.apply.call_into(&args, outs)?;
        for i in 0..np {
            std::mem::swap(&mut state.params[i], &mut outs[i]);
            std::mem::swap(&mut state.vels[i], &mut outs[np + i]);
        }
        if let Some(bi) = out_beta {
            state.beta.copy_from_slice(&outs[bi].data);
            state.vbeta.copy_from_slice(&outs[bi + 1].data);
        }
        state.step += 1;
        Ok(StepMetrics {
            loss: outs[out_loss].data[0],
            acc: outs[out_acc].data[0],
            ce: out_ce.map(|i| outs[i].data[0]),
            reg_w: out_regw.map(|i| outs[i].data[0]),
        })
    }

    /// Evaluate the *current* state on one host batch through the prepared
    /// eval program. Quantized eval programs need the per-layer level
    /// counts `kw` (e.g. `BitAssignment::kw()`) and the activation level
    /// count `ka`; fp32 eval ignores both.
    ///
    /// Batch-polymorphic: a batch at the manifest shape flows through the
    /// preallocated zero-alloc slots; any other size (the held-out ragged
    /// tail) dispatches through fresh x/y buffers at the live shape — the
    /// native backend resolves the batch from the buffer length.
    pub fn eval(
        &mut self,
        x: &[f32],
        y: &[f32],
        kw: Option<&[f32]>,
        ka: f32,
    ) -> Result<(f32, f32)> {
        let pix: usize = self.model.input_shape.iter().product();
        if x.is_empty() || !x.len().is_multiple_of(pix) {
            return Err(anyhow!(
                "{}: x has {} elems, not a multiple of {pix}",
                self.eval.name(),
                x.len()
            ));
        }
        let batch = x.len() / pix;
        if y.len() != batch * self.model.num_classes {
            return Err(anyhow!(
                "{}: y has {} elems, batch {batch} needs {}",
                self.eval.name(),
                y.len(),
                batch * self.model.num_classes
            ));
        }
        let (eval_out_loss, eval_out_acc) = (self.eval_out_loss, self.eval_out_acc);
        let Session {
            eval,
            model,
            state,
            bufs,
            eval_outs,
            eval_kw_buf,
            eval_ka_buf,
            x_idx,
            y_idx,
            eval_quant,
            ..
        } = self;
        let ragged: Option<(Buffer, Buffer)> = if batch == model.batch {
            bufs[*x_idx].fill_from(x)?;
            bufs[*y_idx].fill_from(y)?;
            None
        } else {
            if !eval.batch_polymorphic() {
                return Err(anyhow!(
                    "{}: backend executes fixed shapes; batch {batch} != manifest {}",
                    eval.name(),
                    model.batch
                ));
            }
            Some(model.batch_buffers(batch, x, y)?)
        };
        if *eval_quant {
            let kw = kw.ok_or_else(|| anyhow!("{}: quantized eval needs kw", eval.name()))?;
            eval_kw_buf.fill_from(kw)?;
            eval_ka_buf.data[0] = ka;
        }
        let mut args: Vec<&Buffer> = Vec::with_capacity(state.params.len() + 4);
        args.extend(state.params.iter());
        match &ragged {
            Some((xb, yb)) => {
                args.push(xb);
                args.push(yb);
            }
            None => {
                args.push(&bufs[*x_idx]);
                args.push(&bufs[*y_idx]);
            }
        }
        if *eval_quant {
            args.push(eval_kw_buf);
            args.push(eval_ka_buf);
        }
        eval.call_into(&args, eval_outs)?;
        Ok((eval_outs[eval_out_loss].data[0], eval_outs[eval_out_acc].data[0]))
    }

    /// Freeze the current state into a deployable [`FrozenModel`]: every
    /// quantized layer's weights become bit-packed integer codes at the
    /// layer's bitwidth (learned from beta for waveq programs, the preset
    /// `kw` for dorefa/wrpn), plus the DoReFa/WRPN scale; everything else
    /// stays f32. `ka` is the activation level count captured into the
    /// artifact (ignored — no act fake-quant — for fp32 programs).
    ///
    /// The packed codes satisfy the exact-unpack contract: decoding them
    /// reproduces the fake-quantized weights the eval program computes from
    /// this state bit-for-bit, so a frozen `InferenceSession` serves logits
    /// bitwise identical to [`Session::eval`] at the same `kw`/`ka`.
    pub fn freeze(&self, ka: f32) -> Result<FrozenModel> {
        #[derive(PartialEq)]
        enum FreezeQuant {
            None,
            Dorefa,
            Wrpn,
        }
        let prog = self.train.name();
        let (quant, kw): (FreezeQuant, Vec<f32>) = if prog.starts_with("train_fp32_") {
            (FreezeQuant::None, Vec::new())
        } else if prog.starts_with("train_waveq_") {
            // Eq. 2.4 at the current beta (the same `ceil_bits` mapping the
            // coordinator's BitAssignment applies), so freezing mid-training
            // or post-snap both land on integer bits.
            let kw = self
                .state
                .beta
                .iter()
                .map(|&b| (2u32.pow(kn::ceil_bits(b)) - 1) as f32)
                .collect();
            (FreezeQuant::Dorefa, kw)
        } else if prog.starts_with("train_dorefa_") || prog.starts_with("train_wrpn_") {
            let kw = self
                .slots
                .iter()
                .enumerate()
                .find_map(|(i, s)| match s {
                    Slot::KwVec => Some(self.bufs[i].data.clone()),
                    _ => None,
                })
                .ok_or_else(|| anyhow!("{prog}: program has no kw input to freeze from"))?;
            let q = if prog.starts_with("train_wrpn_") {
                FreezeQuant::Wrpn
            } else {
                FreezeQuant::Dorefa
            };
            (q, kw)
        } else {
            return Err(anyhow!("{prog}: unknown program family; cannot freeze"));
        };

        // Bitwidths from the level counts: k must be 2^b - 1 with b in
        // [2, 8] for the codes to bit-pack (a >8-bit preset has no packed
        // representation — keep serving it from the live f32 state).
        let mut layer_bits = vec![0u8; kw.len()];
        for (q, &k) in kw.iter().enumerate() {
            let levels = k as f64;
            let b = (levels + 1.0).log2();
            let bi = b.round() as i64;
            if levels.fract() != 0.0 || (b - bi as f64).abs() > 1e-9 || !(2..=8).contains(&bi) {
                return Err(anyhow!(
                    "{prog}: qlayer {q} has k = {k}; freeze needs k = 2^b - 1 with b in [2, 8]"
                ));
            }
            layer_bits[q] = bi as u8;
        }

        let mut params = Vec::with_capacity(self.model.params.len());
        for (p, buf) in self.model.params.iter().zip(self.state.params.iter()) {
            let storage = match (p.qidx, &quant) {
                (Some(q), FreezeQuant::Dorefa) => {
                    let (codes, scale) = kn::dorefa_codes(&buf.data, kw[q]);
                    ParamStorage::Packed { bits: layer_bits[q], scale, codes }
                }
                (Some(q), FreezeQuant::Wrpn) => {
                    let (codes, scale) = kn::wrpn_codes(&buf.data, kw[q]);
                    ParamStorage::Packed { bits: layer_bits[q], scale, codes }
                }
                _ => ParamStorage::F32(buf.data.clone()),
            };
            params.push(FrozenParam { name: p.name.clone(), shape: p.shape.clone(), storage });
        }
        let wm = self.model.width_mult.max(1);
        let base = match self.model.name.strip_suffix(&format!("_w{wm}")) {
            Some(b) if wm > 1 => b.to_string(),
            _ => self.model.name.clone(),
        };
        Ok(FrozenModel {
            base,
            width_mult: wm,
            act_levels: if quant == FreezeQuant::None { None } else { Some(ka) },
            params,
        })
    }

    /// Snapshot the live state to a v2 checkpoint (params named by the
    /// manifest layout, beta/vbeta, step counter, model name).
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        Checkpoint::from_state(&self.model, &self.state)?.save(path)
    }

    /// Restore a checkpoint into this session: validates the model name
    /// (v2) and every tensor name/shape against the manifest layout, then
    /// replaces params, beta/vbeta, and the step counter. Momenta are not
    /// checkpointed — velocities restart at zero.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        if !ck.model.is_empty() && ck.model != self.model.name {
            return Err(anyhow!(
                "checkpoint is for model '{}', session trains '{}'",
                ck.model,
                self.model.name
            ));
        }
        if ck.tensors.len() != self.model.params.len() {
            return Err(anyhow!(
                "checkpoint has {} tensors, model '{}' wants {}",
                ck.tensors.len(),
                self.model.name,
                self.model.params.len()
            ));
        }
        for ((name, t), p) in ck.tensors.iter().zip(self.model.params.iter()) {
            if name != &p.name || t.shape != p.shape {
                return Err(anyhow!(
                    "checkpoint tensor '{name}' {:?} does not match model param '{}' {:?}",
                    t.shape,
                    p.name,
                    p.shape
                ));
            }
        }
        if ck.beta.len() != self.model.num_qlayers || ck.vbeta.len() != self.model.num_qlayers {
            return Err(anyhow!(
                "checkpoint beta/vbeta have {}/{} entries, model wants {}",
                ck.beta.len(),
                ck.vbeta.len(),
                self.model.num_qlayers
            ));
        }
        let tensors: Vec<Tensor> = ck.tensors.into_iter().map(|(_, t)| t).collect();
        self.state.set_params(&tensors)?;
        for v in &mut self.state.vels {
            v.data.fill(0.0);
        }
        self.state.beta = ck.beta;
        self.state.vbeta = ck.vbeta;
        self.state.step = ck.step;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch_for(model: &ModelMeta) -> (Vec<f32>, Vec<f32>) {
        let pix: usize = model.input_shape.iter().product();
        let x: Vec<f32> = (0..model.batch * pix).map(|i| ((i as f32) * 0.11).sin()).collect();
        let mut y = vec![0.0f32; model.batch * model.num_classes];
        for r in 0..model.batch {
            y[r * model.num_classes + r % model.num_classes] = 1.0;
        }
        (x, y)
    }

    fn knobs() -> StepKnobs {
        StepKnobs {
            lr: 0.05,
            momentum: 0.9,
            lr_beta: 0.01,
            ka: 255.0,
            lambda_w: 0.1,
            lambda_beta: 0.01,
            beta_train: 1.0,
        }
    }

    #[test]
    fn waveq_session_steps_and_evaluates() {
        let rt = Runtime::native();
        let mut s = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_waveq_mlp".into(),
                eval_program: "eval_quant_mlp".into(),
                seed: 7,
                beta_init: 4.0,
                preset_kw: None,
            },
        )
        .unwrap();
        let (x, y) = batch_for(&s.model().clone());
        let m0 = s.step(&x, &y, &knobs()).unwrap();
        assert!(m0.loss.is_finite() && m0.ce.is_some() && m0.reg_w.is_some());
        let m1 = s.step(&x, &y, &knobs()).unwrap();
        assert!(m1.loss < m0.loss, "same batch twice must reduce loss");
        assert_eq!(s.state().step, 2);
        let kw = vec![15.0; s.model().num_qlayers];
        let (el, ea) = s.eval(&x, &y, Some(&kw), 255.0).unwrap();
        assert!(el.is_finite() && (0.0..=1.0).contains(&ea));
        // Another step after an eval keeps working (buffers are reusable).
        s.step(&x, &y, &knobs()).unwrap();
        assert_eq!(s.state().step, 3);
    }

    #[test]
    fn dorefa_session_requires_preset_kw() {
        let rt = Runtime::native();
        let missing = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_dorefa_mlp".into(),
                eval_program: "eval_quant_mlp".into(),
                seed: 1,
                beta_init: 4.0,
                preset_kw: None,
            },
        );
        assert!(missing.is_err(), "dorefa programs take kw; open must demand it");
        let mut s = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_dorefa_mlp".into(),
                eval_program: "eval_quant_mlp".into(),
                seed: 1,
                beta_init: 4.0,
                preset_kw: Some(vec![7.0; 2]),
            },
        )
        .unwrap();
        let (x, y) = batch_for(&s.model().clone());
        let m = s.step(&x, &y, &knobs()).unwrap();
        assert!(m.loss.is_finite() && m.ce.is_none());
    }

    #[test]
    fn open_rejects_non_train_programs() {
        let rt = Runtime::native();
        // reg_profile declares no model, so open fails at the model guard.
        let err = Session::open(
            &rt,
            &SessionCfg {
                train_program: "reg_profile".into(),
                eval_program: "eval_fp32_mlp".into(),
                seed: 1,
                beta_init: 4.0,
                preset_kw: None,
            },
        )
        .unwrap_err();
        assert!(format!("{err}").contains("declares no model"), "{err}");
        // An eval program has a model but no velocity inputs: rejected by
        // the w/v layout check.
        let err = Session::open(
            &rt,
            &SessionCfg {
                train_program: "eval_fp32_mlp".into(),
                eval_program: "eval_fp32_mlp".into(),
                seed: 1,
                beta_init: 4.0,
                preset_kw: None,
            },
        )
        .unwrap_err();
        assert!(format!("{err}").contains("v inputs"), "{err}");
    }

    #[test]
    fn eval_serves_ragged_batches_through_the_same_program() {
        // A 7-example batch (not the manifest 64) must evaluate cleanly and
        // agree bitwise with the legacy stringly-typed dispatch at the same
        // ragged shape.
        let rt = Runtime::native();
        let mut s = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_waveq_mlp".into(),
                eval_program: "eval_quant_mlp".into(),
                seed: 5,
                beta_init: 4.0,
                preset_kw: None,
            },
        )
        .unwrap();
        let m = s.model().clone();
        let pix: usize = m.input_shape.iter().product();
        let batch = 7usize;
        let x: Vec<f32> = (0..batch * pix).map(|i| ((i as f32) * 0.13).sin()).collect();
        let mut y = vec![0.0f32; batch * m.num_classes];
        for r in 0..batch {
            y[r * m.num_classes + r % m.num_classes] = 1.0;
        }
        let kw = vec![15.0f32; m.num_qlayers];
        let (l, a) = s.eval(&x, &y, Some(&kw), 255.0).unwrap();
        let mut args: Vec<Buffer> = s.state().params.to_vec();
        args.push(buffer_f32(&x, &[batch, 8, 8, 3]).unwrap());
        args.push(buffer_f32(&y, &[batch, m.num_classes]).unwrap());
        args.push(buffer_f32(&kw, &[kw.len()]).unwrap());
        args.push(Buffer::scalar(255.0));
        let outs = rt.execute("eval_quant_mlp", &args).unwrap();
        assert_eq!(l.to_bits(), outs[0].data[0].to_bits(), "ragged eval loss");
        assert_eq!(a.to_bits(), outs[1].data[0].to_bits(), "ragged eval acc");
        // Not a pixel multiple -> clean error; manifest batch still works.
        assert!(s.eval(&x[..10], &y, Some(&kw), 255.0).is_err());
        let (x, y) = batch_for(&m);
        assert!(s.eval(&x, &y, Some(&kw), 255.0).is_ok());
    }

    #[test]
    fn freeze_packs_exactly_the_quantized_layers() {
        let rt = Runtime::native();
        let s = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_waveq_simplenet5".into(),
                eval_program: "eval_quant_simplenet5".into(),
                seed: 9,
                beta_init: 3.0,
                preset_kw: None,
            },
        )
        .unwrap();
        let frozen = s.freeze(255.0).unwrap();
        assert_eq!(frozen.base, "simplenet5");
        assert_eq!(frozen.width_mult, 1);
        assert_eq!(frozen.act_levels, Some(255.0));
        assert_eq!(frozen.params.len(), s.model().params.len());
        // beta_init 3.0 -> every learned layer freezes at 3 bits.
        assert_eq!(frozen.layer_bits(), vec![3; s.model().num_qlayers]);
        // Packed layers are exactly the qidx slots; the rest stay f32.
        for (fp, p) in frozen.params.iter().zip(&s.model().params) {
            assert_eq!(
                matches!(fp.storage, ParamStorage::Packed { .. }),
                p.qidx.is_some(),
                "{}",
                p.name
            );
        }
        // Byte accounting: sum(ceil(n_l * b_l / 8)), >= 4x under f32.
        let want: usize = s
            .model()
            .params
            .iter()
            .filter(|p| p.qidx.is_some())
            .map(|p| (p.shape.iter().product::<usize>() * 3).div_ceil(8))
            .sum();
        assert_eq!(frozen.packed_weight_bytes(), want);
        assert!(frozen.f32_weight_bytes() >= 4 * frozen.packed_weight_bytes());
    }

    #[test]
    fn freeze_rejects_unpackable_presets() {
        let rt = Runtime::native();
        // A 16-bit preset (k = 65535) has no 2..=8-bit packed form.
        let s = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_dorefa_mlp".into(),
                eval_program: "eval_quant_mlp".into(),
                seed: 1,
                beta_init: 4.0,
                preset_kw: Some(vec![65535.0; 2]),
            },
        )
        .unwrap();
        let err = s.freeze(255.0).unwrap_err();
        assert!(format!("{err}").contains("k = 2^b - 1"), "{err}");
    }

    #[test]
    fn checkpoint_round_trips_through_the_session_helpers() {
        let rt = Runtime::native();
        let mut s = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_waveq_mlp".into(),
                eval_program: "eval_quant_mlp".into(),
                seed: 13,
                beta_init: 4.0,
                preset_kw: None,
            },
        )
        .unwrap();
        let (x, y) = batch_for(&s.model().clone());
        s.step(&x, &y, &knobs()).unwrap();
        s.step(&x, &y, &knobs()).unwrap();
        let path = std::env::temp_dir().join("waveq_session_ckpt_test.bin");
        s.save_checkpoint(&path).unwrap();
        let want_params: Vec<Vec<f32>> = s.state().params.iter().map(|b| b.data.clone()).collect();
        let want_beta = s.state().beta.clone();

        // Restore into a *fresh* session: params/beta/step come back, the
        // momenta restart at zero.
        let mut fresh = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_waveq_mlp".into(),
                eval_program: "eval_quant_mlp".into(),
                seed: 999,
                beta_init: 6.0,
                preset_kw: None,
            },
        )
        .unwrap();
        fresh.load_checkpoint(&path).unwrap();
        for (got, want) in fresh.state().params.iter().zip(&want_params) {
            assert_eq!(&got.data, want);
        }
        assert_eq!(fresh.state().beta, want_beta);
        assert_eq!(fresh.state().step, 2);
        assert!(fresh.state().vels.iter().all(|v| v.data.iter().all(|&x| x == 0.0)));

        // A different model rejects the checkpoint by name.
        let mut other = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_waveq_simplenet5".into(),
                eval_program: "eval_quant_simplenet5".into(),
                seed: 1,
                beta_init: 4.0,
                preset_kw: None,
            },
        )
        .unwrap();
        let err = other.load_checkpoint(&path).unwrap_err();
        assert!(format!("{err}").contains("is for model"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn step_rejects_wrong_batch_length() {
        let rt = Runtime::native();
        let mut s = Session::open(
            &rt,
            &SessionCfg {
                train_program: "train_fp32_mlp".into(),
                eval_program: "eval_fp32_mlp".into(),
                seed: 2,
                beta_init: 4.0,
                preset_kw: None,
            },
        )
        .unwrap();
        let (x, y) = batch_for(&s.model().clone());
        assert!(s.step(&x[..10], &y, &knobs()).is_err());
        assert!(s.step(&x, &y[..4], &knobs()).is_err());
        // A good call still works afterwards.
        assert!(s.step(&x, &y, &knobs()).is_ok());
    }
}
