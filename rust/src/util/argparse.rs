//! Tiny CLI argument parser (clap is not resolvable offline).
//!
//! Model: `waveq <subcommand> [--flag value]... [--switch]... [positional]...`
//! Flags may repeat the `--key=value` form. Unknown flags are an error so
//! typos fail fast.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Declarative spec: which `--flags` take values and which are bare switches.
pub struct ArgSpec<'a> {
    pub value_flags: &'a [&'a str],
    pub switch_flags: &'a [&'a str],
}

impl Args {
    pub fn parse(argv: &[String], spec: &ArgSpec) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if spec.switch_flags.contains(&key.as_str()) {
                    if inline_val.is_some() {
                        return Err(anyhow!("--{key} takes no value"));
                    }
                    out.switches.push(key);
                } else if spec.value_flags.contains(&key.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("--{key} requires a value"))?
                            .clone(),
                    };
                    out.flags.insert(key, val);
                } else {
                    return Err(anyhow!("unknown flag --{key}"));
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad float '{v}'")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad integer '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad integer '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec<'static> {
        ArgSpec {
            value_flags: &["model", "steps", "lr"],
            switch_flags: &["verbose", "from-scratch"],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(
            &argv(&["train", "--model", "mlp", "--steps=200", "--verbose", "extra"]),
            &spec(),
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 200);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&argv(&["x", "--nope"]), &spec()).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv(&["x", "--model"]), &spec()).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv(&["x", "--lr", "abc"]), &spec()).unwrap();
        assert!(a.get_f32("lr", 0.1).is_err());
        assert_eq!(a.get_f32("steps", 0.5).unwrap(), 0.5);
    }
}
