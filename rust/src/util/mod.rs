//! Substrate utilities implemented in-crate (the offline environment vendors
//! only the `xla` closure — no serde/clap/rand/criterion).

pub mod argparse;
pub mod json;
pub mod logging;
pub mod rng;
pub mod timer;
