//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! SplitMix64 for seeding / stream splitting and as the core generator —
//! passes BigCrush-level statistical quality for everything this project
//! needs (synthetic data, weight init, shuffles, property-test case
//! generation). Normal variates via Box–Muller.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zeros fixed point and decorrelate small seeds.
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (stable: same parent seed + tag => same child).
    pub fn split(&self, tag: u64) -> Rng {
        let mut mix = Rng::new(self.state ^ tag.wrapping_mul(0xA24BAED4963EE407));
        mix.next_u64();
        mix
    }

    pub fn next_u64(&mut self) -> u64 {
        // SplitMix64 step.
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ_but_are_stable() {
        let root = Rng::new(7);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let mut c1b = root.split(1);
        assert_ne!(c1.next_u64(), c2.next_u64());
        let _ = c1b.next_u64();
        assert_eq!(c1.next_u64(), c1b.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count={c}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
