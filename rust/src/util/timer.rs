//! Timing + micro-benchmark support (criterion is not resolvable offline).
//!
//! [`BenchRunner`] mirrors the criterion workflow: warmup, timed iterations,
//! and a summary with mean / p50 / p95 / p99 / throughput. `cargo bench`
//! targets are `harness = false` binaries built on this.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn from_samples(name: &str, mut samples: Vec<Duration>) -> BenchStats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((n as f64 - 1.0) * p).round() as usize];
        BenchStats {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: samples[n - 1],
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} iters={:<5} mean={:>10.3?} p50={:>10.3?} p95={:>10.3?} p99={:>10.3?} min={:>10.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.p99, self.min
        )
    }

    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

pub struct BenchRunner {
    pub warmup_iters: usize,
    pub measure_iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup_iters: 3, measure_iters: 20 }
    }
}

impl BenchRunner {
    pub fn new(warmup: usize, iters: usize) -> Self {
        BenchRunner { warmup_iters: warmup, measure_iters: iters }
    }

    /// Time `f` (which should do one unit of work); prints and returns stats.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let stats = BenchStats::from_samples(name, samples);
        println!("{}", stats.report());
        stats
    }
}

/// Simple scope timer for coarse phase accounting.
pub struct ScopeTimer {
    start: Instant,
}

impl ScopeTimer {
    pub fn start() -> Self {
        ScopeTimer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let samples = (1..=100).map(|i| Duration::from_micros(i)).collect();
        let s = BenchStats::from_samples("t", samples);
        assert_eq!(s.min, Duration::from_micros(1));
        assert_eq!(s.max, Duration::from_micros(100));
        assert!(s.p50 <= s.p95);
        assert!(s.p95 <= s.p99);
        assert_eq!(s.iters, 100);
    }

    #[test]
    fn runner_runs() {
        let mut count = 0;
        let r = BenchRunner::new(1, 5);
        let s = r.bench("noop", || count += 1);
        assert_eq!(count, 6);
        assert_eq!(s.iters, 5);
    }
}
