//! Minimal, dependency-free JSON parser + writer.
//!
//! serde/serde_json are not resolvable in this offline environment (only the
//! `xla` crate closure is vendored), so the manifest, config files and metric
//! dumps go through this module. It implements the full JSON grammar
//! (objects, arrays, strings with escapes incl. \uXXXX, numbers, bools,
//! null); numbers are held as f64 which is lossless for every value the
//! toolchain emits (shapes, counts < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name (manifest plumbing).
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ---- writer ------------------------------------------------------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialization lives behind `Display`, so `.to_string()` keeps working
/// at every call site via the blanket `ToString`.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                c => {
                    // Re-scan multi-byte UTF-8 from the raw slice.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or("truncated utf-8 sequence")?;
                        s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xf0..=0xf7 => 4,
        0xe0..=0xef => 3,
        0xc0..=0xdf => 2,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_usize().unwrap(), 2);
        assert!(a[2].get("b").unwrap().is_null());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"m":{"x":[0.5,-3,true,false,null],"s":"q\"uote"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integers_stay_integers_in_output() {
        let s = Json::Num(1024.0).to_string();
        assert_eq!(s, "1024");
    }
}
