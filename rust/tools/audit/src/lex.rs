//! A minimal `.rs` scanner: just enough lexical structure that the audit
//! rules never match text inside comments, string/char literals, or raw
//! strings — and so that `// SAFETY:` comment blocks can be tied to the
//! `unsafe` token they justify.
//!
//! This is intentionally not a full Rust lexer. It produces a flat token
//! stream of identifiers/numbers/single-character punctuation plus a
//! per-line record of comment text and whether the line carries any code.
//! That is sufficient for every rule in [`crate::rules`], and it keeps the
//! tool at zero dependencies (no `syn`, no `proc-macro2`).

/// One lexical token: an identifier/number, or a single punctuation char.
#[derive(Debug, Clone)]
pub struct Token {
    /// 1-based source line.
    pub line: u32,
    /// Identifier/number text, or a one-character punctuation string.
    pub text: String,
}

/// Lexical summary of one source file.
#[derive(Debug, Default)]
pub struct Scan {
    pub tokens: Vec<Token>,
    /// Comment text per 1-based line (all comments touching that line,
    /// concatenated). Lines without comments hold an empty string.
    pub line_comment: Vec<String>,
    /// Whether the 1-based line carries any non-comment token or literal.
    pub line_has_code: Vec<bool>,
}

impl Scan {
    fn grow_to(&mut self, line: u32) {
        let need = line as usize + 1;
        if self.line_comment.len() < need {
            self.line_comment.resize(need, String::new());
            self.line_has_code.resize(need, false);
        }
    }

    fn note_comment(&mut self, line: u32, text: &str) {
        self.grow_to(line);
        let slot = &mut self.line_comment[line as usize];
        if !slot.is_empty() {
            slot.push(' ');
        }
        slot.push_str(text.trim());
    }

    fn note_code(&mut self, line: u32) {
        self.grow_to(line);
        self.line_has_code[line as usize] = true;
    }

    /// Comment text on `line`, or `""` (also for out-of-range lines).
    pub fn comment_on(&self, line: u32) -> &str {
        self.line_comment.get(line as usize).map_or("", |s| s.as_str())
    }

    /// Whether `line` carries code (out-of-range lines report `false`).
    pub fn has_code(&self, line: u32) -> bool {
        self.line_has_code.get(line as usize).copied().unwrap_or(false)
    }

    /// The contiguous comment block justifying a token on `line`: the
    /// token's own line comment (if any) plus every comment-only line
    /// immediately above, concatenated top-down. This is how a
    /// `// SAFETY: …` block written above an `unsafe` site is recovered.
    pub fn comment_block_above(&self, line: u32) -> String {
        let mut parts: Vec<&str> = Vec::new();
        let mut l = line.saturating_sub(1);
        while l >= 1 && !self.has_code(l) && !self.comment_on(l).is_empty() {
            parts.push(self.comment_on(l));
            l -= 1;
        }
        parts.reverse();
        if !self.comment_on(line).is_empty() {
            parts.push(self.comment_on(line));
        }
        parts.join(" ")
    }
}

struct Lexer<'a> {
    chars: Vec<char>,
    i: usize,
    line: u32,
    scan: &'a mut Scan,
}

impl Lexer<'_> {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied();
        if let Some(c) = c {
            self.i += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn emit(&mut self, line: u32, text: String) {
        self.scan.note_code(line);
        self.scan.tokens.push(Token { line, text });
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.scan.note_comment(line, &text);
    }

    fn block_comment(&mut self) {
        // `self.i` sits on the `*` of `/*`. Nesting is honored; the
        // comment text is attributed line by line so comment-only lines
        // inside the block still count as comment lines.
        self.bump(); // consume '*'
        let mut depth = 1usize;
        let mut text = String::new();
        let mut line = self.line;
        while depth > 0 {
            match self.bump() {
                None => break,
                Some('*') if self.peek(0) == Some('/') => {
                    self.bump();
                    depth -= 1;
                }
                Some('/') if self.peek(0) == Some('*') => {
                    self.bump();
                    depth += 1;
                }
                Some('\n') => {
                    self.scan.note_comment(line, &text);
                    text.clear();
                    line = self.line;
                }
                Some(c) => text.push(c),
            }
        }
        self.scan.note_comment(line, &text);
    }

    fn string_literal(&mut self) {
        // `self.i` sits just past the opening `"`.
        self.scan.note_code(self.line);
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    self.bump();
                }
                '"' => return,
                _ => {}
            }
        }
    }

    /// Raw (byte) string: `self.i` sits on the first `#` or the `"`.
    fn raw_string(&mut self) {
        self.scan.note_code(self.line);
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        if self.peek(0) != Some('"') {
            return; // not actually a raw string (e.g. `r#ident`); move on
        }
        self.bump();
        loop {
            match self.bump() {
                None => return,
                Some('"') => {
                    let mut seen = 0usize;
                    while seen < hashes && self.peek(0) == Some('#') {
                        seen += 1;
                        self.bump();
                    }
                    if seen == hashes {
                        return;
                    }
                }
                Some(_) => {}
            }
        }
    }

    /// `'` disambiguation: char literal vs lifetime.
    fn quote(&mut self) {
        self.scan.note_code(self.line);
        match (self.peek(0), self.peek(1)) {
            // 'x' — a plain one-character literal (covers '_' too, which
            // would otherwise look like a lifetime).
            (Some(_), Some('\'')) => {
                self.bump();
                self.bump();
            }
            // '\n', '\u{..}' — escaped char literal, scan to the close.
            (Some('\\'), _) => {
                while let Some(c) = self.bump() {
                    if c == '\\' {
                        self.bump();
                    } else if c == '\'' {
                        break;
                    }
                }
            }
            // 'static, 'a — a lifetime: consume the identifier, no token.
            (Some(c), _) if c.is_alphanumeric() || c == '_' => {
                while let Some(c) = self.peek(0) {
                    if c.is_alphanumeric() || c == '_' {
                        self.bump();
                    } else {
                        break;
                    }
                }
            }
            _ => {}
        }
    }

    fn ident(&mut self, first: char) {
        let line = self.line;
        let mut text = String::new();
        text.push(first);
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        // r"…" / r#"…"# / b"…" / br#"…"# / b'…' prefixes.
        match (text.as_str(), self.peek(0)) {
            ("r" | "br" | "b", Some('"')) => {
                self.bump();
                if text == "b" {
                    self.string_literal();
                } else {
                    // already consumed the quote; treat as 0-hash raw body
                    self.raw_string_body(0);
                }
                return;
            }
            ("r" | "br", Some('#')) => {
                self.raw_string();
                return;
            }
            ("b", Some('\'')) => {
                self.bump();
                self.quote();
                return;
            }
            _ => {}
        }
        self.emit(line, text);
    }

    /// Raw-string body after the opening quote was already consumed.
    fn raw_string_body(&mut self, hashes: usize) {
        loop {
            match self.bump() {
                None => return,
                Some('"') => {
                    let mut seen = 0usize;
                    while seen < hashes && self.peek(0) == Some('#') {
                        seen += 1;
                        self.bump();
                    }
                    if seen == hashes {
                        return;
                    }
                }
                Some(_) => {}
            }
        }
    }

    fn number(&mut self, first: char) {
        let line = self.line;
        let mut text = String::new();
        text.push(first);
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else if c == '.' && self.peek(1).is_some_and(|d| d.is_ascii_digit()) {
                text.push(c);
                self.bump();
            } else if (c == '+' || c == '-')
                && (text.ends_with('e') || text.ends_with('E'))
                && text.contains('.')
            {
                // float exponent sign: 1.5e-3
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.emit(line, text);
    }

    fn run(&mut self) {
        while let Some(c) = self.peek(0) {
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.bump();
                self.bump();
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.bump();
                self.block_comment();
            } else if c == '"' {
                self.bump();
                self.string_literal();
            } else if c == '\'' {
                self.bump();
                self.quote();
            } else if c.is_alphabetic() || c == '_' {
                self.bump();
                self.ident(c);
            } else if c.is_ascii_digit() {
                self.bump();
                self.number(c);
            } else {
                let line = self.line;
                self.bump();
                self.emit(line, c.to_string());
            }
        }
    }
}

/// Scan `src`, producing the token stream + comment map the rules run on.
pub fn scan(src: &str) -> Scan {
    let mut out = Scan::default();
    let mut lexer = Lexer { chars: src.chars().collect(), i: 0, line: 1, scan: &mut out };
    lexer.run();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        scan(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_and_strings_are_not_tokens() {
        let src = "let a = \"thread::spawn\"; // thread::scope\nlet b = 1;";
        let toks = texts(src);
        assert_eq!(toks, ["let", "a", "=", ";", "let", "b", "1", ";"]);
    }

    #[test]
    fn raw_strings_and_char_literals_are_skipped() {
        let src = "let s = r#\"unsafe { \"x\" }\"#; let c = 'u'; let lt: &'static str = \"\";";
        let toks = texts(src);
        assert!(!toks.contains(&"unsafe".to_string()));
        assert!(!toks.contains(&"static".to_string()), "lifetime must not tokenize");
    }

    #[test]
    fn block_comments_nest_and_mark_lines() {
        let src = "/* a /* b */ c */ let x = 1;\n// tail\nlet y = 2;";
        let s = scan(src);
        assert!(s.has_code(1));
        assert!(!s.has_code(2));
        assert!(s.comment_on(2).contains("tail"));
        let toks: Vec<_> = s.tokens.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(toks, ["let", "x", "=", "1", ";", "let", "y", "=", "2", ";"]);
    }

    #[test]
    fn comment_block_above_collects_contiguous_lines() {
        let src = "let a = 1;\n// SAFETY: part one\n// part two\nunsafe { x() };\n";
        let s = scan(src);
        let block = s.comment_block_above(4);
        assert!(block.contains("SAFETY: part one part two"), "got: {block}");
    }

    #[test]
    fn numbers_do_not_swallow_method_calls() {
        let toks = texts("let x = 2f64.powf(beta) - 1.0e-3; let y = 1.max(2);");
        assert!(toks.contains(&"powf".to_string()));
        assert!(toks.contains(&"max".to_string()));
    }

    #[test]
    fn lines_are_tracked_per_token() {
        let s = scan("a\nb\n\nc");
        let lines: Vec<u32> = s.tokens.iter().map(|t| t.line).collect();
        assert_eq!(lines, [1, 2, 4]);
    }
}
