//! `waveq-audit` — a zero-dependency determinism/safety lint pass for the
//! WaveQ repo.
//!
//! The repo's headline guarantee — every result is bitwise identical at
//! any `WAVEQ_THREADS`, across train/freeze/serve — rests on a handful of
//! conventions: all parallelism goes through the audited pool, every
//! reduction keeps a fixed sequential-k chain, serialization never walks
//! a hash map, `unsafe` stays confined and justified. This tool turns
//! those conventions into machine-checked invariants (rules D1–D6, see
//! [`rules::Rule`]) the build must respect: it walks `src`, `benches`,
//! `tests` and `examples` under the crate root, applies each rule over a
//! comment/string-aware token stream, filters hits through the plain-text
//! allowlist (`tools/audit/allow.toml`), and exits nonzero on any
//! non-allowlisted violation. An inventory of every `unsafe` site (with
//! its `// SAFETY:` justification) is emitted in `AUDIT_report.json`.

// The lint tool holds itself to the strictest form of its own rule D4.
#![forbid(unsafe_code)]

pub mod allow;
pub mod lex;
pub mod report;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use allow::AllowEntry;
pub use report::Outcome;
pub use rules::{check_file, FileFindings, Rule, UnsafeSite, Violation};

/// Directories walked under the audit root, in deterministic order.
pub const WALKED_DIRS: &[&str] = &["src", "benches", "tests", "examples"];

/// Scan one in-memory source file: lex + all rules. `rel_path` decides
/// rule scoping (it is matched by suffix against the rule file sets), so
/// tests can exercise scoping with synthetic paths.
pub fn scan_source(rel_path: &str, src: &str) -> FileFindings {
    rules::check_file(rel_path, &lex::scan(src))
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    // Sorted walk => deterministic violation order => stable reports.
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Walk `root` and apply every rule, filtering through the allow entries.
pub fn run_audit(root: &Path, allow_entries: &[AllowEntry]) -> io::Result<Outcome> {
    let mut files: Vec<PathBuf> = Vec::new();
    for dir in WALKED_DIRS {
        let d = root.join(dir);
        if d.is_dir() {
            walk_rs(&d, &mut files)?;
        }
    }
    let mut violations = Vec::new();
    let mut allowed = Vec::new();
    let mut unsafe_inventory = Vec::new();
    let mut used = vec![false; allow_entries.len()];
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(path)?;
        let findings = scan_source(&rel, &src);
        unsafe_inventory.extend(findings.unsafe_sites);
        for v in findings.violations {
            match allow_entries.iter().position(|e| e.matches(&v)) {
                Some(i) => {
                    used[i] = true;
                    allowed.push((v, allow_entries[i].reason.clone()));
                }
                None => violations.push(v),
            }
        }
    }
    let unused_allow = allow_entries
        .iter()
        .zip(used.iter())
        .filter(|(_, u)| !**u)
        .map(|(e, _)| e.clone())
        .collect();
    Ok(Outcome {
        root: root.display().to_string(),
        files_scanned: files.len(),
        violations,
        allowed,
        unused_allow,
        unsafe_inventory,
    })
}

/// Load and parse the allow file; a missing file is an empty allowlist.
pub fn load_allow(path: &Path) -> Result<Vec<AllowEntry>, String> {
    match fs::read_to_string(path) {
        Ok(text) => allow::parse(&text),
        Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(Vec::new()),
        Err(e) => Err(format!("reading {}: {e}", path.display())),
    }
}
