//! The plain-text allowlist (`tools/audit/allow.toml`).
//!
//! One entry per line, `key=value` pairs separated by whitespace, values
//! optionally double-quoted (required when they contain spaces). `#`
//! starts a comment. Recognized keys:
//!
//! ```text
//! rule=D1 file=src/runtime/serve.rs [line=545] [fn=loopback_bench]
//!     [pattern=thread::scope] reason="why this site is sound"
//! ```
//!
//! `rule`, `file` and `reason` are mandatory; `line`, `fn` and `pattern`
//! narrow the match. `file` matches by path suffix so entries survive the
//! tree being audited from different roots. Entries that match nothing
//! are reported as *unused* (a warning, not a failure) so stale lines are
//! visible instead of silently hiding future regressions.

use crate::rules::{Rule, Violation};

#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: Rule,
    pub file: String,
    pub line: Option<u32>,
    pub func: Option<String>,
    pub pattern: Option<String>,
    pub reason: String,
    /// 1-based line in the allow file (for diagnostics).
    pub source_line: u32,
}

impl AllowEntry {
    pub fn matches(&self, v: &Violation) -> bool {
        self.rule == v.rule
            && (v.file == self.file || v.file.ends_with(&self.file))
            && self.line.is_none_or(|l| l == v.line)
            && self.func.as_deref().is_none_or(|f| v.in_fn.as_deref() == Some(f))
            && self.pattern.as_deref().is_none_or(|p| v.pattern == p)
    }
}

fn parse_rule(s: &str) -> Option<Rule> {
    match s {
        "D1" => Some(Rule::D1),
        "D2" => Some(Rule::D2),
        "D3" => Some(Rule::D3),
        "D4" => Some(Rule::D4),
        "D5" => Some(Rule::D5),
        "D6" => Some(Rule::D6),
        _ => None,
    }
}

/// Split one line into whitespace-separated fields, honoring double
/// quotes (quotes may start mid-field, as in `reason="…"`).
fn fields(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    for c in line.chars() {
        if c == '"' {
            in_quotes = !in_quotes;
        } else if c.is_whitespace() && !in_quotes {
            if !cur.is_empty() {
                out.push(std::mem::take(&mut cur));
            }
        } else {
            cur.push(c);
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Parse the allow file text. Malformed lines are hard errors — a typo in
/// the allowlist must not silently re-enable (or over-suppress) a rule.
pub fn parse(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut entries = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx as u32 + 1;
        // Truncate at the first `#` that sits outside double quotes (a
        // `#` inside a quoted value, e.g. reason="issue #12", is data).
        let mut in_quotes = false;
        let mut cut = raw.len();
        for (at, c) in raw.char_indices() {
            if c == '"' {
                in_quotes = !in_quotes;
            } else if c == '#' && !in_quotes {
                cut = at;
                break;
            }
        }
        let line = &raw[..cut];
        if line.trim().is_empty() {
            continue;
        }
        let mut rule = None;
        let mut file = None;
        let mut line_no = None;
        let mut func = None;
        let mut pattern = None;
        let mut reason = None;
        for field in fields(line) {
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| format!("allow line {lineno}: `{field}` is not key=value"))?;
            match key {
                "rule" => {
                    rule = Some(parse_rule(value).ok_or_else(|| {
                        format!("allow line {lineno}: unknown rule `{value}`")
                    })?);
                }
                "file" => file = Some(value.replace('\\', "/")),
                "line" => {
                    line_no = Some(value.parse::<u32>().map_err(|_| {
                        format!("allow line {lineno}: line=`{value}` is not a number")
                    })?);
                }
                "fn" => func = Some(value.to_string()),
                "pattern" => pattern = Some(value.to_string()),
                "reason" => reason = Some(value.to_string()),
                _ => return Err(format!("allow line {lineno}: unknown key `{key}`")),
            }
        }
        let rule = rule.ok_or_else(|| format!("allow line {lineno}: missing rule="))?;
        let file = file.ok_or_else(|| format!("allow line {lineno}: missing file="))?;
        let reason = reason.ok_or_else(|| format!("allow line {lineno}: missing reason="))?;
        if reason.trim().is_empty() {
            return Err(format!("allow line {lineno}: empty reason"));
        }
        entries.push(AllowEntry {
            rule,
            file,
            line: line_no,
            func,
            pattern,
            reason,
            source_line: lineno,
        });
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn violation(
        rule: Rule,
        file: &str,
        line: u32,
        pattern: &str,
        in_fn: Option<&str>,
    ) -> Violation {
        Violation {
            rule,
            file: file.to_string(),
            line,
            pattern: pattern.to_string(),
            in_fn: in_fn.map(str::to_string),
            message: String::new(),
        }
    }

    #[test]
    fn parses_and_matches_by_suffix_fn_and_pattern() {
        let text = "\
# comment line
rule=D1 file=src/runtime/serve.rs pattern=thread::scope reason=\"scoped bench clients\"
rule=D3 file=src/runtime/native/kernels.rs fn=max_abs reason=\"serial per-layer scale\"
";
        let entries = parse(text).unwrap();
        assert_eq!(entries.len(), 2);
        let v = violation(Rule::D1, "src/runtime/serve.rs", 545, "thread::scope", None);
        assert!(entries[0].matches(&v));
        let v2 = violation(Rule::D1, "src/runtime/serve.rs", 171, "thread::Builder", None);
        assert!(!entries[0].matches(&v2), "pattern= must narrow the match");
        let v3 =
            violation(Rule::D3, "src/runtime/native/kernels.rs", 45, ".fold(", Some("max_abs"));
        assert!(entries[1].matches(&v3));
        let v4 =
            violation(Rule::D3, "src/runtime/native/kernels.rs", 90, ".fold(", Some("other_fn"));
        assert!(!entries[1].matches(&v4), "fn= must narrow the match");
    }

    #[test]
    fn malformed_lines_are_hard_errors() {
        assert!(parse("rule=D9 file=x.rs reason=\"r\"").is_err());
        assert!(parse("file=x.rs reason=\"r\"").is_err());
        assert!(parse("rule=D1 file=x.rs").is_err());
        assert!(parse("rule=D1 file=x.rs reason=\"r\" bogus=1").is_err());
        assert!(parse("rule=D1 file=x.rs line=abc reason=\"r\"").is_err());
    }

    #[test]
    fn line_pin_and_hash_in_reason() {
        let entries =
            parse("rule=D5 file=a.rs line=7 reason=\"issue #12: legacy\" # trailing\n").unwrap();
        assert_eq!(entries[0].line, Some(7));
        assert_eq!(entries[0].reason, "issue #12: legacy");
        let v = violation(Rule::D5, "src/a.rs", 7, ".lock().unwrap()", None);
        assert!(entries[0].matches(&v));
        let v8 = violation(Rule::D5, "src/a.rs", 8, ".lock().unwrap()", None);
        assert!(!entries[0].matches(&v8));
    }
}
