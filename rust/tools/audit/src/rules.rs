//! The repo-specific determinism/safety rules (D1–D6).
//!
//! Every rule is lexical: it runs over the token stream from [`crate::lex`]
//! (so comments and string literals can never match) plus a little derived
//! context — the innermost enclosing `fn` name and whether the token sits
//! inside a `#[cfg(test)] mod …` block. The rules deliberately
//! over-approximate where order can leak (a false positive costs one
//! documented allowlist line, a false negative silently breaks the bitwise
//! determinism contract), but they are precise where safety is decidable
//! lexically: D2 tracks which names are bound to `HashMap`/`HashSet` and
//! flags only *iteration* over them — membership tests (`contains`, `get`,
//! `insert`, `len`, …) never observe bucket order and pass clean.

use crate::lex::{Scan, Token};

/// The audited invariants. See `tools/audit/allow.toml` and the README's
/// "Static analysis & the determinism contract" section for the rationale
/// behind each.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    /// All parallelism goes through audited entry points: no
    /// `thread::spawn` / `thread::scope` / `thread::Builder` / rayon /
    /// crossbeam outside `runtime/native/pool.rs` (intrinsic) and
    /// explicitly allowlisted sites.
    D1,
    /// No *iteration* over `HashMap`/`HashSet` in serialization/kernel/
    /// reduction files — bucket order is nondeterministic; use
    /// `BTreeMap`/`BTreeSet` or a sorted snapshot. Membership tests
    /// (`contains`/`get`/`insert`/`remove`/`len`/`entry`) are order-safe
    /// and pass without an allowlist entry.
    D2,
    /// No `.sum()` / `.product()` / `.fold()` in kernel files outside the
    /// named fixed-order reduction helpers — float reductions for
    /// association order, integer reductions (the int8 GEMM's i32/i64
    /// accumulation chains) for overflow/order discipline: every
    /// accumulation order must be pinned by a named helper, not an
    /// anonymous iterator chain.
    D3,
    /// Every `unsafe` carries a `// SAFETY:` justification, and
    /// `allow(unsafe_code)` appears only in `runtime/native/pool.rs`.
    D4,
    /// No `.lock().unwrap()` / `.lock().expect(…)` — locks must be
    /// poison-tolerant (`unwrap_or_else(|e| e.into_inner())`) so a
    /// panicking peer cannot wedge the pool/serve machinery.
    D5,
    /// No clocks (`Instant::now`, `SystemTime`) or environment reads
    /// inside kernel code — shard closures must be pure functions of
    /// their inputs or results stop being replayable.
    D6,
}

impl Rule {
    pub fn as_str(self) -> &'static str {
        match self {
            Rule::D1 => "D1",
            Rule::D2 => "D2",
            Rule::D3 => "D3",
            Rule::D4 => "D4",
            Rule::D5 => "D5",
            Rule::D6 => "D6",
        }
    }

    pub fn summary(self) -> &'static str {
        match self {
            Rule::D1 => "parallelism outside audited entry points",
            Rule::D2 => "hash-order nondeterminism in serialization/kernel code",
            Rule::D3 => "float/integer reduction outside fixed-order helpers",
            Rule::D4 => "unsafe without a SAFETY justification",
            Rule::D5 => "poison-propagating lock unwrap",
            Rule::D6 => "clock/env read in kernel code",
        }
    }
}

/// One rule hit, before allowlist filtering.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: Rule,
    /// Path relative to the audited root, `/`-separated.
    pub file: String,
    pub line: u32,
    /// Canonical matched pattern (what `pattern=` allow entries match).
    pub pattern: String,
    /// Innermost enclosing `fn` (what `fn=` allow entries match).
    pub in_fn: Option<String>,
    pub message: String,
}

/// One `unsafe` occurrence (reported even when justified — the audit's
/// JSON report is the crate's unsafe inventory).
#[derive(Debug, Clone)]
pub struct UnsafeSite {
    pub file: String,
    pub line: u32,
    /// "unsafe impl" / "unsafe fn" / "unsafe block" / "unsafe trait".
    pub kind: String,
    pub justified: bool,
    /// Text following `SAFETY:` in the adjoining comment block.
    pub justification: String,
}

/// Files whose *existence* of pool-style concurrency is the audited
/// design itself; D1 and the `allow(unsafe_code)` check are intrinsic
/// there. Everything else — including `runtime/serve.rs` and
/// `data/batcher.rs` — must carry an explicit allowlist entry, so the
/// allow file documents the full sanctioned concurrency surface.
const PARALLELISM_ROOT: &str = "src/runtime/native/pool.rs";

/// Serialization / kernel / reduction files in scope for D2 (hash-order
/// nondeterminism). These are the files whose output bytes or arithmetic
/// must be replayable bit-for-bit.
const ORDER_SENSITIVE_FILES: &[&str] = &[
    "src/util/json.rs",
    "src/runtime/io.rs",
    "src/runtime/artifact.rs",
    "src/runtime/checkpoint.rs",
    "src/runtime/manifest.rs",
    "src/runtime/native/mod.rs",
    "src/runtime/native/kernels.rs",
    "src/runtime/native/models.rs",
    "src/bench_support.rs",
    "src/pareto.rs",
];

/// Kernel files in scope for D3 (reduction order) and D6 (clocks/env).
const KERNEL_FILES: &[&str] =
    &["src/runtime/native/kernels.rs", "src/runtime/native/models.rs"];

/// Methods whose results observe hash bucket order (D2 iteration sites).
const HASH_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Pass 1 of D2: every name lexically bound to a `HashMap`/`HashSet` in
/// this file (`let m = HashMap::new()`, `m: HashMap<…>` fields and params).
/// From each `HashMap`/`HashSet` token the scan walks back over type/path
/// punctuation (`:` `=` `<` `&` `mut` `std` `collections`) to the first
/// identifier and records it. The walk stops at `,`, so a hash collection
/// nested as the *value* of an ordered container
/// (`BTreeMap<String, HashSet<u32>>`) never binds the outer map's name,
/// and stops at declaration keywords so `use` imports bind nothing.
fn hash_bound_names(tokens: &[Token]) -> std::collections::BTreeSet<String> {
    let mut bound = std::collections::BTreeSet::new();
    for (i, t) in tokens.iter().enumerate() {
        if t.text != "HashMap" && t.text != "HashSet" {
            continue;
        }
        let mut j = i;
        let mut budget = 12;
        while j > 0 && budget > 0 {
            j -= 1;
            budget -= 1;
            let w = tokens[j].text.as_str();
            if matches!(w, ":" | "=" | "<" | "&" | "mut" | "std" | "collections") {
                continue;
            }
            let ident = w.chars().next().is_some_and(|c| c.is_alphabetic() || c == '_');
            let keyword = matches!(
                w,
                "let" | "pub" | "use" | "fn" | "in" | "for" | "return" | "type" | "const"
                    | "static" | "where" | "impl" | "struct" | "enum" | "as" | "crate" | "super"
            );
            if ident && !keyword {
                bound.insert(w.to_string());
            }
            break;
        }
    }
    bound
}

fn in_file_set(file: &str, set: &[&str]) -> bool {
    set.iter().any(|s| file == *s || file.ends_with(s))
}

/// Per-token derived context (parallel to the token stream).
struct TokenCtx {
    fn_name: Option<String>,
    in_test: bool,
}

enum Ctx {
    Fn(String),
    TestMod,
    Other,
}

/// One pass over the tokens computing, for each token, the innermost
/// enclosing `fn` and whether it sits inside a `#[cfg(test)] mod`.
/// Brace tracking is approximate (struct literals and closures push
/// anonymous scopes) but exact enough for top-level items, which is all
/// the rules consult it for.
fn contexts(tokens: &[Token]) -> Vec<TokenCtx> {
    let mut stack: Vec<Ctx> = Vec::new();
    let mut pending: Option<Ctx> = None;
    let mut out = Vec::with_capacity(tokens.len());
    let snapshot = |stack: &[Ctx]| {
        let fn_name = stack.iter().rev().find_map(|c| match c {
            Ctx::Fn(name) => Some(name.clone()),
            _ => None,
        });
        let in_test = stack.iter().any(|c| matches!(c, Ctx::TestMod));
        TokenCtx { fn_name, in_test }
    };
    for (i, t) in tokens.iter().enumerate() {
        match t.text.as_str() {
            "{" => {
                stack.push(pending.take().unwrap_or(Ctx::Other));
                out.push(snapshot(&stack));
            }
            "}" => {
                out.push(snapshot(&stack));
                stack.pop();
            }
            ";" => {
                pending = None;
                out.push(snapshot(&stack));
            }
            "fn" => {
                if let Some(name) = tokens.get(i + 1) {
                    pending = Some(Ctx::Fn(name.text.clone()));
                }
                out.push(snapshot(&stack));
            }
            "mod" => {
                // `#[cfg(test)]` lookback: the attribute tokens sit within
                // a few tokens of the `mod` keyword.
                let lo = i.saturating_sub(8);
                let test = tokens[lo..i]
                    .windows(3)
                    .any(|w| w[0].text == "cfg" && w[1].text == "(" && w[2].text == "test");
                pending = Some(if test { Ctx::TestMod } else { Ctx::Other });
                out.push(snapshot(&stack));
            }
            _ => out.push(snapshot(&stack)),
        }
    }
    out
}

fn seq_at(tokens: &[Token], i: usize, pat: &[&str]) -> bool {
    tokens.len() - i >= pat.len() && pat.iter().enumerate().all(|(j, p)| tokens[i + j].text == *p)
}

/// Everything the rules produce for one file.
#[derive(Debug, Default)]
pub struct FileFindings {
    pub violations: Vec<Violation>,
    pub unsafe_sites: Vec<UnsafeSite>,
}

/// Run every rule over one scanned file. `file` is the root-relative,
/// `/`-separated path; scoping decisions key off its suffix.
pub fn check_file(file: &str, scan: &Scan) -> FileFindings {
    let mut violations: Vec<Violation> = Vec::new();
    let mut unsafe_sites: Vec<UnsafeSite> = Vec::new();
    let tokens = &scan.tokens;
    let ctx = contexts(tokens);
    let is_pool = file == PARALLELISM_ROOT || file.ends_with(PARALLELISM_ROOT);
    let order_sensitive = in_file_set(file, ORDER_SENSITIVE_FILES);
    let kernel_file = in_file_set(file, KERNEL_FILES);
    let hash_bound = if order_sensitive {
        hash_bound_names(tokens)
    } else {
        std::collections::BTreeSet::new()
    };

    let mut push = |rule: Rule, line: u32, pattern: &str, in_fn: Option<String>, msg: String| {
        violations.push(Violation {
            rule,
            file: file.to_string(),
            line,
            pattern: pattern.to_string(),
            in_fn,
            message: msg,
        });
    };

    for (i, t) in tokens.iter().enumerate() {
        let text = t.text.as_str();
        let line = t.line;

        // ---- D1: parallelism through audited entry points ----------------
        // (the lexer emits `::` as two `:` tokens, hence the split paths)
        if !is_pool {
            let d1_hit = if seq_at(tokens, i, &["thread", ":", ":", "spawn"]) {
                Some("thread::spawn")
            } else if seq_at(tokens, i, &["thread", ":", ":", "scope"]) {
                Some("thread::scope")
            } else if seq_at(tokens, i, &["thread", ":", ":", "Builder"]) {
                Some("thread::Builder")
            } else if text == "rayon" || text == "crossbeam" {
                Some("external thread pool")
            } else {
                None
            };
            if let Some(pat) = d1_hit {
                let pat = if pat == "external thread pool" { text } else { pat };
                push(
                    Rule::D1,
                    line,
                    pat,
                    ctx[i].fn_name.clone(),
                    format!(
                        "`{pat}` outside runtime/native/pool.rs — route the work through \
                         pool::run_rows or add a justified allowlist entry"
                    ),
                );
            }
        }

        // ---- D2: hash-order nondeterminism (iteration sites only) --------
        if order_sensitive {
            // `name.iter()` / `.keys()` / … on a hash-bound name.
            if hash_bound.contains(text)
                && tokens.get(i + 1).is_some_and(|t| t.text == ".")
                && tokens.get(i + 2).is_some_and(|t| HASH_ITER_METHODS.contains(&t.text.as_str()))
                && tokens.get(i + 3).is_some_and(|t| t.text == "(")
            {
                let method = tokens[i + 2].text.clone();
                push(
                    Rule::D2,
                    line,
                    &format!(".{method}("),
                    ctx[i].fn_name.clone(),
                    format!(
                        "iteration over hash-ordered `{text}` via `.{method}()` — bucket \
                         order varies per process; use BTreeMap/BTreeSet or a sorted \
                         snapshot (membership tests like `.get`/`.contains` are fine)"
                    ),
                );
            }
            // `for x in [&[mut]] name {` on a hash-bound name.
            if text == "in" {
                let mut j = i + 1;
                while tokens.get(j).is_some_and(|t| t.text == "&" || t.text == "mut") {
                    j += 1;
                }
                if tokens.get(j).is_some_and(|t| hash_bound.contains(&t.text))
                    && tokens.get(j + 1).is_some_and(|t| t.text == "{")
                {
                    let name = tokens[j].text.clone();
                    push(
                        Rule::D2,
                        line,
                        "for-in",
                        ctx[i].fn_name.clone(),
                        format!(
                            "`for … in {name}` iterates a hash-ordered collection — bucket \
                             order varies per process; use BTreeMap/BTreeSet or a sorted \
                             snapshot"
                        ),
                    );
                }
            }
        }

        // ---- D3: fixed-order reductions (float AND integer accumulators) -
        if kernel_file && !ctx[i].in_test && text == "." {
            if let Some(next) = tokens.get(i + 1) {
                let name = next.text.as_str();
                if (name == "sum" || name == "product" || name == "fold")
                    && tokens.get(i + 2).is_some_and(|t| t.text == "(" || t.text == ":")
                {
                    let pat = format!(".{name}(");
                    let in_fn = ctx[i].fn_name.clone();
                    let fn_label = in_fn.as_deref().unwrap_or("?").to_string();
                    push(
                        Rule::D3,
                        line,
                        &pat,
                        in_fn,
                        format!(
                            "iterator reduction `{pat}` in kernel fn `{fn_label}` — only the \
                             named fixed-order helpers may reduce, whether the accumulator \
                             is float (association order) or i32/i64 (the int8 GEMM's \
                             overflow/order discipline); allowlist fn= entries"
                        ),
                    );
                }
            }
        }

        // ---- D4: SAFETY-justified unsafe + confined allow(unsafe_code) ---
        if text == "unsafe" {
            let kind = match tokens.get(i + 1).map(|t| t.text.as_str()) {
                Some("impl") => "unsafe impl",
                Some("fn") => "unsafe fn",
                Some("trait") => "unsafe trait",
                Some("{") => "unsafe block",
                _ => "unsafe",
            };
            let block = scan.comment_block_above(line);
            let justified = block.contains("SAFETY:");
            let justification = match block.find("SAFETY:") {
                Some(at) => block[at + "SAFETY:".len()..].trim().to_string(),
                None => String::new(),
            };
            unsafe_sites.push(UnsafeSite {
                file: file.to_string(),
                line,
                kind: kind.to_string(),
                justified,
                justification,
            });
            if !justified {
                push(
                    Rule::D4,
                    line,
                    kind,
                    ctx[i].fn_name.clone(),
                    format!(
                        "`{kind}` without a `// SAFETY:` comment on the preceding lines — \
                         state why the erased lifetimes/aliasing are sound"
                    ),
                );
            }
        }
        if !is_pool && seq_at(tokens, i, &["allow", "(", "unsafe_code", ")"]) {
            push(
                Rule::D4,
                line,
                "allow(unsafe_code)",
                ctx[i].fn_name.clone(),
                "`allow(unsafe_code)` outside runtime/native/pool.rs — the unsafe surface \
                 must not grow silently"
                    .to_string(),
            );
        }

        // ---- D5: poison-tolerant locks -----------------------------------
        if seq_at(tokens, i, &[".", "lock", "(", ")", ".", "unwrap", "("]) {
            push(
                Rule::D5,
                line,
                ".lock().unwrap()",
                ctx[i].fn_name.clone(),
                "`.lock().unwrap()` propagates poison — use \
                 `.lock().unwrap_or_else(|e| e.into_inner())` so a panicking peer cannot \
                 wedge the lock"
                    .to_string(),
            );
        } else if seq_at(tokens, i, &[".", "lock", "(", ")", ".", "expect", "("]) {
            push(
                Rule::D5,
                line,
                ".lock().expect(",
                ctx[i].fn_name.clone(),
                "`.lock().expect(…)` propagates poison — use \
                 `.lock().unwrap_or_else(|e| e.into_inner())`"
                    .to_string(),
            );
        }

        // ---- D6: no clocks/env in kernel code ----------------------------
        if kernel_file && !ctx[i].in_test {
            let d6_hit = if seq_at(tokens, i, &["Instant", ":", ":", "now"]) {
                Some("Instant::now")
            } else if text == "SystemTime" {
                Some("SystemTime")
            } else if seq_at(tokens, i, &["env", ":", ":"]) {
                Some("env::")
            } else {
                None
            };
            if let Some(pat) = d6_hit {
                push(
                    Rule::D6,
                    line,
                    pat,
                    ctx[i].fn_name.clone(),
                    format!(
                        "`{pat}` in kernel code — shard closures must be pure functions of \
                         their inputs (clocks/env make results non-replayable)"
                    ),
                );
            }
        }
    }
    drop(push);
    FileFindings { violations, unsafe_sites }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lex::scan;

    fn violations(file: &str, src: &str) -> Vec<Violation> {
        check_file(file, &scan(src)).violations
    }

    #[test]
    fn contexts_track_fns_and_test_mods() {
        let src = "fn alpha() { x(); }\n#[cfg(test)]\nmod tests {\n fn beta() { y(); } }\n";
        let s = scan(src);
        let ctx = contexts(&s.tokens);
        let x_at = s.tokens.iter().position(|t| t.text == "x").unwrap();
        let y_at = s.tokens.iter().position(|t| t.text == "y").unwrap();
        assert_eq!(ctx[x_at].fn_name.as_deref(), Some("alpha"));
        assert!(!ctx[x_at].in_test);
        assert_eq!(ctx[y_at].fn_name.as_deref(), Some("beta"));
        assert!(ctx[y_at].in_test);
    }

    #[test]
    fn d1_is_intrinsic_in_pool() {
        let src = "fn go() { std::thread::spawn(|| {}); }";
        assert!(violations("src/runtime/native/pool.rs", src).is_empty());
        let hits = violations("src/data/loader.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, Rule::D1);
        assert_eq!(hits[0].pattern, "thread::spawn");
    }

    #[test]
    fn d2_flags_iteration_not_membership() {
        // Membership-only traffic on a hash map is order-safe → clean.
        let src = "fn f() { let mut m = std::collections::HashMap::new(); m.insert(1, 2); \
                   if m.contains_key(&1) { hit(); } let _ = m.get(&1); let _n = m.len(); }";
        assert!(violations("src/runtime/io.rs", src).is_empty());
        // A bare declaration with no iteration is clean too.
        let src = "fn f() { let m = std::collections::HashMap::<u32, u32>::new(); drop(m); }";
        assert!(violations("src/runtime/io.rs", src).is_empty());
        // Method-style iteration is flagged.
        let src = "fn f() { let m = std::collections::HashMap::<u32, u32>::new(); \
                   for k in m.keys() { go(k); } }";
        let hits = violations("src/runtime/io.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, Rule::D2);
        assert_eq!(hits[0].pattern, ".keys(");
        // `for … in &map { … }` is flagged, through a reference and a param.
        let src = "fn f(m: &std::collections::HashMap<u32, u32>) { \
                   for (k, v) in m { use_(k, v); } for x in &m { go(x); } }";
        let hits = violations("src/runtime/io.rs", src);
        assert_eq!(hits.len(), 2);
        assert!(hits.iter().all(|h| h.pattern == "for-in"));
        // Out-of-scope files are untouched either way.
        let src = "fn f() { let s = std::collections::HashSet::from([1]); \
                   let v: Vec<u32> = s.iter().copied().collect(); drop(v); }";
        assert_eq!(violations("src/runtime/io.rs", src).len(), 1);
        assert!(violations("src/config.rs", src).is_empty());
    }

    #[test]
    fn d2_nested_hash_value_type_does_not_bind_the_ordered_outer_name() {
        // `m` is a BTreeMap (ordered): iterating it is fine even though its
        // value type is a HashSet — only `s.iter()`-style iteration on the
        // hash side would be flagged, and membership there is clean.
        let src = "fn f(m: &std::collections::BTreeMap<String, std::collections::HashSet<u32>>) \
                   { for (k, s) in m { if s.contains(&1) { hit(k); } } }";
        assert!(violations("src/runtime/io.rs", src).is_empty());
    }

    #[test]
    fn d3_skips_test_modules() {
        let src = "#[cfg(test)]\nmod tests {\n fn t() { let s: f32 = v.iter().sum(); } }\n";
        assert!(violations("src/runtime/native/kernels.rs", src).is_empty());
    }

    #[test]
    fn d4_classifies_and_requires_safety() {
        let src = "// SAFETY: latch-guarded.\nunsafe impl Send for T {}\nunsafe { go() };\n";
        let f = check_file("src/runtime/native/pool.rs", &scan(src));
        assert_eq!(f.unsafe_sites.len(), 2);
        assert_eq!(f.unsafe_sites[0].kind, "unsafe impl");
        assert!(f.unsafe_sites[0].justified);
        assert_eq!(f.unsafe_sites[0].justification, "latch-guarded.");
        assert_eq!(f.unsafe_sites[1].kind, "unsafe block");
        assert!(!f.unsafe_sites[1].justified);
        assert_eq!(f.violations.len(), 1);
        assert_eq!(f.violations[0].line, 3);
    }
}
